"""Pytest bootstrap: put `python/` on sys.path so `from compile import
...` resolves no matter where pytest is invoked from (repo root as in
CI, `python/`, or anywhere with an absolute path — this conftest sits
in the test directory itself, so it is always collected)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
