"""Core correctness signal: Pallas kernels vs pure-jnp oracle, bit-exact.

Fixed-shape tests at the paper's Fig 5 case-study shapes; the hypothesis
shape/value sweeps live in test_sweeps.py.
"""

import numpy as np
import pytest

from compile.kernels import conv2d_i32, fft_q15, matmul_i32, ref
from compile import model

RNG = np.random.default_rng(0xFE)


def rand_i32(shape, lo=-(2**15), hi=2**15):
    return RNG.integers(lo, hi, size=shape, dtype=np.int64).astype(np.int32)


class TestMatmul:
    def test_paper_shape(self):
        a = rand_i32(model.MM_A_SHAPE)
        b = rand_i32(model.MM_B_SHAPE)
        np.testing.assert_array_equal(matmul_i32(a, b), ref.matmul_i32(a, b))

    def test_identity(self):
        a = rand_i32((16, 16))
        eye = np.eye(16, dtype=np.int32)
        np.testing.assert_array_equal(matmul_i32(a, eye), a)

    def test_wraparound(self):
        # INT32 overflow must wrap (two's complement), not saturate/trap.
        a = np.full((4, 4), 2**30, dtype=np.int32)
        b = np.full((4, 4), 4, dtype=np.int32)
        out = np.asarray(matmul_i32(a, b))
        np.testing.assert_array_equal(out, np.asarray(ref.matmul_i32(a, b)))

    def test_non_divisible_m(self):
        # 121 rows vs bm=32 exercises the zero-row padding path.
        a = rand_i32((121, 16))
        b = rand_i32((16, 4))
        for bm in (1, 7, 32, 121, 128):
            np.testing.assert_array_equal(
                matmul_i32(a, b, bm=bm), ref.matmul_i32(a, b)
            )

    def test_negative_values(self):
        a = rand_i32((5, 3), lo=-100, hi=0)
        b = rand_i32((3, 2), lo=-100, hi=0)
        np.testing.assert_array_equal(matmul_i32(a, b), ref.matmul_i32(a, b))


class TestConv2d:
    def test_paper_shape(self):
        x = rand_i32(model.CONV_X_SHAPE)
        w = rand_i32(model.CONV_W_SHAPE)
        np.testing.assert_array_equal(conv2d_i32(x, w), ref.conv2d_i32(x, w))

    def test_single_filter_delta(self):
        # A delta filter reproduces the input channel sum shifted.
        x = rand_i32((8, 8, 1))
        w = np.zeros((1, 3, 3, 1), dtype=np.int32)
        w[0, 1, 1, 0] = 1
        out = np.asarray(conv2d_i32(x, w))
        np.testing.assert_array_equal(out[:, :, 0], np.asarray(x)[1:7, 1:7, 0])

    def test_filter_block_padding(self):
        x = rand_i32((10, 10, 2))
        w = rand_i32((5, 3, 3, 2))  # 5 filters vs bf=8 -> padding
        for bf in (1, 3, 5, 8):
            np.testing.assert_array_equal(
                conv2d_i32(x, w, bf=bf), ref.conv2d_i32(x, w)
            )

    def test_1x1_kernel(self):
        x = rand_i32((6, 6, 3))
        w = rand_i32((4, 1, 1, 3))
        np.testing.assert_array_equal(conv2d_i32(x, w), ref.conv2d_i32(x, w))


class TestFft:
    def test_paper_shape_512(self):
        re = rand_i32((512,))
        im = rand_i32((512,))
        pr, pi = fft_q15(re, im)
        rr, ri = ref.fft_q15(re, im)
        np.testing.assert_array_equal(pr, rr)
        np.testing.assert_array_equal(pi, ri)

    @pytest.mark.parametrize("n", [2, 4, 8, 64, 256, 1024])
    def test_sizes(self, n):
        re = rand_i32((n,))
        im = rand_i32((n,))
        pr, pi = fft_q15(re, im)
        rr, ri = ref.fft_q15(re, im)
        np.testing.assert_array_equal(pr, rr)
        np.testing.assert_array_equal(pi, ri)

    def test_impulse(self):
        # FFT of unit impulse: flat spectrum scaled by 1/n (stage >>1 x log2 n).
        n = 64
        re = np.zeros(n, dtype=np.int32)
        re[0] = 1 << 15
        im = np.zeros(n, dtype=np.int32)
        pr, pi = fft_q15(re, im)
        expected = (1 << 15) >> 6  # scaled by 2^-log2(64)
        np.testing.assert_array_equal(np.asarray(pr), np.full(n, expected))
        np.testing.assert_array_equal(np.asarray(pi), np.zeros(n))

    def test_dc_signal(self):
        n = 32
        re = np.full(n, 1000, dtype=np.int32)
        im = np.zeros(n, dtype=np.int32)
        pr, pi = fft_q15(re, im)
        # all energy in bin 0: n * 1000 / n = 1000, minus Q15 attrition
        # (W^0 is clamped to 0x7FFF != 1.0, so each stage loses ~1/2^15).
        assert 990 <= int(np.asarray(pr)[0]) <= 1000
        assert np.abs(np.asarray(pr)[1:]).max() <= 2

    def test_matches_float_fft_approximately(self):
        # Sanity: fixed-point result tracks numpy's float FFT within
        # quantization error bounds.
        n = 256
        t = np.arange(n)
        sig = (10000 * np.sin(2 * np.pi * 8 * t / n)).astype(np.int32)
        pr, pi = fft_q15(sig, np.zeros(n, dtype=np.int32))
        flt = np.fft.fft(sig.astype(np.float64)) / n
        got = np.asarray(pr).astype(np.float64) + 1j * np.asarray(pi)
        err = np.abs(got - flt)
        assert err.max() < 40, err.max()  # Q15 + per-stage scaling noise


class TestClassifier:
    def _params(self):
        w1 = rand_i32((model.N_FEATS, model.N_HIDDEN), lo=-(2**14), hi=2**14)
        b1 = rand_i32((model.N_HIDDEN,), lo=-100, hi=100)
        w2 = rand_i32((model.N_HIDDEN, model.N_CLASSES), lo=-(2**14), hi=2**14)
        b2 = rand_i32((model.N_CLASSES,), lo=-100, hi=100)
        return w1, b1, w2, b2

    def test_model_vs_ref(self):
        window = rand_i32((model.FFT_N,))
        params = self._params()
        got = np.asarray(model.classifier(window, *params))
        want = np.asarray(ref.tinyai_classifier(window, *params))
        np.testing.assert_array_equal(got, want)

    def test_output_shape_and_dtype(self):
        window = rand_i32((model.FFT_N,))
        out = np.asarray(model.classifier(window, *self._params()))
        assert out.shape == (model.N_CLASSES,)
        assert out.dtype == np.int32

    def test_deterministic(self):
        window = rand_i32((model.FFT_N,))
        params = self._params()
        a = np.asarray(model.classifier(window, *params))
        b = np.asarray(model.classifier(window, *params))
        np.testing.assert_array_equal(a, b)
