"""Hypothesis sweeps: Pallas kernels vs oracle across shapes and values.

The system prompt contract for L1: hypothesis sweeps the Pallas kernels'
shapes/dtypes and asserts bit-exact agreement with ref.py. Integer kernels
means assert_array_equal, not allclose.
"""

import numpy as np
import pytest

# hypothesis is not part of the minimal offline image; the fixed-shape
# suite (test_kernel.py) still runs there, the sweeps need the full env.
pytest.importorskip("hypothesis", reason="hypothesis not installed (offline image)")
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d_i32, fft_q15, matmul_i32, ref

SETTINGS = dict(max_examples=25, deadline=None)


def arrays_i32(shape, lo=-(2**20), hi=2**20):
    return st.builds(
        lambda seed: np.random.default_rng(seed)
        .integers(lo, hi, size=shape, dtype=np.int64)
        .astype(np.int32),
        st.integers(0, 2**32 - 1),
    )


@settings(**SETTINGS)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 24),
    n=st.integers(1, 12),
    seed=st.integers(0, 2**32 - 1),
    bm=st.sampled_from([1, 4, 8, 32]),
)
def test_matmul_shapes(m, k, n, seed, bm):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**20), 2**20, size=(m, k), dtype=np.int64).astype(np.int32)
    b = rng.integers(-(2**20), 2**20, size=(k, n), dtype=np.int64).astype(np.int32)
    np.testing.assert_array_equal(matmul_i32(a, b, bm=bm), ref.matmul_i32(a, b))


@settings(**SETTINGS)
@given(
    h=st.integers(3, 20),
    w=st.integers(3, 20),
    cin=st.integers(1, 4),
    f=st.integers(1, 10),
    ksz=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**32 - 1),
)
def test_conv2d_shapes(h, w, cin, f, ksz, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-(2**12), 2**12, size=(h, w, cin), dtype=np.int64).astype(
        np.int32
    )
    wt = rng.integers(-(2**12), 2**12, size=(f, ksz, ksz, cin), dtype=np.int64).astype(
        np.int32
    )
    np.testing.assert_array_equal(conv2d_i32(x, wt), ref.conv2d_i32(x, wt))


@settings(**SETTINGS)
@given(
    logn=st.integers(1, 10),
    seed=st.integers(0, 2**32 - 1),
)
def test_fft_sizes(logn, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    re = rng.integers(-(2**15), 2**15, size=n, dtype=np.int64).astype(np.int32)
    im = rng.integers(-(2**15), 2**15, size=n, dtype=np.int64).astype(np.int32)
    pr, pi = fft_q15(re, im)
    rr, ri = ref.fft_q15(re, im)
    np.testing.assert_array_equal(pr, rr)
    np.testing.assert_array_equal(pi, ri)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**32 - 1))
def test_fft_extreme_values(seed):
    # int32 extremes: the >>1 per-stage scaling must prevent overflow.
    n = 64
    rng = np.random.default_rng(seed)
    choices = np.array(
        [np.iinfo(np.int32).min, np.iinfo(np.int32).max, 0, -1, 1], dtype=np.int32
    )
    re = choices[rng.integers(0, 5, size=n)]
    im = choices[rng.integers(0, 5, size=n)]
    pr, pi = fft_q15(re, im)
    rr, ri = ref.fft_q15(re, im)
    np.testing.assert_array_equal(pr, rr)
    np.testing.assert_array_equal(pi, ri)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**32 - 1))
def test_fft_linearity(seed):
    # Property: FFT(a) + FFT(b) == FFT(a+b) holds only approximately in
    # fixed point; check the bounded-error version (error <= stages).
    n = 128
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**12), 2**12, size=n, dtype=np.int64).astype(np.int32)
    b = rng.integers(-(2**12), 2**12, size=n, dtype=np.int64).astype(np.int32)
    ar, ai = ref.fft_q15(a, np.zeros(n, np.int32))
    br, bi = ref.fft_q15(b, np.zeros(n, np.int32))
    sr, si = ref.fft_q15(a + b, np.zeros(n, np.int32))
    stages = n.bit_length() - 1
    assert np.abs(np.asarray(ar) + np.asarray(br) - np.asarray(sr)).max() <= stages
    assert np.abs(np.asarray(ai) + np.asarray(bi) - np.asarray(si)).max() <= stages


@settings(**SETTINGS)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**32 - 1),
)
def test_matmul_identity_property(m, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**20), 2**20, size=(m, k), dtype=np.int64).astype(np.int32)
    eye = np.eye(k, dtype=np.int32)
    np.testing.assert_array_equal(matmul_i32(a, eye), a)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 12),
    k=st.integers(1, 12),
    n=st.integers(1, 6),
    seed=st.integers(0, 2**32 - 1),
)
def test_matmul_distributive_property(m, k, n, seed):
    # (A + B) @ C == A@C + B@C exactly under wrap-around int32.
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**18), 2**18, size=(m, k), dtype=np.int64).astype(np.int32)
    b = rng.integers(-(2**18), 2**18, size=(m, k), dtype=np.int64).astype(np.int32)
    c = rng.integers(-(2**18), 2**18, size=(k, n), dtype=np.int64).astype(np.int32)
    lhs = np.asarray(matmul_i32((a + b).astype(np.int32), c))
    rhs = (
        np.asarray(matmul_i32(a, c)).astype(np.int64)
        + np.asarray(matmul_i32(b, c)).astype(np.int64)
    ).astype(np.int32)
    np.testing.assert_array_equal(lhs, rhs)
