"""AOT artifact tests: lowering succeeds, HLO is parseable, manifest sane.

These guard the L2->runtime interchange contract (HLO text + manifest)
the Rust side depends on (rust/src/runtime/artifacts.rs).
"""

import json
import os
import tempfile

import jax
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_lower_all_entries():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.lower_all(d)
        assert set(manifest["entries"]) == {"matmul", "conv2d", "fft512", "model"}
        for name, e in manifest["entries"].items():
            path = os.path.join(d, e["file"])
            assert os.path.exists(path)
            text = open(path).read()
            # HLO text structural sanity
            assert "HloModule" in text
            assert "ENTRY" in text
            for a in e["args"]:
                assert a["dtype"] == "int32"


def test_manifest_shapes_match_model():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.lower_all(d)
        mm = manifest["entries"]["matmul"]
        assert mm["args"][0]["shape"] == list(model.MM_A_SHAPE)
        assert mm["args"][1]["shape"] == list(model.MM_B_SHAPE)
        assert mm["results"][0]["shape"] == [model.MM_A_SHAPE[0], model.MM_B_SHAPE[1]]
        fft = manifest["entries"]["fft512"]
        assert fft["args"][0]["shape"] == [model.FFT_N]
        assert len(fft["results"]) == 2
        cls = manifest["entries"]["model"]
        assert cls["results"][0]["shape"] == [model.N_CLASSES]


def test_manifest_json_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d)
        with open(os.path.join(d, "manifest.json")) as f:
            m = json.load(f)
        assert m["format"] == "hlo-text"
        assert m["return_tuple"] is True


def test_lowered_matmul_executes_like_oracle():
    # Execute the same jitted entry used for AOT and compare to oracle —
    # guards against the entry functions drifting from ref.
    rng = np.random.default_rng(7)
    a = rng.integers(-1000, 1000, size=model.MM_A_SHAPE, dtype=np.int64).astype(
        np.int32
    )
    b = rng.integers(-1000, 1000, size=model.MM_B_SHAPE, dtype=np.int64).astype(
        np.int32
    )
    got = np.asarray(jax.jit(model.mm_entry)(a, b))
    np.testing.assert_array_equal(got, ref.matmul_i32(a, b))


def test_hlo_has_no_custom_calls():
    # interpret=True must lower to plain HLO — a Mosaic custom-call would
    # be unexecutable by the CPU PJRT client on the Rust side.
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d)
        for name in ("matmul", "conv2d", "fft512", "model"):
            text = open(os.path.join(d, f"{name}.hlo.txt")).read()
            assert "custom-call" not in text, f"{name} contains a custom-call"
