"""L2: the end-to-end TinyAI pipeline model, built from the L1 kernels.

This is the compute graph the FEMU CS executes when an accelerator is
*virtualized* (paper §III-A "accelerator virtualization" / §V-B): the
X-HEEP guest writes operands into a mailbox DRAM region, the CS service
runs the functional model, and writes results back. In our stack the
functional models are these jitted JAX functions, AOT-lowered once by
`aot.py` to HLO text and executed from Rust via PJRT — Python never runs
at emulation time.

Exported entry points (all int32 in / int32 out):

  * mm_entry     — Fig 5 "MM":   (121,16) @ (16,4)
  * conv_entry   — Fig 5 "CONV": (16,16,3) map, (8,3,3,3) filters
  * fft_entry    — Fig 5 "FFT":  512-point Q15
  * model_entry  — §V-C-style classifier: 512-sample window -> FFT
                   features -> FC(64->32) -> ReLU -> FC(32->4) logits.

The classifier's numeric contract: inputs are 16-bit ADC samples
(|x| < 2^15), FC weights are Q15 (|w| <= 2^15), so 64-bit accumulators
never overflow and the Q15 shift is exact against the RV32 mul/mulh
implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import conv2d_i32, fft_q15, matmul_i32
from .kernels import fft as fft_kernel
from .kernels import ref

# --- Fig 5 case-study shapes (paper §V-B) ---------------------------------
MM_A_SHAPE = (121, 16)
MM_B_SHAPE = (16, 4)
CONV_X_SHAPE = (16, 16, 3)
CONV_W_SHAPE = (8, 3, 3, 3)
FFT_N = 512

# --- classifier dims (§V-C wood-moisture-style pipeline) ------------------
N_FEATS = 64
N_HIDDEN = 32
N_CLASSES = 4


def mm_entry(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return matmul_i32(a, b)


def conv_entry(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return conv2d_i32(x, w)


def fft_entry(re, im, *tables):
    # twiddle tables are artifact *parameters*: dense constants do not
    # survive the HLO-text interchange (see kernels/fft.py)
    return fft_kernel.fft_with_tables(re, im, tables)


def classifier(window: jnp.ndarray, w1, b1, w2, b2, tables=None) -> jnp.ndarray:
    """FFT features -> FC -> ReLU -> FC, all int32/Q15 (see ref oracle)."""
    im = jnp.zeros_like(window)
    if tables is None:
        fr, fi = fft_q15(window, im)
    else:
        fr, fi = fft_kernel.fft_with_tables(window, im, tables)
    feats = (jnp.abs(fr[:N_FEATS]) + jnp.abs(fi[:N_FEATS])).astype(jnp.int32)
    h = ref.relu_i32(ref.fc_q15(feats, w1, b1))
    return ref.fc_q15(h, w2, b2)


def model_entry(window, w1, b1, w2, b2, *tables):
    return classifier(window, w1, b1, w2, b2, tables)


def example_args():
    """ShapeDtypeStructs for AOT lowering of every entry point."""
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    table_specs = tuple(sds(shape, i32) for shape in fft_kernel.stage_table_shapes(FFT_N))
    return {
        "matmul": (mm_entry, (sds(MM_A_SHAPE, i32), sds(MM_B_SHAPE, i32))),
        "conv2d": (conv_entry, (sds(CONV_X_SHAPE, i32), sds(CONV_W_SHAPE, i32))),
        "fft512": (fft_entry, (sds((FFT_N,), i32), sds((FFT_N,), i32)) + table_specs),
        "model": (
            model_entry,
            (
                sds((FFT_N,), i32),
                sds((N_FEATS, N_HIDDEN), i32),
                sds((N_HIDDEN,), i32),
                sds((N_HIDDEN, N_CLASSES), i32),
                sds((N_CLASSES,), i32),
            )
            + table_specs,
        ),
    }
