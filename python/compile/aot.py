"""AOT: lower every L2 entry point to HLO *text* artifacts for Rust/PJRT.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the published `xla` 0.1.6 crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under artifacts/):
  <name>.hlo.txt   one per entry point (matmul, conv2d, fft512, model)
  manifest.json    arg/result shapes + dtypes, consumed by
                   rust/src/runtime/artifacts.rs

Run via `make artifacts`; a no-op when inputs are unchanged (make rule).

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "entries": {}}
    for name, (fn, args) in model.example_args().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *args)
        leaves = jax.tree_util.tree_leaves(out_tree)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
            ],
            "results": [
                {"shape": list(l.shape), "dtype": str(l.dtype)} for l in leaves
            ],
        }
        print(f"aot: {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None, help="compat: single-file target; "
                   "artifacts are emitted into its directory")
    args = p.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    lower_all(out_dir or ".")


if __name__ == "__main__":
    main()
