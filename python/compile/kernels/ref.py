"""Pure-jnp oracles for the FEMU accelerator kernels.

These are the bit-exact references every other implementation in the stack
must match:

  * the Pallas kernels in this package (checked by pytest/hypothesis),
  * the RV32 assembly kernels run on the emulated X-HEEP CPU,
  * the CGRA kernel mappings executed by the CGRA emulator,
  * the AOT artifacts executed from Rust through PJRT.

All arithmetic is integer: INT32 for MM/CONV (wrap-around two's-complement
semantics, matching RV32 `mul`/`add`) and Q15 fixed point for the FFT
(int32 data, int32 Q15 twiddles, 64-bit intermediate products shifted
arithmetically right by 15, matching RV32 `mul`+`mulh`).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

Q = 15  # Q15 fixed-point fractional bits used by the FFT and the model's
# fully-connected layers.


def matmul_i32(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """INT32 matrix multiply with two's-complement wrap-around.

    Matches the RV32IM `mul` (low 32 bits) accumulated with `add`.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    # int32 dot with wrap-around: XLA integer dot already wraps (two's
    # complement), same as the RV32 kernel.
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def conv2d_i32(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """INT32 2-D convolution, 'valid' padding, stride 1.

    x: (H, W, Cin) input feature map.
    w: (F, KH, KW, Cin) filters.
    returns (H-KH+1, W-KW+1, F).

    This is the paper's CONV case-study shape family (16x16x3 input,
    8 filters of 3x3) but implemented generically.
    """
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    h, wid, cin = x.shape
    f, kh, kw, cin2 = w.shape
    assert cin == cin2, (cin, cin2)
    oh, ow = h - kh + 1, wid - kw + 1
    # im2col: gather all (kh, kw, cin) patches, then a single integer dot.
    patches = jnp.stack(
        [
            x[i : i + oh, j : j + ow, :]
            for i in range(kh)
            for j in range(kw)
        ],
        axis=2,
    )  # (oh, ow, kh*kw, cin)
    patches = patches.reshape(oh, ow, kh * kw * cin)
    wmat = w.reshape(f, kh * kw * cin).T  # (kh*kw*cin, f)
    return jax.lax.dot_general(
        patches.reshape(oh * ow, -1),
        wmat,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).reshape(oh, ow, f)


def q15_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Q15 fixed-point multiply: (a * b) >> 15 with 64-bit intermediate.

    Arithmetic (sign-propagating) right shift — identical to the RV32
    sequence `mul`/`mulh` followed by a funnel shift, and to the CGRA
    MULQ15 functional unit.
    """
    prod = a.astype(jnp.int64) * b.astype(jnp.int64)
    return (prod >> Q).astype(jnp.int32)


def _bit_reverse_indices(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def twiddles_q15(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Q15 twiddle factors W_n^k = exp(-2*pi*i*k/n) for k in [0, n/2).

    Rounding rule is floor(x + 0.5) — a single documented rule shared
    with the Rust table generator (rust/src/workloads/signals.rs) so the
    tables are bit-identical across the stack. cos(0)=1.0 is clamped to
    0x7FFF to fit Q15.
    """
    k = np.arange(max(n // 2, 1))
    ang = -2.0 * np.pi * k / n
    scale = float(1 << Q)
    wr = np.floor(np.cos(ang) * scale + 0.5).astype(np.int64)
    wi = np.floor(np.sin(ang) * scale + 0.5).astype(np.int64)
    wr = np.clip(wr, -(1 << Q), (1 << Q) - 1).astype(np.int32)
    wi = np.clip(wi, -(1 << Q), (1 << Q) - 1).astype(np.int32)
    return wr, wi


def fft_q15(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Radix-2 DIT fixed-point FFT over int32 data with Q15 twiddles.

    Per-stage scaling by 1/2 (arithmetic >> 1) keeps the dynamic range
    bounded; the RV32 and CGRA implementations apply identical scaling,
    so outputs match bit-for-bit.
    """
    n = int(re.shape[0])
    assert n & (n - 1) == 0 and n >= 2, f"n must be a power of two, got {n}"
    rev = _bit_reverse_indices(n)
    wr_np, wi_np = twiddles_q15(n)
    re = jnp.asarray(re, dtype=jnp.int32)[rev]
    im = jnp.asarray(im, dtype=jnp.int32)[rev]
    wr = jnp.asarray(wr_np, dtype=jnp.int32)
    wi = jnp.asarray(wi_np, dtype=jnp.int32)

    stages = n.bit_length() - 1
    for s in range(1, stages + 1):
        m = 1 << s  # butterfly group size
        half = m // 2
        stride = n // m
        # indices of even/odd elements of every butterfly
        grp = jnp.arange(n // m) * m
        j = jnp.arange(half)
        even_idx = (grp[:, None] + j[None, :]).reshape(-1)
        odd_idx = even_idx + half
        tw_idx = jnp.tile(j * stride, n // m)

        er, ei = re[even_idx], im[even_idx]
        orr, oi = re[odd_idx], im[odd_idx]
        twr, twi = wr[tw_idx], wi[tw_idx]
        # t = W * odd  (Q15 complex multiply)
        tr = q15_mul(orr, twr) - q15_mul(oi, twi)
        ti = q15_mul(orr, twi) + q15_mul(oi, twr)
        # scaled butterfly: out = (even +/- t) >> 1
        new_e_r = (er + tr) >> 1
        new_e_i = (ei + ti) >> 1
        new_o_r = (er - tr) >> 1
        new_o_i = (ei - ti) >> 1
        re = re.at[even_idx].set(new_e_r).at[odd_idx].set(new_o_r)
        im = im.at[even_idx].set(new_e_i).at[odd_idx].set(new_o_i)
    return re, im


def relu_i32(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0).astype(jnp.int32)


def fc_q15(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fully connected layer: (x @ w) >> 15 + b, all int32, Q15 weights.

    Accumulation is in 64-bit then shifted; the RV32 kernel accumulates
    the 64-bit products with mul/mulh + 64-bit adds, so they agree
    bit-for-bit.
    """
    acc = jax.lax.dot_general(
        x.astype(jnp.int64),
        w.astype(jnp.int64),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int64,
    )
    return ((acc >> Q) + b.astype(jnp.int64)).astype(jnp.int32)


def tinyai_classifier(
    window_re: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
) -> jnp.ndarray:
    """End-to-end TinyAI pipeline oracle (the §V-C style classifier).

    window_re: (512,) int32 acquired samples (imag = 0).
    Features = L1-magnitude of the first 64 FFT bins, then two Q15 FC
    layers with ReLU in between. Returns (n_classes,) int32 logits.
    """
    im = jnp.zeros_like(window_re)
    fr, fi = fft_q15(window_re, im)
    feats = (jnp.abs(fr[:64]) + jnp.abs(fi[:64])).astype(jnp.int32)
    h = relu_i32(fc_q15(feats, w1, b1))
    return fc_q15(h, w2, b2)
