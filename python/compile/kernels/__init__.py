"""L1 Pallas kernels for the FEMU virtualized-accelerator models.

Each module exposes a jittable wrapper around a `pallas_call`
(interpret=True) plus shares the `ref` pure-jnp oracle used by pytest.
"""

from . import ref  # noqa: F401
from .matmul import matmul_i32  # noqa: F401
from .conv2d import conv2d_i32  # noqa: F401
from .fft import fft_q15  # noqa: F401
