"""L1 Pallas kernel: INT32 2-D convolution (valid, stride 1).

Paper context (Fig 5, "CONV"): 16x16 input, 3 channels, 8 filters of 3x3,
INT32 — the OpenEdgeCGRA convolution case study. The kernel uses the
shift-and-accumulate formulation: for each (kh, kw) tap the input map is
sliced and multiplied against the per-filter tap weights, accumulating in
INT32. The grid walks output-channel blocks so each grid step holds the
input map plus one block of filters VMEM-resident (the TPU adaptation of
the paper's spatial CGRA mapping; see DESIGN.md §7).

The (kh, kw) loops are unrolled at trace time — kernels here are 3x3, so
this emits 9 fused multiply-accumulate passes rather than a dynamic loop,
which XLA fuses into a single elementwise DAG.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BF = 8  # output-channel (filter) block per grid step


def _conv_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int):
    """One grid step: conv of the full map with a block of filters."""
    x = x_ref[...]  # (H, W, Cin)
    w = w_ref[...]  # (bf, KH, KW, Cin)
    oh = x.shape[0] - kh + 1
    ow = x.shape[1] - kw + 1
    acc = jnp.zeros((oh, ow, w.shape[0]), dtype=jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = x[i : i + oh, j : j + ow, :]  # (oh, ow, Cin)
            taps = w[:, i, j, :]  # (bf, Cin)
            # (oh, ow, Cin) x (bf, Cin) -> (oh, ow, bf)
            acc = acc + jax.lax.dot_general(
                patch,
                taps,
                (((2,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bf",))
def conv2d_i32(x: jnp.ndarray, w: jnp.ndarray, bf: int = DEFAULT_BF) -> jnp.ndarray:
    """INT32 valid conv2d via a Pallas filter-blocked kernel.

    x: (H, W, Cin) int32; w: (F, KH, KW, Cin) int32
    -> (H-KH+1, W-KW+1, F) int32.
    F is padded to a multiple of `bf` with zero filters, sliced back.
    """
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    h, wid, cin = x.shape
    f, kh, kw, cin2 = w.shape
    assert cin == cin2, (cin, cin2)
    oh, ow = h - kh + 1, wid - kw + 1
    bf = min(bf, max(f, 1))
    f_pad = (-f) % bf
    w_p = jnp.pad(w, ((0, f_pad), (0, 0), (0, 0), (0, 0)))
    grid = (w_p.shape[0] // bf,)
    out = pl.pallas_call(
        functools.partial(_conv_kernel, kh=kh, kw=kw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, wid, cin), lambda i: (0, 0, 0)),
            pl.BlockSpec((bf, kh, kw, cin), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((oh, ow, bf), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, w_p.shape[0]), jnp.int32),
        interpret=True,
    )(x, w_p)
    return out[:, :, :f]
