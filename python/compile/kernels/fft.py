"""L1 Pallas kernel: fixed-point (Q15 twiddle) radix-2 DIT FFT.

Paper context (Fig 5, "FFT"): a 512-point FxP32 FFT, the VWR2A workload.
Data is int32, twiddles are Q15 int32, butterflies scale by 1/2 per stage
(arithmetic shift) to bound dynamic range — bit-identical to ref.fft_q15,
the RV32 assembly kernel, and the CGRA mapping.

TPU adaptation (DESIGN.md §7): the whole n-point working set stays
VMEM-resident and each of the log2(n) stages is one full-array vectorized
pass. The kernel is deliberately **gather/scatter-free**:

* the bit-reversal permutation is the classic reshape-to-(2,)*log2(n) +
  axis-reversal transpose,
* each stage views the array as (groups, 2, half) so even/odd lanes are
  static slices, and the per-stage twiddles are a strided static slice of
  the twiddle table.

Static slicing both matches how a TPU kernel would express the HBM↔VMEM
schedule and keeps the lowered HLO inside the op set the AOT runtime's
XLA (xla_extension 0.5.1 — see /opt/xla-example/README.md) compiles
correctly; its gather/scatter handling is not trustworthy for this
interchange path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

Q = ref.Q


def _bit_reverse(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Bit-reversal permutation via reshape + transpose (no gather)."""
    bits = n.bit_length() - 1
    if bits == 0:
        return x
    y = x.reshape((2,) * bits)
    y = jnp.transpose(y, tuple(reversed(range(bits))))
    return y.reshape(n)


def _q15(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return ((a.astype(jnp.int64) * b.astype(jnp.int64)) >> Q).astype(jnp.int32)


def _fft_kernel(re_ref, im_ref, *refs, n: int):
    """refs layout: stages x twr tables, stages x twi tables, then the
    two output refs. Per-stage twiddle tables arrive as separate operands
    (precomputed host-side) so the kernel needs no gather and no strided
    slice — only reshapes, transposes, concats, and elementwise ops."""
    stages = n.bit_length() - 1
    twr_refs = refs[:stages]
    twi_refs = refs[stages : 2 * stages]
    or_ref, oi_ref = refs[2 * stages], refs[2 * stages + 1]
    re = _bit_reverse(re_ref[...], n)
    im = _bit_reverse(im_ref[...], n)

    # unrolled static stage loop
    for s in range(1, stages + 1):
        m = 1 << s
        half = m // 2
        groups = n // m
        xr = re.reshape(groups, 2, half)
        xi = im.reshape(groups, 2, half)
        er, orr = xr[:, 0, :], xr[:, 1, :]
        ei, oi = xi[:, 0, :], xi[:, 1, :]
        twr = twr_refs[s - 1][...][None, :]
        twi = twi_refs[s - 1][...][None, :]
        tr = _q15(orr, twr) - _q15(oi, twi)
        ti = _q15(orr, twi) + _q15(oi, twr)
        new_er = (er + tr) >> 1
        new_ei = (ei + ti) >> 1
        new_or = (er - tr) >> 1
        new_oi = (ei - ti) >> 1
        re = jnp.concatenate([new_er[:, None, :], new_or[:, None, :]], axis=1).reshape(n)
        im = jnp.concatenate([new_ei[:, None, :], new_oi[:, None, :]], axis=1).reshape(n)
    or_ref[...] = re
    oi_ref[...] = im


@functools.partial(jax.jit, static_argnames=())
def _fft_call(re, im, *tables):
    n = re.shape[0]
    kern = functools.partial(_fft_kernel, n=n)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=True,
    )(re, im, *tables)


def stage_tables(n: int):
    """Per-stage (twr, twi) tables: stage s uses W^(j * n/2^s), j < 2^(s-1)."""
    wr, wi = ref.twiddles_q15(n)
    stages = n.bit_length() - 1
    twr, twi = [], []
    for s in range(1, stages + 1):
        half = 1 << (s - 1)
        stride = n // (1 << s)
        idx = [j * stride for j in range(half)]
        twr.append(jnp.asarray([int(wr[i]) for i in idx], jnp.int32))
        twi.append(jnp.asarray([int(wi[i]) for i in idx], jnp.int32))
    return twr + twi


def fft_q15(re: jnp.ndarray, im: jnp.ndarray):
    """Q15 radix-2 FFT via the Pallas kernel.

    re, im: (n,) int32, n a power of two >= 2. Returns (re, im) int32.
    Twiddle tables are generated host-side (same rounding rule as
    ref.twiddles_q15) and passed as kernel operands — exactly how the
    RV32/CGRA implementations receive them in guest memory.
    """
    n = int(re.shape[0])
    assert n & (n - 1) == 0 and n >= 2, f"n must be a power of two, got {n}"
    return _fft_call(re.astype(jnp.int32), im.astype(jnp.int32), *stage_tables(n))


def fft_with_tables(re: jnp.ndarray, im: jnp.ndarray, tables):
    """AOT entry form: twiddle tables arrive as *parameters*.

    The HLO-text interchange elides large dense constants (the old
    xla_extension 0.5.1 parser then fills garbage — see DESIGN.md
    §AOT-pitfalls), so the AOT artifacts must not embed the tables;
    the Rust runtime passes them at execution
    (rust/src/virt/accel.rs::fft_table_tensors).
    """
    return _fft_call(re.astype(jnp.int32), im.astype(jnp.int32), *tables)


def stage_table_shapes(n: int):
    """Shapes of stage_tables(n), in order (twr stages..., twi stages...)."""
    stages = n.bit_length() - 1
    halves = [(1 << (s - 1),) for s in range(1, stages + 1)]
    return halves + halves
