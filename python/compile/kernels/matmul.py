"""L1 Pallas kernel: INT32 tiled matrix multiply.

Paper context (Fig 5, "MM"): a 121x16 by 16x4 INT32 matmul, the VersaSens
wearable workload. The kernel is written generically and tiled for the
TPU mental model: the grid walks M-tiles, each grid step keeps an
(bm, K) A-tile, the whole (K, N) B panel, and a (bm, N) output tile
VMEM-resident (these case-study operands are tiny against ~16 MiB VMEM,
so K and N are not further split; the BlockSpec structure is what a real
MXU lowering would keep).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime executes (see DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 32  # M-tile; 121 rows -> 4 grid steps with padding.


def _mm_kernel(a_ref, b_ref, o_ref):
    """One grid step: o_tile = a_tile @ B (INT32, wrap-around)."""
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("bm",))
def matmul_i32(a: jnp.ndarray, b: jnp.ndarray, bm: int = DEFAULT_BM) -> jnp.ndarray:
    """INT32 matmul via a Pallas M-tiled kernel.

    a: (M, K) int32, b: (K, N) int32 -> (M, N) int32.
    M is padded up to a multiple of `bm` (zero rows), then sliced back —
    zero rows contribute zero products, so padding is exact for integer
    arithmetic.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (k, k2)
    bm = min(bm, max(m, 1))
    m_pad = (-m) % bm
    a_p = jnp.pad(a, ((0, m_pad), (0, 0)))
    grid = (a_p.shape[0] // bm,)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0], n), jnp.int32),
        interpret=True,
    )(a_p, b)
    return out[:m]
