//! Hot-path microbenchmarks — the measurement tool for the §Perf pass
//! (EXPERIMENTS.md §Perf records before/after from this bench).
//!
//! * ISS throughput (emulated instructions / wall second) on a dense ALU
//!   loop, a memory-heavy loop, and the Fig 5 MM kernel;
//! * guest MIPS of the interpreter vs the block-compiled backend on the
//!   same kernel — the headline number of the [`femu::exec`] fast path.
//!   The wall ratio `blocks_over_interp` is tracked in
//!   `rust/BENCH_baseline.json`, so CI fails if the block backend ever
//!   drops below ~3x the interpreter;
//! * event-driven sleep fast-forward rate (emulated cycles / wall s);
//! * CGRA emulator throughput (contexts / wall s);
//! * PJRT artifact execution latency (skipped when `make artifacts` has
//!   not run — CI has no PJRT runtime).
//!
//! `cargo bench --bench perf_hotpaths`

#[path = "harness/mod.rs"]
mod harness;

use femu::exec::BackendKind;
use femu::isa::assemble;
use femu::soc::{Soc, SocConfig};
use femu::util::Json;

fn iss_throughput(name: &str, src: &str) -> f64 {
    let prog = assemble(src).unwrap();
    let (result, secs) = harness::time_best(3, || {
        let mut soc = Soc::new(SocConfig::default());
        soc.load(&prog).unwrap();
        soc.run_to_halt(1 << 34);
        (soc.stats.instructions, soc.now)
    });
    let (instr, cycles) = result;
    println!(
        "{name:<18} {:>12} instr in {:>8}s -> {:>10} instr/s ({} emu cycles)",
        instr,
        harness::eng(secs),
        harness::eng(instr as f64 / secs),
        harness::eng(cycles as f64),
    );
    secs
}

/// A dense straight-line kernel with long basic blocks: the case the
/// block backend is built for. 16 ALU ops per iteration + the loop
/// counter + the back-branch = one 18-instruction block.
const GUEST_MIPS_SRC: &str = r#"
    _start:
        li t0, 300000
    loop:
        addi t1, t1, 3
        xor  t2, t1, t0
        slli t3, t2, 1
        sub  t4, t3, t1
        and  t5, t4, t2
        or   t6, t5, t1
        addi t1, t1, 1
        xor  t2, t2, t3
        slli t4, t1, 2
        sub  t5, t4, t2
        and  t6, t5, t3
        or   t3, t6, t4
        add  t2, t2, t5
        srli t4, t3, 1
        add  t1, t1, t4
        addi t0, t0, -1
        bnez t0, loop
        ebreak
"#;

/// Run [`GUEST_MIPS_SRC`] on one backend; returns (instructions, final
/// cycle clock, best wall seconds).
fn guest_mips_on(backend: BackendKind) -> (u64, u64, f64) {
    let prog = assemble(GUEST_MIPS_SRC).unwrap();
    let ((instr, cycles), secs) = harness::time_best(harness::reps(5), || {
        let mut cfg = SocConfig::default();
        cfg.backend = backend;
        let mut soc = Soc::new(cfg);
        soc.load(&prog).unwrap();
        soc.run_to_halt(1 << 34);
        if backend == BackendKind::Blocks {
            assert!(
                soc.exec_stats().block_dispatches > 0,
                "block backend never took its fast path"
            );
        }
        (soc.stats.instructions, soc.now)
    });
    println!(
        "{:<8} backend: {:>12} instr in {:>8}s -> {:>8.1} guest MIPS",
        backend.name(),
        instr,
        harness::eng(secs),
        instr as f64 / secs / 1e6,
    );
    (instr, cycles, secs)
}

fn main() {
    let mut results: Vec<Json> = Vec::new();

    harness::header("L3 hot paths: instruction-set simulator");
    let alu_s = iss_throughput(
        "alu_loop",
        r#"
        _start:
            li t0, 2000000
        loop:
            addi t1, t1, 3
            xor  t2, t1, t0
            slli t3, t2, 1
            sub  t4, t3, t1
            addi t0, t0, -1
            bnez t0, loop
            ebreak
        "#,
    );
    let mem_s = iss_throughput(
        "mem_loop",
        r#"
        _start:
            li t0, 500000
            li t5, 0x20000      # bank-1 buffer base
        loop:
            sw t0, 0(t5)
            lw t1, 0(t5)
            sw t1, 4(t5)
            lw t2, 4(t5)
            addi t0, t0, -1
            bnez t0, loop
            ebreak
        "#,
    );
    let mul_s = iss_throughput(
        "mul_div_loop",
        r#"
        _start:
            li t0, 200000
        loop:
            mul  t1, t0, t0
            mulh t2, t1, t0
            div  t3, t1, t0
            addi t0, t0, -1
            bnez t0, loop
            ebreak
        "#,
    );
    results.push(harness::json_result("alu_loop", alu_s));
    results.push(harness::json_result("mem_loop", mem_s));
    results.push(harness::json_result("mul_div_loop", mul_s));

    harness::header("Guest MIPS: interpreter vs block-compiled backend");
    {
        let (ii, ic, interp_s) = guest_mips_on(BackendKind::Interp);
        let (bi, bc, blocks_s) = guest_mips_on(BackendKind::Blocks);
        // the backends' bit-identity contract, visible even in a bench:
        // same retired count, same final clock
        assert_eq!((ii, ic), (bi, bc), "backends disagree on architectural totals");
        let ratio = blocks_s / interp_s;
        println!(
            "-> blocks wall / interp wall = {ratio:.3} ({:.2}x speedup)",
            1.0 / ratio
        );
        results.push(harness::json_result("guest_mips_interp", interp_s));
        results.push(harness::json_result("guest_mips_blocks", blocks_s));
        // dimensionless, gated: the committed ceiling makes CI fail if
        // the block backend regresses below ~3x the interpreter
        results.push(harness::json_result("blocks_over_interp", ratio));
    }

    harness::header("Trace ring: disabled tracing must cost ~nothing");
    {
        // the zero-overhead guarantee (DESIGN.md §13): with the ring
        // armed but every category masked off, each record site is one
        // predictable branch. The committed `trace_off_overhead` ceiling
        // in BENCH_baseline.json holds this wall ratio at <= ~3%.
        let prog = assemble(GUEST_MIPS_SRC).unwrap();
        let measure = |armed: bool| {
            harness::time_best(harness::reps(5), || {
                let mut soc = Soc::new(SocConfig::default());
                if armed {
                    // mask 0: ring present, all categories disabled
                    soc.set_trace(femu::trace::TraceConfig::default());
                }
                soc.load(&prog).unwrap();
                soc.run_to_halt(1 << 34);
                let recorded = soc.trace_ring().map(|t| t.total()).unwrap_or(0);
                (soc.stats.instructions, recorded)
            })
        };
        let ((instr_off, _), no_trace_s) = measure(false);
        let ((instr_on, recorded), trace_off_s) = measure(true);
        assert_eq!(instr_off, instr_on, "armed-but-masked ring changed execution");
        assert_eq!(recorded, 0, "a fully-masked ring must record nothing");
        let ratio = trace_off_s / no_trace_s;
        println!(
            "trace-off {:>8}s vs no-trace {:>8}s -> ratio {ratio:.3} ({:+.2}% overhead)",
            harness::eng(trace_off_s),
            harness::eng(no_trace_s),
            (ratio - 1.0) * 100.0,
        );
        results.push(harness::json_result("trace_off_overhead", ratio));
    }

    harness::header("Guest profiler: paused profiling must cost ~nothing");
    {
        // the same guarantee for the profiler (DESIGN.md §14): armed but
        // paused, each retire pays one predictable branch in the record
        // hook. The committed `profile_off_overhead` ceiling in
        // BENCH_baseline.json holds this wall ratio at <= ~3%.
        let prog = assemble(GUEST_MIPS_SRC).unwrap();
        let measure = |armed: bool| {
            harness::time_best(harness::reps(5), || {
                let mut soc = Soc::new(SocConfig::default());
                if armed {
                    soc.set_profile();
                    soc.profiler_mut().unwrap().set_active(false);
                }
                soc.load(&prog).unwrap();
                soc.run_to_halt(1 << 34);
                let recorded = soc.profiler().map(|p| p.records()).unwrap_or(0);
                (soc.stats.instructions, recorded)
            })
        };
        let ((instr_off, _), no_prof_s) = measure(false);
        let ((instr_on, recorded), prof_off_s) = measure(true);
        assert_eq!(instr_off, instr_on, "paused profiler changed execution");
        assert_eq!(recorded, 0, "a paused profiler must record nothing");
        let ratio = prof_off_s / no_prof_s;
        println!(
            "profile-off {:>8}s vs no-profile {:>8}s -> ratio {ratio:.3} ({:+.2}% overhead)",
            harness::eng(prof_off_s),
            harness::eng(no_prof_s),
            (ratio - 1.0) * 100.0,
        );
        results.push(harness::json_result("profile_off_overhead", ratio));
    }

    harness::header("L3 hot paths: event-driven sleep fast-forward");
    {
        let prog = assemble(
            r#"
            .equ TIMER, 0x20000200
            _start:
                la  t0, handler
                csrw mtvec, t0
                li  t0, TIMER
                li  t1, 0x7FFFFFFF   # far-future timer (~7.3 emulated years)
                sw  t1, 8(t0)
                li  t1, 0x10000000
                sw  t1, 12(t0)
                li  t1, 1
                sw  t1, 16(t0)
                li  t1, 0x80
                csrw mie, t1
                csrsi mstatus, 8
                wfi
                ebreak
            handler:
                ebreak
            "#,
        )
        .unwrap();
        let (cycles, secs) = harness::time_best(3, || {
            let mut soc = Soc::new(SocConfig::default());
            soc.load(&prog).unwrap();
            soc.run_to_halt(1 << 62);
            soc.now
        });
        println!(
            "sleep fast-forward: {} emulated cycles in {}s -> {} cycles/s",
            harness::eng(cycles as f64),
            harness::eng(secs),
            harness::eng(cycles as f64 / secs),
        );
        results.push(harness::json_result("sleep_fast_forward", secs));
    }

    harness::header("Fault-injection campaign throughput");
    {
        // the restore-inject-classify hot loop (DESIGN.md §15). The
        // committed `faults_points_per_sec` metric is SECONDS PER POINT
        // (the harness gates on wall time, lower = better) despite the
        // rate-shaped name; the BENCH_baseline.json ceiling keeps
        // campaign throughput within the gate tolerance of baseline.
        use femu::config::PlatformConfig;
        use femu::coordinator::Fleet;
        use femu::faults::{run_campaign, CampaignSpec};
        let mut spec = CampaignSpec::new("acquisition").unwrap();
        spec.points = 32;
        spec.seed = 0xBE7C;
        let cfg = PlatformConfig::default();
        let (report, secs) = harness::time_best(harness::reps(3), || {
            run_campaign(&cfg, Fleet::serial(), &spec).unwrap()
        });
        assert_eq!(report.results.len(), spec.points);
        println!(
            "campaign: {} points in {}s -> {} points/s ({} s/point)",
            spec.points,
            harness::eng(secs),
            harness::eng(spec.points as f64 / secs),
            harness::eng(secs / spec.points as f64),
        );
        results.push(harness::json_result("faults_points_per_sec", secs / spec.points as f64));
    }

    harness::header("CGRA emulator throughput");
    {
        use femu::cgra::{kernels, CgraCore};
        let passes = kernels::conv2d_passes(0, 2048 * 4, 4096 * 4, 16, 16, 3, 8, 3, 3);
        let (run, secs) = harness::time_best(3, || {
            let mut core = CgraCore::new();
            let mut mem = vec![0u32; 16384];
            kernels::run_passes(&mut core, &passes, &mut mem).unwrap()
        });
        println!(
            "conv2d mapping: {} contexts (+{} stalls) in {}s -> {} contexts/s",
            run.contexts,
            run.mem_stalls,
            harness::eng(secs),
            harness::eng(run.contexts as f64 / secs),
        );
        results.push(harness::json_result("cgra_conv2d", secs));
    }

    harness::header("PJRT artifact execution latency (virtualized accelerator)");
    {
        use femu::runtime::{Runtime, TensorI32};
        // CI runners have no PJRT runtime: skip instead of panicking, so
        // the gated metrics above still get measured and written
        match Runtime::load("artifacts") {
            Err(e) => println!("skipped (run `make artifacts`): {e:#}"),
            Ok(rt) => {
                let mut rng = femu::util::Rng::new(1);
                let a = TensorI32::new(vec![121, 16], rng.vec_i32(121 * 16, -99, 99)).unwrap();
                let b = TensorI32::new(vec![16, 4], rng.vec_i32(16 * 4, -99, 99)).unwrap();
                let (_, secs) =
                    harness::time_best(20, || rt.execute("matmul", &[a.clone(), b.clone()]).unwrap());
                println!("matmul artifact: {}s/exec", harness::eng(secs));
                let re = TensorI32::new(vec![512], rng.vec_i32(512, -99, 99)).unwrap();
                let im = TensorI32::new(vec![512], rng.vec_i32(512, -99, 99)).unwrap();
                let mut args = vec![re, im];
                args.extend(femu::virt::accel::fft_table_tensors(512));
                let (_, secs) = harness::time_best(20, || rt.execute("fft512", &args).unwrap());
                println!("fft512 artifact: {}s/exec", harness::eng(secs));
            }
        }
    }

    harness::write_json("perf_hotpaths", vec![], results);
    println!("\nperf_hotpaths done");
}
