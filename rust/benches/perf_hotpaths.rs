//! Hot-path microbenchmarks — the measurement tool for the §Perf pass
//! (EXPERIMENTS.md §Perf records before/after from this bench).
//!
//! * ISS throughput (emulated instructions / wall second) on a dense ALU
//!   loop, a memory-heavy loop, and the Fig 5 MM kernel;
//! * event-driven sleep fast-forward rate (emulated cycles / wall s);
//! * CGRA emulator throughput (contexts / wall s);
//! * PJRT artifact execution latency.
//!
//! `cargo bench --bench perf_hotpaths`

#[path = "harness/mod.rs"]
mod harness;

use femu::isa::assemble;
use femu::soc::{Soc, SocConfig};

fn iss_throughput(name: &str, src: &str) {
    let prog = assemble(src).unwrap();
    let (result, secs) = harness::time_best(3, || {
        let mut soc = Soc::new(SocConfig::default());
        soc.load(&prog).unwrap();
        soc.run_to_halt(1 << 34);
        (soc.stats.instructions, soc.now)
    });
    let (instr, cycles) = result;
    println!(
        "{name:<18} {:>12} instr in {:>8}s -> {:>10} instr/s ({} emu cycles)",
        instr,
        harness::eng(secs),
        harness::eng(instr as f64 / secs),
        harness::eng(cycles as f64),
    );
}

fn main() {
    harness::header("L3 hot paths: instruction-set simulator");
    iss_throughput(
        "alu_loop",
        r#"
        _start:
            li t0, 2000000
        loop:
            addi t1, t1, 3
            xor  t2, t1, t0
            slli t3, t2, 1
            sub  t4, t3, t1
            addi t0, t0, -1
            bnez t0, loop
            ebreak
        "#,
    );
    iss_throughput(
        "mem_loop",
        r#"
        _start:
            li t0, 500000
            li t5, 0x20000      # bank-1 buffer base
        loop:
            sw t0, 0(t5)
            lw t1, 0(t5)
            sw t1, 4(t5)
            lw t2, 4(t5)
            addi t0, t0, -1
            bnez t0, loop
            ebreak
        "#,
    );
    iss_throughput("mul_div_loop",
        r#"
        _start:
            li t0, 200000
        loop:
            mul  t1, t0, t0
            mulh t2, t1, t0
            div  t3, t1, t0
            addi t0, t0, -1
            bnez t0, loop
            ebreak
        "#,
    );

    harness::header("L3 hot paths: event-driven sleep fast-forward");
    {
        let prog = assemble(
            r#"
            .equ TIMER, 0x20000200
            _start:
                la  t0, handler
                csrw mtvec, t0
                li  t0, TIMER
                li  t1, 0x7FFFFFFF   # far-future timer (~7.3 emulated years)
                sw  t1, 8(t0)
                li  t1, 0x10000000
                sw  t1, 12(t0)
                li  t1, 1
                sw  t1, 16(t0)
                li  t1, 0x80
                csrw mie, t1
                csrsi mstatus, 8
                wfi
                ebreak
            handler:
                ebreak
            "#,
        )
        .unwrap();
        let (cycles, secs) = harness::time_best(3, || {
            let mut soc = Soc::new(SocConfig::default());
            soc.load(&prog).unwrap();
            soc.run_to_halt(1 << 62);
            soc.now
        });
        println!(
            "sleep fast-forward: {} emulated cycles in {}s -> {} cycles/s",
            harness::eng(cycles as f64),
            harness::eng(secs),
            harness::eng(cycles as f64 / secs),
        );
    }

    harness::header("CGRA emulator throughput");
    {
        use femu::cgra::{kernels, CgraCore};
        let passes = kernels::conv2d_passes(0, 2048 * 4, 4096 * 4, 16, 16, 3, 8, 3, 3);
        let (run, secs) = harness::time_best(3, || {
            let mut core = CgraCore::new();
            let mut mem = vec![0u32; 16384];
            kernels::run_passes(&mut core, &passes, &mut mem).unwrap()
        });
        println!(
            "conv2d mapping: {} contexts (+{} stalls) in {}s -> {} contexts/s",
            run.contexts,
            run.mem_stalls,
            harness::eng(secs),
            harness::eng(run.contexts as f64 / secs),
        );
    }

    harness::header("PJRT artifact execution latency (virtualized accelerator)");
    {
        use femu::runtime::{Runtime, TensorI32};
        let rt = Runtime::load("artifacts").expect("make artifacts");
        let mut rng = femu::util::Rng::new(1);
        let a = TensorI32::new(vec![121, 16], rng.vec_i32(121 * 16, -99, 99)).unwrap();
        let b = TensorI32::new(vec![16, 4], rng.vec_i32(16 * 4, -99, 99)).unwrap();
        let (_, secs) = harness::time_best(20, || rt.execute("matmul", &[a.clone(), b.clone()]).unwrap());
        println!("matmul artifact: {}s/exec", harness::eng(secs));
        let re = TensorI32::new(vec![512], rng.vec_i32(512, -99, 99)).unwrap();
        let im = TensorI32::new(vec![512], rng.vec_i32(512, -99, 99)).unwrap();
        let mut args = vec![re, im];
        args.extend(femu::virt::accel::fft_table_tensors(512));
        let (_, secs) = harness::time_best(20, || rt.execute("fft512", &args).unwrap());
        println!("fft512 artifact: {}s/exec", harness::eng(secs));
    }
    println!("\nperf_hotpaths done");
}
