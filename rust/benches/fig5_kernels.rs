//! Bench: regenerate **Fig 5** — normalized processing time and energy
//! for the MM / CONV / FFT kernels on CPU and CGRA, under the FEMU and
//! chip calibrations, with bit-exact output validation.
//!
//! `cargo bench --bench fig5_kernels`

#[path = "harness/mod.rs"]
mod harness;

use femu::config::PlatformConfig;
use femu::coordinator::experiments::{self, Fig5Impl, Fig5Kernel};

fn main() {
    let cfg = PlatformConfig::default();
    harness::header("Fig 5: TinyAI kernels, CPU vs CGRA, FEMU vs chip");
    println!(
        "{:>6} {:>6} {:>12} | {:>10} {:>10} {:>11} {:>6} | {:>9}",
        "kernel", "impl", "platform", "cycles", "time", "energy", "valid", "bench_s"
    );
    let mut all = Vec::new();
    for kernel in Fig5Kernel::ALL {
        for imp in [Fig5Impl::Cpu, Fig5Impl::Cgra] {
            let (points, wall) =
                harness::time(|| experiments::fig5_run(&cfg, kernel, imp, 0xF15).unwrap());
            for p in &points {
                let plat = if p.model == "femu" { "X-HEEP-FEMU" } else { "HEEPocrates" };
                println!(
                    "{:>6} {:>6} {:>12} | {:>10} {:>9}s {:>10}J {:>6} | {:>9}",
                    p.kernel,
                    p.implementation,
                    plat,
                    p.cycles,
                    harness::eng(p.time_s),
                    harness::eng(p.energy_mj / 1e3),
                    if p.validated { "yes" } else { "NO" },
                    harness::eng(wall),
                );
            }
            all.extend(points);
        }
    }

    // normalized view (CPU = 1.0 per kernel, femu calibration) — the
    // paper's presentation
    harness::header("Fig 5 normalized (CPU = 1.0, femu calibration)");
    println!("{:>6} | {:>10} {:>10} | {:>10} {:>10}", "kernel", "t_CPU", "t_CGRA", "E_CPU", "E_CGRA");
    for k in ["MM", "CONV", "FFT"] {
        let cpu = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CPU" && p.model == "femu")
            .unwrap();
        let cgra = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CGRA" && p.model == "femu")
            .unwrap();
        println!(
            "{:>6} | {:>10.3} {:>10.3} | {:>10.3} {:>10.3}",
            k,
            1.0,
            cgra.time_s / cpu.time_s,
            1.0,
            cgra.energy_mj / cpu.energy_mj,
        );
    }

    // shape checks
    assert!(all.iter().all(|p| p.validated));
    let speedup = |k: &str| {
        let cpu = all.iter().find(|p| p.kernel == k && p.implementation == "CPU" && p.model == "femu").unwrap();
        let cgra = all.iter().find(|p| p.kernel == k && p.implementation == "CGRA" && p.model == "femu").unwrap();
        cpu.cycles as f64 / cgra.cycles as f64
    };
    let (mm, conv, fft) = (speedup("MM"), speedup("CONV"), speedup("FFT"));
    println!("\nspeedups: MM {mm:.2}x  CONV {conv:.2}x  FFT {fft:.2}x");
    assert!(conv > mm && conv > fft, "CONV must gain most (paper shape)");
    println!("shape check OK: CGRA wins everywhere, CONV gains most");
}
