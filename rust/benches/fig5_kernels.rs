//! Bench: regenerate **Fig 5** — normalized processing time and energy
//! for the MM / CONV / FFT kernels on CPU and CGRA, under the FEMU and
//! chip calibrations, with bit-exact output validation.
//!
//! The grid runs twice — serial reference and experiment fleet —
//! cross-checking bit-identity and asserting the fleet speedup on
//! machines with 4+ cores (the §V turnaround claim).
//!
//! `cargo bench --bench fig5_kernels`

#[path = "harness/mod.rs"]
mod harness;

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Fleet};
use femu::util::Json;

fn main() {
    let cfg = PlatformConfig::default();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let fleet = Fleet::new(4);
    harness::header("Fig 5: TinyAI kernels, CPU vs CGRA, FEMU vs chip");

    let (serial_pts, mut serial_s) =
        harness::time(|| experiments::fig5_all(&Fleet::serial(), &cfg, 0xF15).unwrap());
    let (all, mut fleet_s) = harness::time(|| experiments::fig5_all(&fleet, &cfg, 0xF15).unwrap());

    println!(
        "{:>6} {:>6} {:>12} | {:>10} {:>10} {:>11} {:>6}",
        "kernel", "impl", "platform", "cycles", "time", "energy", "valid"
    );
    for p in &all {
        let plat = if p.model == "femu" { "X-HEEP-FEMU" } else { "HEEPocrates" };
        println!(
            "{:>6} {:>6} {:>12} | {:>10} {:>9}s {:>10}J {:>6}",
            p.kernel,
            p.implementation,
            plat,
            p.cycles,
            harness::eng(p.time_s),
            harness::eng(p.energy_mj / 1e3),
            if p.validated { "yes" } else { "NO" },
        );
    }

    // fleet/serial bit-identity
    assert_eq!(serial_pts.len(), all.len());
    for (a, b) in serial_pts.iter().zip(&all) {
        assert_eq!((a.kernel, a.implementation), (b.kernel, b.implementation));
        assert_eq!(a.model, b.model);
        assert_eq!(a.cycles, b.cycles, "{}/{}", a.kernel, a.implementation);
        let (ae, be) = (a.energy_mj.to_bits(), b.energy_mj.to_bits());
        assert_eq!(ae, be, "{}/{}", a.kernel, a.implementation);
        assert_eq!(a.validated, b.validated);
    }
    println!("\ndeterminism OK: fleet({}) output bit-identical to serial", fleet.workers());
    // available_parallelism() counts logical CPUs: on 4 logical / 2
    // physical cores, 4 CPU-bound workers cannot reach 2x, so the hard
    // 2x floor only arms with headroom (>= 6 logical) and a softer
    // sanity floor covers plain 4-logical machines. Single-sample wall
    // times are noisy (transient host load), so a failing first sample
    // gets one re-measure of both paths (min = least-noise estimator)
    // before the assertion fires.
    let floor = if cores >= 6 {
        Some(2.0)
    } else if cores >= 4 {
        Some(1.3)
    } else {
        None
    };
    if floor.is_some_and(|f| serial_s / fleet_s < f) {
        let (_, s2) =
            harness::time(|| experiments::fig5_all(&Fleet::serial(), &cfg, 0xF15).unwrap());
        let (_, f2) = harness::time(|| experiments::fig5_all(&fleet, &cfg, 0xF15).unwrap());
        serial_s = serial_s.min(s2);
        fleet_s = fleet_s.min(f2);
    }
    let speedup_wall = serial_s / fleet_s;
    println!(
        "wall-clock: serial {}s, fleet({}) {}s -> {:.2}x",
        harness::eng(serial_s),
        fleet.workers(),
        harness::eng(fleet_s),
        speedup_wall,
    );
    match floor {
        Some(f) => {
            assert!(
                speedup_wall >= f,
                "4-worker fig5_all must be >= {f}x faster than serial on a \
                 {cores}-logical-core machine (got {speedup_wall:.2}x)"
            );
            println!("fleet speedup OK: {speedup_wall:.2}x >= {f}x floor on {cores} cores");
        }
        None => println!("fleet speedup not asserted ({cores} core(s) < 4)"),
    }

    // normalized view (CPU = 1.0 per kernel, femu calibration) — the
    // paper's presentation
    harness::header("Fig 5 normalized (CPU = 1.0, femu calibration)");
    println!("{:>6} | {:>10} {:>10} | {:>10} {:>10}", "kernel", "t_CPU", "t_CGRA", "E_CPU", "E_CGRA");
    for k in ["MM", "CONV", "FFT"] {
        let cpu = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CPU" && p.model == "femu")
            .unwrap();
        let cgra = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CGRA" && p.model == "femu")
            .unwrap();
        println!(
            "{:>6} | {:>10.3} {:>10.3} | {:>10.3} {:>10.3}",
            k,
            1.0,
            cgra.time_s / cpu.time_s,
            1.0,
            cgra.energy_mj / cpu.energy_mj,
        );
    }

    // shape checks
    assert!(all.iter().all(|p| p.validated));
    let speedup = |k: &str| {
        let cpu = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CPU" && p.model == "femu")
            .unwrap();
        let cgra = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CGRA" && p.model == "femu")
            .unwrap();
        cpu.cycles as f64 / cgra.cycles as f64
    };
    let (mm, conv, fft) = (speedup("MM"), speedup("CONV"), speedup("FFT"));
    println!("\nspeedups: MM {mm:.2}x  CONV {conv:.2}x  FFT {fft:.2}x");
    assert!(conv > mm && conv > fft, "CONV must gain most (paper shape)");
    println!("shape check OK: CGRA wins everywhere, CONV gains most");

    harness::write_json(
        "fig5_kernels",
        vec![("workers", Json::from(fleet.workers() as i64))],
        vec![
            harness::json_result("grid_serial", serial_s),
            harness::json_result("grid_fleet", fleet_s),
        ],
    );
}
