//! Bench: regenerate **Case C (§V-C)** — the flash-virtualization
//! transfer study: 240 windows of 35 000 16-bit ultrasound samples
//! (70 KiB/window), virtualized vs physical SPI flash.
//!
//! `cargo bench --bench case_c_flash` (FEMU_CASEC_SCALE shrinks the
//! workload; default 1 = full paper size).

#[path = "harness/mod.rs"]
mod harness;

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Fleet};

fn main() {
    let scale: usize =
        std::env::var("FEMU_CASEC_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let cfg = PlatformConfig::default();
    // the two timing variants are independent fleet points, so a 2-worker
    // fleet overlaps the (dominant) physical-timing emulation with the
    // virtualized one
    let fleet = Fleet::new(2);
    harness::header(&format!("Case C (\u{a7}V-C): flash virtualization (scale 1/{scale})"));
    let (r, wall) = harness::time(|| experiments::case_c(&fleet, &cfg, scale).unwrap());
    println!(
        "workload: {} windows x {} samples ({} KiB/window)",
        r.windows,
        r.samples_per_window,
        r.samples_per_window * 2 / 1024
    );
    println!(
        "{:>14} | {:>14} {:>14}",
        "", "virtualized", "physical SPI"
    );
    println!(
        "{:>14} | {:>14} {:>14}",
        "per window",
        format!("{}s", harness::eng(r.virt_window_s)),
        format!("{}s", harness::eng(r.phys_window_s)),
    );
    println!(
        "{:>14} | {:>14} {:>14}",
        "full run",
        format!("{}s", harness::eng(r.virt_total_s)),
        format!("{}s", harness::eng(r.phys_total_s)),
    );
    println!("speedup: {:.0}x (paper: ~250x)", r.speedup);
    println!("bench wall time: {}s", harness::eng(wall));

    assert!(r.speedup > 180.0 && r.speedup < 320.0, "speedup out of band: {}", r.speedup);
    if scale == 1 {
        // absolute claims at the paper size: ~10 ms vs ~2.5 s per window,
        // ~2.4 s vs ~10 min full run
        assert!((r.virt_window_s - 0.010).abs() < 0.005, "virt window {}", r.virt_window_s);
        assert!((r.phys_window_s - 2.5).abs() < 0.5, "phys window {}", r.phys_window_s);
        assert!((r.virt_total_s - 2.4).abs() < 1.0, "virt total {}", r.virt_total_s);
        assert!((r.phys_total_s - 600.0).abs() < 120.0, "phys total {}", r.phys_total_s);
    }
    println!("shape check OK");

    harness::write_json(
        "case_c_flash",
        vec![
            ("scale", femu::util::Json::from(scale as i64)),
            ("workers", femu::util::Json::from(fleet.workers() as i64)),
        ],
        vec![harness::json_result("study_fleet", wall)],
    );
}
