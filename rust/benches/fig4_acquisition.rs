//! Bench: regenerate **Fig 4** — normalized acquisition time and energy
//! for a 5 s window at sampling frequencies 100 Hz..100 kHz, on
//! X-HEEP-FEMU (femu calibration) and the HEEPocrates chip (silicon
//! calibration), with the active/sleep split.
//!
//! The sweep runs twice — on the serial boot-per-point reference path
//! and on the fork-based experiment fleet (golden snapshot, restore per
//! point) — cross-checking bit-identity and reporting the parallel
//! speedup. A second section isolates the fan-out fixed cost itself:
//! boot-per-point vs restore-per-point on one thread at a short window,
//! where per-point setup is a visible fraction of the sweep
//! (`sweep_boot` / `sweep_restore` + `restore_speedup` in the JSON).
//!
//! `cargo bench --bench fig4_acquisition` (set FEMU_FIG4_WINDOW_S to
//! override the emulated window; default 1 s keeps the bench quick while
//! preserving the split — fractions are window-invariant).

#[path = "harness/mod.rs"]
mod harness;

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Fleet};
use femu::util::Json;

fn main() {
    let window_s: f64 = std::env::var("FEMU_FIG4_WINDOW_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = PlatformConfig::default();
    let fleet = Fleet::auto();
    harness::header(&format!(
        "Fig 4: acquisition time & energy, {window_s} s window (normalized)"
    ));

    let (serial_pts, serial_s) = harness::time(|| {
        experiments::fig4_sweep_boot(&Fleet::serial(), &cfg, window_s, 0xF164).unwrap()
    });
    let (points, fleet_s) =
        harness::time(|| experiments::fig4_sweep(&fleet, &cfg, window_s, 0xF164).unwrap());

    println!(
        "{:>9} {:>12} | {:>8} {:>8} | {:>8} {:>8}",
        "f_s (Hz)", "platform", "act_t%", "slp_t%", "act_E%", "slp_E%"
    );
    for p in &points {
        let plat = if p.model == "femu" { "X-HEEP-FEMU" } else { "HEEPocrates" };
        println!(
            "{:>9} {:>12} | {:>7.2}% {:>7.2}% | {:>7.2}% {:>7.2}%",
            p.sample_rate_hz,
            plat,
            100.0 * p.active_s / p.total_s,
            100.0 * p.sleep_s / p.total_s,
            100.0 * p.active_mj / p.total_mj,
            100.0 * p.sleep_mj / p.total_mj,
        );
    }

    // forked-fleet vs serial-reboot bit-identity (the determinism
    // contract, including snapshot-restore exactness)
    assert_eq!(serial_pts.len(), points.len());
    for (a, b) in serial_pts.iter().zip(&points) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.sample_rate_hz.to_bits(), b.sample_rate_hz.to_bits());
        assert_eq!(a.total_mj.to_bits(), b.total_mj.to_bits(), "{} Hz", a.sample_rate_hz);
        assert_eq!(a.active_s.to_bits(), b.active_s.to_bits(), "{} Hz", a.sample_rate_hz);
    }
    println!(
        "\ndeterminism OK: forked fleet({}) output bit-identical to serial re-boot",
        fleet.workers()
    );
    println!(
        "wall-clock: serial-reboot {}s, forked fleet({}) {}s -> {:.2}x",
        harness::eng(serial_s),
        fleet.workers(),
        harness::eng(fleet_s),
        serial_s / fleet_s,
    );

    // fan-out fixed cost: boot-per-point vs restore-per-point, one
    // thread, short window so per-point setup dominates less of the
    // noise floor. Best-of-reps for a stable estimate.
    let fan_window: f64 = std::env::var("FEMU_FIG4_FANOUT_WINDOW_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.02);
    let reps = harness::reps(5);
    let (boot_pts, boot_s) = harness::time_best(reps, || {
        experiments::fig4_sweep_boot(&Fleet::serial(), &cfg, fan_window, 0xF164).unwrap()
    });
    let (restore_pts, restore_s) = harness::time_best(reps, || {
        experiments::fig4_sweep(&Fleet::serial(), &cfg, fan_window, 0xF164).unwrap()
    });
    assert_eq!(boot_pts.len(), restore_pts.len());
    for (a, b) in boot_pts.iter().zip(&restore_pts) {
        assert_eq!(a.total_mj.to_bits(), b.total_mj.to_bits(), "{} Hz", a.sample_rate_hz);
    }
    let restore_speedup = boot_s / restore_s;
    println!(
        "fan-out fixed cost ({fan_window} s window, best of {reps}): \
         boot-per-point {}s vs restore-per-point {}s -> {restore_speedup:.2}x",
        harness::eng(boot_s),
        harness::eng(restore_s),
    );
    if restore_speedup < 1.0 {
        println!("warning: restore-per-point showed no win on this run (noise?)");
    }

    // paper-shape checks (abort the bench loudly if the figure breaks)
    let low = &points[0];
    let high = points.last().unwrap();
    assert!(low.active_s / low.total_s < 0.01, "100 Hz must be sleep-dominated");
    assert!(high.active_s / high.total_s > 0.70, "100 kHz must be active-dominated");
    println!("shape check OK: <1% active at 100 Hz, >70% active at 100 kHz");

    harness::write_json(
        "fig4_acquisition",
        vec![
            ("window_s", Json::Num(window_s)),
            ("fanout_window_s", Json::Num(fan_window)),
            ("workers", Json::from(fleet.workers() as i64)),
            ("restore_speedup", Json::Num(restore_speedup)),
        ],
        vec![
            harness::json_result("sweep_serial", serial_s),
            harness::json_result("sweep_fleet", fleet_s),
            harness::json_result("sweep_boot", boot_s),
            harness::json_result("sweep_restore", restore_s),
        ],
    );
}
