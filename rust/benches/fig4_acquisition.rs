//! Bench: regenerate **Fig 4** — normalized acquisition time and energy
//! for a 5 s window at sampling frequencies 100 Hz..100 kHz, on
//! X-HEEP-FEMU (femu calibration) and the HEEPocrates chip (silicon
//! calibration), with the active/sleep split.
//!
//! `cargo bench --bench fig4_acquisition` (set FEMU_FIG4_WINDOW_S to
//! override the emulated window; default 1 s keeps the bench quick while
//! preserving the split — fractions are window-invariant).

#[path = "harness/mod.rs"]
mod harness;

use femu::config::PlatformConfig;
use femu::coordinator::experiments;

fn main() {
    let window_s: f64 = std::env::var("FEMU_FIG4_WINDOW_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = PlatformConfig::default();
    harness::header(&format!(
        "Fig 4: acquisition time & energy, {window_s} s window (normalized)"
    ));
    println!(
        "{:>9} {:>12} | {:>8} {:>8} | {:>8} {:>8} | {:>9}",
        "f_s (Hz)", "platform", "act_t%", "slp_t%", "act_E%", "slp_E%", "bench_s"
    );
    let mut rows = Vec::new();
    for f in experiments::FIG4_FREQS_HZ {
        let (points, wall) =
            harness::time(|| experiments::fig4_point(&cfg, f, window_s, 0xF164).unwrap());
        for p in &points {
            let plat = if p.model == "femu" { "X-HEEP-FEMU" } else { "HEEPocrates" };
            println!(
                "{:>9} {:>12} | {:>7.2}% {:>7.2}% | {:>7.2}% {:>7.2}% | {:>9}",
                p.sample_rate_hz,
                plat,
                100.0 * p.active_s / p.total_s,
                100.0 * p.sleep_s / p.total_s,
                100.0 * p.active_mj / p.total_mj,
                100.0 * p.sleep_mj / p.total_mj,
                harness::eng(wall),
            );
        }
        rows.push(points);
    }
    // paper-shape checks (abort the bench loudly if the figure breaks)
    let low = &rows[0][0];
    let high = rows.last().unwrap().first().unwrap();
    assert!(low.active_s / low.total_s < 0.01, "100 Hz must be sleep-dominated");
    assert!(high.active_s / high.total_s > 0.70, "100 kHz must be active-dominated");
    println!("\nshape check OK: <1% active at 100 Hz, >70% active at 100 kHz");
}
