//! Bench: regenerate **Fig 4** — normalized acquisition time and energy
//! for a 5 s window at sampling frequencies 100 Hz..100 kHz, on
//! X-HEEP-FEMU (femu calibration) and the HEEPocrates chip (silicon
//! calibration), with the active/sleep split.
//!
//! The sweep runs twice — on the serial reference path and on the
//! experiment fleet — cross-checking bit-identity and reporting the
//! parallel speedup.
//!
//! `cargo bench --bench fig4_acquisition` (set FEMU_FIG4_WINDOW_S to
//! override the emulated window; default 1 s keeps the bench quick while
//! preserving the split — fractions are window-invariant).

#[path = "harness/mod.rs"]
mod harness;

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Fleet};
use femu::util::Json;

fn main() {
    let window_s: f64 = std::env::var("FEMU_FIG4_WINDOW_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = PlatformConfig::default();
    let fleet = Fleet::auto();
    harness::header(&format!(
        "Fig 4: acquisition time & energy, {window_s} s window (normalized)"
    ));

    let (serial_pts, serial_s) =
        harness::time(|| experiments::fig4_sweep(&Fleet::serial(), &cfg, window_s, 0xF164).unwrap());
    let (points, fleet_s) =
        harness::time(|| experiments::fig4_sweep(&fleet, &cfg, window_s, 0xF164).unwrap());

    println!(
        "{:>9} {:>12} | {:>8} {:>8} | {:>8} {:>8}",
        "f_s (Hz)", "platform", "act_t%", "slp_t%", "act_E%", "slp_E%"
    );
    for p in &points {
        let plat = if p.model == "femu" { "X-HEEP-FEMU" } else { "HEEPocrates" };
        println!(
            "{:>9} {:>12} | {:>7.2}% {:>7.2}% | {:>7.2}% {:>7.2}%",
            p.sample_rate_hz,
            plat,
            100.0 * p.active_s / p.total_s,
            100.0 * p.sleep_s / p.total_s,
            100.0 * p.active_mj / p.total_mj,
            100.0 * p.sleep_mj / p.total_mj,
        );
    }

    // fleet/serial bit-identity (the fleet determinism contract)
    assert_eq!(serial_pts.len(), points.len());
    for (a, b) in serial_pts.iter().zip(&points) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.sample_rate_hz.to_bits(), b.sample_rate_hz.to_bits());
        assert_eq!(a.total_mj.to_bits(), b.total_mj.to_bits(), "{} Hz", a.sample_rate_hz);
        assert_eq!(a.active_s.to_bits(), b.active_s.to_bits(), "{} Hz", a.sample_rate_hz);
    }
    println!("\ndeterminism OK: fleet({}) output bit-identical to serial", fleet.workers());
    println!(
        "wall-clock: serial {}s, fleet({}) {}s -> {:.2}x",
        harness::eng(serial_s),
        fleet.workers(),
        harness::eng(fleet_s),
        serial_s / fleet_s,
    );

    // paper-shape checks (abort the bench loudly if the figure breaks)
    let low = &points[0];
    let high = points.last().unwrap();
    assert!(low.active_s / low.total_s < 0.01, "100 Hz must be sleep-dominated");
    assert!(high.active_s / high.total_s > 0.70, "100 kHz must be active-dominated");
    println!("shape check OK: <1% active at 100 Hz, >70% active at 100 kHz");

    harness::write_json(
        "fig4_acquisition",
        vec![
            ("window_s", Json::Num(window_s)),
            ("workers", Json::from(fleet.workers() as i64)),
        ],
        vec![
            harness::json_result("sweep_serial", serial_s),
            harness::json_result("sweep_fleet", fleet_s),
        ],
    );
}
