//! Tiny benchmark harness (criterion is unavailable offline; see
//! Cargo.toml). Each bench binary is `harness = false` and uses these
//! helpers to time emulator wall-clock, print paper-style tables, and
//! emit machine-readable `BENCH_*.json` snapshots for CI.

// Each bench includes this module via #[path] and uses only a subset of
// the helpers, so per-binary dead-code analysis is meaningless here.
#![allow(dead_code)]

use std::time::Instant;

use femu::util::Json;

/// Wall-time one closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Run `f` `reps` times, reporting the minimum wall time (least-noise
/// estimator) and the last result.
pub fn time_best<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    assert!(reps > 0);
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps {
        let (r, s) = time(&mut f);
        best = best.min(s);
        out = Some(r);
    }
    (out.unwrap(), best)
}

/// Engineering formatting (duplicated from femu::util for bench
/// independence).
pub fn eng(x: f64) -> String {
    femu::util::eng(x)
}

pub fn header(title: &str) {
    println!("\n==============================================================");
    println!("{title}");
    println!("==============================================================");
}

/// Iteration count for statistics-gathering loops: `FEMU_BENCH_REPS`
/// overrides `default` (CI's bench-smoke job sets a small value).
pub fn reps(default: usize) -> usize {
    std::env::var("FEMU_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// One timed entry of a bench JSON report.
pub fn json_result(name: &str, wall_s: f64) -> Json {
    Json::obj(vec![("name", Json::from(name)), ("wall_s", Json::Num(wall_s))])
}

/// Write the machine-readable bench snapshot to `BENCH_<bench>.json` (or
/// the path in `FEMU_BENCH_JSON`). CI uploads these as build artifacts so
/// the perf trajectory is tracked run over run.
pub fn write_json(bench: &str, extra: Vec<(&str, Json)>, results: Vec<Json>) {
    let mut fields = vec![("bench", Json::from(bench))];
    fields.extend(extra);
    fields.push(("results", Json::Arr(results)));
    let doc = Json::obj(fields);
    let path =
        std::env::var("FEMU_BENCH_JSON").unwrap_or_else(|_| format!("BENCH_{bench}.json"));
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("\nbench json -> {path}"),
        Err(e) => eprintln!("warning: could not write bench json {path}: {e}"),
    }
}
