//! Tiny benchmark harness (criterion is unavailable offline; see
//! Cargo.toml). Each bench binary is `harness = false` and uses these
//! helpers to time emulator wall-clock and print paper-style tables.

use std::time::Instant;

/// Wall-time one closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Run `f` `reps` times, reporting the minimum wall time (least-noise
/// estimator) and the last result.
pub fn time_best<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    assert!(reps > 0);
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps {
        let (r, s) = time(&mut f);
        best = best.min(s);
        out = Some(r);
    }
    (out.unwrap(), best)
}

/// Engineering formatting (duplicated from femu::util for bench
/// independence).
pub fn eng(x: f64) -> String {
    femu::util::eng(x)
}

pub fn header(title: &str) {
    println!("\n==============================================================");
    println!("{title}");
    println!("==============================================================");
}
