//! Ablation benches for the design choices DESIGN.md §6 calls out:
//!
//! A. **Dual-FIFO ADC pacing vs un-paced reads** — without the nominal-
//!    rate pacing, the acquisition "finishes" as fast as the CPU can
//!    drain the FIFO and the time/energy estimates collapse, which is
//!    why the paper's dual-buffer mechanism matters for honest
//!    acquisition-phase characterization.
//! B. **Energy-model granularity** — per-domain 4-state model vs a
//!    whole-SoC 2-state (active/idle) model: quantifies the estimation
//!    error coarse models introduce across the Fig 4 operating points.
//! C. **Accelerator integration stage** — virtualized (PJRT software
//!    model, placeholder latency) vs RTL-stage (CGRA emulator, cycle
//!    counts): same function, different cost visibility.
//!
//! `cargo bench --bench ablations`

#[path = "harness/mod.rs"]
mod harness;

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Platform};
use femu::energy::EnergyModel;
use femu::perfmon::PowerState;
use femu::workloads::programs;

fn ablation_a_fifo_pacing() {
    harness::header("Ablation A: dual-FIFO pacing vs un-paced ADC reads");
    let cfg = PlatformConfig::default();
    let n = 2_000u64;
    let rate = 1_000.0; // 1 kHz -> nominal 2 s
    // paced (the real mechanism)
    let mut p = Platform::new(cfg.clone());
    p.dbg.load_source(&programs::acquisition(n, 2)).unwrap();
    p.start_adc((0..n as i32).collect(), rate);
    p.run_app(1 << 36).unwrap();
    let paced_s = p.dbg.soc.now as f64 / cfg.soc.freq_hz as f64;
    let paced_e = EnergyModel::femu().estimate(&p.perf_snapshot()).total_mj;

    // un-paced: period forced to 1 cycle (every sample "already there"),
    // modeling a platform that streams without rate emulation
    let mut p = Platform::new(cfg.clone());
    p.dbg.load_source(&programs::acquisition(n, 2)).unwrap();
    p.start_adc((0..n as i32).collect(), cfg.soc.freq_hz as f64); // 1 cycle/sample
    p.run_app(1 << 36).unwrap();
    let unpaced_s = p.dbg.soc.now as f64 / cfg.soc.freq_hz as f64;
    let unpaced_e = EnergyModel::femu().estimate(&p.perf_snapshot()).total_mj;

    println!("paced   : {:>9.4} s, {:>9.5} mJ  (nominal window {:.3} s)", paced_s, paced_e, n as f64 / rate);
    println!("un-paced: {:>9.4} s, {:>9.5} mJ", unpaced_s, unpaced_e);
    println!(
        "-> un-paced underestimates acquisition time {:.0}x and energy {:.1}x",
        paced_s / unpaced_s,
        paced_e / unpaced_e
    );
    assert!(paced_s / unpaced_s > 50.0, "pacing must matter");
    assert!((paced_s - n as f64 / rate).abs() / (n as f64 / rate) < 0.05);
}

fn ablation_b_energy_granularity() {
    harness::header("Ablation B: 4-state per-domain model vs 2-state CPU-centric model");
    // The common MCU-datasheet shortcut: price the whole SoC by the CPU's
    // state alone (P_run while the CPU is active, P_sleep otherwise). It
    // tracks CPU-only workloads closely — and falls apart the moment an
    // accelerator burns power while the CPU sleeps, which is exactly the
    // co-design regime FEMU targets (hence the per-domain counters).
    let cfg = PlatformConfig::default();
    let fine = EnergyModel::heepocrates();
    let banks = cfg.soc.num_banks as f64;
    let p_run: f64 =
        fine.cpu.mw[0] + fine.bus.mw[0] + fine.periph.mw[0] + banks * fine.mem_bank.mw[0];
    let p_sleep: f64 =
        fine.cpu.mw[1] + fine.bus.mw[1] + fine.periph.mw[1] + banks * fine.mem_bank.mw[3];
    println!("{:>10} | {:>12} {:>12} {:>8}", "workload", "4-state mJ", "2-state mJ", "err %");
    let mut errs = Vec::new();
    for (imp, label) in
        [(experiments::Fig5Impl::Cpu, "MM on CPU"), (experiments::Fig5Impl::Cgra, "MM on CGRA")]
    {
        // re-run the kernel to get the window time split
        let mut p = Platform::new(cfg.clone());
        let src = match imp {
            experiments::Fig5Impl::Cpu => programs::mm_cpu(121, 16, 4),
            experiments::Fig5Impl::Cgra => programs::mm_cgra(121, 16, 4),
        };
        let prog = p.dbg.load_source(&src).unwrap();
        let mut rng = femu::util::Rng::new(0xB);
        p.dbg.write_i32_slice(prog.symbol("a_buf").unwrap(), &rng.vec_i32(121 * 16, -99, 99)).unwrap();
        p.dbg.write_i32_slice(prog.symbol("b_buf").unwrap(), &rng.vec_i32(16 * 4, -99, 99)).unwrap();
        p.run_app(1 << 32).unwrap();
        let w = p.perf_window_snapshot().unwrap().clone();
        let fine_mj = fine.estimate(&w).total_mj;
        let freq = cfg.soc.freq_hz as f64;
        let cpu_active_s = w.cpu.get(PowerState::Active) as f64 / freq;
        let cpu_sleep_s = (w.cycles - w.cpu.get(PowerState::Active)) as f64 / freq;
        let coarse_mj = p_run * cpu_active_s + p_sleep * cpu_sleep_s;
        let err = 100.0 * (coarse_mj - fine_mj).abs() / fine_mj;
        println!("{:>10} | {:>12.6} {:>12.6} {:>7.1}%", label, fine_mj, coarse_mj, err);
        errs.push(err);
    }
    println!(
        "-> CPU-only error {:.1}% vs accelerated error {:.1}%: per-domain 4-state \
         tracking is what keeps accelerator energy visible",
        errs[0], errs[1]
    );
    assert!(errs[1] > 3.0 * errs[0].max(0.5), "CGRA-phase error must dominate");
}

fn ablation_c_accel_stage() {
    harness::header("Ablation C: virtualized (PJRT) vs RTL-stage (CGRA) accelerator");
    let cfg = PlatformConfig::default();
    // RTL stage: cycle-accounted CGRA run
    let (points, wall_cgra) = harness::time(|| {
        experiments::fig5_run(&cfg, experiments::Fig5Kernel::Mm, experiments::Fig5Impl::Cgra, 3)
            .unwrap()
    });
    let cgra = &points[0];
    // virtualized stage: PJRT artifact (placeholder latency, functional)
    let rt = femu::runtime::Runtime::load("artifacts").expect("make artifacts");
    let mut rng = femu::util::Rng::new(3);
    let a = rng.vec_i32(121 * 16, -4096, 4096);
    let b = rng.vec_i32(16 * 4, -4096, 4096);
    let (out, wall_virt) = harness::time_best(5, || {
        rt.execute(
            "matmul",
            &[
                femu::runtime::TensorI32::new(vec![121, 16], a.clone()).unwrap(),
                femu::runtime::TensorI32::new(vec![16, 4], b.clone()).unwrap(),
            ],
        )
        .unwrap()
    });
    let oracle = femu::workloads::reference::matmul_i32(&a, &b, 121, 16, 4);
    let functional_equal = out[0].data() == oracle.as_slice();
    println!("RTL-stage  : {} guest cycles, validated={}, bench {}s", cgra.cycles, cgra.validated, harness::eng(wall_cgra));
    println!(
        "virtualized: functional={}, host exec {}s/call, latency model {} cycles",
        functional_equal,
        harness::eng(wall_virt),
        femu::virt::accel::DEFAULT_LATENCY_CYCLES
    );
    println!("-> both stages agree functionally; only the RTL stage yields credible perf/energy");
    assert!(functional_equal && cgra.validated);
}

fn ablation_d_sleep_policy() {
    harness::header("Ablation D: memory sleep policy during WFI (active/gated/retention)");
    let cfg = PlatformConfig::default();
    println!("{:>10} | {:>12} {:>14}", "policy", "energy mJ", "bank state");
    let mut energies = Vec::new();
    for (policy, name) in [(0u32, "active"), (1, "clock-gated"), (2, "retention")] {
        let mut p = Platform::new(cfg.clone());
        p.dbg.load_source(&programs::acquisition(500, policy)).unwrap();
        p.start_adc((0..500).collect(), 1_000.0);
        p.run_app(1 << 36).unwrap();
        let snap = p.perf_snapshot();
        let e = EnergyModel::heepocrates().estimate(&snap).total_mj;
        let dominant = PowerState::ALL
            .iter()
            .max_by_key(|&&s| snap.banks[1].get(s))
            .unwrap()
            .name();
        println!("{:>10} | {:>12.5} {:>14}", name, e, dominant);
        energies.push(e);
    }
    println!("-> retention saves {:.1}% vs always-active memories", 100.0 * (energies[0] - energies[2]) / energies[0]);
    assert!(energies[2] < energies[1] && energies[1] < energies[0]);
}

fn main() {
    ablation_a_fifo_pacing();
    ablation_b_energy_granularity();
    ablation_c_accel_stage();
    ablation_d_sleep_policy();
    println!("\nablations OK");
}
