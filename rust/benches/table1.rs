//! Bench: regenerate **Table I** — the FPGA-platform feature comparison,
//! plus the §II filtering narrative (features applied in descending
//! support order until only FEMU survives) — and time both renderers.
//!
//! `cargo bench --bench table1`
//!
//! `FEMU_BENCH_REPS` shrinks the timing loops (CI's bench-smoke job runs
//! with a small value); the JSON snapshot lands in `BENCH_table1.json`
//! (or `FEMU_BENCH_JSON`) for artifact upload.

#[path = "harness/mod.rs"]
mod harness;

use femu::coordinator::table1::{filtering_steps, render_markdown, Feature, TABLE1};
use femu::util::Json;

fn main() {
    harness::header("Table I: comparison of relevant FPGA-based platforms");
    print!("{}", render_markdown());

    harness::header("\u{a7}II filtering argument");
    for (feature, survivors) in filtering_steps() {
        println!(
            "after `{}`: {} platform(s): {}",
            feature.name(),
            survivors.len(),
            survivors.join(", ")
        );
    }

    // structural checks: the table's headline claims
    let full_support: Vec<_> =
        TABLE1.iter().filter(|r| Feature::ALL.iter().all(|&f| r.supports(f))).collect();
    assert_eq!(full_support.len(), 1);
    assert_eq!(full_support[0].name, "FEMU (this work)");
    let steps = filtering_steps();
    assert_eq!(steps.last().unwrap().1, vec!["FEMU (this work)"]);
    println!("\nshape check OK: FEMU is the only platform with all five features");

    // timing + machine-readable snapshot for the CI perf trajectory
    let reps = harness::reps(500);
    let (_, render_s) = harness::time_best(reps, render_markdown);
    let (_, filter_s) = harness::time_best(reps, filtering_steps);
    println!(
        "\ntiming (best of {reps}): render {}s, filtering {}s",
        harness::eng(render_s),
        harness::eng(filter_s)
    );
    harness::write_json(
        "table1",
        vec![("reps", Json::from(reps as i64))],
        vec![
            harness::json_result("render_markdown", render_s),
            harness::json_result("filtering_steps", filter_s),
        ],
    );
}
