//! Bench: regenerate **Table I** — the FPGA-platform feature comparison,
//! plus the §II filtering narrative (features applied in descending
//! support order until only FEMU survives).
//!
//! `cargo bench --bench table1`

#[path = "harness/mod.rs"]
mod harness;

use femu::coordinator::table1::{filtering_steps, render_markdown, Feature, TABLE1};

fn main() {
    harness::header("Table I: comparison of relevant FPGA-based platforms");
    print!("{}", render_markdown());

    harness::header("\u{a7}II filtering argument");
    for (feature, survivors) in filtering_steps() {
        println!("after `{}`: {} platform(s): {}", feature.name(), survivors.len(), survivors.join(", "));
    }

    // structural checks: the table's headline claims
    let full_support: Vec<_> =
        TABLE1.iter().filter(|r| Feature::ALL.iter().all(|&f| r.supports(f))).collect();
    assert_eq!(full_support.len(), 1);
    assert_eq!(full_support[0].name, "FEMU (this work)");
    let steps = filtering_steps();
    assert_eq!(steps.last().unwrap().1, vec!["FEMU (this work)"]);
    println!("\nshape check OK: FEMU is the only platform with all five features");
}
