//! Offline stub of the `xla` PJRT bindings (see README.md).
//!
//! Mirrors exactly the API surface femu uses — [`PjRtClient`],
//! [`PjRtLoadedExecutable`], [`HloModuleProto`], [`XlaComputation`],
//! [`Literal`], [`PjRtBuffer`] — with every entry point returning
//! [`Error`] at runtime. This keeps the PJRT-facing code compiling in the
//! offline build while making its unavailability explicit and catchable
//! (femu's `Runtime::load` surfaces it as a normal `anyhow` error).

use std::fmt;

/// Error type matching the upstream crate's `Display`-able error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "XLA/PJRT backend unavailable in this offline build ({what}); \
             see rust/vendor/xla/README.md"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module (stub: retains nothing).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a parsed module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// PJRT client handle (stub: construction always fails, so the
/// downstream compile/execute methods are unreachable in practice).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Upstream accepts buffers or literals; femu always passes literals.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device-resident result buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn element_count(&self) -> usize {
        0
    }
}
