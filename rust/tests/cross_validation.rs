//! Cross-implementation validation: the core correctness claim of the
//! reproduction (§V-B step 5 writ large).
//!
//! For each case-study kernel, four implementations must agree
//! **bit-for-bit** on random operands:
//!
//! 1. the Rust oracle (`workloads::reference`, itself mirrored against
//!    the Python `ref.py` by the pytest suite),
//! 2. the RV32 assembly kernel executed on the emulated X-HEEP CPU,
//! 3. the CGRA mapping executed by the CGRA emulator,
//! 4. the AOT Pallas artifact executed through PJRT.

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::runtime::{Runtime, TensorI32};
use femu::util::Rng;
use femu::workloads::{programs, reference as refimpl};

fn artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// AOT artifacts are a build product (`make artifacts`) that needs the
/// Python toolchain plus a real PJRT backend; clean offline checkouts
/// have neither. The CPU/CGRA-vs-oracle legs below always run; the PJRT
/// leg self-skips when the runtime cannot load (hard failure instead if
/// `FEMU_REQUIRE_ARTIFACTS` is set).
fn load_runtime() -> Option<Runtime> {
    Runtime::load_or_skip(artifact_dir(), "PJRT cross-checks")
}

fn run_guest(src: &str, stage: &[(&str, &[i32])], read: (&str, usize)) -> Vec<i32> {
    let mut p = Platform::new(PlatformConfig::default());
    let prog = p.dbg.load_source(src).expect("assemble");
    for (sym, data) in stage {
        p.dbg.write_i32_slice(prog.symbol(sym).unwrap(), data).unwrap();
    }
    p.run_app(1 << 33).unwrap();
    p.dbg.read_i32_slice(prog.symbol(read.0).unwrap(), read.1).unwrap()
}

#[test]
fn matmul_four_way_agreement() {
    let rt = load_runtime();
    let (m, k, n) = (121usize, 16usize, 4usize);
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(seed);
        let a = rng.vec_i32(m * k, -30_000, 30_000);
        let b = rng.vec_i32(k * n, -30_000, 30_000);
        let oracle = refimpl::matmul_i32(&a, &b, m, k, n);

        // RV32 CPU
        let cpu = run_guest(
            &programs::mm_cpu(m, k, n),
            &[("a_buf", &a), ("b_buf", &b)],
            ("c_buf", m * n),
        );
        assert_eq!(cpu, oracle, "seed {seed}: CPU vs oracle");

        // CGRA
        let cgra = run_guest(
            &programs::mm_cgra(m, k, n),
            &[("a_buf", &a), ("b_buf", &b)],
            ("c_buf", m * n),
        );
        assert_eq!(cgra, oracle, "seed {seed}: CGRA vs oracle");

        // PJRT artifact
        if let Some(rt) = &rt {
            let out = rt
                .execute(
                    "matmul",
                    &[
                        TensorI32::new(vec![m, k], a.clone()).unwrap(),
                        TensorI32::new(vec![k, n], b.clone()).unwrap(),
                    ],
                )
                .unwrap();
            assert_eq!(out[0].data(), oracle.as_slice(), "seed {seed}: PJRT vs oracle");
        }
    }
}

#[test]
fn conv2d_four_way_agreement() {
    let rt = load_runtime();
    let (h, w, cin, f, kh, kw) = (16usize, 16usize, 3usize, 8usize, 3usize, 3usize);
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    for seed in [4u64, 5] {
        let mut rng = Rng::new(seed);
        let x = rng.vec_i32(h * w * cin, -2000, 2000);
        let wts = rng.vec_i32(f * kh * kw * cin, -2000, 2000);
        let oracle = refimpl::conv2d_i32(&x, &wts, h, w, cin, f, kh, kw);

        let cpu = run_guest(
            &programs::conv_cpu(h, w, cin, f, kh, kw),
            &[("x_buf", &x), ("w_buf", &wts)],
            ("y_buf", oh * ow * f),
        );
        assert_eq!(cpu, oracle, "seed {seed}: CPU vs oracle");

        let cgra = run_guest(
            &programs::conv_cgra(h, w, cin, f, kh, kw),
            &[("x_buf", &x), ("w_buf", &wts)],
            ("y_buf", oh * ow * f),
        );
        assert_eq!(cgra, oracle, "seed {seed}: CGRA vs oracle");

        // PJRT artifact is fixed at the paper shape; result layout is
        // (oh, ow, f) like the oracle
        if let Some(rt) = &rt {
            let out = rt
                .execute(
                    "conv2d",
                    &[
                        TensorI32::new(vec![h, w, cin], x.clone()).unwrap(),
                        TensorI32::new(vec![f, kh, kw, cin], wts.clone()).unwrap(),
                    ],
                )
                .unwrap();
            assert_eq!(out[0].data(), oracle.as_slice(), "seed {seed}: PJRT vs oracle");
        }
    }
}

#[test]
fn fft_four_way_agreement() {
    let rt = load_runtime();
    let n = 512usize;
    for seed in [6u64, 7] {
        let mut rng = Rng::new(seed);
        let re = rng.vec_i32(n, -(1 << 15), 1 << 15);
        let im = rng.vec_i32(n, -(1 << 15), 1 << 15);
        let mut want_re = re.clone();
        let mut want_im = im.clone();
        refimpl::fft_q15(&mut want_re, &mut want_im);

        let (wr, wi) = refimpl::twiddles_q15(n);
        let rev: Vec<i32> = refimpl::bit_reverse_indices(n).iter().map(|&x| x as i32).collect();

        // RV32 CPU (tables injected like the CS does)
        let mut p = Platform::new(PlatformConfig::default());
        let prog = p.dbg.load_source(&programs::fft_cpu(n)).unwrap();
        for (sym, data) in
            [("re_buf", &re), ("im_buf", &im), ("rev_tbl", &rev), ("wr_tbl", &wr), ("wi_tbl", &wi)]
        {
            p.dbg.write_i32_slice(prog.symbol(sym).unwrap(), data).unwrap();
        }
        p.run_app(1 << 33).unwrap();
        let cpu_re = p.dbg.read_i32_slice(prog.symbol("re_buf").unwrap(), n).unwrap();
        let cpu_im = p.dbg.read_i32_slice(prog.symbol("im_buf").unwrap(), n).unwrap();
        assert_eq!(cpu_re, want_re, "seed {seed}: CPU re");
        assert_eq!(cpu_im, want_im, "seed {seed}: CPU im");

        // CGRA
        let mut p = Platform::new(PlatformConfig::default());
        let prog = p.dbg.load_source(&programs::fft_cgra(n)).unwrap();
        for (sym, data) in
            [("re_buf", &re), ("im_buf", &im), ("rev_tbl", &rev), ("wr_tbl", &wr), ("wi_tbl", &wi)]
        {
            p.dbg.write_i32_slice(prog.symbol(sym).unwrap(), data).unwrap();
        }
        p.run_app(1 << 33).unwrap();
        assert!(p.dbg.soc.cgra_fault.is_none(), "{:?}", p.dbg.soc.cgra_fault);
        let cgra_re = p.dbg.read_i32_slice(prog.symbol("re_buf").unwrap(), n).unwrap();
        let cgra_im = p.dbg.read_i32_slice(prog.symbol("im_buf").unwrap(), n).unwrap();
        assert_eq!(cgra_re, want_re, "seed {seed}: CGRA re");
        assert_eq!(cgra_im, want_im, "seed {seed}: CGRA im");

        // PJRT artifact (twiddle tables are runtime parameters)
        if let Some(rt) = &rt {
            let mut args = vec![
                TensorI32::new(vec![n], re.clone()).unwrap(),
                TensorI32::new(vec![n], im.clone()).unwrap(),
            ];
            args.extend(femu::virt::accel::fft_table_tensors(n));
            let out = rt.execute("fft512", &args).unwrap();
            assert_eq!(out[0].data(), want_re.as_slice(), "seed {seed}: PJRT re");
            assert_eq!(out[1].data(), want_im.as_slice(), "seed {seed}: PJRT im");
        }
    }
}

#[test]
fn classifier_guest_vs_direct_artifact() {
    // the e2e path: guest-run classifier (mailbox) result equals direct
    // artifact execution with the same bound weights
    use femu::workloads::signals;
    let n = 512usize;
    let n_classes = 4usize;
    let req_off = 0x1000u32;

    let Some(rt) = Runtime::load_or_skip(artifact_dir(), "classifier_guest_vs_direct_artifact")
    else {
        return;
    };
    let mut platform = Platform::new(PlatformConfig::default());
    platform.accel = Some(femu::virt::AccelService::new(rt));
    let mut rng = Rng::new(0xC1A55);
    let params = vec![
        TensorI32::new(vec![64, 32], rng.vec_i32(64 * 32, -(1 << 14), 1 << 14)).unwrap(),
        TensorI32::new(vec![32], rng.vec_i32(32, -500, 500)).unwrap(),
        TensorI32::new(vec![32, n_classes], rng.vec_i32(32 * n_classes, -(1 << 14), 1 << 14))
            .unwrap(),
        TensorI32::new(vec![n_classes], rng.vec_i32(n_classes, -500, 500)).unwrap(),
    ];
    let sig = signals::biosignal(0xAB, n, 20_000.0);
    let expected = {
        let mut args = vec![TensorI32::new(vec![n], sig.samples.clone()).unwrap()];
        args.extend(params.iter().cloned());
        args.extend(femu::virt::accel::fft_table_tensors(n));
        platform.accel.as_ref().unwrap().runtime().execute("model", &args).unwrap()[0].clone()
    };
    platform.accel.as_mut().unwrap().bind_params("model", params);
    platform.dbg.load_source(&programs::classifier_mailbox(n, n_classes, req_off)).unwrap();
    platform.start_adc(sig.samples.clone(), 20_000.0);
    platform.run_app(1 << 34).unwrap();
    let logits = platform
        .dbg
        .soc
        .bus
        .cs_dram
        .read_i32_slice(req_off as usize + 8 + n * 4, n_classes)
        .unwrap();
    assert_eq!(logits.as_slice(), expected.data());
}
