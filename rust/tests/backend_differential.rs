//! Differential validation of the execution backends (DESIGN.md §11):
//! the block-compiled fast path must be **bit-identical** to the
//! reference interpreter — same retired-instruction stream, same cycle
//! clock, same perf counters, same snapshot bytes — on the lockstep
//! workload suite and on every number the §V experiments publish.

use femu::prelude::*;

fn small_opts() -> LockstepOptions {
    LockstepOptions { checkpoint_cycles: 50_000, max_cycles: 1 << 30, ..LockstepOptions::default() }
}

#[test]
fn lockstep_with_tracing_compares_event_streams() {
    // arm the full event ring on both sides: the checkpoints then also
    // compare trace digests, and they must agree across backends
    let cfg = PlatformConfig::default();
    let opts = LockstepOptions { trace_mask: femu::trace::category::ALL, ..small_opts() };
    let r = diff::lockstep_workload(&cfg, "mm_cpu", BackendKind::Interp, BackendKind::Blocks, &opts)
        .unwrap();
    assert!(r.matched(), "{r}");

    // and a genuine divergence carries both sides' trace captures
    let (mut a, mut b) = diff::platform_pair(&cfg, BackendKind::Interp, BackendKind::Interp);
    a.dbg.load_source("_start: li a0, 1\nebreak").unwrap();
    b.dbg.load_source("_start: li a0, 2\nebreak").unwrap();
    let r = diff::lockstep("mismatch", &mut a, &mut b, &opts).unwrap();
    let d = r.divergence.expect("must diverge");
    let ta = d.trace_a.expect("divergence must carry trace a");
    let tb = d.trace_b.expect("divergence must carry trace b");
    assert_ne!(ta, tb, "different guests must produce different captures");
    assert!(femu::trace::format::TraceDump::from_bytes(&ta).is_ok());
}

#[test]
fn lockstep_suite_interp_vs_blocks_is_bit_identical() {
    let fleet = Fleet::new(2);
    let cfg = PlatformConfig::default();
    let reports = diff::lockstep_workloads(
        &fleet,
        &cfg,
        BackendKind::Interp,
        BackendKind::Blocks,
        &small_opts(),
    )
    .unwrap();
    assert_eq!(reports.len(), diff::LOCKSTEP_WORKLOADS.len());
    for r in &reports {
        assert!(r.matched(), "{r}");
        assert!(r.instret > 0, "{}: lockstep retired nothing", r.workload);
        assert!(r.checkpoints >= 1);
    }
}

#[test]
fn experiments_publish_identical_numbers_on_both_backends() {
    // fig4 at a 0.05 s window + case C at scale 40, same reductions the
    // benches use; fig5 runs its full grid
    let fleet = Fleet::new(2);
    let cfg = PlatformConfig::default();
    let diffs = diff::diff_experiments(
        &fleet,
        &cfg,
        BackendKind::Interp,
        BackendKind::Blocks,
        0.05,
        40,
    )
    .unwrap();
    assert_eq!(diffs.len(), 3);
    for d in &diffs {
        assert!(
            d.matched(),
            "{}: {} mismatched fields, first: {}",
            d.experiment,
            d.mismatches.len(),
            d.mismatches.first().map(String::as_str).unwrap_or("")
        );
        assert!(d.points > 0);
    }
}

#[test]
fn self_modifying_code_invalidates_compiled_blocks() {
    // run the patch loop on the blocks backend alone and observe the
    // re-decode: the patched `addi s0, s0, 8` must take effect (s0 ends
    // at 9, not 2), and the backend must report at least one
    // write-generation invalidation
    let mut cfg = PlatformConfig::default();
    cfg.soc.backend = BackendKind::Blocks;
    let mut p = Platform::new(cfg);
    p.dbg.load_source(&diff::smc_patch_source()).unwrap();
    let exit = p.run_app(1 << 24).unwrap();
    assert!(matches!(exit, AppExit::Halted(_)), "patch loop did not halt: {exit:?}");
    assert_eq!(p.dbg.reg(10), 9, "stale decoded state survived the self-modifying write");

    let stats = p.dbg.soc.exec_stats();
    assert!(stats.block_dispatches > 0, "fast path never engaged: {stats:?}");
    assert!(stats.blocks_built > 0, "{stats:?}");
    assert!(
        stats.block_invalidations >= 1,
        "self-modifying write did not invalidate any block: {stats:?}"
    );
}

#[test]
fn precompiled_block_cache_is_architecturally_invisible() {
    // the `femu diff --precompile` contract as a test: for every suite
    // workload, a blocks platform warmed from the static analyzer's
    // block map stays bit-identical at every checkpoint to a cold one
    let fleet = Fleet::new(2);
    let cfg = PlatformConfig::default();
    let reports = diff::lockstep_workloads_precompiled(&fleet, &cfg, &small_opts()).unwrap();
    assert_eq!(reports.len(), diff::LOCKSTEP_WORKLOADS.len());
    for r in &reports {
        assert!(r.matched(), "{r}");
        assert!(r.instret > 0, "{}: lockstep retired nothing", r.workload);
    }
}

#[test]
fn device_access_at_block_head_makes_progress() {
    // regression guard for the zero-progress hazard: a block whose first
    // instruction is a device access bails out of replay before
    // executing anything, so dispatching it would spin forever — the
    // backend must decline it and single-step instead
    const SRC: &str = r#"
        _start:
            li t0, 0x20000100
            li t1, 3
        loop:
            sw t1, 0(t0)
            addi t1, t1, -1
            bnez t1, loop
            ebreak
    "#;
    let mut cfg = PlatformConfig::default();
    cfg.soc.backend = BackendKind::Blocks;
    let mut p = Platform::new(cfg.clone());
    p.dbg.load_source(SRC).unwrap();
    let exit = p.run_app(1 << 20).unwrap();
    assert!(matches!(exit, AppExit::Halted(_)), "gpio loop did not halt: {exit:?}");
    assert_eq!(p.dbg.reg(6), 0, "t1 should count down to zero");
    let stats = p.dbg.soc.exec_stats();
    assert!(stats.slow_steps > 0, "device accesses must single-step: {stats:?}");

    // precompiling plants the device-head block in the cache before the
    // first instruction ever runs — the exact setup the guard protects —
    // and the run must still be bit-identical to a cold one
    let r = diff::lockstep_source_precompiled(&cfg, "gpio_loop", SRC, &small_opts()).unwrap();
    assert!(r.matched(), "{r}");
}

#[test]
fn smc_result_matches_the_interpreter_exactly() {
    // the same guest through the reference interpreter: identical
    // architectural outcome, by definition of the backend contract
    let cfg = PlatformConfig::default();
    let mut p = Platform::new(cfg);
    assert_eq!(p.dbg.soc.backend_kind(), BackendKind::Interp);
    p.dbg.load_source(&diff::smc_patch_source()).unwrap();
    p.run_app(1 << 24).unwrap();
    assert_eq!(p.dbg.reg(10), 9);
    // and the interpreter's exec stats stay zero (no block machinery)
    assert_eq!(p.dbg.soc.exec_stats(), ExecStats::default());
}
