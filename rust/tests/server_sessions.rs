//! Session-server contracts (DESIGN.md §9): per-session platforms don't
//! cross-talk, long runs on one session don't serialize others, `batch`
//! pipelines against one session in one round trip, and shutdown under
//! load joins every connection thread.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::server::{Client, Server, ServerOptions};
use femu::util::Json;

fn spawn_with(opts: ServerOptions) -> Server {
    Server::spawn_with(Platform::new(PlatformConfig::default()), "127.0.0.1:0", opts).unwrap()
}

/// A guest that stores `value` to `out` and halts.
fn store_program(value: i64) -> String {
    format!(
        r#"
        _start:
            la t0, out
            li t1, {value}
            sw t1, 0(t0)
            ebreak
        .data
        out: .word 0
        "#
    )
}

/// A guest that spins until interrupted.
const SPIN: &str = "_start:\nspin: j spin";

fn load(c: &mut Client, session: u64, src: &str) -> Json {
    c.call_on(
        session,
        Json::obj(vec![("cmd", Json::from("load_asm")), ("source", Json::from(src))]),
    )
    .unwrap()
}

#[test]
fn concurrent_sessions_do_not_cross_talk() {
    let server = spawn_with(ServerOptions {
        max_sessions: 16,
        workers: 4,
        ..ServerOptions::default()
    });
    let addr = server.addr();

    // N clients, each with a private session running its own program;
    // every readback must see its own value, never a neighbour's.
    let handles: Vec<_> = (0..6i64)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let session = c.open_session(Json::Null).unwrap();
                let value = 1000 + i;
                for round in 0..3 {
                    let loaded = load(&mut c, session, &store_program(value));
                    let out =
                        loaded.get("symbols").unwrap().get("out").unwrap().as_i64().unwrap();
                    let run = c
                        .call_on(session, Json::obj(vec![("cmd", Json::from("run"))]))
                        .unwrap();
                    assert_eq!(run.str_field("exit").unwrap(), "halted", "round {round}");
                    let mem = c
                        .call_on(
                            session,
                            Json::obj(vec![
                                ("cmd", Json::from("read_mem")),
                                ("addr", Json::from(out)),
                                ("n", Json::from(1i64)),
                            ]),
                        )
                        .unwrap();
                    assert_eq!(
                        mem.as_arr().unwrap()[0].as_i64().unwrap(),
                        value,
                        "session {session} read a foreign value in round {round}"
                    );
                }
                c.close_session(session).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}

#[test]
fn long_run_on_one_session_does_not_serialize_another() {
    let server = spawn_with(ServerOptions {
        max_sessions: 8,
        workers: 4,
        ..ServerOptions::default()
    });
    let addr = server.addr();

    // session A: a spinning guest with an effectively unbounded budget,
    // interrupted only by session.close
    let mut ctl = Client::connect(addr).unwrap();
    let a = ctl.open_session(Json::Null).unwrap();
    load(&mut ctl, a, SPIN);
    let a_done = Arc::new(AtomicBool::new(false));
    let a_done2 = a_done.clone();
    let a_runner = std::thread::spawn(move || {
        let mut ca = Client::connect(addr).unwrap();
        let run = ca.call_on(a, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        a_done2.store(true, Ordering::SeqCst);
        run.str_field("exit").unwrap().to_string()
    });

    // wait until A's run is actually holding a worker
    let t0 = Instant::now();
    loop {
        let listed = ctl.call(Json::obj(vec![("cmd", Json::from("session.list"))])).unwrap();
        let a_busy = listed
            .as_arr()
            .unwrap()
            .iter()
            .any(|s| {
                s.get("session").unwrap().as_i64().unwrap() == a as i64
                    && s.get("busy").unwrap().as_bool().unwrap()
            });
        if a_busy {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "A's run never started");
        std::thread::sleep(Duration::from_millis(10));
    }

    // session B: a full load/run/read cycle completes while A spins —
    // with the old global platform lock this would block behind A's
    // 2^33-cycle run
    let mut cb = Client::connect(addr).unwrap();
    let b = cb.open_session(Json::Null).unwrap();
    let loaded = load(&mut cb, b, &store_program(7777));
    let out = loaded.get("symbols").unwrap().get("out").unwrap().as_i64().unwrap();
    let run = cb.call_on(b, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
    assert_eq!(run.str_field("exit").unwrap(), "halted");
    let mem = cb
        .call_on(
            b,
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(out)),
                ("n", Json::from(1i64)),
            ]),
        )
        .unwrap();
    assert_eq!(mem.as_arr().unwrap()[0].as_i64().unwrap(), 7777);
    assert!(
        !a_done.load(Ordering::SeqCst),
        "A's unbounded run finished before B: sessions are serializing"
    );

    // closing A interrupts its run at the next slice boundary
    ctl.close_session(a).unwrap();
    let exit = a_runner.join().unwrap();
    assert_eq!(exit, "interrupted");
    server.shutdown();
}

#[test]
fn shutdown_under_load_joins_all_connections() {
    let server = spawn_with(ServerOptions {
        max_sessions: 8,
        workers: 3,
        ..ServerOptions::default()
    });
    let addr = server.addr();

    // three sessions each running an unbounded spin, plus one idle
    // connection parked in a read
    let started: Vec<_> = (0..3)
        .map(|_| {
            let flag = Arc::new(AtomicBool::new(false));
            let flag2 = flag.clone();
            let h = std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let s = c.open_session(Json::Null).unwrap();
                load(&mut c, s, SPIN);
                flag2.store(true, Ordering::SeqCst);
                // the run is either interrupted by shutdown (response
                // delivered before the stream drops) or the connection
                // closes under us — both are clean outcomes
                match c.call_on(s, Json::obj(vec![("cmd", Json::from("run"))])) {
                    Ok(r) => assert_eq!(r.str_field("exit").unwrap(), "interrupted"),
                    Err(e) => {
                        let msg = format!("{e:#}");
                        assert!(
                            msg.contains("connection closed")
                                || msg.contains("reading server response")
                                || msg.contains("sending request"),
                            "unexpected error under shutdown: {msg}"
                        );
                    }
                }
            });
            (flag, h)
        })
        .collect();
    let _idle = Client::connect(addr).unwrap();

    // wait until every run has been submitted
    let t0 = Instant::now();
    while !started.iter().all(|(f, _)| f.load(Ordering::SeqCst)) {
        assert!(t0.elapsed() < Duration::from_secs(30), "runs never started");
        std::thread::sleep(Duration::from_millis(10));
    }
    std::thread::sleep(Duration::from_millis(100)); // let the runs enter the pool

    // graceful shutdown: must return with every connection thread joined
    // even though three unbounded runs are in flight
    let t0 = Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "shutdown took {:?} — connection threads not quiescing",
        t0.elapsed()
    );
    for (_, h) in started {
        h.join().unwrap();
    }
}

#[test]
fn batch_pipelines_one_round_trip() {
    let server = spawn_with(ServerOptions::default());
    let mut c = Client::connect(server.addr()).unwrap();
    let s = c.open_session(Json::Null).unwrap();

    // stage + run + read in ONE round trip
    let resp = c
        .batch_on(
            s,
            vec![
                Json::obj(vec![
                    ("cmd", Json::from("load_asm")),
                    ("source", Json::from(store_program(4242).as_str())),
                ]),
                Json::obj(vec![("cmd", Json::from("run"))]),
                Json::obj(vec![("cmd", Json::from("uart"))]),
            ],
        )
        .unwrap();
    assert_eq!(resp.get("completed").unwrap().as_i64().unwrap(), 3);
    let results = resp.get("results").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(r.get("ok").unwrap().as_bool().unwrap());
    }
    assert_eq!(
        results[1].get("result").unwrap().str_field("exit").unwrap(),
        "halted"
    );
    // follow-up read through the same session sees the batch's effects
    let out = results[0]
        .get("result")
        .unwrap()
        .get("symbols")
        .unwrap()
        .get("out")
        .unwrap()
        .as_i64()
        .unwrap();
    let mem = c
        .call_on(
            s,
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(out)),
                ("n", Json::from(1i64)),
            ]),
        )
        .unwrap();
    assert_eq!(mem.as_arr().unwrap()[0].as_i64().unwrap(), 4242);

    // a failing sub-request aborts the rest: [ping, bogus, ping] stops
    // after the error, reporting one success
    let resp = c
        .batch_on(
            s,
            vec![
                Json::obj(vec![("cmd", Json::from("ping"))]),
                Json::obj(vec![("cmd", Json::from("warp"))]),
                Json::obj(vec![("cmd", Json::from("ping"))]),
            ],
        )
        .unwrap();
    assert_eq!(resp.get("completed").unwrap().as_i64().unwrap(), 1);
    let results = resp.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2, "batch must abort after the first failure");
    assert!(!results[1].get("ok").unwrap().as_bool().unwrap());

    // nested batches and session commands are rejected inside a batch
    let resp = c
        .batch_on(s, vec![Json::obj(vec![("cmd", Json::from("session.close"))])])
        .unwrap();
    assert_eq!(resp.get("completed").unwrap().as_i64().unwrap(), 0);
    server.shutdown();
}

#[test]
fn session_capacity_evicts_lru_idle() {
    let server = spawn_with(ServerOptions {
        max_sessions: 3, // session 0 + two client sessions
        workers: 2,
        ..ServerOptions::default()
    });
    let mut c = Client::connect(server.addr()).unwrap();
    let s1 = c.open_session(Json::Null).unwrap();
    std::thread::sleep(Duration::from_millis(10));
    let s2 = c.open_session(Json::Null).unwrap();
    // touch s1 so s2 is the LRU
    c.call_on(s1, Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
    let s3 = c.open_session(Json::Null).unwrap();
    let err = c.call_on(s2, Json::obj(vec![("cmd", Json::from("regs"))])).unwrap_err();
    assert!(format!("{err:#}").contains("unknown session"), "{err:#}");
    c.call_on(s1, Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
    c.call_on(s3, Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
    // the default session is never evicted
    c.call(Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
    server.shutdown();
}

#[test]
fn idle_sessions_reaped_by_accept_loop() {
    let server = spawn_with(ServerOptions {
        idle_timeout: Duration::from_millis(100),
        ..ServerOptions::default()
    });
    let mut c = Client::connect(server.addr()).unwrap();
    let s = c.open_session(Json::Null).unwrap();
    // the accept loop reaps roughly every 500ms of idle ticking
    std::thread::sleep(Duration::from_millis(1500));
    let err = c.call_on(s, Json::obj(vec![("cmd", Json::from("regs"))])).unwrap_err();
    assert!(format!("{err:#}").contains("unknown session"), "{err:#}");
    // the default session survives reaping
    c.call(Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
    server.shutdown();
}

#[test]
fn sessions_from_named_and_inline_configs() {
    let chip = PlatformConfig::parse("name = \"chip\"\nfreq_hz = 32_000_000").unwrap();
    let server = spawn_with(ServerOptions {
        named_configs: vec![("chip".into(), chip)],
        ..ServerOptions::default()
    });
    let mut c = Client::connect(server.addr()).unwrap();

    let named = c
        .open_session(Json::obj(vec![("config_name", Json::from("chip"))]))
        .unwrap();
    let inline = c
        .open_session(Json::obj(vec![(
            "config",
            Json::from("name = \"tiny\"\nfreq_hz = 10_000_000"),
        )]))
        .unwrap();
    // both run a guest fine and report their config label in the listing
    for s in [named, inline] {
        load(&mut c, s, &store_program(1));
        let run = c.call_on(s, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert_eq!(run.str_field("exit").unwrap(), "halted");
    }
    let listed = c.call(Json::obj(vec![("cmd", Json::from("session.list"))])).unwrap();
    let labels: Vec<String> = listed
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.str_field("config").unwrap().to_string())
        .collect();
    assert!(labels.iter().any(|l| l == "chip"), "{labels:?}");
    assert!(labels.iter().any(|l| l == "inline:tiny"), "{labels:?}");
    server.shutdown();
}

#[test]
fn experiment_command_over_the_wire() {
    let server = spawn_with(ServerOptions::default());
    let mut c = Client::connect(server.addr()).unwrap();
    let r = c
        .call(Json::obj(vec![
            ("cmd", Json::from("sweep_acquisition")),
            ("window_s", Json::Num(0.02)),
        ]))
        .unwrap();
    let points = r.get("points").unwrap().as_arr().unwrap();
    assert_eq!(points.len(), 12); // 6 freqs x 2 calibrations
    for p in points {
        assert!(p.get("total_s").unwrap().as_f64().unwrap() > 0.0);
    }
    server.shutdown();
}
