//! Property tests over the ISA layer (hand-rolled generator: the
//! `proptest` crate is unavailable offline; `femu::util::Rng` provides
//! seeded deterministic generation with the failing seed in the panic
//! message).
//!
//! Invariants:
//! * decode(encode(i)) == i for every representable instruction,
//! * decode never panics on arbitrary words,
//! * the CPU ALU matches a wide-integer reference on random operands,
//! * assembled programs decode word by word.

use femu::isa::{self, decode, encode, AluOp, BranchOp, CsrOp, Instr, LoadOp, StoreOp};
use femu::util::Rng;

const CASES: usize = 5_000;

fn rand_instr(rng: &mut Rng) -> Instr {
    let rd = rng.range_i32(0, 32) as u8;
    let rs1 = rng.range_i32(0, 32) as u8;
    let rs2 = rng.range_i32(0, 32) as u8;
    let imm12 = rng.range_i32(-2048, 2048);
    let imm_u = (rng.range_i32(0, 1 << 20) << 12) as i32;
    match rng.below(13) {
        0 => Instr::Lui { rd, imm: imm_u },
        1 => Instr::Auipc { rd, imm: imm_u },
        2 => Instr::Jal { rd, imm: rng.range_i32(-(1 << 20) / 2, (1 << 20) / 2) * 2 },
        3 => Instr::Jalr { rd, rs1, imm: imm12 },
        4 => {
            let op = [
                BranchOp::Eq,
                BranchOp::Ne,
                BranchOp::Lt,
                BranchOp::Ge,
                BranchOp::Ltu,
                BranchOp::Geu,
            ][rng.below(6) as usize];
            Instr::Branch { op, rs1, rs2, imm: rng.range_i32(-2048, 2048) * 2 }
        }
        5 => {
            let op = [LoadOp::Lb, LoadOp::Lh, LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu]
                [rng.below(5) as usize];
            Instr::Load { op, rd, rs1, imm: imm12 }
        }
        6 => {
            let op = [StoreOp::Sb, StoreOp::Sh, StoreOp::Sw][rng.below(3) as usize];
            Instr::Store { op, rs1, rs2, imm: imm12 }
        }
        7 => {
            // immediate ALU (no Sub / M-ops)
            let op = [
                AluOp::Add,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Xor,
                AluOp::Or,
                AluOp::And,
            ][rng.below(6) as usize];
            Instr::OpImm { op, rd, rs1, imm: imm12 }
        }
        8 => {
            let op = [AluOp::Sll, AluOp::Srl, AluOp::Sra][rng.below(3) as usize];
            Instr::OpImm { op, rd, rs1, imm: rng.range_i32(0, 32) }
        }
        9 => {
            let op = [
                AluOp::Add,
                AluOp::Sub,
                AluOp::Sll,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Xor,
                AluOp::Srl,
                AluOp::Sra,
                AluOp::Or,
                AluOp::And,
                AluOp::Mul,
                AluOp::Mulh,
                AluOp::Mulhsu,
                AluOp::Mulhu,
                AluOp::Div,
                AluOp::Divu,
                AluOp::Rem,
                AluOp::Remu,
            ][rng.below(18) as usize];
            Instr::Op { op, rd, rs1, rs2 }
        }
        10 => [Instr::Fence, Instr::Ecall, Instr::Ebreak, Instr::Wfi, Instr::Mret]
            [rng.below(5) as usize],
        11 => {
            let op = [CsrOp::Rw, CsrOp::Rs, CsrOp::Rc][rng.below(3) as usize];
            Instr::Csr {
                op,
                rd,
                rs1,
                csr: rng.range_i32(0, 4096) as u16,
                imm: false,
            }
        }
        _ => {
            let op = [CsrOp::Rw, CsrOp::Rs, CsrOp::Rc][rng.below(3) as usize];
            Instr::Csr { op, rd, rs1: rng.range_i32(0, 32) as u8, csr: rng.range_i32(0, 4096) as u16, imm: true }
        }
    }
}

#[test]
fn prop_encode_decode_roundtrip() {
    let mut rng = Rng::new(0x150_1);
    for case in 0..CASES {
        let instr = rand_instr(&mut rng);
        let word = encode(instr);
        let back = decode(word);
        assert_eq!(back, Some(instr), "case {case}: word {word:#010x}");
    }
}

#[test]
fn prop_decode_total_no_panic() {
    let mut rng = Rng::new(0x150_2);
    for _ in 0..50_000 {
        let word = rng.next_u32();
        // must not panic; re-encoding a decoded word must round-trip
        if let Some(i) = decode(word) {
            assert_eq!(decode(encode(i)), Some(i), "{word:#010x} -> {i:?}");
        }
    }
}

#[test]
fn prop_alu_matches_wide_reference() {
    // run random R-type ops through the CPU and compare with an i64/i128
    // reference computed independently
    use femu::soc::{Soc, SocConfig};
    let mut rng = Rng::new(0x150_3);
    for _ in 0..300 {
        let a = rng.next_u32();
        let b = rng.next_u32();
        let (op_name, expect): (&str, u32) = match rng.below(8) {
            0 => ("add", a.wrapping_add(b)),
            1 => ("sub", a.wrapping_sub(b)),
            2 => ("mul", (a as u64).wrapping_mul(b as u64) as u32),
            3 => ("mulh", (((a as i32 as i128) * (b as i32 as i128)) >> 32) as u32),
            4 => ("mulhu", (((a as u128) * (b as u128)) >> 32) as u32),
            5 => (
                "div",
                if b == 0 {
                    u32::MAX
                } else if a == 0x8000_0000 && b == u32::MAX {
                    a
                } else {
                    ((a as i32) / (b as i32)) as u32
                },
            ),
            6 => ("remu", if b == 0 { a } else { a % b }),
            _ => ("sltu", (a < b) as u32),
        };
        let src = format!(
            "_start:\nli t0, {}\nli t1, {}\n{op_name} t2, t0, t1\nebreak",
            a as i32, b as i32
        );
        let prog = isa::assemble(&src).unwrap();
        let mut soc = Soc::new(SocConfig::default());
        soc.load(&prog).unwrap();
        soc.run_to_halt(1_000);
        assert_eq!(soc.cpu.regs[7], expect, "{op_name}({a:#x}, {b:#x})");
    }
}

#[test]
fn prop_assembled_words_all_decode() {
    // every program generator's output decodes word by word
    use femu::workloads::programs;
    for src in [
        programs::acquisition(64, 2),
        programs::mm_cpu(9, 5, 3),
        programs::conv_cpu(8, 8, 2, 3, 3, 3),
        programs::fft_cpu(64),
        programs::mm_cgra(9, 5, 3),
        programs::conv_cgra(8, 8, 2, 3, 3, 3),
        programs::fft_cgra(64),
        programs::classifier_mailbox(128, 4, 0x800),
    ] {
        let prog = isa::assemble(&src).unwrap();
        for (i, w) in prog.text.iter().enumerate() {
            assert!(decode(*w).is_some(), "word {i} = {w:#010x} does not decode");
        }
    }
}

#[test]
fn prop_branch_offset_symmetry() {
    // encoding a branch with offset x and decoding gives x, for all legal
    // even offsets at the range edges
    for imm in [-4096i32, -2048, -2, 0, 2, 2048, 4094] {
        let i = Instr::Branch { op: BranchOp::Ne, rs1: 1, rs2: 2, imm };
        assert_eq!(decode(encode(i)), Some(i), "imm {imm}");
    }
    for imm in [-(1 << 20), -2, 0, 2, (1 << 20) - 2] {
        let i = Instr::Jal { rd: 1, imm };
        assert_eq!(decode(encode(i)), Some(i), "jal imm {imm}");
    }
}

#[test]
fn prop_disasm_assemble_roundtrip() {
    // disassemble(word) must re-assemble to the identical word for every
    // representable instruction (pc-relative forms rendered at pc=0 can
    // encode absolute targets beyond the +-1 MiB jal range, so jumps and
    // branches are rendered at a mid-range pc)
    use femu::isa::{assemble_with, disassemble};
    let mut rng = Rng::new(0xD15A);
    let pc = 0x10_0000u32; // mid-range anchor
    for case in 0..2_000 {
        let instr = rand_instr(&mut rng);
        let text = disassemble(instr, pc);
        let prog = assemble_with(
            &format!(".text\n{text}\n"),
            femu::isa::asm::Options { text_base: pc, data_base: 0x2_0000 },
        )
        .unwrap_or_else(|e| panic!("case {case}: `{text}` from {instr:?}: {e:#}"));
        // pseudo-expansions (li of large constants) may be 2 words; the
        // round-trip property applies to 1-word renderings
        if prog.text.len() == 1 {
            assert_eq!(
                prog.text[0],
                encode(instr),
                "case {case}: `{text}` from {instr:?}"
            );
        }
    }
}
