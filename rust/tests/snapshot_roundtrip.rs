//! Snapshot/restore contracts: (1) run N cycles, snapshot, run K more,
//! restore, re-run K — every architectural observable (registers,
//! memory, perf counters, UART) is bit-identical between the two K-legs;
//! (2) a snapshot survives the bytes/hex codecs and restores into a
//! fresh platform; (3) corrupted, truncated, and shape-mismatched
//! images are rejected before any state is touched.

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::perfmon::PerfSnapshot;
use femu::snapshot::PlatformSnapshot;
use femu::workloads::programs;

/// Every guest-visible observable we can cheaply collect.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    now: u64,
    pc: u32,
    regs: Vec<u32>,
    instret: u64,
    instructions: u64,
    uart: Vec<u8>,
    perf: PerfSnapshot,
    sram: Vec<u8>,
}

fn fingerprint(p: &mut Platform) -> Fingerprint {
    let uart = p.dbg.uart();
    let soc = &p.dbg.soc;
    let sram = soc
        .bus
        .banks
        .iter()
        .flat_map(|b| b.dump(0, b.size()).unwrap().to_vec())
        .collect();
    Fingerprint {
        now: soc.now,
        pc: soc.cpu.pc,
        regs: soc.cpu.regs.to_vec(),
        instret: soc.cpu.instret,
        instructions: soc.stats.instructions,
        uart,
        perf: soc.perf.snapshot(soc.now),
        sram,
    }
}

/// A busy mixed workload: timer-paced WFI sleep (retention memories),
/// UART logging, a DMA copy and a CGRA matmul launch per iteration —
/// touches every stateful device the snapshot must capture.
fn busy_guest(iterations: u32) -> String {
    format!(
        r#"
        .equ UART,  0x20000000
        .equ TIMER, 0x20000200
        .equ DMA,   0x20000500
        .equ POWER, 0x20000600
        .equ CGRA,  0x20000700
        _start:
            la  t0, handler
            csrw mtvec, t0
            li  t0, POWER
            li  t1, 2            # retention sleep for memories
            sw  t1, 0(t0)
            li  s0, {iterations}
            li  s1, 0            # iteration counter
        loop:
            # log one byte
            li  t0, UART
            addi t1, s1, 65
            sw  t1, 0(t0)
            # DMA: copy src -> dst
            la  t0, src
            la  t1, dst
            li  t2, DMA
            sw  t0, 0(t2)
            sw  t1, 4(t2)
            li  t3, 12
            sw  t3, 8(t2)
            li  t3, 1
            sw  t3, 12(t2)
        dma_wait:
            lw  t4, 16(t2)
            andi t4, t4, 1
            beqz t4, dma_wait
            # CGRA: 4x4 matmul launch
            li  t0, CGRA
            sw  zero, 8(t0)
            la  t1, a
            sw  t1, 0x40(t0)
            la  t1, b
            sw  t1, 0x44(t0)
            la  t1, c
            sw  t1, 0x48(t0)
            li  t1, 4
            sw  t1, 0x4C(t0)
            sw  t1, 0x50(t0)
            sw  t1, 0x54(t0)
            li  t1, 1
            sw  t1, 4(t0)
        cgra_wait:
            lw  t2, 0(t0)
            andi t2, t2, 1
            beqz t2, cgra_wait
            # sleep until the next timer tick
            li  t0, TIMER
            lw  t1, 0(t0)        # mtime_lo
            addi t1, t1, 2000
            sw  t1, 8(t0)        # mtimecmp_lo
            sw  zero, 12(t0)
            li  t1, 1
            sw  t1, 16(t0)       # irq enable
            li  t1, 0x80
            csrw mie, t1
            csrsi mstatus, 8
            wfi
            csrci mstatus, 8
            addi s1, s1, 1
            blt  s1, s0, loop
            ebreak
        handler:
            li  t5, TIMER
            li  t6, -1
            sw  t6, 8(t5)        # push mtimecmp far out (clear MTIP)
            sw  t6, 12(t5)
            mret
        .data
        src: .word 11, 22, 33
        dst: .word 0, 0, 0
        a:  .word 1, 0, 0, 0
            .word 0, 2, 0, 0
            .word 0, 0, 3, 0
            .word 0, 0, 0, 4
        b:  .word 1, 1, 1, 1
            .word 1, 1, 1, 1
            .word 1, 1, 1, 1
            .word 1, 1, 1, 1
        c:  .space 64
        "#
    )
}

fn busy_platform() -> Platform {
    let mut p = Platform::new(PlatformConfig::default());
    p.dbg.load_source(&busy_guest(200)).unwrap();
    p
}

#[test]
fn mid_flight_roundtrip_is_bit_identical() {
    // property grid: snapshot at N cycles, compare two K-cycle re-runs
    for &n in &[5_000u64, 37_123, 250_000] {
        for &k in &[20_000u64, 111_111] {
            let mut p = busy_platform();
            p.run_app(n).unwrap();
            let snap = p.snapshot();
            p.run_app(k).unwrap();
            let first = fingerprint(&mut p);

            p.restore(&snap).unwrap();
            p.run_app(k).unwrap();
            let second = fingerprint(&mut p);
            assert_eq!(first, second, "divergence after restore (n={n}, k={k})");
        }
    }
}

#[test]
fn acquisition_roundtrip_covers_adc_service_state() {
    // mid-acquisition snapshot: the dual-FIFO pacing (device + CS
    // software FIFO) must resume without underrun or drift
    let build = || {
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg.load_source(&programs::acquisition(2_000, 2)).unwrap();
        p.start_adc((0..2_000).collect(), 100_000.0);
        p
    };
    let mut p = build();
    p.run_app(120_000).unwrap(); // mid-stream (full run is ~400k cycles)
    let snap = p.snapshot();
    p.run_app(10_000_000).unwrap(); // to halt
    let first = fingerprint(&mut p);
    assert!(!p.dbg.soc.bus.spi_adc.underrun());

    p.restore(&snap).unwrap();
    p.run_app(10_000_000).unwrap();
    let second = fingerprint(&mut p);
    assert_eq!(first, second);
    assert!(!p.dbg.soc.bus.spi_adc.underrun());
}

#[test]
fn restore_into_fresh_platform_through_bytes_and_hex() {
    let mut p = busy_platform();
    p.run_app(42_000).unwrap();
    let snap = p.snapshot();

    // bytes codec
    let bytes = snap.as_bytes().to_vec();
    let reloaded = PlatformSnapshot::from_bytes(bytes).unwrap();
    // hex codec (the snapshot.save/restore wire form)
    let rehexed = PlatformSnapshot::from_hex(&snap.to_hex()).unwrap();

    p.run_app(60_000).unwrap();
    let want = fingerprint(&mut p);

    for image in [reloaded, rehexed] {
        let mut fresh = Platform::new(PlatformConfig::default());
        fresh.restore(&image).unwrap();
        fresh.run_app(60_000).unwrap();
        assert_eq!(fingerprint(&mut fresh), want);
    }
}

#[test]
fn fork_matches_source_and_diverges_independently() {
    let mut p = busy_platform();
    p.run_app(30_000).unwrap();
    let mut fork = p.fork().unwrap();

    // same start, same future
    p.run_app(25_000).unwrap();
    fork.run_app(25_000).unwrap();
    assert_eq!(fingerprint(&mut p), fingerprint(&mut fork));

    // divergence stays private to the fork
    fork.dbg.write32(0x100, 0xDEAD_0001).unwrap();
    assert_ne!(p.dbg.read32(0x100).unwrap(), 0xDEAD_0001);
}

#[test]
fn corrupted_and_truncated_snapshots_are_rejected() {
    let mut p = busy_platform();
    p.run_app(10_000).unwrap();
    let snap = p.snapshot();
    let good = snap.as_bytes().to_vec();

    // flip one byte anywhere in the payload: checksum must catch it
    for at in [28usize, good.len() / 2, good.len() - 1] {
        let mut bad = good.clone();
        bad[at] ^= 0x40;
        let err = PlatformSnapshot::from_bytes(bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checksum") || msg.contains("version") || msg.contains("truncated"),
            "byte {at}: {msg}"
        );
    }
    // truncations at several depths
    for keep in [0usize, 7, 20, good.len() - 1] {
        let mut short = good.clone();
        short.truncate(keep);
        assert!(PlatformSnapshot::from_bytes(short).is_err(), "keep={keep}");
    }
    // the platform that produced it is still intact and restorable
    p.restore(&snap).unwrap();
}

#[test]
fn shape_mismatch_is_rejected_before_any_state_is_touched() {
    let p = busy_platform();
    let snap = p.snapshot();
    let mut other_cfg = PlatformConfig::default();
    other_cfg.soc.num_banks = 4;
    let mut other = Platform::new(other_cfg);
    other.dbg.load_source("_start: li a0, 9\nebreak").unwrap();
    let err = other.restore(&snap).unwrap_err();
    assert!(format!("{err:#}").contains("shape mismatch"), "{err:#}");
    // untouched: still runs its own guest
    other.run_app(10_000).unwrap();
    assert_eq!(other.dbg.reg(10), 9);
}
