//! Campaign determinism matrix (DESIGN.md §15): the same spec must
//! produce a bit-identical outcome table for any worker count and on
//! either execution backend, every point must classify, and code-page
//! faults on the blocks backend must trip the self-modifying-code
//! invalidation rather than executing a stale compiled block.

use femu::config::PlatformConfig;
use femu::coordinator::{Fleet, Platform};
use femu::exec::BackendKind;
use femu::faults::{
    golden_from, run_campaign, run_point, CampaignSpec, FaultModel, FaultPoint, Outcome,
    TargetSpace,
};

/// The acceptance-criteria campaign: 1000 points over every target
/// space of the acquisition workload at a fixed seed, run three ways.
/// Results must be identical across serial vs fleet(4) and across
/// interp vs blocks, with zero unclassified outcomes (classification is
/// total by construction; the sum check holds the line).
#[test]
fn thousand_point_campaign_is_bit_identical_across_workers_and_backends() {
    let mut spec = CampaignSpec::new("acquisition").unwrap();
    spec.points = 1000;
    spec.seed = 0x5EED_F417;

    let mut interp_cfg = PlatformConfig::default();
    interp_cfg.soc.backend = BackendKind::Interp;
    let mut blocks_cfg = interp_cfg.clone();
    blocks_cfg.soc.backend = BackendKind::Blocks;

    let serial = run_campaign(&interp_cfg, Fleet::serial(), &spec).unwrap();
    let fleet = run_campaign(&interp_cfg, Fleet::new(4), &spec).unwrap();
    let blocks = run_campaign(&blocks_cfg, Fleet::new(4), &spec).unwrap();

    assert_eq!(serial.results.len(), 1000);
    let counts = serial.class_counts();
    assert_eq!(counts.iter().sum::<usize>(), 1000, "zero unclassified outcomes");
    // a full-space campaign is not degenerate: more than one class shows up
    assert!(counts.iter().filter(|&&c| c > 0).count() >= 2, "{counts:?}");

    assert_eq!(serial.golden, fleet.golden, "golden record: serial vs fleet(4)");
    assert_eq!(serial.results, fleet.results, "outcome table: serial vs fleet(4)");
    assert_eq!(serial.golden, blocks.golden, "golden record: interp vs blocks");
    assert_eq!(serial.results, blocks.results, "outcome table: interp vs blocks");
}

/// A code-page fault injected mid-loop on the blocks backend: the
/// faulted word sits in a block that has already been compiled and
/// dispatched, so a stale-block bug would keep adding the old constant
/// (masked); correct SMC invalidation recompiles and the output
/// diverges (SDC). The invalidation must also be visible in
/// `exec_stats`.
#[test]
fn code_fault_trips_block_invalidation_not_stale_execution() {
    let asm = r#"
        _start:
            li s0, 64
            la s1, dst
        pass:
            li t0, 42
            add t2, t2, t0
            addi s0, s0, -1
            bnez s0, pass
            sw t2, 0(s1)
            ebreak
        .data
        dst: .word 0
    "#;
    let mut cfg = PlatformConfig::default();
    cfg.soc.backend = BackendKind::Blocks;
    let mut p = Platform::new(cfg);
    let prog = p.dbg.load_source(asm).unwrap();
    let outputs = vec![(prog.symbol("dst").unwrap(), 4)];
    let (snap, golden) = golden_from(&mut p, &outputs).unwrap();

    p.restore(&snap).unwrap();
    // `li t0, 42` at the `pass` label assembles to addi with the
    // immediate in bits 31:20; flipping bit 20 turns 42 into 43 for
    // every remaining iteration
    let fault = FaultPoint {
        target: TargetSpace::SramCode,
        model: FaultModel::BitFlip,
        addr: prog.symbol("pass").unwrap(),
        bit: 20,
        inject_cycle: (golden.warm_cycle + golden.end_cycle) / 2,
    };
    let r = run_point(&mut p, &golden, &outputs, 4, 0, fault).unwrap();
    assert_eq!(
        r.outcome,
        Outcome::Sdc,
        "a mid-loop code flip must change the sum — masked means a stale block executed"
    );
    let stats = p.dbg.soc.exec_stats();
    assert!(stats.block_dispatches > 0, "the blocks backend actually ran: {stats:?}");
    assert!(
        stats.block_invalidations >= 1,
        "the code-page write must invalidate compiled blocks: {stats:?}"
    );
}

/// The same point-level scenario classifies identically on both
/// backends — the per-point path (restore, inject, watchdog, classify)
/// is backend-agnostic, not just whole campaigns.
#[test]
fn single_point_classification_matches_across_backends() {
    let run_on = |backend: BackendKind| {
        let mut cfg = PlatformConfig::default();
        cfg.soc.backend = backend;
        let mut p = Platform::new(cfg);
        let prog = p
            .dbg
            .load_source(
                r#"
                _start:
                    la t0, src
                    lw t1, 0(t0)
                    la t2, dst
                    sw t1, 0(t2)
                    ebreak
                .data
                src: .word 0x5A5A
                dst: .word 0
                "#,
            )
            .unwrap();
        let outputs = vec![(prog.symbol("dst").unwrap(), 4)];
        let (snap, golden) = golden_from(&mut p, &outputs).unwrap();
        p.restore(&snap).unwrap();
        let fault = FaultPoint {
            target: TargetSpace::SramData,
            model: FaultModel::BitFlip,
            addr: prog.symbol("src").unwrap(),
            bit: 3,
            inject_cycle: golden.warm_cycle,
        };
        (golden.clone(), run_point(&mut p, &golden, &outputs, 4, 0, fault).unwrap())
    };
    let (ga, ra) = run_on(BackendKind::Interp);
    let (gb, rb) = run_on(BackendKind::Blocks);
    assert_eq!(ga, gb);
    assert_eq!(ra, rb);
    assert_eq!(ra.outcome, Outcome::Sdc);
}
