//! Guest profiler + control-plane metrics integration tests
//! (DESIGN.md §14): cross-backend bit-identity of pc histograms, the
//! cycle/instruction/energy conservation contract of folded reports,
//! derived-state behavior across snapshot restore, and the histogram
//! percentile math the server metrics are built on.

use femu::analyze::{analyze_program, AnalyzeConfig};
use femu::config::PlatformConfig;
use femu::coordinator::{AppExit, Platform};
use femu::exec::BackendKind;
use femu::profile::{build_report, ProfileReport};

/// Run `src` with the profiler armed on `backend`; returns the halted
/// platform.
fn run_profiled(backend: BackendKind, src: &str) -> Platform {
    let mut cfg = PlatformConfig::default();
    cfg.soc.backend = backend;
    cfg.soc.profile = true;
    let mut p = Platform::new(cfg);
    p.dbg.load_source(src).unwrap();
    let exit = p.run_app(1 << 30).unwrap();
    assert!(matches!(exit, AppExit::Halted(_)), "guest did not halt: {exit:?}");
    p
}

/// Fold the platform's capture through the analyzer's symbols — the
/// same path `femu profile` takes.
fn report_of(p: &Platform, src: &str, name: &str) -> ProfileReport {
    let prog = femu::isa::assemble(src).unwrap();
    let acfg = AnalyzeConfig::from_platform(&p.cfg);
    let table = analyze_program(&prog, name, &acfg).function_table();
    let soc = &p.dbg.soc;
    let prof = soc.profiler().unwrap();
    let perf_now = soc.perf.snapshot(soc.now);
    build_report(prof, soc.now, &perf_now, &table, &p.cfg.energy, soc.backend_kind().name())
}

/// A self-modifying guest: the loop patches its own body (the store
/// invalidates any compiled block), so the blocks backend must fall
/// back and still produce the interpreter's exact capture.
const SMC_SRC: &str = r#"
    _start:
        li t0, 3
        la t1, target
        li t3, 0x00250513    # addi a0, a0, 2
    loop:
        sw t3, 0(t1)
    target:
        addi a0, a0, 1       # rewritten to +2 by the first store
        addi t0, t0, -1
        bnez t0, loop
        ebreak
"#;

#[test]
fn interp_and_blocks_profiles_are_bit_identical() {
    for src in [femu::workloads::builtin("mm_cpu").unwrap(), SMC_SRC.to_string()] {
        let a = run_profiled(BackendKind::Interp, &src);
        let b = run_profiled(BackendKind::Blocks, &src);
        let c = run_profiled(BackendKind::Interp, &src);
        let digest = |p: &Platform| {
            let prof = p.dbg.soc.profiler().unwrap();
            (prof.digest(), prof.attributed_cycles(), prof.retired(), prof.records())
        };
        assert_eq!(digest(&a), digest(&b), "backends produced different captures");
        assert_eq!(digest(&a), digest(&c), "repeat run produced a different capture");
    }
}

#[test]
fn attribution_conserves_cycles_instructions_and_energy() {
    let src = femu::workloads::builtin("mm_cpu").unwrap();
    let p = run_profiled(BackendKind::Interp, &src);
    let rep = report_of(&p, &src, "mm_cpu");
    let soc = &p.dbg.soc;

    // the window is exactly the perf monitor's delta over the same span
    let prof = soc.profiler().unwrap();
    let delta = soc.perf.snapshot(soc.now).delta(prof.baseline());
    assert_eq!(rep.window_cycles, delta.cycles);
    assert_eq!(rep.attributed_cycles + rep.idle_cycles, rep.window_cycles);

    // every attributed cycle and retire lands in exactly one function
    let flat: u64 = rep.functions.iter().map(|f| f.flat_cycles).sum();
    assert_eq!(flat, rep.attributed_cycles);
    let instret: u64 = rep.functions.iter().map(|f| f.flat_instret).sum();
    assert_eq!(instret, rep.retired);
    assert_eq!(rep.retired, soc.stats.instructions, "profiler missed retires");

    // energy conserves: function shares + [idle] == the model's total
    // for the same window, to float round-off
    let mj: f64 = rep.functions.iter().map(|f| f.flat_mj).sum::<f64>() + rep.idle_mj;
    assert!((mj - rep.total_mj).abs() <= 1e-9 * rep.total_mj.max(1.0), "{mj} != {}", rep.total_mj);
    let est = p.cfg.energy.estimate(&delta);
    assert!((rep.total_mj - est.total_mj).abs() < 1e-12);
}

#[test]
fn sleep_fast_forward_lands_in_idle() {
    // WFI until a timer at cycle 20000: the fast-forwarded cycles never
    // hit a retire hook, so they must come out as [idle], and the
    // conservation identity must still hold exactly
    const SRC: &str = r#"
        .equ TIMER, 0x20000200
        _start:
            la t0, handler
            csrw mtvec, t0
            li t0, TIMER
            li t1, 20000
            sw t1, 8(t0)
            sw zero, 12(t0)
            li t1, 1
            sw t1, 16(t0)
            li t1, 0x80
            csrw mie, t1
            csrsi mstatus, 8
            wfi
            ebreak
        handler:
            ebreak
    "#;
    let p = run_profiled(BackendKind::Interp, SRC);
    let rep = report_of(&p, SRC, "wfi");
    assert!(rep.idle_cycles > 0, "sleep fast-forward recorded no idle cycles");
    assert_eq!(rep.attributed_cycles + rep.idle_cycles, rep.window_cycles);
    assert!(rep.idle_mj > 0.0, "sleeping must still cost retention/gated power");
}

#[test]
fn restore_resets_the_profile_without_phantom_samples() {
    const SRC: &str = "_start: li t0, 5000\nloop: addi t0, t0, -1\nbnez t0, loop\nebreak";
    let mut cfg = PlatformConfig::default();
    cfg.soc.profile = true;
    let mut p = Platform::new(cfg.clone());
    p.dbg.load_source(SRC).unwrap();
    let exit = p.run_app(1000).unwrap();
    assert!(matches!(exit, AppExit::Budget), "{exit:?}");
    assert!(p.dbg.soc.profiler().unwrap().records() > 0, "nothing recorded before snapshot");
    let snap = p.snapshot();

    // profiles are derived state: an armed and an unarmed platform at
    // the same architectural point snapshot to identical bytes
    let mut cfg_off = cfg.clone();
    cfg_off.soc.profile = false;
    let mut q = Platform::new(cfg_off);
    q.dbg.load_source(SRC).unwrap();
    q.run_app(1000).unwrap();
    let dir = std::env::temp_dir();
    let pa = dir.join(format!("femu_prof_a_{}.femusnap", std::process::id()));
    let pb = dir.join(format!("femu_prof_b_{}.femusnap", std::process::id()));
    snap.save(&pa).unwrap();
    q.snapshot().save(&pb).unwrap();
    let (ba, bb) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
    assert_eq!(ba, bb, "an armed profiler leaked into the snapshot");

    // restoring into an armed platform reopens an empty window at the
    // restored clock — no samples from before the boundary survive
    let mut r = Platform::new(cfg);
    r.restore(&snap).unwrap();
    let restored_at = r.dbg.soc.now;
    let prof = r.dbg.soc.profiler().expect("profiling stays armed across restore");
    assert_eq!(prof.records(), 0, "phantom samples survived the restore");
    assert_eq!(prof.start_cycle(), restored_at);
    let exit = r.run_app(1 << 24).unwrap();
    assert!(matches!(exit, AppExit::Halted(_)), "{exit:?}");
    let prof = r.dbg.soc.profiler().unwrap();
    assert_eq!(
        prof.attributed_cycles(),
        r.dbg.soc.now - restored_at,
        "the restored window must cover exactly the post-restore cycles"
    );
}

#[test]
fn profile_and_analyze_share_symbol_names() {
    // the satellite contract: profile JSON function names are drawn
    // from the same symbol scheme as `femu analyze --json`
    let src = femu::workloads::builtin("mm_cpu").unwrap();
    let prog = femu::isa::assemble(&src).unwrap();
    let p = run_profiled(BackendKind::Interp, &src);
    let acfg = AnalyzeConfig::from_platform(&p.cfg);
    let analyze_json = analyze_program(&prog, "mm_cpu", &acfg).to_json().to_string();
    let rep = report_of(&p, &src, "mm_cpu");
    assert!(!rep.functions.is_empty());
    for f in &rep.functions {
        if f.name == femu::profile::UNKNOWN_NAME {
            continue;
        }
        assert!(
            analyze_json.contains(&format!("\"{}\"", f.name)),
            "profile function `{}` is not an analyzer symbol",
            f.name
        );
    }
}

#[test]
fn histogram_percentiles_and_counters() {
    use femu::metrics::{Counter, Gauge, Histogram, LATENCY_BOUNDS_US};

    let c = Counter::new();
    c.inc();
    c.add(4);
    assert_eq!(c.get(), 5);
    let g = Gauge::new();
    g.add(3);
    g.add(-5);
    assert_eq!(g.get(), -2);
    g.set(7);
    assert_eq!(g.get(), 7);

    // 100 observations 1..=100 µs: every one lands in the 100 µs bucket
    // or below, so p50/p90/p99 all report bucket upper bounds that
    // bracket the true values
    let h = Histogram::new(LATENCY_BOUNDS_US);
    for v in 1..=100u64 {
        h.observe(v);
    }
    assert_eq!(h.count(), 100);
    assert_eq!(h.sum(), 5050);
    assert!((h.mean() - 50.5).abs() < 1e-9);
    let p50 = h.percentile(0.50);
    let p90 = h.percentile(0.90);
    let p99 = h.percentile(0.99);
    assert!((50..=100).contains(&p50), "p50 bucket bound {p50}");
    assert!(p90 >= 90, "p90 bucket bound {p90}");
    assert!(p99 >= p90 && p50 <= p90, "percentiles must be monotone");

    // overflow observations clamp to the last finite bound
    let h = Histogram::new(LATENCY_BOUNDS_US);
    h.observe(u64::MAX);
    assert_eq!(h.count(), 1);
    assert_eq!(h.percentile(0.99), *LATENCY_BOUNDS_US.last().unwrap());
}
