//! End-to-end validation of the static analyzer (DESIGN.md §12) against
//! real execution:
//!
//! * every built-in workload lints at **zero diagnostics** (the CI
//!   analyzer-lint job runs the same check through the CLI),
//! * for the pure-CPU kernels, the statically recovered block map is
//!   *identical* to what the blocks backend builds dynamically, and
//!   precompiling from it leaves nothing to build at run time,
//! * the self-modifying-code workload trips FEMU-A003,
//! * the static WCET/CPI and energy ceilings bound the measured
//!   `perf_snapshot()` numbers of real runs.

use femu::analyze::{analyze_program, AnalyzeConfig, Severity};
use femu::config::PlatformConfig;
use femu::coordinator::{AppExit, Platform};
use femu::exec::BackendKind;
use femu::isa::assemble;
use femu::soc::{Soc, SocConfig};
use femu::workloads::{builtin, BUILTIN_NAMES};

/// Kernels with no peripheral waits, interrupts, or sleep: the cases
/// where the static block map must match the dynamic one exactly.
const CPU_KERNELS: [&str; 3] = ["mm_cpu", "conv_cpu", "fft_cpu"];

const BUDGET: u64 = 1 << 26;

fn blocks_soc() -> Soc {
    let cfg = SocConfig { backend: BackendKind::Blocks, ..SocConfig::default() };
    Soc::new(cfg)
}

#[test]
fn every_builtin_lints_clean() {
    let cfg = AnalyzeConfig::default();
    for &name in BUILTIN_NAMES {
        let prog = assemble(&builtin(name).unwrap()).unwrap();
        let r = analyze_program(&prog, name, &cfg);
        assert!(
            r.clean(),
            "{name}: expected zero diagnostics, got {:#?}",
            r.diagnostics
        );
        assert!(r.instructions > 0, "{name}: nothing reachable");
        assert!(!r.blocks.is_empty(), "{name}: empty block map");
        assert!(r.cpi_bound >= 1, "{name}");
    }
}

#[test]
fn static_block_map_equals_dynamic_for_cpu_kernels() {
    let cfg = AnalyzeConfig::default();
    for name in CPU_KERNELS {
        let prog = assemble(&builtin(name).unwrap()).unwrap();
        let r = analyze_program(&prog, name, &cfg);

        let mut soc = blocks_soc();
        soc.load(&prog).unwrap();
        soc.run_to_halt(BUDGET);

        assert_eq!(
            soc.block_map(),
            r.blocks,
            "{name}: static and dynamic block maps differ"
        );
        assert_eq!(
            soc.exec_stats().blocks_built as usize,
            r.blocks.len(),
            "{name}: backend built blocks the analyzer missed (or vice versa)"
        );
    }
}

#[test]
fn precompiled_cache_leaves_nothing_to_build() {
    let cfg = AnalyzeConfig::default();
    for name in CPU_KERNELS {
        let prog = assemble(&builtin(name).unwrap()).unwrap();
        let r = analyze_program(&prog, name, &cfg);
        let entries = r.block_entries();

        let mut soc = blocks_soc();
        soc.load(&prog).unwrap();
        soc.precompile(&entries);
        assert_eq!(
            soc.exec_stats().blocks_built as usize,
            entries.len(),
            "{name}: precompile did not build every offered entry"
        );

        soc.run_to_halt(BUDGET);
        let stats = soc.exec_stats();
        assert_eq!(
            stats.blocks_built as usize,
            entries.len(),
            "{name}: run after precompile still had to build blocks"
        );
        assert_eq!(stats.block_invalidations, 0, "{name}");
        assert_eq!(soc.block_map(), r.blocks, "{name}");
    }
}

#[test]
fn smc_workload_trips_a003() {
    let src = femu::exec::diff::smc_patch_source();
    let prog = assemble(&src).unwrap();
    let r = analyze_program(&prog, "smc_patch", &AnalyzeConfig::default());
    let hits: Vec<_> =
        r.diagnostics.iter().filter(|d| d.rule == "FEMU-A003").collect();
    assert!(
        !hits.is_empty(),
        "self-modifying store not flagged: {:#?}",
        r.diagnostics
    );
    for d in hits {
        assert_eq!(d.severity, Severity::Warning);
        assert!(d.pc.is_some(), "A003 should point at the store");
    }
}

#[test]
fn static_bounds_cover_measured_runs() {
    // run each CPU kernel for real and check every advertised bound:
    // measured cycles <= instret * cpi_bound, measured energy <= the
    // all-active ceiling, and the backend's own conservative cycle
    // accounting brackets its fast-path cycles.
    let pcfg = {
        let mut c = PlatformConfig::default();
        c.soc.backend = BackendKind::Blocks;
        c
    };
    let acfg = AnalyzeConfig::from_platform(&pcfg);
    for name in CPU_KERNELS {
        let src = builtin(name).unwrap();
        let prog = assemble(&src).unwrap();
        let r = analyze_program(&prog, name, &acfg);
        assert!(r.clean(), "{name}: {:#?}", r.diagnostics);

        let mut p = Platform::new(pcfg.clone());
        p.dbg.load_source(&src).unwrap();
        match p.run_app(BUDGET).unwrap() {
            AppExit::Halted(_) => {}
            AppExit::Budget => panic!("{name} blew the cycle budget"),
        }

        let snap = p.perf_snapshot();
        let instret = p.dbg.soc.cpu.instret;
        assert!(instret > 0 && snap.cycles > 0, "{name}");
        assert!(
            snap.cycles <= r.cycle_bound(instret),
            "{name}: measured {} cycles > static bound {} ({} instret x {} cpi)",
            snap.cycles,
            r.cycle_bound(instret),
            instret,
            r.cpi_bound,
        );

        let measured_mj = p.cfg.energy.estimate(&snap).total_mj;
        let ceiling_mj = r.energy_bound_mj(snap.cycles);
        assert!(
            measured_mj <= ceiling_mj + 1e-12,
            "{name}: measured {measured_mj} mJ > static ceiling {ceiling_mj} mJ"
        );

        let stats = p.dbg.soc.exec_stats();
        assert!(
            stats.block_cycles <= stats.bounded_cycles,
            "{name}: fast-path accounting above its own bound"
        );
    }
}

#[test]
fn call_program_gets_finite_wcet_and_depth() {
    // the non-leaf saves ra in a callee-saved register (not the stack:
    // the walk does not track memory, and a stack-reloaded ra would
    // correctly lint as FEMU-A007)
    let src = r#"
        _start:
            jal ra, outer
            ebreak
        outer:
            mv s0, ra
            jal ra, inner
            mv ra, s0
            ret
        inner:
            addi a0, a0, 1
            ret
    "#;
    let prog = assemble(src).unwrap();
    let r = analyze_program(&prog, "calls", &AnalyzeConfig::default());
    assert!(r.clean(), "{:#?}", r.diagnostics);
    assert_eq!(r.call_depth, 3);
    for f in &r.functions {
        assert!(
            f.wcet_cycles.is_some(),
            "loop-free fn {} reported unbounded",
            f.name
        );
    }
    // the static WCET of the whole program bounds an actual run
    let main = r.functions.iter().find(|f| f.entry == r.entry).unwrap();
    let mut soc = Soc::new(SocConfig::default());
    soc.load(&prog).unwrap();
    soc.run_to_halt(10_000);
    assert!(
        soc.now <= main.wcet_cycles.unwrap(),
        "measured {} > WCET {}",
        soc.now,
        main.wcet_cycles.unwrap()
    );
}
