//! Frame-robustness sweeps: random byte mutations, truncations, and
//! length-field lies against the FEMUSNAP and FEMUTRAC containers must
//! never panic and never trigger unbounded allocation — every rejection
//! is a clean typed error. Deterministic (fixed xorshift seed), so a
//! surviving mutation is reproducible.

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::snapshot::PlatformSnapshot;
use femu::trace::format::TraceDump;
use femu::trace::{category, TraceConfig, TraceRing};

/// xorshift64 — a tiny deterministic position picker for the sweeps.
fn xorshift(s: &mut u64) -> u64 {
    *s ^= *s << 13;
    *s ^= *s >> 7;
    *s ^= *s << 17;
    *s
}

/// FNV-1a 64 with the frame parameters (re-derived here so the test
/// can forge checksum-valid corruptions without a crate-internal hook).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

const SNAP_HEADER_LEN: usize = 28;

fn good_snapshot_bytes() -> Vec<u8> {
    Platform::new(PlatformConfig::default()).snapshot().as_bytes().to_vec()
}

fn good_trace_bytes() -> Vec<u8> {
    let mut ring = TraceRing::new(TraceConfig {
        mask: category::ALL,
        ..TraceConfig::default()
    });
    for i in 0..200u64 {
        ring.retire(10 + i * 2, (i as u32) * 4);
    }
    ring.bus_write(100, 0, 0x80, 0xDEAD_BEEF, 1);
    ring.bus_write(150, 1, 0x2000_0000, 7, 0);
    ring.irq_edges(200, 0b10);
    ring.irq_edges(260, 0b00);
    ring.power(300, 1, 2);
    TraceDump::from_ring(&ring, 20_000_000, 2).to_bytes()
}

#[test]
fn snapshot_single_bit_flips_are_always_rejected() {
    let good = good_snapshot_bytes();
    // sanity: the pristine frame round-trips
    PlatformSnapshot::from_bytes(good.clone()).unwrap();

    // every header bit, plus a deterministic sample of payload bits
    let mut positions: Vec<(usize, u8)> = (0..SNAP_HEADER_LEN)
        .flat_map(|i| (0..8).map(move |b| (i, b)))
        .collect();
    let mut s = 0x5EED_0001u64;
    for _ in 0..4096 {
        let i = SNAP_HEADER_LEN + (xorshift(&mut s) as usize) % (good.len() - SNAP_HEADER_LEN);
        let b = (xorshift(&mut s) % 8) as u8;
        positions.push((i, b));
    }
    for (i, bit) in positions {
        let mut m = good.clone();
        m[i] ^= 1 << bit;
        let r = PlatformSnapshot::from_bytes(m);
        assert!(
            r.is_err(),
            "single-bit flip at byte {i} bit {bit} slipped past frame validation"
        );
    }
}

#[test]
fn snapshot_truncations_and_padding_are_always_rejected() {
    let good = good_snapshot_bytes();
    // every short prefix near the header, then strided prefixes, then
    // one-byte-short and one-byte-padded frames
    let mut lens: Vec<usize> = (0..SNAP_HEADER_LEN.min(good.len())).collect();
    lens.extend((SNAP_HEADER_LEN..good.len()).step_by(97));
    lens.push(good.len() - 1);
    for len in lens {
        let r = PlatformSnapshot::from_bytes(good[..len].to_vec());
        assert!(r.is_err(), "truncation to {len} bytes slipped past frame validation");
    }
    let mut padded = good.clone();
    padded.push(0);
    assert!(PlatformSnapshot::from_bytes(padded).is_err(), "padded frame accepted");
}

#[test]
fn snapshot_length_field_lies_fail_cleanly_without_allocation() {
    let good = good_snapshot_bytes();
    let payload_len = (good.len() - SNAP_HEADER_LEN) as u64;
    for lie in [0u64, 1, payload_len - 1, payload_len + 1, u32::MAX as u64, u64::MAX] {
        let mut m = good.clone();
        m[12..20].copy_from_slice(&lie.to_le_bytes());
        // must reject by *comparison*, never by allocating `lie` bytes
        let r = PlatformSnapshot::from_bytes(m);
        assert!(r.is_err(), "length lie {lie} slipped past frame validation");
    }
}

/// Corruptions that beat the outer checksum (payload flip + forged
/// checksum) pass frame validation by construction — the restore
/// decoder is then the last line of defense and must fail cleanly (or
/// decode to *some* platform) without panicking or over-allocating.
#[test]
fn checksum_valid_payload_corruptions_never_panic_restore() {
    let good = good_snapshot_bytes();
    let mut target = Platform::new(PlatformConfig::default());
    let mut s = 0x5EED_0002u64;
    for _ in 0..256 {
        let i = SNAP_HEADER_LEN + (xorshift(&mut s) as usize) % (good.len() - SNAP_HEADER_LEN);
        let bit = (xorshift(&mut s) % 8) as u8;
        let mut m = good.clone();
        m[i] ^= 1 << bit;
        let forged = fnv1a64(&m[SNAP_HEADER_LEN..]);
        m[20..28].copy_from_slice(&forged.to_le_bytes());
        let snap = PlatformSnapshot::from_bytes(m)
            .expect("forged checksum must pass frame validation");
        // Err is fine (decoder catches the corruption), Ok is fine (the
        // flip landed in don't-care state); a panic/abort is the bug
        let _ = target.restore(&snap);
    }
}

const TRACE_HEADER_LEN: usize = 28;

#[test]
fn trace_single_bit_flips_are_always_rejected() {
    let good = good_trace_bytes();
    TraceDump::from_bytes(&good).unwrap();

    // the trace frame carries the same payload checksum as snapshots,
    // so every single-bit flip — header or payload — must be rejected
    let mut positions: Vec<(usize, u8)> = (0..TRACE_HEADER_LEN.min(good.len()))
        .flat_map(|i| (0..8).map(move |b| (i, b)))
        .collect();
    let mut s = 0x5EED_0003u64;
    for _ in 0..4096 {
        let i = (xorshift(&mut s) as usize) % good.len();
        let b = (xorshift(&mut s) % 8) as u8;
        positions.push((i, b));
    }
    for (i, bit) in positions {
        let mut m = good.clone();
        m[i] ^= 1 << bit;
        let r = TraceDump::from_bytes(&m);
        assert!(
            r.is_err(),
            "single-bit flip at byte {i} bit {bit} slipped past trace validation"
        );
    }
}

#[test]
fn trace_truncations_are_always_rejected() {
    let good = good_trace_bytes();
    let mut lens: Vec<usize> = (0..TRACE_HEADER_LEN.min(good.len())).collect();
    lens.extend((TRACE_HEADER_LEN..good.len()).step_by(13));
    lens.push(good.len() - 1);
    for len in lens {
        let r = TraceDump::from_bytes(&good[..len]);
        assert!(r.is_err(), "trace truncation to {len} bytes slipped past validation");
    }
}

#[test]
fn trace_header_field_lies_fail_cleanly_without_allocation() {
    let good = good_trace_bytes();
    // stamp every header byte past the magic with adversarial values:
    // version, length, and checksum lies must all be caught by
    // comparison, never trusted into allocations
    for i in 8..TRACE_HEADER_LEN.min(good.len()) {
        for v in [0x00u8, 0x01, 0x7F, 0xFF] {
            if good[i] == v {
                continue; // not a lie
            }
            let mut m = good.clone();
            m[i] = v;
            assert!(
                TraceDump::from_bytes(&m).is_err(),
                "header byte {i} stamped to {v:#x} slipped past trace validation"
            );
        }
    }
    // length-field lies specifically: reject by comparison, never by
    // allocating the claimed size
    for lie in [0u64, 1, u32::MAX as u64, u64::MAX] {
        let mut m = good.clone();
        m[12..20].copy_from_slice(&lie.to_le_bytes());
        assert!(TraceDump::from_bytes(&m).is_err(), "trace length lie {lie} accepted");
    }
}
