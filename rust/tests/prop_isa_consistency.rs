//! Consistency sweeps across the ISA tooling: decoder, encoder, and the
//! disassembler must agree on *which* words are instructions and on what
//! they mean. `prop_isa.rs` checks round-trips from the instruction side;
//! this file sweeps from the word side, systematically over the encoding
//! space, including the rejected-encoding agreement the static analyzer
//! relies on (a word is undecodable iff the disassembler renders it as raw
//! `.word` data iff the CPU would raise an illegal-instruction trap).
//!
//! Invariants:
//! * decode is total (never panics) over systematic and random words,
//! * decode∘encode is idempotent: decode(encode(i)) == Some(i) for every
//!   decoded i, even for non-canonical source words (fence variants),
//! * disassemble_word(w) == disassemble(decode(w)) when w decodes, and
//!   exactly `.word 0x........` when it does not,
//! * re-assembling a disassembled word yields a word with the same decode.

use femu::isa::{
    assemble_with, decode, disassemble, disassemble_word, encode, Instr,
};
use femu::util::Rng;

/// Mid-range pc anchor: pc-relative forms rendered at pc=0 can encode
/// absolute targets beyond the ±1 MiB jal range (same anchor as
/// `prop_isa.rs`).
const PC: u32 = 0x10_0000;

/// The single agreement check, applied to every word the sweeps produce.
fn check_word(word: u32, ctx: &str) {
    let rendered = disassemble_word(word, PC);
    match decode(word) {
        Some(instr) => {
            // decode∘encode idempotence: the canonical re-encoding must
            // mean the same thing (it need not be bit-identical — any
            // opcode-0b0001111 word decodes to the one Fence).
            assert_eq!(
                decode(encode(instr)),
                Some(instr),
                "{ctx}: {word:#010x} -> {instr:?} not idempotent"
            );
            assert_eq!(
                rendered,
                disassemble(instr, PC),
                "{ctx}: {word:#010x} disasm mismatch"
            );
        }
        None => {
            // Rejected-encoding agreement: the disassembler must surface
            // undecodable words as raw data, never as an instruction.
            assert_eq!(
                rendered,
                format!(".word {word:#010x}"),
                "{ctx}: rejected {word:#010x} rendered as an instruction"
            );
        }
    }
}

#[test]
fn sweep_opcode_funct_space() {
    // Systematic grid over the fields that select an encoding: every
    // opcode × funct3 × the funct7 values the ISA distinguishes (plus an
    // all-ones probe), with register/imm fields in a few fixed patterns.
    // ~45k words covering every accept/reject arm in the decoder.
    let regs: &[(u32, u32, u32)] = &[(0, 0, 0), (1, 2, 3), (31, 31, 31), (10, 0, 17)];
    for opcode in 0..128u32 {
        for funct3 in 0..8u32 {
            for &funct7 in &[0u32, 0b0000001, 0b0100000, 0b1111111] {
                for &(rd, rs1, rs2) in regs {
                    let word = (funct7 << 25)
                        | (rs2 << 20)
                        | (rs1 << 15)
                        | (funct3 << 12)
                        | (rd << 7)
                        | opcode;
                    check_word(word, "grid");
                }
            }
        }
    }
}

#[test]
fn sweep_random_words() {
    let mut rng = Rng::new(0xC0_515);
    for case in 0..100_000 {
        check_word(rng.next_u32(), &format!("random case {case}"));
    }
}

#[test]
fn system_words_exhaustive() {
    // opcode 0b1110011 with funct3=0 admits exactly four words (ecall,
    // ebreak, wfi, mret); sweep the entire 12-bit imm field and verify
    // nothing else slips through, and that nonzero rd/rs1 reject even for
    // the accepted imm values.
    let mut accepted = Vec::new();
    for imm in 0..4096u32 {
        let word = (imm << 20) | 0b1110011;
        if let Some(i) = decode(word) {
            accepted.push((word, i));
        }
        check_word(word, "system imm sweep");
    }
    assert_eq!(
        accepted,
        vec![
            (0x0000_0073, Instr::Ecall),
            (0x0010_0073, Instr::Ebreak),
            (0x1050_0073, Instr::Wfi),
            (0x3020_0073, Instr::Mret),
        ]
    );
    for (word, _) in accepted {
        for (rd, rs1) in [(1u32, 0u32), (0, 1), (31, 31)] {
            let bad = word | (rd << 7) | (rs1 << 15);
            assert_eq!(decode(bad), None, "{bad:#010x} must reject");
            check_word(bad, "system nonzero-reg");
        }
    }
}

#[test]
fn csr_space_exhaustive() {
    // Every CSR address × every Zicsr funct3 form decodes, round-trips,
    // and disassembles consistently; funct3=0b100 (the hole in the Zicsr
    // table) always rejects.
    for csr in 0..4096u32 {
        for funct3 in [1u32, 2, 3, 4, 5, 6, 7] {
            let word = (csr << 20) | (5 << 15) | (funct3 << 12) | (6 << 7) | 0b1110011;
            if funct3 == 0b100 {
                assert_eq!(decode(word), None, "{word:#010x} funct3=100 must reject");
            } else {
                let i = decode(word).unwrap_or_else(|| panic!("{word:#010x} must decode"));
                assert!(matches!(i, Instr::Csr { csr: c, .. } if c == csr as u16));
            }
            check_word(word, "csr sweep");
        }
    }
}

#[test]
fn shift_immediate_funct7_exhaustive() {
    // Shift-immediates are the one OpImm family gated on funct7: sweep all
    // 128 funct7 values for funct3 ∈ {001, 101} and verify exactly the
    // spec'd encodings decode (slli: funct7=0; srli: 0; srai: 0b0100000).
    for funct3 in [0b001u32, 0b101] {
        for funct7 in 0..128u32 {
            for shamt in [0u32, 7, 31] {
                let word =
                    (funct7 << 25) | (shamt << 20) | (9 << 15) | (funct3 << 12) | (8 << 7) | 0b0010011;
                let legal = funct7 == 0 || (funct3 == 0b101 && funct7 == 0b0100000);
                assert_eq!(
                    decode(word).is_some(),
                    legal,
                    "funct3={funct3:#05b} funct7={funct7:#09b} shamt={shamt}"
                );
                if let Some(Instr::OpImm { imm, .. }) = decode(word) {
                    assert_eq!(imm, shamt as i32, "shamt must survive decode");
                }
                check_word(word, "shift sweep");
            }
        }
    }
}

#[test]
fn noncanonical_fence_words_normalize() {
    // Any word with opcode 0b0001111 (fence, fence.i, arbitrary fm/pred/
    // succ bits) decodes to the single Fence no-op; the canonical
    // re-encoding differs bit-wise but must mean the same thing.
    let mut rng = Rng::new(0xFE_CE);
    for _ in 0..2_000 {
        let word = (rng.next_u32() & !0x7F) | 0b0001111;
        assert_eq!(decode(word), Some(Instr::Fence), "{word:#010x}");
        assert_eq!(encode(Instr::Fence), 0x0000_000F);
        check_word(word, "fence variant");
    }
}

#[test]
fn reassembled_disasm_preserves_decode() {
    // For random *words* that decode, the disassembly must re-assemble to
    // a word with the identical decode. Unlike prop_isa.rs (which starts
    // from canonical encodings) this covers non-canonical sources: the
    // reassembled word may differ from the original, but never in meaning.
    let mut rng = Rng::new(0x0D15_A52);
    let mut covered = 0;
    for case in 0..20_000 {
        let word = rng.next_u32();
        let Some(instr) = decode(word) else { continue };
        let text = disassemble_word(word, PC);
        let prog = assemble_with(
            &format!(".text\n{text}\n"),
            femu::isa::asm::Options { text_base: PC, data_base: 0x2_0000 },
        )
        .unwrap_or_else(|e| panic!("case {case}: `{text}` from {word:#010x}: {e:#}"));
        if prog.text.len() == 1 {
            assert_eq!(
                decode(prog.text[0]),
                Some(instr),
                "case {case}: `{text}` changed meaning ({word:#010x} -> {:#010x})",
                prog.text[0]
            );
            covered += 1;
        }
    }
    assert!(covered > 500, "too few decodable samples ({covered}) — generator broken?");
}
