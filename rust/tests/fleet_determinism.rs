//! Fleet contracts: (1) the fork-based sweeps (golden snapshot, restore
//! per point) are bit-identical to the serial boot-per-point reference
//! path for the §V experiment drivers — one comparison that proves both
//! worker-count invariance and snapshot-restore exactness — and (2) the
//! control server stays correct under simultaneous TCP clients.

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Fleet, Platform};
use femu::server::{Client, Server};
use femu::util::Json;

/// f64 equality as bit patterns — "identical" here means identical down
/// to the last mantissa bit, not approximately equal.
fn assert_bits_eq(a: f64, b: f64, what: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b}");
}

#[test]
fn fig4_forked_fleet_bit_identical_to_serial_reboot() {
    let cfg = PlatformConfig::default();
    // short window keeps the debug-build runtime sane; the determinism
    // contract is window-independent. The reference path boots a fresh
    // platform per point on one thread; the fork path restores a golden
    // snapshot per point across 4 workers.
    let window_s = 0.05;
    let serial = experiments::fig4_sweep_boot(&Fleet::serial(), &cfg, window_s, 0xF164).unwrap();
    let fleet = experiments::fig4_sweep(&Fleet::new(4), &cfg, window_s, 0xF164).unwrap();
    assert_eq!(serial.len(), fleet.len());
    assert_eq!(serial.len(), 2 * experiments::FIG4_FREQS_HZ.len());
    for (a, b) in serial.iter().zip(&fleet) {
        let what = format!("{} Hz / {}", a.sample_rate_hz, a.model);
        assert_eq!(a.model, b.model, "{what}");
        assert_bits_eq(a.sample_rate_hz, b.sample_rate_hz, &what);
        assert_bits_eq(a.total_s, b.total_s, &what);
        assert_bits_eq(a.active_s, b.active_s, &what);
        assert_bits_eq(a.sleep_s, b.sleep_s, &what);
        assert_bits_eq(a.active_mj, b.active_mj, &what);
        assert_bits_eq(a.sleep_mj, b.sleep_mj, &what);
        assert_bits_eq(a.total_mj, b.total_mj, &what);
    }
}

#[test]
fn fig5_forked_fleet_bit_identical_to_serial_reboot() {
    let cfg = PlatformConfig::default();
    let serial = experiments::fig5_all_boot(&Fleet::serial(), &cfg, 0xF15).unwrap();
    let fleet = experiments::fig5_all(&Fleet::new(4), &cfg, 0xF15).unwrap();
    assert_eq!(serial.len(), fleet.len());
    assert_eq!(serial.len(), 12); // 3 kernels x 2 impls x 2 models
    for (a, b) in serial.iter().zip(&fleet) {
        let what = format!("{}/{}/{}", a.kernel, a.implementation, a.model);
        assert_eq!(a.kernel, b.kernel, "{what}");
        assert_eq!(a.implementation, b.implementation, "{what}");
        assert_eq!(a.model, b.model, "{what}");
        assert_eq!(a.cycles, b.cycles, "{what}");
        assert_bits_eq(a.time_s, b.time_s, &what);
        assert_bits_eq(a.energy_mj, b.energy_mj, &what);
        assert_eq!(a.validated, b.validated, "{what}");
        assert!(a.validated, "{what}: outputs must stay bit-exact vs the oracle");
    }
}

#[test]
fn case_c_forked_fleet_bit_identical_to_serial_reboot() {
    let cfg = PlatformConfig::default();
    let serial = experiments::case_c_boot(&Fleet::serial(), &cfg, 40).unwrap();
    let fleet = experiments::case_c(&Fleet::new(2), &cfg, 40).unwrap();
    assert_eq!(serial.windows, fleet.windows);
    assert_eq!(serial.samples_per_window, fleet.samples_per_window);
    assert_bits_eq(serial.virt_total_s, fleet.virt_total_s, "virt_total_s");
    assert_bits_eq(serial.phys_total_s, fleet.phys_total_s, "phys_total_s");
    assert_bits_eq(serial.speedup, fleet.speedup, "speedup");
}

#[test]
fn forked_sweep_worker_count_invariance() {
    // restore-per-point with 1 worker == restore-per-point with 4
    let cfg = PlatformConfig::default();
    let one = experiments::fig4_sweep(&Fleet::serial(), &cfg, 0.02, 7).unwrap();
    let four = experiments::fig4_sweep(&Fleet::new(4), &cfg, 0.02, 7).unwrap();
    assert_eq!(one.len(), four.len());
    for (a, b) in one.iter().zip(&four) {
        assert_bits_eq(a.total_mj, b.total_mj, "total_mj");
        assert_bits_eq(a.active_s, b.active_s, "active_s");
    }
}

#[test]
fn server_survives_four_simultaneous_clients() {
    let server = Server::spawn(Platform::new(PlatformConfig::default()), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut handles = Vec::new();
    // client 0 owns the load/run/read flow; the guest result must be
    // unaffected by the three interrogating clients hammering away
    handles.push(std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        let src = r#"
            _start:
                la t0, out
                li t1, 4242
                sw t1, 0(t0)
                ebreak
            .data
            out: .word 0
        "#;
        let loaded = c
            .call(Json::obj(vec![("cmd", Json::from("load_asm")), ("source", Json::from(src))]))
            .unwrap();
        let out_addr = loaded.get("symbols").unwrap().get("out").unwrap().as_i64().unwrap();
        let run = c.call(Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert_eq!(run.str_field("exit").unwrap(), "halted");
        let mem = c
            .call(Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(out_addr)),
                ("n", Json::from(1i64)),
            ]))
            .unwrap();
        assert_eq!(mem.as_arr().unwrap()[0].as_i64().unwrap(), 4242);
    }));
    // clients 1..3: concurrent read-only traffic on the same platform
    for _ in 1..4 {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            for _ in 0..25 {
                let pong = c.call(Json::obj(vec![("cmd", Json::from("ping"))])).unwrap();
                assert_eq!(pong.as_str().unwrap(), "pong");
                let regs = c.call(Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
                assert_eq!(regs.as_arr().unwrap().len(), 32);
                let perf = c.call(Json::obj(vec![("cmd", Json::from("perf"))])).unwrap();
                assert!(perf.get("cycles").unwrap().as_i64().unwrap() >= 0);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}
