//! Property tests over the platform/coordinator layer.
//!
//! Invariants:
//! * perf-counter conservation: every domain's four state counts sum to
//!   the global cycle counter, on arbitrary workloads;
//! * determinism: identical (program, dataset, seed) produce identical
//!   cycle counts and energy;
//! * energy monotonicity: more cycles never decrease energy; active time
//!   is never cheaper than the same time asleep;
//! * failure injection: underrun detection when the CS starves the ADC
//!   FIFO; poison visibility after power-gating.

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::energy::EnergyModel;
use femu::perfmon::PowerState;
use femu::soc::{RunExit, Soc, SocConfig};
use femu::util::Rng;
use femu::workloads::programs;

/// Generate a random but halting guest program.
fn random_program(rng: &mut Rng) -> String {
    let mut body = String::from("_start:\n");
    let n = rng.range_usize(4, 40);
    for _ in 0..n {
        match rng.below(6) {
            0 => body.push_str(&format!(
                "    li t{}, {}\n",
                rng.range_i32(0, 7),
                rng.range_i32(-10_000, 10_000)
            )),
            1 => body.push_str(&format!(
                "    add t{}, t{}, t{}\n",
                rng.range_i32(0, 7),
                rng.range_i32(0, 7),
                rng.range_i32(0, 7)
            )),
            2 => body.push_str(&format!(
                "    mul t{}, t{}, t{}\n",
                rng.range_i32(0, 7),
                rng.range_i32(0, 7),
                rng.range_i32(0, 7)
            )),
            3 => body.push_str(&format!(
                "    sw t{}, {}(sp)\n",
                rng.range_i32(0, 7),
                rng.range_i32(0, 64) * 4
            )),
            4 => body.push_str(&format!(
                "    lw t{}, {}(sp)\n",
                rng.range_i32(0, 7),
                rng.range_i32(0, 64) * 4
            )),
            _ => body.push_str(&format!(
                "    srai t{}, t{}, {}\n",
                rng.range_i32(0, 7),
                rng.range_i32(0, 7),
                rng.range_i32(0, 31)
            )),
        }
    }
    body.push_str("    ebreak\n");
    // sp points into bank 1 (data area)
    format!("_pre:\n    li sp, 0x20400\n    j _body\n_body:\n{}", &body["_start:\n".len()..])
}

#[test]
fn prop_perf_counter_conservation() {
    let mut rng = Rng::new(0x00C5);
    for case in 0..40 {
        let src = random_program(&mut rng);
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg.load_source(&src).unwrap_or_else(|e| panic!("case {case}: {e:#}\n{src}"));
        p.run_app(1_000_000).unwrap();
        let snap = p.perf_snapshot();
        for (d, counts) in snap.domains() {
            assert_eq!(
                counts.total(),
                snap.cycles,
                "case {case}: domain {d} counts {counts:?} vs cycles {}",
                snap.cycles
            );
        }
    }
}

#[test]
fn prop_determinism() {
    for seed in [1u64, 9, 77] {
        let run = |seed: u64| {
            let mut p = Platform::new(PlatformConfig::default());
            p.dbg.load_source(&programs::acquisition(200, 2)).unwrap();
            let data = Rng::new(seed).vec_i32(200, -30_000, 30_000);
            p.start_adc(data, 5_000.0);
            p.run_app(1 << 32).unwrap();
            let snap = p.perf_snapshot();
            let e = EnergyModel::femu().estimate(&snap);
            (snap.cycles, p.dbg.soc.stats.instructions, format!("{:.9}", e.total_mj))
        };
        assert_eq!(run(seed), run(seed), "seed {seed}");
    }
}

#[test]
fn prop_energy_monotone_in_time() {
    let model = EnergyModel::heepocrates();
    let mut pm = femu::perfmon::PerfMonitor::new(2);
    let mut last = 0.0;
    for t in [10u64, 100, 1_000, 50_000] {
        let e = model.estimate(&pm.snapshot(t)).total_mj;
        assert!(e > last, "t={t}: {e} <= {last}");
        last = e;
    }
    // active is never cheaper than clock-gated for the same duration
    pm.set_state(femu::perfmon::Domain::Cpu, PowerState::ClockGated, 0);
    let gated = model.estimate(&pm.snapshot(1_000)).total_mj;
    let mut pm2 = femu::perfmon::PerfMonitor::new(2);
    pm2.set_state(femu::perfmon::Domain::Cpu, PowerState::Active, 0);
    let active = model.estimate(&pm2.snapshot(1_000)).total_mj;
    assert!(active > gated);
}

#[test]
fn failure_injection_adc_starvation() {
    // CS never refills: the schedule says samples are due, the FIFO is
    // empty after the prefill -> underrun latches.
    let mut soc = Soc::new(SocConfig::default());
    let prog = femu::isa::assemble(&programs::acquisition(600, 0)).unwrap();
    soc.load(&prog).unwrap();
    // configure the stream but refuse to feed more than the prefill
    soc.bus.spi_adc.configure_stream(600, 100, 0);
    let first: Vec<i32> = (0..256).collect();
    soc.bus.spi_adc.refill(&first);
    soc.bus.spi_adc.write(femu::periph::spi_adc::regs::CTRL, 0b11);
    loop {
        match soc.run(1 << 30) {
            RunExit::AdcRefill => { /* starve on purpose */ }
            RunExit::Halted(_) | RunExit::DeadSleep => break,
            RunExit::CycleBudget => break,
            other => panic!("{other:?}"),
        }
        if soc.bus.spi_adc.underrun() {
            break;
        }
    }
    assert!(soc.bus.spi_adc.underrun(), "starved FIFO must latch underrun");
}

#[test]
fn failure_injection_power_gated_poison() {
    // guest gates bank 1, wakes it, and reads poison — emulating the
    // data-loss bug class the power model is meant to surface
    let mut soc = Soc::new(SocConfig::default());
    let prog = femu::isa::assemble(
        r#"
        .equ POWER, 0x20000600
        _start:
            la  t0, marker
            lw  a0, 0(t0)        # a0 = 1234 (before)
            li  t1, POWER
            li  t2, 2            # power-gate bank 1
            sw  t2, 0x44(t1)
            li  t2, 0            # back on
            sw  t2, 0x44(t1)
            lw  a1, 0(t0)        # a1 = poison
            ebreak
        .data
        marker: .word 1234
        "#,
    )
    .unwrap();
    soc.load(&prog).unwrap();
    soc.run_to_halt(100_000);
    assert_eq!(soc.cpu.regs[10], 1234);
    assert_eq!(soc.cpu.regs[11], femu::mem::POISON);
}

#[test]
fn prop_manual_window_subset_of_total() {
    // the manual perf window can never exceed the automatic window
    let mut rng = Rng::new(0x77);
    for _ in 0..10 {
        let pause = rng.range_i32(5, 60);
        let src = format!(
            r#"
            .equ GPIO, 0x20000100
            _start:
                li t0, GPIO
                li t1, {pause}
            warmup:
                addi t1, t1, -1
                bnez t1, warmup
                li t2, 0x10000
                sw t2, 0(t0)
                li t1, {pause}
            region:
                addi t1, t1, -1
                bnez t1, region
                sw zero, 0(t0)
                ebreak
            "#
        );
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg.load_source(&src).unwrap();
        p.run_app(1_000_000).unwrap();
        let total = p.perf_snapshot();
        let window = p.dbg.soc.perf.window_snapshot().unwrap();
        assert!(window.cycles < total.cycles);
        assert!(window.cpu.get(PowerState::Active) <= total.cpu.get(PowerState::Active));
    }
}

#[test]
fn config_variants_still_run() {
    // sweep bank counts / sizes / timing via the config layer
    for (banks, size, div) in [(1usize, 0x40000u32, 10u64), (4, 0x10000, 34), (3, 0x8000, 50)] {
        let cfg = PlatformConfig::parse(&format!(
            "[mem]\nnum_banks = {banks}\nbank_size = {size:#x}\n[timing]\ndiv = {div}"
        ))
        .unwrap();
        let mut p = Platform::new(cfg);
        p.dbg.load_source("_start:\nli a0, 9\nli a1, 3\ndiv a2, a0, a1\nebreak").unwrap();
        p.run_app(10_000).unwrap();
        assert_eq!(p.dbg.reg(12), 3);
        let snap = p.perf_snapshot();
        assert_eq!(snap.banks.len(), banks);
    }
}
