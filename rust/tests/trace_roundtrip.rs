//! Trace subsystem integration tests (DESIGN.md §13): the on-disk
//! `FEMUTRAC` round trip, corruption rejection, ring wraparound
//! semantics, derived-state behavior across snapshot restore, and the
//! cross-backend bit-identity of captures.

use femu::config::PlatformConfig;
use femu::coordinator::{AppExit, Platform};
use femu::exec::BackendKind;
use femu::trace::{category, format::TraceDump, kind, TraceConfig};

/// Run `src` with every category armed on `backend`; returns the halted
/// platform and its capture.
fn run_traced(backend: BackendKind, src: &str, depth: usize) -> (Platform, TraceDump) {
    let mut cfg = PlatformConfig::default();
    cfg.soc.backend = backend;
    cfg.soc.trace = TraceConfig { mask: category::ALL, depth };
    let mut p = Platform::new(cfg);
    p.dbg.load_source(src).unwrap();
    let exit = p.run_app(1 << 30).unwrap();
    assert!(matches!(exit, AppExit::Halted(_)), "guest did not halt: {exit:?}");
    let dump = {
        let soc = &p.dbg.soc;
        TraceDump::from_ring(soc.trace_ring().unwrap(), soc.freq_hz, soc.bus.banks.len() as u32)
    };
    (p, dump)
}

#[test]
fn capture_roundtrips_through_the_file_format() {
    let (p, dump) = run_traced(
        BackendKind::Interp,
        "_start: li t0, 40\nloop: addi t0, t0, -1\nbnez t0, loop\nebreak",
        1 << 12,
    );
    assert!(dump.total > 0);
    // encode/decode identity in memory...
    let back = TraceDump::from_bytes(&dump.to_bytes()).unwrap();
    assert_eq!(back, dump);
    // ...and through a real file
    let path = std::env::temp_dir().join(format!("femu_trace_rt_{}.trace", std::process::id()));
    dump.save(&path).unwrap();
    let loaded = TraceDump::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, dump);
    // the ring's retire accounting matches the architectural counters
    let soc = &p.dbg.soc;
    assert_eq!(dump.counts[0], soc.stats.instructions);
    assert_eq!(soc.trace_ring().unwrap().retires(), soc.cpu.instret);
}

#[test]
fn truncated_and_corrupt_captures_are_rejected() {
    let (_p, dump) =
        run_traced(BackendKind::Interp, "_start: li a0, 1\nli a1, 2\nebreak", 1 << 8);
    let good = dump.to_bytes();
    assert!(TraceDump::from_bytes(&good).is_ok());

    // flipped payload byte: checksum failure
    let mut bad = good.clone();
    *bad.last_mut().unwrap() ^= 0xFF;
    let err = TraceDump::from_bytes(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");

    // truncation anywhere: header-only, mid-header, mid-payload
    for cut in [0, 7, 27, good.len() - 1] {
        assert!(TraceDump::from_bytes(&good[..cut]).is_err(), "cut at {cut} accepted");
    }

    // bad magic and unsupported version
    let mut magic = good.clone();
    magic[0] = b'Z';
    assert!(TraceDump::from_bytes(&magic).is_err());
    let mut vers = good;
    vers[8] = 0x7F;
    let err = TraceDump::from_bytes(&vers).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");
}

#[test]
fn ring_wraparound_keeps_the_newest_events() {
    // a 32-slot ring against hundreds of retires: the capture must hold
    // exactly the newest window and account for the rest as dropped
    let (p, dump) = run_traced(
        BackendKind::Interp,
        "_start: li t0, 300\nloop: addi t0, t0, -1\nbnez t0, loop\nebreak",
        32,
    );
    assert!(dump.total > 32, "guest too short to wrap: {} events", dump.total);
    assert_eq!(dump.events.len(), 32);
    assert_eq!(dump.dropped(), dump.total - 32);
    // newest-wins: the final event is the halting ebreak's retire, at
    // the platform's final clock
    let last = dump.events.last().unwrap();
    assert_eq!(last.kind, kind::RETIRE);
    assert_eq!(last.cycle, p.dbg.soc.now);
    // a wrapped capture still frames and validates cleanly
    assert_eq!(TraceDump::from_bytes(&dump.to_bytes()).unwrap(), dump);
}

#[test]
fn restore_resets_the_ring_without_phantom_edges() {
    // arm the machine timer to fire at cycle 2000, snapshot mid-spin
    // before the interrupt, restore into a second traced platform, and
    // resume: the ring is derived state, so it must come back empty,
    // and the IRQ baseline must be resynced so the timer line's rise is
    // recorded as exactly one real edge — never a phantom drop first
    const SRC: &str = r#"
        .equ TIMER, 0x20000200
        _start:
            la t0, handler
            csrw mtvec, t0
            li t0, TIMER
            li t1, 2000
            sw t1, 8(t0)
            sw zero, 12(t0)
            li t1, 1
            sw t1, 16(t0)
            li t1, 0x80
            csrw mie, t1
            csrsi mstatus, 8
        wait:
            j wait
        handler:
            ebreak
    "#;
    let mut cfg = PlatformConfig::default();
    cfg.soc.trace = TraceConfig { mask: category::ALL, depth: 1 << 12 };
    let mut p = Platform::new(cfg.clone());
    p.dbg.load_source(SRC).unwrap();
    let exit = p.run_app(1000).unwrap();
    assert!(matches!(exit, AppExit::Budget), "{exit:?}");
    assert!(p.dbg.soc.trace_ring().unwrap().total() > 0, "no events before snapshot");
    let snap = p.snapshot();

    let mut q = Platform::new(cfg);
    q.restore(&snap).unwrap();
    let ring = q.dbg.soc.trace_ring().expect("tracing stays armed across restore");
    assert_eq!(ring.total(), 0, "restored ring must start empty (derived state)");

    let exit = q.run_app(1 << 24).unwrap();
    assert!(matches!(exit, AppExit::Halted(_)), "{exit:?}");
    let dump = {
        let soc = &q.dbg.soc;
        TraceDump::from_ring(soc.trace_ring().unwrap(), soc.freq_hz, soc.bus.banks.len() as u32)
    };
    let irqs: Vec<_> = dump
        .events
        .iter()
        .filter(|e| e.kind == kind::IRQ_RAISE || e.kind == kind::IRQ_DROP)
        .collect();
    assert!(!irqs.is_empty(), "timer interrupt left no IRQ events");
    assert_eq!(
        irqs[0].kind,
        kind::IRQ_RAISE,
        "first IRQ event after restore must be a real raise, not a phantom drop"
    );
    assert_eq!(q.dbg.soc.cpu.irqs_taken, 1, "the guest takes exactly one interrupt");
}

#[test]
fn interp_and_blocks_captures_are_bit_identical() {
    // the backend bit-identity contract (DESIGN.md §11) extended to the
    // event stream: same guest, same categories, byte-identical capture
    let src = femu::workloads::builtin("mm_cpu").unwrap();
    let (_pa, da) = run_traced(BackendKind::Interp, &src, 1 << 16);
    let (_pb, db) = run_traced(BackendKind::Blocks, &src, 1 << 16);
    assert_eq!(da.to_bytes(), db.to_bytes(), "backends produced different captures");
    // and a repeat run is bit-identical too (determinism)
    let (_pc, dc) = run_traced(BackendKind::Interp, &src, 1 << 16);
    assert_eq!(da.to_bytes(), dc.to_bytes(), "repeat run produced a different capture");
}
