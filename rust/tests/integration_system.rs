//! System-level integration tests: whole-platform flows across modules
//! (SoC + virtualization + coordinator + server + config).

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, AppExit, Fleet, Platform};
use femu::cpu::Halt;
use femu::energy::{relative_deviation, EnergyModel};
use femu::server::{Client, Server};
use femu::util::Json;
use femu::virt::FlashService;
use femu::workloads::{programs, signals};

#[test]
fn fig4_shape_sleep_to_active_transition() {
    // the Fig 4 qualitative claim across the sweep: the active share of
    // time rises monotonically with the sampling frequency
    let cfg = PlatformConfig::default();
    let mut last_frac = -1.0;
    for f in [100.0, 1_000.0, 10_000.0, 100_000.0] {
        let pts = experiments::fig4_point(&cfg, f, 0.1, 3).unwrap();
        let p = &pts[0];
        let frac = p.active_s / p.total_s;
        assert!(frac > last_frac, "active fraction not rising at {f} Hz");
        last_frac = frac;
    }
    assert!(last_frac > 0.7, "100 kHz should be active-dominated, got {last_frac}");
}

#[test]
fn fig5_full_grid_shape() {
    // who wins and by what factor: CGRA wins everywhere; CONV gains the
    // most; FEMU-vs-chip deviations stay inside the paper's bands
    let cfg = PlatformConfig::default();
    let all = experiments::fig5_all(&Fleet::auto(), &cfg, 42).unwrap();
    assert_eq!(all.len(), 12); // 3 kernels x 2 impls x 2 models
    assert!(all.iter().all(|p| p.validated), "all outputs bit-exact");

    let speedup = |k: &str| {
        let cpu = all.iter().find(|p| p.kernel == k && p.implementation == "CPU" && p.model == "femu").unwrap();
        let cgra = all.iter().find(|p| p.kernel == k && p.implementation == "CGRA" && p.model == "femu").unwrap();
        cpu.cycles as f64 / cgra.cycles as f64
    };
    let (mm, conv, fft) = (speedup("MM"), speedup("CONV"), speedup("FFT"));
    // paper: substantial reductions (up to ~9x), CONV largest
    assert!(conv > mm && conv > fft, "CONV must gain most: mm={mm:.1} conv={conv:.1} fft={fft:.1}");
    for (name, s) in [("MM", mm), ("CONV", conv), ("FFT", fft)] {
        assert!(s > 2.0 && s < 25.0, "{name} speedup {s:.1} out of plausible band");
    }

    // energy: CGRA reduces energy for every kernel under both models
    for k in ["MM", "CONV", "FFT"] {
        for m in ["femu", "heepocrates"] {
            let cpu = all.iter().find(|p| p.kernel == k && p.implementation == "CPU" && p.model == m).unwrap();
            let cgra = all.iter().find(|p| p.kernel == k && p.implementation == "CGRA" && p.model == m).unwrap();
            assert!(cgra.energy_mj < cpu.energy_mj, "{k}/{m}");
        }
    }

    // FEMU-vs-chip deviation bands: CPU-only small (~5%), CGRA larger
    // (post-PnR calibration), as §V-B reports
    for k in ["MM", "CONV", "FFT"] {
        let dev = |imp: &str| {
            let fe = all.iter().find(|p| p.kernel == k && p.implementation == imp && p.model == "femu").unwrap();
            let ch = all
                .iter()
                .find(|p| p.kernel == k && p.implementation == imp && p.model == "heepocrates")
                .unwrap();
            relative_deviation(fe.energy_mj, ch.energy_mj)
        };
        let cpu_dev = dev("CPU");
        let cgra_dev = dev("CGRA");
        assert!(cpu_dev < 0.10, "{k} CPU deviation {cpu_dev}");
        assert!(cgra_dev > cpu_dev, "{k}: CGRA deviation should exceed CPU");
        assert!(cgra_dev < 0.25, "{k} CGRA deviation {cgra_dev}");
    }
}

#[test]
fn case_c_flash_speedup_band() {
    let cfg = PlatformConfig::default();
    let r = experiments::case_c(&Fleet::auto(), &cfg, 24).unwrap(); // 10 windows, quick
    assert!(r.speedup > 180.0 && r.speedup < 320.0, "speedup {}", r.speedup);
    // absolute per-window times scale to the paper's 10 ms / 2.5 s
    let scale_up = 35_000.0 / r.samples_per_window as f64;
    let full_virt = r.virt_window_s * scale_up;
    let full_phys = r.phys_window_s * scale_up;
    assert!((full_virt - 0.010).abs() < 0.005, "virt {full_virt}");
    assert!((full_phys - 2.5).abs() < 0.5, "phys {full_phys}");
}

#[test]
fn flash_write_path_roundtrip() {
    // §III-A: virtualized flash supports writes — guest logs results,
    // CS reads them back
    let mut p = Platform::new(PlatformConfig::default());
    p.dbg
        .load_source(
            r#"
            .equ FLASH, 0x20000400
            _start:
                li t0, FLASH
                li t1, 0x1000
                sw t1, 8(t0)
                li t2, 5
                li t3, 100
            log:
                sw t3, 12(t0)
                addi t3, t3, 1
                addi t2, t2, -1
                bnez t2, log
                ebreak
            "#,
        )
        .unwrap();
    p.run_app(1_000_000).unwrap();
    assert_eq!(
        FlashService::read_samples(&p.dbg.soc, 0x1000, 5),
        vec![100, 101, 102, 103, 104]
    );
}

#[test]
fn server_full_session_over_tcp() {
    let platform = Platform::new(PlatformConfig::default());
    let server = Server::spawn(platform, "127.0.0.1:0").unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    // load, inject, run, inspect — the remote batch-test flow
    let loaded = c
        .call(Json::obj(vec![
            ("cmd", Json::from("load_asm")),
            (
                "source",
                Json::from(
                    "_start:\nla t0, v\nlw a0, 0(t0)\nslli a0, a0, 1\nebreak\n.data\nv: .word 0",
                ),
            ),
        ]))
        .unwrap();
    let v_addr = loaded.get("symbols").unwrap().get("v").unwrap().as_i64().unwrap();
    c.call(Json::obj(vec![
        ("cmd", Json::from("write_mem")),
        ("addr", Json::from(v_addr)),
        ("values", Json::arr_i32(&[21])),
    ]))
    .unwrap();
    c.call(Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
    let regs = c.call(Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
    assert_eq!(regs.as_arr().unwrap()[10].as_i64().unwrap(), 42);
    // two clients can talk to the same platform sequentially
    let mut c2 = Client::connect(server.addr()).unwrap();
    assert!(c2.call(Json::obj(vec![("cmd", Json::from("ping"))])).is_ok());
    server.shutdown();
}

#[test]
fn acquisition_with_dma_drain() {
    // alternative acquisition strategy: DMA copies the guest buffer to
    // the bridge window after capture (exercises DMA + bridge together)
    let mut p = Platform::new(PlatformConfig::default());
    let n = 64;
    let src = format!(
        r#"{prelude}
        _start:
            li  s0, SPI_ADC
            li  s1, {n}
            la  s2, buf
            li  t0, 3
            sw  t0, 0(s0)
            li  t0, MIE_ADC
            csrw mie, t0
        loop:
            lw  t1, 4(s0)
            andi t2, t1, 1
            bnez t2, take
            wfi
            j   loop
        take:
            lw  t3, 8(s0)
            sw  t3, 0(s2)
            addi s2, s2, 4
            addi s1, s1, -1
            bnez s1, loop
            # DMA buf -> bridge window
            li  t0, DMA
            la  t1, buf
            sw  t1, 0(t0)
            li  t1, BRIDGE
            sw  t1, 4(t0)
            li  t1, {bytes}
            sw  t1, 8(t0)
            li  t1, 1
            sw  t1, 12(t0)
        wait:
            lw  t2, 16(t0)
            andi t2, t2, 1
            beqz t2, wait
            ebreak
        .data
        buf: .space {bytes}
        "#,
        prelude = programs::PRELUDE,
        bytes = n * 4,
    );
    p.dbg.load_source(&src).unwrap();
    let data: Vec<i32> = (0..n as i32).map(|i| i * 3 - 50).collect();
    p.start_adc(data.clone(), 50_000.0);
    assert_eq!(p.run_app(1 << 32).unwrap(), AppExit::Halted(Halt::Ebreak));
    let got = p.dbg.soc.bus.cs_dram.read_i32_slice(0, n).unwrap();
    assert_eq!(got, data);
}

#[test]
fn chip_config_loads_and_runs() {
    let cfg = PlatformConfig::load(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/heepocrates-chip.toml"),
    )
    .unwrap();
    assert_eq!(cfg.energy.name, "heepocrates");
    assert_eq!(cfg.soc.flash_timing, femu::periph::FlashTiming::physical());
    let mut p = Platform::new(cfg);
    p.dbg.load_source("_start: li a0, 1\nebreak").unwrap();
    p.run_app(10_000).unwrap();
}

#[test]
fn energy_report_consistency_across_models() {
    // same counters, two calibrations: deviation within the documented
    // bands for an all-CPU workload
    let mut p = Platform::new(PlatformConfig::default());
    p.dbg.load_source(&programs::mm_cpu(32, 8, 4)).unwrap();
    let mut rng = femu::util::Rng::new(1);
    let prog = femu::isa::assemble(&programs::mm_cpu(32, 8, 4)).unwrap();
    p.dbg.write_i32_slice(prog.symbol("a_buf").unwrap(), &rng.vec_i32(32 * 8, -99, 99)).unwrap();
    p.dbg.write_i32_slice(prog.symbol("b_buf").unwrap(), &rng.vec_i32(8 * 4, -99, 99)).unwrap();
    p.run_app(1 << 30).unwrap();
    let snap = p.perf_snapshot();
    let femu_e = EnergyModel::femu().estimate(&snap);
    let chip_e = EnergyModel::heepocrates().estimate(&snap);
    let dev = relative_deviation(femu_e.total_mj, chip_e.total_mj);
    assert!(dev > 0.0 && dev < 0.10, "deviation {dev}");
}

#[test]
fn ultrasound_windows_through_flash_study() {
    // end-to-end §V-C data path: stage windows, guest streams one, CS
    // confirms the stream content arrived in guest memory
    let mut p = Platform::new(PlatformConfig::default());
    let windows = signals::ultrasound_windows(3, 2, 128);
    FlashService::stage_windows(&mut p.dbg.soc, 0, &windows);
    let src = format!(
        r#"{prelude}
        _start:
            li  s0, SPI_FLASH
            sw  zero, 8(s0)
            la  s2, buf
            li  s3, 128
        rd: lw  t0, 12(s0)
            sw  t0, 0(s2)
            addi s2, s2, 4
            addi s3, s3, -1
            bnez s3, rd
            ebreak
        .data
        buf: .space 512
        "#,
        prelude = programs::PRELUDE
    );
    let prog = p.dbg.load_source(&src).unwrap();
    p.run_app(1 << 30).unwrap();
    let got = p.dbg.read_i32_slice(prog.symbol("buf").unwrap(), 128).unwrap();
    assert_eq!(got, windows[0]);
}
