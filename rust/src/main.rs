//! `femu` — the X-HEEP-FEMU launcher.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! ```text
//! femu run [prog.s | --builtin NAME] [--config <platform.toml>]
//!          [--max-cycles N] [--from-snapshot FILE] [--profile]
//!          [--trace CATS] [--trace-out FILE] [--trace-depth N]
//! femu profile [prog.s | --builtin NAME] [--config ..] [--model ..]
//!              [--json | --folded [FILE]] [--annotate] [--vcd out.vcd]
//! femu profile --validate [--builtin NAME|all] [--folded FILE]
//! femu snapshot save <prog.s> --out FILE [--cycles N] [--config ..]
//! femu snapshot info <FILE>
//! femu sweep-acquisition [--window-s S] [--from-snapshot FILE]   (Fig 4)
//! femu kernels [--validate] [--from-snapshot FILE]               (Fig 5)
//! femu flash-study [--scale N] [--from-snapshot FILE]            (Case C)
//! femu diff [prog.s] [--backends A,B] [--experiments] [--trace CATS]
//!           [--checkpoint-cycles N] [--window-s S] [--scale N]
//! femu trace dump <FILE> [--vcd OUT] [--jsonl OUT]
//! femu trace info <FILE>
//! femu trace validate [--builtin NAME|all]
//! femu table1                                                    (Table I)
//! femu faults run [--builtin NAME | --campaign FILE] [--points N]
//!            [--seed S] [--targets LIST] [--models LIST] [--window LO:HI]
//!            [--watchdog-factor N] [--check] [--json | --out FILE]
//! femu faults report <FILE> [--json]
//! femu serve [--addr HOST:PORT] [--artifacts DIR] [--config ..]
//!            [--max-sessions N] [--workers N] [--idle-timeout SECS]
//!            [--configs DIR] [--metrics-interval SECS]
//! femu metrics [--addr HOST:PORT] [--prometheus]
//! ```
//!
//! Experiment subcommands shard their sweep across an experiment fleet
//! (one worker per core by default); `--workers N` sizes the pool and
//! `--serial` forces the single-threaded reference path. Results are
//! bit-identical either way.
//!
//! Every subcommand that builds a platform accepts `--backend
//! interp|blocks` to pick the execution engine (config file key:
//! `backend`); `femu diff` runs workloads on two backends in lockstep
//! and proves them bit-identical (DESIGN.md §11).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, table1, AppExit, Fleet, Platform};
use femu::energy::EnergyModel;
use femu::exec::{diff, BackendKind};
use femu::snapshot::PlatformSnapshot;
use femu::util::eng;

fn main() {
    if let Err(e) = run() {
        eprintln!("femu: error: {e:#}");
        // snapshot-load failures carry a typed kind; turn it into an
        // actionable hint (corrupt file vs stale build vs wrong config)
        if let Some(se) = e.downcast_ref::<femu::snapshot::SnapError>() {
            eprintln!("femu: {}: {}", se.kind.name(), se.kind.hint());
        }
        std::process::exit(1);
    }
}

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut switches = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                switches.push(name.to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags, switches }
}

fn load_config(args: &Args) -> Result<PlatformConfig> {
    let mut cfg = match args.flags.get("config") {
        Some(path) => PlatformConfig::load(path)?,
        None => PlatformConfig::default(),
    };
    // --backend overrides the config file's execution engine
    if let Some(b) = args.flags.get("backend") {
        cfg.soc.backend = BackendKind::parse(b)?;
    }
    Ok(cfg)
}

/// Experiment fleet sizing: `--serial` wins, then `--workers N`, then one
/// worker per available core.
fn fleet_from_args(args: &Args) -> Result<Fleet> {
    if args.switches.iter().any(|s| s == "serial") {
        Ok(Fleet::serial())
    } else if let Some(w) = args.flags.get("workers") {
        let n: usize = w.parse().with_context(|| format!("--workers `{w}`"))?;
        Ok(Fleet::new(n))
    } else {
        Ok(Fleet::auto())
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print_usage();
        return Ok(());
    };
    let args = parse_args(&argv[1..]);
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "profile" => cmd_profile(&args),
        "snapshot" => cmd_snapshot(&args),
        "sweep-acquisition" => cmd_sweep_acquisition(&args),
        "kernels" => cmd_kernels(&args),
        "flash-study" => cmd_flash_study(&args),
        "diff" => cmd_diff(&args),
        "trace" => cmd_trace(&args),
        "analyze" => cmd_analyze(&args),
        "table1" => cmd_table1(),
        "disasm" => cmd_disasm(&args),
        "faults" => cmd_faults(&args),
        "serve" => cmd_serve(&args),
        "metrics" => cmd_metrics(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `femu help`)"),
    }
}

fn print_usage() {
    println!(
        "femu — FPGA EMUlation framework for TinyAI heterogeneous systems \
         (software reproduction)\n\n\
         USAGE:\n  \
         femu run [prog.s | --builtin NAME] [--config <platform.toml>]\n  \
         \x20        [--max-cycles N] [--from-snapshot FILE] [--profile]\n  \
         \x20        [--trace CATS] [--trace-out FILE] [--trace-depth N]\n  \
         femu profile [prog.s | --builtin NAME] [--config ..] [--model ..]\n  \
         \x20          [--json | --folded [FILE]] [--annotate] [--vcd out.vcd]\n  \
         femu profile --validate [--builtin NAME|all] [--folded FILE]\n  \
         femu snapshot save <prog.s> --out FILE [--cycles N] [--config ..]\n  \
         femu snapshot info <FILE>                    inspect a snapshot\n  \
         femu disasm <prog.s>                         assemble + list\n  \
         femu sweep-acquisition [--window-s S]        reproduce Fig 4\n  \
         femu kernels [--validate]                    reproduce Fig 5\n  \
         femu flash-study [--scale N]                 reproduce Case C (\u{a7}V-C)\n  \
         femu diff [prog.s] [--backends A,B] [--experiments] [--window-s S]\n  \
         \x20         [--scale N] [--checkpoint-cycles N] [--precompile]\n  \
         \x20         [--trace CATS]               lockstep backend diff\n  \
         femu trace dump <FILE> [--vcd OUT] [--jsonl OUT]   export a capture\n  \
         femu trace info <FILE>                       inspect a capture\n  \
         femu trace validate [--builtin NAME|all]     stream self-check\n  \
         femu analyze [prog.s] [--builtin NAME|all] [--from-snapshot FILE]\n  \
         \x20          [--config <platform.toml>] [--json]  static analysis\n  \
         femu table1                                  reproduce Table I\n  \
         femu faults run [--builtin NAME | --campaign FILE] [--points N]\n  \
         \x20          [--seed S] [--targets LIST] [--models LIST]\n  \
         \x20          [--window LO:HI] [--watchdog-factor N] [--check]\n  \
         \x20          [--json | --out FILE]          fault-injection campaign\n  \
         femu faults report <FILE> [--json]           re-render a campaign\n  \
         femu serve [--addr HOST:PORT] [--artifacts DIR] [--max-sessions N]\n  \
         \x20          [--workers N] [--idle-timeout SECS] [--configs DIR]\n  \
         \x20          [--metrics-interval SECS]\n  \
         femu metrics [--addr HOST:PORT] [--prometheus]   server counters\n\n\
         Experiment subcommands accept --workers N (fleet size; default: \
         one per core),\n  \
         --serial (single-threaded reference path), and --from-snapshot FILE \
         (use a saved\n  \
         snapshot as the golden image the sweep forks from).\n  \
         Platform-building subcommands accept --backend interp|blocks \
         (execution engine).\n  \
         --trace CATS arms the event ring: a comma list of \
         retire,bus,irq,power, or all."
    );
}

fn load_guest(args: &Args) -> Result<(Platform, femu::isa::Program)> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("expected a guest assembly file"))?;
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut platform = Platform::new(load_config(args)?);
    if let Some(dir) = args.flags.get("artifacts") {
        platform.attach_artifacts(dir)?;
    } else if std::path::Path::new("artifacts/manifest.json").exists() {
        platform.attach_artifacts("artifacts")?;
    }
    let prog = platform.dbg.load_source(&src)?;
    Ok((platform, prog))
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut platform = if let Some(path) = args.flags.get("from-snapshot") {
        // resume from a saved image; a guest file, if given, is loaded
        // over the restored state (seamless reprogramming)
        let snap = PlatformSnapshot::load(path)?;
        let mut platform = Platform::new(load_config(args)?);
        if let Some(dir) = args.flags.get("artifacts") {
            platform.attach_artifacts(dir)?;
        }
        platform.restore(&snap)?;
        if let Some(prog) = args.positional.first() {
            let src =
                std::fs::read_to_string(prog).with_context(|| format!("reading {prog}"))?;
            platform.dbg.load_source(&src)?;
        }
        platform
    } else if args.flags.contains_key("builtin") {
        let mut platform = Platform::new(load_config(args)?);
        if let Some(dir) = args.flags.get("artifacts") {
            platform.attach_artifacts(dir)?;
        } else if std::path::Path::new("artifacts/manifest.json").exists() {
            platform.attach_artifacts("artifacts")?;
        }
        load_builtin(&mut platform, args.flags.get("builtin").unwrap())?;
        platform
    } else {
        load_guest(args)?.0
    };
    let trace_mask = trace_mask_from_args(args)?;
    if trace_mask != 0 {
        let depth = args
            .flags
            .get("trace-depth")
            .map(|s| s.parse::<u64>())
            .transpose()?
            .unwrap_or(femu::trace::DEFAULT_DEPTH as u64) as usize;
        platform.dbg.soc.set_trace(femu::trace::TraceConfig { mask: trace_mask, depth });
    }
    let budget = args
        .flags
        .get("max-cycles")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(1 << 33);
    let profile = args.switches.iter().any(|s| s == "profile");
    if profile {
        platform.dbg.soc.set_profile();
    }
    let exit = platform.run_app(budget)?;
    let uart = platform.dbg.uart();
    if !uart.is_empty() {
        print!("{}", String::from_utf8_lossy(&uart));
    }
    println!(
        "exit: {exit:?} after {} cycles ({}s emulated)",
        platform.dbg.soc.now,
        eng(platform.dbg.soc.now as f64 / platform.cfg.soc.freq_hz as f64)
    );
    if trace_mask != 0 {
        let out = args.flags.get("trace-out").map(String::as_str).unwrap_or("femu.trace");
        save_trace(&platform, out)?;
    }
    if profile {
        print!("{}", profile_report_from_soc(&platform)?.render_text());
    }
    Ok(())
}

/// Fold the live Soc's profiler capture through the analyzer's symbol
/// recovery — the same path the server's `profile.read` takes, for
/// guests loaded from snapshots or builtins where no assembled
/// [`femu::isa::Program`] is at hand.
fn profile_report_from_soc(platform: &Platform) -> Result<femu::profile::ProfileReport> {
    use femu::analyze::{self, AnalyzeConfig};
    let soc = &platform.dbg.soc;
    let prof = soc.profiler().ok_or_else(|| anyhow!("profiling was not enabled"))?;
    let acfg = AnalyzeConfig::from_platform(&platform.cfg);
    let mut img = analyze::Image::from_soc(soc);
    img.entry = prof.entry_pc();
    let table = analyze::analyze(&img, "run", &acfg).function_table();
    let perf_now = soc.perf.snapshot(soc.now);
    Ok(femu::profile::build_report(
        prof,
        soc.now,
        &perf_now,
        &table,
        &platform.cfg.energy,
        soc.backend_kind().name(),
    ))
}

/// Load a named builtin guest into a platform, wiring up any CS-side
/// service it expects (the acquisition kernel drains the virtualized
/// ADC, so it gets the same synthetic dataset the lockstep suite uses).
fn load_builtin(platform: &mut Platform, name: &str) -> Result<femu::isa::Program> {
    use femu::workloads::{builtin, BUILTIN_NAMES};
    let src = builtin(name).ok_or_else(|| {
        anyhow!("unknown builtin `{name}` (have: {})", BUILTIN_NAMES.join(", "))
    })?;
    let prog = platform.dbg.load_source(&src)?;
    if name == "acquisition" {
        platform.start_adc((0..100).collect(), 100_000.0);
    }
    Ok(prog)
}

/// `--trace CATS[,CATS..]` (or bare `--trace` for everything): the
/// category mask for the event ring, 0 when tracing is off.
fn trace_mask_from_args(args: &Args) -> Result<u8> {
    if let Some(v) = args.flags.get("trace") {
        femu::trace::parse_categories(v)
    } else if args.switches.iter().any(|s| s == "trace") {
        Ok(femu::trace::category::ALL)
    } else {
        Ok(0)
    }
}

/// Dump the armed event ring to a `FEMUTRAC` capture file and print a
/// one-line summary.
fn save_trace(platform: &Platform, out: &str) -> Result<()> {
    let soc = &platform.dbg.soc;
    let ring = soc.trace_ring().ok_or_else(|| anyhow!("tracing was not enabled"))?;
    let dump =
        femu::trace::format::TraceDump::from_ring(ring, soc.freq_hz, soc.bus.banks.len() as u32);
    dump.save(out)?;
    println!(
        "trace: {} event(s) recorded, {} retained ({} dropped), categories {}, \
         digest {:#018x} -> {out}",
        dump.total,
        dump.events.len(),
        dump.dropped(),
        dump.categories(),
        dump.digest
    );
    Ok(())
}

/// `femu profile`: run a guest under the cycle-exact profiler and fold
/// the capture to function granularity (DESIGN.md §14). The default
/// text output keeps the original whole-run energy table, followed by
/// the per-function flat/inclusive view; `--json` and `--folded [FILE]`
/// select machine exports, `--annotate` appends a per-pc disassembly,
/// and `--validate` is the CI profile-validate job's engine.
fn cmd_profile(args: &Args) -> Result<()> {
    if args.switches.iter().any(|s| s == "validate") {
        return cmd_profile_validate(args);
    }
    let (mut platform, prog, label) = if let Some(name) = args.flags.get("builtin") {
        let mut platform = Platform::new(load_config(args)?);
        if let Some(dir) = args.flags.get("artifacts") {
            platform.attach_artifacts(dir)?;
        } else if std::path::Path::new("artifacts/manifest.json").exists() {
            platform.attach_artifacts("artifacts")?;
        }
        let prog = load_builtin(&mut platform, name)?;
        (platform, prog, name.clone())
    } else {
        let (platform, prog) = load_guest(args)?;
        let label = args.positional.first().cloned().unwrap_or_default();
        (platform, prog, label)
    };
    platform.dbg.soc.set_profile();
    if args.flags.contains_key("vcd") {
        platform.dbg.soc.perf.enable_trace();
    }
    let exit = platform.run_app(1 << 33)?;
    if exit != AppExit::Halted(femu::cpu::Halt::Ebreak) {
        eprintln!("warning: guest exit was {exit:?}");
    }
    let model_name = args.flags.get("model").map(String::as_str).unwrap_or("femu");
    let model = EnergyModel::by_name(model_name)
        .ok_or_else(|| anyhow!("unknown model `{model_name}`"))?;

    // fold the capture to function granularity; symbols come from the
    // analyzer, so names match `femu analyze --json` exactly
    let acfg = femu::analyze::AnalyzeConfig::from_platform(&platform.cfg);
    let table = femu::analyze::analyze_program(&prog, &label, &acfg).function_table();
    let soc = &platform.dbg.soc;
    let prof = soc.profiler().expect("armed before the run");
    let perf_now = soc.perf.snapshot(soc.now);
    let prep = femu::profile::build_report(
        prof,
        soc.now,
        &perf_now,
        &table,
        &model,
        soc.backend_kind().name(),
    );

    let json = args.switches.iter().any(|s| s == "json");
    let folded_stdout = args.switches.iter().any(|s| s == "folded");
    if json {
        println!("{}", prep.to_json());
    } else if let Some(out) = args.flags.get("folded") {
        std::fs::write(out, prep.to_folded()).with_context(|| format!("writing {out}"))?;
        println!("folded stacks -> {out}");
    } else if folded_stdout {
        print!("{}", prep.to_folded());
    } else {
        let snap = platform.perf_snapshot();
        let report = model.estimate(&snap);
        println!("== femu profile ({model_name} calibration) ==");
        println!(
            "cycles: {}  time: {}s  instructions: {}",
            snap.cycles,
            eng(report.seconds()),
            platform.dbg.soc.stats.instructions
        );
        println!("domain        active       clk-gated    pwr-gated    retention    energy");
        for (d, c) in snap.domains() {
            println!(
                "{:<12} {:>12} {:>12} {:>12} {:>12}    {}J",
                d.to_string(),
                c.counts[0],
                c.counts[1],
                c.counts[2],
                c.counts[3],
                eng(model.domain_energy_mj(d, &c) / 1e3),
            );
        }
        println!(
            "total: {}J (active {}J, sleep {}J), avg power {}W",
            eng(report.total_mj / 1e3),
            eng(report.active_mj / 1e3),
            eng(report.sleep_mj / 1e3),
            eng(report.avg_power_mw() / 1e3),
        );
        if let Some(w) = platform.perf_window_snapshot() {
            let wr = model.estimate(w);
            println!("manual window: {} cycles, {}J", w.cycles, eng(wr.total_mj / 1e3));
        }
        print!("{}", prep.render_text());
    }
    if args.switches.iter().any(|s| s == "annotate") {
        print!(
            "{}",
            femu::profile::render_annotated(prof, &table, |a| platform.dbg.read32(a).ok())
        );
    }
    if let Some(path) = args.flags.get("vcd") {
        let trace = platform.dbg.soc.perf.trace().expect("trace enabled above");
        std::fs::write(path, trace.to_vcd(platform.cfg.soc.freq_hz, platform.dbg.soc.now))?;
        println!("power-domain VCD ({} transitions) -> {path}", trace.len());
    }
    Ok(())
}

/// The CI `profile-validate` job: every builtin runs under the profiler
/// twice on the interpreter (repeatability) and once on the block
/// backend (cross-backend identity). The capture digests must be
/// bit-identical across all three runs, and every folded report must
/// conserve cycles, instructions, and energy against the perf monitor.
/// `--folded FILE` additionally writes the first builtin's folded
/// stacks as a CI artifact.
fn cmd_profile_validate(args: &Args) -> Result<()> {
    use femu::analyze::{self, AnalyzeConfig};
    use femu::workloads::BUILTIN_NAMES;

    let cfg = load_config(args)?;
    let which = args.flags.get("builtin").map(String::as_str).unwrap_or("all");
    let names: Vec<&str> =
        if which == "all" { BUILTIN_NAMES.to_vec() } else { vec![which] };
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let acfg = AnalyzeConfig::from_platform(&cfg);

    // (digest, attributed, retired, folded export, conservation problems)
    let run_one = |name: &str,
                   backend: BackendKind|
     -> Result<(u64, u64, u64, String, Vec<String>)> {
        let mut cfg = cfg.clone();
        cfg.soc.backend = backend;
        cfg.soc.profile = true;
        let mut p = Platform::new(cfg);
        if have_artifacts {
            p.attach_artifacts("artifacts")?;
        }
        let prog = load_builtin(&mut p, name)?;
        let exit = p.run_app(1 << 28)?;
        if !matches!(exit, AppExit::Halted(_)) {
            bail!("{name} on {backend}: unexpected exit {exit:?}");
        }
        let soc = &p.dbg.soc;
        let prof = soc.profiler().expect("armed via config");
        let table = analyze::analyze_program(&prog, name, &acfg).function_table();
        let perf_now = soc.perf.snapshot(soc.now);
        let rep = femu::profile::build_report(
            prof,
            soc.now,
            &perf_now,
            &table,
            &p.cfg.energy,
            backend.name(),
        );
        let mut problems = Vec::new();
        let flat: u64 = rep.functions.iter().map(|f| f.flat_cycles).sum();
        if flat != rep.attributed_cycles {
            problems
                .push(format!("sum of flat cycles {flat} != attributed {}", rep.attributed_cycles));
        }
        if rep.attributed_cycles + rep.idle_cycles != rep.window_cycles {
            problems.push(format!(
                "attributed {} + idle {} != window {}",
                rep.attributed_cycles, rep.idle_cycles, rep.window_cycles
            ));
        }
        let instret: u64 = rep.functions.iter().map(|f| f.flat_instret).sum();
        if instret != rep.retired {
            problems.push(format!("sum of flat instret {instret} != retired {}", rep.retired));
        }
        let mj: f64 = rep.functions.iter().map(|f| f.flat_mj).sum::<f64>() + rep.idle_mj;
        if (mj - rep.total_mj).abs() > 1e-9 * rep.total_mj.max(1.0) {
            problems.push(format!("sum of energy {mj} mJ != model total {} mJ", rep.total_mj));
        }
        Ok((prof.digest(), prof.attributed_cycles(), prof.retired(), rep.to_folded(), problems))
    };

    let mut failed = false;
    let mut folded_artifact: Option<(String, String)> = None;
    for name in names {
        if name == "classifier_mailbox" && !have_artifacts {
            println!("  [skip] {name}: needs PJRT artifacts (run `make artifacts` first)");
            continue;
        }
        let (d1, a1, r1, folded, mut problems) = run_one(name, BackendKind::Interp)?;
        let (d2, _, _, _, p2) = run_one(name, BackendKind::Interp)?;
        let (d3, a3, r3, _, p3) = run_one(name, BackendKind::Blocks)?;
        problems.extend(p2);
        problems.extend(p3);
        if d1 != d2 {
            problems.push("repeat interp captures not bit-identical".to_string());
        }
        if d1 != d3 || a1 != a3 || r1 != r3 {
            problems.push(format!(
                "interp and blocks captures differ (digest {d1:#018x} vs {d3:#018x})"
            ));
        }
        if problems.is_empty() {
            println!(
                "  [ok] {name}: {r1} retire(s), {a1} cycle(s) attributed; capture \
                 bit-identical across repeats and backends"
            );
        } else {
            failed = true;
            println!("  [FAIL] {name}: {}", problems.join("; "));
        }
        if folded_artifact.is_none() {
            folded_artifact = Some((name.to_string(), folded));
        }
    }
    if let Some(out) = args.flags.get("folded") {
        if let Some((name, text)) = &folded_artifact {
            std::fs::write(out, text).with_context(|| format!("writing {out}"))?;
            println!("folded stacks ({name}) -> {out}");
        }
    }
    if failed {
        bail!("profile validation failed");
    }
    println!("profile validation passed");
    Ok(())
}

fn cmd_disasm(args: &Args) -> Result<()> {
    let path = args.positional.first().ok_or_else(|| anyhow!("expected an assembly file"))?;
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let prog = femu::isa::assemble(&src)?;
    print!("{}", femu::isa::listing(&prog.text, prog.text_base));
    if !prog.data.is_empty() {
        println!("
.data ({} bytes at {:#x})", prog.data.len(), prog.data_base);
    }
    Ok(())
}

/// `--from-snapshot FILE`: the golden image a forked experiment sweep
/// restores per point, replacing the fresh boot (+ warmup).
fn golden_from_args(args: &Args) -> Result<Option<PlatformSnapshot>> {
    match args.flags.get("from-snapshot") {
        Some(path) => Ok(Some(PlatformSnapshot::load(path)?)),
        None => Ok(None),
    }
}

fn cmd_snapshot(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("save") => {
            let prog = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: femu snapshot save <prog.s> --out FILE"))?;
            let src =
                std::fs::read_to_string(prog).with_context(|| format!("reading {prog}"))?;
            let mut platform = Platform::new(load_config(args)?);
            if let Some(dir) = args.flags.get("artifacts") {
                platform.attach_artifacts(dir)?;
            }
            platform.dbg.load_source(&src)?;
            let cycles = args
                .flags
                .get("cycles")
                .map(|s| s.parse::<u64>())
                .transpose()?
                .unwrap_or(0);
            if cycles > 0 {
                let exit = platform.run_app(cycles)?;
                println!("warmup: {exit:?} at cycle {}", platform.dbg.soc.now);
            }
            let out = args
                .flags
                .get("out")
                .map(String::as_str)
                .unwrap_or("snapshot.femusnap");
            let snap = platform.snapshot();
            snap.save(out)?;
            println!(
                "snapshot v{} ({} bytes, cycle {}) -> {out}",
                femu::snapshot::VERSION,
                snap.size_bytes(),
                platform.dbg.soc.now
            );
            Ok(())
        }
        Some("info") => {
            let path = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: femu snapshot info <FILE>"))?;
            let snap = PlatformSnapshot::load(path)?;
            let info = snap.info()?;
            println!("snapshot: {path} ({} bytes, format v{})", snap.size_bytes(), femu::snapshot::VERSION);
            println!("platform: {} @ {} Hz", info.name, info.freq_hz);
            println!(
                "shape:    {} banks x {:#x} B SRAM, {} B CS DRAM, {} B flash",
                info.num_banks, info.bank_size, info.cs_dram_size, info.flash_size
            );
            println!("cycles:   {} ({}s emulated)", info.cycles, eng(info.cycles as f64 / info.freq_hz as f64));
            Ok(())
        }
        _ => bail!("usage: femu snapshot save <prog.s> --out FILE [--cycles N] | femu snapshot info <FILE>"),
    }
}

fn cmd_sweep_acquisition(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let fleet = fleet_from_args(args)?;
    let golden = golden_from_args(args)?;
    let window_s = args
        .flags
        .get("window-s")
        .map(|s| s.parse::<f64>())
        .transpose()?
        .unwrap_or(5.0);
    println!(
        "== Fig 4: normalized acquisition time & energy ({window_s} s window, {} worker(s)) ==",
        fleet.workers()
    );
    println!(
        "{:>10} {:>12} | {:>9} {:>9} {:>8} | {:>10} {:>10} {:>8}",
        "f_s (Hz)", "platform", "active_s", "sleep_s", "act_t%", "act_mJ", "slp_mJ", "act_E%"
    );
    for p in experiments::fig4_sweep_from(&fleet, &cfg, window_s, 0xF164, golden.as_ref(), &|| false)? {
        let plat = if p.model == "femu" { "X-HEEP-FEMU" } else { "HEEPocrates" };
        println!(
            "{:>10} {:>12} | {:>9.4} {:>9.4} {:>7.2}% | {:>10.4} {:>10.4} {:>7.2}%",
            p.sample_rate_hz,
            plat,
            p.active_s,
            p.sleep_s,
            100.0 * p.active_s / p.total_s,
            p.active_mj,
            p.sleep_mj,
            100.0 * p.active_mj / p.total_mj,
        );
    }
    Ok(())
}

fn cmd_kernels(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let fleet = fleet_from_args(args)?;
    let golden = golden_from_args(args)?;
    println!(
        "== Fig 5: TinyAI kernels, CPU vs CGRA, FEMU vs chip ({} worker(s)) ==",
        fleet.workers()
    );
    println!(
        "{:>6} {:>6} {:>12} | {:>12} {:>10} {:>12} {:>6}",
        "kernel", "impl", "platform", "cycles", "time", "energy", "valid"
    );
    let all = experiments::fig5_all_from(&fleet, &cfg, 0xF15, golden.as_ref(), &|| false)?;
    for p in &all {
        let plat = if p.model == "femu" { "X-HEEP-FEMU" } else { "HEEPocrates" };
        println!(
            "{:>6} {:>6} {:>12} | {:>12} {:>9}s {:>11}J {:>6}",
            p.kernel,
            p.implementation,
            plat,
            p.cycles,
            eng(p.time_s),
            eng(p.energy_mj / 1e3),
            if p.validated { "yes" } else { "NO" },
        );
    }
    println!("\nsummary (femu calibration):");
    for k in ["MM", "CONV", "FFT"] {
        let cpu = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CPU" && p.model == "femu")
            .unwrap();
        let cgra = all
            .iter()
            .find(|p| p.kernel == k && p.implementation == "CGRA" && p.model == "femu")
            .unwrap();
        println!(
            "  {k}: CGRA speedup {:.2}x, energy ratio {:.2}x",
            cpu.cycles as f64 / cgra.cycles as f64,
            cpu.energy_mj / cgra.energy_mj
        );
    }
    for k in ["MM", "CONV", "FFT"] {
        for imp in ["CPU", "CGRA"] {
            let femu_e = all
                .iter()
                .find(|p| p.kernel == k && p.implementation == imp && p.model == "femu")
                .unwrap();
            let chip_e = all
                .iter()
                .find(|p| p.kernel == k && p.implementation == imp && p.model == "heepocrates")
                .unwrap();
            let dev = femu::energy::relative_deviation(femu_e.energy_mj, chip_e.energy_mj);
            println!("  {k}/{imp}: FEMU-vs-chip energy deviation {:.1}%", dev * 100.0);
        }
    }
    if args.switches.iter().any(|s| s == "validate") {
        validate_virtualized()?;
    }
    Ok(())
}

/// §V-B step 5: run a kernel through the *virtualized* accelerator
/// (PJRT artifacts) and cross-check against the shared oracle.
fn validate_virtualized() -> Result<()> {
    use femu::runtime::{Runtime, TensorI32};
    use femu::util::Rng;
    use femu::workloads::reference as refimpl;
    println!("\n== virtualized-accelerator validation (PJRT artifacts) ==");
    let rt = Runtime::load("artifacts").context("run `make artifacts` first")?;
    let mut rng = Rng::new(0x7A);
    let a = rng.vec_i32(121 * 16, -4096, 4096);
    let b = rng.vec_i32(16 * 4, -4096, 4096);
    let out = rt.execute(
        "matmul",
        &[TensorI32::new(vec![121, 16], a.clone())?, TensorI32::new(vec![16, 4], b.clone())?],
    )?;
    let ok = out[0].data() == refimpl::matmul_i32(&a, &b, 121, 16, 4).as_slice();
    println!("  matmul virtualized == oracle: {}", if ok { "yes" } else { "NO" });
    if !ok {
        bail!("virtualized matmul mismatch");
    }
    let re = rng.vec_i32(512, -(1 << 15), 1 << 15);
    let im = rng.vec_i32(512, -(1 << 15), 1 << 15);
    let mut args =
        vec![TensorI32::new(vec![512], re.clone())?, TensorI32::new(vec![512], im.clone())?];
    args.extend(femu::virt::accel::fft_table_tensors(512));
    let out = rt.execute("fft512", &args)?;
    let mut wr = re.clone();
    let mut wi = im.clone();
    refimpl::fft_q15(&mut wr, &mut wi);
    let ok = out[0].data() == wr.as_slice() && out[1].data() == wi.as_slice();
    println!("  fft512 virtualized == oracle: {}", if ok { "yes" } else { "NO" });
    if !ok {
        bail!("virtualized fft mismatch");
    }
    Ok(())
}

fn cmd_flash_study(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let fleet = fleet_from_args(args)?;
    let golden = golden_from_args(args)?;
    let scale = args
        .flags
        .get("scale")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(1);
    println!("== Case C (\u{a7}V-C): flash virtualization transfer study ==");
    let r = experiments::case_c_from(&fleet, &cfg, scale, golden.as_ref(), &|| false)?;
    if golden.is_some() {
        println!(
            "note: measuring the snapshot's own guest + flash contents; only the \
             totals and speedup below describe it (window figures assume the \
             standard \u{a7}V-C layout)"
        );
    }
    println!(
        "windows: {} x {} samples ({} KiB/window)",
        r.windows,
        r.samples_per_window,
        r.samples_per_window * 2 / 1024
    );
    println!(
        "per-window: virtualized {}s vs physical {}s",
        eng(r.virt_window_s),
        eng(r.phys_window_s)
    );
    println!(
        "full experiment: virtualized {}s vs physical {}s -> {:.0}x speedup",
        eng(r.virt_total_s),
        eng(r.phys_total_s),
        r.speedup
    );
    Ok(())
}

/// `femu diff`: lockstep differential validation of two execution
/// backends (DESIGN.md §11). With a guest file, diffs that program;
/// without, runs the standard lockstep suite; `--experiments` re-runs
/// fig4/fig5/case C once per backend and compares every published
/// number bit-for-bit. Exits nonzero on any divergence.
fn cmd_diff(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let fleet = fleet_from_args(args)?;
    let (a, b) = match args.flags.get("backends") {
        Some(s) => {
            let (x, y) = s
                .split_once(',')
                .ok_or_else(|| anyhow!("--backends wants `A,B` (e.g. interp,blocks)"))?;
            (BackendKind::parse(x.trim())?, BackendKind::parse(y.trim())?)
        }
        None => (BackendKind::Interp, BackendKind::Blocks),
    };
    let mut opts = diff::LockstepOptions::default();
    if let Some(v) = args.flags.get("checkpoint-cycles") {
        opts.checkpoint_cycles = v.parse().with_context(|| format!("--checkpoint-cycles `{v}`"))?;
    }
    if let Some(v) = args.flags.get("diff-max-cycles") {
        opts.max_cycles = v.parse().with_context(|| format!("--diff-max-cycles `{v}`"))?;
    }
    // --trace: arm the event ring on both sides; checkpoints then also
    // compare trace digests, and a divergence carries both captures
    opts.trace_mask = trace_mask_from_args(args)?;
    println!(
        "== femu diff: {a} vs {b} in lockstep (checkpoint every {} cycles, {} worker(s)) ==",
        opts.checkpoint_cycles,
        fleet.workers()
    );
    let reports = match args.positional.first() {
        Some(path) => {
            let src =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            vec![diff::lockstep_source(&cfg, path, &src, a, b, &opts)?]
        }
        None => diff::lockstep_workloads(&fleet, &cfg, a, b, &opts)?,
    };
    let mut failed = false;
    for r in &reports {
        println!("  [{}] {r}", if r.matched() { "ok" } else { "DIVERGED" });
        failed |= !r.matched();
        write_divergence_traces(r)?;
    }
    if args.switches.iter().any(|s| s == "precompile") {
        // cold vs analyzer-precompiled block caches, both on the blocks
        // backend: warming must be architecturally invisible
        println!("== precompile diff: blocks cold vs analyzer-precompiled ==");
        let pre = match args.positional.first() {
            Some(path) => {
                let src =
                    std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
                vec![diff::lockstep_source_precompiled(&cfg, path, &src, &opts)?]
            }
            None => diff::lockstep_workloads_precompiled(&fleet, &cfg, &opts)?,
        };
        for r in &pre {
            println!("  [{}] {r}", if r.matched() { "ok" } else { "DIVERGED" });
            failed |= !r.matched();
            write_divergence_traces(r)?;
        }
    }
    if args.switches.iter().any(|s| s == "experiments") {
        let window_s =
            args.flags.get("window-s").map(|s| s.parse::<f64>()).transpose()?.unwrap_or(0.05);
        let scale =
            args.flags.get("scale").map(|s| s.parse::<usize>()).transpose()?.unwrap_or(40);
        println!(
            "== experiment-level diff (fig4 window {window_s} s, case C scale 1/{scale}) =="
        );
        for d in diff::diff_experiments(&fleet, &cfg, a, b, window_s, scale)? {
            if d.matched() {
                println!("  [ok] {}: {} point(s) bit-identical", d.experiment, d.points);
            } else {
                failed = true;
                println!("  [DIVERGED] {}:", d.experiment);
                for m in &d.mismatches {
                    println!("    {m}");
                }
            }
        }
    }
    if failed {
        bail!("backends {a} and {b} diverged");
    }
    println!("backends {a} and {b} are bit-identical on everything tested");
    Ok(())
}

/// On a traced divergence, drop both sides' capture files into the CWD
/// so CI can upload them as failure artifacts.
fn write_divergence_traces(r: &diff::LockstepReport) -> Result<()> {
    let Some(d) = &r.divergence else { return Ok(()) };
    let stem: String = r
        .workload
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    for (side, bytes) in [("a", &d.trace_a), ("b", &d.trace_b)] {
        if let Some(bytes) = bytes {
            let path = format!("{stem}.{side}.trace");
            std::fs::write(&path, bytes).with_context(|| format!("writing {path}"))?;
            println!("    trace capture ({side}) -> {path}");
        }
    }
    Ok(())
}

/// `femu trace`: work with binary trace captures (DESIGN.md §13).
/// `dump` exports a `.trace` file to VCD / JSON-lines (no output flag:
/// JSON-lines to stdout), `info` prints the header, `validate` is the
/// CI trace-validate job's engine.
fn cmd_trace(args: &Args) -> Result<()> {
    use femu::trace::format::TraceDump;
    match args.positional.first().map(String::as_str) {
        Some("dump") => {
            let path = args.positional.get(1).ok_or_else(|| {
                anyhow!("usage: femu trace dump <FILE> [--vcd OUT] [--jsonl OUT]")
            })?;
            let dump = TraceDump::load(path)?;
            let mut exported = false;
            if let Some(out) = args.flags.get("vcd") {
                std::fs::write(out, femu::trace::export::to_vcd(&dump))
                    .with_context(|| format!("writing {out}"))?;
                println!("vcd: {} event(s) -> {out}", dump.events.len());
                exported = true;
            }
            if let Some(out) = args.flags.get("jsonl") {
                std::fs::write(out, femu::trace::export::to_jsonl(&dump))
                    .with_context(|| format!("writing {out}"))?;
                println!("jsonl: {} event(s) -> {out}", dump.events.len());
                exported = true;
            }
            if !exported {
                print!("{}", femu::trace::export::to_jsonl(&dump));
            }
            Ok(())
        }
        Some("info") => {
            let path = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: femu trace info <FILE>"))?;
            let dump = TraceDump::load(path)?;
            println!(
                "trace:      {path} (format v{}, {} bytes/event)",
                femu::trace::format::VERSION,
                femu::trace::EVENT_BYTES
            );
            println!("platform:   {} Hz, {} SRAM bank(s)", dump.freq_hz, dump.num_banks);
            println!("categories: {}", dump.categories());
            println!(
                "events:     {} recorded, {} retained, {} dropped",
                dump.total,
                dump.events.len(),
                dump.dropped()
            );
            for (i, name) in ["retire", "bus", "irq", "power"].iter().enumerate() {
                println!("  {name:<8} {}", dump.counts[i]);
            }
            if let (Some(first), Some(last)) = (dump.events.first(), dump.events.last()) {
                println!(
                    "window:     cycle {} .. {} ({}s at {} Hz)",
                    first.cycle,
                    last.cycle,
                    eng((last.cycle - first.cycle) as f64 / dump.freq_hz.max(1) as f64),
                    dump.freq_hz
                );
            }
            println!("digest:     {:#018x}", dump.digest);
            Ok(())
        }
        Some("validate") => cmd_trace_validate(args),
        _ => bail!(
            "usage: femu trace dump <FILE> [--vcd OUT] [--jsonl OUT] | \
             femu trace info <FILE> | femu trace validate [--builtin NAME|all]"
        ),
    }
}

/// The CI `trace-validate` job: for every requested builtin, run it
/// with every category armed — twice on the interpreter (repeatability)
/// and once on the block backend (cross-backend identity) — then check
/// that the capture bytes are bit-identical across all three runs and
/// that the ring's retire count equals the CPU's architectural instret.
fn cmd_trace_validate(args: &Args) -> Result<()> {
    use femu::trace::{category, TraceConfig};
    use femu::workloads::BUILTIN_NAMES;

    let cfg = load_config(args)?;
    let which = args.flags.get("builtin").map(String::as_str).unwrap_or("all");
    let names: Vec<&str> =
        if which == "all" { BUILTIN_NAMES.to_vec() } else { vec![which] };
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();

    let run_one = |name: &str, backend: BackendKind| -> Result<(Vec<u8>, u64, u64, u64)> {
        let mut cfg = cfg.clone();
        cfg.soc.backend = backend;
        cfg.soc.trace = TraceConfig { mask: category::ALL, ..TraceConfig::default() };
        let mut p = Platform::new(cfg);
        if have_artifacts {
            p.attach_artifacts("artifacts")?;
        }
        load_builtin(&mut p, name)?;
        let exit = p.run_app(1 << 28)?;
        if !matches!(exit, AppExit::Halted(_)) {
            bail!("{name} on {backend}: unexpected exit {exit:?}");
        }
        let soc = &p.dbg.soc;
        let ring = soc.trace_ring().expect("armed via config");
        let dump =
            femu::trace::format::TraceDump::from_ring(ring, soc.freq_hz, soc.bus.banks.len() as u32);
        Ok((dump.to_bytes(), ring.retires(), soc.cpu.instret, soc.cpu.irqs_taken))
    };

    let mut failed = false;
    for name in names {
        if name == "classifier_mailbox" && !have_artifacts {
            println!("  [skip] {name}: needs PJRT artifacts (run `make artifacts` first)");
            continue;
        }
        let (d1, retires, instret, irqs) = run_one(name, BackendKind::Interp)?;
        let (d2, ..) = run_one(name, BackendKind::Interp)?;
        let (d3, ..) = run_one(name, BackendKind::Blocks)?;
        let mut problems = Vec::new();
        if retires != instret {
            problems.push(format!("ring retires {retires} != cpu instret {instret}"));
        }
        if d1 != d2 {
            problems.push("repeat interp runs not bit-identical".to_string());
        }
        if d1 != d3 {
            problems.push("interp and blocks captures differ".to_string());
        }
        if problems.is_empty() {
            println!(
                "  [ok] {name}: {instret} retire(s), {irqs} interrupt(s) taken; capture \
                 bit-identical across repeats and backends"
            );
        } else {
            failed = true;
            println!("  [FAIL] {name}: {}", problems.join("; "));
        }
    }
    if failed {
        bail!("trace validation failed");
    }
    println!("trace validation passed");
    Ok(())
}

fn cmd_faults(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_faults_run(args),
        Some("report") => cmd_faults_report(args),
        _ => bail!(
            "usage: femu faults run [--builtin NAME | --campaign FILE] [--points N] \
             [--seed S] [--targets LIST] [--models LIST] [--window LO:HI] [--check] \
             [--json | --out FILE] | femu faults report <FILE> [--json]"
        ),
    }
}

/// A `--flag` value that may be decimal or `0x`-hex.
fn parse_u64_flag(flag: &str, v: &str) -> Result<u64> {
    let r = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => v.parse(),
    };
    r.with_context(|| format!("--{flag} `{v}`"))
}

/// Build a campaign spec from `--campaign FILE` (TOML) or `--builtin
/// NAME`, then apply per-flag overrides. Validation runs last, so a
/// TOML base plus CLI overrides is checked as a whole.
fn faults_spec_from_args(args: &Args) -> Result<femu::faults::CampaignSpec> {
    use femu::faults::{CampaignSpec, FaultModel, TargetSpace};

    let mut spec = match args.flags.get("campaign") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            CampaignSpec::from_toml(&text).with_context(|| format!("parsing campaign {path}"))?
        }
        None => {
            let builtin = args.flags.get("builtin").map(String::as_str).unwrap_or("mm_cpu");
            CampaignSpec::new(builtin)?
        }
    };
    if let Some(v) = args.flags.get("points") {
        spec.points = v.parse().with_context(|| format!("--points `{v}`"))?;
    }
    if let Some(v) = args.flags.get("seed") {
        spec.seed = parse_u64_flag("seed", v)?;
    }
    if let Some(v) = args.flags.get("targets") {
        spec.targets = TargetSpace::parse_list(v)?;
    }
    if let Some(v) = args.flags.get("models") {
        spec.models = FaultModel::parse_list(v)?;
    }
    if let Some(v) = args.flags.get("window") {
        let (lo, hi) = v
            .split_once(':')
            .ok_or_else(|| anyhow!("--window `{v}` (want LO:HI, e.g. 0.0:1.0)"))?;
        spec.window = (
            lo.parse().with_context(|| format!("--window lo `{lo}`"))?,
            hi.parse().with_context(|| format!("--window hi `{hi}`"))?,
        );
    }
    if let Some(v) = args.flags.get("watchdog-factor") {
        spec.watchdog_factor = v.parse().with_context(|| format!("--watchdog-factor `{v}`"))?;
    }
    spec.validate()?;
    Ok(spec)
}

/// `femu faults run`: run a fault-injection campaign (DESIGN.md §15).
/// `--check` additionally re-runs it with a different worker count and
/// on the other execution backend and requires the outcome tables to be
/// bit-identical — the CI `fault-smoke` gate.
fn cmd_faults_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let fleet = fleet_from_args(args)?;
    let spec = faults_spec_from_args(args)?;

    let report = femu::faults::run_campaign(&cfg, fleet, &spec)?;

    if args.switches.iter().any(|s| s == "check") {
        let mut problems = Vec::new();

        let other_fleet = if fleet.is_serial() { Fleet::new(4) } else { Fleet::serial() };
        let across_workers = femu::faults::run_campaign(&cfg, other_fleet, &spec)?;
        let workers_ok = across_workers.results == report.results
            && across_workers.golden == report.golden;
        println!(
            "  [{}] outcome table identical across {} and {} worker(s)",
            if workers_ok { "ok" } else { "FAIL" },
            fleet.workers(),
            other_fleet.workers()
        );
        if !workers_ok {
            problems.push("worker-count divergence".to_string());
        }

        let mut other_cfg = cfg.clone();
        other_cfg.soc.backend = match cfg.soc.backend {
            BackendKind::Interp => BackendKind::Blocks,
            BackendKind::Blocks => BackendKind::Interp,
        };
        let across_backends = femu::faults::run_campaign(&other_cfg, fleet, &spec)?;
        let backends_ok = across_backends.results == report.results
            && across_backends.golden == report.golden;
        println!(
            "  [{}] outcome table identical across {} and {} backends",
            if backends_ok { "ok" } else { "FAIL" },
            cfg.soc.backend.name(),
            other_cfg.soc.backend.name()
        );
        if !backends_ok {
            problems.push("cross-backend divergence".to_string());
        }

        if !problems.is_empty() {
            bail!("fault campaign determinism check failed: {}", problems.join("; "));
        }
    }

    let json = report.to_json().to_string();
    if let Some(path) = args.flags.get("out") {
        std::fs::write(path, format!("{json}\n")).with_context(|| format!("writing {path}"))?;
        println!("wrote {} points to {path}", report.results.len());
    } else if args.switches.iter().any(|s| s == "json") {
        println!("{json}");
    } else {
        print!("{}", report.render_text());
    }
    Ok(())
}

/// `femu faults report`: re-render a saved campaign JSON document.
fn cmd_faults_report(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: femu faults report <FILE> [--json]"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let report = femu::faults::CampaignReport::from_json(
        &femu::util::json::Json::parse(&text).with_context(|| format!("parsing {path}"))?,
    )
    .with_context(|| format!("decoding campaign report {path}"))?;
    if args.switches.iter().any(|s| s == "json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    Ok(())
}

/// `femu analyze`: static analysis of guest firmware — CFG recovery,
/// `FEMU-Axxx` lints, static WCET/energy bounds, and the block map the
/// blocks backend can precompile from (DESIGN.md §12). Exits nonzero if
/// any target produces diagnostics, so CI can gate on a clean report.
fn cmd_analyze(args: &Args) -> Result<()> {
    use femu::analyze::{self, AnalyzeConfig};
    use femu::workloads::{builtin, BUILTIN_NAMES};

    let cfg = load_config(args)?;
    let acfg = AnalyzeConfig::from_platform(&cfg);
    let json = args.switches.iter().any(|s| s == "json");

    // collect (name, report) for every requested target
    let mut reports: Vec<analyze::Report> = Vec::new();
    if let Some(which) = args.flags.get("builtin") {
        let names: Vec<&str> = if which == "all" {
            BUILTIN_NAMES.to_vec()
        } else {
            vec![which.as_str()]
        };
        for name in names {
            let src = builtin(name).ok_or_else(|| {
                anyhow!("unknown builtin `{name}` (have: {})", BUILTIN_NAMES.join(", "))
            })?;
            let prog = femu::isa::assemble(&src).with_context(|| format!("assembling {name}"))?;
            reports.push(analyze::analyze_program(&prog, name, &acfg));
        }
    }
    if let Some(path) = args.flags.get("from-snapshot") {
        let snap = PlatformSnapshot::load(path)?;
        let mut platform = Platform::new(cfg.clone());
        platform.restore(&snap)?;
        reports.push(analyze::analyze_soc(&platform.dbg.soc, path, &acfg));
    }
    for path in &args.positional {
        let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let prog = femu::isa::assemble(&src).with_context(|| format!("assembling {path}"))?;
        reports.push(analyze::analyze_program(&prog, path, &acfg));
    }
    if reports.is_empty() {
        bail!("nothing to analyze: pass a .s file, --builtin NAME|all, or --from-snapshot FILE");
    }

    if json {
        let arr = femu::util::Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        println!("{arr}");
    } else {
        for r in &reports {
            print!("{}", r.render_text());
        }
    }
    let dirty: Vec<&analyze::Report> = reports.iter().filter(|r| !r.clean()).collect();
    if !dirty.is_empty() {
        bail!(
            "{} of {} target(s) produced diagnostics: {}",
            dirty.len(),
            reports.len(),
            dirty.iter().map(|r| r.name.as_str()).collect::<Vec<_>>().join(", ")
        );
    }
    if !json {
        println!("all {} target(s) clean", reports.len());
    }
    Ok(())
}

fn cmd_table1() -> Result<()> {
    println!("== Table I: FPGA platform comparison ==\n");
    print!("{}", table1::render_markdown());
    println!("\n\u{a7}II filtering argument:");
    for (feature, survivors) in table1::filtering_steps() {
        println!("  after `{}`: {}", feature.name(), survivors.join(", "));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let addr = args.flags.get("addr").map(String::as_str).unwrap_or("127.0.0.1:9178");
    let mut opts = femu::server::ServerOptions::default();
    if let Some(v) = args.flags.get("max-sessions") {
        opts.max_sessions = v.parse().with_context(|| format!("--max-sessions `{v}`"))?;
    }
    if let Some(v) = args.flags.get("workers") {
        opts.workers = v.parse().with_context(|| format!("--workers `{v}`"))?;
    }
    if let Some(v) = args.flags.get("idle-timeout") {
        let secs: u64 = v.parse().with_context(|| format!("--idle-timeout `{v}`"))?;
        if secs == 0 {
            bail!("--idle-timeout must be at least 1 second");
        }
        opts.idle_timeout = std::time::Duration::from_secs(secs);
    }
    if let Some(dir) = args.flags.get("configs") {
        for entry in std::fs::read_dir(dir).with_context(|| format!("reading {dir}"))? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("toml") {
                continue;
            }
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| anyhow!("bad config filename {path:?}"))?
                .to_string();
            opts.named_configs.push((name, PlatformConfig::load(&path)?));
        }
    }
    let mut platform = Platform::new(cfg);
    if let Some(dir) = args.flags.get("artifacts") {
        platform.attach_artifacts(dir)?;
    }
    let workers = opts.workers;
    let max_sessions = opts.max_sessions;
    let named: Vec<String> = opts.named_configs.iter().map(|(n, _)| n.clone()).collect();
    let server = femu::server::Server::spawn_with(platform, addr, opts)?;
    println!("femu control server listening on {}", server.addr());
    println!(
        "sessions: {max_sessions} max, {workers} worker(s); named configs: default{}{}",
        if named.is_empty() { "" } else { ", " },
        named.join(", ")
    );
    println!(
        "protocol: one JSON object per line; try {{\"cmd\":\"ping\"}} or \
         {{\"cmd\":\"session.open\"}}"
    );
    // --metrics-interval N: print a one-line control-plane metrics
    // summary every N seconds (same counters as the `metrics` command)
    let interval = args
        .flags
        .get("metrics-interval")
        .map(|v| v.parse::<u64>().with_context(|| format!("--metrics-interval `{v}`")))
        .transpose()?
        .unwrap_or(0);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(if interval > 0 {
            interval
        } else {
            3600
        }));
        if interval > 0 {
            println!("{}", server.metrics_line());
        }
    }
}

/// `femu metrics`: fetch a running server's control-plane counters over
/// the wire (protocol command `metrics`, proto v6) — JSON by default,
/// Prometheus text exposition with `--prometheus`.
fn cmd_metrics(args: &Args) -> Result<()> {
    use femu::util::Json;
    let addr = args.flags.get("addr").map(String::as_str).unwrap_or("127.0.0.1:9178");
    let addr: std::net::SocketAddr =
        addr.parse().with_context(|| format!("--addr `{addr}`"))?;
    let mut client = femu::server::Client::connect(addr)?;
    if args.switches.iter().any(|s| s == "prometheus") {
        let resp = client.call(Json::obj(vec![
            ("cmd", Json::from("metrics")),
            ("format", Json::from("prometheus")),
        ]))?;
        print!("{}", resp.str_field("text")?);
    } else {
        println!("{}", client.metrics()?);
    }
    Ok(())
}
