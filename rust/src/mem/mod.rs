//! Banked SRAM with power states, plus the CS (DRAM) memory the bridge
//! window exposes to the guest.
//!
//! Models the X-HEEP memory subsystem: N independently power-switchable
//! SRAM banks (§IV-C tracks per-bank power states: active / clock-gated /
//! power-gated / retention). Contents survive retention but are lost on
//! power-gating (refilled with a poison pattern so guest bugs surface
//! deterministically).

use crate::perfmon::PowerState;

/// Poison word written into a bank when it loses power. 0xdeadbeef makes
/// use-after-power-gate bugs visible and deterministic.
pub const POISON: u32 = 0xDEAD_BEEF;

/// Write-generation granule: one generation counter per 2^9 = 512 bytes.
/// Coarse enough to keep the per-store overhead to one counter bump,
/// fine enough that unrelated data stores rarely evict compiled blocks.
pub const GEN_PAGE_SHIFT: u32 = 9;

/// One SRAM bank.
#[derive(Clone, Debug)]
pub struct SramBank {
    data: Vec<u8>,
    state: PowerState,
    /// Cycles in which this bank served an access (for the auto-clock-gate
    /// accounting in the energy model: a powered bank burns active power
    /// only while selected).
    access_cycles: u64,
    /// Per-page write generations ([`GEN_PAGE_SHIFT`]), bumped on every
    /// mutation path: stores, bulk loads, power-gate poisoning, snapshot
    /// restore. The block execution backend tags each compiled block with
    /// the generation it decoded against and re-decodes on mismatch —
    /// the self-modifying-code invalidation hook (DESIGN.md §11). Not
    /// serialized: generations are monotonic derived state, and keeping
    /// them out of snapshots preserves the payload layout.
    gens: Vec<u64>,
}

/// Error for accesses that the bank cannot serve in its power state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemError {
    /// Access while power-gated or in retention — a bus error in the real
    /// SoC (the bank's clock is off).
    NotPowered(PowerState),
    /// Address beyond the bank size.
    OutOfRange,
}

impl SramBank {
    pub fn new(size: usize) -> Self {
        assert!(size % 4 == 0, "bank size must be word-aligned");
        Self {
            data: vec![0; size],
            state: PowerState::Active,
            access_cycles: 0,
            gens: vec![0; size.div_ceil(1 << GEN_PAGE_SHIFT)],
        }
    }

    pub fn size(&self) -> usize {
        self.data.len()
    }

    pub fn state(&self) -> PowerState {
        self.state
    }

    pub fn access_cycles(&self) -> u64 {
        self.access_cycles
    }

    /// Change the bank's power state. Power-gating poisons the contents;
    /// retention and clock-gating preserve them.
    pub fn set_state(&mut self, new: PowerState) {
        if new == PowerState::PowerGated && self.state != PowerState::PowerGated {
            for chunk in self.data.chunks_exact_mut(4) {
                chunk.copy_from_slice(&POISON.to_le_bytes());
            }
            self.bump_all_gens();
        }
        self.state = new;
    }

    /// Current write generation of the page containing `offset`.
    #[inline]
    pub fn page_gen(&self, offset: usize) -> u64 {
        self.gens[offset >> GEN_PAGE_SHIFT]
    }

    #[inline]
    fn bump_gens(&mut self, offset: usize, len: usize) {
        let first = offset >> GEN_PAGE_SHIFT;
        let last = (offset + len - 1) >> GEN_PAGE_SHIFT;
        for p in first..=last {
            self.gens[p] += 1;
        }
    }

    fn bump_all_gens(&mut self) {
        for g in &mut self.gens {
            *g += 1;
        }
    }

    #[inline]
    fn check(&self, offset: usize, len: usize) -> Result<(), MemError> {
        match self.state {
            PowerState::Active | PowerState::ClockGated => {}
            s => return Err(MemError::NotPowered(s)),
        }
        if offset + len > self.data.len() {
            return Err(MemError::OutOfRange);
        }
        Ok(())
    }

    #[inline]
    pub fn read8(&mut self, offset: usize) -> Result<u8, MemError> {
        self.check(offset, 1)?;
        self.access_cycles += 1;
        Ok(self.data[offset])
    }

    #[inline]
    pub fn read16(&mut self, offset: usize) -> Result<u16, MemError> {
        self.check(offset, 2)?;
        self.access_cycles += 1;
        Ok(u16::from_le_bytes([self.data[offset], self.data[offset + 1]]))
    }

    #[inline]
    pub fn read32(&mut self, offset: usize) -> Result<u32, MemError> {
        self.check(offset, 4)?;
        self.access_cycles += 1;
        // single bounds check via the slice conversion (§Perf opt 5)
        Ok(u32::from_le_bytes(self.data[offset..offset + 4].try_into().unwrap()))
    }

    /// Instruction fetch: same as read32 but does not count an access
    /// cycle twice when the fetch pipeline hits the same bank as a data
    /// access (the caller accounts fetch cycles).
    #[inline]
    pub fn fetch32(&self, offset: usize) -> Result<u32, MemError> {
        self.check(offset, 4)?;
        Ok(u32::from_le_bytes(self.data[offset..offset + 4].try_into().unwrap()))
    }

    #[inline]
    pub fn write8(&mut self, offset: usize, v: u8) -> Result<(), MemError> {
        self.check(offset, 1)?;
        self.access_cycles += 1;
        self.bump_gens(offset, 1);
        self.data[offset] = v;
        Ok(())
    }

    #[inline]
    pub fn write16(&mut self, offset: usize, v: u16) -> Result<(), MemError> {
        self.check(offset, 2)?;
        self.access_cycles += 1;
        self.bump_gens(offset, 2);
        self.data[offset..offset + 2].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    #[inline]
    pub fn write32(&mut self, offset: usize, v: u32) -> Result<(), MemError> {
        self.check(offset, 4)?;
        self.access_cycles += 1;
        self.bump_gens(offset, 4);
        self.data[offset..offset + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// Bulk load (program loader / debugger virtualization). Ignores the
    /// power state — the debugger can always write SRAM (the real OpenOCD
    /// path powers the bank first).
    pub fn load(&mut self, offset: usize, bytes: &[u8]) -> Result<(), MemError> {
        if offset + bytes.len() > self.data.len() {
            return Err(MemError::OutOfRange);
        }
        if !bytes.is_empty() {
            self.bump_gens(offset, bytes.len());
        }
        self.data[offset..offset + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    /// Bulk read (debugger/CS inspection), ignoring power state.
    pub fn dump(&self, offset: usize, len: usize) -> Result<&[u8], MemError> {
        if offset + len > self.data.len() {
            return Err(MemError::OutOfRange);
        }
        Ok(&self.data[offset..offset + len])
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u8(self.state.to_u8());
        w.u64(self.access_cycles);
        w.filled_bytes(&self.data, 0);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.state = PowerState::from_u8(r.u8()?)?;
        self.access_cycles = r.u64()?;
        // banks are small (code + data live here): always fully restored
        r.filled_bytes_into(&mut self.data, 0, false)?;
        // the whole image may have changed: every compiled block is stale
        self.bump_all_gens();
        Ok(())
    }
}

/// CS-side DRAM: the memory the PS owns. The guest reaches a window of it
/// through the OBI-AXI bridge; CS services (virtual ADC/flash/accelerator
/// models) read and write it directly.
#[derive(Clone, Debug)]
pub struct CsDram {
    data: Vec<u8>,
    /// False while the memory is provably all-zero (never written since
    /// construction or since the last restore-to-pristine). Lets
    /// snapshot save skip the 16 MiB scan and restore skip the reset
    /// memset — the restore-per-point hot path of forked sweeps.
    touched: bool,
}

impl CsDram {
    pub fn new(size: usize) -> Self {
        Self { data: vec![0; size], touched: false }
    }

    pub fn size(&self) -> usize {
        self.data.len()
    }

    #[inline]
    fn check(&self, offset: usize, len: usize) -> Result<(), MemError> {
        if offset + len > self.data.len() {
            return Err(MemError::OutOfRange);
        }
        Ok(())
    }

    pub fn read8(&self, offset: usize) -> Result<u8, MemError> {
        self.check(offset, 1)?;
        Ok(self.data[offset])
    }

    pub fn read16(&self, offset: usize) -> Result<u16, MemError> {
        self.check(offset, 2)?;
        Ok(u16::from_le_bytes([self.data[offset], self.data[offset + 1]]))
    }

    pub fn read32(&self, offset: usize) -> Result<u32, MemError> {
        self.check(offset, 4)?;
        Ok(u32::from_le_bytes([
            self.data[offset],
            self.data[offset + 1],
            self.data[offset + 2],
            self.data[offset + 3],
        ]))
    }

    pub fn write8(&mut self, offset: usize, v: u8) -> Result<(), MemError> {
        self.check(offset, 1)?;
        self.touched = true;
        self.data[offset] = v;
        Ok(())
    }

    pub fn write16(&mut self, offset: usize, v: u16) -> Result<(), MemError> {
        self.check(offset, 2)?;
        self.touched = true;
        self.data[offset..offset + 2].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub fn write32(&mut self, offset: usize, v: u32) -> Result<(), MemError> {
        self.check(offset, 4)?;
        self.touched = true;
        self.data[offset..offset + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// Read a run of i32 words (tensor marshaling for the accelerator
    /// mailbox).
    pub fn read_i32_slice(&self, offset: usize, n: usize) -> Result<Vec<i32>, MemError> {
        self.check(offset, n * 4)?;
        Ok(self.data[offset..offset + n * 4]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Write a run of i32 words.
    pub fn write_i32_slice(&mut self, offset: usize, vals: &[i32]) -> Result<(), MemError> {
        self.check(offset, vals.len() * 4)?;
        self.touched = true;
        for (i, v) in vals.iter().enumerate() {
            self.data[offset + i * 4..offset + i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    pub fn load(&mut self, offset: usize, bytes: &[u8]) -> Result<(), MemError> {
        self.check(offset, bytes.len())?;
        self.touched = true;
        self.data[offset..offset + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    pub fn dump(&self, offset: usize, len: usize) -> Result<&[u8], MemError> {
        self.check(offset, len)?;
        Ok(&self.data[offset..offset + len])
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.bool(self.touched);
        if self.touched {
            w.filled_bytes(&self.data, 0);
        } else {
            w.filled_bytes_clean(self.data.len());
        }
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        let snap_touched = r.bool()?;
        // skip the reset memset only when this memory is still pristine
        r.filled_bytes_into(&mut self.data, 0, !self.touched)?;
        self.touched = snap_touched;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_roundtrip_all_widths() {
        let mut b = SramBank::new(64);
        b.write32(0, 0x1234_5678).unwrap();
        assert_eq!(b.read32(0).unwrap(), 0x1234_5678);
        assert_eq!(b.read16(0).unwrap(), 0x5678);
        assert_eq!(b.read16(2).unwrap(), 0x1234);
        assert_eq!(b.read8(3).unwrap(), 0x12);
        b.write8(1, 0xAB).unwrap();
        assert_eq!(b.read32(0).unwrap(), 0x1234_AB78);
        b.write16(2, 0xCDEF).unwrap();
        assert_eq!(b.read32(0).unwrap(), 0xCDEF_AB78);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut b = SramBank::new(8);
        assert_eq!(b.read32(8), Err(MemError::OutOfRange));
        assert_eq!(b.write32(5, 0), Err(MemError::OutOfRange));
        assert_eq!(b.read8(7).unwrap(), 0); // last byte fine
    }

    #[test]
    fn power_gating_poisons_contents() {
        let mut b = SramBank::new(16);
        b.write32(4, 42).unwrap();
        b.set_state(PowerState::PowerGated);
        assert_eq!(b.read32(4), Err(MemError::NotPowered(PowerState::PowerGated)));
        b.set_state(PowerState::Active);
        assert_eq!(b.read32(4).unwrap(), POISON);
    }

    #[test]
    fn retention_preserves_contents_but_blocks_access() {
        let mut b = SramBank::new(16);
        b.write32(0, 7).unwrap();
        b.set_state(PowerState::Retention);
        assert_eq!(b.read32(0), Err(MemError::NotPowered(PowerState::Retention)));
        b.set_state(PowerState::Active);
        assert_eq!(b.read32(0).unwrap(), 7);
    }

    #[test]
    fn access_cycles_counted() {
        let mut b = SramBank::new(16);
        b.write32(0, 1).unwrap();
        b.read32(0).unwrap();
        b.read8(1).unwrap();
        assert_eq!(b.access_cycles(), 3);
    }

    #[test]
    fn debugger_load_ignores_power_state() {
        let mut b = SramBank::new(16);
        b.set_state(PowerState::Retention);
        b.load(0, &[1, 2, 3, 4]).unwrap();
        b.set_state(PowerState::Active);
        assert_eq!(b.read32(0).unwrap(), 0x0403_0201);
    }

    #[test]
    fn write_generations_track_every_mutation_path() {
        let mut b = SramBank::new(2048);
        let g0 = b.page_gen(0);
        b.write32(0, 1).unwrap();
        assert!(b.page_gen(0) > g0, "store bumps its page");
        let far = b.page_gen(1024);
        b.write8(512, 7).unwrap();
        assert_eq!(b.page_gen(1024), far, "store leaves other pages alone");
        assert!(b.page_gen(512) > 0);
        let before = b.page_gen(0);
        b.load(0, &[1, 2, 3]).unwrap();
        assert!(b.page_gen(0) > before, "bulk load bumps");
        let before = b.page_gen(1536);
        b.set_state(PowerState::PowerGated);
        assert!(b.page_gen(1536) > before, "power-gate poison bumps every page");
        // a write16 straddling a page boundary bumps both pages
        b.set_state(PowerState::Active);
        let (p0, p1) = (b.page_gen(0), b.page_gen(512));
        b.write16(511, 0xBEEF).unwrap();
        assert!(b.page_gen(0) > p0 && b.page_gen(512) > p1);
    }

    #[test]
    fn dram_i32_slices() {
        let mut d = CsDram::new(64);
        d.write_i32_slice(8, &[-1, 2, -3]).unwrap();
        assert_eq!(d.read_i32_slice(8, 3).unwrap(), vec![-1, 2, -3]);
        assert_eq!(d.read32(8).unwrap(), 0xFFFF_FFFF);
        assert!(d.read_i32_slice(60, 2).is_err());
    }
}
