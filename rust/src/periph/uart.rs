//! UART peripheral — application-level logging.
//!
//! In X-HEEP-FEMU the X-HEEP UART is routed to a PS UART port so the CS
//! sees guest printf output (§IV-B "debugger virtualization"). Here the TX
//! stream lands in a byte buffer the CS/debugger drains.

/// Register offsets within the UART window.
pub mod regs {
    pub const TXDATA: u32 = 0x00; // W: transmit one byte
    pub const STATUS: u32 = 0x04; // R: bit0 tx_ready (always 1 here)
    pub const RXDATA: u32 = 0x08; // R: reads 0 (no host->guest channel)
}

#[derive(Clone, Debug, Default)]
pub struct Uart {
    tx: Vec<u8>,
}

impl Uart {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(&mut self, offset: u32) -> u32 {
        match offset {
            regs::STATUS => 1, // always ready (CS drains instantly)
            regs::RXDATA => 0,
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        if offset == regs::TXDATA {
            self.tx.push(value as u8);
        }
    }

    /// Drain everything transmitted so far (CS side).
    pub fn drain(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.tx)
    }

    /// Peek at the TX stream without draining.
    pub fn peek(&self) -> &[u8] {
        &self.tx
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.bytes(&self.tx);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.tx = r.bytes()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx_collects_and_drains() {
        let mut u = Uart::new();
        for b in b"hi\n" {
            u.write(regs::TXDATA, *b as u32);
        }
        assert_eq!(u.peek(), b"hi\n");
        assert_eq!(u.drain(), b"hi\n".to_vec());
        assert!(u.peek().is_empty());
    }

    #[test]
    fn status_always_ready() {
        let mut u = Uart::new();
        assert_eq!(u.read(regs::STATUS) & 1, 1);
    }
}
