//! DMA engine: memory-to-memory block copies with completion interrupt.
//!
//! X-HEEP ships a small DMA the acquisition flow uses to drain peripheral
//! FIFOs without CPU involvement. The model is transactional: the guest
//! programs SRC/DST/LEN and sets START; the copy is performed by the SoC
//! at `busy_until` (start + modeled transfer time), at which point DONE is
//! set and the IRQ raised. Reads of DST before DONE observe old data —
//! matching real DMA semantics closely enough for the power/timing studies
//! (the guest must synchronize on DONE/IRQ either way).

/// Register offsets within the DMA window.
pub mod regs {
    pub const SRC: u32 = 0x00; // R/W: source byte address
    pub const DST: u32 = 0x04; // R/W: destination byte address
    pub const LEN: u32 = 0x08; // R/W: length in bytes (word multiple)
    pub const CTRL: u32 = 0x0C; // W: bit0 start, bit1 irq enable
    pub const STATUS: u32 = 0x10; // R: bit0 done, bit1 busy
}

/// Per-word transfer cost (read + write over the OBI bus).
pub const CYCLES_PER_WORD: u64 = 2;
/// Setup cost per transfer.
pub const SETUP_CYCLES: u64 = 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DmaRequest {
    pub src: u32,
    pub dst: u32,
    pub len: u32,
}

#[derive(Clone, Debug, Default)]
pub struct Dma {
    src: u32,
    dst: u32,
    len: u32,
    irq_enabled: bool,
    /// In-flight transfer and its completion time.
    inflight: Option<(DmaRequest, u64)>,
    done: bool,
    irq_level: bool,
}

impl Dma {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(&self, offset: u32) -> u32 {
        match offset {
            regs::SRC => self.src,
            regs::DST => self.dst,
            regs::LEN => self.len,
            regs::STATUS => (self.done as u32) | ((self.inflight.is_some() as u32) << 1),
            _ => 0,
        }
    }

    /// Guest register write at cycle `now`.
    pub fn write(&mut self, offset: u32, value: u32, now: u64) {
        match offset {
            regs::SRC => self.src = value,
            regs::DST => self.dst = value,
            regs::LEN => self.len = value,
            regs::CTRL => {
                self.irq_enabled = value & 2 != 0;
                if value & 1 != 0 && self.inflight.is_none() {
                    let words = (self.len as u64).div_ceil(4);
                    let finish = now + SETUP_CYCLES + words * CYCLES_PER_WORD;
                    self.inflight =
                        Some((DmaRequest { src: self.src, dst: self.dst, len: self.len }, finish));
                    self.done = false;
                    self.irq_level = false;
                }
            }
            _ => {}
        }
    }

    /// SoC polls: if the in-flight transfer completes at or before `now`,
    /// return the request so the SoC can apply the copy.
    pub fn take_completed(&mut self, now: u64) -> Option<DmaRequest> {
        match self.inflight {
            Some((req, finish)) if now >= finish => {
                self.inflight = None;
                self.done = true;
                if self.irq_enabled {
                    self.irq_level = true;
                }
                Some(req)
            }
            _ => None,
        }
    }

    /// Completion time of the in-flight transfer (WFI fast-forward).
    pub fn next_event(&self, now: u64) -> Option<u64> {
        self.inflight.map(|(_, finish)| finish.max(now))
    }

    pub fn irq_pending(&self) -> bool {
        self.irq_level
    }

    /// Guest acknowledges the IRQ by reading STATUS then writing CTRL=0.
    pub fn clear_irq(&mut self) {
        self.irq_level = false;
    }

    pub fn busy(&self) -> bool {
        self.inflight.is_some()
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u32(self.src);
        w.u32(self.dst);
        w.u32(self.len);
        w.bool(self.irq_enabled);
        match self.inflight {
            None => w.bool(false),
            Some((req, finish)) => {
                w.bool(true);
                w.u32(req.src);
                w.u32(req.dst);
                w.u32(req.len);
                w.u64(finish);
            }
        }
        w.bool(self.done);
        w.bool(self.irq_level);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.src = r.u32()?;
        self.dst = r.u32()?;
        self.len = r.u32()?;
        self.irq_enabled = r.bool()?;
        self.inflight = if r.bool()? {
            let req = DmaRequest { src: r.u32()?, dst: r.u32()?, len: r.u32()? };
            Some((req, r.u64()?))
        } else {
            None
        };
        self.done = r.bool()?;
        self.irq_level = r.bool()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_lifecycle() {
        let mut d = Dma::new();
        d.write(regs::SRC, 0x100, 0);
        d.write(regs::DST, 0x200, 0);
        d.write(regs::LEN, 16, 0);
        d.write(regs::CTRL, 0b11, 1000);
        assert!(d.busy());
        assert_eq!(d.read(regs::STATUS), 0b10);
        let finish = 1000 + SETUP_CYCLES + 4 * CYCLES_PER_WORD;
        assert_eq!(d.next_event(1000), Some(finish));
        assert!(d.take_completed(finish - 1).is_none());
        let req = d.take_completed(finish).unwrap();
        assert_eq!(req, DmaRequest { src: 0x100, dst: 0x200, len: 16 });
        assert!(d.irq_pending());
        assert_eq!(d.read(regs::STATUS), 0b01);
        d.clear_irq();
        assert!(!d.irq_pending());
    }

    #[test]
    fn start_while_busy_ignored() {
        let mut d = Dma::new();
        d.write(regs::LEN, 4, 0);
        d.write(regs::CTRL, 1, 0);
        let first = d.next_event(0).unwrap();
        d.write(regs::SRC, 0x999, 1);
        d.write(regs::CTRL, 1, 1); // ignored: busy
        assert_eq!(d.next_event(1), Some(first));
    }

    #[test]
    fn no_irq_when_disabled() {
        let mut d = Dma::new();
        d.write(regs::LEN, 4, 0);
        d.write(regs::CTRL, 1, 0); // start without irq enable
        let f = d.next_event(0).unwrap();
        d.take_completed(f).unwrap();
        assert!(!d.irq_pending());
        assert_eq!(d.read(regs::STATUS) & 1, 1);
    }
}
