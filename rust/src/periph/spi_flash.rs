//! SPI flash: virtualized (DRAM-backed, fast) or physical (SPI-timed,
//! slow) non-volatile storage.
//!
//! Paper §IV-B: flash virtualization connects a second SPI-AXI bridge to
//! PS DRAM, supporting reads **and** writes at bridge speed. Case study
//! §V-C quantifies the payoff: a 70 KiB window transfers in ~10 ms
//! virtualized vs ~2.5 s over a physical SPI flash — the `FlashTiming`
//! models both so the Case C bench can reproduce the ~250x ratio.
//!
//! Programming model: the guest writes the word address to `ADDR`, then
//! reads/writes `DATA` with post-increment. Each `DATA` access costs the
//! timing model's per-word cycles (returned to the bus as wait states).

/// Register offsets within the SPI-flash window.
pub mod regs {
    pub const CTRL: u32 = 0x00; // R/W: bit0 enable
    pub const STATUS: u32 = 0x04; // R: bit0 ready (always, costs are wait-states)
    pub const ADDR: u32 = 0x08; // R/W: current byte address (word aligned)
    pub const DATA: u32 = 0x0C; // R/W: read/write word at ADDR, ADDR += 4
    pub const SIZE: u32 = 0x10; // R: device size in bytes
}

/// Access-cost model for one 32-bit word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlashTiming {
    /// Cycles per 32-bit word access.
    pub cycles_per_word: u32,
    /// One-time command/setup cost applied when ADDR is (re)written —
    /// models the SPI command + address phase of a physical flash.
    pub setup_cycles: u32,
}

impl FlashTiming {
    /// Virtualized flash: SPI-AXI bridge into PS DRAM. Costs are AXI
    /// bridge latency only. Calibrated so one 70 KiB window transfer —
    /// including the ~7-cycle/word guest driver loop — lands at the
    /// paper's ≈10 ms at 20 MHz (§V-C): 17 500 words x (4 + 7) cycles
    /// ≈ 9.6 ms.
    pub fn virtualized() -> Self {
        Self { cycles_per_word: 4, setup_cycles: 20 }
    }

    /// Physical SPI flash at the case-study operating point. Calibrated so
    /// a 70 KiB window ≈ 2.5 s at 20 MHz: 17 500 words in 50 M cycles
    /// ≈ 2857 cycles/word (SPI clock + flash array latency + command
    /// overhead amortized per word).
    pub fn physical() -> Self {
        Self { cycles_per_word: 2857, setup_cycles: 4000 }
    }
}

#[derive(Clone, Debug)]
pub struct SpiFlash {
    mem: Vec<u8>,
    addr: u32,
    enabled: bool,
    timing: FlashTiming,
    /// Total wait-state cycles charged (observability for benches).
    busy_cycles: u64,
    /// Words transferred (observability).
    words: u64,
    /// False while the array is provably all-erased (0xFF) — never
    /// written since construction or the last restore-to-pristine. Lets
    /// snapshot save/restore skip scanning/resetting the whole array.
    touched: bool,
}

impl SpiFlash {
    pub fn new(size: usize, timing: FlashTiming) -> Self {
        assert!(size % 4 == 0);
        Self {
            mem: vec![0xFF; size],
            addr: 0,
            enabled: true,
            timing,
            busy_cycles: 0,
            words: 0,
            touched: false,
        }
    }

    pub fn timing(&self) -> FlashTiming {
        self.timing
    }

    pub fn set_timing(&mut self, t: FlashTiming) {
        self.timing = t;
    }

    pub fn busy_cycles(&self) -> u64 {
        self.busy_cycles
    }

    pub fn words_transferred(&self) -> u64 {
        self.words
    }

    pub fn size(&self) -> usize {
        self.mem.len()
    }

    /// Guest read. Returns (value, wait_cycles).
    pub fn read(&mut self, offset: u32) -> (u32, u32) {
        match offset {
            regs::CTRL => (self.enabled as u32, 0),
            regs::STATUS => (1, 0),
            regs::ADDR => (self.addr, 0),
            regs::SIZE => (self.mem.len() as u32, 0),
            regs::DATA => {
                let a = self.addr as usize;
                let v = if a + 4 <= self.mem.len() {
                    u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap())
                } else {
                    0xFFFF_FFFF // reads past the end: erased pattern
                };
                self.addr = self.addr.wrapping_add(4);
                self.busy_cycles += self.timing.cycles_per_word as u64;
                self.words += 1;
                (v, self.timing.cycles_per_word)
            }
            _ => (0, 0),
        }
    }

    /// Guest write. Returns wait_cycles.
    pub fn write(&mut self, offset: u32, value: u32) -> u32 {
        match offset {
            regs::CTRL => {
                self.enabled = value & 1 != 0;
                0
            }
            regs::ADDR => {
                self.addr = value & !3;
                self.busy_cycles += self.timing.setup_cycles as u64;
                self.timing.setup_cycles
            }
            regs::DATA => {
                let a = self.addr as usize;
                if a + 4 <= self.mem.len() {
                    self.touched = true;
                    self.mem[a..a + 4].copy_from_slice(&value.to_le_bytes());
                }
                self.addr = self.addr.wrapping_add(4);
                self.busy_cycles += self.timing.cycles_per_word as u64;
                self.words += 1;
                self.timing.cycles_per_word
            }
            _ => 0,
        }
    }

    // ---- CS-side dataset access (virt::flash) ---------------------------

    /// CS loads a dataset into flash (no guest-visible cost — in the real
    /// platform the PS writes its own DRAM).
    pub fn load(&mut self, addr: usize, bytes: &[u8]) {
        let end = (addr + bytes.len()).min(self.mem.len());
        self.touched = true;
        self.mem[addr..end].copy_from_slice(&bytes[..end - addr]);
    }

    /// CS reads back data (e.g. results the guest logged to flash).
    pub fn dump(&self, addr: usize, len: usize) -> &[u8] {
        &self.mem[addr..(addr + len).min(self.mem.len())]
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.bool(self.enabled);
        w.u32(self.addr);
        w.u32(self.timing.cycles_per_word);
        w.u32(self.timing.setup_cycles);
        w.u64(self.busy_cycles);
        w.u64(self.words);
        w.bool(self.touched);
        if self.touched {
            w.filled_bytes(&self.mem, 0xFF);
        } else {
            w.filled_bytes_clean(self.mem.len());
        }
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.enabled = r.bool()?;
        self.addr = r.u32()?;
        self.timing.cycles_per_word = r.u32()?;
        self.timing.setup_cycles = r.u32()?;
        self.busy_cycles = r.u64()?;
        self.words = r.u64()?;
        let snap_touched = r.bool()?;
        r.filled_bytes_into(&mut self.mem, 0xFF, !self.touched)?;
        self.touched = snap_touched;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_read_with_autoincrement() {
        let mut f = SpiFlash::new(64, FlashTiming::virtualized());
        f.load(0, &[1, 0, 0, 0, 2, 0, 0, 0]);
        f.write(regs::ADDR, 0);
        let (v0, c0) = f.read(regs::DATA);
        let (v1, _) = f.read(regs::DATA);
        assert_eq!((v0, v1), (1, 2));
        assert_eq!(c0, 4);
        assert_eq!(f.read(regs::ADDR).0, 8);
    }

    #[test]
    fn write_then_read_back() {
        let mut f = SpiFlash::new(64, FlashTiming::virtualized());
        f.write(regs::ADDR, 16);
        f.write(regs::DATA, 0xCAFE_F00D);
        f.write(regs::ADDR, 16);
        assert_eq!(f.read(regs::DATA).0, 0xCAFE_F00D);
        assert_eq!(f.dump(16, 4), &0xCAFE_F00Du32.to_le_bytes());
    }

    #[test]
    fn physical_timing_is_much_slower() {
        let virt = FlashTiming::virtualized();
        let phys = FlashTiming::physical();
        // inclusive of the ~7-cycle driver loop, the window ratio is the
        // paper's ~250x; the raw device-cost ratio is much larger
        let ratio = (phys.cycles_per_word as f64 + 7.0) / (virt.cycles_per_word as f64 + 7.0);
        assert!(ratio > 200.0 && ratio < 300.0, "ratio {ratio}");
    }

    #[test]
    fn case_c_window_costs_match_paper_scale() {
        // 35000 16-bit samples = 70 KiB = 17500 words
        let words = 17_500u64;
        let virt = FlashTiming::virtualized();
        let phys = FlashTiming::physical();
        let freq = 20_000_000f64;
        // +7 cycles/word of guest driver loop (lw/addi/bnez)
        let t_virt = (words * (virt.cycles_per_word as u64 + 7)) as f64 / freq;
        let t_phys = (words * (phys.cycles_per_word as u64 + 7)) as f64 / freq;
        assert!((t_virt - 0.010).abs() < 0.005, "virt window {t_virt}s");
        assert!((t_phys - 2.5).abs() < 0.3, "phys window {t_phys}s");
    }

    #[test]
    fn reads_past_end_return_erased() {
        let mut f = SpiFlash::new(8, FlashTiming::virtualized());
        f.write(regs::ADDR, 8);
        assert_eq!(f.read(regs::DATA).0, 0xFFFF_FFFF);
    }

    #[test]
    fn busy_cycles_accumulate() {
        let mut f = SpiFlash::new(64, FlashTiming::physical());
        f.write(regs::ADDR, 0);
        f.read(regs::DATA);
        f.read(regs::DATA);
        assert_eq!(f.busy_cycles(), 4000 + 2 * 2857);
        assert_eq!(f.words_transferred(), 2);
    }
}
