//! Power-control block: guest-visible knobs for domain power states.
//!
//! Mirrors X-HEEP's power manager: the guest (or the CS, via the same
//! registers) can gate individual memory banks, park the CGRA, and choose
//! the sleep policy applied to memories while the CPU sits in WFI. The
//! perf monitor observes the resulting domain-state transitions and the
//! energy model prices them (§IV-C/D).

use crate::perfmon::PowerState;

/// Register offsets within the power-control window.
pub mod regs {
    /// R/W: sleep policy for memory banks during WFI:
    /// 0 = stay active, 1 = clock-gate, 2 = retention.
    pub const SLEEP_MEM_MODE: u32 = 0x00;
    /// R/W: CGRA domain state (0 active, 1 clock-gated, 2 power-gated).
    pub const CGRA_STATE: u32 = 0x04;
    /// R/W base: per-bank explicit state (0 active, 1 clock-gated,
    /// 2 power-gated, 3 retention); bank i at `BANK_STATE + 4*i`.
    pub const BANK_STATE: u32 = 0x40;
}

/// Sleep policy for memory banks while the CPU is in WFI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SleepMemMode {
    Active,
    ClockGated,
    Retention,
}

impl SleepMemMode {
    pub fn as_power_state(self) -> PowerState {
        match self {
            SleepMemMode::Active => PowerState::Active,
            SleepMemMode::ClockGated => PowerState::ClockGated,
            SleepMemMode::Retention => PowerState::Retention,
        }
    }
}

fn decode_state(v: u32) -> PowerState {
    match v & 3 {
        0 => PowerState::Active,
        1 => PowerState::ClockGated,
        2 => PowerState::PowerGated,
        _ => PowerState::Retention,
    }
}

fn encode_state(s: PowerState) -> u32 {
    match s {
        PowerState::Active => 0,
        PowerState::ClockGated => 1,
        PowerState::PowerGated => 2,
        PowerState::Retention => 3,
    }
}

/// A request the SoC applies after the store completes (bank/CGRA state
/// changes go through the SoC so the perf monitor sees them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PowerRequest {
    Bank(usize, PowerState),
    Cgra(PowerState),
}

#[derive(Clone, Debug)]
pub struct PowerCtrl {
    sleep_mem_mode: SleepMemMode,
    bank_states: Vec<PowerState>,
    cgra_state: PowerState,
    pending: Vec<PowerRequest>,
}

impl PowerCtrl {
    pub fn new(num_banks: usize) -> Self {
        Self {
            sleep_mem_mode: SleepMemMode::Active,
            bank_states: vec![PowerState::Active; num_banks],
            cgra_state: PowerState::PowerGated,
            pending: Vec::new(),
        }
    }

    pub fn sleep_mem_mode(&self) -> SleepMemMode {
        self.sleep_mem_mode
    }

    pub fn bank_state(&self, i: usize) -> PowerState {
        self.bank_states[i]
    }

    pub fn cgra_state(&self) -> PowerState {
        self.cgra_state
    }

    pub fn read(&self, offset: u32) -> u32 {
        match offset {
            regs::SLEEP_MEM_MODE => match self.sleep_mem_mode {
                SleepMemMode::Active => 0,
                SleepMemMode::ClockGated => 1,
                SleepMemMode::Retention => 2,
            },
            regs::CGRA_STATE => encode_state(self.cgra_state),
            o if o >= regs::BANK_STATE => {
                let i = ((o - regs::BANK_STATE) / 4) as usize;
                self.bank_states.get(i).map(|s| encode_state(*s)).unwrap_or(0)
            }
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        match offset {
            regs::SLEEP_MEM_MODE => {
                self.sleep_mem_mode = match value & 3 {
                    0 => SleepMemMode::Active,
                    1 => SleepMemMode::ClockGated,
                    _ => SleepMemMode::Retention,
                };
            }
            regs::CGRA_STATE => {
                let s = decode_state(value);
                self.cgra_state = s;
                self.pending.push(PowerRequest::Cgra(s));
            }
            o if o >= regs::BANK_STATE => {
                let i = ((o - regs::BANK_STATE) / 4) as usize;
                if i < self.bank_states.len() {
                    let s = decode_state(value);
                    self.bank_states[i] = s;
                    self.pending.push(PowerRequest::Bank(i, s));
                }
            }
            _ => {}
        }
    }

    /// SoC consumes state-change requests after each store.
    pub fn take_requests(&mut self) -> Vec<PowerRequest> {
        std::mem::take(&mut self.pending)
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u8(match self.sleep_mem_mode {
            SleepMemMode::Active => 0,
            SleepMemMode::ClockGated => 1,
            SleepMemMode::Retention => 2,
        });
        w.u32(self.bank_states.len() as u32);
        for s in &self.bank_states {
            w.u8(s.to_u8());
        }
        w.u8(self.cgra_state.to_u8());
        w.u32(self.pending.len() as u32);
        for req in &self.pending {
            match req {
                PowerRequest::Bank(i, s) => {
                    w.u8(0);
                    w.u32(*i as u32);
                    w.u8(s.to_u8());
                }
                PowerRequest::Cgra(s) => {
                    w.u8(1);
                    w.u8(s.to_u8());
                }
            }
        }
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.sleep_mem_mode = match r.u8()? {
            0 => SleepMemMode::Active,
            1 => SleepMemMode::ClockGated,
            2 => SleepMemMode::Retention,
            other => anyhow::bail!("snapshot corrupt: sleep-mem-mode tag {other}"),
        };
        let n = r.u32()? as usize;
        if n != self.bank_states.len() {
            anyhow::bail!(
                "snapshot has {n} power-ctrl bank states, platform has {}",
                self.bank_states.len()
            );
        }
        for s in &mut self.bank_states {
            *s = PowerState::from_u8(r.u8()?)?;
        }
        self.cgra_state = PowerState::from_u8(r.u8()?)?;
        let pending = r.u32()? as usize;
        self.pending.clear();
        for _ in 0..pending {
            self.pending.push(match r.u8()? {
                0 => {
                    let i = r.u32()? as usize;
                    PowerRequest::Bank(i, PowerState::from_u8(r.u8()?)?)
                }
                1 => PowerRequest::Cgra(PowerState::from_u8(r.u8()?)?),
                other => anyhow::bail!("snapshot corrupt: power-request tag {other}"),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_state_requests() {
        let mut p = PowerCtrl::new(2);
        p.write(regs::BANK_STATE + 4, 3); // bank1 -> retention
        assert_eq!(p.bank_state(1), PowerState::Retention);
        assert_eq!(p.take_requests(), vec![PowerRequest::Bank(1, PowerState::Retention)]);
        assert!(p.take_requests().is_empty());
    }

    #[test]
    fn out_of_range_bank_ignored() {
        let mut p = PowerCtrl::new(1);
        p.write(regs::BANK_STATE + 4 * 9, 2);
        assert!(p.take_requests().is_empty());
    }

    #[test]
    fn sleep_mode_roundtrip() {
        let mut p = PowerCtrl::new(1);
        p.write(regs::SLEEP_MEM_MODE, 2);
        assert_eq!(p.sleep_mem_mode(), SleepMemMode::Retention);
        assert_eq!(p.read(regs::SLEEP_MEM_MODE), 2);
        assert_eq!(p.sleep_mem_mode().as_power_state(), PowerState::Retention);
    }

    #[test]
    fn cgra_wakeup() {
        let mut p = PowerCtrl::new(1);
        assert_eq!(p.cgra_state(), PowerState::PowerGated);
        p.write(regs::CGRA_STATE, 0);
        assert_eq!(p.cgra_state(), PowerState::Active);
        assert_eq!(p.take_requests(), vec![PowerRequest::Cgra(PowerState::Active)]);
    }
}
