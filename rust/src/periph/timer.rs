//! Machine timer (CLINT-style mtime/mtimecmp).
//!
//! Lives in the always-on domain; it is the wake-up source for the
//! acquisition workloads' sleep phases. `mtime` mirrors the global cycle
//! counter; when `mtime >= mtimecmp` and the interrupt is enabled, the
//! machine-timer interrupt (MTIP) is asserted until the guest rewrites
//! `mtimecmp`.

/// Register offsets within the timer window.
pub mod regs {
    pub const MTIME_LO: u32 = 0x00; // R
    pub const MTIME_HI: u32 = 0x04; // R
    pub const MTIMECMP_LO: u32 = 0x08; // R/W
    pub const MTIMECMP_HI: u32 = 0x0C; // R/W
    pub const CTRL: u32 = 0x10; // R/W: bit0 = irq enable
}

#[derive(Clone, Debug)]
pub struct Timer {
    mtimecmp: u64,
    irq_enable: bool,
}

impl Default for Timer {
    fn default() -> Self {
        Self { mtimecmp: u64::MAX, irq_enable: false }
    }
}

impl Timer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(&self, offset: u32, now: u64) -> u32 {
        match offset {
            regs::MTIME_LO => now as u32,
            regs::MTIME_HI => (now >> 32) as u32,
            regs::MTIMECMP_LO => self.mtimecmp as u32,
            regs::MTIMECMP_HI => (self.mtimecmp >> 32) as u32,
            regs::CTRL => self.irq_enable as u32,
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        match offset {
            regs::MTIMECMP_LO => {
                self.mtimecmp = (self.mtimecmp & 0xFFFF_FFFF_0000_0000) | value as u64;
            }
            regs::MTIMECMP_HI => {
                self.mtimecmp = (self.mtimecmp & 0xFFFF_FFFF) | ((value as u64) << 32);
            }
            regs::CTRL => self.irq_enable = value & 1 != 0,
            _ => {}
        }
    }

    /// MTIP level at cycle `now`.
    pub fn irq_pending(&self, now: u64) -> bool {
        self.irq_enable && now >= self.mtimecmp
    }

    /// Next cycle at which this timer changes state (for WFI
    /// fast-forwarding), if any.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        if self.irq_enable && now < self.mtimecmp {
            Some(self.mtimecmp)
        } else {
            None
        }
    }

    pub fn mtimecmp(&self) -> u64 {
        self.mtimecmp
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u64(self.mtimecmp);
        w.bool(self.irq_enable);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.mtimecmp = r.u64()?;
        self.irq_enable = r.bool()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mtime_reflects_cycle_counter() {
        let t = Timer::new();
        assert_eq!(t.read(regs::MTIME_LO, 0x1_0000_0002), 2);
        assert_eq!(t.read(regs::MTIME_HI, 0x1_0000_0002), 1);
    }

    #[test]
    fn cmp_write_and_irq() {
        let mut t = Timer::new();
        t.write(regs::MTIMECMP_LO, 100);
        t.write(regs::MTIMECMP_HI, 0);
        assert!(!t.irq_pending(50)); // irq not enabled yet
        t.write(regs::CTRL, 1);
        assert!(!t.irq_pending(50));
        assert!(t.irq_pending(100));
        assert!(t.irq_pending(150));
        assert_eq!(t.next_event(50), Some(100));
        assert_eq!(t.next_event(100), None); // already fired
    }

    #[test]
    fn disabled_timer_has_no_event() {
        let t = Timer::new();
        assert_eq!(t.next_event(0), None);
        assert!(!t.irq_pending(u64::MAX - 1));
    }

    #[test]
    fn rewriting_cmp_clears_irq() {
        let mut t = Timer::new();
        t.write(regs::CTRL, 1);
        t.write(regs::MTIMECMP_LO, 10);
        t.write(regs::MTIMECMP_HI, 0);
        assert!(t.irq_pending(20));
        t.write(regs::MTIMECMP_LO, 100);
        assert!(!t.irq_pending(20));
    }
}
