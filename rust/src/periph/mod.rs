//! Memory-mapped peripherals of the emulated X-HEEP host.
//!
//! Register blocks live at [`crate::bus::PERIPH_BASE`], one 256-byte
//! window each (offsets in [`regs`]). The set mirrors what X-HEEP-FEMU
//! wires up (§IV-B): UART (logging), GPIO (perf-monitor manual mode),
//! machine timer, the two SPI-AXI bridges (virtual ADC and virtual/
//! physical flash), a DMA engine, and the power-control block, plus the
//! CGRA control port and the CS mailbox doorbell.

pub mod dma;
pub mod gpio;
pub mod power;
pub mod spi_adc;
pub mod spi_flash;
pub mod timer;
pub mod uart;

pub use dma::Dma;
pub use gpio::Gpio;
pub use power::PowerCtrl;
pub use spi_adc::SpiAdc;
pub use spi_flash::{FlashTiming, SpiFlash};
pub use timer::Timer;
pub use uart::Uart;

/// Peripheral register offsets relative to each device's 0x100 window.
/// Device windows (offsets from `PERIPH_BASE`):
pub mod map {
    pub const UART: u32 = 0x000;
    pub const GPIO: u32 = 0x100;
    pub const TIMER: u32 = 0x200;
    pub const SPI_ADC: u32 = 0x300;
    pub const SPI_FLASH: u32 = 0x400;
    pub const DMA: u32 = 0x500;
    pub const POWER: u32 = 0x600;
    pub const CGRA: u32 = 0x700;
    pub const MAILBOX: u32 = 0x800;
    /// Size of one device window.
    pub const WINDOW: u32 = 0x100;
    /// Total peripheral region size.
    pub const REGION: u32 = 0x1000;
}

/// Interrupt line numbers (bit indices in the machine external interrupt
/// pending word; see [`crate::cpu`]).
pub mod irq {
    pub const TIMER: u32 = 0; // machine timer (MTIP, modeled separately)
    pub const ADC: u32 = 1; // ADC sample ready
    pub const DMA: u32 = 2; // DMA transfer complete
    pub const CGRA: u32 = 3; // CGRA kernel done
    pub const MAILBOX: u32 = 4; // CS completion doorbell
}
