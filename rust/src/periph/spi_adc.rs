//! SPI-ADC bridge: the guest-visible half of ADC virtualization.
//!
//! Paper §IV-B: an SPI-to-AXI bridge in the PL translates the guest's SPI
//! reads into AXI reads of a hardware FIFO, which a PS-side software FIFO
//! keeps topped up from storage — the dual circular-buffer mechanism that
//! paces pre-recorded samples at the configured sampling rate.
//!
//! Model: sample `k` becomes available exactly at
//! `start_cycle + k * period_cycles` (the HW FIFO guarantees availability
//! at the nominal rate). The device holds a bounded FIFO chunk; when it
//! runs low it raises a refill request the CS ADC service
//! ([`crate::virt::adc`]) answers between run slices. If the CS fails to
//! refill in time an **underrun** is latched — the ablation bench uses
//! this to show why the dual-FIFO pacing matters.

use std::collections::VecDeque;

/// Register offsets within the SPI-ADC window.
pub mod regs {
    pub const CTRL: u32 = 0x00; // R/W: bit0 enable, bit1 irq enable
    pub const STATUS: u32 = 0x04; // R: bit0 sample ready, bit1 underrun, bit2 stream done
    pub const RXDATA: u32 = 0x08; // R: pop next sample (i32)
    pub const PERIOD_LO: u32 = 0x0C; // R: sampling period in cycles (CS-configured)
    pub const PERIOD_HI: u32 = 0x10; // R
    pub const COUNT: u32 = 0x14; // R: samples consumed so far
}

/// Cycles one 32-bit SPI sample transfer occupies the core (SPI clock at
/// 1/6.4 of the 20 MHz core clock: 32 bits ≈ 128 core cycles, visible as
/// wait states on the RXDATA read — this is what makes the acquisition
/// active phase dominate at 100 kHz, the right side of Fig 4).
pub const WORD_CYCLES: u32 = 128;

/// Capacity of the modeled hardware FIFO (samples).
pub const HW_FIFO_DEPTH: usize = 256;
/// Refill request threshold: below this the device asks the CS for more.
pub const REFILL_THRESHOLD: usize = 64;

#[derive(Clone, Debug)]
pub struct SpiAdc {
    enabled: bool,
    irq_enabled: bool,
    /// HW FIFO contents (filled by the CS service in chunks).
    fifo: VecDeque<i32>,
    /// Cycle at which streaming started.
    start_cycle: u64,
    /// Sampling period in CPU cycles (cpu_freq / sample_rate).
    period_cycles: u64,
    /// Samples consumed by the guest so far.
    consumed: u64,
    /// Total samples the CS intends to stream (0 = not configured).
    total: u64,
    /// Samples pushed by the CS so far.
    pushed: u64,
    underrun: bool,
}

impl Default for SpiAdc {
    fn default() -> Self {
        Self {
            enabled: false,
            irq_enabled: false,
            fifo: VecDeque::new(),
            start_cycle: 0,
            period_cycles: 1,
            consumed: 0,
            total: 0,
            pushed: 0,
            underrun: false,
        }
    }
}

impl SpiAdc {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- CS-side configuration (virt::adc) -----------------------------

    /// Configure a stream of `total` samples at `period_cycles`, starting
    /// at cycle `now`. Clears any previous stream.
    pub fn configure_stream(&mut self, total: u64, period_cycles: u64, now: u64) {
        assert!(period_cycles > 0, "period must be positive");
        self.fifo.clear();
        self.start_cycle = now;
        self.period_cycles = period_cycles;
        self.consumed = 0;
        self.total = total;
        self.pushed = 0;
        self.underrun = false;
    }

    /// CS pushes a chunk of samples into the HW FIFO. Returns how many
    /// were accepted (FIFO capacity permitting).
    pub fn refill(&mut self, samples: &[i32]) -> usize {
        let space = HW_FIFO_DEPTH - self.fifo.len();
        let n = space.min(samples.len()).min((self.total - self.pushed) as usize);
        self.fifo.extend(samples[..n].iter().copied());
        self.pushed += n as u64;
        n
    }

    /// True when the CS should push more samples.
    pub fn wants_refill(&self) -> bool {
        self.pushed < self.total && self.fifo.len() < REFILL_THRESHOLD
    }

    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    // ---- guest-visible behavior ----------------------------------------

    /// Number of samples whose nominal arrival time has passed.
    fn available_by_schedule(&self, now: u64) -> u64 {
        if !self.enabled || self.total == 0 || now < self.start_cycle {
            return 0;
        }
        let elapsed = now - self.start_cycle;
        (elapsed / self.period_cycles + 1).min(self.total)
    }

    /// Sample ready = schedule says one is due AND the FIFO actually has
    /// it (otherwise underrun).
    fn ready(&self, now: u64) -> bool {
        self.consumed < self.available_by_schedule(now) && !self.fifo.is_empty()
    }

    pub fn read(&mut self, offset: u32, now: u64) -> u32 {
        match offset {
            regs::CTRL => (self.enabled as u32) | ((self.irq_enabled as u32) << 1),
            regs::STATUS => {
                let mut s = 0;
                if self.ready(now) {
                    s |= 1;
                }
                if self.underrun {
                    s |= 2;
                }
                if self.consumed >= self.total && self.total > 0 {
                    s |= 4;
                }
                s
            }
            regs::RXDATA => {
                if self.consumed < self.available_by_schedule(now) {
                    match self.fifo.pop_front() {
                        Some(v) => {
                            self.consumed += 1;
                            v as u32
                        }
                        None => {
                            // schedule says ready but CS failed to refill
                            self.underrun = true;
                            0
                        }
                    }
                } else {
                    // read before the sample's nominal time: underrun-style
                    // protocol violation, latched for the CS to see
                    self.underrun = true;
                    0
                }
            }
            regs::PERIOD_LO => self.period_cycles as u32,
            regs::PERIOD_HI => (self.period_cycles >> 32) as u32,
            regs::COUNT => self.consumed as u32,
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        if offset == regs::CTRL {
            self.enabled = value & 1 != 0;
            self.irq_enabled = value & 2 != 0;
        }
    }

    /// Sample-ready interrupt level.
    pub fn irq_pending(&self, now: u64) -> bool {
        self.irq_enabled && self.ready(now)
    }

    /// Next cycle at which a new sample becomes due (WFI fast-forward).
    /// A starved (underrun) stream has no future events — the SoC reports
    /// the guest as dead-sleeping rather than spinning.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        if !self.enabled || self.total == 0 || self.consumed >= self.total || self.underrun {
            return None;
        }
        let avail = self.available_by_schedule(now);
        if self.consumed < avail {
            if self.fifo.is_empty() {
                // due but no data: the CS failed the pacing contract
                return None;
            }
            return Some(now); // already due
        }
        // next sample index = avail, due at start + avail*period
        Some(self.start_cycle + avail * self.period_cycles)
    }

    /// Time-advance hook (SoC post-step): a sample whose nominal time has
    /// passed while the FIFO is empty latches the underrun flag — the
    /// hardware FIFO missed its deadline.
    pub fn tick(&mut self, now: u64) {
        if self.enabled
            && !self.underrun
            && self.consumed < self.available_by_schedule(now)
            && self.fifo.is_empty()
            && self.total > 0
        {
            self.underrun = true;
        }
    }

    pub fn underrun(&self) -> bool {
        self.underrun
    }

    pub fn consumed(&self) -> u64 {
        self.consumed
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.bool(self.enabled);
        w.bool(self.irq_enabled);
        w.u32(self.fifo.len() as u32);
        for &s in &self.fifo {
            w.i32(s);
        }
        w.u64(self.start_cycle);
        w.u64(self.period_cycles);
        w.u64(self.consumed);
        w.u64(self.total);
        w.u64(self.pushed);
        w.bool(self.underrun);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.enabled = r.bool()?;
        self.irq_enabled = r.bool()?;
        let n = r.u32()? as usize;
        self.fifo.clear();
        for _ in 0..n {
            self.fifo.push_back(r.i32()?);
        }
        self.start_cycle = r.u64()?;
        self.period_cycles = r.u64()?;
        if self.period_cycles == 0 {
            anyhow::bail!("snapshot corrupt: zero ADC period");
        }
        self.consumed = r.u64()?;
        self.total = r.u64()?;
        self.pushed = r.u64()?;
        self.underrun = r.bool()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(total: u64, period: u64) -> SpiAdc {
        let mut a = SpiAdc::new();
        a.configure_stream(total, period, 0);
        let chunk: Vec<i32> = (0..total.min(HW_FIFO_DEPTH as u64) as i32).collect();
        a.refill(&chunk);
        a.write(regs::CTRL, 0b11); // enable + irq
        a
    }

    #[test]
    fn samples_paced_by_schedule() {
        let mut a = setup(4, 100);
        // t=0: sample 0 due immediately
        assert_eq!(a.read(regs::STATUS, 0) & 1, 1);
        assert_eq!(a.read(regs::RXDATA, 0), 0);
        // sample 1 not due until t=100
        assert_eq!(a.read(regs::STATUS, 50) & 1, 0);
        assert_eq!(a.next_event(50), Some(100));
        assert_eq!(a.read(regs::STATUS, 100) & 1, 1);
        assert_eq!(a.read(regs::RXDATA, 100) as i32, 1);
    }

    #[test]
    fn early_read_latches_underrun() {
        let mut a = setup(4, 100);
        let _ = a.read(regs::RXDATA, 0);
        let _ = a.read(regs::RXDATA, 10); // too early
        assert!(a.underrun());
        assert_eq!(a.read(regs::STATUS, 10) & 2, 2);
    }

    #[test]
    fn stream_done_flag() {
        let mut a = setup(2, 10);
        let _ = a.read(regs::RXDATA, 0);
        let _ = a.read(regs::RXDATA, 10);
        assert_eq!(a.read(regs::STATUS, 20) & 4, 4);
        assert_eq!(a.next_event(20), None);
    }

    #[test]
    fn refill_protocol() {
        let mut a = SpiAdc::new();
        a.configure_stream(1000, 10, 0);
        a.write(regs::CTRL, 1);
        assert!(a.wants_refill());
        let chunk: Vec<i32> = (0..HW_FIFO_DEPTH as i32).collect();
        assert_eq!(a.refill(&chunk), HW_FIFO_DEPTH);
        assert!(!a.wants_refill());
        // consume until below threshold
        for k in 0..(HW_FIFO_DEPTH - REFILL_THRESHOLD + 1) as u64 {
            let _ = a.read(regs::RXDATA, k * 10);
        }
        assert!(a.wants_refill());
    }

    #[test]
    fn empty_fifo_with_due_sample_is_underrun() {
        let mut a = SpiAdc::new();
        a.configure_stream(10, 10, 0);
        a.write(regs::CTRL, 1);
        // no refill happened
        let _ = a.read(regs::RXDATA, 0);
        assert!(a.underrun());
    }

    #[test]
    fn irq_follows_ready() {
        let mut a = setup(2, 100);
        assert!(a.irq_pending(0));
        let _ = a.read(regs::RXDATA, 0);
        assert!(!a.irq_pending(1));
        assert!(a.irq_pending(100));
    }
}
