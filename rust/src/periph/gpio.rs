//! GPIO block.
//!
//! Two paper-relevant uses: (1) the perf-monitor **manual mode** — the
//! guest toggles a dedicated GPIO bit around a region of interest to
//! open/close a measurement window (§IV-C); (2) general pin I/O the CS can
//! observe/drive (the JTAG pins of the real platform are virtualized at a
//! higher level by [`crate::virt::debugger`], so they do not appear here).

/// Register offsets within the GPIO window.
pub mod regs {
    pub const OUT: u32 = 0x00; // R/W: output pins
    pub const IN: u32 = 0x04; // R: input pins (driven by CS)
    pub const DIR: u32 = 0x08; // R/W: 1 = output (bookkeeping only)
}

/// Output bit reserved for the perf-monitor manual start/stop signal.
pub const PERF_GPIO_BIT: u32 = 16;

/// Edge events the SoC consumes after each guest write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GpioEvent {
    PerfWindowOpen,
    PerfWindowClose,
}

#[derive(Clone, Debug, Default)]
pub struct Gpio {
    out: u32,
    input: u32,
    dir: u32,
    pending: Vec<GpioEvent>,
}

impl Gpio {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(&self, offset: u32) -> u32 {
        match offset {
            regs::OUT => self.out,
            regs::IN => self.input,
            regs::DIR => self.dir,
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        match offset {
            regs::OUT => {
                let old = self.out;
                self.out = value;
                let perf_mask = 1 << PERF_GPIO_BIT;
                if old & perf_mask == 0 && value & perf_mask != 0 {
                    self.pending.push(GpioEvent::PerfWindowOpen);
                } else if old & perf_mask != 0 && value & perf_mask == 0 {
                    self.pending.push(GpioEvent::PerfWindowClose);
                }
            }
            regs::DIR => self.dir = value,
            _ => {}
        }
    }

    /// CS side: drive input pins.
    pub fn set_input(&mut self, value: u32) {
        self.input = value;
    }

    /// CS side: observe outputs.
    pub fn out(&self) -> u32 {
        self.out
    }

    /// SoC consumes pending edge events after each store.
    pub fn take_events(&mut self) -> Vec<GpioEvent> {
        std::mem::take(&mut self.pending)
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u32(self.out);
        w.u32(self.input);
        w.u32(self.dir);
        w.u32(self.pending.len() as u32);
        for ev in &self.pending {
            w.u8(match ev {
                GpioEvent::PerfWindowOpen => 0,
                GpioEvent::PerfWindowClose => 1,
            });
        }
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.out = r.u32()?;
        self.input = r.u32()?;
        self.dir = r.u32()?;
        let n = r.u32()? as usize;
        self.pending.clear();
        for _ in 0..n {
            self.pending.push(match r.u8()? {
                0 => GpioEvent::PerfWindowOpen,
                1 => GpioEvent::PerfWindowClose,
                other => anyhow::bail!("snapshot corrupt: gpio event tag {other}"),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perf_bit_edges_generate_events() {
        let mut g = Gpio::new();
        g.write(regs::OUT, 1 << PERF_GPIO_BIT);
        g.write(regs::OUT, 1 << PERF_GPIO_BIT); // no edge
        g.write(regs::OUT, 0);
        assert_eq!(g.take_events(), vec![GpioEvent::PerfWindowOpen, GpioEvent::PerfWindowClose]);
        assert!(g.take_events().is_empty());
    }

    #[test]
    fn other_bits_do_not_trigger() {
        let mut g = Gpio::new();
        g.write(regs::OUT, 0xFF);
        assert!(g.take_events().is_empty());
        assert_eq!(g.read(regs::OUT), 0xFF);
    }

    #[test]
    fn input_pins_cs_driven() {
        let mut g = Gpio::new();
        g.set_input(0xA5);
        assert_eq!(g.read(regs::IN), 0xA5);
    }
}
