//! RH↔CS bridge: the mailbox doorbell for accelerator virtualization.
//!
//! Paper §IV-B: "X-HEEP writes configuration parameters and input data to
//! predefined DRAM regions through an OBI-AXI bridge ... the accelerator
//! software model running on the PS monitors these memory regions,
//! executes the required computations, and writes the results back."
//!
//! The data path is the bridge *window* (guest loads/stores at
//! [`crate::bus::BRIDGE_BASE`] reach CS DRAM with AXI-crossing latency).
//! This module is the control path: a doorbell register block. The guest
//! lays out `[kernel_id, n_args, args..]` at the mailbox offset in CS
//! DRAM, rings [`regs::DOORBELL`], and sleeps; the SoC surfaces the ring
//! to the coordinator, the CS service ([`crate::virt::accel`]) executes
//! the AOT artifact via PJRT and schedules completion after the modeled
//! CS turnaround latency, which raises the MAILBOX interrupt.

/// Register offsets within the mailbox window.
pub mod regs {
    pub const DOORBELL: u32 = 0x00; // W: ring (bit0)
    pub const STATUS: u32 = 0x04; // R: bit0 done, bit1 busy
    pub const CTRL: u32 = 0x08; // R/W: bit0 irq enable
    /// R/W: guest-chosen byte offset of the request block within CS DRAM.
    pub const REQ_OFF: u32 = 0x0C;
}

/// Fixed request-block layout (word offsets within the request block in
/// CS DRAM): `[kernel_id, n_args, arg0, arg1, ...]`.
pub const MAX_ARGS: usize = 12;

#[derive(Clone, Debug, Default)]
pub struct Mailbox {
    irq_enabled: bool,
    req_off: u32,
    /// Rung but not yet picked up by the coordinator.
    pending: bool,
    /// Completion time scheduled by the CS service.
    done_at: Option<u64>,
    /// Completed (STATUS.done reads 1 until the next ring).
    done: bool,
    irq_level: bool,
}

impl Mailbox {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(&self, offset: u32, now: u64) -> u32 {
        match offset {
            regs::STATUS => {
                let busy = self.pending || self.done_at.map(|t| now < t).unwrap_or(false);
                (self.done as u32) | ((busy as u32) << 1)
            }
            regs::CTRL => self.irq_enabled as u32,
            regs::REQ_OFF => self.req_off,
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        match offset {
            regs::DOORBELL => {
                if value & 1 != 0 && !self.pending && self.done_at.is_none() {
                    self.pending = true;
                    self.done = false;
                    self.irq_level = false;
                }
            }
            regs::CTRL => self.irq_enabled = value & 1 != 0,
            regs::REQ_OFF => self.req_off = value,
            _ => {}
        }
    }

    /// Coordinator side: take the pending ring (request block offset).
    pub fn take_pending(&mut self) -> Option<u32> {
        if self.pending {
            self.pending = false;
            Some(self.req_off)
        } else {
            None
        }
    }

    /// CS service: schedule completion at `at` (results already written to
    /// CS DRAM — the guest must not read them before STATUS.done).
    pub fn schedule_completion(&mut self, at: u64) {
        self.done_at = Some(at);
    }

    /// SoC tick: fire completion when due.
    pub fn tick(&mut self, now: u64) {
        if let Some(t) = self.done_at {
            if now >= t {
                self.done_at = None;
                self.done = true;
                if self.irq_enabled {
                    self.irq_level = true;
                }
            }
        }
    }

    pub fn irq_pending(&self) -> bool {
        self.irq_level
    }

    pub fn clear_irq(&mut self) {
        self.irq_level = false;
    }

    pub fn next_event(&self, now: u64) -> Option<u64> {
        self.done_at.map(|t| t.max(now))
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.bool(self.irq_enabled);
        w.u32(self.req_off);
        w.bool(self.pending);
        w.opt_u64(self.done_at);
        w.bool(self.done);
        w.bool(self.irq_level);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.irq_enabled = r.bool()?;
        self.req_off = r.u32()?;
        self.pending = r.bool()?;
        self.done_at = r.opt_u64()?;
        self.done = r.bool()?;
        self.irq_level = r.bool()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doorbell_lifecycle() {
        let mut m = Mailbox::new();
        m.write(regs::CTRL, 1);
        m.write(regs::REQ_OFF, 0x8000);
        m.write(regs::DOORBELL, 1);
        assert_eq!(m.read(regs::STATUS, 0), 0b10);
        assert_eq!(m.take_pending(), Some(0x8000));
        assert_eq!(m.take_pending(), None);
        m.schedule_completion(500);
        assert_eq!(m.read(regs::STATUS, 100), 0b10); // busy until 500
        m.tick(499);
        assert!(!m.irq_pending());
        m.tick(500);
        assert!(m.irq_pending());
        assert_eq!(m.read(regs::STATUS, 500), 0b01);
    }

    #[test]
    fn ring_while_busy_ignored() {
        let mut m = Mailbox::new();
        m.write(regs::DOORBELL, 1);
        m.take_pending().unwrap();
        m.schedule_completion(100);
        m.write(regs::DOORBELL, 1); // busy: ignored
        assert_eq!(m.take_pending(), None);
    }

    #[test]
    fn no_irq_when_disabled() {
        let mut m = Mailbox::new();
        m.write(regs::DOORBELL, 1);
        m.take_pending().unwrap();
        m.schedule_completion(10);
        m.tick(10);
        assert!(!m.irq_pending());
        assert_eq!(m.read(regs::STATUS, 10) & 1, 1);
    }
}
