//! Platform snapshot/restore: a versioned, compact binary image of every
//! stateful component of an X-HEEP-FEMU instance.
//!
//! Checkpoint-based forking is the standard trick in FPGA/hybrid
//! emulation (FASE restores pre-validated checkpoints to skip redundant
//! execution; CHESSY synchronizes state across emulation domains). Here
//! it serves three layers:
//!
//! * the experiment fleet boots one golden platform per sweep, snapshots
//!   it after warmup, and restores per point instead of re-booting
//!   ([`crate::coordinator::Fleet::run_sweep_forked`]);
//! * the control server exposes `snapshot.save` / `snapshot.restore` /
//!   `session.fork` so a client can clone a warmed session;
//! * the CLI persists snapshots to disk (`femu snapshot`,
//!   `--from-snapshot`).
//!
//! Format (all integers little-endian):
//!
//! ```text
//! magic "FEMUSNAP" | version u32 | payload_len u64 | fnv1a64(payload) | payload
//! ```
//!
//! The payload starts with a [`SnapshotInfo`] header (platform shape:
//! bank count/size, CS-DRAM and flash sizes, clock) that
//! [`crate::coordinator::Platform::restore`] validates before touching
//! any state, followed by every component's `save_state` output in a
//! fixed order. Large memories use a sparse fill-aware encoding
//! ([`Writer::filled_bytes`]) so a mostly-pristine 16 MiB CS DRAM costs
//! a few bytes, not megabytes.
//!
//! **Not captured** (documented in DESIGN.md §10): the CPU's decode
//! cache (word-tagged, semantically transparent), the perf monitor's
//! optional VCD transition log (cleared on restore), and the PJRT
//! accelerator runtime (`Platform::accel` — process-local handles; the
//! restored platform keeps whatever artifact binding it already has).

use std::fmt;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// File/stream magic.
pub const MAGIC: [u8; 8] = *b"FEMUSNAP";

/// Machine-readable discriminant for the snapshot-load failures tooling
/// needs to tell apart: a corrupt file (checksum), a file from another
/// build (version), and a healthy file for the wrong platform shape.
/// Surfaced over the wire as the `error_kind` response field and as a
/// distinct CLI exit hint — campaign tooling uses it to distinguish
/// corruption from staleness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapErrorKind {
    /// The frame's FNV-1a64 checksum does not match the payload.
    ChecksumMismatch,
    /// The frame was written by a build with a different format version.
    VersionMismatch,
    /// The snapshot is valid but describes a different platform shape
    /// (bank count/size, memory sizes, clock) than the restore target.
    ShapeMismatch,
}

impl SnapErrorKind {
    /// Wire-stable name, used as the `error_kind` response field.
    pub fn name(self) -> &'static str {
        match self {
            SnapErrorKind::ChecksumMismatch => "snapshot_checksum_mismatch",
            SnapErrorKind::VersionMismatch => "snapshot_version_mismatch",
            SnapErrorKind::ShapeMismatch => "snapshot_shape_mismatch",
        }
    }

    /// One-line operator hint printed by the CLI alongside the error.
    pub fn hint(self) -> &'static str {
        match self {
            SnapErrorKind::ChecksumMismatch => {
                "the file is corrupt -- re-copy or re-create the snapshot"
            }
            SnapErrorKind::VersionMismatch => {
                "the file was written by a different build -- re-save it with this femu"
            }
            SnapErrorKind::ShapeMismatch => {
                "the snapshot's platform shape differs from the target config"
            }
        }
    }
}

/// A typed snapshot-load error: a [`SnapErrorKind`] plus the exact
/// human-readable message the untyped path used to produce (the wire and
/// CLI text is byte-identical to previous releases; only the machine
/// discriminant is new).
#[derive(Debug)]
pub struct SnapError {
    pub kind: SnapErrorKind,
    msg: String,
}

impl SnapError {
    pub fn new(kind: SnapErrorKind, msg: impl Into<String>) -> Self {
        Self { kind, msg: msg.into() }
    }
}

impl fmt::Display for SnapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for SnapError {}

/// Build an `anyhow::Error` carrying a typed [`SnapError`], so callers
/// (the control server's `error_response`, the CLI exit path) can
/// `downcast_ref::<SnapError>()` through any context layers.
pub fn snap_err(kind: SnapErrorKind, msg: String) -> anyhow::Error {
    anyhow::Error::new(SnapError::new(kind, msg))
}

/// Snapshot format version. Bump on any layout change; restore rejects
/// mismatches outright (no cross-version migration).
/// History: 1 = initial layout; 2 = cpu gains `irqs_taken`.
pub const VERSION: u32 = 2;

/// Header size in bytes: magic + version + payload_len + checksum.
const HEADER_LEN: usize = 8 + 4 + 8 + 8;

/// Sparse-encoding granularity for large memories.
const SPARSE_CHUNK: usize = 4096;

/// FNV-1a 64-bit (corruption detection, not cryptographic).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

// ---------------------------------------------------------------------
// Writer / Reader
// ---------------------------------------------------------------------

/// Append-only encoder every component's `save_state` writes into.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            None => self.bool(false),
            Some(x) => {
                self.bool(true);
                self.u64(x);
            }
        }
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }

    /// Length-prefixed i32 slice.
    pub fn i32s(&mut self, vs: &[i32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.i32(v);
        }
    }

    /// Sparse fill-aware memory image: only [`SPARSE_CHUNK`]-sized runs
    /// that differ from `fill` are stored. A pristine memory costs a few
    /// bytes regardless of size.
    pub fn filled_bytes(&mut self, data: &[u8], fill: u8) {
        self.u64(data.len() as u64);
        // collect (offset, len) runs of dirty chunks, coalescing neighbours
        let mut runs: Vec<(usize, usize)> = Vec::new();
        let mut off = 0;
        while off < data.len() {
            let end = (off + SPARSE_CHUNK).min(data.len());
            if data[off..end].iter().any(|&b| b != fill) {
                match runs.last_mut() {
                    Some((ro, rl)) if *ro + *rl == off => *rl = end - *ro,
                    _ => runs.push((off, end - off)),
                }
            }
            off = end;
        }
        self.u32(runs.len() as u32);
        for (ro, rl) in runs {
            self.u64(ro as u64);
            self.u64(rl as u64);
            self.buf.extend_from_slice(&data[ro..ro + rl]);
        }
    }

    /// [`Writer::filled_bytes`] for a memory the caller knows is pristine
    /// (all `fill`): skips the scan entirely.
    pub fn filled_bytes_clean(&mut self, len: usize) {
        self.u64(len as u64);
        self.u32(0);
    }

    pub fn into_payload(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential decoder every component's `restore_state` reads from.
/// Every accessor validates bounds — a truncated snapshot is an error,
/// never a panic.
pub struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(payload: &'a [u8]) -> Self {
        Self { b: payload, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // pos <= len always holds, so this cannot over/underflow even
        // for adversarial length fields
        if n > self.b.len() - self.pos {
            bail!(
                "snapshot truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            );
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("snapshot corrupt: bool byte {other}"),
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn opt_u64(&mut self) -> Result<Option<u64>> {
        Ok(if self.bool()? { Some(self.u64()?) } else { None })
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn str(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?).map_err(|_| anyhow!("snapshot corrupt: bad UTF-8 string"))
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        // bound the pre-allocation by what the buffer can actually hold
        if n * 4 > self.b.len() - self.pos {
            bail!("snapshot truncated: i32 run of {n} words");
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.i32()?);
        }
        Ok(out)
    }

    /// Restore a sparse memory image written by [`Writer::filled_bytes`]
    /// into `out`. `out_is_clean` asserts that `out` is already all-`fill`
    /// (e.g. a never-written CS DRAM), letting the reset memset be
    /// skipped; dirty runs are always applied.
    pub fn filled_bytes_into(
        &mut self,
        out: &mut [u8],
        fill: u8,
        out_is_clean: bool,
    ) -> Result<()> {
        let total = self.u64()? as usize;
        if total != out.len() {
            bail!("snapshot memory size {total} does not match platform size {}", out.len());
        }
        let runs = self.u32()? as usize;
        if !out_is_clean {
            out.fill(fill);
        }
        for _ in 0..runs {
            let off = self.u64()? as usize;
            let len = self.u64()? as usize;
            match off.checked_add(len) {
                Some(end) if end <= out.len() => {}
                _ => bail!("snapshot corrupt: sparse run {off}+{len} exceeds memory size {total}"),
            }
            out[off..off + len].copy_from_slice(self.take(len)?);
        }
        Ok(())
    }

    /// Assert the whole payload was consumed (catches format drift
    /// between save and restore orders).
    pub fn finish(&self) -> Result<()> {
        if self.pos != self.b.len() {
            bail!(
                "snapshot has {} trailing bytes (format drift between save and restore?)",
                self.b.len() - self.pos
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// SnapshotInfo — the validated payload header
// ---------------------------------------------------------------------

/// Platform shape + provenance, written first in every payload and
/// validated by `Platform::restore` before any state is touched.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotInfo {
    pub name: String,
    pub freq_hz: u64,
    pub num_banks: u32,
    pub bank_size: u32,
    pub cs_dram_size: u64,
    pub flash_size: u64,
    /// Emulated cycle count at snapshot time.
    pub cycles: u64,
}

impl SnapshotInfo {
    pub fn write(&self, w: &mut Writer) {
        w.str(&self.name);
        w.u64(self.freq_hz);
        w.u32(self.num_banks);
        w.u32(self.bank_size);
        w.u64(self.cs_dram_size);
        w.u64(self.flash_size);
        w.u64(self.cycles);
    }

    pub fn read(r: &mut Reader) -> Result<SnapshotInfo> {
        Ok(SnapshotInfo {
            name: r.str()?,
            freq_hz: r.u64()?,
            num_banks: r.u32()?,
            bank_size: r.u32()?,
            cs_dram_size: r.u64()?,
            flash_size: r.u64()?,
            cycles: r.u64()?,
        })
    }
}

// ---------------------------------------------------------------------
// PlatformSnapshot — the framed, checksummed container
// ---------------------------------------------------------------------

/// A serialized platform image: header-framed, checksummed payload.
/// Construction through [`PlatformSnapshot::from_bytes`] (and the hex /
/// file loaders on top of it) validates magic, version, length, and
/// checksum, so corrupted or truncated images are rejected before any
/// restore begins.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlatformSnapshot {
    bytes: Vec<u8>,
}

impl PlatformSnapshot {
    /// Frame a freshly-encoded payload (the `Platform::snapshot` path).
    pub fn from_payload(payload: Vec<u8>) -> Self {
        let mut bytes = Vec::with_capacity(HEADER_LEN + payload.len());
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        Self { bytes }
    }

    /// Validate and adopt a serialized snapshot.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self> {
        if bytes.len() < HEADER_LEN {
            bail!("snapshot truncated: {} bytes, need at least {HEADER_LEN}", bytes.len());
        }
        if bytes[..8] != MAGIC {
            bail!("not a FEMU snapshot (bad magic)");
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(snap_err(
                SnapErrorKind::VersionMismatch,
                format!("snapshot version {version} unsupported (this build reads version {VERSION})"),
            ));
        }
        let payload_len = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        if bytes.len() - HEADER_LEN != payload_len {
            bail!(
                "snapshot truncated or padded: header says {payload_len} payload bytes, have {}",
                bytes.len() - HEADER_LEN
            );
        }
        let checksum = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
        let actual = fnv1a64(&bytes[HEADER_LEN..]);
        if checksum != actual {
            return Err(snap_err(
                SnapErrorKind::ChecksumMismatch,
                format!("snapshot corrupt: checksum {actual:#x} != recorded {checksum:#x}"),
            ));
        }
        Ok(Self { bytes })
    }

    /// The validated state payload (after the frame header).
    pub fn payload(&self) -> &[u8] {
        &self.bytes[HEADER_LEN..]
    }

    /// The full serialized form (header + payload).
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Parse the payload's [`SnapshotInfo`] header.
    pub fn info(&self) -> Result<SnapshotInfo> {
        SnapshotInfo::read(&mut Reader::new(self.payload()))
    }

    /// Hex encoding (the wire form of `snapshot.save`/`snapshot.restore`;
    /// the JSON-line protocol cannot carry raw bytes).
    pub fn to_hex(&self) -> String {
        let mut s = String::with_capacity(self.bytes.len() * 2);
        for &b in &self.bytes {
            s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
            s.push(char::from_digit((b & 0xF) as u32, 16).unwrap());
        }
        s
    }

    pub fn from_hex(hex: &str) -> Result<Self> {
        let hex = hex.trim();
        if hex.len() % 2 != 0 {
            bail!("snapshot hex has odd length {}", hex.len());
        }
        let mut bytes = Vec::with_capacity(hex.len() / 2);
        for pair in hex.as_bytes().chunks_exact(2) {
            let digit = |b: u8| {
                (b as char)
                    .to_digit(16)
                    .ok_or_else(|| anyhow!("snapshot hex has non-hex byte {b:#x}"))
            };
            bytes.push(((digit(pair[0])? << 4) | digit(pair[1])?) as u8);
        }
        Self::from_bytes(bytes)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, &self.bytes)
            .with_context(|| format!("writing snapshot {path:?}"))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).with_context(|| format!("reading snapshot {path:?}"))?;
        Self::from_bytes(bytes).with_context(|| format!("validating snapshot {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_failures_carry_typed_kinds() {
        let mut w = Writer::new();
        w.u32(0xDEAD_BEEF);
        let good = PlatformSnapshot::from_payload(w.into_payload()).as_bytes().to_vec();

        let mut corrupt = good.clone();
        *corrupt.last_mut().unwrap() ^= 0x01;
        let err = PlatformSnapshot::from_bytes(corrupt).unwrap_err();
        let kind = err.downcast_ref::<SnapError>().expect("typed checksum error").kind;
        assert_eq!(kind, SnapErrorKind::ChecksumMismatch);
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");

        let mut stale = good;
        stale[8] = 0x7F; // version field
        let err = PlatformSnapshot::from_bytes(stale).unwrap_err();
        let kind = err.downcast_ref::<SnapError>().expect("typed version error").kind;
        assert_eq!(kind, SnapErrorKind::VersionMismatch);
        assert!(format!("{err:#}").contains("version"), "{err:#}");

        // wire names + hints are distinct per kind
        let names: Vec<&str> = [
            SnapErrorKind::ChecksumMismatch,
            SnapErrorKind::VersionMismatch,
            SnapErrorKind::ShapeMismatch,
        ]
        .iter()
        .map(|k| k.name())
        .collect();
        let mut uniq = names.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), names.len());
    }

    #[test]
    fn primitive_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.bool(true);
        w.u32(0xDEAD_BEEF);
        w.i32(-5);
        w.u64(1 << 40);
        w.opt_u64(None);
        w.opt_u64(Some(99));
        w.str("héllo");
        w.i32s(&[-1, 0, 1]);
        let payload = w.into_payload();
        let mut r = Reader::new(&payload);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.opt_u64().unwrap(), None);
        assert_eq!(r.opt_u64().unwrap(), Some(99));
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.i32s().unwrap(), vec![-1, 0, 1]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = Writer::new();
        w.u64(12345);
        let payload = w.into_payload();
        let mut r = Reader::new(&payload[..4]);
        assert!(r.u64().is_err());
    }

    #[test]
    fn sparse_memory_roundtrip() {
        let mut data = vec![0u8; 3 * SPARSE_CHUNK + 100];
        data[10] = 1;
        data[SPARSE_CHUNK * 2 + 5] = 9;
        *data.last_mut().unwrap() = 3;
        let mut w = Writer::new();
        w.filled_bytes(&data, 0);
        let payload = w.into_payload();
        // sparse: far smaller than the memory itself
        assert!(payload.len() < data.len());
        let mut out = vec![0xAAu8; data.len()];
        Reader::new(&payload).filled_bytes_into(&mut out, 0, false).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn clean_memory_costs_almost_nothing() {
        let mut w = Writer::new();
        w.filled_bytes(&vec![0xFFu8; 1 << 20], 0xFF);
        assert!(w.into_payload().len() <= 16);
        let mut w = Writer::new();
        w.filled_bytes_clean(1 << 20);
        let payload = w.into_payload();
        let mut out = vec![0xFFu8; 1 << 20];
        Reader::new(&payload).filled_bytes_into(&mut out, 0xFF, true).unwrap();
        assert!(out.iter().all(|&b| b == 0xFF));
    }

    #[test]
    fn sparse_size_mismatch_rejected() {
        let mut w = Writer::new();
        w.filled_bytes(&[1, 2, 3], 0);
        let payload = w.into_payload();
        let mut out = vec![0u8; 4];
        assert!(Reader::new(&payload).filled_bytes_into(&mut out, 0, false).is_err());
    }

    #[test]
    fn frame_validation_catches_corruption() {
        let snap = PlatformSnapshot::from_payload(vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let good = snap.as_bytes().to_vec();
        assert_eq!(PlatformSnapshot::from_bytes(good.clone()).unwrap(), snap);

        // flipped payload byte -> checksum failure
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0xFF;
        let err = PlatformSnapshot::from_bytes(bad).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");

        // truncated
        let mut short = good.clone();
        short.truncate(short.len() - 3);
        assert!(PlatformSnapshot::from_bytes(short).is_err());

        // bad magic
        let mut magic = good.clone();
        magic[0] = b'X';
        let err = PlatformSnapshot::from_bytes(magic).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");

        // future version
        let mut vers = good;
        vers[8] = 0xEE;
        let err = PlatformSnapshot::from_bytes(vers).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn hex_roundtrip_and_rejection() {
        let snap = PlatformSnapshot::from_payload(vec![0xAB; 37]);
        let hex = snap.to_hex();
        assert_eq!(PlatformSnapshot::from_hex(&hex).unwrap(), snap);
        assert!(PlatformSnapshot::from_hex(&hex[..hex.len() - 1]).is_err()); // odd length
        let mut bad = hex;
        bad.replace_range(0..1, "z");
        assert!(PlatformSnapshot::from_hex(&bad).is_err());
    }

    #[test]
    fn info_header_roundtrip() {
        let info = SnapshotInfo {
            name: "x-heep-femu".into(),
            freq_hz: 20_000_000,
            num_banks: 2,
            bank_size: 0x2_0000,
            cs_dram_size: 16 << 20,
            flash_size: 4 << 20,
            cycles: 123_456,
        };
        let mut w = Writer::new();
        info.write(&mut w);
        let payload = w.into_payload();
        let got = SnapshotInfo::read(&mut Reader::new(&payload)).unwrap();
        assert_eq!(got, info);
    }
}
