//! Control server: the user-interface layer of the platform.
//!
//! Paper §IV-E wraps the platform in a Python class served through
//! Jupyter so "any HTTP client can connect to the platform and access its
//! internal functionalities". The equivalent here is a TCP JSON-line
//! protocol (one JSON object per line, request/response) exposing the
//! same functionality: program loading, execution control, memory and
//! register access, perf counters, and energy estimation. [`Client`] is
//! the in-repo convenience wrapper (`examples/remote_control.rs` drives
//! it end to end).
//!
//! Threading note: the std TCP listener + thread-per-connection model is
//! used because tokio is unavailable in the offline build environment
//! (Cargo.toml); the protocol is line-oriented and stateless per request,
//! so the transport choice is invisible to clients.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{AppExit, Platform};
use crate as femu;
use crate::energy::EnergyModel;
use crate::util::Json;

/// Platform wrapper moved into the server thread. The `xla` crate's PJRT
/// handles are `Rc`-based and thus not `Send`; every access here happens
/// with the `Mutex` held and the `Rc`s never escape the platform, so
/// moving the whole platform between threads is sound.
struct SendPlatform(Platform);
// SAFETY: see above — Mutex-serialized access, no Rc clones escape.
unsafe impl Send for SendPlatform {}

/// A running control server.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve `platform` on `addr` (use port 0 for ephemeral).
    pub fn spawn(platform: Platform, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).context("binding control server")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let platform = Arc::new(Mutex::new(SendPlatform(platform)));
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let p = platform.clone();
                        let stop3 = stop2.clone();
                        std::thread::spawn(move || {
                            let _ = serve_connection(stream, p, stop3);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn serve_connection(
    stream: TcpStream,
    platform: Arc<Mutex<SendPlatform>>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    while !stop.load(Ordering::Relaxed) {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let response = match handle_request(&line, &platform) {
            Ok(v) => Json::obj(vec![("ok", Json::Bool(true)), ("result", v)]),
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(format!("{e:#}"))),
            ]),
        };
        writeln!(writer, "{response}")?;
    }
    Ok(())
}

fn handle_request(line: &str, platform: &Arc<Mutex<SendPlatform>>) -> Result<Json> {
    let req = Json::parse(line.trim()).context("parsing request")?;
    let cmd = req.str_field("cmd")?;
    let mut guard = platform.lock().map_err(|_| anyhow!("platform lock poisoned"))?;
    let p = &mut guard.0;
    match cmd {
        "ping" => Ok(Json::from("pong")),
        "load_asm" => {
            let src = req.str_field("source")?;
            let prog = p.dbg.load_source(src)?;
            let symbols = Json::Obj(
                prog.symbols
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect(),
            );
            Ok(Json::obj(vec![
                ("entry", Json::from(prog.entry as i64)),
                ("text_words", Json::from(prog.text.len() as i64)),
                ("symbols", symbols),
            ]))
        }
        "run" => {
            let budget = req.opt("max_cycles").map(|v| v.as_i64()).transpose()?.unwrap_or(1 << 33)
                as u64;
            let exit = p.run_app(budget)?;
            let (kind, detail) = match exit {
                AppExit::Halted(h) => ("halted", format!("{h:?}")),
                AppExit::Budget => ("budget", String::new()),
            };
            Ok(Json::obj(vec![
                ("exit", Json::from(kind)),
                ("detail", Json::Str(detail)),
                ("cycles", Json::from(p.dbg.soc.now as i64)),
            ]))
        }
        "reset" => {
            let entry = req.opt("entry").map(|v| v.as_i64()).transpose()?.unwrap_or(0) as u32;
            p.dbg.reset(entry);
            Ok(Json::Null)
        }
        "regs" => Ok(Json::Arr(
            p.dbg.soc.cpu.regs.iter().map(|&r| Json::Num(r as i32 as f64)).collect(),
        )),
        "read_mem" => {
            let addr = req.get("addr")?.as_i64()? as u32;
            let n = req.get("n")?.as_usize()?;
            let vals = p.dbg.read_i32_slice(addr, n)?;
            Ok(Json::arr_i32(&vals))
        }
        "write_mem" => {
            let addr = req.get("addr")?.as_i64()? as u32;
            let vals: Vec<i32> = req
                .get("values")?
                .as_arr()?
                .iter()
                .map(|v| v.as_i64().map(|x| x as i32))
                .collect::<Result<_>>()?;
            p.dbg.write_i32_slice(addr, &vals)?;
            Ok(Json::Null)
        }
        "disasm" => {
            let addr = req.get("addr")?.as_i64()? as u32;
            let n = req.get("n")?.as_usize()?;
            let words: Vec<u32> = (0..n)
                .map(|i| p.dbg.read32(addr + (i * 4) as u32).map(|w| w))
                .collect::<Result<_>>()?;
            Ok(Json::Str(femu::isa::listing(&words, addr)))
        }
        "step" => {
            let stop = p.dbg.step();
            Ok(Json::obj(vec![
                ("stop", Json::Str(format!("{stop:?}"))),
                ("pc", Json::from(p.dbg.pc() as i64)),
            ]))
        }
        "add_breakpoint" => {
            let addr = req.get("addr")?.as_i64()? as u32;
            p.dbg.add_breakpoint(addr);
            Ok(Json::Null)
        }
        "remove_breakpoint" => {
            let addr = req.get("addr")?.as_i64()? as u32;
            p.dbg.remove_breakpoint(addr);
            Ok(Json::Null)
        }
        "uart" => {
            let bytes = p.dbg.uart();
            Ok(Json::Str(String::from_utf8_lossy(&bytes).into_owned()))
        }
        "perf" => {
            let snap = p.snapshot();
            let mut domains = std::collections::BTreeMap::new();
            for (d, c) in snap.domains() {
                domains.insert(
                    d.to_string(),
                    Json::obj(vec![
                        ("active", Json::from(c.counts[0] as i64)),
                        ("clock_gated", Json::from(c.counts[1] as i64)),
                        ("power_gated", Json::from(c.counts[2] as i64)),
                        ("retention", Json::from(c.counts[3] as i64)),
                    ]),
                );
            }
            Ok(Json::obj(vec![
                ("cycles", Json::from(snap.cycles as i64)),
                ("domains", Json::Obj(domains)),
            ]))
        }
        "energy" => {
            let model_name = req.opt("model").map(|v| v.as_str()).transpose()?.unwrap_or("femu");
            let model = EnergyModel::by_name(model_name)
                .ok_or_else(|| anyhow!("unknown energy model `{model_name}`"))?;
            let snap = p.snapshot();
            let r = model.estimate(&snap);
            Ok(Json::obj(vec![
                ("model", Json::from(model_name)),
                ("total_mj", Json::Num(r.total_mj)),
                ("active_mj", Json::Num(r.active_mj)),
                ("sleep_mj", Json::Num(r.sleep_mj)),
                ("seconds", Json::Num(r.seconds())),
            ]))
        }
        other => Err(anyhow!("unknown command `{other}`")),
    }
}

/// Line-protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to control server")?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request object; returns the `result` payload.
    pub fn call(&mut self, request: Json) -> Result<Json> {
        writeln!(self.writer, "{request}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim())?;
        if resp.get("ok")?.as_bool()? {
            Ok(resp.opt("result").cloned().unwrap_or(Json::Null))
        } else {
            Err(anyhow!("server error: {}", resp.str_field("error").unwrap_or("?")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn spawn() -> (Server, Client) {
        let platform = Platform::new(PlatformConfig::default());
        let server = Server::spawn(platform, "127.0.0.1:0").unwrap();
        let client = Client::connect(server.addr()).unwrap();
        (server, client)
    }

    #[test]
    fn ping_pong() {
        let (server, mut client) = spawn();
        let r = client.call(Json::obj(vec![("cmd", Json::from("ping"))])).unwrap();
        assert_eq!(r.as_str().unwrap(), "pong");
        server.shutdown();
    }

    #[test]
    fn load_run_read_cycle() {
        let (server, mut client) = spawn();
        let src = r#"
            _start:
                la t0, out
                li t1, 77
                sw t1, 0(t0)
                ebreak
            .data
            out: .word 0
        "#;
        let loaded = client
            .call(Json::obj(vec![("cmd", Json::from("load_asm")), ("source", Json::from(src))]))
            .unwrap();
        let out_addr = loaded.get("symbols").unwrap().get("out").unwrap().as_i64().unwrap();
        let run = client.call(Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert_eq!(run.str_field("exit").unwrap(), "halted");
        let mem = client
            .call(Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(out_addr)),
                ("n", Json::from(1i64)),
            ]))
            .unwrap();
        assert_eq!(mem.as_arr().unwrap()[0].as_i64().unwrap(), 77);
        server.shutdown();
    }

    #[test]
    fn energy_and_perf_queries() {
        let (server, mut client) = spawn();
        client
            .call(Json::obj(vec![
                ("cmd", Json::from("load_asm")),
                ("source", Json::from("_start: li a0, 1\nebreak")),
            ]))
            .unwrap();
        client.call(Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        let perf = client.call(Json::obj(vec![("cmd", Json::from("perf"))])).unwrap();
        assert!(perf.get("cycles").unwrap().as_i64().unwrap() > 0);
        let energy = client
            .call(Json::obj(vec![
                ("cmd", Json::from("energy")),
                ("model", Json::from("heepocrates")),
            ]))
            .unwrap();
        assert!(energy.get("total_mj").unwrap().as_f64().unwrap() > 0.0);
        server.shutdown();
    }

    #[test]
    fn bad_requests_error_cleanly() {
        let (server, mut client) = spawn();
        assert!(client.call(Json::obj(vec![("cmd", Json::from("warp"))])).is_err());
        assert!(client
            .call(Json::obj(vec![("cmd", Json::from("read_mem")), ("addr", Json::from(0i64))]))
            .is_err());
        // connection still usable
        assert!(client.call(Json::obj(vec![("cmd", Json::from("ping"))])).is_ok());
        server.shutdown();
    }
}
