//! Control server: the user-interface layer of the platform.
//!
//! Paper §IV-E wraps the platform in a Python class served through
//! Jupyter so "any HTTP client can connect to the platform and access its
//! internal functionalities". The equivalent here is a TCP JSON-line
//! protocol (one JSON object per line, request/response) — but grown into
//! a **session-oriented control service** (DESIGN.md §9):
//!
//! * `session.open` gives each client a *private* [`Platform`] built from
//!   a named or inline [`PlatformConfig`]; commands carry a `session` id.
//!   Two sessions never contend on each other's emulator state, so
//!   concurrent users' `run`s proceed in parallel.
//! * every command executes on a bounded [`WorkerPool`] (the
//!   `coordinator/fleet.rs` pool machinery), which bounds execution
//!   concurrency regardless of connection count;
//! * `batch` pipelines an array of commands against one session in a
//!   single round trip;
//! * the §V experiment drivers (`sweep_acquisition`, `kernels`,
//!   `flash_study`) are callable over the wire and shard across a shared
//!   [`Fleet`], same as the CLI;
//! * shutdown is graceful: the accept loop stops, live connections are
//!   unblocked (per-stream read timeouts + stream shutdown) and joined,
//!   in-flight commands finish (long `run`s are interrupted at a slice
//!   boundary), the pool drains, and sessions are torn down in id order.
//!
//! Requests without a `session` field target session 0 — the platform
//! `Server::spawn` received — so the original session-less protocol keeps
//! working unchanged. [`Client`] is the in-repo convenience wrapper
//! (`examples/remote_control.rs` drives it end to end).
//!
//! Threading note: the std TCP listener + thread-per-connection model is
//! used because tokio is unavailable in the offline build environment
//! (Cargo.toml); connection threads only parse and route — execution
//! concurrency is owned by the pool.

pub mod protocol;
pub mod session;

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::PlatformConfig;
use crate::coordinator::fleet::WorkerPool;
use crate::coordinator::{Fleet, Platform};
use crate::exec::BackendKind;
use crate::metrics::ServerMetrics;
use crate::util::Json;

pub use session::{ConfigRegistry, Session, SessionTable, DEFAULT_SESSION};

/// Wire-protocol version, announced in the hello banner. Bumped to 2
/// when sessions grew `session.fork` + `snapshot.save`/`snapshot.restore`
/// and the banner itself was introduced; bumped to 3 when `session.open`
/// grew the optional `backend` field (execution engine per session,
/// `"interp"` / `"blocks"`) and error responses grew the additive
/// machine-readable `error_kind` field ([`protocol::ErrorKind`]). v3 is
/// backward compatible: v2 requests and substring-matching error
/// handling behave exactly as before. Bumped to 4 when the additive
/// `analyze` command arrived (static analysis of the session's current
/// memory: CFG, `FEMU-Axxx` lints, WCET/energy bounds, block map —
/// [`crate::analyze`]); every v3 request is unchanged. Bumped to 5 when
/// the additive `trace.subscribe` / `trace.read` / `trace.stop` command
/// family arrived (per-session event tracing with cursor-paged
/// streaming — [`crate::trace`], DESIGN.md §13); every v4 request is
/// unchanged. Bumped to 6 when the additive `metrics` command (server
/// observability — [`crate::metrics`], DESIGN.md §14) and the
/// `profile.start` / `profile.read` / `profile.stop` family (per-session
/// cycle-exact guest profiling — [`crate::profile`]) arrived, and
/// `session.list` entries grew additive `uptime_s` / `idle_s` /
/// `last_command_unix_ms` / `backend` / `instret` / `cycles` fields;
/// every v5 request is unchanged. Bumped to 7 when the additive
/// `faults.run` experiment command arrived (snapshot-powered
/// fault-injection campaigns — [`crate::faults`], DESIGN.md §15) and
/// snapshot-load failures gained distinct `error_kind` values
/// (`snapshot_checksum_mismatch` / `snapshot_version_mismatch` /
/// `snapshot_shape_mismatch`, [`crate::snapshot::SnapErrorKind`]) with
/// unchanged error text; every v6 request is unchanged.
pub const PROTO_VERSION: u32 = 7;

/// The one-line JSON banner every accepted connection receives before
/// its first request: `{"hello":"femu-control-server","proto":...,
/// "version":...}`. Clients assert on it ([`Client::hello`]) to fail
/// fast against a mismatched or non-FEMU endpoint.
fn hello_banner() -> Json {
    Json::obj(vec![
        ("hello", Json::from("femu-control-server")),
        ("proto", Json::from(PROTO_VERSION as i64)),
        ("version", Json::from(env!("CARGO_PKG_VERSION"))),
    ])
}

/// How long a blocked connection read waits before re-checking the stop
/// flag. Bounds the shutdown latency contribution of idle connections.
const READ_TICK: Duration = Duration::from_millis(100);

/// Accept-loop poll interval; idle-session reaping runs every
/// [`REAP_EVERY_TICKS`] of these.
const ACCEPT_TICK: Duration = Duration::from_millis(5);
const REAP_EVERY_TICKS: u32 = 100;

/// Server sizing knobs (`femu serve --max-sessions --workers
/// --idle-timeout`).
pub struct ServerOptions {
    /// Session-table capacity, *including* the default session 0.
    pub max_sessions: usize,
    /// Worker-pool width: how many commands execute concurrently. Also
    /// sizes the shared experiment [`Fleet`].
    pub workers: usize,
    /// Idle sessions (except session 0) older than this are reaped.
    pub idle_timeout: Duration,
    /// Extra named configs for `session.open {"config_name": ...}`;
    /// `"default"` (the spawn config) is always registered.
    pub named_configs: Vec<(String, PlatformConfig)>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self {
            max_sessions: 8,
            // at least 2 so one long run never serializes the server
            workers: cores.max(2),
            idle_timeout: Duration::from_secs(300),
            named_configs: Vec::new(),
        }
    }
}

/// Live connections: one registered stream clone (for shutdown) and one
/// join handle per connection thread.
type ConnRegistry = Arc<Mutex<Vec<(TcpStream, std::thread::JoinHandle<()>)>>>;

/// State shared by the accept loop, connection threads, and pool jobs.
struct Shared {
    stop: AtomicBool,
    sessions: SessionTable,
    registry: ConfigRegistry,
    pool: WorkerPool,
    fleet: Fleet,
    /// Experiment sweeps spawn up to `fleet.workers()` scoped threads of
    /// their own; running them one at a time keeps total execution
    /// threads bounded at ~2x the pool width no matter how many clients
    /// ask for sweeps concurrently. Acquired with `try_lock`: a second
    /// concurrent experiment is refused outright rather than parking on
    /// a pool worker (which would starve session commands).
    experiment_lock: Mutex<()>,
    /// Control-plane observability (proto v6): per-command latency,
    /// byte/connection totals, batch sizes, trace backpressure. Session
    /// and pool counters live with their owners and are joined into the
    /// `metrics` response.
    metrics: ServerMetrics,
}

/// A running control server.
pub struct Server {
    addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: ConnRegistry,
}

impl Server {
    /// Bind and serve `platform` on `addr` (use port 0 for ephemeral)
    /// with default sizing. `platform` becomes session 0.
    pub fn spawn(platform: Platform, addr: &str) -> Result<Server> {
        Self::spawn_with(platform, addr, ServerOptions::default())
    }

    /// Bind and serve with explicit sizing.
    pub fn spawn_with(platform: Platform, addr: &str, opts: ServerOptions) -> Result<Server> {
        let listener = TcpListener::bind(addr).context("binding control server")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let mut registry = ConfigRegistry::new(platform.cfg.clone());
        for (name, cfg) in opts.named_configs {
            registry.register(name, cfg);
        }
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            sessions: SessionTable::new(platform, opts.max_sessions, opts.idle_timeout),
            registry,
            pool: WorkerPool::new(opts.workers),
            fleet: Fleet::new(opts.workers),
            experiment_lock: Mutex::new(()),
            metrics: ServerMetrics::new(),
        });
        let conns: ConnRegistry = Arc::new(Mutex::new(Vec::new()));

        let shared2 = shared.clone();
        let conns2 = conns.clone();
        let accept = std::thread::Builder::new()
            .name("femu-accept".into())
            .spawn(move || {
                let mut tick = 0u32;
                while !shared2.stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let registered = match stream.try_clone() {
                                Ok(c) => c,
                                Err(_) => continue,
                            };
                            stream.set_nodelay(true).ok();
                            stream.set_read_timeout(Some(READ_TICK)).ok();
                            let s = shared2.clone();
                            let handle = std::thread::spawn(move || {
                                let _ = serve_connection(stream, s);
                            });
                            let mut reg = conns2.lock().unwrap_or_else(|p| p.into_inner());
                            reg.retain(|(_, h)| !h.is_finished());
                            reg.push((registered, handle));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(ACCEPT_TICK);
                            tick = tick.wrapping_add(1);
                            if tick % REAP_EVERY_TICKS == 0 {
                                shared2.sessions.reap_idle();
                            }
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawning accept thread");

        Ok(Server { addr: local, shared, accept: Some(accept), conns })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// One-line operational summary (`femu serve --metrics-interval`
    /// prints this periodically).
    pub fn metrics_line(&self) -> String {
        let m = &self.shared.metrics;
        let ps = self.shared.pool.stats();
        format!(
            "metrics: conns={}open/{}closed cmds={} errs={} p50_us={} p99_us={} \
             sessions={} queue={} in={}B out={}B",
            m.connections_opened.get(),
            m.connections_closed.get(),
            m.commands.get(),
            m.errors.get(),
            m.latency_us.percentile(0.5),
            m.latency_us.percentile(0.99),
            self.shared.sessions.len(),
            ps.queue_depth.get(),
            m.bytes_in.get(),
            m.bytes_out.get(),
        )
    }

    /// Graceful shutdown: returns only after the accept loop and **all**
    /// connection threads are joined, the worker pool has drained, and
    /// every session is torn down. In-flight commands finish (long runs
    /// are interrupted at their next slice boundary).
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Unblock every connection first, then join: a connection thread
        // may be waiting on a pool job, which observes the stop flag.
        let conns: Vec<_> =
            self.conns.lock().unwrap_or_else(|p| p.into_inner()).drain(..).collect();
        for (stream, _) in &conns {
            let _ = stream.shutdown(Shutdown::Both);
        }
        for (_, handle) in conns {
            let _ = handle.join();
        }
        // No submitters remain: drain queued jobs and join the workers.
        self.shared.pool.shutdown();
        // Deterministic teardown, session 0 first.
        for session in self.shared.sessions.drain() {
            drop(session);
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Build an `{ok:false}` response object. Since proto v3 a failure
/// classified by the protocol layer additionally carries its
/// machine-readable kind (`error_kind`); the `error` text is unchanged,
/// so substring-matching v2 clients keep working.
fn error_response(e: &anyhow::Error) -> Json {
    let mut fields =
        vec![("ok", Json::Bool(false)), ("error", Json::Str(format!("{e:#}")))];
    if let Some(pe) = e.downcast_ref::<protocol::ProtoError>() {
        fields.push(("error_kind", Json::from(pe.kind.name())));
    } else if let Some(se) = e.downcast_ref::<crate::snapshot::SnapError>() {
        fields.push(("error_kind", Json::from(se.kind.name())));
    }
    Json::obj(fields)
}

fn serve_connection(stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    shared.metrics.connections_opened.inc();
    let r = serve_connection_inner(stream, &shared);
    shared.metrics.connections_closed.inc();
    r
}

fn serve_connection_inner(stream: TcpStream, shared: &Arc<Shared>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    // versioned hello before the first request (clients assert on it)
    writeln!(writer, "{}", hello_banner())?;
    // byte buffer (not String): read_until keeps partially-read requests
    // across read timeouts, with no UTF-8 guard to discard them
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                shared.metrics.bytes_in.add(buf.len() as u64);
                let response = match std::str::from_utf8(&buf) {
                    Ok(line) => match dispatch(line, shared) {
                        Ok(v) => Json::obj(vec![("ok", Json::Bool(true)), ("result", v)]),
                        Err(e) => error_response(&e),
                    },
                    Err(_) => Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::from("request is not valid UTF-8")),
                    ]),
                };
                buf.clear();
                let text = response.to_string();
                shared.metrics.bytes_out.add(text.len() as u64 + 1); // + newline
                writeln!(writer, "{text}")?;
            }
            // read timeout: partial data (if any) stays in `buf`;
            // re-check the stop flag and keep reading
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Optional `session` field, defaulting to the default session.
fn session_field(req: &Json) -> Result<u64> {
    match req.opt("session") {
        None => Ok(DEFAULT_SESSION),
        Some(v) => {
            let id = v.as_i64()?;
            u64::try_from(id).map_err(|_| anyhow!("`session` {id} out of range"))
        }
    }
}

/// Parse one request line, route it, and record it in the server
/// metrics (per-command call/error counts + wall-clock latency). A line
/// that fails to parse or carries no `cmd` is not attributable to a
/// command and only shows up in the byte counters.
fn dispatch(line: &str, shared: &Arc<Shared>) -> Result<Json> {
    let req = Json::parse(line.trim()).context("parsing request")?;
    let cmd = req.str_field("cmd")?.to_string();
    let t0 = std::time::Instant::now();
    let result = route(&cmd, req, shared);
    shared.metrics.observe_command(&cmd, result.is_ok(), t0.elapsed().as_micros() as u64);
    // trace-stream backpressure: events delivered vs lost to ring
    // overwrite before the subscriber drained them
    if cmd == "trace.read" {
        if let Ok(v) = &result {
            if let Some(events) = v.opt("events").and_then(|e| e.as_arr().ok()) {
                shared.metrics.trace_events_read.add(events.len() as u64);
            }
            if let Some(skipped) = v.opt("skipped").and_then(|s| s.as_i64().ok()) {
                shared.metrics.trace_events_skipped.add(skipped.max(0) as u64);
            }
        }
    }
    result
}

/// Route one request: table operations run inline on the connection
/// thread (cheap, never blocked by running guests); everything that
/// touches a platform or a sweep is dispatched onto the worker pool.
fn route(cmd: &str, req: Json, shared: &Arc<Shared>) -> Result<Json> {
    match cmd {
        // ping answers inline so liveness probes work even with every
        // worker busy
        "ping" => Ok(Json::from("pong")),
        "session.open" => {
            if shared.stop.load(Ordering::Relaxed) {
                bail!("server is shutting down");
            }
            let (mut cfg, label) = shared.registry.resolve(&req)?;
            // proto v3: the request may pick the execution engine,
            // overriding whatever the resolved config says
            if let Some(b) = req.opt("backend") {
                cfg.soc.backend = BackendKind::parse(b.as_str()?).map_err(|e| {
                    protocol::proto_err(protocol::ErrorKind::BadParam, format!("{e:#}"))
                })?;
            }
            let backend = cfg.soc.backend;
            let session = shared.sessions.open(Platform::new(cfg), label)?;
            Ok(Json::obj(vec![
                ("session", Json::from(session.id() as i64)),
                ("config", Json::from(session.config_label())),
                ("backend", Json::from(backend.name())),
            ]))
        }
        "session.close" => {
            let id = req.get("session")?.as_i64()?;
            let id = u64::try_from(id).map_err(|_| anyhow!("`session` {id} out of range"))?;
            shared.sessions.close(id)?;
            Ok(Json::Null)
        }
        "session.fork" => {
            if shared.stop.load(Ordering::Relaxed) {
                bail!("server is shutting down");
            }
            // fork = snapshot the (possibly warmed) source platform and
            // open a new session restored from it; the clone diverges
            // independently from here on
            let id = req.get("session")?.as_i64()?;
            let id = u64::try_from(id).map_err(|_| anyhow!("`session` {id} out of range"))?;
            let src = shared.sessions.get(id)?;
            let shared2 = shared.clone();
            shared.pool.submit_wait(move || -> Result<Json> {
                let (snap, cfg) = src.with_platform(|p| (p.snapshot(), p.cfg.clone()))?;
                let mut platform = Platform::new(cfg);
                platform.restore(&snap)?;
                let label = format!("fork:{}", src.config_label());
                let session = shared2.sessions.open(platform, label)?;
                Ok(Json::obj(vec![
                    ("session", Json::from(session.id() as i64)),
                    ("config", Json::from(session.config_label())),
                    ("forked_from", Json::from(src.id() as i64)),
                    ("cycles", Json::from(snap.info()?.cycles as i64)),
                ]))
            })?
        }
        "session.list" => Ok(shared.sessions.describe()),
        // metrics answers inline: observability must work with every
        // worker busy (that is exactly when you want it)
        "metrics" => {
            let format = req.opt("format").map(|v| v.as_str()).transpose()?.unwrap_or("json");
            match format {
                "json" => Ok(metrics_json(shared)),
                "prometheus" => Ok(Json::obj(vec![
                    ("format", Json::from("prometheus")),
                    ("text", Json::Str(metrics_prometheus(shared))),
                ])),
                other => Err(protocol::proto_err(
                    protocol::ErrorKind::BadParam,
                    format!("unknown metrics format `{other}` (want json|prometheus)"),
                )),
            }
        }
        "batch" => {
            let session = shared.sessions.get(session_field(&req)?)?;
            let sub: Vec<Json> = req.get("requests")?.as_arr()?.to_vec();
            shared.metrics.batch_len.observe(sub.len() as u64);
            if sub.len() > protocol::MAX_BATCH_REQUESTS {
                return Err(protocol::proto_err(
                    protocol::ErrorKind::CapExceeded,
                    format!(
                        "batch of {} exceeds the {}-request cap",
                        sub.len(),
                        protocol::MAX_BATCH_REQUESTS
                    ),
                ));
            }
            let shared2 = shared.clone();
            shared.pool.submit_wait(move || run_batch(&shared2, &session, &sub))?
        }
        _ if protocol::is_experiment_cmd(cmd) => {
            let (cfg, _) = shared.registry.resolve(&req)?;
            let shared2 = shared.clone();
            // the job outlives this borrow of `cmd`, so it gets an owned copy
            let cmd = cmd.to_string();
            shared.pool.submit_wait(move || {
                let _one_at_a_time = match shared2.experiment_lock.try_lock() {
                    Ok(guard) => guard,
                    Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
                    Err(std::sync::TryLockError::WouldBlock) => {
                        return Err(anyhow!(
                            "another experiment is already running; retry when it finishes"
                        ))
                    }
                };
                let cancelled = || shared2.stop.load(Ordering::Relaxed);
                protocol::execute_experiment_cmd(&shared2.fleet, &cfg, &cmd, &req, &cancelled)
            })?
        }
        _ => {
            let session = shared.sessions.get(session_field(&req)?)?;
            let shared2 = shared.clone();
            let cmd = cmd.to_string();
            shared.pool.submit_wait(move || {
                session.with_platform(|p| {
                    let cancelled =
                        || shared2.stop.load(Ordering::Relaxed) || session.cancelled();
                    protocol::execute_platform_cmd(p, &cmd, &req, &cancelled)
                })?
            })?
        }
    }
}

/// Execute a `batch`'s sub-requests in order against one session,
/// aborting after the first failure. The response carries one entry per
/// executed sub-request plus the count of successes.
fn run_batch(shared: &Arc<Shared>, session: &Arc<Session>, sub: &[Json]) -> Result<Json> {
    session.with_platform(|p| {
        let cancelled = || shared.stop.load(Ordering::Relaxed) || session.cancelled();
        let mut results = Vec::with_capacity(sub.len());
        let mut completed = 0i64;
        for r in sub {
            let outcome = r.str_field("cmd").map(str::to_string).and_then(|c| {
                if c == "batch" || c.starts_with("session.") || protocol::is_experiment_cmd(&c) {
                    bail!("`{c}` is not allowed inside a batch");
                }
                protocol::execute_platform_cmd(p, &c, r, &cancelled)
            });
            match outcome {
                Ok(v) => {
                    results.push(Json::obj(vec![("ok", Json::Bool(true)), ("result", v)]));
                    completed += 1;
                }
                Err(e) => {
                    results.push(error_response(&e));
                    break;
                }
            }
        }
        Ok(Json::obj(vec![
            ("results", Json::Arr(results)),
            ("completed", Json::from(completed)),
        ]))
    })?
}

/// The `metrics` response (proto v6): server counters, session
/// lifecycle, worker-pool queue accounting, and per-command stats, all
/// derived state (reset on server restart, never snapshotted).
fn metrics_json(shared: &Shared) -> Json {
    let m = &shared.metrics;
    let ss = shared.sessions.stats();
    let ps = shared.pool.stats();
    let per_command = Json::Obj(
        m.per_command()
            .into_iter()
            .map(|(name, st)| {
                (
                    name,
                    Json::obj(vec![
                        ("calls", Json::from(st.calls.get() as i64)),
                        ("errors", Json::from(st.errors.get() as i64)),
                        ("latency_us", st.latency_us.to_json()),
                    ]),
                )
            })
            .collect(),
    );
    Json::obj(vec![
        (
            "server",
            Json::obj(vec![
                ("connections_opened", Json::from(m.connections_opened.get() as i64)),
                ("connections_closed", Json::from(m.connections_closed.get() as i64)),
                ("bytes_in", Json::from(m.bytes_in.get() as i64)),
                ("bytes_out", Json::from(m.bytes_out.get() as i64)),
                ("commands", Json::from(m.commands.get() as i64)),
                ("errors", Json::from(m.errors.get() as i64)),
                ("latency_us", m.latency_us.to_json()),
                ("batch_len", m.batch_len.to_json()),
                ("trace_events_read", Json::from(m.trace_events_read.get() as i64)),
                ("trace_events_skipped", Json::from(m.trace_events_skipped.get() as i64)),
            ]),
        ),
        (
            "sessions",
            Json::obj(vec![
                ("live", Json::from(shared.sessions.len() as i64)),
                ("opened", Json::from(ss.opened.get() as i64)),
                ("closed", Json::from(ss.closed.get() as i64)),
                ("evicted", Json::from(ss.evicted.get() as i64)),
                ("reaped", Json::from(ss.reaped.get() as i64)),
            ]),
        ),
        (
            "pool",
            Json::obj(vec![
                ("workers", Json::from(shared.pool.workers() as i64)),
                ("submitted", Json::from(ps.submitted.get() as i64)),
                ("completed", Json::from(ps.completed.get() as i64)),
                ("rejected", Json::from(ps.rejected.get() as i64)),
                ("queue_depth", Json::from(ps.queue_depth.get())),
                ("wait_us", ps.wait_us.to_json()),
            ]),
        ),
        ("per_command", per_command),
    ])
}

/// The same counters in the Prometheus text exposition format, for
/// scraping through `{"cmd":"metrics","format":"prometheus"}` or
/// `femu metrics --prometheus`.
fn metrics_prometheus(shared: &Shared) -> String {
    use std::fmt::Write as _;
    let m = &shared.metrics;
    let ss = shared.sessions.stats();
    let ps = shared.pool.stats();
    let mut out = String::new();
    let _ = writeln!(out, "femu_connections_opened_total {}", m.connections_opened.get());
    let _ = writeln!(out, "femu_connections_closed_total {}", m.connections_closed.get());
    let _ = writeln!(out, "femu_bytes_in_total {}", m.bytes_in.get());
    let _ = writeln!(out, "femu_bytes_out_total {}", m.bytes_out.get());
    let _ = writeln!(out, "femu_commands_total {}", m.commands.get());
    let _ = writeln!(out, "femu_errors_total {}", m.errors.get());
    for (q, p) in [("0.5", 0.5), ("0.9", 0.9), ("0.99", 0.99)] {
        let _ = writeln!(
            out,
            "femu_command_latency_us{{quantile=\"{q}\"}} {}",
            m.latency_us.percentile(p)
        );
    }
    let _ = writeln!(out, "femu_trace_events_read_total {}", m.trace_events_read.get());
    let _ = writeln!(out, "femu_trace_events_skipped_total {}", m.trace_events_skipped.get());
    let _ = writeln!(out, "femu_sessions_live {}", shared.sessions.len());
    let _ = writeln!(out, "femu_sessions_opened_total {}", ss.opened.get());
    let _ = writeln!(out, "femu_sessions_closed_total {}", ss.closed.get());
    let _ = writeln!(out, "femu_sessions_evicted_total {}", ss.evicted.get());
    let _ = writeln!(out, "femu_sessions_reaped_total {}", ss.reaped.get());
    let _ = writeln!(out, "femu_pool_workers {}", shared.pool.workers());
    let _ = writeln!(out, "femu_pool_submitted_total {}", ps.submitted.get());
    let _ = writeln!(out, "femu_pool_completed_total {}", ps.completed.get());
    let _ = writeln!(out, "femu_pool_rejected_total {}", ps.rejected.get());
    let _ = writeln!(out, "femu_pool_queue_depth {}", ps.queue_depth.get());
    for (q, p) in [("0.5", 0.5), ("0.99", 0.99)] {
        let _ = writeln!(
            out,
            "femu_pool_wait_us{{quantile=\"{q}\"}} {}",
            ps.wait_us.percentile(p)
        );
    }
    for (name, st) in m.per_command() {
        let _ = writeln!(out, "femu_command_calls_total{{cmd=\"{name}\"}} {}", st.calls.get());
        let _ =
            writeln!(out, "femu_command_errors_total{{cmd=\"{name}\"}} {}", st.errors.get());
    }
    out
}

/// Line-protocol client. Reads and validates the server's hello banner
/// on connect; an optional I/O timeout bounds how long any connect,
/// send, or response wait may block (a hung server surfaces as a clean
/// "timed out" error instead of blocking forever).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    hello: Json,
    /// Set after a response timeout: the line framing is then undefined
    /// (the late response may still arrive and would be misread as the
    /// answer to the *next* request), so every further call refuses.
    poisoned: bool,
}

/// True for the error kinds a socket read/write timeout surfaces as.
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

impl Client {
    /// How long [`Client::connect`] waits for the hello banner. Bounds
    /// the one read that happens before the caller gets a handle back —
    /// a mute endpoint (or a non-FEMU service waiting for the client to
    /// speak first) errors instead of hanging the constructor forever.
    pub const BANNER_TIMEOUT: Duration = Duration::from_secs(10);

    /// Connect with no per-request I/O timeout (requests wait
    /// indefinitely, as before); only the hello banner read is bounded,
    /// by [`Client::BANNER_TIMEOUT`].
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to control server")?;
        stream.set_read_timeout(Some(Self::BANNER_TIMEOUT)).ok();
        let mut client = Self::from_stream(stream)?;
        client.set_io_timeout(None)?;
        Ok(client)
    }

    /// Connect with `timeout` bounding the TCP connect, the banner read,
    /// and every subsequent request/response.
    pub fn connect_with_timeout(addr: std::net::SocketAddr, timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .context("connecting to control server")?;
        stream.set_read_timeout(Some(timeout)).ok();
        stream.set_write_timeout(Some(timeout)).ok();
        Self::from_stream(stream)
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut line = String::new();
        let n = match reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) if is_timeout(&e) => bail!("timed out waiting for the server hello banner"),
            Err(e) => return Err(e).context("reading server hello banner"),
        };
        if n == 0 {
            bail!("connection closed by server before the hello banner");
        }
        let hello = Json::parse(line.trim()).context("parsing server hello banner")?;
        if hello.str_field("hello")? != "femu-control-server" {
            bail!("endpoint did not identify as a femu control server");
        }
        Ok(Client { reader, writer: stream, hello, poisoned: false })
    }

    /// The server's hello banner (`hello`, `proto`, `version` fields).
    pub fn hello(&self) -> &Json {
        &self.hello
    }

    /// Adjust the per-operation I/O timeout after connecting (`None`
    /// blocks indefinitely).
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        self.writer.set_write_timeout(timeout)?;
        Ok(())
    }

    /// Send one request object; returns the `result` payload. After a
    /// response timeout the connection is poisoned (the late response
    /// would desync the framing) — reconnect to continue.
    pub fn call(&mut self, request: Json) -> Result<Json> {
        if self.poisoned {
            bail!("connection poisoned by an earlier response timeout; reconnect");
        }
        writeln!(self.writer, "{request}").context("sending request to control server")?;
        let mut line = String::new();
        let n = match self.reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) if is_timeout(&e) => {
                self.poisoned = true;
                bail!("timed out waiting for control-server response");
            }
            Err(e) => return Err(e).context("reading server response"),
        };
        if n == 0 {
            bail!("connection closed by server");
        }
        let resp = Json::parse(line.trim())?;
        if resp.get("ok")?.as_bool()? {
            Ok(resp.opt("result").cloned().unwrap_or(Json::Null))
        } else {
            Err(anyhow!("server error: {}", resp.str_field("error").unwrap_or("?")))
        }
    }

    /// Send a request with a `session` field added.
    pub fn call_on(&mut self, session: u64, request: Json) -> Result<Json> {
        self.call(with_field(request, "session", Json::from(session as i64))?)
    }

    /// Open a session; `opts` is `Json::Null` for the default config, or
    /// an object carrying `config` / `config_name`.
    pub fn open_session(&mut self, opts: Json) -> Result<u64> {
        let req = match opts {
            Json::Null => Json::obj(vec![]),
            obj @ Json::Obj(_) => obj,
            other => bail!("open_session opts must be an object or null, got {other:?}"),
        };
        let resp = self.call(with_field(req, "cmd", Json::from("session.open"))?)?;
        let id = resp.get("session")?.as_i64()?;
        u64::try_from(id).map_err(|_| anyhow!("server returned bad session id {id}"))
    }

    pub fn close_session(&mut self, session: u64) -> Result<()> {
        self.call(Json::obj(vec![
            ("cmd", Json::from("session.close")),
            ("session", Json::from(session as i64)),
        ]))?;
        Ok(())
    }

    /// Pipeline `requests` against one session in a single round trip;
    /// returns the raw `{results, completed}` payload.
    pub fn batch_on(&mut self, session: u64, requests: Vec<Json>) -> Result<Json> {
        self.call(Json::obj(vec![
            ("cmd", Json::from("batch")),
            ("session", Json::from(session as i64)),
            ("requests", Json::Arr(requests)),
        ]))
    }

    /// Arm event tracing on a session (proto v5). `categories` is a
    /// comma list (`"retire,irq"`) or `"all"`; returns the subscribe
    /// payload (`categories`, `capacity`, starting `cursor`).
    pub fn trace_subscribe(&mut self, session: u64, categories: &str) -> Result<Json> {
        self.call_on(
            session,
            Json::obj(vec![
                ("cmd", Json::from("trace.subscribe")),
                ("categories", Json::from(categories)),
            ]),
        )
    }

    /// Drain trace events recorded since `cursor` (proto v5); returns
    /// the raw `{events, next, skipped, dropped, total, digest}`
    /// payload. Stream by looping with the returned `next`.
    pub fn trace_read(&mut self, session: u64, cursor: u64) -> Result<Json> {
        self.call_on(
            session,
            Json::obj(vec![
                ("cmd", Json::from("trace.read")),
                ("cursor", Json::from(cursor as i64)),
            ]),
        )
    }

    /// Fetch the server's control-plane metrics (proto v6): `server`,
    /// `sessions`, `pool`, and `per_command` sections.
    pub fn metrics(&mut self) -> Result<Json> {
        self.call(Json::obj(vec![("cmd", Json::from("metrics"))]))
    }
}

fn with_field(v: Json, key: &str, val: Json) -> Result<Json> {
    match v {
        Json::Obj(mut m) => {
            m.insert(key.to_string(), val);
            Ok(Json::Obj(m))
        }
        other => bail!("expected a request object, got {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn spawn() -> (Server, Client) {
        let platform = Platform::new(PlatformConfig::default());
        let server = Server::spawn(platform, "127.0.0.1:0").unwrap();
        let client = Client::connect(server.addr()).unwrap();
        (server, client)
    }

    #[test]
    fn ping_pong() {
        let (server, mut client) = spawn();
        let r = client.call(Json::obj(vec![("cmd", Json::from("ping"))])).unwrap();
        assert_eq!(r.as_str().unwrap(), "pong");
        server.shutdown();
    }

    #[test]
    fn load_run_read_cycle() {
        let (server, mut client) = spawn();
        let src = r#"
            _start:
                la t0, out
                li t1, 77
                sw t1, 0(t0)
                ebreak
            .data
            out: .word 0
        "#;
        let loaded = client
            .call(Json::obj(vec![("cmd", Json::from("load_asm")), ("source", Json::from(src))]))
            .unwrap();
        let out_addr = loaded.get("symbols").unwrap().get("out").unwrap().as_i64().unwrap();
        let run = client.call(Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert_eq!(run.str_field("exit").unwrap(), "halted");
        let mem = client
            .call(Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(out_addr)),
                ("n", Json::from(1i64)),
            ]))
            .unwrap();
        assert_eq!(mem.as_arr().unwrap()[0].as_i64().unwrap(), 77);
        server.shutdown();
    }

    #[test]
    fn energy_and_perf_queries() {
        let (server, mut client) = spawn();
        client
            .call(Json::obj(vec![
                ("cmd", Json::from("load_asm")),
                ("source", Json::from("_start: li a0, 1\nebreak")),
            ]))
            .unwrap();
        client.call(Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        let perf = client.call(Json::obj(vec![("cmd", Json::from("perf"))])).unwrap();
        assert!(perf.get("cycles").unwrap().as_i64().unwrap() > 0);
        let energy = client
            .call(Json::obj(vec![
                ("cmd", Json::from("energy")),
                ("model", Json::from("heepocrates")),
            ]))
            .unwrap();
        assert!(energy.get("total_mj").unwrap().as_f64().unwrap() > 0.0);
        server.shutdown();
    }

    #[test]
    fn bad_requests_error_cleanly() {
        let (server, mut client) = spawn();
        assert!(client.call(Json::obj(vec![("cmd", Json::from("warp"))])).is_err());
        assert!(client
            .call(Json::obj(vec![("cmd", Json::from("read_mem")), ("addr", Json::from(0i64))]))
            .is_err());
        // connection still usable
        assert!(client.call(Json::obj(vec![("cmd", Json::from("ping"))])).is_ok());
        server.shutdown();
    }

    #[test]
    fn session_open_list_close_over_the_wire() {
        let (server, mut client) = spawn();
        let id = client.open_session(Json::Null).unwrap();
        assert!(id > 0);
        let listed = client.call(Json::obj(vec![("cmd", Json::from("session.list"))])).unwrap();
        let ids: Vec<i64> = listed
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.get("session").unwrap().as_i64().unwrap())
            .collect();
        assert!(ids.contains(&0) && ids.contains(&(id as i64)));
        client.close_session(id).unwrap();
        let err = client.call_on(id, Json::obj(vec![("cmd", Json::from("regs"))])).unwrap_err();
        assert!(format!("{err:#}").contains("unknown session"), "{err:#}");
        // the default session backs the session-less protocol: not closable
        let err = client.close_session(DEFAULT_SESSION).unwrap_err();
        assert!(format!("{err:#}").contains("cannot be closed"), "{err:#}");
        client.call(Json::obj(vec![("cmd", Json::from("regs"))])).unwrap();
        server.shutdown();
    }

    #[test]
    fn unknown_config_name_is_a_clean_error() {
        let (server, mut client) = spawn();
        let err = client
            .open_session(Json::obj(vec![("config_name", Json::from("warp-chip"))]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown config"), "{err:#}");
        server.shutdown();
    }

    #[test]
    fn session_open_selects_the_execution_backend() {
        let (server, mut client) = spawn();
        let resp = client
            .call(Json::obj(vec![
                ("cmd", Json::from("session.open")),
                ("backend", Json::from("blocks")),
            ]))
            .unwrap();
        assert_eq!(resp.str_field("backend").unwrap(), "blocks");
        let id = resp.get("session").unwrap().as_i64().unwrap() as u64;
        // the blocks session runs guests like any other
        client
            .call_on(
                id,
                Json::obj(vec![
                    ("cmd", Json::from("load_asm")),
                    ("source", Json::from("_start: li a0, 5\nebreak")),
                ]),
            )
            .unwrap();
        let run = client.call_on(id, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert_eq!(run.str_field("exit").unwrap(), "halted");
        // omitting the field keeps the config's backend (interp default)
        let resp = client.call(Json::obj(vec![("cmd", Json::from("session.open"))])).unwrap();
        assert_eq!(resp.str_field("backend").unwrap(), "interp");
        // a bogus backend is a clean error
        let err = client
            .open_session(Json::obj(vec![("backend", Json::from("jit"))]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown backend"), "{err:#}");
        server.shutdown();
    }

    #[test]
    fn error_responses_carry_a_machine_readable_kind() {
        let (server, _client) = spawn();
        // raw wire check: Client::call folds errors into anyhow, so read
        // the response object directly off a fresh socket
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // hello banner
        let mut ask = |req: &str| {
            writeln!(writer, "{req}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).unwrap()
        };
        let resp = ask("{\"cmd\":\"warp\"}");
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(resp.str_field("error_kind").unwrap(), "unknown_command");
        let resp = ask("{\"cmd\":\"read_mem\",\"addr\":-1,\"n\":1}");
        assert_eq!(resp.str_field("error_kind").unwrap(), "out_of_range");
        // a non-protocol failure carries the error text but no kind
        let resp = ask("{\"cmd\":\"load_asm\",\"source\":\"bogus$\"}");
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert!(resp.opt("error_kind").is_none());
        server.shutdown();
    }

    #[test]
    fn snapshot_load_failures_carry_distinct_error_kinds() {
        let (server, _client) = spawn();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap(); // hello banner
        let mut ask = |req: String| {
            writeln!(writer, "{req}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).unwrap()
        };
        let good = crate::coordinator::Platform::new(crate::config::PlatformConfig::default())
            .snapshot();

        // checksum mismatch: flip one payload bit and re-hex
        let mut corrupt = good.as_bytes().to_vec();
        *corrupt.last_mut().unwrap() ^= 0x01;
        let hex: String = corrupt.iter().map(|b| format!("{b:02x}")).collect();
        let resp = ask(format!("{{\"cmd\":\"snapshot.restore\",\"hex\":\"{hex}\"}}"));
        assert!(!resp.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(resp.str_field("error_kind").unwrap(), "snapshot_checksum_mismatch");
        assert!(resp.str_field("error").unwrap().contains("checksum"));

        // version mismatch: stamp a bogus format version
        let mut stale = good.as_bytes().to_vec();
        stale[8] = 0x7E;
        let hex: String = stale.iter().map(|b| format!("{b:02x}")).collect();
        let resp = ask(format!("{{\"cmd\":\"snapshot.restore\",\"hex\":\"{hex}\"}}"));
        assert_eq!(resp.str_field("error_kind").unwrap(), "snapshot_version_mismatch");
        assert!(resp.str_field("error").unwrap().contains("version"));
        server.shutdown();
    }

    #[test]
    fn hello_banner_is_versioned_and_asserted() {
        let (server, client) = spawn();
        let hello = client.hello();
        assert_eq!(hello.str_field("hello").unwrap(), "femu-control-server");
        assert_eq!(hello.get("proto").unwrap().as_i64().unwrap(), PROTO_VERSION as i64);
        assert_eq!(hello.str_field("version").unwrap(), env!("CARGO_PKG_VERSION"));
        server.shutdown();
    }

    #[test]
    fn session_fork_clones_a_warmed_session() {
        let (server, mut client) = spawn();
        let src = client.open_session(Json::Null).unwrap();
        let loaded = client
            .call_on(
                src,
                Json::obj(vec![
                    ("cmd", Json::from("load_asm")),
                    (
                        "source",
                        Json::from(
                            "_start:\n la t0, out\n li t1, 1234\n sw t1, 0(t0)\n ebreak\n.data\nout: .word 0",
                        ),
                    ),
                ]),
            )
            .unwrap();
        let out_addr = loaded.get("symbols").unwrap().get("out").unwrap().as_i64().unwrap();
        client.call_on(src, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();

        let forked = client
            .call(Json::obj(vec![
                ("cmd", Json::from("session.fork")),
                ("session", Json::from(src as i64)),
            ]))
            .unwrap();
        let fork_id = forked.get("session").unwrap().as_i64().unwrap() as u64;
        assert_ne!(fork_id, src);
        assert_eq!(forked.get("forked_from").unwrap().as_i64().unwrap(), src as i64);
        assert!(forked.str_field("config").unwrap().starts_with("fork:"));

        // the fork saw the warmed state...
        let read = |c: &mut Client, session: u64| {
            c.call_on(
                session,
                Json::obj(vec![
                    ("cmd", Json::from("read_mem")),
                    ("addr", Json::from(out_addr)),
                    ("n", Json::from(1i64)),
                ]),
            )
            .unwrap()
            .as_arr()
            .unwrap()[0]
                .as_i64()
                .unwrap()
        };
        assert_eq!(read(&mut client, fork_id), 1234);
        // ...and diverges independently of the source
        client
            .call_on(
                fork_id,
                Json::obj(vec![
                    ("cmd", Json::from("write_mem")),
                    ("addr", Json::from(out_addr)),
                    ("values", Json::arr_i32(&[-1])),
                ]),
            )
            .unwrap();
        assert_eq!(read(&mut client, fork_id), -1);
        assert_eq!(read(&mut client, src), 1234);
        server.shutdown();
    }

    #[test]
    fn trace_streaming_over_the_wire() {
        let (server, mut client) = spawn();
        let id = client.open_session(Json::Null).unwrap();
        let sub = client.trace_subscribe(id, "retire").unwrap();
        assert_eq!(sub.str_field("categories").unwrap(), "retire");
        client
            .call_on(
                id,
                Json::obj(vec![
                    ("cmd", Json::from("load_asm")),
                    ("source", Json::from("_start: li a0, 1\nli a1, 2\nebreak")),
                ]),
            )
            .unwrap();
        client.call_on(id, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        // stream with the cursor protocol until drained
        let mut cursor = 0u64;
        let mut seen = 0usize;
        loop {
            let page = client.trace_read(id, cursor).unwrap();
            let events = page.get("events").unwrap().as_arr().unwrap().len();
            seen += events;
            cursor = page.get("next").unwrap().as_i64().unwrap() as u64;
            if events == 0 {
                break;
            }
        }
        assert_eq!(seen, 3, "three retires expected");
        let stop =
            client.call_on(id, Json::obj(vec![("cmd", Json::from("trace.stop"))])).unwrap();
        assert_eq!(stop.get("total").unwrap().as_i64().unwrap(), 3);
        // tracing on one session never arms another: the default session
        // rejects reads
        assert!(client.call(Json::obj(vec![("cmd", Json::from("trace.read"))])).is_err());
        server.shutdown();
    }

    #[test]
    fn metrics_command_reports_counters() {
        let (server, mut client) = spawn();
        client.call(Json::obj(vec![("cmd", Json::from("ping"))])).unwrap();
        client
            .call(Json::obj(vec![
                ("cmd", Json::from("load_asm")),
                ("source", Json::from("_start: li a0, 1\nebreak")),
            ]))
            .unwrap();
        client.call(Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert!(client.call(Json::obj(vec![("cmd", Json::from("warp"))])).is_err());

        let m = client.metrics().unwrap();
        let srv = m.get("server").unwrap();
        assert!(srv.get("commands").unwrap().as_i64().unwrap() >= 4);
        assert!(srv.get("errors").unwrap().as_i64().unwrap() >= 1);
        assert!(srv.get("connections_opened").unwrap().as_i64().unwrap() >= 1);
        assert!(srv.get("bytes_in").unwrap().as_i64().unwrap() > 0);
        assert!(srv.get("bytes_out").unwrap().as_i64().unwrap() > 0);
        let pool = m.get("pool").unwrap();
        // ping and metrics run inline; load_asm + run + warp hit the pool
        assert!(pool.get("submitted").unwrap().as_i64().unwrap() >= 3);
        assert_eq!(m.get("sessions").unwrap().get("live").unwrap().as_i64().unwrap(), 1);
        let per = m.get("per_command").unwrap();
        assert_eq!(per.get("run").unwrap().get("calls").unwrap().as_i64().unwrap(), 1);
        assert_eq!(per.get("warp").unwrap().get("errors").unwrap().as_i64().unwrap(), 1);
        assert!(
            per.get("run").unwrap().get("latency_us").unwrap().get("count").unwrap()
                .as_i64()
                .unwrap()
                == 1
        );

        // the prometheus text form carries the same counters
        let prom = client
            .call(Json::obj(vec![
                ("cmd", Json::from("metrics")),
                ("format", Json::from("prometheus")),
            ]))
            .unwrap();
        let text = prom.str_field("text").unwrap();
        assert!(text.contains("femu_commands_total"), "{text}");
        assert!(text.contains("femu_command_calls_total{cmd=\"run\"} 1"), "{text}");
        assert!(text.contains("femu_pool_queue_depth"), "{text}");
        // a bad format is a clean error
        assert!(client
            .call(Json::obj(vec![
                ("cmd", Json::from("metrics")),
                ("format", Json::from("xml")),
            ]))
            .is_err());
        server.shutdown();
    }

    #[test]
    fn session_list_is_enriched_over_the_wire() {
        let (server, mut client) = spawn();
        let id = client.open_session(Json::Null).unwrap();
        client
            .call_on(
                id,
                Json::obj(vec![
                    ("cmd", Json::from("load_asm")),
                    ("source", Json::from("_start: li a0, 1\nli a1, 2\nebreak")),
                ]),
            )
            .unwrap();
        client.call_on(id, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        let listed = client.call(Json::obj(vec![("cmd", Json::from("session.list"))])).unwrap();
        let entry = listed
            .as_arr()
            .unwrap()
            .iter()
            .find(|s| s.get("session").unwrap().as_i64().unwrap() == id as i64)
            .unwrap()
            .clone();
        assert!(!entry.get("busy").unwrap().as_bool().unwrap());
        assert_eq!(entry.str_field("backend").unwrap(), "interp");
        assert_eq!(entry.get("instret").unwrap().as_i64().unwrap(), 3);
        assert!(entry.get("cycles").unwrap().as_i64().unwrap() > 0);
        assert!(entry.get("last_command_unix_ms").unwrap().as_i64().unwrap() > 0);
        server.shutdown();
    }

    #[test]
    fn profile_over_the_wire_conserves_cycles() {
        let (server, mut client) = spawn();
        let id = client.open_session(Json::Null).unwrap();
        client
            .call_on(
                id,
                Json::obj(vec![
                    ("cmd", Json::from("load_asm")),
                    ("source", Json::from("_start: li a0, 5\nli a1, 7\nadd a2, a0, a1\nebreak")),
                ]),
            )
            .unwrap();
        client.call_on(id, Json::obj(vec![("cmd", Json::from("profile.start"))])).unwrap();
        client.call_on(id, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        let prof =
            client.call_on(id, Json::obj(vec![("cmd", Json::from("profile.read"))])).unwrap();
        assert_eq!(prof.get("retired").unwrap().as_i64().unwrap(), 4);
        let flat: i64 = prof
            .get("functions")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|f| f.get("flat_cycles").unwrap().as_i64().unwrap())
            .sum();
        assert_eq!(flat, prof.get("attributed_cycles").unwrap().as_i64().unwrap());
        // profiling on one session never arms another
        assert!(client
            .call(Json::obj(vec![("cmd", Json::from("profile.read"))]))
            .is_err());
        let stop =
            client.call_on(id, Json::obj(vec![("cmd", Json::from("profile.stop"))])).unwrap();
        assert_eq!(stop.get("retired").unwrap().as_i64().unwrap(), 4);
        server.shutdown();
    }

    #[test]
    fn client_timeout_fails_fast_against_a_mute_endpoint() {
        // a listener that accepts but never sends the hello banner
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            let (_stream, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_millis(500));
        });
        let t0 = std::time::Instant::now();
        let err = Client::connect_with_timeout(addr, Duration::from_millis(100)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_millis(450), "timeout must bound the wait");
        assert!(format!("{err:#}").contains("timed out"), "{err:#}");
        hold.join().unwrap();
    }

    #[test]
    fn client_reports_connection_closed_by_server() {
        let (server, mut client) = spawn();
        assert!(client.call(Json::obj(vec![("cmd", Json::from("ping"))])).is_ok());
        server.shutdown();
        let err = client.call(Json::obj(vec![("cmd", Json::from("ping"))])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("connection closed by server")
                || msg.contains("sending request")
                || msg.contains("reading server response"),
            "expected a connection-level error, got: {msg}"
        );
        assert!(!msg.contains("parsing"), "must not surface a JSON parse error: {msg}");
    }
}
