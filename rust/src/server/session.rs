//! Session management for the control server: each connected client can
//! open any number of private [`Platform`]s, so concurrent users never
//! contend on each other's emulator state (DESIGN.md §9).
//!
//! A [`Session`] owns one platform behind a `Mutex`; the [`SessionTable`]
//! maps session ids to live sessions with an LRU-capped population and
//! idle reaping. Session 0 is the *default session* — the platform the
//! server was spawned with. It is exempt from eviction and reaping so the
//! original session-less protocol (`{"cmd":"run"}` with no `session`
//! field) keeps working unchanged.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, TryLockError};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{anyhow, bail, Result};

use crate::config::PlatformConfig;
use crate::coordinator::Platform;
use crate::metrics::Counter;
use crate::util::Json;

/// The id of the default session (the platform `Server::spawn` received).
pub const DEFAULT_SESSION: u64 = 0;

// Sessions hand their platform between pool worker threads, which needs
// `Platform: Send`. This used to be asserted with an
// `unsafe impl Send` wrapper justified by a stale comment about a
// non-`Send` dependency the crate does not have. The audit conclusion:
// every type inside `Platform` is plain owned data, and the one dyn
// boundary ([`crate::exec::ExecBackend`]) carries `Send` as a supertrait
// — so the property holds in safe Rust, and the crate can (and does)
// `#![deny(unsafe_code)]` with no exceptions. This assertion turns any
// future regression (say, an `Rc` slipping into a peripheral) into a
// compile error here instead of an unsound wrapper.
const _: fn() = || {
    fn assert_send<T: Send>() {}
    assert_send::<Platform>();
};

/// One client-owned platform instance.
pub struct Session {
    id: u64,
    /// Human-readable config provenance (named config or inline name).
    config_label: String,
    platform: Mutex<Platform>,
    /// Set when the session is closed or the server shuts down; a
    /// long `run` in flight observes it at its next slice boundary and
    /// returns with exit `"interrupted"`.
    cancel: AtomicBool,
    created: Instant,
    last_used: Mutex<Instant>,
    /// Wall-clock timestamp (unix ms) of the last command on this
    /// session; 0 until the first command. `session.list` reports it so
    /// operators can correlate sessions with external logs.
    last_cmd_unix_ms: AtomicU64,
}

impl Session {
    fn new(id: u64, config_label: String, platform: Platform) -> Self {
        Self {
            id,
            config_label,
            platform: Mutex::new(platform),
            cancel: AtomicBool::new(false),
            created: Instant::now(),
            last_used: Mutex::new(Instant::now()),
            last_cmd_unix_ms: AtomicU64::new(0),
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn config_label(&self) -> &str {
        &self.config_label
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Run `f` with exclusive access to the session's platform. The
    /// session's idle clock restarts when the command finishes, so a
    /// long-running command never makes its own session reapable.
    pub fn with_platform<R>(&self, f: impl FnOnce(&mut Platform) -> R) -> Result<R> {
        let mut guard = self
            .platform
            .lock()
            .map_err(|_| anyhow!("session {} platform poisoned by an earlier panic", self.id))?;
        let r = f(&mut guard);
        drop(guard);
        self.touch();
        Ok(r)
    }

    /// A session is busy while a command holds its platform lock.
    pub fn busy(&self) -> bool {
        matches!(self.platform.try_lock(), Err(TryLockError::WouldBlock))
    }

    fn touch(&self) {
        *self.last_used.lock().unwrap_or_else(|p| p.into_inner()) = Instant::now();
        let unix_ms = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        self.last_cmd_unix_ms.store(unix_ms, Ordering::Relaxed);
    }

    fn idle_for(&self) -> Duration {
        self.last_used.lock().unwrap_or_else(|p| p.into_inner()).elapsed()
    }

    pub fn uptime(&self) -> Duration {
        self.created.elapsed()
    }
}

/// Lifecycle counters for the [`SessionTable`], exposed through the
/// server's `metrics` command. Monotonic over the server's lifetime.
#[derive(Debug, Default)]
pub struct SessionStats {
    /// Sessions opened (excluding the default session 0).
    pub opened: Counter,
    /// Sessions closed by explicit `session.close`.
    pub closed: Counter,
    /// Sessions evicted by LRU pressure on `session.open`.
    pub evicted: Counter,
    /// Sessions dropped by the idle reaper.
    pub reaped: Counter,
}

/// The live-session table: LRU-capped, idle-reaped.
pub struct SessionTable {
    /// Capacity including the default session.
    max_sessions: usize,
    idle_timeout: Duration,
    next_id: AtomicU64,
    sessions: Mutex<BTreeMap<u64, Arc<Session>>>,
    stats: SessionStats,
}

impl SessionTable {
    /// Build a table seeded with `default_platform` as session 0.
    pub fn new(default_platform: Platform, max_sessions: usize, idle_timeout: Duration) -> Self {
        let mut map = BTreeMap::new();
        map.insert(
            DEFAULT_SESSION,
            Arc::new(Session::new(DEFAULT_SESSION, "default".into(), default_platform)),
        );
        Self {
            max_sessions: max_sessions.max(1),
            // a zero timeout would reap every session before its first
            // command; clamp to something strictly positive
            idle_timeout: idle_timeout.max(Duration::from_millis(1)),
            next_id: AtomicU64::new(1),
            sessions: Mutex::new(map),
            stats: SessionStats::default(),
        }
    }

    /// Lifecycle counters (opened / closed / evicted / reaped).
    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Open a new session. At capacity, the least-recently-used *idle*
    /// session (never session 0) is evicted to make room; if every slot
    /// is busy the open is refused — that is the backpressure signal.
    pub fn open(&self, platform: Platform, config_label: String) -> Result<Arc<Session>> {
        let mut map = self.lock_map();
        Self::reap_locked(&mut map, self.idle_timeout, &self.stats);
        if map.len() >= self.max_sessions {
            let lru = map
                .values()
                .filter(|s| s.id() != DEFAULT_SESSION && !s.busy())
                .min_by_key(|s| std::cmp::Reverse(s.idle_for()))
                .map(|s| s.id());
            match lru {
                Some(id) => {
                    if let Some(evicted) = map.remove(&id) {
                        evicted.cancel();
                        self.stats.evicted.inc();
                    }
                }
                None => bail!(
                    "server at session capacity ({} of {}, all busy); \
                     close a session or retry",
                    map.len(),
                    self.max_sessions
                ),
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let session = Arc::new(Session::new(id, config_label, platform));
        map.insert(id, session.clone());
        self.stats.opened.inc();
        Ok(session)
    }

    /// Look up a session and restart its idle clock.
    pub fn get(&self, id: u64) -> Result<Arc<Session>> {
        match self.lock_map().get(&id) {
            Some(s) => {
                s.touch();
                Ok(s.clone())
            }
            None => bail!("unknown session {id} (never opened, closed, evicted, or reaped)"),
        }
    }

    /// Close a session. An in-flight command on it is cancelled at its
    /// next slice boundary and still completes its response. Session 0
    /// is not closable: it backs the session-less protocol and can never
    /// be recreated (ids only count up).
    pub fn close(&self, id: u64) -> Result<()> {
        if id == DEFAULT_SESSION {
            bail!("the default session 0 cannot be closed");
        }
        match self.lock_map().remove(&id) {
            Some(s) => {
                s.cancel();
                self.stats.closed.inc();
                Ok(())
            }
            None => bail!("unknown session {id}"),
        }
    }

    /// Drop idle sessions older than the idle timeout (never session 0,
    /// never a busy session). Called from the server's accept-loop tick
    /// and on every `open`.
    pub fn reap_idle(&self) {
        let mut map = self.lock_map();
        Self::reap_locked(&mut map, self.idle_timeout, &self.stats);
    }

    fn reap_locked(map: &mut BTreeMap<u64, Arc<Session>>, timeout: Duration, stats: &SessionStats) {
        map.retain(|&id, s| {
            let keep = id == DEFAULT_SESSION || s.busy() || s.idle_for() < timeout;
            if !keep {
                s.cancel();
                stats.reaped.inc();
            }
            keep
        });
    }

    pub fn len(&self) -> usize {
        self.lock_map().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Protocol view of the table (for `session.list`). Guest-state
    /// fields (backend, instret, cycles) come from a non-blocking peek at
    /// each platform and are omitted for a busy session — `session.list`
    /// must never queue behind a long `run`.
    pub fn describe(&self) -> Json {
        Json::Arr(
            self.lock_map()
                .values()
                .map(|s| {
                    let mut fields = vec![
                        ("session", Json::from(s.id() as i64)),
                        ("config", Json::from(s.config_label())),
                        ("uptime_s", Json::from(s.uptime().as_secs() as i64)),
                        ("idle_s", Json::from(s.idle_for().as_secs() as i64)),
                        (
                            "last_command_unix_ms",
                            Json::from(s.last_cmd_unix_ms.load(Ordering::Relaxed) as i64),
                        ),
                    ];
                    match s.platform.try_lock() {
                        Ok(p) => {
                            fields.push(("busy", Json::from(false)));
                            fields.push((
                                "backend",
                                Json::from(p.dbg.soc.backend_kind().name()),
                            ));
                            fields.push((
                                "instret",
                                Json::from(p.dbg.soc.stats.instructions as i64),
                            ));
                            fields.push(("cycles", Json::from(p.dbg.soc.now as i64)));
                        }
                        Err(_) => fields.push(("busy", Json::from(true))),
                    }
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    /// Remove every session (cancelling in-flight runs) and hand them
    /// back in id order for deterministic teardown.
    pub fn drain(&self) -> Vec<Arc<Session>> {
        let mut map = self.lock_map();
        let drained: Vec<Arc<Session>> = std::mem::take(&mut *map).into_values().collect();
        for s in &drained {
            s.cancel();
        }
        drained
    }

    fn lock_map(&self) -> std::sync::MutexGuard<'_, BTreeMap<u64, Arc<Session>>> {
        self.sessions.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Named platform configurations a client can instantiate sessions from.
/// `"default"` is always present (the config the server was spawned
/// with); `femu serve --configs DIR` registers one entry per TOML file.
pub struct ConfigRegistry {
    named: BTreeMap<String, PlatformConfig>,
}

impl ConfigRegistry {
    pub fn new(default_cfg: PlatformConfig) -> Self {
        let mut named = BTreeMap::new();
        named.insert("default".to_string(), default_cfg);
        Self { named }
    }

    pub fn register(&mut self, name: impl Into<String>, cfg: PlatformConfig) {
        self.named.insert(name.into(), cfg);
    }

    pub fn names(&self) -> Vec<&str> {
        self.named.keys().map(String::as_str).collect()
    }

    /// Resolve the config a request asks for: `config` (inline TOML
    /// text) or `config_name` (registered name), defaulting to
    /// `"default"`. Returns the config plus a provenance label.
    pub fn resolve(&self, req: &Json) -> Result<(PlatformConfig, String)> {
        match (req.opt("config"), req.opt("config_name")) {
            (Some(_), Some(_)) => bail!("pass either `config` or `config_name`, not both"),
            (Some(inline), None) => {
                let cfg = PlatformConfig::parse(inline.as_str()?)?;
                let label = format!("inline:{}", cfg.name);
                Ok((cfg, label))
            }
            (None, Some(name)) => {
                let name = name.as_str()?;
                let cfg = self.named.get(name).ok_or_else(|| {
                    anyhow!("unknown config `{name}` (registered: {})", self.names().join(", "))
                })?;
                Ok((cfg.clone(), name.to_string()))
            }
            (None, None) => {
                Ok((self.named["default"].clone(), "default".to_string()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(max: usize, timeout_ms: u64) -> SessionTable {
        SessionTable::new(
            Platform::new(PlatformConfig::default()),
            max,
            Duration::from_millis(timeout_ms),
        )
    }

    fn open(t: &SessionTable) -> u64 {
        t.open(Platform::new(PlatformConfig::default()), "default".into()).unwrap().id()
    }

    #[test]
    fn open_get_close_roundtrip() {
        let t = table(4, 60_000);
        let id = open(&t);
        assert!(id > DEFAULT_SESSION);
        assert_eq!(t.get(id).unwrap().id(), id);
        t.close(id).unwrap();
        assert!(t.get(id).is_err());
        assert!(t.close(id).is_err());
        // default session always reachable, never closable
        assert_eq!(t.get(DEFAULT_SESSION).unwrap().id(), DEFAULT_SESSION);
        assert!(t.close(DEFAULT_SESSION).is_err());
    }

    #[test]
    fn lru_eviction_spares_default_and_recently_used() {
        let t = table(3, 60_000); // capacity includes session 0
        let a = open(&t);
        std::thread::sleep(Duration::from_millis(10));
        let b = open(&t);
        // touch a so b becomes the LRU
        t.get(a).unwrap();
        let c = open(&t);
        assert!(t.get(b).is_err(), "LRU session must be evicted");
        assert!(t.get(a).is_ok());
        assert!(t.get(c).is_ok());
        assert!(t.get(DEFAULT_SESSION).is_ok());
    }

    #[test]
    fn busy_sessions_are_not_evicted() {
        let t = table(2, 60_000);
        let a = t.open(Platform::new(PlatformConfig::default()), "default".into()).unwrap();
        let a2 = a.clone();
        let _r = a2
            .with_platform(|_| {
                // while a's platform is locked, opening must refuse
                assert!(a.busy());
                let err = t
                    .open(Platform::new(PlatformConfig::default()), "default".into())
                    .unwrap_err();
                assert!(format!("{err:#}").contains("capacity"), "{err:#}");
            })
            .unwrap();
        // once idle again the slot can be reclaimed
        let c = open(&t);
        assert!(t.get(c).is_ok());
    }

    #[test]
    fn idle_sessions_reaped_but_not_default() {
        let t = table(8, 20);
        let id = open(&t);
        std::thread::sleep(Duration::from_millis(60));
        t.reap_idle();
        assert!(t.get(id).is_err(), "idle session must be reaped");
        assert!(t.get(DEFAULT_SESSION).is_ok());
    }

    #[test]
    fn describe_reports_uptime_backend_and_instret() {
        let t = table(4, 60_000);
        let id = open(&t);
        t.get(id).unwrap(); // touch: stamps last_command_unix_ms
        let listed = t.describe();
        let arr = listed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        for entry in arr {
            assert!(entry.opt("uptime_s").is_some());
            assert!(entry.opt("idle_s").is_some());
            assert!(!entry.get("busy").unwrap().as_bool().unwrap());
            // idle sessions expose guest state
            assert_eq!(entry.str_field("backend").unwrap(), "interp");
            assert_eq!(entry.get("instret").unwrap().as_i64().unwrap(), 0);
        }
        let touched = arr
            .iter()
            .find(|e| e.get("session").unwrap().as_i64().unwrap() == id as i64)
            .unwrap();
        assert!(touched.get("last_command_unix_ms").unwrap().as_i64().unwrap() > 0);
    }

    #[test]
    fn lifecycle_counters_track_open_close_evict_reap() {
        let t = table(2, 20);
        let a = open(&t);
        t.close(a).unwrap();
        let _b = open(&t);
        std::thread::sleep(Duration::from_millis(5));
        let _c = open(&t); // at capacity: evicts b (idle LRU)
        std::thread::sleep(Duration::from_millis(60));
        t.reap_idle(); // c idles out
        let s = t.stats();
        assert_eq!(s.opened.get(), 3);
        assert_eq!(s.closed.get(), 1);
        assert_eq!(s.evicted.get(), 1);
        assert_eq!(s.reaped.get(), 1);
    }

    #[test]
    fn registry_resolves_inline_named_and_default() {
        let mut reg = ConfigRegistry::new(PlatformConfig::default());
        let chip = PlatformConfig::parse("name = \"chip\"").unwrap();
        reg.register("chip", chip);

        let (cfg, label) = reg.resolve(&Json::obj(vec![])).unwrap();
        assert_eq!(label, "default");
        assert_eq!(cfg.name, "x-heep-femu");

        let (cfg, label) = reg
            .resolve(&Json::obj(vec![("config_name", Json::from("chip"))]))
            .unwrap();
        assert_eq!((cfg.name.as_str(), label.as_str()), ("chip", "chip"));

        let (cfg, label) = reg
            .resolve(&Json::obj(vec![("config", Json::from("name = \"mine\""))]))
            .unwrap();
        assert_eq!((cfg.name.as_str(), label.as_str()), ("mine", "inline:mine"));

        assert!(reg.resolve(&Json::obj(vec![("config_name", Json::from("nope"))])).is_err());
        assert!(reg
            .resolve(&Json::obj(vec![
                ("config", Json::from("")),
                ("config_name", Json::from("chip")),
            ]))
            .is_err());
    }
}
