//! The control-server wire protocol: request field validation and the
//! per-platform command interpreter.
//!
//! One JSON object per line, request/response. Every numeric field is
//! range-checked *before* it is cast — a negative `max_cycles` or an
//! `addr` outside the 32-bit bus is a protocol error carried back to the
//! client, never a silent wrap or a debug-build panic. Command execution
//! here is pure of any transport or session concern: it takes `&mut
//! Platform` plus the parsed request and returns the `result` payload
//! (`server/mod.rs` owns dispatch, sessions, and the worker pool).

use anyhow::{anyhow, bail, Result};

use crate::config::PlatformConfig;
use crate::coordinator::{experiments, AppExit, Fleet, Platform};
use crate::energy::EnergyModel;
use crate::snapshot::PlatformSnapshot;
use crate::util::Json;
use crate as femu;

/// Cap on `read_mem` / `write_mem` / `disasm` word counts: a protocol
/// guard against one request pinning a worker on a gigabyte transfer.
pub const MAX_TRANSFER_WORDS: usize = 1 << 20;

/// Cap on sub-requests per `batch`.
pub const MAX_BATCH_REQUESTS: usize = 1024;

/// Cap on the hex payload `snapshot.restore` accepts (a full platform
/// image is ~tens of MiB of hex at worst; this guards against a request
/// pinning a worker on gigabytes of decode).
pub const MAX_SNAPSHOT_HEX: usize = 1 << 28;

/// Cycles a `run` executes between cancellation checks. Small enough
/// that `session.close` and server shutdown interrupt a runaway guest in
/// well under a second; large enough that the re-entry overhead on the
/// event-driven run loop is unmeasurable.
pub const RUN_SLICE_CYCLES: u64 = 2_000_000;

/// Default `run` budget when the request does not carry `max_cycles`.
pub const DEFAULT_RUN_BUDGET: u64 = 1 << 33;

// ---------------------------------------------------------------------
// field validation
// ---------------------------------------------------------------------

/// A required 32-bit bus address / value field.
pub fn u32_field(req: &Json, key: &str) -> Result<u32> {
    let v = req.get(key)?.as_i64()?;
    u32::try_from(v).map_err(|_| anyhow!("`{key}` {v} out of range (want 0..=4294967295)"))
}

/// An optional u32 field with a default.
pub fn opt_u32_field(req: &Json, key: &str, default: u32) -> Result<u32> {
    match req.opt(key) {
        None => Ok(default),
        Some(v) => {
            let v = v.as_i64()?;
            u32::try_from(v)
                .map_err(|_| anyhow!("`{key}` {v} out of range (want 0..=4294967295)"))
        }
    }
}

/// A required word-count field, capped at [`MAX_TRANSFER_WORDS`].
pub fn count_field(req: &Json, key: &str) -> Result<usize> {
    let v = req.get(key)?.as_i64()?;
    if v < 0 {
        bail!("`{key}` must be non-negative, got {v}");
    }
    let n = v as usize;
    if n > MAX_TRANSFER_WORDS {
        bail!("`{key}` {n} exceeds the {MAX_TRANSFER_WORDS}-word transfer cap");
    }
    Ok(n)
}

/// The `run` budget: optional, non-negative (a negative budget must not
/// wrap through `as u64` into a ~2^64-cycle run).
pub fn budget_field(req: &Json) -> Result<u64> {
    match req.opt("max_cycles") {
        None => Ok(DEFAULT_RUN_BUDGET),
        Some(v) => {
            let b = v.as_i64()?;
            if b < 0 {
                bail!("`max_cycles` must be non-negative, got {b}");
            }
            Ok(b as u64)
        }
    }
}

/// An optional seed field (any integer; reinterpreted as u64 bits).
pub fn seed_field(req: &Json, default: u64) -> Result<u64> {
    match req.opt("seed") {
        None => Ok(default),
        Some(v) => Ok(v.as_i64()? as u64),
    }
}

/// A memory-word value: accepts the i32 range and the u32 range (the
/// bus carries 32-bit words; `read_mem` reports them signed), rejecting
/// anything that would silently truncate through `as i32`.
pub fn word_value(v: &Json) -> Result<i32> {
    let v = v.as_i64()?;
    if !(i32::MIN as i64..=u32::MAX as i64).contains(&v) {
        bail!("memory value {v} does not fit in 32 bits");
    }
    Ok(v as i32) // identical low-32 bit pattern for both accepted ranges
}

/// Check that `words` 32-bit words starting at `addr` stay inside the
/// 32-bit address space (checked arithmetic — no wrap, no panic).
pub fn check_span(addr: u32, words: usize) -> Result<()> {
    let end = addr as u64 + words as u64 * 4;
    if end > 1 << 32 {
        bail!("address range {addr:#x}+{words} words overflows the 32-bit bus");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// per-platform command execution
// ---------------------------------------------------------------------

/// Execute one platform-bound command against `p`. `cancelled` is polled
/// between `run` slices so session close / server shutdown interrupt
/// long runs at a bounded latency.
pub fn execute_platform_cmd(
    p: &mut Platform,
    cmd: &str,
    req: &Json,
    cancelled: &dyn Fn() -> bool,
) -> Result<Json> {
    match cmd {
        "ping" => Ok(Json::from("pong")),
        "load_asm" => {
            let src = req.str_field("source")?;
            let prog = p.dbg.load_source(src)?;
            let symbols = Json::Obj(
                prog.symbols
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                    .collect(),
            );
            Ok(Json::obj(vec![
                ("entry", Json::from(prog.entry as i64)),
                ("text_words", Json::from(prog.text.len() as i64)),
                ("symbols", symbols),
            ]))
        }
        "run" => run_sliced(p, budget_field(req)?, cancelled),
        "reset" => {
            p.dbg.reset(opt_u32_field(req, "entry", 0)?);
            Ok(Json::Null)
        }
        "regs" => Ok(Json::Arr(
            p.dbg.soc.cpu.regs.iter().map(|&r| Json::Num(r as i32 as f64)).collect(),
        )),
        "read_mem" => {
            let addr = u32_field(req, "addr")?;
            let n = count_field(req, "n")?;
            check_span(addr, n)?;
            let vals = p.dbg.read_i32_slice(addr, n)?;
            Ok(Json::arr_i32(&vals))
        }
        "write_mem" => {
            let addr = u32_field(req, "addr")?;
            let values = req.get("values")?.as_arr()?;
            if values.len() > MAX_TRANSFER_WORDS {
                bail!(
                    "`values` length {} exceeds the {MAX_TRANSFER_WORDS}-word transfer cap",
                    values.len()
                );
            }
            check_span(addr, values.len())?;
            let vals: Vec<i32> = values.iter().map(word_value).collect::<Result<_>>()?;
            p.dbg.write_i32_slice(addr, &vals)?;
            Ok(Json::Null)
        }
        "disasm" => {
            let addr = u32_field(req, "addr")?;
            let n = count_field(req, "n")?;
            check_span(addr, n)?;
            let words: Vec<u32> = (0..n)
                .map(|i| {
                    let a = addr
                        .checked_add((i as u32) * 4)
                        .ok_or_else(|| anyhow!("disasm address overflows at word {i}"))?;
                    p.dbg.read32(a)
                })
                .collect::<Result<_>>()?;
            Ok(Json::Str(femu::isa::listing(&words, addr)))
        }
        "step" => {
            let stop = p.dbg.step();
            Ok(Json::obj(vec![
                ("stop", Json::Str(format!("{stop:?}"))),
                ("pc", Json::from(p.dbg.pc() as i64)),
            ]))
        }
        "add_breakpoint" => {
            p.dbg.add_breakpoint(u32_field(req, "addr")?);
            Ok(Json::Null)
        }
        "remove_breakpoint" => {
            p.dbg.remove_breakpoint(u32_field(req, "addr")?);
            Ok(Json::Null)
        }
        "uart" => {
            let bytes = p.dbg.uart();
            Ok(Json::Str(String::from_utf8_lossy(&bytes).into_owned()))
        }
        "snapshot.save" => {
            let snap = p.snapshot();
            Ok(Json::obj(vec![
                ("version", Json::from(crate::snapshot::VERSION as i64)),
                ("bytes", Json::from(snap.size_bytes() as i64)),
                ("cycles", Json::from(p.dbg.soc.now as i64)),
                ("snapshot", Json::Str(snap.to_hex())),
            ]))
        }
        "snapshot.restore" => {
            let hex = req.str_field("snapshot")?;
            if hex.len() > MAX_SNAPSHOT_HEX {
                bail!("`snapshot` hex of {} bytes exceeds the {MAX_SNAPSHOT_HEX}-byte cap", hex.len());
            }
            let snap = PlatformSnapshot::from_hex(hex)?;
            // transactional: a client-supplied image that fails mid-decode
            // must not leave the session half-restored
            p.restore_transactional(&snap)?;
            Ok(Json::obj(vec![("cycles", Json::from(p.dbg.soc.now as i64))]))
        }
        "perf" => {
            let snap = p.perf_snapshot();
            let mut domains = std::collections::BTreeMap::new();
            for (d, c) in snap.domains() {
                domains.insert(
                    d.to_string(),
                    Json::obj(vec![
                        ("active", Json::from(c.counts[0] as i64)),
                        ("clock_gated", Json::from(c.counts[1] as i64)),
                        ("power_gated", Json::from(c.counts[2] as i64)),
                        ("retention", Json::from(c.counts[3] as i64)),
                    ]),
                );
            }
            Ok(Json::obj(vec![
                ("cycles", Json::from(snap.cycles as i64)),
                ("domains", Json::Obj(domains)),
            ]))
        }
        "energy" => {
            let model_name = req.opt("model").map(|v| v.as_str()).transpose()?.unwrap_or("femu");
            let model = EnergyModel::by_name(model_name)
                .ok_or_else(|| anyhow!("unknown energy model `{model_name}`"))?;
            let snap = p.perf_snapshot();
            let r = model.estimate(&snap);
            Ok(Json::obj(vec![
                ("model", Json::from(model_name)),
                ("total_mj", Json::Num(r.total_mj)),
                ("active_mj", Json::Num(r.active_mj)),
                ("sleep_mj", Json::Num(r.sleep_mj)),
                ("seconds", Json::Num(r.seconds())),
            ]))
        }
        other => Err(anyhow!("unknown command `{other}`")),
    }
}

/// Execute a guest run in [`RUN_SLICE_CYCLES`] slices, polling
/// `cancelled` between slices. Exit kinds on the wire: `"halted"`,
/// `"budget"`, `"interrupted"`.
fn run_sliced(p: &mut Platform, budget: u64, cancelled: &dyn Fn() -> bool) -> Result<Json> {
    let mut remaining = budget;
    let (kind, detail) = loop {
        if cancelled() {
            break ("interrupted", String::new());
        }
        let slice = remaining.min(RUN_SLICE_CYCLES);
        match p.run_app(slice)? {
            AppExit::Halted(h) => break ("halted", format!("{h:?}")),
            AppExit::Budget => {
                remaining -= slice;
                if remaining == 0 {
                    break ("budget", String::new());
                }
            }
        }
    };
    Ok(Json::obj(vec![
        ("exit", Json::from(kind)),
        ("detail", Json::Str(detail)),
        ("cycles", Json::from(p.dbg.soc.now as i64)),
    ]))
}

// ---------------------------------------------------------------------
// server-side experiment commands
// ---------------------------------------------------------------------

/// Does `cmd` name a server-side experiment driver?
pub fn is_experiment_cmd(cmd: &str) -> bool {
    matches!(cmd, "sweep_acquisition" | "kernels" | "flash_study")
}

/// Run one §V experiment driver through the shared fleet, against a
/// resolved platform config. Remote clients get the same parallel sweep
/// machinery as the CLI subcommands. `cancelled` is polled before every
/// sweep point, so server shutdown aborts an in-flight experiment with
/// at most one point left to finish.
pub fn execute_experiment_cmd(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    cmd: &str,
    req: &Json,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<Json> {
    match cmd {
        "sweep_acquisition" => {
            let window_s = match req.opt("window_s") {
                None => 5.0,
                Some(v) => v.as_f64()?,
            };
            if !(window_s > 0.0 && window_s <= 60.0) {
                bail!("`window_s` must be in (0, 60], got {window_s}");
            }
            let seed = seed_field(req, 0xF164)?;
            let points = experiments::fig4_sweep_with_abort(fleet, cfg, window_s, seed, cancelled)?;
            Ok(Json::obj(vec![(
                "points",
                Json::Arr(
                    points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("sample_rate_hz", Json::Num(p.sample_rate_hz)),
                                ("model", Json::from(p.model.as_str())),
                                ("total_s", Json::Num(p.total_s)),
                                ("active_s", Json::Num(p.active_s)),
                                ("sleep_s", Json::Num(p.sleep_s)),
                                ("active_mj", Json::Num(p.active_mj)),
                                ("sleep_mj", Json::Num(p.sleep_mj)),
                                ("total_mj", Json::Num(p.total_mj)),
                            ])
                        })
                        .collect(),
                ),
            )]))
        }
        "kernels" => {
            let seed = seed_field(req, 0xF15)?;
            let points = experiments::fig5_all_with_abort(fleet, cfg, seed, cancelled)?;
            Ok(Json::obj(vec![(
                "points",
                Json::Arr(
                    points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("kernel", Json::from(p.kernel)),
                                ("implementation", Json::from(p.implementation)),
                                ("model", Json::from(p.model.as_str())),
                                ("cycles", Json::from(p.cycles as i64)),
                                ("time_s", Json::Num(p.time_s)),
                                ("energy_mj", Json::Num(p.energy_mj)),
                                ("validated", Json::from(p.validated)),
                            ])
                        })
                        .collect(),
                ),
            )]))
        }
        "flash_study" => {
            let scale = match req.opt("scale") {
                None => 1,
                Some(v) => {
                    let s = v.as_i64()?;
                    if !(1..=100_000).contains(&s) {
                        bail!("`scale` must be in 1..=100000, got {s}");
                    }
                    s as usize
                }
            };
            let r = experiments::case_c_with_abort(fleet, cfg, scale, cancelled)?;
            Ok(Json::obj(vec![
                ("windows", Json::from(r.windows as i64)),
                ("samples_per_window", Json::from(r.samples_per_window as i64)),
                ("virt_window_s", Json::Num(r.virt_window_s)),
                ("phys_window_s", Json::Num(r.phys_window_s)),
                ("virt_total_s", Json::Num(r.virt_total_s)),
                ("phys_total_s", Json::Num(r.phys_total_s)),
                ("speedup", Json::Num(r.speedup)),
            ]))
        }
        other => Err(anyhow!("unknown experiment command `{other}`")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform() -> Platform {
        Platform::new(PlatformConfig::default())
    }

    fn never() -> impl Fn() -> bool {
        || false
    }

    fn exec(p: &mut Platform, req: Json) -> Result<Json> {
        let cmd = req.str_field("cmd")?.to_string();
        execute_platform_cmd(p, &cmd, &req, &never())
    }

    #[test]
    fn negative_budget_is_a_protocol_error_not_a_wrap() {
        let mut p = platform();
        p.dbg.load_source("_start: li a0, 1\nebreak").unwrap();
        let err = exec(
            &mut p,
            Json::obj(vec![("cmd", Json::from("run")), ("max_cycles", Json::from(-1i64))]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("max_cycles"), "{err:#}");
        // a zero budget is legal and returns immediately with exit=budget
        let r = exec(
            &mut p,
            Json::obj(vec![("cmd", Json::from("run")), ("max_cycles", Json::from(0i64))]),
        )
        .unwrap();
        assert_eq!(r.str_field("exit").unwrap(), "budget");
    }

    #[test]
    fn out_of_range_addr_and_count_are_rejected() {
        let mut p = platform();
        for req in [
            // negative address
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(-4i64)),
                ("n", Json::from(1i64)),
            ]),
            // address beyond the 32-bit bus
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(1i64 << 33)),
                ("n", Json::from(1i64)),
            ]),
            // negative count
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(0i64)),
                ("n", Json::from(-1i64)),
            ]),
            // count over the transfer cap
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(0i64)),
                ("n", Json::from((MAX_TRANSFER_WORDS + 1) as i64)),
            ]),
            // span walks off the end of the address space
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(u32::MAX as i64 - 7)),
                ("n", Json::from(4i64)),
            ]),
        ] {
            let err = exec(&mut p, req).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("out of range")
                    || msg.contains("non-negative")
                    || msg.contains("cap")
                    || msg.contains("overflows"),
                "{msg}"
            );
        }
    }

    #[test]
    fn disasm_near_u32_max_errors_cleanly_instead_of_panicking() {
        let mut p = platform();
        // addr + i*4 would overflow u32 for i >= 1: must be a clean
        // protocol error (debug builds used to panic here)
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("disasm")),
                ("addr", Json::from((u32::MAX - 3) as i64)),
                ("n", Json::from(4i64)),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("overflows"), "{err:#}");
    }

    #[test]
    fn write_mem_validates_before_touching_memory() {
        let mut p = platform();
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("write_mem")),
                ("addr", Json::from(-8i64)),
                ("values", Json::arr_i32(&[1, 2])),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn cancelled_run_reports_interrupted() {
        let mut p = platform();
        p.dbg.load_source("_start:\nspin: j spin").unwrap();
        let r = execute_platform_cmd(
            &mut p,
            "run",
            &Json::obj(vec![("cmd", Json::from("run"))]),
            &|| true,
        )
        .unwrap();
        assert_eq!(r.str_field("exit").unwrap(), "interrupted");
    }

    #[test]
    fn sliced_run_halts_like_a_plain_run() {
        // a guest that halts well past one slice boundary must still
        // report halted with the same final cycle count
        let mut sliced = platform();
        sliced
            .dbg
            .load_source("_start:\nli t0, 1500000\nspin: addi t0, t0, -1\nbnez t0, spin\nebreak")
            .unwrap();
        let r = execute_platform_cmd(
            &mut sliced,
            "run",
            &Json::obj(vec![("cmd", Json::from("run"))]),
            &never(),
        )
        .unwrap();
        assert_eq!(r.str_field("exit").unwrap(), "halted");

        let mut plain = platform();
        plain
            .dbg
            .load_source("_start:\nli t0, 1500000\nspin: addi t0, t0, -1\nbnez t0, spin\nebreak")
            .unwrap();
        plain.run_app(DEFAULT_RUN_BUDGET).unwrap();
        assert_eq!(
            r.get("cycles").unwrap().as_i64().unwrap(),
            plain.dbg.soc.now as i64,
            "slicing must not change guest-visible timing"
        );
    }

    #[test]
    fn write_mem_values_must_fit_in_32_bits() {
        let mut p = platform();
        // one word past u32::MAX silently truncated through `as i32`
        // before; now a protocol error
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("write_mem")),
                ("addr", Json::from(0i64)),
                ("values", Json::Arr(vec![Json::from(1i64 << 32)])),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("32 bits"), "{err:#}");
        // u32-range values are accepted as bit patterns
        exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("write_mem")),
                ("addr", Json::from(0i64)),
                ("values", Json::Arr(vec![Json::from(u32::MAX as i64)])),
            ]),
        )
        .unwrap();
        let read = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(0i64)),
                ("n", Json::from(1i64)),
            ]),
        )
        .unwrap();
        assert_eq!(read.as_arr().unwrap()[0].as_i64().unwrap(), -1);
    }

    #[test]
    fn snapshot_save_restore_roundtrip_over_protocol() {
        let mut p = platform();
        p.dbg.load_source("_start: li a0, 42\nebreak").unwrap();
        exec(&mut p, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        let saved = exec(&mut p, Json::obj(vec![("cmd", Json::from("snapshot.save"))])).unwrap();
        let hex = saved.str_field("snapshot").unwrap().to_string();
        let cycles = saved.get("cycles").unwrap().as_i64().unwrap();
        assert_eq!(
            saved.get("version").unwrap().as_i64().unwrap(),
            crate::snapshot::VERSION as i64
        );

        // diverge, then restore back
        p.dbg.load_source("_start: li a0, 7\nebreak").unwrap();
        exec(&mut p, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert_eq!(p.dbg.reg(10), 7);
        let restored = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("snapshot.restore")),
                ("snapshot", Json::Str(hex.clone())),
            ]),
        )
        .unwrap();
        assert_eq!(restored.get("cycles").unwrap().as_i64().unwrap(), cycles);
        assert_eq!(p.dbg.reg(10), 42);

        // corrupted hex is a protocol error, not a half-restored platform
        let mut bad = hex;
        let tail = bad.split_off(bad.len() - 2);
        bad.push_str(if tail == "00" { "11" } else { "00" });
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("snapshot.restore")),
                ("snapshot", Json::Str(bad)),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        assert_eq!(p.dbg.reg(10), 42); // untouched
    }

    #[test]
    fn experiment_commands_run_through_a_fleet() {
        let fleet = Fleet::new(2);
        let cfg = PlatformConfig::default();
        let live = || false;
        let r = execute_experiment_cmd(
            &fleet,
            &cfg,
            "sweep_acquisition",
            &Json::obj(vec![("window_s", Json::Num(0.02))]),
            &live,
        )
        .unwrap();
        let points = r.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2 * experiments::FIG4_FREQS_HZ.len());
        // bad params are protocol errors
        assert!(execute_experiment_cmd(
            &fleet,
            &cfg,
            "sweep_acquisition",
            &Json::obj(vec![("window_s", Json::Num(-1.0))]),
            &live,
        )
        .is_err());
        assert!(execute_experiment_cmd(
            &fleet,
            &cfg,
            "flash_study",
            &Json::obj(vec![("scale", Json::from(0i64))]),
            &live,
        )
        .is_err());
        // a cancelled experiment aborts instead of sweeping
        let err = execute_experiment_cmd(
            &fleet,
            &cfg,
            "sweep_acquisition",
            &Json::obj(vec![("window_s", Json::Num(0.02))]),
            &|| true,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("aborted"), "{err:#}");
    }
}
