//! The control-server wire protocol: request field validation and the
//! per-platform command interpreter.
//!
//! One JSON object per line, request/response. Every numeric field is
//! range-checked *before* it is cast — a negative `max_cycles` or an
//! `addr` outside the 32-bit bus is a protocol error carried back to the
//! client, never a silent wrap or a debug-build panic. Since proto v3
//! the raw `(cmd, request)` pair is parsed into a typed command first
//! ([`PlatformCmd`] / [`ExperimentCmd`]), so every field is validated
//! before the platform is touched and the command set is an exhaustive
//! `match` instead of a string fall-through; protocol failures carry a
//! machine-readable [`ErrorKind`] alongside the unchanged v2 message
//! text. Command execution here is pure of any transport or session
//! concern: it takes `&mut Platform` plus the parsed request and returns
//! the `result` payload (`server/mod.rs` owns dispatch, sessions, and
//! the worker pool).

use anyhow::Result;

use crate::config::PlatformConfig;
use crate::coordinator::{experiments, AppExit, Fleet, Platform};
use crate::energy::EnergyModel;
use crate::snapshot::PlatformSnapshot;
use crate::util::Json;
use crate as femu;

/// Cap on `read_mem` / `write_mem` / `disasm` word counts: a protocol
/// guard against one request pinning a worker on a gigabyte transfer.
pub const MAX_TRANSFER_WORDS: usize = 1 << 20;

/// Cap on sub-requests per `batch`.
pub const MAX_BATCH_REQUESTS: usize = 1024;

/// Cap on the hex payload `snapshot.restore` accepts (a full platform
/// image is ~tens of MiB of hex at worst; this guards against a request
/// pinning a worker on gigabytes of decode).
pub const MAX_SNAPSHOT_HEX: usize = 1 << 28;

/// Cycles a `run` executes between cancellation checks. Small enough
/// that `session.close` and server shutdown interrupt a runaway guest in
/// well under a second; large enough that the re-entry overhead on the
/// event-driven run loop is unmeasurable.
pub const RUN_SLICE_CYCLES: u64 = 2_000_000;

/// Default `run` budget when the request does not carry `max_cycles`.
pub const DEFAULT_RUN_BUDGET: u64 = 1 << 33;

/// Cap on `faults.run` campaign points over the wire (proto v7): a
/// remote campaign holds the experiment lock for its whole run, so one
/// request must not pin the fleet on a million-point sweep. Larger
/// campaigns belong on the CLI (`femu faults run --campaign FILE`).
pub const MAX_CAMPAIGN_POINTS: usize = 100_000;

/// Cap on events per `trace.read` response (proto v5): one drain is at
/// most ~5 MiB of JSON; clients page with the returned `next` cursor.
pub const MAX_TRACE_READ: usize = 1 << 16;

/// Cap on the `trace.subscribe` ring depth (proto v5): 2^22 events is
/// an ~80 MiB ring, the most one session may pin.
pub const MAX_TRACE_DEPTH: u64 = 1 << 22;

// ---------------------------------------------------------------------
// typed protocol errors
// ---------------------------------------------------------------------

/// Machine-readable classification of a protocol-level failure, carried
/// on the wire as the additive `error_kind` response field (proto v3).
/// The human-readable `error` text is unchanged from v2, so clients
/// that match on substrings keep working.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// A numeric field is outside its legal range: a negative count or
    /// budget, an address past the 32-bit bus, a value that does not
    /// fit a memory word, an experiment parameter off its grid.
    OutOfRange,
    /// The request exceeds a server resource cap (transfer words,
    /// batch length, snapshot hex bytes).
    CapExceeded,
    /// `cmd` names no known command.
    UnknownCommand,
    /// A field is well-formed but names nothing (an unknown energy
    /// model, an unknown execution backend).
    BadParam,
}

impl ErrorKind {
    /// Wire name, as carried in the `error_kind` response field.
    pub fn name(self) -> &'static str {
        match self {
            ErrorKind::OutOfRange => "out_of_range",
            ErrorKind::CapExceeded => "cap_exceeded",
            ErrorKind::UnknownCommand => "unknown_command",
            ErrorKind::BadParam => "bad_param",
        }
    }
}

/// A typed protocol error: an [`ErrorKind`] plus the exact message text
/// proto v2 used. `Display` prints only the message, so error strings
/// on the wire are byte-identical to before; the kind survives anyhow
/// `context` layers and is recovered by downcast when the server builds
/// the response object.
#[derive(Debug)]
pub struct ProtoError {
    pub kind: ErrorKind,
    msg: String,
}

impl ProtoError {
    pub fn new(kind: ErrorKind, msg: impl Into<String>) -> Self {
        Self { kind, msg: msg.into() }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for ProtoError {}

/// Build an `anyhow::Error` carrying a [`ProtoError`].
pub fn proto_err(kind: ErrorKind, msg: String) -> anyhow::Error {
    anyhow::Error::new(ProtoError::new(kind, msg))
}

// ---------------------------------------------------------------------
// field validation
// ---------------------------------------------------------------------

/// A required 32-bit bus address / value field.
pub fn u32_field(req: &Json, key: &str) -> Result<u32> {
    let v = req.get(key)?.as_i64()?;
    u32::try_from(v).map_err(|_| {
        proto_err(ErrorKind::OutOfRange, format!("`{key}` {v} out of range (want 0..=4294967295)"))
    })
}

/// An optional u32 field with a default.
pub fn opt_u32_field(req: &Json, key: &str, default: u32) -> Result<u32> {
    match req.opt(key) {
        None => Ok(default),
        Some(v) => {
            let v = v.as_i64()?;
            u32::try_from(v).map_err(|_| {
                proto_err(
                    ErrorKind::OutOfRange,
                    format!("`{key}` {v} out of range (want 0..=4294967295)"),
                )
            })
        }
    }
}

/// A required word-count field, capped at [`MAX_TRANSFER_WORDS`].
pub fn count_field(req: &Json, key: &str) -> Result<usize> {
    let v = req.get(key)?.as_i64()?;
    if v < 0 {
        return Err(proto_err(
            ErrorKind::OutOfRange,
            format!("`{key}` must be non-negative, got {v}"),
        ));
    }
    let n = v as usize;
    if n > MAX_TRANSFER_WORDS {
        return Err(proto_err(
            ErrorKind::CapExceeded,
            format!("`{key}` {n} exceeds the {MAX_TRANSFER_WORDS}-word transfer cap"),
        ));
    }
    Ok(n)
}

/// The `run` budget: optional, non-negative (a negative budget must not
/// wrap through `as u64` into a ~2^64-cycle run).
pub fn budget_field(req: &Json) -> Result<u64> {
    match req.opt("max_cycles") {
        None => Ok(DEFAULT_RUN_BUDGET),
        Some(v) => {
            let b = v.as_i64()?;
            if b < 0 {
                return Err(proto_err(
                    ErrorKind::OutOfRange,
                    format!("`max_cycles` must be non-negative, got {b}"),
                ));
            }
            Ok(b as u64)
        }
    }
}

/// An optional seed field (any integer; reinterpreted as u64 bits).
pub fn seed_field(req: &Json, default: u64) -> Result<u64> {
    match req.opt("seed") {
        None => Ok(default),
        Some(v) => Ok(v.as_i64()? as u64),
    }
}

/// A memory-word value: accepts the i32 range and the u32 range (the
/// bus carries 32-bit words; `read_mem` reports them signed), rejecting
/// anything that would silently truncate through `as i32`.
pub fn word_value(v: &Json) -> Result<i32> {
    let v = v.as_i64()?;
    if !(i32::MIN as i64..=u32::MAX as i64).contains(&v) {
        return Err(proto_err(
            ErrorKind::OutOfRange,
            format!("memory value {v} does not fit in 32 bits"),
        ));
    }
    Ok(v as i32) // identical low-32 bit pattern for both accepted ranges
}

/// Check that `words` 32-bit words starting at `addr` stay inside the
/// 32-bit address space (checked arithmetic — no wrap, no panic).
pub fn check_span(addr: u32, words: usize) -> Result<()> {
    let end = addr as u64 + words as u64 * 4;
    if end > 1 << 32 {
        return Err(proto_err(
            ErrorKind::OutOfRange,
            format!("address range {addr:#x}+{words} words overflows the 32-bit bus"),
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// typed per-platform commands
// ---------------------------------------------------------------------

/// One platform-bound command, parsed and range-checked. Every wire
/// command maps to exactly one variant, so the command set is closed
/// over by two exhaustive matches (parse + execute) instead of one long
/// string fall-through, and every field violation surfaces in
/// [`PlatformCmd::parse`] before the platform is touched.
#[derive(Clone, Debug)]
pub enum PlatformCmd {
    Ping,
    LoadAsm { source: String },
    Run { budget: u64 },
    Reset { entry: u32 },
    Regs,
    ReadMem { addr: u32, n: usize },
    WriteMem { addr: u32, values: Vec<i32> },
    Disasm { addr: u32, n: usize },
    Step,
    AddBreakpoint { addr: u32 },
    RemoveBreakpoint { addr: u32 },
    Uart,
    SnapshotSave,
    SnapshotRestore { snapshot: Box<PlatformSnapshot> },
    Perf,
    Energy { model: String },
    /// Static analysis of the session's current memory from the current
    /// pc (proto v4): CFG, lints, WCET/energy bounds, block map.
    Analyze,
    /// Arm the event trace ring on the session platform (proto v5).
    TraceSubscribe { mask: u8, depth: usize },
    /// Drain events recorded since `cursor` from the armed ring (proto
    /// v5). Paged: the response carries the next cursor.
    TraceRead { cursor: u64, max: usize },
    /// Disarm the ring and report its final totals (proto v5).
    TraceStop,
    /// Arm the cycle-exact guest profiler on the session platform
    /// (proto v6). The window opens at the current cycle; the current pc
    /// becomes the call-graph root for `profile.read`.
    ProfileStart,
    /// Fold the armed profiler to function granularity and report flat /
    /// inclusive cycles plus the energy split (proto v6). `folded`
    /// selects the flamegraph text form instead of the JSON report.
    ProfileRead { model: String, folded: bool },
    /// Disarm the profiler and report its final totals (proto v6).
    ProfileStop,
}

impl PlatformCmd {
    /// Parse and validate one request into a typed command. All field
    /// range and cap violations are reported here, as [`ProtoError`]s.
    pub fn parse(cmd: &str, req: &Json) -> Result<Self> {
        Ok(match cmd {
            "ping" => PlatformCmd::Ping,
            "load_asm" => PlatformCmd::LoadAsm { source: req.str_field("source")?.to_string() },
            "run" => PlatformCmd::Run { budget: budget_field(req)? },
            "reset" => PlatformCmd::Reset { entry: opt_u32_field(req, "entry", 0)? },
            "regs" => PlatformCmd::Regs,
            "read_mem" => {
                let addr = u32_field(req, "addr")?;
                let n = count_field(req, "n")?;
                check_span(addr, n)?;
                PlatformCmd::ReadMem { addr, n }
            }
            "write_mem" => {
                let addr = u32_field(req, "addr")?;
                let values = req.get("values")?.as_arr()?;
                if values.len() > MAX_TRANSFER_WORDS {
                    return Err(proto_err(
                        ErrorKind::CapExceeded,
                        format!(
                            "`values` length {} exceeds the {MAX_TRANSFER_WORDS}-word transfer cap",
                            values.len()
                        ),
                    ));
                }
                check_span(addr, values.len())?;
                let values: Vec<i32> = values.iter().map(word_value).collect::<Result<_>>()?;
                PlatformCmd::WriteMem { addr, values }
            }
            "disasm" => {
                let addr = u32_field(req, "addr")?;
                let n = count_field(req, "n")?;
                check_span(addr, n)?;
                PlatformCmd::Disasm { addr, n }
            }
            "step" => PlatformCmd::Step,
            "add_breakpoint" => PlatformCmd::AddBreakpoint { addr: u32_field(req, "addr")? },
            "remove_breakpoint" => {
                PlatformCmd::RemoveBreakpoint { addr: u32_field(req, "addr")? }
            }
            "uart" => PlatformCmd::Uart,
            "snapshot.save" => PlatformCmd::SnapshotSave,
            "snapshot.restore" => {
                let hex = req.str_field("snapshot")?;
                if hex.len() > MAX_SNAPSHOT_HEX {
                    return Err(proto_err(
                        ErrorKind::CapExceeded,
                        format!(
                            "`snapshot` hex of {} bytes exceeds the {MAX_SNAPSHOT_HEX}-byte cap",
                            hex.len()
                        ),
                    ));
                }
                PlatformCmd::SnapshotRestore { snapshot: Box::new(PlatformSnapshot::from_hex(hex)?) }
            }
            "perf" => PlatformCmd::Perf,
            "energy" => {
                let model =
                    req.opt("model").map(|v| v.as_str()).transpose()?.unwrap_or("femu").to_string();
                if EnergyModel::by_name(&model).is_none() {
                    return Err(proto_err(
                        ErrorKind::BadParam,
                        format!("unknown energy model `{model}`"),
                    ));
                }
                PlatformCmd::Energy { model }
            }
            "analyze" => PlatformCmd::Analyze,
            "trace.subscribe" => {
                let cats = req
                    .opt("categories")
                    .map(|v| v.as_str())
                    .transpose()?
                    .unwrap_or("all");
                let mask = crate::trace::parse_categories(cats)
                    .map_err(|e| proto_err(ErrorKind::BadParam, format!("{e:#}")))?;
                if mask == 0 {
                    return Err(proto_err(
                        ErrorKind::BadParam,
                        "`categories` must enable at least one category".to_string(),
                    ));
                }
                let depth = match req.opt("depth") {
                    None => crate::trace::DEFAULT_DEPTH as u64,
                    Some(v) => {
                        let d = v.as_i64()?;
                        if d < 1 {
                            return Err(proto_err(
                                ErrorKind::OutOfRange,
                                format!("`depth` must be positive, got {d}"),
                            ));
                        }
                        if d as u64 > MAX_TRACE_DEPTH {
                            return Err(proto_err(
                                ErrorKind::CapExceeded,
                                format!("`depth` {d} exceeds the {MAX_TRACE_DEPTH}-event cap"),
                            ));
                        }
                        d as u64
                    }
                };
                PlatformCmd::TraceSubscribe { mask, depth: depth as usize }
            }
            "trace.read" => {
                let cursor = match req.opt("cursor") {
                    None => 0,
                    Some(v) => {
                        let c = v.as_i64()?;
                        if c < 0 {
                            return Err(proto_err(
                                ErrorKind::OutOfRange,
                                format!("`cursor` must be non-negative, got {c}"),
                            ));
                        }
                        c as u64
                    }
                };
                let max = match req.opt("max") {
                    None => MAX_TRACE_READ,
                    Some(v) => {
                        let m = v.as_i64()?;
                        if m < 1 {
                            return Err(proto_err(
                                ErrorKind::OutOfRange,
                                format!("`max` must be positive, got {m}"),
                            ));
                        }
                        if m as u64 > MAX_TRACE_READ as u64 {
                            return Err(proto_err(
                                ErrorKind::CapExceeded,
                                format!("`max` {m} exceeds the {MAX_TRACE_READ}-event cap"),
                            ));
                        }
                        m as usize
                    }
                };
                PlatformCmd::TraceRead { cursor, max }
            }
            "trace.stop" => PlatformCmd::TraceStop,
            "profile.start" => PlatformCmd::ProfileStart,
            "profile.read" => {
                let model =
                    req.opt("model").map(|v| v.as_str()).transpose()?.unwrap_or("femu").to_string();
                if EnergyModel::by_name(&model).is_none() {
                    return Err(proto_err(
                        ErrorKind::BadParam,
                        format!("unknown energy model `{model}`"),
                    ));
                }
                let folded = match req.opt("format") {
                    None => false,
                    Some(v) => match v.as_str()? {
                        "json" => false,
                        "folded" => true,
                        other => {
                            return Err(proto_err(
                                ErrorKind::BadParam,
                                format!("unknown profile format `{other}` (want json|folded)"),
                            ))
                        }
                    },
                };
                PlatformCmd::ProfileRead { model, folded }
            }
            "profile.stop" => PlatformCmd::ProfileStop,
            other => {
                return Err(proto_err(
                    ErrorKind::UnknownCommand,
                    format!("unknown command `{other}`"),
                ))
            }
        })
    }

    /// Execute against `p`. `cancelled` is polled between `run` slices
    /// so session close / server shutdown interrupt long runs at a
    /// bounded latency.
    pub fn execute(self, p: &mut Platform, cancelled: &dyn Fn() -> bool) -> Result<Json> {
        match self {
            PlatformCmd::Ping => Ok(Json::from("pong")),
            PlatformCmd::LoadAsm { source } => {
                let prog = p.dbg.load_source(&source)?;
                let symbols = Json::Obj(
                    prog.symbols
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                        .collect(),
                );
                Ok(Json::obj(vec![
                    ("entry", Json::from(prog.entry as i64)),
                    ("text_words", Json::from(prog.text.len() as i64)),
                    ("symbols", symbols),
                ]))
            }
            PlatformCmd::Run { budget } => run_sliced(p, budget, cancelled),
            PlatformCmd::Reset { entry } => {
                p.dbg.reset(entry);
                Ok(Json::Null)
            }
            PlatformCmd::Regs => Ok(Json::Arr(
                p.dbg.soc.cpu.regs.iter().map(|&r| Json::Num(r as i32 as f64)).collect(),
            )),
            PlatformCmd::ReadMem { addr, n } => {
                let vals = p.dbg.read_i32_slice(addr, n)?;
                Ok(Json::arr_i32(&vals))
            }
            PlatformCmd::WriteMem { addr, values } => {
                p.dbg.write_i32_slice(addr, &values)?;
                Ok(Json::Null)
            }
            PlatformCmd::Disasm { addr, n } => {
                let words: Vec<u32> = (0..n)
                    .map(|i| {
                        let a = addr.checked_add((i as u32) * 4).ok_or_else(|| {
                            proto_err(
                                ErrorKind::OutOfRange,
                                format!("disasm address overflows at word {i}"),
                            )
                        })?;
                        p.dbg.read32(a)
                    })
                    .collect::<Result<_>>()?;
                Ok(Json::Str(femu::isa::listing(&words, addr)))
            }
            PlatformCmd::Step => {
                let stop = p.dbg.step();
                Ok(Json::obj(vec![
                    ("stop", Json::Str(format!("{stop:?}"))),
                    ("pc", Json::from(p.dbg.pc() as i64)),
                ]))
            }
            PlatformCmd::AddBreakpoint { addr } => {
                p.dbg.add_breakpoint(addr);
                Ok(Json::Null)
            }
            PlatformCmd::RemoveBreakpoint { addr } => {
                p.dbg.remove_breakpoint(addr);
                Ok(Json::Null)
            }
            PlatformCmd::Uart => {
                let bytes = p.dbg.uart();
                Ok(Json::Str(String::from_utf8_lossy(&bytes).into_owned()))
            }
            PlatformCmd::SnapshotSave => {
                let snap = p.snapshot();
                Ok(Json::obj(vec![
                    ("version", Json::from(crate::snapshot::VERSION as i64)),
                    ("bytes", Json::from(snap.size_bytes() as i64)),
                    ("cycles", Json::from(p.dbg.soc.now as i64)),
                    ("snapshot", Json::Str(snap.to_hex())),
                ]))
            }
            PlatformCmd::SnapshotRestore { snapshot } => {
                // transactional: a client-supplied image that fails
                // mid-decode must not leave the session half-restored
                p.restore_transactional(&snapshot)?;
                Ok(Json::obj(vec![("cycles", Json::from(p.dbg.soc.now as i64))]))
            }
            PlatformCmd::Perf => {
                let snap = p.perf_snapshot();
                let mut domains = std::collections::BTreeMap::new();
                for (d, c) in snap.domains() {
                    domains.insert(
                        d.to_string(),
                        Json::obj(vec![
                            ("active", Json::from(c.counts[0] as i64)),
                            ("clock_gated", Json::from(c.counts[1] as i64)),
                            ("power_gated", Json::from(c.counts[2] as i64)),
                            ("retention", Json::from(c.counts[3] as i64)),
                        ]),
                    );
                }
                Ok(Json::obj(vec![
                    ("cycles", Json::from(snap.cycles as i64)),
                    ("domains", Json::Obj(domains)),
                ]))
            }
            PlatformCmd::Energy { model } => {
                let m = EnergyModel::by_name(&model).ok_or_else(|| {
                    proto_err(ErrorKind::BadParam, format!("unknown energy model `{model}`"))
                })?;
                let snap = p.perf_snapshot();
                let r = m.estimate(&snap);
                Ok(Json::obj(vec![
                    ("model", Json::from(model.as_str())),
                    ("total_mj", Json::Num(r.total_mj)),
                    ("active_mj", Json::Num(r.active_mj)),
                    ("sleep_mj", Json::Num(r.sleep_mj)),
                    ("seconds", Json::Num(r.seconds())),
                ]))
            }
            PlatformCmd::Analyze => {
                let acfg = crate::analyze::AnalyzeConfig::from_platform(&p.cfg);
                let report = crate::analyze::analyze_soc(&p.dbg.soc, "session", &acfg);
                Ok(report.to_json())
            }
            PlatformCmd::TraceSubscribe { mask, depth } => {
                p.dbg.soc.set_trace(crate::trace::TraceConfig { mask, depth });
                let ring = p.dbg.soc.trace_ring().expect("armed above");
                Ok(Json::obj(vec![
                    ("categories", Json::Str(crate::trace::category_list(mask))),
                    ("capacity", Json::from(ring.capacity() as i64)),
                    ("cursor", Json::from(ring.total() as i64)),
                ]))
            }
            PlatformCmd::TraceRead { cursor, max } => {
                let num_banks = p.dbg.soc.bus.banks.len();
                let ring = p.dbg.soc.trace_ring().ok_or_else(|| {
                    proto_err(ErrorKind::BadParam, "tracing not enabled (trace.subscribe first)".into())
                })?;
                let (events, next, skipped) = ring.events_from(cursor, max);
                Ok(Json::obj(vec![
                    (
                        "events",
                        Json::Arr(
                            events
                                .iter()
                                .map(|ev| crate::trace::export::event_json(ev, num_banks))
                                .collect(),
                        ),
                    ),
                    ("next", Json::from(next as i64)),
                    ("skipped", Json::from(skipped as i64)),
                    ("dropped", Json::from(ring.dropped() as i64)),
                    ("total", Json::from(ring.total() as i64)),
                    ("digest", Json::Str(format!("{:#018x}", ring.digest()))),
                ]))
            }
            PlatformCmd::TraceStop => {
                let ring = p.dbg.soc.take_trace().ok_or_else(|| {
                    proto_err(ErrorKind::BadParam, "tracing not enabled (trace.subscribe first)".into())
                })?;
                Ok(Json::obj(vec![
                    ("total", Json::from(ring.total() as i64)),
                    ("dropped", Json::from(ring.dropped() as i64)),
                    ("digest", Json::Str(format!("{:#018x}", ring.digest()))),
                ]))
            }
            PlatformCmd::ProfileStart => {
                p.dbg.soc.set_profile();
                let prof = p.dbg.soc.profiler().expect("armed above");
                Ok(Json::obj(vec![
                    ("enabled", Json::from(true)),
                    ("start_cycle", Json::from(prof.start_cycle() as i64)),
                    ("entry", Json::from(prof.entry_pc() as i64)),
                ]))
            }
            PlatformCmd::ProfileRead { model, folded } => {
                let m = EnergyModel::by_name(&model).ok_or_else(|| {
                    proto_err(ErrorKind::BadParam, format!("unknown energy model `{model}`"))
                })?;
                let soc = &p.dbg.soc;
                let prof = soc.profiler().ok_or_else(|| {
                    proto_err(
                        ErrorKind::BadParam,
                        "profiling not enabled (profile.start first)".into(),
                    )
                })?;
                // No assembled program survives `load_asm`, so symbols
                // come from re-analyzing the live memory image, rooted
                // at the pc the profile window opened on.
                let acfg = crate::analyze::AnalyzeConfig::from_platform(&p.cfg);
                let mut img = crate::analyze::Image::from_soc(soc);
                img.entry = prof.entry_pc();
                let report = crate::analyze::analyze(&img, "session", &acfg);
                let table = report.function_table();
                let perf_now = soc.perf.snapshot(soc.now);
                let rep = crate::profile::build_report(
                    prof,
                    soc.now,
                    &perf_now,
                    &table,
                    &m,
                    soc.backend_kind().name(),
                );
                if folded {
                    Ok(Json::obj(vec![("folded", Json::Str(rep.to_folded()))]))
                } else {
                    Ok(rep.to_json())
                }
            }
            PlatformCmd::ProfileStop => {
                let prof = p.dbg.soc.take_profile().ok_or_else(|| {
                    proto_err(
                        ErrorKind::BadParam,
                        "profiling not enabled (profile.start first)".into(),
                    )
                })?;
                Ok(Json::obj(vec![
                    ("attributed_cycles", Json::from(prof.attributed_cycles() as i64)),
                    ("retired", Json::from(prof.retired() as i64)),
                    ("records", Json::from(prof.records() as i64)),
                    ("digest", Json::Str(format!("{:#018x}", prof.digest()))),
                ]))
            }
        }
    }
}

/// Parse + execute one platform-bound command against `p` (the proto v2
/// entry point, kept for dispatch and the batch runner).
pub fn execute_platform_cmd(
    p: &mut Platform,
    cmd: &str,
    req: &Json,
    cancelled: &dyn Fn() -> bool,
) -> Result<Json> {
    PlatformCmd::parse(cmd, req)?.execute(p, cancelled)
}

/// Execute a guest run in [`RUN_SLICE_CYCLES`] slices, polling
/// `cancelled` between slices. Exit kinds on the wire: `"halted"`,
/// `"budget"`, `"interrupted"`.
fn run_sliced(p: &mut Platform, budget: u64, cancelled: &dyn Fn() -> bool) -> Result<Json> {
    let mut remaining = budget;
    let (kind, detail) = loop {
        if cancelled() {
            break ("interrupted", String::new());
        }
        let slice = remaining.min(RUN_SLICE_CYCLES);
        match p.run_app(slice)? {
            AppExit::Halted(h) => break ("halted", format!("{h:?}")),
            AppExit::Budget => {
                remaining -= slice;
                if remaining == 0 {
                    break ("budget", String::new());
                }
            }
        }
    };
    Ok(Json::obj(vec![
        ("exit", Json::from(kind)),
        ("detail", Json::Str(detail)),
        ("cycles", Json::from(p.dbg.soc.now as i64)),
    ]))
}

// ---------------------------------------------------------------------
// typed server-side experiment commands
// ---------------------------------------------------------------------

/// Does `cmd` name a server-side experiment driver?
pub fn is_experiment_cmd(cmd: &str) -> bool {
    matches!(cmd, "sweep_acquisition" | "kernels" | "flash_study" | "faults.run")
}

/// One §V experiment request, parsed and range-checked.
#[derive(Clone, Debug)]
pub enum ExperimentCmd {
    SweepAcquisition { window_s: f64, seed: u64 },
    Kernels { seed: u64 },
    FlashStudy { scale: usize },
    FaultsRun { spec: crate::faults::CampaignSpec },
}

impl ExperimentCmd {
    /// Parse and validate one experiment request.
    pub fn parse(cmd: &str, req: &Json) -> Result<Self> {
        Ok(match cmd {
            "sweep_acquisition" => {
                let window_s = match req.opt("window_s") {
                    None => 5.0,
                    Some(v) => v.as_f64()?,
                };
                if !(window_s > 0.0 && window_s <= 60.0) {
                    return Err(proto_err(
                        ErrorKind::OutOfRange,
                        format!("`window_s` must be in (0, 60], got {window_s}"),
                    ));
                }
                ExperimentCmd::SweepAcquisition { window_s, seed: seed_field(req, 0xF164)? }
            }
            "kernels" => ExperimentCmd::Kernels { seed: seed_field(req, 0xF15)? },
            "flash_study" => {
                let scale = match req.opt("scale") {
                    None => 1,
                    Some(v) => {
                        let s = v.as_i64()?;
                        if !(1..=100_000).contains(&s) {
                            return Err(proto_err(
                                ErrorKind::OutOfRange,
                                format!("`scale` must be in 1..=100000, got {s}"),
                            ));
                        }
                        s as usize
                    }
                };
                ExperimentCmd::FlashStudy { scale }
            }
            "faults.run" => {
                let builtin = match req.opt("builtin") {
                    None => "mm_cpu".to_string(),
                    Some(v) => v.as_str()?.to_string(),
                };
                let mut spec = crate::faults::CampaignSpec::new(&builtin)
                    .map_err(|e| proto_err(ErrorKind::BadParam, format!("{e:#}")))?;
                if let Some(v) = req.opt("points") {
                    let n = v.as_i64()?;
                    if !(1..=MAX_CAMPAIGN_POINTS as i64).contains(&n) {
                        let kind = if n > MAX_CAMPAIGN_POINTS as i64 {
                            ErrorKind::CapExceeded
                        } else {
                            ErrorKind::OutOfRange
                        };
                        return Err(proto_err(
                            kind,
                            format!("`points` must be in 1..={MAX_CAMPAIGN_POINTS}, got {n}"),
                        ));
                    }
                    spec.points = n as usize;
                }
                spec.seed = seed_field(req, spec.seed)?;
                if let Some(v) = req.opt("targets") {
                    spec.targets = crate::faults::TargetSpace::parse_list(v.as_str()?)
                        .map_err(|e| proto_err(ErrorKind::BadParam, format!("{e:#}")))?;
                }
                if let Some(v) = req.opt("models") {
                    spec.models = crate::faults::FaultModel::parse_list(v.as_str()?)
                        .map_err(|e| proto_err(ErrorKind::BadParam, format!("{e:#}")))?;
                }
                if let Some(v) = req.opt("window_lo") {
                    spec.window.0 = v.as_f64()?;
                }
                if let Some(v) = req.opt("window_hi") {
                    spec.window.1 = v.as_f64()?;
                }
                if let Some(v) = req.opt("watchdog_factor") {
                    let f = v.as_i64()?;
                    if !(2..=64).contains(&f) {
                        return Err(proto_err(
                            ErrorKind::OutOfRange,
                            format!("`watchdog_factor` must be in 2..=64, got {f}"),
                        ));
                    }
                    spec.watchdog_factor = f as u64;
                }
                spec.validate()
                    .map_err(|e| proto_err(ErrorKind::BadParam, format!("{e:#}")))?;
                ExperimentCmd::FaultsRun { spec }
            }
            other => {
                return Err(proto_err(
                    ErrorKind::UnknownCommand,
                    format!("unknown experiment command `{other}`"),
                ))
            }
        })
    }

    /// Run through the shared fleet against a resolved platform config.
    /// `cancelled` is polled before every sweep point, so server
    /// shutdown aborts an in-flight experiment with at most one point
    /// left to finish.
    pub fn execute(
        self,
        fleet: &Fleet,
        cfg: &PlatformConfig,
        cancelled: &(dyn Fn() -> bool + Sync),
    ) -> Result<Json> {
        match self {
            ExperimentCmd::SweepAcquisition { window_s, seed } => {
                let points =
                    experiments::fig4_sweep_with_abort(fleet, cfg, window_s, seed, cancelled)?;
                Ok(Json::obj(vec![(
                    "points",
                    Json::Arr(
                        points
                            .iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("sample_rate_hz", Json::Num(p.sample_rate_hz)),
                                    ("model", Json::from(p.model.as_str())),
                                    ("total_s", Json::Num(p.total_s)),
                                    ("active_s", Json::Num(p.active_s)),
                                    ("sleep_s", Json::Num(p.sleep_s)),
                                    ("active_mj", Json::Num(p.active_mj)),
                                    ("sleep_mj", Json::Num(p.sleep_mj)),
                                    ("total_mj", Json::Num(p.total_mj)),
                                ])
                            })
                            .collect(),
                    ),
                )]))
            }
            ExperimentCmd::Kernels { seed } => {
                let points = experiments::fig5_all_with_abort(fleet, cfg, seed, cancelled)?;
                Ok(Json::obj(vec![(
                    "points",
                    Json::Arr(
                        points
                            .iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("kernel", Json::from(p.kernel)),
                                    ("implementation", Json::from(p.implementation)),
                                    ("model", Json::from(p.model.as_str())),
                                    ("cycles", Json::from(p.cycles as i64)),
                                    ("time_s", Json::Num(p.time_s)),
                                    ("energy_mj", Json::Num(p.energy_mj)),
                                    ("validated", Json::from(p.validated)),
                                ])
                            })
                            .collect(),
                    ),
                )]))
            }
            ExperimentCmd::FlashStudy { scale } => {
                let r = experiments::case_c_with_abort(fleet, cfg, scale, cancelled)?;
                Ok(Json::obj(vec![
                    ("windows", Json::from(r.windows as i64)),
                    ("samples_per_window", Json::from(r.samples_per_window as i64)),
                    ("virt_window_s", Json::Num(r.virt_window_s)),
                    ("phys_window_s", Json::Num(r.phys_window_s)),
                    ("virt_total_s", Json::Num(r.virt_total_s)),
                    ("phys_total_s", Json::Num(r.phys_total_s)),
                    ("speedup", Json::Num(r.speedup)),
                ]))
            }
            ExperimentCmd::FaultsRun { spec } => {
                let report = crate::faults::run_campaign_cancellable(cfg, *fleet, &spec, cancelled)?;
                Ok(report.to_json())
            }
        }
    }
}

/// Parse + run one §V experiment driver (the proto v2 entry point).
/// Remote clients get the same parallel sweep machinery as the CLI
/// subcommands.
pub fn execute_experiment_cmd(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    cmd: &str,
    req: &Json,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<Json> {
    ExperimentCmd::parse(cmd, req)?.execute(fleet, cfg, cancelled)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform() -> Platform {
        Platform::new(PlatformConfig::default())
    }

    fn never() -> impl Fn() -> bool {
        || false
    }

    fn exec(p: &mut Platform, req: Json) -> Result<Json> {
        let cmd = req.str_field("cmd")?.to_string();
        execute_platform_cmd(p, &cmd, &req, &never())
    }

    #[test]
    fn analyze_reports_the_loaded_guest() {
        let mut p = platform();
        p.dbg.load_source("_start: li a0, 5\nli a1, 7\nadd a2, a0, a1\nebreak").unwrap();
        let r = exec(&mut p, Json::obj(vec![("cmd", Json::from("analyze"))])).unwrap();
        assert_eq!(r.get("entry").unwrap().as_i64().unwrap(), 0);
        assert_eq!(r.get("instructions").unwrap().as_i64().unwrap(), 4);
        assert!(r.get("block_map").unwrap().as_arr().unwrap().len() >= 1);
        // memory images carry no text extent, so no unreachable-text
        // noise from the data section — a loaded straight-line guest is
        // clean over the wire
        assert_eq!(r.get("diagnostics").unwrap().as_arr().unwrap().len(), 0);
        assert!(r.get("cpi_bound").unwrap().as_i64().unwrap() >= 1);
    }

    #[test]
    fn negative_budget_is_a_protocol_error_not_a_wrap() {
        let mut p = platform();
        p.dbg.load_source("_start: li a0, 1\nebreak").unwrap();
        let err = exec(
            &mut p,
            Json::obj(vec![("cmd", Json::from("run")), ("max_cycles", Json::from(-1i64))]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("max_cycles"), "{err:#}");
        // a zero budget is legal and returns immediately with exit=budget
        let r = exec(
            &mut p,
            Json::obj(vec![("cmd", Json::from("run")), ("max_cycles", Json::from(0i64))]),
        )
        .unwrap();
        assert_eq!(r.str_field("exit").unwrap(), "budget");
    }

    #[test]
    fn out_of_range_addr_and_count_are_rejected() {
        let mut p = platform();
        for req in [
            // negative address
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(-4i64)),
                ("n", Json::from(1i64)),
            ]),
            // address beyond the 32-bit bus
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(1i64 << 33)),
                ("n", Json::from(1i64)),
            ]),
            // negative count
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(0i64)),
                ("n", Json::from(-1i64)),
            ]),
            // count over the transfer cap
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(0i64)),
                ("n", Json::from((MAX_TRANSFER_WORDS + 1) as i64)),
            ]),
            // span walks off the end of the address space
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(u32::MAX as i64 - 7)),
                ("n", Json::from(4i64)),
            ]),
        ] {
            let err = exec(&mut p, req).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("out of range")
                    || msg.contains("non-negative")
                    || msg.contains("cap")
                    || msg.contains("overflows"),
                "{msg}"
            );
            // every protocol violation also carries a typed kind
            let kind = err.downcast_ref::<ProtoError>().expect("typed protocol error").kind;
            assert!(
                matches!(kind, ErrorKind::OutOfRange | ErrorKind::CapExceeded),
                "{kind:?}: {msg}"
            );
        }
    }

    #[test]
    fn faults_run_parses_validates_and_executes() {
        // defaults: mm_cpu, bit-flips over every target space
        let cmd = ExperimentCmd::parse("faults.run", &Json::obj(vec![])).unwrap();
        let ExperimentCmd::FaultsRun { spec } = cmd else { panic!("wrong variant") };
        assert_eq!(spec.workload, "mm_cpu");
        assert_eq!(spec.points, 100);

        // field violations surface as typed protocol errors at parse time
        let kind_of = |req: Json| {
            ExperimentCmd::parse("faults.run", &req)
                .unwrap_err()
                .downcast_ref::<ProtoError>()
                .map(|e| e.kind)
        };
        assert_eq!(
            kind_of(Json::obj(vec![("builtin", Json::from("warp_core"))])),
            Some(ErrorKind::BadParam)
        );
        assert_eq!(
            kind_of(Json::obj(vec![("points", Json::from(0i64))])),
            Some(ErrorKind::OutOfRange)
        );
        assert_eq!(
            kind_of(Json::obj(vec![("points", Json::from((MAX_CAMPAIGN_POINTS + 1) as i64))])),
            Some(ErrorKind::CapExceeded)
        );
        assert_eq!(
            kind_of(Json::obj(vec![("targets", Json::from("dram"))])),
            Some(ErrorKind::BadParam)
        );
        assert_eq!(
            kind_of(Json::obj(vec![("watchdog_factor", Json::from(1i64))])),
            Some(ErrorKind::OutOfRange)
        );

        // a tiny campaign over the wire-shaped path returns the report
        let req = Json::obj(vec![
            ("builtin", Json::from("mm_cpu")),
            ("points", Json::from(4i64)),
            ("seed", Json::from(9i64)),
        ]);
        let r = execute_experiment_cmd(
            &Fleet::serial(),
            &PlatformConfig::default(),
            "faults.run",
            &req,
            &never(),
        )
        .unwrap();
        assert_eq!(r.get("points").unwrap().as_usize().unwrap(), 4);
        assert_eq!(r.get("results").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(r.str_field("seed").unwrap(), "0x9");
    }

    #[test]
    fn error_kinds_classify_protocol_failures() {
        let mut p = platform();
        let kind_of = |req: Json, p: &mut Platform| {
            exec(p, req).unwrap_err().downcast_ref::<ProtoError>().map(|e| e.kind)
        };
        assert_eq!(
            kind_of(Json::obj(vec![("cmd", Json::from("warp"))]), &mut p),
            Some(ErrorKind::UnknownCommand)
        );
        assert_eq!(
            kind_of(
                Json::obj(vec![("cmd", Json::from("energy")), ("model", Json::from("coal"))]),
                &mut p
            ),
            Some(ErrorKind::BadParam)
        );
        assert_eq!(
            kind_of(
                Json::obj(vec![
                    ("cmd", Json::from("run")),
                    ("max_cycles", Json::from(-1i64)),
                ]),
                &mut p
            ),
            Some(ErrorKind::OutOfRange)
        );
        // a *platform* failure (bad asm) is not a protocol error: no kind
        let err = exec(
            &mut p,
            Json::obj(vec![("cmd", Json::from("load_asm")), ("source", Json::from("bogus$"))]),
        )
        .unwrap_err();
        assert!(err.downcast_ref::<ProtoError>().is_none());
        // Display of the typed error is the bare v2 message text
        assert_eq!(
            ProtoError::new(ErrorKind::UnknownCommand, "unknown command `x`").to_string(),
            "unknown command `x`"
        );
        assert_eq!(ErrorKind::UnknownCommand.name(), "unknown_command");
    }

    #[test]
    fn parse_validates_before_execution_touches_the_platform() {
        // a request mixing one good field with one bad one must fail in
        // parse and leave memory untouched
        let p = platform();
        let err = PlatformCmd::parse(
            "write_mem",
            &Json::obj(vec![
                ("addr", Json::from(0i64)),
                ("values", Json::Arr(vec![Json::from(1i64), Json::from(1i64 << 40)])),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("32 bits"), "{err:#}");
        assert_eq!(p.dbg.read_i32_slice(0, 1).unwrap(), vec![0]);
        // and a fully-valid request parses to the typed form
        match PlatformCmd::parse(
            "read_mem",
            &Json::obj(vec![("addr", Json::from(64i64)), ("n", Json::from(2i64))]),
        )
        .unwrap()
        {
            PlatformCmd::ReadMem { addr: 64, n: 2 } => {}
            other => panic!("bad parse: {other:?}"),
        }
    }

    #[test]
    fn disasm_near_u32_max_errors_cleanly_instead_of_panicking() {
        let mut p = platform();
        // addr + i*4 would overflow u32 for i >= 1: must be a clean
        // protocol error (debug builds used to panic here)
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("disasm")),
                ("addr", Json::from((u32::MAX - 3) as i64)),
                ("n", Json::from(4i64)),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("overflows"), "{err:#}");
    }

    #[test]
    fn write_mem_validates_before_touching_memory() {
        let mut p = platform();
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("write_mem")),
                ("addr", Json::from(-8i64)),
                ("values", Json::arr_i32(&[1, 2])),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn cancelled_run_reports_interrupted() {
        let mut p = platform();
        p.dbg.load_source("_start:\nspin: j spin").unwrap();
        let r = execute_platform_cmd(
            &mut p,
            "run",
            &Json::obj(vec![("cmd", Json::from("run"))]),
            &|| true,
        )
        .unwrap();
        assert_eq!(r.str_field("exit").unwrap(), "interrupted");
    }

    #[test]
    fn sliced_run_halts_like_a_plain_run() {
        // a guest that halts well past one slice boundary must still
        // report halted with the same final cycle count
        let mut sliced = platform();
        sliced
            .dbg
            .load_source("_start:\nli t0, 1500000\nspin: addi t0, t0, -1\nbnez t0, spin\nebreak")
            .unwrap();
        let r = execute_platform_cmd(
            &mut sliced,
            "run",
            &Json::obj(vec![("cmd", Json::from("run"))]),
            &never(),
        )
        .unwrap();
        assert_eq!(r.str_field("exit").unwrap(), "halted");

        let mut plain = platform();
        plain
            .dbg
            .load_source("_start:\nli t0, 1500000\nspin: addi t0, t0, -1\nbnez t0, spin\nebreak")
            .unwrap();
        plain.run_app(DEFAULT_RUN_BUDGET).unwrap();
        assert_eq!(
            r.get("cycles").unwrap().as_i64().unwrap(),
            plain.dbg.soc.now as i64,
            "slicing must not change guest-visible timing"
        );
    }

    #[test]
    fn write_mem_values_must_fit_in_32_bits() {
        let mut p = platform();
        // one word past u32::MAX silently truncated through `as i32`
        // before; now a protocol error
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("write_mem")),
                ("addr", Json::from(0i64)),
                ("values", Json::Arr(vec![Json::from(1i64 << 32)])),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("32 bits"), "{err:#}");
        // u32-range values are accepted as bit patterns
        exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("write_mem")),
                ("addr", Json::from(0i64)),
                ("values", Json::Arr(vec![Json::from(u32::MAX as i64)])),
            ]),
        )
        .unwrap();
        let read = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(0i64)),
                ("n", Json::from(1i64)),
            ]),
        )
        .unwrap();
        assert_eq!(read.as_arr().unwrap()[0].as_i64().unwrap(), -1);
    }

    #[test]
    fn snapshot_save_restore_roundtrip_over_protocol() {
        let mut p = platform();
        p.dbg.load_source("_start: li a0, 42\nebreak").unwrap();
        exec(&mut p, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        let saved = exec(&mut p, Json::obj(vec![("cmd", Json::from("snapshot.save"))])).unwrap();
        let hex = saved.str_field("snapshot").unwrap().to_string();
        let cycles = saved.get("cycles").unwrap().as_i64().unwrap();
        assert_eq!(
            saved.get("version").unwrap().as_i64().unwrap(),
            crate::snapshot::VERSION as i64
        );

        // diverge, then restore back
        p.dbg.load_source("_start: li a0, 7\nebreak").unwrap();
        exec(&mut p, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        assert_eq!(p.dbg.reg(10), 7);
        let restored = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("snapshot.restore")),
                ("snapshot", Json::Str(hex.clone())),
            ]),
        )
        .unwrap();
        assert_eq!(restored.get("cycles").unwrap().as_i64().unwrap(), cycles);
        assert_eq!(p.dbg.reg(10), 42);

        // corrupted hex is a protocol error, not a half-restored platform
        let mut bad = hex;
        let tail = bad.split_off(bad.len() - 2);
        bad.push_str(if tail == "00" { "11" } else { "00" });
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("snapshot.restore")),
                ("snapshot", Json::Str(bad)),
            ]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        assert_eq!(p.dbg.reg(10), 42); // untouched
    }

    #[test]
    fn trace_subscribe_read_stop_over_protocol() {
        let mut p = platform();
        // read/stop before subscribe: a typed protocol failure
        let err =
            exec(&mut p, Json::obj(vec![("cmd", Json::from("trace.read"))])).unwrap_err();
        assert!(format!("{err:#}").contains("not enabled"), "{err:#}");

        let sub = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("trace.subscribe")),
                ("categories", Json::from("retire,irq")),
                ("depth", Json::from(1024i64)),
            ]),
        )
        .unwrap();
        assert_eq!(sub.str_field("categories").unwrap(), "retire,irq");
        assert_eq!(sub.get("capacity").unwrap().as_i64().unwrap(), 1024);

        p.dbg.load_source("_start: li a0, 5\nli a1, 7\nadd a2, a0, a1\nebreak").unwrap();
        exec(&mut p, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();
        let read = exec(&mut p, Json::obj(vec![("cmd", Json::from("trace.read"))])).unwrap();
        let events = read.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4, "four retires expected");
        assert_eq!(events[0].str_field("event").unwrap(), "retire");
        assert_eq!(read.get("next").unwrap().as_i64().unwrap(), 4);

        // paging: a cursor mid-stream resumes without re-reading
        let page = exec(
            &mut p,
            Json::obj(vec![("cmd", Json::from("trace.read")), ("cursor", Json::from(2i64))]),
        )
        .unwrap();
        assert_eq!(page.get("events").unwrap().as_arr().unwrap().len(), 2);

        let stop = exec(&mut p, Json::obj(vec![("cmd", Json::from("trace.stop"))])).unwrap();
        assert_eq!(stop.get("total").unwrap().as_i64().unwrap(), 4);
        assert!(p.dbg.soc.trace_ring().is_none(), "stop must disarm the ring");

        // bad category names are protocol errors with a typed kind
        let err = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("trace.subscribe")),
                ("categories", Json::from("vibes")),
            ]),
        )
        .unwrap_err();
        assert_eq!(err.downcast_ref::<ProtoError>().map(|e| e.kind), Some(ErrorKind::BadParam));
    }

    #[test]
    fn profile_start_read_stop_over_protocol() {
        let mut p = platform();
        // read/stop before start: a typed protocol failure
        let err =
            exec(&mut p, Json::obj(vec![("cmd", Json::from("profile.read"))])).unwrap_err();
        assert!(format!("{err:#}").contains("not enabled"), "{err:#}");
        assert_eq!(err.downcast_ref::<ProtoError>().map(|e| e.kind), Some(ErrorKind::BadParam));

        p.dbg.load_source("_start: li a0, 5\nli a1, 7\nadd a2, a0, a1\nebreak").unwrap();
        let started =
            exec(&mut p, Json::obj(vec![("cmd", Json::from("profile.start"))])).unwrap();
        assert!(started.get("enabled").unwrap().as_bool().unwrap());
        exec(&mut p, Json::obj(vec![("cmd", Json::from("run"))])).unwrap();

        let read = exec(&mut p, Json::obj(vec![("cmd", Json::from("profile.read"))])).unwrap();
        assert_eq!(read.get("retired").unwrap().as_i64().unwrap(), 4);
        let funcs = read.get("functions").unwrap().as_arr().unwrap();
        assert!(!funcs.is_empty());
        let flat_sum: i64 =
            funcs.iter().map(|f| f.get("flat_cycles").unwrap().as_i64().unwrap()).sum();
        assert_eq!(
            flat_sum,
            read.get("attributed_cycles").unwrap().as_i64().unwrap(),
            "per-function cycles must conserve"
        );

        // the folded form carries stack lines with cycle counts
        let folded = exec(
            &mut p,
            Json::obj(vec![
                ("cmd", Json::from("profile.read")),
                ("format", Json::from("folded")),
            ]),
        )
        .unwrap();
        assert!(folded.str_field("folded").unwrap().contains(' '));

        let stop = exec(&mut p, Json::obj(vec![("cmd", Json::from("profile.stop"))])).unwrap();
        assert_eq!(stop.get("retired").unwrap().as_i64().unwrap(), 4);
        assert!(p.dbg.soc.profiler().is_none(), "stop must disarm the profiler");

        // bad formats and models are typed protocol errors
        for req in [
            Json::obj(vec![
                ("cmd", Json::from("profile.read")),
                ("format", Json::from("xml")),
            ]),
            Json::obj(vec![
                ("cmd", Json::from("profile.read")),
                ("model", Json::from("coal")),
            ]),
        ] {
            let err = exec(&mut p, req).unwrap_err();
            assert_eq!(
                err.downcast_ref::<ProtoError>().map(|e| e.kind),
                Some(ErrorKind::BadParam)
            );
        }
    }

    #[test]
    fn experiment_commands_run_through_a_fleet() {
        let fleet = Fleet::new(2);
        let cfg = PlatformConfig::default();
        let live = || false;
        let r = execute_experiment_cmd(
            &fleet,
            &cfg,
            "sweep_acquisition",
            &Json::obj(vec![("window_s", Json::Num(0.02))]),
            &live,
        )
        .unwrap();
        let points = r.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2 * experiments::FIG4_FREQS_HZ.len());
        // bad params are protocol errors, with a typed kind
        let err = execute_experiment_cmd(
            &fleet,
            &cfg,
            "sweep_acquisition",
            &Json::obj(vec![("window_s", Json::Num(-1.0))]),
            &live,
        )
        .unwrap_err();
        assert_eq!(err.downcast_ref::<ProtoError>().map(|e| e.kind), Some(ErrorKind::OutOfRange));
        assert!(execute_experiment_cmd(
            &fleet,
            &cfg,
            "flash_study",
            &Json::obj(vec![("scale", Json::from(0i64))]),
            &live,
        )
        .is_err());
        // a cancelled experiment aborts instead of sweeping
        let err = execute_experiment_cmd(
            &fleet,
            &cfg,
            "sweep_acquisition",
            &Json::obj(vec![("window_s", Json::Num(0.02))]),
            &|| true,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("aborted"), "{err:#}");
    }
}
