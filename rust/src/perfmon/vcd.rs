//! VCD export of power-domain state timelines.
//!
//! The real X-HEEP-FEMU exposes its counters as registers; a software
//! framework can do better — record every domain transition and render a
//! Value Change Dump any waveform viewer (GTKWave etc.) opens. This is
//! the visualization counterpart of the §IV-C counters: designers *see*
//! the active/sleep structure Fig 4 aggregates.
//!
//! Recording is opt-in ([`TransitionLog`] attached to the monitor by the
//! SoC when tracing is requested) so the hot path stays allocation-free
//! when disabled.

use std::fmt::Write as _;

use crate::perfmon::{Domain, PowerState};

/// One recorded transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transition {
    pub cycle: u64,
    pub domain_index: usize,
    pub state: PowerState,
}

/// Append-only transition recorder.
#[derive(Clone, Debug, Default)]
pub struct TransitionLog {
    /// Domain display names, index-aligned with `domain_index`.
    names: Vec<String>,
    initial: Vec<PowerState>,
    events: Vec<Transition>,
}

/// Display names of the standard domain set, index-aligned with
/// [`TransitionLog::index_of`]: cpu, bus, periph, bank 0..n, cgra.
/// Shared with the general trace exporter ([`crate::trace::export`]),
/// which labels `POWER` events by the same indices.
pub(crate) fn domain_names(num_banks: usize) -> Vec<String> {
    let mut names =
        vec![Domain::Cpu.to_string(), Domain::Bus.to_string(), Domain::Periph.to_string()];
    for i in 0..num_banks {
        names.push(Domain::MemBank(i).to_string());
    }
    names.push(Domain::Cgra.to_string());
    names
}

/// Stable index of a domain in the standard set, aligned with
/// [`domain_names`]. The trace ring stamps `POWER` events with these
/// indices, so both VCD pipelines label identically.
pub(crate) fn domain_index(d: Domain, num_banks: usize) -> usize {
    match d {
        Domain::Cpu => 0,
        Domain::Bus => 1,
        Domain::Periph => 2,
        Domain::MemBank(i) => 3 + i,
        Domain::Cgra => 3 + num_banks,
    }
}

impl TransitionLog {
    /// Build for the standard domain set (cpu, bus, periph, banks, cgra).
    pub fn for_domains(num_banks: usize) -> Self {
        let names = domain_names(num_banks);
        let mut initial = vec![PowerState::Active; 3 + num_banks];
        // the CGRA powers up gated
        initial.push(PowerState::PowerGated);
        Self { names, initial, events: Vec::new() }
    }

    /// Stable index of a domain within this log.
    pub fn index_of(&self, d: Domain, num_banks: usize) -> usize {
        domain_index(d, num_banks)
    }

    pub fn record(&mut self, cycle: u64, domain_index: usize, state: PowerState) {
        self.events.push(Transition { cycle, domain_index, state });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[Transition] {
        &self.events
    }

    /// Render as VCD. `freq_hz` sets the timescale (one tick = one cycle;
    /// the timescale line documents the cycle length in ns).
    pub fn to_vcd(&self, freq_hz: u64, end_cycle: u64) -> String {
        let ns_per_cycle = 1e9 / freq_hz as f64;
        let mut out = String::new();
        let _ = writeln!(out, "$comment femu power-domain trace $end");
        let _ = writeln!(
            out,
            "$comment one tick = one cycle = {ns_per_cycle:.1} ns at {freq_hz} Hz $end"
        );
        let _ = writeln!(out, "$timescale 1 ns $end");
        let _ = writeln!(out, "$scope module femu $end");
        // 2-bit vectors per domain: 00 active, 01 clock-gated,
        // 10 power-gated, 11 retention
        for (i, name) in self.names.iter().enumerate() {
            let id = ident(i);
            let _ = writeln!(out, "$var wire 2 {id} {name} $end");
        }
        let _ = writeln!(out, "$upscope $end");
        let _ = writeln!(out, "$enddefinitions $end");
        let _ = writeln!(out, "#0");
        for (i, s) in self.initial.iter().enumerate() {
            let _ = writeln!(out, "b{} {}", bits(*s), ident(i));
        }
        // events must be time-ordered; transitions are recorded in
        // monotonic emulation order already, but defensive-sort anyway
        let mut events = self.events.clone();
        events.sort_by_key(|e| e.cycle);
        let mut last_time = 0u64;
        for e in events {
            let t = (e.cycle as f64 * ns_per_cycle) as u64;
            if t != last_time {
                let _ = writeln!(out, "#{t}");
                last_time = t;
            }
            let _ = writeln!(out, "b{} {}", bits(e.state), ident(e.domain_index));
        }
        let end_t = (end_cycle as f64 * ns_per_cycle) as u64;
        if end_t > last_time {
            let _ = writeln!(out, "#{end_t}");
        }
        out
    }
}

/// 2-bit VCD encoding of a power state (shared with the general trace
/// exporter so both pipelines render identical waveform values).
pub(crate) fn bits(s: PowerState) -> &'static str {
    match s {
        PowerState::Active => "00",
        PowerState::ClockGated => "01",
        PowerState::PowerGated => "10",
        PowerState::Retention => "11",
    }
}

/// Printable VCD identifier for variable `i` (shared with the general
/// trace exporter).
pub(crate) fn ident(i: usize) -> String {
    // printable ASCII 33..=126, base-94
    let mut i = i;
    let mut s = String::new();
    loop {
        s.push((33 + (i % 94)) as u8 as char);
        i /= 94;
        if i == 0 {
            break;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vcd_structure() {
        let mut log = TransitionLog::for_domains(2);
        let cpu = log.index_of(Domain::Cpu, 2);
        let bank1 = log.index_of(Domain::MemBank(1), 2);
        log.record(100, cpu, PowerState::ClockGated);
        log.record(100, bank1, PowerState::Retention);
        log.record(250, cpu, PowerState::Active);
        let vcd = log.to_vcd(20_000_000, 400);
        assert!(vcd.contains("$timescale 1 ns $end"));
        assert!(vcd.contains("$var wire 2 ! cpu $end"));
        assert!(vcd.contains("mem_bank1"));
        // 100 cycles at 20 MHz = 5000 ns
        assert!(vcd.contains("#5000"), "{vcd}");
        assert!(vcd.contains("#12500"));
        // retention encoding for bank1 at 5000
        let after = vcd.split("#5000").nth(1).unwrap();
        assert!(after.contains("b11"), "{after}");
    }

    #[test]
    fn domain_indices_stable() {
        let log = TransitionLog::for_domains(3);
        assert_eq!(log.index_of(Domain::Cpu, 3), 0);
        assert_eq!(log.index_of(Domain::MemBank(2), 3), 5);
        assert_eq!(log.index_of(Domain::Cgra, 3), 6);
        assert_eq!(log.names.len(), 7);
    }

    #[test]
    fn ident_unique_printable() {
        let ids: Vec<String> = (0..200).map(ident).collect();
        let set: std::collections::HashSet<_> = ids.iter().collect();
        assert_eq!(set.len(), 200);
        assert!(ids.iter().all(|s| s.chars().all(|c| (33..=126).contains(&(c as u32)))));
    }

    #[test]
    fn empty_log_still_valid() {
        let log = TransitionLog::for_domains(1);
        let vcd = log.to_vcd(20_000_000, 100);
        assert!(vcd.contains("$enddefinitions"));
        assert!(vcd.contains("#0"));
    }
}
