//! Performance monitor: per-domain power-state cycle counters.
//!
//! Models the counters the paper integrates into the PL next to X-HEEP
//! (§IV-C): for every power domain they count the cycles spent in each of
//! the four power states — (1) active, (2) clock-gated, (3) power-gated,
//! (4) retention (memories) — plus two operating modes:
//!
//! * **automatic** — armed at program start, stopped when the program
//!   halts (no guest intervention);
//! * **manual** — the guest toggles a dedicated GPIO bit
//!   ([`crate::periph::gpio::PERF_GPIO_BIT`]) around a region of interest,
//!   enabling fine-grained profiling of code sections.
//!
//! Counter values are read CS-side (memory-mapped on the PS bus in the
//! paper; a struct access here) and combined with the energy model
//! ([`crate::energy`]) into per-domain energy estimates.
//!
//! Implementation note: counters accumulate on *state transitions*
//! (`last_change` timestamping) rather than per cycle, so the emulator hot
//! loop pays one branch per transition, not per cycle.

pub mod vcd;

use std::fmt;

use vcd::TransitionLog;

/// The four power states of §IV-C.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PowerState {
    Active = 0,
    ClockGated = 1,
    PowerGated = 2,
    Retention = 3,
}

impl PowerState {
    pub const ALL: [PowerState; 4] =
        [PowerState::Active, PowerState::ClockGated, PowerState::PowerGated, PowerState::Retention];

    pub fn name(self) -> &'static str {
        match self {
            PowerState::Active => "active",
            PowerState::ClockGated => "clock_gated",
            PowerState::PowerGated => "power_gated",
            PowerState::Retention => "retention",
        }
    }

    /// Snapshot encoding (stable: the enum discriminants are part of the
    /// snapshot format).
    pub fn to_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(v: u8) -> anyhow::Result<PowerState> {
        Ok(match v {
            0 => PowerState::Active,
            1 => PowerState::ClockGated,
            2 => PowerState::PowerGated,
            3 => PowerState::Retention,
            other => anyhow::bail!("snapshot corrupt: power state tag {other}"),
        })
    }
}

impl fmt::Display for PowerState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A power domain of the emulated platform. Matches the HEEPocrates
/// domain partitioning: CPU, bus/always-on, peripheral subsystem,
/// individually switchable memory banks, and the accelerator.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Domain {
    Cpu,
    Bus,
    Periph,
    MemBank(usize),
    Cgra,
}

impl fmt::Display for Domain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Domain::Cpu => write!(f, "cpu"),
            Domain::Bus => write!(f, "bus"),
            Domain::Periph => write!(f, "periph"),
            Domain::MemBank(i) => write!(f, "mem_bank{i}"),
            Domain::Cgra => write!(f, "cgra"),
        }
    }
}

/// Cycle counts per power state for one domain.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StateCycles {
    pub counts: [u64; 4],
}

impl StateCycles {
    pub fn get(&self, s: PowerState) -> u64 {
        self.counts[s as usize]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    fn add(&mut self, s: PowerState, cycles: u64) {
        self.counts[s as usize] += cycles;
    }
}

/// Transition-accumulating tracker for one domain.
#[derive(Clone, Debug)]
struct DomainTracker {
    state: PowerState,
    last_change: u64,
    cycles: StateCycles,
}

impl DomainTracker {
    fn new(initial: PowerState, now: u64) -> Self {
        Self { state: initial, last_change: now, cycles: StateCycles::default() }
    }

    fn set_state(&mut self, new: PowerState, now: u64) {
        debug_assert!(now >= self.last_change, "time went backwards");
        if new != self.state {
            self.cycles.add(self.state, now - self.last_change);
            self.state = new;
            self.last_change = now;
        }
    }

    fn snapshot(&self, now: u64) -> StateCycles {
        let mut c = self.cycles;
        c.add(self.state, now - self.last_change);
        c
    }
}

/// The full performance monitor: one tracker per domain plus measurement
/// windowing (automatic/manual modes).
#[derive(Clone, Debug)]
pub struct PerfMonitor {
    cpu: DomainTracker,
    bus: DomainTracker,
    periph: DomainTracker,
    banks: Vec<DomainTracker>,
    cgra: DomainTracker,
    /// Measurement window state (manual mode gates against this).
    measuring: bool,
    window_start: Option<u64>,
    window_cycles: u64,
    /// Snapshot taken when the current window opened.
    window_base: Option<PerfSnapshot>,
    /// Accumulated per-window deltas (manual mode may open/close several
    /// windows; they accumulate like the paper's start/stop GPIO).
    window_acc: Option<PerfSnapshot>,
    /// Optional transition recorder (VCD export); None keeps the hot
    /// path allocation-free.
    trace: Option<TransitionLog>,
}

/// Counter values for every domain at one instant (or a window delta).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PerfSnapshot {
    pub cpu: StateCycles,
    pub bus: StateCycles,
    pub periph: StateCycles,
    pub banks: Vec<StateCycles>,
    pub cgra: StateCycles,
    pub cycles: u64,
}

impl PerfSnapshot {
    /// Per-domain iteration in a stable order (for reports and the energy
    /// estimator).
    pub fn domains(&self) -> Vec<(Domain, StateCycles)> {
        let mut v = vec![
            (Domain::Cpu, self.cpu),
            (Domain::Bus, self.bus),
            (Domain::Periph, self.periph),
        ];
        for (i, b) in self.banks.iter().enumerate() {
            v.push((Domain::MemBank(i), *b));
        }
        v.push((Domain::Cgra, self.cgra));
        v
    }

    /// Counter-wise difference `self - base`: the window between two
    /// snapshots of the same monitor, `base` taken earlier. This is the
    /// public face of [`PerfSnapshot::sub`] for window-style consumers
    /// (the guest profiler's per-power-state splits and energy
    /// attribution, [`crate::profile`]).
    pub fn delta(&self, base: &PerfSnapshot) -> PerfSnapshot {
        self.sub(base)
    }

    fn sub(&self, base: &PerfSnapshot) -> PerfSnapshot {
        fn d(a: StateCycles, b: StateCycles) -> StateCycles {
            let mut out = StateCycles::default();
            for i in 0..4 {
                out.counts[i] = a.counts[i] - b.counts[i];
            }
            out
        }
        PerfSnapshot {
            cpu: d(self.cpu, base.cpu),
            bus: d(self.bus, base.bus),
            periph: d(self.periph, base.periph),
            banks: self.banks.iter().zip(&base.banks).map(|(a, b)| d(*a, *b)).collect(),
            cgra: d(self.cgra, base.cgra),
            cycles: self.cycles - base.cycles,
        }
    }

    fn add(&mut self, delta: &PerfSnapshot) {
        fn a(acc: &mut StateCycles, d: StateCycles) {
            for i in 0..4 {
                acc.counts[i] += d.counts[i];
            }
        }
        a(&mut self.cpu, delta.cpu);
        a(&mut self.bus, delta.bus);
        a(&mut self.periph, delta.periph);
        if self.banks.len() < delta.banks.len() {
            self.banks.resize(delta.banks.len(), StateCycles::default());
        }
        for (acc, d) in self.banks.iter_mut().zip(&delta.banks) {
            a(acc, *d);
        }
        a(&mut self.cgra, delta.cgra);
        self.cycles += delta.cycles;
    }
}

impl PerfMonitor {
    pub fn new(num_banks: usize) -> Self {
        Self {
            cpu: DomainTracker::new(PowerState::Active, 0),
            bus: DomainTracker::new(PowerState::Active, 0),
            periph: DomainTracker::new(PowerState::Active, 0),
            banks: (0..num_banks).map(|_| DomainTracker::new(PowerState::Active, 0)).collect(),
            cgra: DomainTracker::new(PowerState::PowerGated, 0),
            measuring: false,
            window_start: None,
            window_cycles: 0,
            window_base: None,
            window_acc: None,
            trace: None,
        }
    }

    /// Start recording domain transitions for VCD export.
    pub fn enable_trace(&mut self) {
        let n = self.banks.len();
        self.trace = Some(TransitionLog::for_domains(n));
    }

    /// The recorded transition log, if tracing is enabled.
    pub fn trace(&self) -> Option<&TransitionLog> {
        self.trace.as_ref()
    }

    fn tracker(&mut self, d: Domain) -> &mut DomainTracker {
        match d {
            Domain::Cpu => &mut self.cpu,
            Domain::Bus => &mut self.bus,
            Domain::Periph => &mut self.periph,
            Domain::MemBank(i) => &mut self.banks[i],
            Domain::Cgra => &mut self.cgra,
        }
    }

    /// Record a domain state transition at cycle `now`. Returns whether
    /// the state actually changed, so callers (the SoC's trace hook) can
    /// record real transitions without re-deriving the edge.
    pub fn set_state(&mut self, d: Domain, s: PowerState, now: u64) -> bool {
        let changed = {
            let t = self.tracker(d);
            let changed = t.state != s;
            t.set_state(s, now);
            changed
        };
        if changed {
            let num_banks = self.banks.len();
            if let Some(trace) = self.trace.as_mut() {
                let idx = trace.index_of(d, num_banks);
                trace.record(now, idx, s);
            }
        }
        changed
    }

    /// Current state of a domain.
    pub fn state(&self, d: Domain) -> PowerState {
        match d {
            Domain::Cpu => self.cpu.state,
            Domain::Bus => self.bus.state,
            Domain::Periph => self.periph.state,
            Domain::MemBank(i) => self.banks[i].state,
            Domain::Cgra => self.cgra.state,
        }
    }

    pub fn num_banks(&self) -> usize {
        self.banks.len()
    }

    /// Counters for everything since reset (the automatic-mode window).
    pub fn snapshot(&self, now: u64) -> PerfSnapshot {
        PerfSnapshot {
            cpu: self.cpu.snapshot(now),
            bus: self.bus.snapshot(now),
            periph: self.periph.snapshot(now),
            banks: self.banks.iter().map(|b| b.snapshot(now)).collect(),
            cgra: self.cgra.snapshot(now),
            cycles: now,
        }
    }

    // ---- manual measurement windows (GPIO-toggled in the paper) --------

    /// Open a manual measurement window.
    pub fn window_open(&mut self, now: u64) {
        if !self.measuring {
            self.measuring = true;
            self.window_start = Some(now);
            self.window_base = Some(self.snapshot(now));
        }
    }

    /// Close the current manual window, accumulating its delta.
    pub fn window_close(&mut self, now: u64) {
        if self.measuring {
            self.measuring = false;
            let base = self.window_base.take().expect("window_base set while measuring");
            let delta = self.snapshot(now).sub(&base);
            self.window_cycles += delta.cycles;
            match &mut self.window_acc {
                Some(acc) => acc.add(&delta),
                None => self.window_acc = Some(delta),
            }
            self.window_start = None;
        }
    }

    /// True while a manual window is open.
    pub fn measuring(&self) -> bool {
        self.measuring
    }

    /// Accumulated manual-window counters (None if no window ever closed).
    pub fn window_snapshot(&self) -> Option<&PerfSnapshot> {
        self.window_acc.as_ref()
    }

    /// Clear accumulated manual windows.
    pub fn window_reset(&mut self) {
        self.window_acc = None;
        self.window_cycles = 0;
    }

    /// Serialize all counters and window state. The optional VCD
    /// transition log is **not** captured (restore clears it).
    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        self.cpu.save_state(w);
        self.bus.save_state(w);
        self.periph.save_state(w);
        w.u32(self.banks.len() as u32);
        for b in &self.banks {
            b.save_state(w);
        }
        self.cgra.save_state(w);
        w.bool(self.measuring);
        w.opt_u64(self.window_start);
        w.u64(self.window_cycles);
        save_opt_snap(w, &self.window_base);
        save_opt_snap(w, &self.window_acc);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.cpu.restore_state(r)?;
        self.bus.restore_state(r)?;
        self.periph.restore_state(r)?;
        let n = r.u32()? as usize;
        if n != self.banks.len() {
            anyhow::bail!(
                "snapshot has {n} memory-bank trackers, platform has {}",
                self.banks.len()
            );
        }
        for b in &mut self.banks {
            b.restore_state(r)?;
        }
        self.cgra.restore_state(r)?;
        self.measuring = r.bool()?;
        self.window_start = r.opt_u64()?;
        self.window_cycles = r.u64()?;
        self.window_base = read_opt_snap(r)?;
        self.window_acc = read_opt_snap(r)?;
        self.trace = None; // transition log is not part of the snapshot
        Ok(())
    }
}

impl DomainTracker {
    fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u8(self.state.to_u8());
        w.u64(self.last_change);
        for c in self.cycles.counts {
            w.u64(c);
        }
    }

    fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.state = PowerState::from_u8(r.u8()?)?;
        self.last_change = r.u64()?;
        for c in &mut self.cycles.counts {
            *c = r.u64()?;
        }
        Ok(())
    }
}

fn save_state_cycles(w: &mut crate::snapshot::Writer, c: &StateCycles) {
    for v in c.counts {
        w.u64(v);
    }
}

fn read_state_cycles(r: &mut crate::snapshot::Reader) -> anyhow::Result<StateCycles> {
    let mut c = StateCycles::default();
    for v in &mut c.counts {
        *v = r.u64()?;
    }
    Ok(c)
}

fn save_opt_snap(w: &mut crate::snapshot::Writer, s: &Option<PerfSnapshot>) {
    match s {
        None => w.bool(false),
        Some(snap) => {
            w.bool(true);
            save_state_cycles(w, &snap.cpu);
            save_state_cycles(w, &snap.bus);
            save_state_cycles(w, &snap.periph);
            w.u32(snap.banks.len() as u32);
            for b in &snap.banks {
                save_state_cycles(w, b);
            }
            save_state_cycles(w, &snap.cgra);
            w.u64(snap.cycles);
        }
    }
}

fn read_opt_snap(r: &mut crate::snapshot::Reader) -> anyhow::Result<Option<PerfSnapshot>> {
    if !r.bool()? {
        return Ok(None);
    }
    let cpu = read_state_cycles(r)?;
    let bus = read_state_cycles(r)?;
    let periph = read_state_cycles(r)?;
    let n = r.u32()? as usize;
    let mut banks = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        banks.push(read_state_cycles(r)?);
    }
    let cgra = read_state_cycles(r)?;
    let cycles = r.u64()?;
    Ok(Some(PerfSnapshot { cpu, bus, periph, banks, cgra, cycles }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transitions_accumulate() {
        let mut pm = PerfMonitor::new(2);
        pm.set_state(Domain::Cpu, PowerState::ClockGated, 100);
        pm.set_state(Domain::Cpu, PowerState::Active, 150);
        let snap = pm.snapshot(200);
        assert_eq!(snap.cpu.get(PowerState::Active), 100 + 50);
        assert_eq!(snap.cpu.get(PowerState::ClockGated), 50);
        assert_eq!(snap.cpu.total(), 200);
    }

    #[test]
    fn same_state_transition_is_noop() {
        let mut pm = PerfMonitor::new(1);
        pm.set_state(Domain::Cpu, PowerState::Active, 10);
        pm.set_state(Domain::Cpu, PowerState::Active, 20);
        let snap = pm.snapshot(30);
        assert_eq!(snap.cpu.get(PowerState::Active), 30);
    }

    #[test]
    fn cgra_starts_power_gated() {
        let pm = PerfMonitor::new(1);
        let snap = pm.snapshot(1000);
        assert_eq!(snap.cgra.get(PowerState::PowerGated), 1000);
        assert_eq!(snap.cgra.get(PowerState::Active), 0);
    }

    #[test]
    fn bank_retention_counts() {
        let mut pm = PerfMonitor::new(2);
        pm.set_state(Domain::MemBank(1), PowerState::Retention, 10);
        pm.set_state(Domain::MemBank(1), PowerState::Active, 110);
        let snap = pm.snapshot(120);
        assert_eq!(snap.banks[1].get(PowerState::Retention), 100);
        assert_eq!(snap.banks[1].get(PowerState::Active), 20);
        // bank 0 untouched
        assert_eq!(snap.banks[0].get(PowerState::Active), 120);
    }

    #[test]
    fn manual_windows_accumulate() {
        let mut pm = PerfMonitor::new(1);
        // window 1: cycles 100..200, cpu active
        pm.window_open(100);
        pm.window_close(200);
        // state change outside window is not attributed to the window
        pm.set_state(Domain::Cpu, PowerState::ClockGated, 300);
        pm.window_open(400);
        pm.set_state(Domain::Cpu, PowerState::Active, 450);
        pm.window_close(500);
        let w = pm.window_snapshot().unwrap();
        assert_eq!(w.cycles, 200);
        assert_eq!(w.cpu.get(PowerState::Active), 100 + 50);
        assert_eq!(w.cpu.get(PowerState::ClockGated), 50);
    }

    #[test]
    fn window_reset_clears() {
        let mut pm = PerfMonitor::new(1);
        pm.window_open(0);
        pm.window_close(10);
        assert!(pm.window_snapshot().is_some());
        pm.window_reset();
        assert!(pm.window_snapshot().is_none());
    }

    #[test]
    fn double_open_ignored() {
        let mut pm = PerfMonitor::new(1);
        pm.window_open(0);
        pm.window_open(5); // ignored — already measuring
        pm.window_close(10);
        assert_eq!(pm.window_snapshot().unwrap().cycles, 10);
    }
}
