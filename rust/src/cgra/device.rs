//! Guest-visible CGRA control port (the accelerator's register file on
//! the SoC bus).
//!
//! The guest programs kernel id + arguments, then writes START. The SoC
//! (which owns both the CGRA core and the SRAM banks) services the launch:
//! it builds the configuration passes, executes them over guest memory,
//! and completes the launch at `now + total_cycles` — the CPU can WFI
//! until the DONE interrupt, which is exactly the co-design flow the
//! paper's design cycle prototypes (§III-B step 7).

use super::CgraRun;

/// Register offsets within the CGRA window.
pub mod regs {
    pub const STATUS: u32 = 0x00; // R: bit0 done, bit1 busy
    pub const START: u32 = 0x04; // W: bit0 launches KERNEL with ARGs
    pub const KERNEL: u32 = 0x08; // R/W: kernel id
    pub const CYCLES_LO: u32 = 0x0C; // R: cycles of last completed run
    pub const CYCLES_HI: u32 = 0x10; // R
    pub const CTRL: u32 = 0x14; // R/W: bit0 irq enable
    pub const ARG_BASE: u32 = 0x40; // R/W: ARG0.. at ARG_BASE + 4*i
    pub const NUM_ARGS: usize = 10;
}

/// Kernel ids (KERNEL register values).
pub mod kernel_id {
    pub const MATMUL: u32 = 0;
    pub const CONV2D: u32 = 1;
    /// All FFT stages (guest must bit-reverse first).
    pub const FFT: u32 = 2;
}

/// A launch the SoC must service.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaunchRequest {
    pub kernel: u32,
    pub args: [u32; regs::NUM_ARGS],
}

#[derive(Clone, Debug)]
pub struct CgraDevice {
    kernel: u32,
    args: [u32; regs::NUM_ARGS],
    irq_enabled: bool,
    /// Launch awaiting SoC service.
    pending: Option<LaunchRequest>,
    /// Completion time of the in-flight run.
    busy_until: Option<u64>,
    /// Cycle count of the last completed run.
    last_run: Option<CgraRun>,
    irq_level: bool,
}

impl Default for CgraDevice {
    fn default() -> Self {
        Self::new()
    }
}

impl CgraDevice {
    pub fn new() -> Self {
        Self {
            kernel: 0,
            args: [0; regs::NUM_ARGS],
            irq_enabled: false,
            pending: None,
            busy_until: None,
            last_run: None,
            irq_level: false,
        }
    }

    pub fn read(&mut self, offset: u32, now: u64) -> u32 {
        match offset {
            regs::STATUS => {
                let busy = self.pending.is_some()
                    || self.busy_until.map(|t| now < t).unwrap_or(false);
                let done = !busy && self.last_run.is_some();
                (done as u32) | ((busy as u32) << 1)
            }
            regs::KERNEL => self.kernel,
            regs::CYCLES_LO => self.last_run.map(|r| r.total_cycles() as u32).unwrap_or(0),
            regs::CYCLES_HI => {
                self.last_run.map(|r| (r.total_cycles() >> 32) as u32).unwrap_or(0)
            }
            regs::CTRL => self.irq_enabled as u32,
            o if (regs::ARG_BASE..regs::ARG_BASE + 4 * regs::NUM_ARGS as u32).contains(&o) => {
                self.args[((o - regs::ARG_BASE) / 4) as usize]
            }
            _ => 0,
        }
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        match offset {
            regs::KERNEL => self.kernel = value,
            regs::CTRL => self.irq_enabled = value & 1 != 0,
            regs::START => {
                if value & 1 != 0 && self.pending.is_none() && self.busy_until.is_none() {
                    self.pending = Some(LaunchRequest { kernel: self.kernel, args: self.args });
                    self.irq_level = false;
                }
            }
            o if (regs::ARG_BASE..regs::ARG_BASE + 4 * regs::NUM_ARGS as u32).contains(&o) => {
                self.args[((o - regs::ARG_BASE) / 4) as usize] = value;
            }
            _ => {}
        }
    }

    /// SoC side: take a pending launch for servicing.
    pub fn take_pending(&mut self) -> Option<LaunchRequest> {
        self.pending.take()
    }

    /// SoC side: record the serviced run; the accelerator appears busy
    /// until `now + run.total_cycles()`.
    pub fn complete(&mut self, run: CgraRun, now: u64) {
        self.busy_until = Some(now + run.total_cycles());
        self.last_run = Some(run);
    }

    /// SoC side: called as time advances; fires the DONE irq when the run
    /// finishes.
    pub fn tick(&mut self, now: u64) {
        if let Some(t) = self.busy_until {
            if now >= t {
                self.busy_until = None;
                if self.irq_enabled {
                    self.irq_level = true;
                }
            }
        }
    }

    pub fn irq_pending(&self) -> bool {
        self.irq_level
    }

    pub fn clear_irq(&mut self) {
        self.irq_level = false;
    }

    /// Completion time for WFI fast-forwarding.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        self.busy_until.map(|t| t.max(now))
    }

    pub fn last_run(&self) -> Option<CgraRun> {
        self.last_run
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u32(self.kernel);
        for &a in &self.args {
            w.u32(a);
        }
        w.bool(self.irq_enabled);
        match &self.pending {
            None => w.bool(false),
            Some(req) => {
                w.bool(true);
                w.u32(req.kernel);
                for &a in &req.args {
                    w.u32(a);
                }
            }
        }
        w.opt_u64(self.busy_until);
        match &self.last_run {
            None => w.bool(false),
            Some(run) => {
                w.bool(true);
                w.u64(run.compute_cycles);
                w.u64(run.config_cycles);
                w.u64(run.contexts);
                w.u64(run.mem_stalls);
            }
        }
        w.bool(self.irq_level);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.kernel = r.u32()?;
        for a in &mut self.args {
            *a = r.u32()?;
        }
        self.irq_enabled = r.bool()?;
        self.pending = if r.bool()? {
            let kernel = r.u32()?;
            let mut args = [0u32; regs::NUM_ARGS];
            for a in &mut args {
                *a = r.u32()?;
            }
            Some(LaunchRequest { kernel, args })
        } else {
            None
        };
        self.busy_until = r.opt_u64()?;
        self.last_run = if r.bool()? {
            Some(CgraRun {
                compute_cycles: r.u64()?,
                config_cycles: r.u64()?,
                contexts: r.u64()?,
                mem_stalls: r.u64()?,
            })
        } else {
            None
        };
        self.irq_level = r.bool()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(cycles: u64) -> CgraRun {
        CgraRun { compute_cycles: cycles, config_cycles: 0, contexts: cycles, mem_stalls: 0 }
    }

    #[test]
    fn launch_lifecycle() {
        let mut d = CgraDevice::new();
        d.write(regs::KERNEL, kernel_id::CONV2D, );
        d.write(regs::ARG_BASE, 0x1000);
        d.write(regs::ARG_BASE + 4, 0x2000);
        d.write(regs::CTRL, 1);
        d.write(regs::START, 1);
        assert_eq!(d.read(regs::STATUS, 0), 0b10); // busy (pending)
        let req = d.take_pending().unwrap();
        assert_eq!(req.kernel, kernel_id::CONV2D);
        assert_eq!(req.args[0], 0x1000);
        d.complete(run(100), 10);
        assert_eq!(d.read(regs::STATUS, 50), 0b10); // still busy
        d.tick(110);
        assert_eq!(d.read(regs::STATUS, 110), 0b01); // done
        assert!(d.irq_pending());
        d.clear_irq();
        assert_eq!(d.read(regs::CYCLES_LO, 110), 100);
    }

    #[test]
    fn start_while_busy_ignored() {
        let mut d = CgraDevice::new();
        d.write(regs::START, 1);
        assert!(d.pending.is_some());
        d.write(regs::KERNEL, 5);
        d.write(regs::START, 1); // ignored: pending not yet serviced
        let req = d.take_pending().unwrap();
        assert_eq!(req.kernel, 0);
        assert!(d.take_pending().is_none());
    }

    #[test]
    fn no_irq_when_disabled() {
        let mut d = CgraDevice::new();
        d.write(regs::START, 1);
        d.take_pending().unwrap();
        d.complete(run(10), 0);
        d.tick(10);
        assert!(!d.irq_pending());
        assert_eq!(d.read(regs::STATUS, 10), 0b01);
    }
}
