//! CGRA mapping for INT32 valid conv2d (Fig 5 "CONV").
//!
//! Output-stationary spatial mapping: each active PE (r, c) owns output
//! pixel (ty0 + r, tile_x*4 + c); the unrolled tap loop (KH*KW*Cin
//! load/load/mul/add quads with constant immediate offsets) runs inside
//! the body, the body loop walks output channels (weights advance by one
//! filter per iteration), and the outer loop walks column tiles (constant
//! x/y pointer strides). One pass per (row-tile, full/remainder column
//! block) — the launch sequence a static mapper would emit.
//!
//! Register map per PE: R0 acc, R1 x_ptr (top-left of this PE's patch),
//! R2 w_ptr (current filter), R3 y_ptr (current output element),
//! R4 x_val, R5 w_val, R6 product.

use crate::cgra::isa::{CgraProgram, Context, Op, PeInstr, Src, COLS, ROWS};

/// Generate the passes for y = conv2d(x, w), 'valid', stride 1.
/// x: (h, w, cin) HWC; wts: (f, kh, kw, cin); y: (oh, ow, f) HWC.
/// All base addresses are byte addresses of i32 arrays.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_passes(
    x_base: u32,
    w_base: u32,
    y_base: u32,
    h: usize,
    w: usize,
    cin: usize,
    f: usize,
    kh: usize,
    kw: usize,
) -> Vec<CgraProgram> {
    assert!(h >= kh && w >= kw && cin > 0 && f > 0);
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    let mut passes = Vec::new();
    let full_col_tiles = ow / COLS;
    let rem_cols = ow % COLS;
    for ty0 in (0..oh).step_by(ROWS) {
        let active_rows = ROWS.min(oh - ty0);
        if full_col_tiles > 0 {
            passes.push(gen_pass(
                x_base,
                w_base,
                y_base,
                w,
                cin,
                f,
                kh,
                kw,
                ow,
                ty0,
                active_rows,
                0,
                COLS,
                full_col_tiles as u32,
            ));
        }
        if rem_cols > 0 {
            passes.push(gen_pass(
                x_base,
                w_base,
                y_base,
                w,
                cin,
                f,
                kh,
                kw,
                ow,
                ty0,
                active_rows,
                full_col_tiles * COLS,
                rem_cols,
                1,
            ));
        }
    }
    passes
}

#[allow(clippy::too_many_arguments)]
fn gen_pass(
    x_base: u32,
    w_base: u32,
    y_base: u32,
    w: usize,
    cin: usize,
    f: usize,
    kh: usize,
    kw: usize,
    ow: usize,
    ty0: usize,
    active_rows: usize,
    tx0: usize,
    active_cols: usize,
    col_tiles: u32,
) -> CgraProgram {
    let active = |r: usize, c: usize| r < active_rows && c < active_cols;
    let pe = PeInstr::new;
    let filter_words = kh * kw * cin;

    let prologue = vec![
        // x_ptr: top-left of the receptive field of pixel (ty0+r, tx0+c)
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            let off = ((ty0 + r) * w + (tx0 + c)) * cin * 4;
            pe(Op::Mov, 1, Src::Imm, Src::Zero, (x_base as usize + off) as i32)
        }),
        // w_ptr: filter 0
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mov, 2, Src::Imm, Src::Zero, w_base as i32)
        }),
        // y_ptr: (ty0+r, tx0+c, f=0)
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            let off = ((ty0 + r) * ow + (tx0 + c)) * f * 4;
            pe(Op::Mov, 3, Src::Imm, Src::Zero, (y_base as usize + off) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mov, 0, Src::Zero, Src::Zero, 0)
        }),
    ];

    // body: all taps for one output channel, then store + advance filter.
    // The filter tap is shared by every PE (they differ only in pixel):
    // PE (0,0) loads it through one memory port and the broadcast bus fans
    // it out — the key operand-reuse trick that makes CONV the
    // best-scaling Fig 5 kernel.
    let mut body = Vec::with_capacity(filter_words * 4 + 3);
    for di in 0..kh {
        for dj in 0..kw {
            for ci in 0..cin {
                let x_off = (((di * w) + dj) * cin + ci) * 4;
                let w_off = ((di * kw + dj) * cin + ci) * 4;
                body.push(Context::from_fn(|r, c| {
                    if !active(r, c) {
                        return PeInstr::NOP;
                    }
                    pe(Op::Load, 4, Src::Reg(1), Src::Imm, x_off as i32)
                }));
                // weight load: PE (0,0) only; lands on the broadcast bus
                body.push(Context::from_fn(|r, c| {
                    if r == 0 && c == 0 {
                        pe(Op::Load, 5, Src::Reg(2), Src::Imm, w_off as i32)
                    } else {
                        PeInstr::NOP
                    }
                }));
                body.push(Context::from_fn(|r, c| {
                    if !active(r, c) {
                        return PeInstr::NOP;
                    }
                    pe(Op::Mul, 6, Src::Reg(4), Src::Bcast, 0)
                }));
                body.push(Context::from_fn(|r, c| {
                    if !active(r, c) {
                        return PeInstr::NOP;
                    }
                    pe(Op::Add, 0, Src::Reg(0), Src::Reg(6), 0)
                }));
            }
        }
    }
    // store y[..., fi] and step to the next channel
    body.push(Context::from_fn(|r, c| {
        if !active(r, c) {
            return PeInstr::NOP;
        }
        pe(Op::StoreInc, 0, Src::Reg(3), Src::Reg(0), 4)
    }));
    body.push(Context::from_fn(|r, c| {
        if !active(r, c) {
            return PeInstr::NOP;
        }
        pe(Op::Mov, 0, Src::Zero, Src::Zero, 0)
    }));
    body.push(Context::from_fn(|r, c| {
        if !active(r, c) {
            return PeInstr::NOP;
        }
        pe(Op::Add, 2, Src::Reg(2), Src::Imm, (filter_words * 4) as i32)
    }));

    // outer: advance to the next column tile (x_ptr += 4 pixels,
    // y_ptr += 4 pixels minus the F words the StoreIncs already added),
    // rewind w_ptr.
    let outer = vec![
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Add, 1, Src::Reg(1), Src::Imm, (COLS * cin * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Add, 3, Src::Reg(3), Src::Imm, ((COLS - 1) * f * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Add, 2, Src::Reg(2), Src::Imm, -((f * filter_words * 4) as i32))
        }),
    ];

    CgraProgram {
        name: format!("conv_ty{ty0}_tx{tx0}"),
        prologue,
        body,
        body_iterations: f as u32,
        outer,
        outer_iterations: col_tiles,
        epilogue: vec![],
    }
}
