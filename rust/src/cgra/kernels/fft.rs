//! CGRA mapping for the Q15 radix-2 FFT (Fig 5 "FFT").
//!
//! One pass per stage, reconfigured between stages (the VWR2A-style flow:
//! a reconfigurable array reloads per-phase configurations). Input must be
//! **bit-reversed** by the driver first (the guest does this on the CPU;
//! see `workloads::reference::bit_reverse_permute`).
//!
//! Within a stage, the flat butterfly index k (0..n/2) is distributed
//! round-robin over the active PEs; each PE derives the even/odd/twiddle
//! addresses from k with shift/mask arithmetic (half = 1 << (s-1) is a
//! power of two, so no division is needed):
//!
//! ```text
//! even = ((k >> (s-1)) << s) + (k & (half-1))
//! odd  = even + half
//! tw   = (k & (half-1)) << (stages - s)
//! ```
//!
//! Register map per PE: R1 k, R2 even byte offset (scratch), R3 twiddle
//! byte offset (scratch), R4 er, R5 ei, R6 or, R7 oi, R8 twr, R9 twi,
//! R10 tr, R11 ti, R12..R15 butterfly outputs.

use crate::cgra::isa::{CgraProgram, Context, Op, PeInstr, Src, COLS, NUM_PES};

/// Generate one pass per stage for an n-point FFT (n a power of two >= 2).
/// re/im/wr/wi are byte addresses of the data and twiddle arrays
/// (wr/wi hold n/2 Q15 words as produced by
/// [`crate::workloads::reference::twiddles_q15`]).
pub fn fft_passes(re_base: u32, im_base: u32, wr_base: u32, wi_base: u32, n: usize) -> Vec<CgraProgram> {
    assert!(n.is_power_of_two() && n >= 2, "n must be a power of two >= 2");
    let stages = n.trailing_zeros() as usize;
    let butterflies = n / 2;
    let active_pes = NUM_PES.min(butterflies);
    let iters = (butterflies / active_pes) as u32;
    (1..=stages)
        .map(|s| gen_stage(re_base, im_base, wr_base, wi_base, n, s, stages, active_pes, iters))
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn gen_stage(
    re_base: u32,
    im_base: u32,
    wr_base: u32,
    wi_base: u32,
    _n: usize,
    s: usize,
    stages: usize,
    active_pes: usize,
    iters: u32,
) -> CgraProgram {
    let half = 1i32 << (s - 1);
    let pe = PeInstr::new;
    let act = |r: usize, c: usize, ins: PeInstr| {
        if r * COLS + c < active_pes {
            ins
        } else {
            PeInstr::NOP
        }
    };

    // prologue: k = linear PE index
    let prologue = vec![
        Context::from_fn(|r, c| act(r, c, pe(Op::Mul, 1, Src::Row, Src::Imm, COLS as i32))),
        Context::from_fn(|r, c| act(r, c, pe(Op::Add, 1, Src::Reg(1), Src::Col, 0))),
    ];

    let mut body = Vec::with_capacity(32);
    let mut push = |ins: PeInstr| {
        body.push(Context::from_fn(|r, c| act(r, c, ins)));
    };

    // address generation
    push(pe(Op::Srl, 2, Src::Reg(1), Src::Imm, (s - 1) as i32));
    push(pe(Op::Sll, 2, Src::Reg(2), Src::Imm, s as i32));
    push(pe(Op::And, 3, Src::Reg(1), Src::Imm, half - 1));
    push(pe(Op::Add, 2, Src::Reg(2), Src::Reg(3), 0)); // even index
    push(pe(Op::Sll, 3, Src::Reg(3), Src::Imm, (stages - s) as i32)); // tw index
    push(pe(Op::Sll, 2, Src::Reg(2), Src::Imm, 2)); // even byte offset
    push(pe(Op::Sll, 3, Src::Reg(3), Src::Imm, 2)); // tw byte offset
    // operand loads
    push(pe(Op::Load, 4, Src::Reg(2), Src::Imm, re_base as i32));
    push(pe(Op::Load, 5, Src::Reg(2), Src::Imm, im_base as i32));
    push(pe(Op::Load, 6, Src::Reg(2), Src::Imm, re_base as i32 + half * 4));
    push(pe(Op::Load, 7, Src::Reg(2), Src::Imm, im_base as i32 + half * 4));
    push(pe(Op::Load, 8, Src::Reg(3), Src::Imm, wr_base as i32));
    push(pe(Op::Load, 9, Src::Reg(3), Src::Imm, wi_base as i32));
    // t = W * odd (Q15 complex multiply)
    push(pe(Op::MulQ15, 10, Src::Reg(6), Src::Reg(8), 0));
    push(pe(Op::MulQ15, 11, Src::Reg(7), Src::Reg(9), 0));
    push(pe(Op::Sub, 10, Src::Reg(10), Src::Reg(11), 0)); // tr
    push(pe(Op::MulQ15, 11, Src::Reg(6), Src::Reg(9), 0));
    push(pe(Op::MulQ15, 12, Src::Reg(7), Src::Reg(8), 0));
    push(pe(Op::Add, 11, Src::Reg(11), Src::Reg(12), 0)); // ti
    // scaled butterfly outputs
    push(pe(Op::Add, 12, Src::Reg(4), Src::Reg(10), 0));
    push(pe(Op::Sra, 12, Src::Reg(12), Src::Imm, 1)); // new even re
    push(pe(Op::Add, 13, Src::Reg(5), Src::Reg(11), 0));
    push(pe(Op::Sra, 13, Src::Reg(13), Src::Imm, 1)); // new even im
    push(pe(Op::Sub, 14, Src::Reg(4), Src::Reg(10), 0));
    push(pe(Op::Sra, 14, Src::Reg(14), Src::Imm, 1)); // new odd re
    push(pe(Op::Sub, 15, Src::Reg(5), Src::Reg(11), 0));
    push(pe(Op::Sra, 15, Src::Reg(15), Src::Imm, 1)); // new odd im
    // writeback
    push(pe(Op::Store, 0, Src::Reg(2), Src::Reg(12), re_base as i32));
    push(pe(Op::Store, 0, Src::Reg(2), Src::Reg(13), im_base as i32));
    push(pe(Op::Store, 0, Src::Reg(2), Src::Reg(14), re_base as i32 + half * 4));
    push(pe(Op::Store, 0, Src::Reg(2), Src::Reg(15), im_base as i32 + half * 4));
    // next butterfly for this PE
    push(pe(Op::Add, 1, Src::Reg(1), Src::Imm, active_pes as i32));

    CgraProgram {
        name: format!("fft_stage{s}"),
        prologue,
        body,
        body_iterations: iters,
        outer: vec![],
        outer_iterations: 1,
        epilogue: vec![],
    }
}
