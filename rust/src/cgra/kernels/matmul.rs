//! CGRA mapping for INT32 matmul (Fig 5 "MM").
//!
//! Output-stationary mapping: each active PE (r, c) owns output element
//! C[tile_row*4 + r, col_tile*4 + c] and walks the K dimension with
//! post-increment loads. The two-level hardware loop runs K in the inner
//! body and row-tiles in the outer loop; column tiles (n > 4) and the
//! row remainder (m % 4) become extra passes, each paying its own
//! reconfiguration cost — exactly how a real OpenEdgeCGRA launch sequence
//! would look.
//!
//! Register map per PE: R0 acc, R1 a_ptr, R2 b_ptr, R3 c_ptr,
//! R4 a_val, R5 b_val, R6 product.

use crate::cgra::isa::{CgraProgram, Context, Op, PeInstr, Src, COLS, ROWS};

/// Generate the passes for C = A(m x k) @ B(k x n). Addresses are byte
/// addresses of row-major i32 arrays in CGRA-visible memory.
pub fn matmul_passes(a_base: u32, b_base: u32, c_base: u32, m: usize, k: usize, n: usize) -> Vec<CgraProgram> {
    assert!(m > 0 && k > 0 && n > 0);
    let mut passes = Vec::new();
    let full_row_tiles = m / ROWS;
    let rem_rows = m % ROWS;
    for c0 in (0..n).step_by(COLS) {
        let active_cols = COLS.min(n - c0);
        if full_row_tiles > 0 {
            passes.push(gen_pass(
                a_base, b_base, c_base, k, n, 0, full_row_tiles as u32, ROWS, c0, active_cols,
            ));
        }
        if rem_rows > 0 {
            passes.push(gen_pass(
                a_base,
                b_base,
                c_base,
                k,
                n,
                full_row_tiles * ROWS,
                1,
                rem_rows,
                c0,
                active_cols,
            ));
        }
    }
    passes
}

#[allow(clippy::too_many_arguments)]
fn gen_pass(
    a_base: u32,
    b_base: u32,
    c_base: u32,
    k: usize,
    n: usize,
    row0: usize,
    row_tiles: u32,
    active_rows: usize,
    c0: usize,
    active_cols: usize,
) -> CgraProgram {
    let active = |r: usize, c: usize| r < active_rows && c < active_cols;
    let pe = PeInstr::new;

    // prologue: pointer setup + acc clear
    let prologue = vec![
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mov, 1, Src::Imm, Src::Zero, (a_base as usize + (row0 + r) * k * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mov, 2, Src::Imm, Src::Zero, (b_base as usize + (c0 + c) * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mov, 3, Src::Imm, Src::Zero, (c_base as usize + ((row0 + r) * n + c0 + c) * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mov, 0, Src::Zero, Src::Zero, 0)
        }),
    ];

    // body: one K step
    let body = vec![
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::LoadInc, 4, Src::Reg(1), Src::Zero, 4)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::LoadInc, 5, Src::Reg(2), Src::Zero, (n * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mul, 6, Src::Reg(4), Src::Reg(5), 0)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Add, 0, Src::Reg(0), Src::Reg(6), 0)
        }),
    ];

    // outer (per row tile): store C, clear acc, advance A to row r+4,
    // rewind B to the top of its columns.
    let outer = vec![
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            // store then advance c_ptr by 4 rows of C
            pe(Op::StoreInc, 0, Src::Reg(3), Src::Reg(0), (ROWS * n * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            pe(Op::Mov, 0, Src::Zero, Src::Zero, 0)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            // a_ptr is at end of row (row0+r): advance (ROWS-1) more rows
            pe(Op::Add, 1, Src::Reg(1), Src::Imm, ((ROWS - 1) * k * 4) as i32)
        }),
        Context::from_fn(|r, c| {
            if !active(r, c) {
                return PeInstr::NOP;
            }
            // b_ptr walked K rows: rewind
            pe(Op::Add, 2, Src::Reg(2), Src::Imm, -((k * n * 4) as i32))
        }),
    ];

    CgraProgram {
        name: format!("mm_r{row0}_c{c0}"),
        prologue,
        body,
        body_iterations: k as u32,
        outer,
        outer_iterations: row_tiles,
        epilogue: vec![],
    }
}
