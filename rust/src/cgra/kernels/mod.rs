//! CGRA kernel mappings: configuration generators for the Fig 5 case
//! studies (MM, CONV, FFT).
//!
//! These play the role of the paper's CGRA mapping/compilation flow
//! ([32]): each generator takes the kernel's memory layout (byte addresses
//! in CGRA-visible SRAM) and emits one or more [`CgraProgram`] *passes*.
//! Multi-pass kernels model per-launch reconfiguration exactly as the real
//! array pays it (config streaming cycles are part of [`CgraRun`]).
//!
//! All mappings produce results bit-identical to
//! [`crate::workloads::reference`] — verified by the unit tests here and
//! the cross-implementation integration tests.

pub mod conv2d;
pub mod fft;
pub mod matmul;

pub use conv2d::conv2d_passes;
pub use fft::fft_passes;
pub use matmul::matmul_passes;

use super::{CgraCore, CgraFault, CgraMem, CgraProgram, CgraRun};

/// Execute a sequence of passes, merging cycle accounting. The core is
/// reset between passes (each pass re-establishes its pointers).
pub fn run_passes<M: CgraMem>(
    core: &mut CgraCore,
    passes: &[CgraProgram],
    mem: &mut M,
) -> Result<CgraRun, CgraFault> {
    let mut total = CgraRun::default();
    for pass in passes {
        core.reset();
        total.merge(core.execute(pass, mem)?);
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workloads::reference as refimpl;

    /// Memory helper: pack slices into a flat word memory at word offsets.
    fn mem_with(regions: &[(&[i32], usize)], total_words: usize) -> Vec<u32> {
        let mut mem = vec![0u32; total_words];
        for (data, word_off) in regions {
            for (i, v) in data.iter().enumerate() {
                mem[word_off + i] = *v as u32;
            }
        }
        mem
    }

    fn extract(mem: &[u32], word_off: usize, n: usize) -> Vec<i32> {
        mem[word_off..word_off + n].iter().map(|&w| w as i32).collect()
    }

    #[test]
    fn matmul_paper_shape_121x16x4() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (121, 16, 4);
        let a = rng.vec_i32(m * k, -1000, 1000);
        let b = rng.vec_i32(k * n, -1000, 1000);
        let (a_off, b_off, c_off) = (0usize, 4096usize, 8192usize);
        let mut mem = mem_with(&[(&a, a_off), (&b, b_off)], 16384);
        let passes =
            matmul_passes(a_off as u32 * 4, b_off as u32 * 4, c_off as u32 * 4, m, k, n);
        let mut core = CgraCore::new();
        let run = run_passes(&mut core, &passes, &mut mem).unwrap();
        assert_eq!(extract(&mem, c_off, m * n), refimpl::matmul_i32(&a, &b, m, k, n));
        assert!(run.compute_cycles > 0 && run.config_cycles > 0);
    }

    #[test]
    fn matmul_small_and_odd_shapes() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (4, 4, 4), (5, 2, 3), (8, 16, 4), (7, 3, 7)] {
            let a = rng.vec_i32(m * k, -100, 100);
            let b = rng.vec_i32(k * n, -100, 100);
            let (a_off, b_off, c_off) = (0usize, 1024usize, 2048usize);
            let mut mem = mem_with(&[(&a, a_off), (&b, b_off)], 4096);
            let passes =
                matmul_passes(a_off as u32 * 4, b_off as u32 * 4, c_off as u32 * 4, m, k, n);
            let mut core = CgraCore::new();
            run_passes(&mut core, &passes, &mut mem).unwrap();
            assert_eq!(
                extract(&mem, c_off, m * n),
                refimpl::matmul_i32(&a, &b, m, k, n),
                "shape ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn conv2d_paper_shape_16x16x3_8f() {
        let mut rng = Rng::new(3);
        let (h, w, cin, f, kh, kw) = (16, 16, 3, 8, 3, 3);
        let x = rng.vec_i32(h * w * cin, -500, 500);
        let wts = rng.vec_i32(f * kh * kw * cin, -500, 500);
        let (x_off, w_off, y_off) = (0usize, 2048usize, 4096usize);
        let mut mem = mem_with(&[(&x, x_off), (&wts, w_off)], 16384);
        let passes = conv2d_passes(
            x_off as u32 * 4,
            w_off as u32 * 4,
            y_off as u32 * 4,
            h,
            w,
            cin,
            f,
            kh,
            kw,
        );
        let mut core = CgraCore::new();
        run_passes(&mut core, &passes, &mut mem).unwrap();
        let oh = h - kh + 1;
        let ow = w - kw + 1;
        assert_eq!(
            extract(&mem, y_off, oh * ow * f),
            refimpl::conv2d_i32(&x, &wts, h, w, cin, f, kh, kw)
        );
    }

    #[test]
    fn conv2d_odd_shapes() {
        let mut rng = Rng::new(4);
        for &(h, w, cin, f, kh, kw) in
            &[(5, 5, 1, 1, 3, 3), (6, 9, 2, 3, 2, 2), (4, 4, 1, 5, 1, 1), (10, 7, 3, 2, 3, 3)]
        {
            let x = rng.vec_i32(h * w * cin, -50, 50);
            let wts = rng.vec_i32(f * kh * kw * cin, -50, 50);
            let (x_off, w_off, y_off) = (0usize, 2048usize, 4096usize);
            let mut mem = mem_with(&[(&x, x_off), (&wts, w_off)], 8192);
            let passes = conv2d_passes(
                x_off as u32 * 4,
                w_off as u32 * 4,
                y_off as u32 * 4,
                h,
                w,
                cin,
                f,
                kh,
                kw,
            );
            let mut core = CgraCore::new();
            run_passes(&mut core, &passes, &mut mem).unwrap();
            let oh = h - kh + 1;
            let ow = w - kw + 1;
            assert_eq!(
                extract(&mem, y_off, oh * ow * f),
                refimpl::conv2d_i32(&x, &wts, h, w, cin, f, kh, kw),
                "shape ({h},{w},{cin},{f},{kh},{kw})"
            );
        }
    }

    #[test]
    fn fft_512_matches_reference() {
        let mut rng = Rng::new(5);
        let n = 512;
        let mut re = rng.vec_i32(n, -(1 << 15), 1 << 15);
        let mut im = rng.vec_i32(n, -(1 << 15), 1 << 15);
        let mut want_re = re.clone();
        let mut want_im = im.clone();
        refimpl::fft_q15(&mut want_re, &mut want_im);

        // guest driver responsibility: bit-reverse before CGRA stages
        refimpl::bit_reverse_permute(&mut re, &mut im);
        let (wr, wi) = refimpl::twiddles_q15(n);
        let (re_off, im_off, wr_off, wi_off) = (0usize, 1024usize, 2048usize, 3072usize);
        let mut mem =
            mem_with(&[(&re, re_off), (&im, im_off), (&wr, wr_off), (&wi, wi_off)], 8192);
        let passes = fft_passes(
            re_off as u32 * 4,
            im_off as u32 * 4,
            wr_off as u32 * 4,
            wi_off as u32 * 4,
            n,
        );
        assert_eq!(passes.len(), 9); // log2(512) stage launches
        let mut core = CgraCore::new();
        let run = run_passes(&mut core, &passes, &mut mem).unwrap();
        assert_eq!(extract(&mem, re_off, n), want_re);
        assert_eq!(extract(&mem, im_off, n), want_im);
        // load-heavy kernel: stalls should be a visible fraction
        assert!(run.mem_stalls > run.contexts / 4, "{run:?}");
    }

    #[test]
    fn fft_small_sizes() {
        let mut rng = Rng::new(6);
        for logn in 1..=6 {
            let n = 1usize << logn;
            let mut re = rng.vec_i32(n, -(1 << 15), 1 << 15);
            let mut im = rng.vec_i32(n, -(1 << 15), 1 << 15);
            let mut want_re = re.clone();
            let mut want_im = im.clone();
            refimpl::fft_q15(&mut want_re, &mut want_im);
            refimpl::bit_reverse_permute(&mut re, &mut im);
            let (wr, wi) = refimpl::twiddles_q15(n);
            let (re_off, im_off, wr_off, wi_off) = (0usize, 256usize, 512usize, 768usize);
            let mut mem =
                mem_with(&[(&re, re_off), (&im, im_off), (&wr, wr_off), (&wi, wi_off)], 1024);
            let passes = fft_passes(
                re_off as u32 * 4,
                im_off as u32 * 4,
                wr_off as u32 * 4,
                wi_off as u32 * 4,
                n,
            );
            let mut core = CgraCore::new();
            run_passes(&mut core, &passes, &mut mem).unwrap();
            assert_eq!(extract(&mem, re_off, n), want_re, "n={n} re");
            assert_eq!(extract(&mem, im_off, n), want_im, "n={n} im");
        }
    }

    #[test]
    fn fig5_shape_conv_speedup_exceeds_others() {
        // Structural property behind Fig 5: on the case-study shapes the
        // CGRA's cycles-per-MAC is best for CONV (compute-dense, operand
        // reuse) and worst for FFT (load-heavy + per-stage reconfig).
        let mut core = CgraCore::new();

        let mut mem = vec![0u32; 16384];
        let mm = matmul_passes(0, 4096 * 4, 8192 * 4, 121, 16, 4);
        let mm_run = run_passes(&mut core, &mm, &mut mem).unwrap();
        let mm_macs = 121 * 16 * 4;

        let mut mem = vec![0u32; 16384];
        let cv = conv2d_passes(0, 2048 * 4, 4096 * 4, 16, 16, 3, 8, 3, 3);
        let cv_run = run_passes(&mut core, &cv, &mut mem).unwrap();
        let cv_macs = 14 * 14 * 8 * 27;

        let mut mem = vec![0u32; 8192];
        let ff = fft_passes(0, 1024 * 4, 2048 * 4, 3072 * 4, 512);
        let ff_run = run_passes(&mut core, &ff, &mut mem).unwrap();
        let ff_macs = 256 * 9 * 4; // 4 Q15 muls per butterfly

        let mm_cpm = mm_run.total_cycles() as f64 / mm_macs as f64;
        let cv_cpm = cv_run.total_cycles() as f64 / cv_macs as f64;
        let ff_cpm = ff_run.total_cycles() as f64 / ff_macs as f64;
        assert!(cv_cpm < mm_cpm, "conv {cv_cpm} vs mm {mm_cpm}");
        assert!(cv_cpm < ff_cpm, "conv {cv_cpm} vs fft {ff_cpm}");
    }
}
