//! CGRA configuration-word ISA.
//!
//! Models an OpenEdgeCGRA-class array (paper ref [31]): a 4x4 torus of
//! processing elements executing one configuration word each per cycle in
//! lockstep. A configuration *context* is the set of per-PE words for one
//! cycle; a kernel is a prologue, a two-level hardware loop (inner body ×
//! `body_iterations`, then per-outer-iteration `outer` contexts, repeated
//! `outer_iterations` times), and an epilogue — the loop structure the
//! OpenEdgeCGRA sequencer's counters provide.
//!
//! Each PE has a 16-entry register file, an output register visible to
//! its four torus neighbors on the *next* cycle, and a port into the
//! array's shared memory masters (2 OBI ports into the SoC bus — see
//! [`super::MEM_PORTS`]; concurrent memory ops beyond the port count
//! serialize, which is what keeps load-heavy mappings from scaling
//! linearly with PE count, the Fig 5 shape).

/// Grid dimensions (4x4, as in OpenEdgeCGRA).
pub const ROWS: usize = 4;
pub const COLS: usize = 4;
pub const NUM_PES: usize = ROWS * COLS;
/// Registers per PE.
pub const NUM_REGS: usize = 16;

/// Operand source for a PE instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    /// Own register.
    Reg(u8),
    /// The instruction's immediate field.
    Imm,
    /// Neighbor output registers (previous cycle's value, torus wrap).
    North,
    East,
    South,
    West,
    /// This PE's row / column index (constants wired into the fabric).
    Row,
    Col,
    /// The array's shared broadcast bus: PE (0,0)'s output register from
    /// the previous cycle (used to fan one loaded operand out to all PEs,
    /// e.g. the conv weights every PE multiplies by).
    Bcast,
    /// Constant zero.
    Zero,
}

/// PE operation. Integer ops match the RV32/ref semantics bit-for-bit
/// (wrap-around adds/muls, arithmetic shifts, Q15 multiply with 64-bit
/// intermediate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Nop,
    /// dst = a + b
    Add,
    /// dst = a - b
    Sub,
    /// dst = a * b (low 32)
    Mul,
    /// dst = (a * b) >> 15 with 64-bit intermediate (Q15 FU).
    MulQ15,
    /// dst = a >> b (arithmetic)
    Sra,
    /// dst = a >> b (logical)
    Srl,
    /// dst = a << b
    Sll,
    And,
    Or,
    Xor,
    /// dst = (a < b) signed
    Slt,
    /// dst = a (move/select)
    Mov,
    /// dst = mem[a + b] (byte address; b is usually `Imm` or `Zero`).
    Load,
    /// dst = mem[a]; then the a-register += imm (post-increment
    /// addressing; a must be `Src::Reg`).
    LoadInc,
    /// mem[a + imm] = b.
    Store,
    /// mem[a] = b; then the a-register += imm (a must be `Src::Reg`).
    StoreInc,
}

impl Op {
    /// True for ops that use a memory port (contention accounting).
    pub fn is_mem(self) -> bool {
        matches!(self, Op::Load | Op::LoadInc | Op::Store | Op::StoreInc)
    }
}

/// One PE's configuration word for one context.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeInstr {
    pub op: Op,
    /// Destination register (ignored for stores/Nop).
    pub dst: u8,
    pub a: Src,
    pub b: Src,
    pub imm: i32,
}

impl PeInstr {
    pub const NOP: PeInstr = PeInstr { op: Op::Nop, dst: 0, a: Src::Zero, b: Src::Zero, imm: 0 };

    pub fn new(op: Op, dst: u8, a: Src, b: Src, imm: i32) -> Self {
        Self { op, dst, a, b, imm }
    }
}

/// One cycle of configuration for the whole grid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Context {
    pub pe: [PeInstr; NUM_PES],
}

impl Context {
    pub fn nops() -> Self {
        Self { pe: [PeInstr::NOP; NUM_PES] }
    }

    /// Build with a closure over (row, col). Return [`PeInstr::NOP`] for
    /// PEs that idle in this context.
    pub fn from_fn(mut f: impl FnMut(usize, usize) -> PeInstr) -> Self {
        let mut pe = [PeInstr::NOP; NUM_PES];
        for r in 0..ROWS {
            for c in 0..COLS {
                pe[r * COLS + c] = f(r, c);
            }
        }
        Self { pe }
    }

    /// Same instruction on every PE.
    pub fn broadcast(ins: PeInstr) -> Self {
        Self { pe: [ins; NUM_PES] }
    }
}

/// A complete kernel configuration with the two-level hardware loop:
///
/// ```text
/// prologue
/// repeat outer_iterations:
///     repeat body_iterations:
///         body
///     outer
/// epilogue
/// ```
#[derive(Clone, Debug)]
pub struct CgraProgram {
    pub name: String,
    pub prologue: Vec<Context>,
    pub body: Vec<Context>,
    pub body_iterations: u32,
    /// Contexts run once per outer iteration, after the body loop
    /// (pointer adjustments between tiles; empty for single-level loops).
    pub outer: Vec<Context>,
    pub outer_iterations: u32,
    pub epilogue: Vec<Context>,
}

impl CgraProgram {
    /// Single-level loop helper.
    pub fn simple(
        name: &str,
        prologue: Vec<Context>,
        body: Vec<Context>,
        body_iterations: u32,
        epilogue: Vec<Context>,
    ) -> Self {
        Self {
            name: name.into(),
            prologue,
            body,
            body_iterations,
            outer: Vec::new(),
            outer_iterations: 1,
            epilogue,
        }
    }

    /// Total configuration words (for the reconfiguration-cost model).
    pub fn config_words(&self) -> usize {
        (self.prologue.len() + self.body.len() + self.outer.len() + self.epilogue.len()) * NUM_PES
    }

    /// Contexts executed (ignoring memory stalls).
    pub fn contexts_executed(&self) -> u64 {
        self.prologue.len() as u64
            + self.outer_iterations as u64
                * (self.body.len() as u64 * self.body_iterations as u64 + self.outer.len() as u64)
            + self.epilogue.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_from_fn_layout() {
        let ctx = Context::from_fn(|r, c| {
            PeInstr::new(Op::Mov, 0, Src::Imm, Src::Zero, (r * 10 + c) as i32)
        });
        assert_eq!(ctx.pe[0].imm, 0);
        assert_eq!(ctx.pe[5].imm, 11); // r=1, c=1
        assert_eq!(ctx.pe[15].imm, 33);
    }

    #[test]
    fn program_accounting_two_level() {
        let p = CgraProgram {
            name: "t".into(),
            prologue: vec![Context::nops(); 2],
            body: vec![Context::nops(); 3],
            body_iterations: 10,
            outer: vec![Context::nops(); 1],
            outer_iterations: 5,
            epilogue: vec![Context::nops()],
        };
        assert_eq!(p.contexts_executed(), 2 + 5 * (30 + 1) + 1);
        assert_eq!(p.config_words(), 7 * 16);
    }

    #[test]
    fn simple_constructor() {
        let p = CgraProgram::simple("s", vec![], vec![Context::nops()], 4, vec![]);
        assert_eq!(p.contexts_executed(), 4);
        assert_eq!(p.outer_iterations, 1);
    }

    #[test]
    fn mem_op_classification() {
        assert!(Op::LoadInc.is_mem());
        assert!(Op::Store.is_mem());
        assert!(!Op::MulQ15.is_mem());
    }
}
