//! CGRA emulator: the "RTL-stage" accelerator of the design cycle.
//!
//! Executes [`isa::CgraProgram`] configurations over guest memory,
//! producing both **results** (bit-exact with the ref oracle / Pallas
//! kernels / RV32 kernels) and **cycle counts** (contexts + memory-port
//! stalls + reconfiguration cost), which the perf monitor attributes to
//! the CGRA power domain and the energy model prices.
//!
//! Microarchitecture model (documented deltas from OpenEdgeCGRA in
//! DESIGN.md): 4x4 torus, lockstep contexts, [`MEM_PORTS`] shared memory
//! masters into the SoC bus (memory ops beyond the port count in one
//! context serialize — this keeps load-heavy kernels like FFT from
//! scaling as well as compute-dense CONV, which is the Fig 5 shape),
//! neighbor routing reads the previous context's outputs
//! (double-buffered), stores commit at end of context.

pub mod device;
pub mod isa;
pub mod kernels;

pub use device::CgraDevice;
pub use isa::{CgraProgram, Context, Op, PeInstr, Src, COLS, NUM_PES, NUM_REGS, ROWS};

/// Word-addressed memory the CGRA masters (implemented by the SoC over
/// the SRAM banks, and by flat vectors in tests).
pub trait CgraMem {
    fn read32(&mut self, addr: u32) -> Result<u32, ()>;
    fn write32(&mut self, addr: u32, value: u32) -> Result<(), ()>;
}

impl CgraMem for Vec<u32> {
    fn read32(&mut self, addr: u32) -> Result<u32, ()> {
        self.get((addr / 4) as usize).copied().ok_or(())
    }

    fn write32(&mut self, addr: u32, value: u32) -> Result<(), ()> {
        match self.get_mut((addr / 4) as usize) {
            Some(slot) => {
                *slot = value;
                Ok(())
            }
            None => Err(()),
        }
    }
}

/// Shared memory masters between the array and the SoC bus.
pub const MEM_PORTS: u64 = 2;

/// Reconfiguration cost: cycles per configuration word streamed into the
/// context memories (AXI-lite at one word/cycle in OpenEdgeCGRA).
pub const CONFIG_CYCLES_PER_WORD: u64 = 1;

/// Execution outcome.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CgraRun {
    /// Compute cycles (contexts + memory stalls).
    pub compute_cycles: u64,
    /// Reconfiguration cycles (config streaming).
    pub config_cycles: u64,
    /// Total contexts executed.
    pub contexts: u64,
    /// Memory-port stall cycles included in `compute_cycles`.
    pub mem_stalls: u64,
}

impl CgraRun {
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.config_cycles
    }

    /// Merge a subsequent run (multi-pass kernels: per-stage FFT,
    /// remainder tiles).
    pub fn merge(&mut self, other: CgraRun) {
        self.compute_cycles += other.compute_cycles;
        self.config_cycles += other.config_cycles;
        self.contexts += other.contexts;
        self.mem_stalls += other.mem_stalls;
    }
}

/// Runtime error (bad memory access in a mapping — an emulation bug, not
/// a guest-recoverable fault).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CgraFault {
    pub context_index: u64,
    pub pe: usize,
    pub addr: u32,
}

impl std::fmt::Display for CgraFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CGRA fault: pe {} at context {} touched bad address {:#x}",
            self.pe, self.context_index, self.addr
        )
    }
}

impl std::error::Error for CgraFault {}

/// The PE-array state machine.
#[derive(Clone, Debug)]
pub struct CgraCore {
    regs: [[i32; NUM_REGS]; NUM_PES],
    /// Output registers: `out[pe]` as produced by the previous context.
    out: [i32; NUM_PES],
}

impl Default for CgraCore {
    fn default() -> Self {
        Self::new()
    }
}

impl CgraCore {
    pub fn new() -> Self {
        Self { regs: [[0; NUM_REGS]; NUM_PES], out: [0; NUM_PES] }
    }

    pub fn reset(&mut self) {
        self.regs = [[0; NUM_REGS]; NUM_PES];
        self.out = [0; NUM_PES];
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        for pe in &self.regs {
            for &r in pe {
                w.i32(r);
            }
        }
        for &o in &self.out {
            w.i32(o);
        }
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        for pe in &mut self.regs {
            for v in pe {
                *v = r.i32()?;
            }
        }
        for o in &mut self.out {
            *o = r.i32()?;
        }
        Ok(())
    }

    #[inline]
    fn src_value(&self, pe: usize, s: Src, imm: i32) -> i32 {
        let r = pe / COLS;
        let c = pe % COLS;
        match s {
            Src::Reg(i) => self.regs[pe][i as usize],
            Src::Imm => imm,
            Src::Zero => 0,
            Src::Bcast => self.out[0],
            Src::Row => r as i32,
            Src::Col => c as i32,
            // torus neighbors, previous-context outputs
            Src::North => self.out[((r + ROWS - 1) % ROWS) * COLS + c],
            Src::South => self.out[((r + 1) % ROWS) * COLS + c],
            Src::West => self.out[r * COLS + (c + COLS - 1) % COLS],
            Src::East => self.out[r * COLS + (c + 1) % COLS],
        }
    }

    /// Execute one context. Returns memory stall cycles beyond the base
    /// context cycle.
    fn step<M: CgraMem>(
        &mut self,
        ctx: &Context,
        mem: &mut M,
        ctx_index: u64,
    ) -> Result<u64, CgraFault> {
        let mut new_out = self.out;
        let mut mem_ops = 0u64;
        // deferred stores commit after all reads in this context
        let mut stores: [(u32, u32, usize); NUM_PES] = [(0, 0, usize::MAX); NUM_PES];
        let mut n_stores = 0usize;

        for pe in 0..NUM_PES {
            let ins = &ctx.pe[pe];
            if ins.op == Op::Nop {
                continue;
            }
            let a = self.src_value(pe, ins.a, ins.imm);
            let b = self.src_value(pe, ins.b, ins.imm);
            if ins.op.is_mem() {
                mem_ops += 1;
            }
            let result: Option<i32> = match ins.op {
                Op::Nop => None,
                Op::Add => Some(a.wrapping_add(b)),
                Op::Sub => Some(a.wrapping_sub(b)),
                Op::Mul => Some(a.wrapping_mul(b)),
                Op::MulQ15 => Some(((a as i64 * b as i64) >> 15) as i32),
                Op::Sra => Some(a >> (b & 31)),
                Op::Srl => Some(((a as u32) >> (b & 31)) as i32),
                Op::Sll => Some(((a as u32) << (b & 31)) as i32),
                Op::And => Some(a & b),
                Op::Or => Some(a | b),
                Op::Xor => Some(a ^ b),
                Op::Slt => Some((a < b) as i32),
                Op::Mov => Some(a),
                Op::Load => {
                    let addr = a.wrapping_add(b) as u32;
                    let v = mem
                        .read32(addr)
                        .map_err(|_| CgraFault { context_index: ctx_index, pe, addr })?;
                    Some(v as i32)
                }
                Op::LoadInc => {
                    let addr = a as u32;
                    let v = mem
                        .read32(addr)
                        .map_err(|_| CgraFault { context_index: ctx_index, pe, addr })?;
                    if let Src::Reg(i) = ins.a {
                        self.regs[pe][i as usize] =
                            self.regs[pe][i as usize].wrapping_add(ins.imm);
                    }
                    Some(v as i32)
                }
                Op::Store => {
                    stores[n_stores] = ((a.wrapping_add(ins.imm)) as u32, b as u32, pe);
                    n_stores += 1;
                    None
                }
                Op::StoreInc => {
                    stores[n_stores] = (a as u32, b as u32, pe);
                    n_stores += 1;
                    if let Src::Reg(i) = ins.a {
                        self.regs[pe][i as usize] =
                            self.regs[pe][i as usize].wrapping_add(ins.imm);
                    }
                    None
                }
            };
            if let Some(v) = result {
                self.regs[pe][ins.dst as usize] = v;
                new_out[pe] = v;
            }
        }

        for &(addr, value, pe) in &stores[..n_stores] {
            mem.write32(addr, value)
                .map_err(|_| CgraFault { context_index: ctx_index, pe, addr })?;
        }
        self.out = new_out;

        // Memory-port contention: MEM_PORTS ops issue per cycle; the
        // lockstep grid stalls for the rest.
        let stalls = mem_ops.div_ceil(MEM_PORTS).saturating_sub(1);
        Ok(stalls)
    }

    /// Run a full program over `mem`. The core is *not* reset first —
    /// multi-pass kernels may carry register state between passes; call
    /// [`CgraCore::reset`] between unrelated kernels.
    pub fn execute<M: CgraMem>(
        &mut self,
        prog: &CgraProgram,
        mem: &mut M,
    ) -> Result<CgraRun, CgraFault> {
        let mut contexts = 0u64;
        let mut stalls = 0u64;
        for ctx in &prog.prologue {
            stalls += self.step(ctx, mem, contexts)?;
            contexts += 1;
        }
        for _ in 0..prog.outer_iterations {
            for _ in 0..prog.body_iterations {
                for ctx in &prog.body {
                    stalls += self.step(ctx, mem, contexts)?;
                    contexts += 1;
                }
            }
            for ctx in &prog.outer {
                stalls += self.step(ctx, mem, contexts)?;
                contexts += 1;
            }
        }
        for ctx in &prog.epilogue {
            stalls += self.step(ctx, mem, contexts)?;
            contexts += 1;
        }
        let config_cycles = prog.config_words() as u64 * CONFIG_CYCLES_PER_WORD;
        Ok(CgraRun {
            compute_cycles: contexts + stalls,
            config_cycles,
            contexts,
            mem_stalls: stalls,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_one(pe: usize, ins: PeInstr) -> Context {
        let mut c = Context::nops();
        c.pe[pe] = ins;
        c
    }

    fn run_prologue(contexts: Vec<Context>, mem: &mut Vec<u32>) -> CgraRun {
        let mut core = CgraCore::new();
        let prog = CgraProgram::simple("t", contexts, vec![], 0, vec![]);
        core.execute(&prog, mem).unwrap()
    }

    #[test]
    fn alu_and_store() {
        let mut mem: Vec<u32> = vec![0; 16];
        run_prologue(
            vec![
                ctx_one(0, PeInstr::new(Op::Mov, 1, Src::Imm, Src::Zero, 21)),
                ctx_one(0, PeInstr::new(Op::Add, 2, Src::Reg(1), Src::Reg(1), 0)),
                ctx_one(0, PeInstr::new(Op::Store, 0, Src::Zero, Src::Reg(2), 0)),
            ],
            &mut mem,
        );
        assert_eq!(mem[0], 42);
    }

    #[test]
    fn q15_multiply_matches_ref_semantics() {
        let mut mem: Vec<u32> = vec![0; 4];
        run_prologue(
            vec![
                ctx_one(0, PeInstr::new(Op::Mov, 1, Src::Imm, Src::Zero, -30000)),
                ctx_one(0, PeInstr::new(Op::Mov, 2, Src::Imm, Src::Zero, 0x4000)),
                ctx_one(0, PeInstr::new(Op::MulQ15, 3, Src::Reg(1), Src::Reg(2), 0)),
                ctx_one(0, PeInstr::new(Op::Store, 0, Src::Zero, Src::Reg(3), 0)),
            ],
            &mut mem,
        );
        assert_eq!(mem[0] as i32, -15000);
    }

    #[test]
    fn routing_previous_cycle_value() {
        let mut mem: Vec<u32> = vec![0; 4];
        // PE0 produces 5; PE1 (east of PE0) reads West the next context.
        run_prologue(
            vec![
                ctx_one(0, PeInstr::new(Op::Mov, 0, Src::Imm, Src::Zero, 5)),
                ctx_one(1, PeInstr::new(Op::Mov, 0, Src::West, Src::Zero, 0)),
                ctx_one(1, PeInstr::new(Op::Store, 0, Src::Zero, Src::Reg(0), 0)),
            ],
            &mut mem,
        );
        assert_eq!(mem[0], 5);
    }

    #[test]
    fn torus_wraparound() {
        let mut mem: Vec<u32> = vec![0; 4];
        // PE0 (row 0) reading North wraps to row 3 (PE12).
        run_prologue(
            vec![
                ctx_one(12, PeInstr::new(Op::Mov, 0, Src::Imm, Src::Zero, 9)),
                ctx_one(0, PeInstr::new(Op::Mov, 0, Src::North, Src::Zero, 0)),
                ctx_one(0, PeInstr::new(Op::Store, 0, Src::Zero, Src::Reg(0), 0)),
            ],
            &mut mem,
        );
        assert_eq!(mem[0], 9);
    }

    #[test]
    fn load_with_offset_and_loadinc() {
        let mut mem: Vec<u32> = vec![10, 20, 30, 0];
        let mut core = CgraCore::new();
        let prog = CgraProgram::simple(
            "ldinc",
            vec![ctx_one(0, PeInstr::new(Op::Mov, 1, Src::Zero, Src::Zero, 0))],
            vec![
                ctx_one(0, PeInstr::new(Op::LoadInc, 2, Src::Reg(1), Src::Zero, 4)),
                ctx_one(0, PeInstr::new(Op::Add, 3, Src::Reg(3), Src::Reg(2), 0)),
            ],
            3,
            vec![ctx_one(0, PeInstr::new(Op::Store, 0, Src::Zero, Src::Reg(3), 12))],
        );
        core.execute(&prog, &mut mem).unwrap();
        assert_eq!(mem[3], 60);
        // Load with a=Imm base + b=Zero and offset via imm in a
        let mut mem2: Vec<u32> = vec![7, 8, 9, 0];
        run_prologue(
            vec![
                ctx_one(0, PeInstr::new(Op::Mov, 1, Src::Imm, Src::Zero, 4)),
                ctx_one(0, PeInstr::new(Op::Load, 2, Src::Reg(1), Src::Imm, 4)), // mem[4+4]=9
                ctx_one(0, PeInstr::new(Op::Store, 0, Src::Zero, Src::Reg(2), 12)),
            ],
            &mut mem2,
        );
        assert_eq!(mem2[3], 9);
    }

    #[test]
    fn mem_port_contention_stalls() {
        let mut mem: Vec<u32> = vec![0; 64];
        // 16 loads in one context over MEM_PORTS=2 -> ceil(16/2)-1 = 7 stalls.
        let ctx = Context::from_fn(|r, c| {
            PeInstr::new(Op::Load, 0, Src::Imm, Src::Zero, ((r * 4 + c) * 4) as i32)
        });
        let run = run_prologue(vec![ctx], &mut mem);
        assert_eq!(run.contexts, 1);
        assert_eq!(run.mem_stalls, 7);
        assert_eq!(run.compute_cycles, 8);
    }

    #[test]
    fn two_mem_ops_no_stall() {
        let mut mem: Vec<u32> = vec![0; 64];
        let mut ctx = Context::nops();
        ctx.pe[0] = PeInstr::new(Op::Load, 0, Src::Imm, Src::Zero, 0);
        ctx.pe[5] = PeInstr::new(Op::Load, 0, Src::Imm, Src::Zero, 4);
        let run = run_prologue(vec![ctx], &mut mem);
        assert_eq!(run.mem_stalls, 0);
    }

    #[test]
    fn two_level_loop_execution() {
        // acc += 1, body_iters=3, outer: store acc to slot[t] and bump ptr,
        // outer_iters=2 -> slots get 3 and 6.
        let mut mem: Vec<u32> = vec![0; 4];
        let mut core = CgraCore::new();
        let prog = CgraProgram {
            name: "2lvl".into(),
            prologue: vec![ctx_one(0, PeInstr::new(Op::Mov, 1, Src::Zero, Src::Zero, 0))],
            body: vec![ctx_one(0, PeInstr::new(Op::Add, 2, Src::Reg(2), Src::Imm, 1))],
            body_iterations: 3,
            outer: vec![ctx_one(0, PeInstr::new(Op::StoreInc, 0, Src::Reg(1), Src::Reg(2), 4))],
            outer_iterations: 2,
            epilogue: vec![],
        };
        core.execute(&prog, &mut mem).unwrap();
        assert_eq!(mem[0], 3);
        assert_eq!(mem[1], 6);
    }

    #[test]
    fn bad_address_faults() {
        let mut mem: Vec<u32> = vec![0; 1];
        let mut core = CgraCore::new();
        let prog = CgraProgram::simple(
            "bad",
            vec![ctx_one(3, PeInstr::new(Op::Load, 0, Src::Imm, Src::Zero, 0x1000))],
            vec![],
            0,
            vec![],
        );
        let f = core.execute(&prog, &mut mem).unwrap_err();
        assert_eq!(f.pe, 3);
        assert_eq!(f.addr, 0x1000);
    }

    #[test]
    fn row_col_sources() {
        let mut mem: Vec<u32> = vec![0; NUM_PES];
        // each PE stores row*4+col at its own slot
        let compute =
            Context::broadcast(PeInstr::new(Op::Mul, 1, Src::Row, Src::Imm, COLS as i32));
        let add = Context::broadcast(PeInstr::new(Op::Add, 1, Src::Reg(1), Src::Col, 0));
        let addr = Context::broadcast(PeInstr::new(Op::Mul, 2, Src::Reg(1), Src::Imm, 4));
        let store = Context::broadcast(PeInstr::new(Op::Store, 0, Src::Reg(2), Src::Reg(1), 0));
        let run = run_prologue(vec![compute, add, addr, store], &mut mem);
        for (i, v) in mem.iter().enumerate() {
            assert_eq!(*v as usize, i);
        }
        // store context: 16 stores over 2 ports -> 7 stalls
        assert_eq!(run.mem_stalls, 7);
    }
}
