//! The FEMU coordinator: platform assembly, the CS service loop, and the
//! paper's experiment drivers.
//!
//! [`Platform`] is one X-HEEP-FEMU instance: the emulated RH (SoC behind
//! a [`DebugSession`]) plus the CS services (ADC / flash / accelerator
//! virtualization) and the two energy calibrations. [`Platform::run_app`]
//! is the CS event loop: run the guest, answer service hand-offs, repeat
//! — the in-process equivalent of the PL/PS control flow.
//!
//! [`experiments`] implements §V: every figure/table has a driver that
//! benches and the CLI share (DESIGN.md §5 maps them). The drivers run on
//! an experiment [`Fleet`] — a worker pool that shards sweep points
//! across threads with serial-order, bit-identical aggregation
//! (DESIGN.md §8). The control server reuses the same pool machinery: a
//! [`WorkerPool`] of long-lived threads executes every session command
//! (DESIGN.md §9).

pub mod experiments;
pub mod fleet;
pub mod table1;

pub use fleet::{Fleet, WorkerPool};

use anyhow::{anyhow, Context, Result};

use crate::config::PlatformConfig;
use crate::cpu::Halt;
use crate::energy::{EnergyModel, EnergyReport};
use crate::perfmon::PerfSnapshot;
use crate::runtime::Runtime;
use crate::soc::{RunExit, Soc};
use crate::virt::{AccelService, AdcService, DebugSession};

/// Why [`Platform::run_app`] returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppExit {
    Halted(Halt),
    Budget,
}

/// One X-HEEP-FEMU platform instance.
pub struct Platform {
    pub dbg: DebugSession,
    pub cfg: PlatformConfig,
    pub adc: Option<AdcService>,
    pub accel: Option<AccelService>,
}

impl Platform {
    /// Build a platform from a config (no AOT artifacts — accelerator
    /// virtualization disabled until [`Platform::attach_artifacts`]).
    pub fn new(cfg: PlatformConfig) -> Self {
        let mut soc = Soc::new(cfg.soc.clone());
        soc.cpu.timing = cfg.timing;
        Self { dbg: DebugSession::new(soc), cfg, adc: None, accel: None }
    }

    /// Attach the AOT artifact runtime (enables accelerator
    /// virtualization / the mailbox path).
    pub fn attach_artifacts(&mut self, dir: impl AsRef<std::path::Path>) -> Result<()> {
        let rt = Runtime::load(dir).context("loading AOT artifacts (run `make artifacts`)")?;
        self.accel = Some(AccelService::new(rt));
        Ok(())
    }

    /// Attach an ADC dataset and start streaming at `sample_rate_hz`.
    pub fn start_adc(&mut self, dataset: Vec<i32>, sample_rate_hz: f64) {
        let mut adc = AdcService::new(dataset);
        adc.start(&mut self.dbg.soc, sample_rate_hz);
        self.adc = Some(adc);
    }

    /// The CS event loop: run the guest, servicing ADC refills and
    /// mailbox rings, until halt or budget exhaustion.
    pub fn run_app(&mut self, max_cycles: u64) -> Result<AppExit> {
        let deadline = self.dbg.soc.now.saturating_add(max_cycles);
        loop {
            let left = deadline.saturating_sub(self.dbg.soc.now);
            if left == 0 {
                return Ok(AppExit::Budget);
            }
            match self.dbg.run(left) {
                crate::virt::debugger::DebugStop::Halted(h) => return Ok(AppExit::Halted(h)),
                crate::virt::debugger::DebugStop::Budget => return Ok(AppExit::Budget),
                crate::virt::debugger::DebugStop::Breakpoint(pc) => {
                    return Err(anyhow!("unexpected breakpoint at {pc:#x} in run_app"))
                }
                crate::virt::debugger::DebugStop::Service(RunExit::AdcRefill) => {
                    let adc = self
                        .adc
                        .as_mut()
                        .ok_or_else(|| anyhow!("guest used the ADC but no dataset attached"))?;
                    adc.refill(&mut self.dbg.soc);
                }
                crate::virt::debugger::DebugStop::Service(RunExit::MailboxRing(off)) => {
                    let accel = self.accel.as_mut().ok_or_else(|| {
                        anyhow!("guest rang the mailbox but no artifacts attached")
                    })?;
                    accel.service(&mut self.dbg.soc, off)?;
                }
                crate::virt::debugger::DebugStop::Service(RunExit::DeadSleep) => {
                    return Err(anyhow!(
                        "guest dead-sleep at cycle {} (no wake source)",
                        self.dbg.soc.now
                    ))
                }
                crate::virt::debugger::DebugStop::Service(other) => {
                    return Err(anyhow!("unhandled service exit {other:?}"))
                }
            }
        }
    }

    /// Perf counters since reset (automatic mode).
    pub fn snapshot(&self) -> PerfSnapshot {
        self.dbg.soc.perf.snapshot(self.dbg.soc.now)
    }

    /// Estimate energy for a snapshot under a named calibration.
    pub fn estimate(&self, snap: &PerfSnapshot, model: &EnergyModel) -> EnergyReport {
        model.estimate(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::programs;

    #[test]
    fn run_app_plain_program() {
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg.load_source("_start: li a0, 3\nebreak").unwrap();
        assert_eq!(p.run_app(10_000).unwrap(), AppExit::Halted(Halt::Ebreak));
    }

    #[test]
    fn run_app_with_adc() {
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg.load_source(&programs::acquisition(600, 0)).unwrap();
        p.start_adc((0..600).collect(), 100_000.0);
        assert_eq!(p.run_app(10_000_000).unwrap(), AppExit::Halted(Halt::Ebreak));
        assert!(!p.dbg.soc.bus.spi_adc.underrun());
    }

    #[test]
    fn run_app_mailbox_without_artifacts_errors() {
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg
            .load_source(
                r#"
                .equ MBOX, 0x20000800
                _start:
                    li t0, MBOX
                    li t1, 1
                    sw t1, 0(t0)
                    ebreak
                "#,
            )
            .unwrap();
        assert!(p.run_app(10_000).is_err());
    }
}
