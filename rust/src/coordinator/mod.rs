//! The FEMU coordinator: platform assembly, the CS service loop, and the
//! paper's experiment drivers.
//!
//! [`Platform`] is one X-HEEP-FEMU instance: the emulated RH (SoC behind
//! a [`DebugSession`]) plus the CS services (ADC / flash / accelerator
//! virtualization) and the two energy calibrations. [`Platform::run_app`]
//! is the CS event loop: run the guest, answer service hand-offs, repeat
//! — the in-process equivalent of the PL/PS control flow.
//!
//! [`experiments`] implements §V: every figure/table has a driver that
//! benches and the CLI share (DESIGN.md §5 maps them). The drivers run on
//! an experiment [`Fleet`] — a worker pool that shards sweep points
//! across threads with serial-order, bit-identical aggregation
//! (DESIGN.md §8). The control server reuses the same pool machinery: a
//! [`WorkerPool`] of long-lived threads executes every session command
//! (DESIGN.md §9).

pub mod experiments;
pub mod fleet;
pub mod table1;

pub use fleet::{Fleet, WorkerPool};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::PlatformConfig;
use crate::cpu::Halt;
use crate::energy::{EnergyModel, EnergyReport};
use crate::perfmon::PerfSnapshot;
use crate::runtime::Runtime;
use crate::snapshot::{PlatformSnapshot, Reader, SnapshotInfo, Writer};
use crate::soc::{RunExit, Soc};
use crate::virt::{AccelService, AdcService, DebugSession};

/// Why [`Platform::run_app`] returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppExit {
    Halted(Halt),
    Budget,
}

/// One X-HEEP-FEMU platform instance.
pub struct Platform {
    pub dbg: DebugSession,
    pub cfg: PlatformConfig,
    pub adc: Option<AdcService>,
    pub accel: Option<AccelService>,
}

impl Platform {
    /// Build a platform from a config (no AOT artifacts — accelerator
    /// virtualization disabled until [`Platform::attach_artifacts`]).
    pub fn new(cfg: PlatformConfig) -> Self {
        let mut soc = Soc::new(cfg.soc.clone());
        soc.cpu.timing = cfg.timing;
        Self { dbg: DebugSession::new(soc), cfg, adc: None, accel: None }
    }

    /// Attach the AOT artifact runtime (enables accelerator
    /// virtualization / the mailbox path).
    pub fn attach_artifacts(&mut self, dir: impl AsRef<std::path::Path>) -> Result<()> {
        let rt = Runtime::load(dir).context("loading AOT artifacts (run `make artifacts`)")?;
        self.accel = Some(AccelService::new(rt));
        Ok(())
    }

    /// Attach an ADC dataset and start streaming at `sample_rate_hz`.
    pub fn start_adc(&mut self, dataset: Vec<i32>, sample_rate_hz: f64) {
        let mut adc = AdcService::new(dataset);
        adc.start(&mut self.dbg.soc, sample_rate_hz);
        self.adc = Some(adc);
    }

    /// The CS event loop: run the guest, servicing ADC refills and
    /// mailbox rings, until halt or budget exhaustion.
    pub fn run_app(&mut self, max_cycles: u64) -> Result<AppExit> {
        let deadline = self.dbg.soc.now.saturating_add(max_cycles);
        loop {
            let left = deadline.saturating_sub(self.dbg.soc.now);
            if left == 0 {
                return Ok(AppExit::Budget);
            }
            match self.dbg.run(left) {
                crate::virt::debugger::DebugStop::Halted(h) => return Ok(AppExit::Halted(h)),
                crate::virt::debugger::DebugStop::Budget => return Ok(AppExit::Budget),
                crate::virt::debugger::DebugStop::Breakpoint(pc) => {
                    return Err(anyhow!("unexpected breakpoint at {pc:#x} in run_app"))
                }
                crate::virt::debugger::DebugStop::Service(RunExit::AdcRefill) => {
                    let adc = self
                        .adc
                        .as_mut()
                        .ok_or_else(|| anyhow!("guest used the ADC but no dataset attached"))?;
                    adc.refill(&mut self.dbg.soc);
                }
                crate::virt::debugger::DebugStop::Service(RunExit::MailboxRing(off)) => {
                    let accel = self.accel.as_mut().ok_or_else(|| {
                        anyhow!("guest rang the mailbox but no artifacts attached")
                    })?;
                    accel.service(&mut self.dbg.soc, off)?;
                }
                crate::virt::debugger::DebugStop::Service(RunExit::DeadSleep) => {
                    return Err(anyhow!(
                        "guest dead-sleep at cycle {} (no wake source)",
                        self.dbg.soc.now
                    ))
                }
                crate::virt::debugger::DebugStop::Service(other) => {
                    return Err(anyhow!("unhandled service exit {other:?}"))
                }
            }
        }
    }

    /// Perf counters since reset (automatic mode).
    pub fn perf_snapshot(&self) -> PerfSnapshot {
        self.dbg.soc.perf.snapshot(self.dbg.soc.now)
    }

    /// The manual perf window (GPIO-toggled by the guest), if one was
    /// closed. Counterpart of [`Platform::perf_snapshot`] so callers
    /// stop reaching through `dbg.soc.perf` for one mode and not the
    /// other.
    pub fn perf_window_snapshot(&self) -> Option<&PerfSnapshot> {
        self.dbg.soc.perf.window_snapshot()
    }

    /// Estimate energy for a snapshot under a named calibration.
    pub fn estimate(&self, snap: &PerfSnapshot, model: &EnergyModel) -> EnergyReport {
        model.estimate(snap)
    }

    // ---- snapshot / restore / fork (DESIGN.md §10) ----------------------

    /// Serialize the full platform state into a versioned, checksummed
    /// [`PlatformSnapshot`]: SoC (CPU, interconnect, every peripheral,
    /// CGRA, perf counters), debug-session state, and the CS ADC service.
    /// The PJRT accelerator runtime is **not** captured (process-local
    /// handles); a restored platform keeps its current artifact binding.
    pub fn snapshot(&self) -> PlatformSnapshot {
        let mut w = Writer::new();
        SnapshotInfo {
            name: self.cfg.name.clone(),
            freq_hz: self.cfg.soc.freq_hz,
            num_banks: self.cfg.soc.num_banks as u32,
            bank_size: self.cfg.soc.bank_size,
            cs_dram_size: self.cfg.soc.cs_dram_size as u64,
            flash_size: self.cfg.soc.flash_size as u64,
            cycles: self.dbg.soc.now,
        }
        .write(&mut w);
        self.dbg.save_state(&mut w);
        match &self.adc {
            None => w.bool(false),
            Some(adc) => {
                w.bool(true);
                adc.save_state(&mut w);
            }
        }
        PlatformSnapshot::from_payload(w.into_payload())
    }

    /// Reset this platform to `snap`. The snapshot's platform shape
    /// (bank count/size, CS-DRAM/flash sizes, clock) must match this
    /// platform's config — validated before any state is touched. This
    /// is the restore-per-point hot path of forked sweeps, so it decodes
    /// straight into the live state (pristine large memories are
    /// skipped, not memset): if a frame-valid payload fails *mid*-decode
    /// (possible only for hand-corrupted images that beat the checksum,
    /// or cross-build format drift), the platform is left partially
    /// restored and the caller must discard it. Untrusted images should
    /// go through [`Platform::restore_transactional`].
    pub fn restore(&mut self, snap: &PlatformSnapshot) -> Result<()> {
        let mut r = Reader::new(snap.payload());
        let info = SnapshotInfo::read(&mut r)?;
        let soc = &self.cfg.soc;
        if info.num_banks != soc.num_banks as u32
            || info.bank_size != soc.bank_size
            || info.cs_dram_size != soc.cs_dram_size as u64
            || info.flash_size != soc.flash_size as u64
            || info.freq_hz != soc.freq_hz
        {
            return Err(crate::snapshot::snap_err(
                crate::snapshot::SnapErrorKind::ShapeMismatch,
                format!(
                    "snapshot shape mismatch: snapshot `{}` has {} banks x {:#x} B, \
                 {} B CS DRAM, {} B flash at {} Hz; platform `{}` differs",
                    info.name,
                    info.num_banks,
                    info.bank_size,
                    info.cs_dram_size,
                    info.flash_size,
                    info.freq_hz,
                    self.cfg.name,
                ),
            ));
        }
        self.dbg.restore_state(&mut r)?;
        self.adc = if r.bool()? { Some(AdcService::from_state(&mut r)?) } else { None };
        r.finish()
    }

    /// [`Platform::restore`] with all-or-nothing semantics for untrusted
    /// images (the server's `snapshot.restore`): the image is decoded
    /// into a scratch platform first, and this platform is only replaced
    /// on full success — a mid-decode failure leaves it untouched. The
    /// attached accelerator runtime (not part of snapshots) survives.
    pub fn restore_transactional(&mut self, snap: &PlatformSnapshot) -> Result<()> {
        let mut fresh = Platform::new(self.cfg.clone());
        fresh.restore(snap)?;
        fresh.accel = self.accel.take();
        *self = fresh;
        Ok(())
    }

    /// Clone this platform through a snapshot: a new instance with
    /// identical state that diverges independently from here on. (The
    /// accelerator runtime, if any, is not carried over — attach
    /// artifacts on the fork if it needs the mailbox path.)
    pub fn fork(&self) -> Result<Platform> {
        let snap = self.snapshot();
        let mut p = Platform::new(self.cfg.clone());
        p.restore(&snap).context("restoring fork from snapshot")?;
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::programs;

    #[test]
    fn run_app_plain_program() {
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg.load_source("_start: li a0, 3\nebreak").unwrap();
        assert_eq!(p.run_app(10_000).unwrap(), AppExit::Halted(Halt::Ebreak));
    }

    #[test]
    fn run_app_with_adc() {
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg.load_source(&programs::acquisition(600, 0)).unwrap();
        p.start_adc((0..600).collect(), 100_000.0);
        assert_eq!(p.run_app(10_000_000).unwrap(), AppExit::Halted(Halt::Ebreak));
        assert!(!p.dbg.soc.bus.spi_adc.underrun());
    }

    #[test]
    fn run_app_mailbox_without_artifacts_errors() {
        let mut p = Platform::new(PlatformConfig::default());
        p.dbg
            .load_source(
                r#"
                .equ MBOX, 0x20000800
                _start:
                    li t0, MBOX
                    li t1, 1
                    sw t1, 0(t0)
                    ebreak
                "#,
            )
            .unwrap();
        assert!(p.run_app(10_000).is_err());
    }
}
