//! Table I — comparison of FPGA-based platforms across the five key
//! features. The survey data is encoded here and the table is rendered
//! programmatically (`femu table1`, `benches/table1.rs`), including the
//! paper's filtering argument (§II): features are applied in descending
//! frequency order and the platform set narrows until only FEMU remains.

/// The five feature dimensions of Table I.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Feature {
    HsBasedRh,
    OsBasedCs,
    IpVirtualization,
    PerformanceEstimation,
    EnergyEstimation,
}

impl Feature {
    pub const ALL: [Feature; 5] = [
        Feature::HsBasedRh,
        Feature::OsBasedCs,
        Feature::IpVirtualization,
        Feature::PerformanceEstimation,
        Feature::EnergyEstimation,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Feature::HsBasedRh => "HS-based RH",
            Feature::OsBasedCs => "OS-based CS",
            Feature::IpVirtualization => "IP Virtualization",
            Feature::PerformanceEstimation => "Performance Estimation",
            Feature::EnergyEstimation => "Energy Estimation",
        }
    }
}

/// One surveyed platform row.
#[derive(Clone, Copy, Debug)]
pub struct PlatformRow {
    pub name: &'static str,
    pub reference: &'static str,
    /// Feature support in [`Feature::ALL`] order.
    pub features: [bool; 5],
}

impl PlatformRow {
    pub fn supports(&self, f: Feature) -> bool {
        self.features[Feature::ALL.iter().position(|&x| x == f).unwrap()]
    }
}

/// The Table I survey data, exactly as published.
pub const TABLE1: [PlatformRow; 14] = [
    PlatformRow { name: "LiME", reference: "[13]", features: [false, false, false, true, false] },
    PlatformRow { name: "Hybrid", reference: "[14]", features: [false, true, true, true, false] },
    PlatformRow { name: "FAME", reference: "[15]", features: [false, true, false, true, false] },
    PlatformRow {
        name: "Extrapolator",
        reference: "[16]",
        features: [false, true, false, true, false],
    },
    PlatformRow { name: "ULPemu", reference: "[17]", features: [true, false, false, true, true] },
    PlatformRow { name: "ACE", reference: "[18]", features: [false, true, false, true, false] },
    PlatformRow {
        name: "SnifferSoC",
        reference: "[19]",
        features: [false, false, false, true, true],
    },
    PlatformRow {
        name: "ThermalMPSoC",
        reference: "[20]",
        features: [false, false, false, true, true],
    },
    PlatformRow { name: "HLL", reference: "[21]", features: [false, false, false, true, false] },
    PlatformRow { name: "HERO", reference: "[22]", features: [true, true, true, true, false] },
    PlatformRow { name: "Plug", reference: "[23]", features: [true, false, true, true, false] },
    PlatformRow {
        name: "SoftPower",
        reference: "[24]",
        features: [true, false, false, true, true],
    },
    PlatformRow { name: "DAQ", reference: "[25]", features: [true, false, false, false, false] },
    PlatformRow {
        name: "FEMU (this work)",
        reference: "",
        features: [true, true, true, true, true],
    },
];

/// Render the table as Markdown (the regenerated artifact).
pub fn render_markdown() -> String {
    let mut s = String::new();
    s.push_str("| FPGA Platforms |");
    for f in Feature::ALL {
        s.push_str(&format!(" {} |", f.name()));
    }
    s.push('\n');
    s.push_str("|---|---|---|---|---|---|\n");
    for row in TABLE1 {
        s.push_str(&format!("| {} {} |", row.name, row.reference));
        for f in Feature::ALL {
            s.push_str(if row.supports(f) { " yes |" } else { " - |" });
        }
        s.push('\n');
    }
    s
}

/// The §II filtering argument: apply features in descending support
/// frequency; return (feature, surviving platforms) per step.
pub fn filtering_steps() -> Vec<(Feature, Vec<&'static str>)> {
    // order features by how many surveyed platforms (excluding FEMU)
    // support them, descending — the paper's narrative order
    let mut order: Vec<Feature> = Feature::ALL.to_vec();
    let count = |f: Feature| {
        TABLE1.iter().take(TABLE1.len() - 1).filter(|r| r.supports(f)).count()
    };
    order.sort_by_key(|&f| std::cmp::Reverse(count(f)));

    let mut surviving: Vec<&PlatformRow> = TABLE1.iter().collect();
    let mut steps = Vec::new();
    for f in order {
        surviving.retain(|r| r.supports(f));
        steps.push((f, surviving.iter().map(|r| r.name).collect()));
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn only_femu_supports_all_five() {
        let full: Vec<_> =
            TABLE1.iter().filter(|r| Feature::ALL.iter().all(|&f| r.supports(f))).collect();
        assert_eq!(full.len(), 1);
        assert_eq!(full[0].name, "FEMU (this work)");
    }

    #[test]
    fn filtering_narrows_to_femu() {
        let steps = filtering_steps();
        assert_eq!(steps.len(), 5);
        // first filter is performance estimation (most common, 13/13
        // minus DAQ)
        assert_eq!(steps[0].0, Feature::PerformanceEstimation);
        assert!(!steps[0].1.contains(&"DAQ"));
        // final set: FEMU alone
        assert_eq!(steps.last().unwrap().1, vec!["FEMU (this work)"]);
    }

    #[test]
    fn paper_row_spot_checks() {
        let hero = TABLE1.iter().find(|r| r.name == "HERO").unwrap();
        assert!(hero.supports(Feature::HsBasedRh));
        assert!(hero.supports(Feature::OsBasedCs));
        assert!(!hero.supports(Feature::EnergyEstimation));
        let ulp = TABLE1.iter().find(|r| r.name == "ULPemu").unwrap();
        assert!(ulp.supports(Feature::EnergyEstimation));
        assert!(!ulp.supports(Feature::OsBasedCs));
    }

    #[test]
    fn markdown_renders_all_rows() {
        let md = render_markdown();
        assert_eq!(md.lines().count(), 2 + 14);
        assert!(md.contains("FEMU (this work)"));
    }
}
