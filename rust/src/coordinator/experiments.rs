//! Experiment drivers for every table and figure in the paper's §V
//! evaluation (DESIGN.md §5 maps each to its bench target).
//!
//! * [`fig4_sweep`] — §V-A signal-acquisition characterization,
//! * [`fig5_all`] — §V-B TinyAI kernels (CPU vs CGRA, FEMU vs chip),
//! * [`case_c`] — §V-C flash-virtualization transfer study,
//! * Table I lives in [`super::table1`].
//!
//! The sweep drivers take a [`Fleet`] and shard their points across it;
//! pass [`Fleet::serial()`] for the single-threaded reference path. Both
//! paths are bit-identical by construction (per-point seeds come from
//! [`super::fleet::point_seed`], aggregation preserves point order).
//!
//! Fan-out strategy: the default drivers run on
//! [`Fleet::run_sweep_forked`] — one golden platform is booted (and, for
//! Case C, warmed with the staged flash dataset + loaded guest) per
//! sweep, snapshotted, and restored per point, so repeated boot/warmup
//! work is paid once. The `*_boot` variants keep the boot-per-point
//! reference path alive; `tests/fleet_determinism.rs` proves both paths
//! bit-identical and `benches/fig4_acquisition.rs` reports the
//! wall-clock win.

use anyhow::{anyhow, bail, Result};

use super::fleet::Fleet;
use crate::config::PlatformConfig;
use crate::energy::EnergyModel;
use crate::isa::assemble;
use crate::periph::FlashTiming;
use crate::perfmon::PowerState;
use crate::snapshot::PlatformSnapshot;
use crate::virt::FlashService;
use crate::workloads::{programs, reference as refimpl, signals};

use super::{AppExit, Platform};

// =====================================================================
// Fig 4 — signal acquisition characterization
// =====================================================================

/// The sampling frequencies of Fig 4.
pub const FIG4_FREQS_HZ: [f64; 6] = [100.0, 500.0, 1_000.0, 5_000.0, 10_000.0, 100_000.0];

/// One bar group of Fig 4 under one calibration.
#[derive(Clone, Debug)]
pub struct Fig4Point {
    pub sample_rate_hz: f64,
    pub model: String,
    /// Wall-clock of the acquisition window (s).
    pub total_s: f64,
    /// Time with the CPU domain active / asleep (s).
    pub active_s: f64,
    pub sleep_s: f64,
    /// Energy split (mJ).
    pub active_mj: f64,
    pub sleep_mj: f64,
    pub total_mj: f64,
}

/// Run the §V-A acquisition kernel for `window_s` seconds at
/// `sample_rate_hz`, under both energy calibrations (FEMU + chip), on a
/// platform freshly booted (or freshly restored to the golden image).
pub fn fig4_point_on(
    p: &mut Platform,
    sample_rate_hz: f64,
    window_s: f64,
    seed: u64,
) -> Result<Vec<Fig4Point>> {
    let n_samples = (sample_rate_hz * window_s).round() as u64;
    if n_samples == 0 {
        bail!("window too short for {sample_rate_hz} Hz");
    }
    // retention sleep for memories — the ULP acquisition configuration
    p.dbg.load_source(&programs::acquisition(n_samples, 2))?;
    let sig = signals::biosignal(seed, n_samples as usize, sample_rate_hz);
    p.start_adc(sig.samples, sample_rate_hz);
    let budget = (p.cfg.soc.freq_hz as f64 * window_s * 3.0) as u64 + 10_000_000;
    match p.run_app(budget)? {
        AppExit::Halted(_) => {}
        AppExit::Budget => bail!("acquisition did not finish within budget"),
    }
    if p.dbg.soc.bus.spi_adc.underrun() {
        bail!("ADC underrun during fig4 acquisition");
    }
    let snap = p.perf_snapshot();
    let freq = p.cfg.soc.freq_hz as f64;
    let active_cycles = snap.cpu.get(PowerState::Active);
    let sleep_cycles = snap.cycles - active_cycles;
    let mut out = Vec::new();
    for model in [EnergyModel::femu(), EnergyModel::heepocrates()] {
        let report = model.estimate(&snap);
        out.push(Fig4Point {
            sample_rate_hz,
            model: model.name.clone(),
            total_s: snap.cycles as f64 / freq,
            active_s: active_cycles as f64 / freq,
            sleep_s: sleep_cycles as f64 / freq,
            active_mj: report.active_mj,
            sleep_mj: report.sleep_mj,
            total_mj: report.total_mj,
        });
    }
    Ok(out)
}

/// Boot-per-point convenience wrapper around [`fig4_point_on`].
pub fn fig4_point(
    cfg: &PlatformConfig,
    sample_rate_hz: f64,
    window_s: f64,
    seed: u64,
) -> Result<Vec<Fig4Point>> {
    let mut p = Platform::new(cfg.clone());
    fig4_point_on(&mut p, sample_rate_hz, window_s, seed)
}

/// The full Fig 4 sweep, sharded across `fleet` with fork-based fan-out
/// (golden boot snapshot, restore per point). `window_s` defaults to
/// the paper's 5 s via [`fig4_sweep_default`]; benches shrink it to keep
/// runtimes sane (the active/sleep *fractions* are window-invariant).
pub fn fig4_sweep(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    window_s: f64,
    seed: u64,
) -> Result<Vec<Fig4Point>> {
    fig4_sweep_with_abort(fleet, cfg, window_s, seed, &|| false)
}

/// [`fig4_sweep`] with a cancellation hook polled before each point —
/// the control server uses it so shutdown aborts an in-flight sweep.
pub fn fig4_sweep_with_abort(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    window_s: f64,
    seed: u64,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<Vec<Fig4Point>> {
    fig4_sweep_from(fleet, cfg, window_s, seed, None, cancelled)
}

/// [`fig4_sweep`] with an explicit golden snapshot (`femu
/// sweep-acquisition --from-snapshot`): the sweep's per-point platforms
/// restore from `golden` instead of a fresh boot, so results are
/// relative to that warmed state.
pub fn fig4_sweep_from(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    window_s: f64,
    seed: u64,
    golden: Option<&PlatformSnapshot>,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<Vec<Fig4Point>> {
    fleet.run_sweep_forked(
        cfg,
        seed,
        FIG4_FREQS_HZ.to_vec(),
        golden,
        &|_p| Ok(()),
        |p, f, point_seed| {
            if cancelled() {
                bail!("experiment aborted");
            }
            fig4_point_on(p, f, window_s, point_seed)
        },
    )
}

/// Boot-per-point reference path (every point builds its own platform).
/// Kept for the determinism proof and the boot-vs-restore bench; results
/// are bit-identical to [`fig4_sweep`].
pub fn fig4_sweep_boot(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    window_s: f64,
    seed: u64,
) -> Result<Vec<Fig4Point>> {
    fleet.run_sweep(cfg, seed, FIG4_FREQS_HZ.to_vec(), |cfg, f, point_seed| {
        fig4_point(cfg, f, window_s, point_seed)
    })
}

pub fn fig4_sweep_default(fleet: &Fleet, cfg: &PlatformConfig) -> Result<Vec<Fig4Point>> {
    fig4_sweep(fleet, cfg, 5.0, 0xF16_4)
}

// =====================================================================
// Fig 5 — TinyAI kernels: CPU vs CGRA, FEMU vs chip
// =====================================================================

/// The three §V-B kernels at the paper's shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig5Kernel {
    /// 121x16 @ 16x4 INT32.
    Mm,
    /// 16x16x3 input, 8 3x3 filters, INT32.
    Conv,
    /// 512-point FxP32 (Q15).
    Fft,
}

impl Fig5Kernel {
    pub const ALL: [Fig5Kernel; 3] = [Fig5Kernel::Mm, Fig5Kernel::Conv, Fig5Kernel::Fft];

    pub fn name(self) -> &'static str {
        match self {
            Fig5Kernel::Mm => "MM",
            Fig5Kernel::Conv => "CONV",
            Fig5Kernel::Fft => "FFT",
        }
    }
}

/// Execution stage (the paper's two configurations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig5Impl {
    Cpu,
    Cgra,
}

impl Fig5Impl {
    pub fn name(self) -> &'static str {
        match self {
            Fig5Impl::Cpu => "CPU",
            Fig5Impl::Cgra => "CGRA",
        }
    }
}

/// One bar of Fig 5 under one calibration.
#[derive(Clone, Debug)]
pub struct Fig5Point {
    pub kernel: &'static str,
    pub implementation: &'static str,
    pub model: String,
    pub cycles: u64,
    pub time_s: f64,
    pub energy_mj: f64,
    /// Output checked bit-exact against the shared oracle.
    pub validated: bool,
}

/// Run one (kernel, impl) cell on a freshly booted/restored platform;
/// returns one point per calibration.
pub fn fig5_run_on(
    p: &mut Platform,
    kernel: Fig5Kernel,
    imp: Fig5Impl,
    seed: u64,
) -> Result<Vec<Fig5Point>> {
    let soc_freq = p.cfg.soc.freq_hz as f64;

    // assemble + load the guest
    let src = match (kernel, imp) {
        (Fig5Kernel::Mm, Fig5Impl::Cpu) => programs::mm_cpu(121, 16, 4),
        (Fig5Kernel::Mm, Fig5Impl::Cgra) => programs::mm_cgra(121, 16, 4),
        (Fig5Kernel::Conv, Fig5Impl::Cpu) => programs::conv_cpu(16, 16, 3, 8, 3, 3),
        (Fig5Kernel::Conv, Fig5Impl::Cgra) => programs::conv_cgra(16, 16, 3, 8, 3, 3),
        (Fig5Kernel::Fft, Fig5Impl::Cpu) => programs::fft_cpu(512),
        (Fig5Kernel::Fft, Fig5Impl::Cgra) => programs::fft_cgra(512),
    };
    let prog = p.dbg.load_source(&src)?;

    // stage operands + compute expected outputs
    let mut rng = crate::util::Rng::new(seed);
    let validated: bool;
    match kernel {
        Fig5Kernel::Mm => {
            let (m, k, n) = (121, 16, 4);
            let a = rng.vec_i32(m * k, -4096, 4096);
            let b = rng.vec_i32(k * n, -4096, 4096);
            p.dbg.write_i32_slice(prog.symbol("a_buf")?, &a)?;
            p.dbg.write_i32_slice(prog.symbol("b_buf")?, &b)?;
            run_to_halt(p)?;
            let got = p.dbg.read_i32_slice(prog.symbol("c_buf")?, m * n)?;
            validated = got == refimpl::matmul_i32(&a, &b, m, k, n);
        }
        Fig5Kernel::Conv => {
            let (h, w, cin, f, kh, kw) = (16, 16, 3, 8, 3, 3);
            let x = rng.vec_i32(h * w * cin, -2048, 2048);
            let wts = rng.vec_i32(f * kh * kw * cin, -2048, 2048);
            p.dbg.write_i32_slice(prog.symbol("x_buf")?, &x)?;
            p.dbg.write_i32_slice(prog.symbol("w_buf")?, &wts)?;
            run_to_halt(p)?;
            let oh = h - kh + 1;
            let ow = w - kw + 1;
            let got = p.dbg.read_i32_slice(prog.symbol("y_buf")?, oh * ow * f)?;
            validated = got == refimpl::conv2d_i32(&x, &wts, h, w, cin, f, kh, kw);
        }
        Fig5Kernel::Fft => {
            let n = 512;
            let re = rng.vec_i32(n, -(1 << 15), 1 << 15);
            let im = rng.vec_i32(n, -(1 << 15), 1 << 15);
            let (wr, wi) = refimpl::twiddles_q15(n);
            let rev: Vec<i32> =
                refimpl::bit_reverse_indices(n).iter().map(|&x| x as i32).collect();
            p.dbg.write_i32_slice(prog.symbol("re_buf")?, &re)?;
            p.dbg.write_i32_slice(prog.symbol("im_buf")?, &im)?;
            p.dbg.write_i32_slice(prog.symbol("rev_tbl")?, &rev)?;
            p.dbg.write_i32_slice(prog.symbol("wr_tbl")?, &wr)?;
            p.dbg.write_i32_slice(prog.symbol("wi_tbl")?, &wi)?;
            run_to_halt(p)?;
            let got_re = p.dbg.read_i32_slice(prog.symbol("re_buf")?, n)?;
            let got_im = p.dbg.read_i32_slice(prog.symbol("im_buf")?, n)?;
            let mut want_re = re.clone();
            let mut want_im = im.clone();
            refimpl::fft_q15(&mut want_re, &mut want_im);
            validated = got_re == want_re && got_im == want_im;
        }
    }

    // perf window (manual mode) covers exactly the compute region
    let window = p
        .perf_window_snapshot()
        .ok_or_else(|| anyhow!("kernel did not toggle the perf GPIO"))?
        .clone();
    let mut out = Vec::new();
    for model in [EnergyModel::femu(), EnergyModel::heepocrates()] {
        let report = model.estimate(&window);
        out.push(Fig5Point {
            kernel: kernel.name(),
            implementation: imp.name(),
            model: model.name.clone(),
            cycles: window.cycles,
            time_s: window.cycles as f64 / soc_freq,
            energy_mj: report.total_mj,
            validated,
        });
    }
    Ok(out)
}

/// Boot-per-point convenience wrapper around [`fig5_run_on`].
pub fn fig5_run(
    cfg: &PlatformConfig,
    kernel: Fig5Kernel,
    imp: Fig5Impl,
    seed: u64,
) -> Result<Vec<Fig5Point>> {
    let mut p = Platform::new(cfg.clone());
    fig5_run_on(&mut p, kernel, imp, seed)
}

fn run_to_halt(p: &mut Platform) -> Result<()> {
    match p.run_app(2_000_000_000)? {
        AppExit::Halted(_) => Ok(()),
        AppExit::Budget => bail!("kernel did not halt within budget"),
    }
}

/// Every (kernel, implementation) cell of the Fig 5 grid, in the grid's
/// serial order (kernels outer, CPU before CGRA).
pub fn fig5_cells() -> Vec<(Fig5Kernel, Fig5Impl)> {
    Fig5Kernel::ALL
        .iter()
        .flat_map(|&k| [(k, Fig5Impl::Cpu), (k, Fig5Impl::Cgra)])
        .collect()
}

/// The full Fig 5 grid: 3 kernels x {CPU, CGRA} x {femu, chip}, one
/// fleet point per (kernel, impl) cell, with fork-based fan-out.
pub fn fig5_all(fleet: &Fleet, cfg: &PlatformConfig, seed: u64) -> Result<Vec<Fig5Point>> {
    fig5_all_with_abort(fleet, cfg, seed, &|| false)
}

/// [`fig5_all`] with a cancellation hook polled before each cell.
pub fn fig5_all_with_abort(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    seed: u64,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<Vec<Fig5Point>> {
    fig5_all_from(fleet, cfg, seed, None, cancelled)
}

/// [`fig5_all`] with an explicit golden snapshot (`femu kernels
/// --from-snapshot`).
pub fn fig5_all_from(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    seed: u64,
    golden: Option<&PlatformSnapshot>,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<Vec<Fig5Point>> {
    fleet.run_sweep_forked(
        cfg,
        seed,
        fig5_cells(),
        golden,
        &|_p| Ok(()),
        |p, (kernel, imp), point_seed| {
            if cancelled() {
                bail!("experiment aborted");
            }
            fig5_run_on(p, kernel, imp, point_seed)
        },
    )
}

/// Boot-per-point reference path; bit-identical to [`fig5_all`].
pub fn fig5_all_boot(fleet: &Fleet, cfg: &PlatformConfig, seed: u64) -> Result<Vec<Fig5Point>> {
    fleet.run_sweep(cfg, seed, fig5_cells(), |cfg, (kernel, imp), point_seed| {
        fig5_run(cfg, kernel, imp, point_seed)
    })
}

// =====================================================================
// Case C — §V-C flash virtualization transfer study
// =====================================================================

/// Result of the §V-C study.
#[derive(Clone, Debug)]
pub struct CaseCResult {
    pub windows: usize,
    pub samples_per_window: usize,
    /// Per-window transfer time (s).
    pub virt_window_s: f64,
    pub phys_window_s: f64,
    /// Full-experiment transfer time (all windows).
    pub virt_total_s: f64,
    pub phys_total_s: f64,
    pub speedup: f64,
}

/// Guest program: stream `windows x words` from flash, discarding data
/// (transfer characterization, like the paper's measurement).
fn flash_reader(windows: usize, words: usize) -> String {
    format!(
        r#"{prelude}
.equ WINDOWS, {windows}
.equ WORDS, {words}
.equ WBYTES, {wbytes}
_start:
    li  s0, SPI_FLASH
    li  s1, WINDOWS
    li  s5, 0            # window base addr
outer:
    sw  s5, 8(s0)        # ADDR
    li  s3, WORDS
inner:
    lw  t0, 12(s0)       # DATA
    addi s3, s3, -1
    bnez s3, inner
    li  t1, WBYTES
    add s5, s5, t1
    addi s1, s1, -1
    bnez s1, outer
    ebreak
"#,
        prelude = programs::PRELUDE,
        wbytes = words * 4,
    )
}

/// Run the transfer study with one flash timing; returns (cycles_total,
/// cycles_per_window).
fn case_c_one(cfg: &PlatformConfig, timing: FlashTiming, windows: usize, words: usize, seed: u64) -> Result<u64> {
    let mut cfg = cfg.clone();
    cfg.soc.flash_timing = timing;
    cfg.soc.flash_size = (windows * words * 4).next_power_of_two().max(1 << 20);
    let mut p = Platform::new(cfg);
    // stage real windows, packed two 16-bit samples per word (the §V-C
    // image layout; content irrelevant for timing, staged for fidelity)
    let data = signals::ultrasound_windows(seed, windows, words * 2);
    let mut off = 0usize;
    for w in &data {
        FlashService::stage_bytes(&mut p.dbg.soc, off, &signals::pack_i16_pairs(w));
        off += w.len() * 2;
    }
    let prog = assemble(&flash_reader(windows, words))?;
    p.dbg.load_program(&prog)?;
    let start = p.dbg.soc.now;
    match p.run_app(1u64 << 40)? {
        AppExit::Halted(_) => Ok(p.dbg.soc.now - start),
        AppExit::Budget => bail!("flash reader did not halt"),
    }
}

/// §V-C: 240 windows of 35 000 16-bit samples (packed two per word =
/// 70 KiB/window), virtualized vs physical flash. `scale` shrinks the
/// workload for quick runs (1 = paper size). The two timing variants are
/// independent platforms, so they run as two fleet points (both stage the
/// same 0xCC dataset: the §V-C content is timing-irrelevant and keeping
/// it fixed preserves the seed repo's exact staging).
pub fn case_c(fleet: &Fleet, cfg: &PlatformConfig, scale: usize) -> Result<CaseCResult> {
    case_c_with_abort(fleet, cfg, scale, &|| false)
}

/// [`case_c`] with a cancellation hook polled before each timing point —
/// the control server uses it so shutdown aborts an in-flight study.
pub fn case_c_with_abort(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    scale: usize,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<CaseCResult> {
    case_c_from(fleet, cfg, scale, None, cancelled)
}

/// The sizes a `scale` factor resolves to.
fn case_c_shape(scale: usize) -> (usize, usize, usize) {
    let windows = (240 / scale.max(1)).max(2);
    let samples = (35_000 / scale.max(1)).max(200);
    (windows, samples, samples / 2)
}

/// Golden-platform warmup shared by both timing points: stage the
/// dataset into flash and load the reader guest. Under fork-based
/// fan-out this (signal generation + a multi-MiB staging pass +
/// assembly) is paid once per study instead of once per point.
fn case_c_warmup(p: &mut Platform, windows: usize, words: usize, seed: u64) -> Result<()> {
    let data = signals::ultrasound_windows(seed, windows, words * 2);
    let mut off = 0usize;
    for w in &data {
        FlashService::stage_bytes(&mut p.dbg.soc, off, &signals::pack_i16_pairs(w));
        off += w.len() * 2;
    }
    let prog = assemble(&flash_reader(windows, words))?;
    p.dbg.load_program(&prog)?;
    Ok(())
}

fn case_c_result(cfg: &PlatformConfig, windows: usize, samples: usize, cycles: &[u64]) -> CaseCResult {
    let (virt_cycles, phys_cycles) = (cycles[0], cycles[1]);
    let f = cfg.soc.freq_hz as f64;
    let virt_total_s = virt_cycles as f64 / f;
    let phys_total_s = phys_cycles as f64 / f;
    CaseCResult {
        windows,
        samples_per_window: samples,
        virt_window_s: virt_total_s / windows as f64,
        phys_window_s: phys_total_s / windows as f64,
        virt_total_s,
        phys_total_s,
        speedup: phys_total_s / virt_total_s,
    }
}

/// [`case_c`] with an explicit golden snapshot: the study then measures
/// *that snapshot's* loaded guest + staged flash under the two flash
/// timings. The flash size is adopted from the snapshot; every other
/// shape field (banks, CS DRAM, clock) must still match `cfg`, and the
/// returned `windows`/`samples_per_window` (and the per-window figures
/// derived from them) describe the standard §V-C layout, **not** the
/// snapshot's workload — only the totals and speedup are meaningful
/// then. `None` boots and warms the standard §V-C golden platform here.
pub fn case_c_from(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    scale: usize,
    golden: Option<&PlatformSnapshot>,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<CaseCResult> {
    let (windows, samples, words) = case_c_shape(scale);
    let mut golden_cfg = cfg.clone();
    golden_cfg.soc.flash_size = match golden {
        Some(snap) => snap.info()?.flash_size as usize,
        None => (windows * words * 4).next_power_of_two().max(1 << 20),
    };
    let timings = vec![FlashTiming::virtualized(), FlashTiming::physical()];
    let cycles = fleet.run_sweep_forked(
        &golden_cfg,
        0xCC,
        timings,
        golden,
        &|p| case_c_warmup(p, windows, words, 0xCC),
        |p, timing, _point_seed| {
            if cancelled() {
                bail!("experiment aborted");
            }
            // the timing model is the sweep variable; everything else is
            // the restored golden image
            p.dbg.soc.bus.spi_flash.set_timing(timing);
            let start = p.dbg.soc.now;
            match p.run_app(1u64 << 40)? {
                AppExit::Halted(_) => Ok(vec![p.dbg.soc.now - start]),
                AppExit::Budget => bail!("flash reader did not halt"),
            }
        },
    )?;
    Ok(case_c_result(cfg, windows, samples, &cycles))
}

/// Boot-per-point reference path; bit-identical to [`case_c`].
pub fn case_c_boot(fleet: &Fleet, cfg: &PlatformConfig, scale: usize) -> Result<CaseCResult> {
    let (windows, samples, words) = case_c_shape(scale);
    let timings = vec![FlashTiming::virtualized(), FlashTiming::physical()];
    let cycles = fleet.run_sweep(cfg, 0xCC, timings, |cfg, timing, _point_seed| {
        Ok(vec![case_c_one(cfg, timing, windows, words, 0xCC)?])
    })?;
    Ok(case_c_result(cfg, windows, samples, &cycles))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlatformConfig {
        PlatformConfig::default()
    }

    #[test]
    fn fig4_point_low_freq_sleep_dominated() {
        // 100 Hz, shortened window: active share must be < 1% of time.
        let points = fig4_point(&cfg(), 100.0, 0.5, 1).unwrap();
        assert_eq!(points.len(), 2);
        let p = &points[0];
        assert!(p.active_s / p.total_s < 0.01, "active frac {}", p.active_s / p.total_s);
        assert!((p.total_s - 0.5).abs() < 0.05, "total {}", p.total_s);
    }

    #[test]
    fn fig4_point_high_freq_active_dominated() {
        let points = fig4_point(&cfg(), 100_000.0, 0.05, 1).unwrap();
        let p = &points[0];
        assert!(p.active_s / p.total_s > 0.70, "active frac {}", p.active_s / p.total_s);
        // energy follows
        assert!(p.active_mj > p.sleep_mj);
    }

    #[test]
    fn fig5_mm_cpu_vs_cgra() {
        let cpu = fig5_run(&cfg(), Fig5Kernel::Mm, Fig5Impl::Cpu, 5).unwrap();
        let cgra = fig5_run(&cfg(), Fig5Kernel::Mm, Fig5Impl::Cgra, 5).unwrap();
        assert!(cpu[0].validated && cgra[0].validated);
        let speedup = cpu[0].cycles as f64 / cgra[0].cycles as f64;
        assert!(speedup > 2.0 && speedup < 20.0, "MM speedup {speedup}");
        // CGRA also reduces energy (both calibrations)
        for (c, g) in cpu.iter().zip(&cgra) {
            assert!(g.energy_mj < c.energy_mj, "{} vs {}", g.energy_mj, c.energy_mj);
        }
    }

    #[test]
    fn case_c_speedup_scale() {
        let r = case_c(&Fleet::auto(), &cfg(), 40).unwrap();
        assert!(r.speedup > 150.0 && r.speedup < 350.0, "speedup {}", r.speedup);
        assert!(r.phys_window_s > r.virt_window_s * 100.0);
    }

    #[test]
    fn fig5_cells_order_is_the_serial_grid_order() {
        let cells = fig5_cells();
        assert_eq!(cells.len(), 6);
        assert_eq!(cells[0], (Fig5Kernel::Mm, Fig5Impl::Cpu));
        assert_eq!(cells[1], (Fig5Kernel::Mm, Fig5Impl::Cgra));
        assert_eq!(cells[5], (Fig5Kernel::Fft, Fig5Impl::Cgra));
    }
}
