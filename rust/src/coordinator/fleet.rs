//! The experiment fleet: coarse-grained parallelism across independent
//! platform instances.
//!
//! FEMU's §V sweeps are embarrassingly parallel — every sweep point
//! builds its own [`Platform`](super::Platform) from a cloned
//! [`PlatformConfig`], owns its own RNG stream, and shares no mutable
//! state with any other point. [`Fleet`] exploits that: it shards a
//! sweep's points across a pool of std threads (pulling from a shared
//! in-order work queue, so uneven points balance), gives each point a
//! deterministic seed derived from the sweep's base seed, and aggregates
//! the per-point result batches back into **serial order**.
//!
//! Determinism contract: for any worker count, [`Fleet::run_sweep`]
//! returns results bit-identical to [`Fleet::serial`] — each point's seed
//! depends only on (base seed, point index), and aggregation order
//! depends only on point index. `tests/fleet_determinism.rs` holds the
//! line on this.
//!
//! Two pool shapes live here:
//!
//! * [`Fleet`] — scoped, per-sweep threads for the experiment drivers
//!   (workers borrow the sweep closure; nothing outlives the call);
//! * [`WorkerPool`] — a long-lived bounded pool of named threads for
//!   `'static` jobs. The control server dispatches every session command
//!   onto one of these, which is what bounds its execution concurrency
//!   regardless of how many connections are open (DESIGN.md §9).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::config::PlatformConfig;
use crate::metrics::{Counter, Gauge, Histogram, LATENCY_BOUNDS_US};
use crate::snapshot::PlatformSnapshot;

use super::Platform;

/// A worker pool for sweep execution. `Copy`-cheap handle: the threads
/// are scoped to each [`Fleet::run_sweep`] call, not kept alive between
/// sweeps (platform construction dominates thread spawn cost by orders
/// of magnitude).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fleet {
    workers: usize,
}

impl Fleet {
    /// A fleet of `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// The serial reference path: runs every point in order on the
    /// calling thread. Used for determinism cross-checks.
    pub fn serial() -> Self {
        Self { workers: 1 }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Self {
        Self::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn is_serial(&self) -> bool {
        self.workers == 1
    }

    /// Run one sweep: each element of `points` is executed by
    /// `run(cfg, point, seed)` on some worker, where `seed` is
    /// [`point_seed`]`(base_seed, index)`. Each invocation is expected to
    /// build its own private `Platform` from a clone of `cfg` (the
    /// experiment drivers all do). The returned batches are concatenated
    /// in point order, so the output is independent of the worker count.
    ///
    /// On error the first failing point's error (in point order) is
    /// returned and the remaining unclaimed points are abandoned.
    pub fn run_sweep<P, T, F>(
        &self,
        cfg: &PlatformConfig,
        base_seed: u64,
        points: Vec<P>,
        run: F,
    ) -> Result<Vec<T>>
    where
        P: Send,
        T: Send,
        F: Fn(&PlatformConfig, P, u64) -> Result<Vec<T>> + Sync,
    {
        let n = points.len();
        if self.workers <= 1 || n <= 1 {
            let mut all = Vec::new();
            for (i, p) in points.into_iter().enumerate() {
                all.extend(run(cfg, p, point_seed(base_seed, i))?);
            }
            return Ok(all);
        }

        // Shared sweep state: a work queue handing out (index, point)
        // pairs in order, and one result slot per point.
        let workers = self.workers.min(n);
        let abort = AtomicBool::new(false);
        let queue = Mutex::new(points.into_iter().enumerate());
        let results: Vec<Mutex<Option<Result<Vec<T>>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let Some((i, point)) = queue.lock().expect("queue poisoned").next() else {
                        break;
                    };
                    let r = run(cfg, point, point_seed(base_seed, i));
                    if r.is_err() {
                        abort.store(true, Ordering::Relaxed);
                    }
                    *results[i].lock().expect("result slot poisoned") = Some(r);
                });
            }
        });

        gather_results(results, n)
    }

    /// [`Fleet::run_sweep`] with fork-based fan-out: instead of every
    /// point booting its own platform, the sweep boots **one** golden
    /// platform, applies `warmup` (stage datasets, load a program, run an
    /// init phase — whatever is identical across points), snapshots it,
    /// and hands every point a platform *restored* from that snapshot.
    /// Each worker keeps one reusable platform and restores it between
    /// points, so the per-point fixed cost is a sparse state copy rather
    /// than a full re-boot plus re-warmup.
    ///
    /// `golden` overrides the boot+warmup with a pre-made snapshot (the
    /// CLI's `--from-snapshot`); its shape must match `cfg`.
    ///
    /// Determinism contract: identical to [`Fleet::run_sweep`] — every
    /// point starts from the bit-identical restored image and seeds
    /// depend only on (base seed, index), so the output is independent of
    /// the worker count *and* bit-identical to boot-per-point sweeps
    /// (restore reproduces a freshly-booted-and-warmed platform exactly;
    /// `tests/fleet_determinism.rs` holds the line on both).
    pub fn run_sweep_forked<P, T, F>(
        &self,
        cfg: &PlatformConfig,
        base_seed: u64,
        points: Vec<P>,
        golden: Option<&PlatformSnapshot>,
        warmup: &(dyn Fn(&mut Platform) -> Result<()> + Sync),
        run: F,
    ) -> Result<Vec<T>>
    where
        P: Send,
        T: Send,
        F: Fn(&mut Platform, P, u64) -> Result<Vec<T>> + Sync,
    {
        let owned;
        // the golden platform itself is reused as the serial path's
        // restore target (no second boot)
        let mut reuse: Option<Platform> = None;
        let snap: &PlatformSnapshot = match golden {
            Some(s) => s,
            None => {
                let mut g = Platform::new(cfg.clone());
                warmup(&mut g)?;
                owned = g.snapshot();
                reuse = Some(g);
                &owned
            }
        };

        let n = points.len();
        if self.workers <= 1 || n <= 1 {
            let mut platform = reuse.take().unwrap_or_else(|| Platform::new(cfg.clone()));
            let mut all = Vec::new();
            for (i, p) in points.into_iter().enumerate() {
                platform.restore(snap)?;
                all.extend(run(&mut platform, p, point_seed(base_seed, i))?);
            }
            return Ok(all);
        }

        let workers = self.workers.min(n);
        let abort = AtomicBool::new(false);
        let queue = Mutex::new(points.into_iter().enumerate());
        let results: Vec<Mutex<Option<Result<Vec<T>>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    // one reusable platform per worker, restored per point
                    let mut platform = Platform::new(cfg.clone());
                    loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        let Some((i, point)) = queue.lock().expect("queue poisoned").next()
                        else {
                            break;
                        };
                        let r = platform
                            .restore(snap)
                            .and_then(|()| run(&mut platform, point, point_seed(base_seed, i)));
                        if r.is_err() {
                            abort.store(true, Ordering::Relaxed);
                        }
                        *results[i].lock().expect("result slot poisoned") = Some(r);
                    }
                });
            }
        });

        gather_results(results, n)
    }
}

/// One result slot per sweep point, filled by whichever worker ran it.
type PointSlots<T> = Vec<Mutex<Option<Result<Vec<T>>>>>;

/// Aggregate per-point result slots in point order (== serial order).
/// Errors win over partial results; missing slots can only occur after
/// an abort.
fn gather_results<T>(results: PointSlots<T>, n: usize) -> Result<Vec<T>> {
    let mut err = None;
    let mut batches = Vec::with_capacity(n);
    for slot in results {
        match slot.into_inner().expect("result slot poisoned") {
            Some(Ok(batch)) => batches.push(batch),
            Some(Err(e)) => {
                if err.is_none() {
                    err = Some(e);
                }
            }
            None => {}
        }
    }
    if let Some(e) = err {
        return Err(e);
    }
    if batches.len() != n {
        bail!("fleet aborted with {} of {n} points completed and no error", batches.len());
    }
    Ok(batches.into_iter().flatten().collect())
}

/// Deterministic per-point seed: a splitmix64 step over the base seed and
/// the point index. Identical for every worker count by construction —
/// this is what makes the fleet/serial bit-identity possible while still
/// giving every sweep point an independent RNG stream.
pub fn point_seed(base: u64, index: usize) -> u64 {
    let mut z = base.wrapping_add((index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// =====================================================================
// WorkerPool — long-lived bounded pool for 'static jobs
// =====================================================================

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Queue accounting for a [`WorkerPool`], shared between submitters and
/// workers. All counters are monotonic except `queue_depth`, which tracks
/// jobs accepted but not yet started — the live backlog the control
/// server's `metrics` command reports (DESIGN.md §14).
#[derive(Debug)]
pub struct PoolStats {
    /// Jobs accepted into the queue.
    pub submitted: Counter,
    /// Jobs a worker finished running (including panicked ones — the
    /// panic is contained per job, so from the queue's point of view the
    /// job completed).
    pub completed: Counter,
    /// Jobs refused because the pool was already shut down.
    pub rejected: Counter,
    /// Jobs accepted but not yet picked up by a worker.
    pub queue_depth: Gauge,
    /// Time each job spent waiting in the queue before a worker picked
    /// it up, in microseconds.
    pub wait_us: Histogram,
}

impl PoolStats {
    fn new() -> Self {
        Self {
            submitted: Counter::new(),
            completed: Counter::new(),
            rejected: Counter::new(),
            queue_depth: Gauge::new(),
            wait_us: Histogram::new(LATENCY_BOUNDS_US),
        }
    }
}

/// A bounded pool of long-lived worker threads executing `'static` jobs
/// from a shared FIFO queue.
///
/// Unlike [`Fleet`] (scoped threads per sweep), a `WorkerPool` outlives
/// any single call: jobs are boxed closures, submitters can block on a
/// result with [`WorkerPool::submit_wait`], and [`WorkerPool::shutdown`]
/// drains the queue — every job already submitted still runs — before
/// joining the workers. A panicking job is contained (caught per job) and
/// surfaces to its submitter as an error instead of killing the worker.
pub struct WorkerPool {
    sender: Mutex<Option<mpsc::Sender<(Instant, Job)>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    workers: usize,
    stats: Arc<PoolStats>,
}

impl WorkerPool {
    /// Spawn a pool of `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<(Instant, Job)>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(PoolStats::new());
        let handles = (0..workers)
            .map(|i| {
                let rx = rx.clone();
                let stats = stats.clone();
                std::thread::Builder::new()
                    .name(format!("femu-pool-{i}"))
                    .spawn(move || loop {
                        // Receive outside the job so a panicking job can
                        // never poison the queue lock.
                        let job = rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
                        match job {
                            Ok((enqueued, job)) => {
                                stats.queue_depth.add(-1);
                                stats.wait_us.observe(enqueued.elapsed().as_micros() as u64);
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                stats.completed.inc();
                            }
                            Err(_) => break, // sender dropped: pool shut down
                        }
                    })
                    .expect("spawning pool worker thread")
            })
            .collect();
        Self { sender: Mutex::new(Some(tx)), handles: Mutex::new(handles), workers, stats }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Queue accounting: submissions, completions, rejections, live
    /// backlog, and queue-wait latency.
    pub fn stats(&self) -> &PoolStats {
        &self.stats
    }

    /// Enqueue a fire-and-forget job. Errors if the pool is shut down.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        let guard = self.sender.lock().unwrap_or_else(|p| p.into_inner());
        let Some(tx) = guard.as_ref() else {
            self.stats.rejected.inc();
            return Err(anyhow!("worker pool is shut down"));
        };
        match tx.send((Instant::now(), Box::new(job))) {
            Ok(()) => {
                self.stats.submitted.inc();
                self.stats.queue_depth.add(1);
                Ok(())
            }
            Err(_) => {
                self.stats.rejected.inc();
                Err(anyhow!("worker pool is shut down"))
            }
        }
    }

    /// Enqueue `f` and block until a worker has run it, returning its
    /// result. This is the backpressure point: with all workers busy the
    /// caller waits in queue order.
    pub fn submit_wait<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> Result<T> {
        let (tx, rx) = mpsc::channel();
        self.submit(move || {
            let _ = tx.send(f());
        })?;
        rx.recv().map_err(|_| anyhow!("worker abandoned the job (panic during execution?)"))
    }

    /// Stop accepting jobs, drain everything already queued, and join the
    /// workers. Idempotent; callable through a shared reference.
    pub fn shutdown(&self) {
        drop(self.sender.lock().unwrap_or_else(|p| p.into_inner()).take());
        let handles: Vec<_> =
            self.handles.lock().unwrap_or_else(|p| p.into_inner()).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_seed_is_deterministic_and_spread() {
        assert_eq!(point_seed(7, 3), point_seed(7, 3));
        assert_ne!(point_seed(7, 3), point_seed(7, 4));
        assert_ne!(point_seed(7, 3), point_seed(8, 3));
        // no trivially colliding neighbours in a small window
        let seeds: Vec<u64> = (0..64).map(|i| point_seed(0xF164, i)).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
    }

    #[test]
    fn point_seed_constants_are_frozen() {
        // splitmix64 reference vectors: stored fault-campaign results
        // ([`crate::faults`]) replay only if the per-point seed stream
        // never changes, so the mix function is pinned to known outputs
        assert_eq!(point_seed(0, 0), 0);
        assert_eq!(point_seed(0, 1), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn run_sweep_preserves_serial_order() {
        let cfg = PlatformConfig::default();
        // batches of varying length, tagged by (index, seed)
        let work = |_: &PlatformConfig, p: usize, seed: u64| {
            Ok((0..=p % 3).map(|j| (p, j, seed)).collect())
        };
        let points: Vec<usize> = (0..23).collect();
        let serial = Fleet::serial().run_sweep(&cfg, 9, points.clone(), work).unwrap();
        let fleet = Fleet::new(4).run_sweep(&cfg, 9, points, work).unwrap();
        assert_eq!(serial, fleet);
        // order really is point order
        let idx: Vec<usize> = serial.iter().map(|&(p, _, _)| p).collect();
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(idx, sorted);
    }

    #[test]
    fn run_sweep_propagates_first_error_in_order() {
        let cfg = PlatformConfig::default();
        let work = |_: &PlatformConfig, p: usize, _seed: u64| -> Result<Vec<usize>> {
            if p == 5 || p == 11 {
                bail!("point {p} failed");
            }
            Ok(vec![p])
        };
        let err = Fleet::new(4).run_sweep(&cfg, 0, (0..16).collect(), work).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("failed"), "{msg}");
    }

    #[test]
    fn worker_counts_clamp() {
        assert_eq!(Fleet::new(0).workers(), 1);
        assert!(Fleet::serial().is_serial());
        assert!(Fleet::auto().workers() >= 1);
    }

    #[test]
    fn pool_runs_jobs_and_returns_results() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let results: Vec<usize> = (0..10)
            .map(|i| pool.submit_wait(move || i * i))
            .collect::<Result<_>>()
            .unwrap();
        assert_eq!(results, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_shutdown_drains_queued_jobs_then_rejects() {
        use std::sync::atomic::AtomicUsize;
        let pool = WorkerPool::new(1);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let done = done.clone();
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                done.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 8, "queued jobs must drain on shutdown");
        assert!(pool.submit(|| ()).is_err());
        assert!(pool.submit_wait(|| 1).is_err());
    }

    #[test]
    fn pool_contains_a_panicking_job() {
        let pool = WorkerPool::new(1);
        let err = pool.submit_wait(|| -> usize { panic!("job exploded") }).unwrap_err();
        assert!(format!("{err:#}").contains("abandoned"), "{err:#}");
        // the worker survives and keeps serving
        assert_eq!(pool.submit_wait(|| 7usize).unwrap(), 7);
    }

    #[test]
    fn pool_stats_count_the_queue() {
        let pool = WorkerPool::new(2);
        for i in 0..6 {
            assert_eq!(pool.submit_wait(move || i).unwrap(), i);
        }
        // shutdown joins the workers, so completed counts are settled
        pool.shutdown();
        let s = pool.stats();
        assert_eq!(s.submitted.get(), 6);
        assert_eq!(s.completed.get(), 6);
        assert_eq!(s.queue_depth.get(), 0, "drained queue has no backlog");
        assert_eq!(s.wait_us.count(), 6, "every job's queue wait is observed");
        // post-shutdown submissions are counted as rejections
        assert!(pool.submit(|| ()).is_err());
        assert_eq!(s.rejected.get(), 1);
    }
}
