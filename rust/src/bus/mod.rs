//! System interconnect: the OBI-style crossbar of the emulated X-HEEP
//! host, plus the address map.
//!
//! Address map (see DESIGN.md §4):
//!
//! ```text
//! 0x0000_0000 .. banks*bank_size   SRAM banks (code + data)
//! 0x2000_0000 .. +0x1000           peripherals (see periph::map)
//! 0x4000_0000 .. +cs_dram_size     bridge window into CS DRAM
//! ```
//!
//! Wait-state model: SRAM 0 extra cycles, peripheral registers
//! [`PERIPH_WAIT`], bridge window [`BRIDGE_WAIT`] (the OBI→AXI→DDR
//! crossing of §IV-B), plus device-specific costs (SPI flash word timing).

use crate::bridge::Mailbox;
use crate::cgra::CgraDevice;
use crate::cpu::{BusAccess, BusFault, Size};
use crate::mem::{CsDram, MemError, SramBank};
use crate::periph::{map, Dma, Gpio, PowerCtrl, SpiAdc, SpiFlash, Timer, Uart};

/// Base of the SRAM bank region.
pub const SRAM_BASE: u32 = 0x0000_0000;
/// Base of the peripheral region.
pub const PERIPH_BASE: u32 = 0x2000_0000;
/// Base of the bridge window into CS DRAM.
pub const BRIDGE_BASE: u32 = 0x4000_0000;

/// Extra wait states for peripheral register access.
pub const PERIPH_WAIT: u32 = 1;
/// Extra wait states for bridge-window access (OBI→AXI→DDR crossing).
pub const BRIDGE_WAIT: u32 = 20;

/// Which address-map window an address lands in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Region {
    /// SRAM banks (the only executable window).
    Sram,
    /// Peripheral registers (word-access only).
    Periph,
    /// Bridge window into CS DRAM.
    Bridge,
    /// Nothing decodes here: any access faults.
    Unmapped,
}

impl Region {
    pub fn name(self) -> &'static str {
        match self {
            Self::Sram => "SRAM",
            Self::Periph => "periph",
            Self::Bridge => "bridge",
            Self::Unmapped => "unmapped",
        }
    }
}

/// The platform address-map *shape*, detached from any live [`Bus`] —
/// the single memory-map validation helper shared by the program loader
/// ([`crate::soc::loader`]) and the static analyzer
/// ([`crate::analyze`]), so "would this access fault?" has exactly one
/// answer in the codebase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoryMap {
    pub num_banks: usize,
    pub bank_size: u32,
    pub cs_dram_size: usize,
}

impl MemoryMap {
    pub fn new(num_banks: usize, bank_size: u32, cs_dram_size: usize) -> Self {
        Self { num_banks, bank_size, cs_dram_size }
    }

    /// One past the last SRAM byte.
    pub fn sram_end(&self) -> u32 {
        SRAM_BASE + self.num_banks as u32 * self.bank_size
    }

    /// Classify an address (mirrors the [`Bus`] decode exactly,
    /// including the CS-DRAM bound the bridge window checks internally).
    pub fn region(&self, addr: u32) -> Region {
        if (SRAM_BASE..self.sram_end()).contains(&addr) {
            Region::Sram
        } else if (PERIPH_BASE..PERIPH_BASE + map::REGION).contains(&addr) {
            Region::Periph
        } else if addr >= BRIDGE_BASE
            && (addr as u64) < BRIDGE_BASE as u64 + self.cs_dram_size as u64
        {
            Region::Bridge
        } else {
            Region::Unmapped
        }
    }

    /// Validate that `[addr, addr + len)` lies entirely inside SRAM,
    /// reporting the offending range and the actual window on failure.
    pub fn check_sram_span(&self, addr: u32, len: usize) -> anyhow::Result<()> {
        let end = addr as u64 + len as u64;
        if addr < SRAM_BASE || end > self.sram_end() as u64 {
            anyhow::bail!(
                "address range {addr:#010x}..{end:#010x} falls outside SRAM \
                 {SRAM_BASE:#010x}..{:#010x} ({} banks x {:#x} B)",
                self.sram_end(),
                self.num_banks,
                self.bank_size,
            );
        }
        Ok(())
    }
}

/// The interconnect and everything behind it.
pub struct Bus {
    pub banks: Vec<SramBank>,
    pub bank_size: u32,
    /// log2(bank_size): the hot-path address decode uses shift/mask
    /// instead of div/mod (§Perf opt 3).
    bank_shift: u32,
    bank_mask: u32,
    pub uart: Uart,
    pub gpio: Gpio,
    pub timer: Timer,
    pub spi_adc: SpiAdc,
    pub spi_flash: SpiFlash,
    pub dma: Dma,
    pub power: PowerCtrl,
    pub cgra_dev: CgraDevice,
    pub mailbox: Mailbox,
    pub cs_dram: CsDram,
    /// Set by any peripheral register write; the SoC uses it to skip the
    /// write-triggered half of its post-step work on the (overwhelmingly
    /// common) steps that never touch a device (§Perf opt 2).
    pub periph_touched: bool,
    /// Optional trace ring (DESIGN.md §13). Lives on the bus so the CPU
    /// step paths, the bus decode arms, and the SoC hooks all reach it
    /// through one `Option` branch. Derived state: never serialized;
    /// [`crate::soc::Soc`] clears and resyncs it on restore.
    pub trace: Option<Box<crate::trace::TraceRing>>,
    /// Optional guest profiler (DESIGN.md §14). Same placement contract
    /// as the trace ring: both backends' retire paths feed it through
    /// one `Option` branch, and it is derived state — never serialized,
    /// reset with a fresh perf baseline on load/restore.
    pub profile: Option<Box<crate::profile::Profiler>>,
}

impl Bus {
    pub fn new(
        num_banks: usize,
        bank_size: u32,
        cs_dram_size: usize,
        flash: SpiFlash,
    ) -> Self {
        assert!(num_banks > 0 && bank_size.is_power_of_two());
        Self {
            banks: (0..num_banks).map(|_| SramBank::new(bank_size as usize)).collect(),
            bank_size,
            bank_shift: bank_size.trailing_zeros(),
            bank_mask: bank_size - 1,
            uart: Uart::new(),
            gpio: Gpio::new(),
            timer: Timer::new(),
            spi_adc: SpiAdc::new(),
            spi_flash: flash,
            dma: Dma::new(),
            power: PowerCtrl::new(num_banks),
            cgra_dev: CgraDevice::new(),
            mailbox: Mailbox::new(),
            cs_dram: CsDram::new(cs_dram_size),
            periph_touched: false,
            trace: None,
            profile: None,
        }
    }

    fn sram_end(&self) -> u32 {
        SRAM_BASE + self.banks.len() as u32 * self.bank_size
    }

    /// The address-map shape of this bus (see [`MemoryMap`]).
    pub fn memory_map(&self) -> MemoryMap {
        MemoryMap::new(self.banks.len(), self.bank_size, self.cs_dram.size())
    }

    /// Which bank serves `addr`, if any.
    #[inline]
    pub fn bank_index(&self, addr: u32) -> Option<usize> {
        if (SRAM_BASE..self.sram_end()).contains(&addr) {
            Some(((addr - SRAM_BASE) >> self.bank_shift) as usize)
        } else {
            None
        }
    }

    /// Offset within a bank (shift/mask fast path).
    #[inline]
    pub fn bank_offset(&self, addr: u32) -> usize {
        ((addr - SRAM_BASE) & self.bank_mask) as usize
    }

    fn mem_err(_e: MemError) -> BusFault {
        match _e {
            MemError::NotPowered(_) => BusFault::NotPowered,
            MemError::OutOfRange => BusFault::Access,
        }
    }

    /// Debug/CS access: read a word anywhere without side effects on
    /// devices (SRAM and bridge window only). Ignores power states — this
    /// is the debugger-virtualization path.
    pub fn debug_read32(&self, addr: u32) -> Option<u32> {
        if let Some(i) = self.bank_index(addr) {
            let off = self.bank_offset(addr);
            let b = self.banks[i].dump(off, 4).ok()?;
            return Some(u32::from_le_bytes(b.try_into().unwrap()));
        }
        if addr >= BRIDGE_BASE {
            let off = (addr - BRIDGE_BASE) as usize;
            return self.cs_dram.read32(off).ok();
        }
        None
    }

    /// Debug/CS access: write a word (SRAM / bridge window), ignoring
    /// power states.
    pub fn debug_write32(&mut self, addr: u32, value: u32) -> Option<()> {
        if let Some(i) = self.bank_index(addr) {
            let off = self.bank_offset(addr);
            return self.banks[i].load(off, &value.to_le_bytes()).ok();
        }
        if addr >= BRIDGE_BASE {
            let off = (addr - BRIDGE_BASE) as usize;
            return self.cs_dram.write32(off, value).ok();
        }
        None
    }

    fn periph_read(&mut self, offset: u32, now: u64) -> Result<(u32, u32), BusFault> {
        let dev = offset & !(map::WINDOW - 1);
        let reg = offset & (map::WINDOW - 1);
        let v = match dev {
            map::UART => self.uart.read(reg),
            map::GPIO => self.gpio.read(reg),
            map::TIMER => self.timer.read(reg, now),
            map::SPI_ADC => {
                let v = self.spi_adc.read(reg, now);
                // popping a sample costs the SPI word-transfer time
                if reg == crate::periph::spi_adc::regs::RXDATA {
                    return Ok((v, PERIPH_WAIT + crate::periph::spi_adc::WORD_CYCLES));
                }
                v
            }
            map::SPI_FLASH => {
                let (v, wait) = self.spi_flash.read(reg);
                return Ok((v, PERIPH_WAIT + wait));
            }
            map::DMA => self.dma.read(reg),
            map::POWER => self.power.read(reg),
            map::CGRA => self.cgra_dev.read(reg, now),
            map::MAILBOX => self.mailbox.read(reg, now),
            _ => return Err(BusFault::Access),
        };
        Ok((v, PERIPH_WAIT))
    }

    fn periph_write(&mut self, offset: u32, value: u32, now: u64) -> Result<u32, BusFault> {
        self.periph_touched = true;
        let dev = offset & !(map::WINDOW - 1);
        let reg = offset & (map::WINDOW - 1);
        match dev {
            map::UART => self.uart.write(reg, value),
            map::GPIO => self.gpio.write(reg, value),
            map::TIMER => self.timer.write(reg, value),
            map::SPI_ADC => self.spi_adc.write(reg, value),
            map::SPI_FLASH => {
                let wait = self.spi_flash.write(reg, value);
                return Ok(PERIPH_WAIT + wait);
            }
            map::DMA => self.dma.write(reg, value, now),
            map::POWER => self.power.write(reg, value),
            map::CGRA => self.cgra_dev.write(reg, value),
            map::MAILBOX => self.mailbox.write(reg, value),
            _ => return Err(BusFault::Access),
        }
        Ok(PERIPH_WAIT)
    }

    /// Serialize every device behind the interconnect in a fixed order
    /// (banks, then each peripheral, then CS DRAM).
    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u32(self.banks.len() as u32);
        for b in &self.banks {
            b.save_state(w);
        }
        self.uart.save_state(w);
        self.gpio.save_state(w);
        self.timer.save_state(w);
        self.spi_adc.save_state(w);
        self.spi_flash.save_state(w);
        self.dma.save_state(w);
        self.power.save_state(w);
        self.cgra_dev.save_state(w);
        self.mailbox.save_state(w);
        self.cs_dram.save_state(w);
        w.bool(self.periph_touched);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        let n = r.u32()? as usize;
        if n != self.banks.len() {
            anyhow::bail!("snapshot has {n} SRAM banks, platform has {}", self.banks.len());
        }
        for b in &mut self.banks {
            b.restore_state(r)?;
        }
        self.uart.restore_state(r)?;
        self.gpio.restore_state(r)?;
        self.timer.restore_state(r)?;
        self.spi_adc.restore_state(r)?;
        self.spi_flash.restore_state(r)?;
        self.dma.restore_state(r)?;
        self.power.restore_state(r)?;
        self.cgra_dev.restore_state(r)?;
        self.mailbox.restore_state(r)?;
        self.cs_dram.restore_state(r)?;
        self.periph_touched = r.bool()?;
        Ok(())
    }

    /// Fast external interrupt lines (see [`crate::periph::irq`]),
    /// recomputed by the SoC after every step/event.
    pub fn fast_irq_lines(&self, now: u64) -> u32 {
        use crate::periph::irq;
        let mut lines = 0u32;
        if self.spi_adc.irq_pending(now) {
            lines |= 1 << irq::ADC;
        }
        if self.dma.irq_pending() {
            lines |= 1 << irq::DMA;
        }
        if self.cgra_dev.irq_pending() {
            lines |= 1 << irq::CGRA;
        }
        if self.mailbox.irq_pending() {
            lines |= 1 << irq::MAILBOX;
        }
        lines
    }
}

impl BusAccess for Bus {
    #[inline]
    fn fetch32(&mut self, addr: u32, _now: u64) -> Result<(u32, u32), BusFault> {
        // instruction fetch only from SRAM (no execute-from-periph/bridge)
        let i = self.bank_index(addr).ok_or(BusFault::Access)?;
        let off = self.bank_offset(addr);
        let w = self.banks[i].fetch32(off).map_err(Self::mem_err)?;
        Ok((w, 0))
    }

    #[inline]
    fn read(&mut self, addr: u32, size: Size, now: u64) -> Result<(u32, u32), BusFault> {
        if let Some(i) = self.bank_index(addr) {
            let off = self.bank_offset(addr);
            let bank = &mut self.banks[i];
            let v = match size {
                Size::Byte => bank.read8(off).map(|v| v as u32),
                Size::Half => bank.read16(off).map(|v| v as u32),
                Size::Word => bank.read32(off),
            }
            .map_err(Self::mem_err)?;
            return Ok((v, 0));
        }
        if (PERIPH_BASE..PERIPH_BASE + map::REGION).contains(&addr) {
            // registers are word-access only
            if size != Size::Word {
                return Err(BusFault::Access);
            }
            let r = self.periph_read(addr - PERIPH_BASE, now);
            if let (Some(t), Ok((v, wait))) = (self.trace.as_deref_mut(), &r) {
                t.bus_read(now, crate::trace::bus_region::PERIPH, addr, *v, *wait);
            }
            return r;
        }
        if addr >= BRIDGE_BASE {
            let off = (addr - BRIDGE_BASE) as usize;
            let v = match size {
                Size::Byte => self.cs_dram.read8(off).map(|v| v as u32),
                Size::Half => self.cs_dram.read16(off).map(|v| v as u32),
                Size::Word => self.cs_dram.read32(off),
            }
            .map_err(Self::mem_err)?;
            if let Some(t) = self.trace.as_deref_mut() {
                t.bus_read(now, crate::trace::bus_region::BRIDGE, addr, v, BRIDGE_WAIT);
            }
            return Ok((v, BRIDGE_WAIT));
        }
        Err(BusFault::Access)
    }

    #[inline]
    fn write(&mut self, addr: u32, size: Size, value: u32, now: u64) -> Result<u32, BusFault> {
        if let Some(i) = self.bank_index(addr) {
            let off = self.bank_offset(addr);
            let bank = &mut self.banks[i];
            match size {
                Size::Byte => bank.write8(off, value as u8),
                Size::Half => bank.write16(off, value as u16),
                Size::Word => bank.write32(off, value),
            }
            .map_err(Self::mem_err)?;
            return Ok(0);
        }
        if (PERIPH_BASE..PERIPH_BASE + map::REGION).contains(&addr) {
            if size != Size::Word {
                return Err(BusFault::Access);
            }
            let r = self.periph_write(addr - PERIPH_BASE, value, now);
            if let (Some(t), Ok(wait)) = (self.trace.as_deref_mut(), &r) {
                t.bus_write(now, crate::trace::bus_region::PERIPH, addr, value, *wait);
            }
            return r;
        }
        if addr >= BRIDGE_BASE {
            let off = (addr - BRIDGE_BASE) as usize;
            match size {
                Size::Byte => self.cs_dram.write8(off, value as u8),
                Size::Half => self.cs_dram.write16(off, value as u16),
                Size::Word => self.cs_dram.write32(off, value),
            }
            .map_err(Self::mem_err)?;
            if let Some(t) = self.trace.as_deref_mut() {
                t.bus_write(now, crate::trace::bus_region::BRIDGE, addr, value, BRIDGE_WAIT);
            }
            return Ok(BRIDGE_WAIT);
        }
        Err(BusFault::Access)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::periph::FlashTiming;

    fn bus() -> Bus {
        Bus::new(2, 0x2_0000, 1 << 20, SpiFlash::new(1 << 16, FlashTiming::virtualized()))
    }

    #[test]
    fn sram_rw_across_banks() {
        let mut b = bus();
        b.write(0x0000_0004, Size::Word, 0xAA55, 0).unwrap();
        b.write(0x0002_0008, Size::Word, 0x1234, 0).unwrap(); // bank 1
        assert_eq!(b.read(0x0000_0004, Size::Word, 0).unwrap().0, 0xAA55);
        assert_eq!(b.read(0x0002_0008, Size::Word, 0).unwrap().0, 0x1234);
        assert_eq!(b.bank_index(0x0002_0008), Some(1));
    }

    #[test]
    fn periph_access_and_waits() {
        let mut b = bus();
        let uart_tx = PERIPH_BASE + map::UART;
        let w = b.write(uart_tx, Size::Word, b'x' as u32, 0).unwrap();
        assert_eq!(w, PERIPH_WAIT);
        assert_eq!(b.uart.peek(), b"x");
        // byte access to registers is a fault
        assert!(b.write(uart_tx, Size::Byte, 0, 0).is_err());
    }

    #[test]
    fn bridge_window_reaches_cs_dram() {
        let mut b = bus();
        let addr = BRIDGE_BASE + 0x100;
        let w = b.write(addr, Size::Word, 77, 0).unwrap();
        assert_eq!(w, BRIDGE_WAIT);
        assert_eq!(b.cs_dram.read32(0x100).unwrap(), 77);
        let (v, w) = b.read(addr, Size::Word, 0).unwrap();
        assert_eq!((v, w), (77, BRIDGE_WAIT));
    }

    #[test]
    fn unmapped_faults() {
        let mut b = bus();
        assert!(b.read(0x1000_0000, Size::Word, 0).is_err());
        assert!(b.fetch32(PERIPH_BASE, 0).is_err());
        assert!(b.fetch32(BRIDGE_BASE, 0).is_err());
    }

    #[test]
    fn flash_word_cost_propagates() {
        let mut b = bus();
        use crate::periph::spi_flash::regs as f;
        let base = PERIPH_BASE + map::SPI_FLASH;
        b.write(base + f::ADDR, Size::Word, 0, 0).unwrap();
        let (_, wait) = b.read(base + f::DATA, Size::Word, 0).unwrap();
        assert_eq!(wait, PERIPH_WAIT + FlashTiming::virtualized().cycles_per_word);
    }

    #[test]
    fn debug_access_ignores_power_state() {
        let mut b = bus();
        b.write(0x10, Size::Word, 42, 0).unwrap();
        b.banks[0].set_state(crate::perfmon::PowerState::Retention);
        assert!(b.read(0x10, Size::Word, 0).is_err());
        assert_eq!(b.debug_read32(0x10), Some(42));
        b.debug_write32(0x14, 7).unwrap();
        b.banks[0].set_state(crate::perfmon::PowerState::Active);
        assert_eq!(b.read(0x14, Size::Word, 0).unwrap().0, 7);
    }

    #[test]
    fn memory_map_matches_bus_decode() {
        let b = bus();
        let m = b.memory_map();
        assert_eq!(m.region(0), Region::Sram);
        assert_eq!(m.region(2 * 0x2_0000 - 1), Region::Sram);
        assert_eq!(m.region(2 * 0x2_0000), Region::Unmapped);
        assert_eq!(m.region(PERIPH_BASE), Region::Periph);
        assert_eq!(m.region(PERIPH_BASE + map::REGION), Region::Unmapped);
        assert_eq!(m.region(BRIDGE_BASE), Region::Bridge);
        assert_eq!(m.region(BRIDGE_BASE + (1 << 20)), Region::Unmapped);
        assert!(m.check_sram_span(0, 2 * 0x2_0000).is_ok());
        let err = m.check_sram_span(0x3_0000, 0x2_0000).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("0x00030000..0x00050000"), "{msg}");
        assert!(msg.contains("outside SRAM"), "{msg}");
    }

    #[test]
    fn fast_irq_aggregation() {
        let mut b = bus();
        assert_eq!(b.fast_irq_lines(0), 0);
        b.spi_adc.configure_stream(4, 100, 0);
        b.spi_adc.refill(&[1, 2, 3, 4]);
        b.spi_adc.write(crate::periph::spi_adc::regs::CTRL, 0b11);
        assert_eq!(b.fast_irq_lines(0), 1 << crate::periph::irq::ADC);
    }
}
