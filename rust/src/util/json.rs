//! Minimal JSON parser/serializer (offline substrate — serde is not
//! available in this environment; see Cargo.toml).
//!
//! Supports the full JSON grammar needed by the artifact manifest and the
//! control-server protocol: objects, arrays, strings with escapes,
//! numbers, booleans, null. Numbers are kept as f64 (the manifest only
//! carries small integers) with an integer accessor that checks exactness.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    /// Integer accessor; errors if the number is not exactly integral.
    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 || f < i64::MIN as f64 || f > i64::MAX as f64 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| anyhow!("expected non-negative integer, got {i}"))
    }

    /// Object field access with a path-quality error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field `{key}`"))
    }

    /// Optional field: None if absent or null.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => match m.get(key) {
                Some(Json::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)?.as_str()
    }

    /// Build an object from key/value pairs (serialization helper).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_i32(v: &[i32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input at byte {}", self.i))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected `{}` at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}` at byte {}, found `{}`", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]` at byte {}, found `{}`", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape `{hex}`"))?;
                            self.i += 4;
                            // Surrogate pairs: JSON encodes astral chars as
                            // \uD8xx\uDCxx; combine when we see a high one.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| anyhow!("bad \\u escape `{hex2}`"))?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                        .ok_or_else(|| anyhow!("bad surrogate pair"))?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        other => bail!("bad escape `\\{}`", other as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string"),
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: find the full char in the source
                    let start = self.i - 1;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| anyhow!("invalid utf8 in string"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text.parse().map_err(|_| anyhow!("bad number `{text}`"))?;
        Ok(Json::Num(n))
    }
}

/// Serialize with correct string escaping (compact form).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let text = r#"{
          "format": "hlo-text", "return_tuple": true,
          "entries": {"matmul": {"file": "matmul.hlo.txt",
            "args": [{"shape": [121, 16], "dtype": "int32"}],
            "results": [{"shape": [121, 4], "dtype": "int32"}]}}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.str_field("format").unwrap(), "hlo-text");
        assert!(v.get("return_tuple").unwrap().as_bool().unwrap());
        let mm = v.get("entries").unwrap().get("matmul").unwrap();
        let arg0 = &mm.get("args").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = arg0
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![121, 16]);
    }

    #[test]
    fn roundtrip_escapes() {
        let v = Json::Obj(
            [("k\n\"x".to_string(), Json::Str("v\\t\t".into()))].into_iter().collect(),
        );
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("-12").unwrap().as_i64().unwrap(), -12);
        assert_eq!(Json::parse("3.5").unwrap().as_f64().unwrap(), 3.5);
        assert_eq!(Json::parse("1e3").unwrap().as_i64().unwrap(), 1000);
        assert!(Json::parse("3.5").unwrap().as_i64().is_err());
    }

    #[test]
    fn parse_nested_arrays() {
        let v = Json::parse("[[1,2],[3],[]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert!(a[2].as_arr().unwrap().is_empty());
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn opt_and_missing_fields() {
        let v = Json::parse(r#"{"a": null, "b": 1}"#).unwrap();
        assert!(v.opt("a").is_none());
        assert!(v.opt("c").is_none());
        assert_eq!(v.opt("b").unwrap().as_i64().unwrap(), 1);
        assert!(v.get("z").is_err());
    }
}
