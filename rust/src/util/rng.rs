//! Deterministic PRNG (SplitMix64) — offline substrate for `rand`.
//!
//! Used by workload/signal generators, the property-testing helper, and
//! tests. Deterministic by construction: every stream is fully defined by
//! its seed, which EXPERIMENTS.md records per run.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and trivially
/// reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) (Lemire's multiply-shift; exactness is irrelevant
    /// at our ranges, determinism is what matters).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform i64 in [lo, hi) — the integer-range generator used for
    /// kernel operands.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        self.range_i64(lo as i64, hi as i64) as i32
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Vector of int32 values in [lo, hi).
    pub fn vec_i32(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i32(lo, hi)).collect()
    }

    /// Fork a child stream (for independent substreams per component).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn ranges_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range_i32(-5, 5);
            assert!((-5..5).contains(&v));
            let u = r.below(3);
            assert!(u < 3);
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(1);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[r.below(4) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
