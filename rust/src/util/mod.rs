//! Small in-repo substrates replacing crates that are unavailable in the
//! offline build environment (see Cargo.toml): JSON, a TOML subset, a
//! deterministic PRNG, and misc helpers.

pub mod json;
pub mod rng;
pub mod toml;

pub use json::Json;
pub use rng::Rng;

/// Format a cycle count at a clock frequency as seconds (helper used by
/// reports and benches).
pub fn cycles_to_secs(cycles: u64, freq_hz: u64) -> f64 {
    cycles as f64 / freq_hz as f64
}

/// Pretty engineering formatting for report tables: 1234567 -> "1.235M".
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    let (v, suffix) = if ax >= 1e9 {
        (x / 1e9, "G")
    } else if ax >= 1e6 {
        (x / 1e6, "M")
    } else if ax >= 1e3 {
        (x / 1e3, "k")
    } else if ax >= 1.0 || x == 0.0 {
        (x, "")
    } else if ax >= 1e-3 {
        (x * 1e3, "m")
    } else if ax >= 1e-6 {
        (x * 1e6, "u")
    } else {
        (x * 1e9, "n")
    };
    format!("{v:.3}{suffix}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_to_secs_at_20mhz() {
        assert_eq!(cycles_to_secs(20_000_000, 20_000_000), 1.0);
        assert_eq!(cycles_to_secs(10_000, 20_000_000), 0.0005);
    }

    #[test]
    fn eng_formats() {
        assert_eq!(eng(0.0), "0.000");
        assert_eq!(eng(1_500.0), "1.500k");
        assert_eq!(eng(2.5e6), "2.500M");
        assert_eq!(eng(0.002), "2.000m");
        assert_eq!(eng(3.2e-7), "320.000n");
    }
}
