//! Minimal TOML-subset parser (offline substrate — the `toml` crate is not
//! available; see Cargo.toml).
//!
//! Supports what the FEMU config system uses: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / array-of-number values, `#` comments, and bare or quoted
//! keys. Everything parses into a flat `section.key -> Value` map, which
//! is all the typed config layer ([`crate::config`]) needs.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A TOML scalar/array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let i = self.as_i64()?;
        u64::try_from(i).map_err(|_| anyhow!("expected non-negative integer, got {i}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }
}

/// A parsed TOML document: flat map of `section.key` (or bare `key` for
/// the root table) to values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = line[..eq].trim().trim_matches('"');
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(full.clone(), val).is_some() {
                bail!("line {}: duplicate key `{full}`", lineno + 1);
            }
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Result<&Value> {
        self.entries.get(key).ok_or_else(|| anyhow!("missing config key `{key}`"))
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.entries.get(key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.entries.get(key) {
            Some(v) => v.as_u64(),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.entries.get(key) {
            Some(v) => v.as_f64(),
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.entries.get(key) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }

    /// All keys under a `section.` prefix (key names with prefix removed).
    pub fn section_keys(&self, section: &str) -> Vec<String> {
        let prefix = format!("{section}.");
        self.entries
            .keys()
            .filter_map(|k| k.strip_prefix(&prefix).map(str::to_string))
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        // minimal escapes; config strings are paths/names
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => bail!("bad escape `\\{other:?}`"),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut vals = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue; // trailing comma
                }
                vals.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(vals));
    }
    // numbers: allow underscores, hex ints, floats with exponents
    let cleaned = s.replace('_', "");
    if let Some(hex) = cleaned.strip_prefix("0x").or_else(|| cleaned.strip_prefix("0X")) {
        return Ok(Value::Int(
            i64::from_str_radix(hex, 16).map_err(|_| anyhow!("bad hex int `{s}`"))?,
        ));
    }
    if !cleaned.contains('.') && !cleaned.contains('e') && !cleaned.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value `{s}`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = Doc::parse(
            r#"
            # energy model
            name = "heepocrates"   # calibration id
            [cpu]
            active_mw = 1.8
            gated_mw = 0.35
            states = 4
            retention = false
            [mem.bank0]
            size = 0x8000
            freqs = [100, 1_000, 10000]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "heepocrates");
        assert_eq!(doc.get("cpu.active_mw").unwrap().as_f64().unwrap(), 1.8);
        assert_eq!(doc.get("cpu.states").unwrap().as_i64().unwrap(), 4);
        assert!(!doc.get("cpu.retention").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("mem.bank0.size").unwrap().as_i64().unwrap(), 0x8000);
        let arr = doc.get("mem.bank0.freqs").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_i64().unwrap(), 1000);
    }

    #[test]
    fn defaults_and_missing() {
        let doc = Doc::parse("a = 1").unwrap();
        assert_eq!(doc.u64_or("a", 9).unwrap(), 1);
        assert_eq!(doc.u64_or("b", 9).unwrap(), 9);
        assert!(doc.get("b").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = Doc::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.get("k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(Doc::parse("a = 1\na = 2").is_err());
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = ").is_err());
    }

    #[test]
    fn section_keys_lists_children() {
        let doc = Doc::parse("[d.cpu]\na=1\nb=2\n[d.mem]\nc=3").unwrap();
        let mut keys = doc.section_keys("d.cpu");
        keys.sort();
        assert_eq!(keys, vec!["a", "b"]);
    }

    #[test]
    fn float_and_exponent_forms() {
        let doc = Doc::parse("a = 1.5e3\nb = -2").unwrap();
        assert_eq!(doc.get("a").unwrap().as_f64().unwrap(), 1500.0);
        assert_eq!(doc.get("b").unwrap().as_i64().unwrap(), -2);
    }
}
