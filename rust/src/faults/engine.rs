//! Campaign engine: golden-run capture, deterministic fault sampling,
//! injection, the slice-based watchdog, and the outcome classifier.
//!
//! Determinism is the load-bearing property. A point's fault is fully
//! derived from `point_seed(campaign_seed, index)` **before** the point
//! executes, every point starts from the bit-identical golden snapshot,
//! and both execution backends are cycle-exact — so the outcome table
//! is a pure function of (spec, platform config) and bit-identical for
//! any worker count and for interp vs blocks
//! (`tests/fault_campaign.rs` holds the line).

use anyhow::{anyhow, bail, Result};

use crate::config::PlatformConfig;
use crate::coordinator::{AppExit, Fleet, Platform};
use crate::cpu::Halt;
use crate::isa::Program;
use crate::snapshot::PlatformSnapshot;
use crate::workloads;

use super::fnv1a64;
use super::report::{CampaignReport, PointResult};
use super::spec::{CampaignSpec, FaultModel, TargetSpace};
use super::Outcome;

/// Watchdog slice: a faulted run's budget is spent in slices this size
/// so a wedged guest is bounded without giving up run-loop service
/// hand-offs (ADC refills keep working under the watchdog).
pub const WATCHDOG_SLICE: u64 = 2_000_000;

/// Cycle budget for the golden run — generous; a builtin that cannot
/// finish under it is a staging bug, not a campaign outcome.
pub const GOLDEN_BUDGET: u64 = 1 << 33;

/// Fixed watchdog slack on top of the scaled golden remainder, so
/// near-end injections still get a meaningful grace window.
const WATCHDOG_SLACK: u64 = 100_000;

/// What the fault-free run did — the oracle every faulted run is
/// diffed against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GoldenRecord {
    /// Cycle the golden snapshot was taken at (boot + staging done).
    pub warm_cycle: u64,
    /// Cycle the golden run halted at.
    pub end_cycle: u64,
    /// Instructions retired at halt (absolute counter).
    pub instret: u64,
    /// Instructions recorded by the retire trace (counted from warm).
    pub retire_count: u64,
    /// FNV-1a digest of the retired-pc stream (from warm).
    pub retire_hash: u64,
    /// FNV-1a digest of the workload's output buffers plus the UART
    /// stream at halt.
    pub output_digest: u64,
}

/// One fully-specified injection, derived from the point seed before
/// execution. `addr` is a byte address for SRAM/flash targets, a
/// register index (1..=31) for the register file, and a CSR slot
/// (0..8) for CSRs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPoint {
    pub target: TargetSpace,
    pub model: FaultModel,
    pub addr: u32,
    pub bit: u8,
    pub inject_cycle: u64,
}

/// The address spans faults are sampled from, fixed per campaign from
/// the staged program and the platform config.
#[derive(Clone, Copy, Debug)]
pub struct TargetGeometry {
    /// Text segment: `[code_base, code_base + code_len)`.
    pub code_base: u32,
    pub code_len: u32,
    /// Data segment: `[data_base, data_base + data_len)`.
    pub data_base: u32,
    pub data_len: u32,
    /// SPI flash contents: `[0, flash_len)`.
    pub flash_len: u32,
}

impl TargetGeometry {
    pub fn new(prog: &Program, cfg: &PlatformConfig) -> TargetGeometry {
        TargetGeometry {
            code_base: prog.text_base,
            code_len: (prog.text.len() * 4) as u32,
            data_base: prog.data_base,
            data_len: prog.data.len() as u32,
            flash_len: cfg.soc.flash_size as u32,
        }
    }
}

/// One splitmix64 draw; the same finalizer as
/// [`point_seed`](crate::coordinator::fleet::point_seed), advanced as a
/// stream. Frozen: stored campaign results replay only if this never
/// changes.
fn mix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive point `seed`'s fault: target space, model, word-aligned
/// address (or register/CSR index), bit position, and injection cycle
/// inside the spec's window of the golden run. Pure — no platform
/// state is read, which is what makes the campaign's outcome table
/// independent of scheduling.
pub fn sample_fault(
    spec: &CampaignSpec,
    geom: &TargetGeometry,
    golden: &GoldenRecord,
    seed: u64,
) -> FaultPoint {
    let mut s = seed;
    let target = spec.targets[(mix(&mut s) as usize) % spec.targets.len()];
    let model = spec.models[(mix(&mut s) as usize) % spec.models.len()];
    let word_in = |s: &mut u64, base: u32, len: u32| {
        let words = (len / 4).max(1) as u64;
        base + ((mix(s) % words) as u32) * 4
    };
    let addr = match target {
        TargetSpace::SramData => word_in(&mut s, geom.data_base, geom.data_len),
        TargetSpace::SramCode => word_in(&mut s, geom.code_base, geom.code_len),
        TargetSpace::RegFile => 1 + (mix(&mut s) % 31) as u32,
        TargetSpace::Csr => (mix(&mut s) % 8) as u32,
        TargetSpace::Flash => word_in(&mut s, 0, geom.flash_len),
    };
    let bit = (mix(&mut s) % 32) as u8;
    let dur = golden.end_cycle.saturating_sub(golden.warm_cycle);
    let lo = golden.warm_cycle + (dur as f64 * spec.window.0) as u64;
    let hi = golden.warm_cycle + (dur as f64 * spec.window.1) as u64;
    let span = hi.saturating_sub(lo).max(1);
    let inject_cycle = lo + mix(&mut s) % span;
    FaultPoint { target, model, addr, bit, inject_cycle }
}

/// Load builtin `name` and stage its input buffers with deterministic
/// data (derived from the workload name, not the campaign seed — the
/// staged image is part of the golden state, identical across
/// campaigns). Returns the assembled program for symbol lookups.
pub fn stage_workload(platform: &mut Platform, name: &str) -> Result<Program> {
    let src = workloads::builtin(name)
        .ok_or_else(|| anyhow!("unknown builtin workload `{name}`"))?;
    let prog = platform.dbg.load_source(&src)?;
    let mut s = fnv1a64(name.as_bytes());
    let mut fill = |platform: &mut Platform, sym: &str, words: usize| -> Result<()> {
        let addr = prog.symbol(sym)?;
        let vals: Vec<i32> =
            (0..words).map(|_| ((mix(&mut s) & 0xFFFF) as i32) - 0x8000).collect();
        platform.dbg.write_i32_slice(addr, &vals)
    };
    match name {
        "acquisition" => platform.start_adc((0..100).collect(), 100_000.0),
        "mm_cpu" | "mm_cgra" => {
            fill(platform, "a_buf", 121 * 16)?;
            fill(platform, "b_buf", 16 * 4)?;
        }
        "conv_cpu" | "conv_cgra" => {
            fill(platform, "x_buf", 16 * 16 * 3)?;
            fill(platform, "w_buf", 8 * 3 * 3 * 3)?;
        }
        "fft_cpu" | "fft_cgra" => {
            fill(platform, "re_buf", 512)?;
            fill(platform, "im_buf", 512)?;
            fill(platform, "wr_tbl", 256)?;
            fill(platform, "wi_tbl", 256)?;
            // identity permutation: a valid bit-reversal table shape
            // (indices in range, no swaps executed)
            let rev: Vec<i32> = (0..512).collect();
            platform.dbg.write_i32_slice(prog.symbol("rev_tbl")?, &rev)?;
        }
        other => bail!("workload `{other}` is not campaignable (needs host artifacts)"),
    }
    Ok(prog)
}

/// Arm the retire trace, snapshot the warmed platform, run the golden
/// (fault-free) pass to completion, and record the oracle. `outputs`
/// are resolved `(address, length_in_bytes)` output regions.
pub fn golden_from(
    platform: &mut Platform,
    outputs: &[(u32, usize)],
) -> Result<(PlatformSnapshot, GoldenRecord)> {
    platform.dbg.soc.cpu.trace = Some(Box::default());
    let warm_cycle = platform.dbg.soc.now;
    let snap = platform.snapshot();
    match platform.run_app(GOLDEN_BUDGET)? {
        AppExit::Halted(Halt::Ebreak) => {}
        other => bail!("golden run did not halt cleanly: {other:?}"),
    }
    let soc = &platform.dbg.soc;
    let trace = soc.cpu.trace.as_ref().ok_or_else(|| anyhow!("retire trace disappeared"))?;
    let golden = GoldenRecord {
        warm_cycle,
        end_cycle: soc.now,
        instret: soc.cpu.instret,
        retire_count: trace.count,
        retire_hash: trace.hash,
        output_digest: output_digest(platform, outputs),
    };
    Ok((snap, golden))
}

/// Digest the workload's output state: every word of every output
/// region (via the side-effect-free debug port; unmapped/unpowered
/// reads fold in as `0xFFFF_FFFF`) plus the accumulated UART stream
/// (peeked, not drained — the digest is side-effect-free too).
pub fn output_digest(platform: &Platform, outputs: &[(u32, usize)]) -> u64 {
    let bus = &platform.dbg.soc.bus;
    let mut bytes = Vec::new();
    for &(addr, len) in outputs {
        let mut off = 0u32;
        while (off as usize) < len {
            let word = bus.debug_read32(addr.wrapping_add(off)).unwrap_or(0xFFFF_FFFF);
            bytes.extend_from_slice(&word.to_le_bytes());
            off += 4;
        }
    }
    bytes.extend_from_slice(bus.uart.peek());
    fnv1a64(&bytes)
}

/// Apply `fault` to the platform's live state through the existing
/// architectural surfaces. SRAM faults go through [`SramBank::load`]
/// (`crate::mem`), which bumps the page write generations — exactly
/// the path a guest store takes, so the blocks backend's
/// self-modifying-code invalidation sees code faults and never runs a
/// stale compiled block.
pub fn inject(platform: &mut Platform, fault: FaultPoint) -> Result<()> {
    match fault.target {
        TargetSpace::SramData | TargetSpace::SramCode => {
            let bus = &mut platform.dbg.soc.bus;
            let idx = bus
                .bank_index(fault.addr)
                .ok_or_else(|| anyhow!("fault address {:#x} outside SRAM", fault.addr))?;
            let off = bus.bank_offset(fault.addr);
            let word = {
                let b = bus.banks[idx]
                    .dump(off, 4)
                    .map_err(|e| anyhow!("reading fault word at {:#x}: {e:?}", fault.addr))?;
                u32::from_le_bytes([b[0], b[1], b[2], b[3]])
            };
            bus.banks[idx]
                .load(off, &fault.model.apply(word, fault.bit).to_le_bytes())
                .map_err(|e| anyhow!("writing fault word at {:#x}: {e:?}", fault.addr))?;
        }
        TargetSpace::Flash => {
            let flash = &mut platform.dbg.soc.bus.spi_flash;
            let word = {
                let b = flash.dump(fault.addr as usize, 4);
                let mut w = [0u8; 4];
                let n = b.len().min(4);
                w[..n].copy_from_slice(&b[..n]);
                u32::from_le_bytes(w)
            };
            flash.load(fault.addr as usize, &fault.model.apply(word, fault.bit).to_le_bytes());
        }
        TargetSpace::RegFile => {
            let idx = (fault.addr as usize % 32).max(1); // x0 is hardwired zero
            let cpu = &mut platform.dbg.soc.cpu;
            cpu.regs[idx] = fault.model.apply(cpu.regs[idx], fault.bit);
        }
        TargetSpace::Csr => {
            let c = &mut platform.dbg.soc.cpu.csrs;
            let reg = match fault.addr % 8 {
                0 => &mut c.mstatus,
                1 => &mut c.mie,
                2 => &mut c.mip,
                3 => &mut c.mtvec,
                4 => &mut c.mscratch,
                5 => &mut c.mepc,
                6 => &mut c.mcause,
                _ => &mut c.mtval,
            };
            *reg = fault.model.apply(*reg, fault.bit);
        }
    }
    Ok(())
}

/// Run one injection point on a platform freshly restored from the
/// golden snapshot: run to the injection cycle, inject, run under the
/// watchdog, classify. Guest misbehavior (traps, wedged sleeps,
/// watchdog expiry) is a *classification*, never an `Err` — only
/// infrastructure failures (a fault address outside every surface)
/// propagate and abort the sweep.
pub fn run_point(
    platform: &mut Platform,
    golden: &GoldenRecord,
    outputs: &[(u32, usize)],
    watchdog_factor: u64,
    index: usize,
    fault: FaultPoint,
) -> Result<PointResult> {
    // snapshots never carry the retire trace -- re-arm after restore so
    // faulted runs hash their pc stream from the same warm point the
    // golden record did
    platform.dbg.soc.cpu.trace = Some(Box::default());

    let result = |platform: &Platform, outcome: Outcome| PointResult {
        index,
        target: fault.target,
        model: fault.model,
        addr: fault.addr,
        bit: fault.bit,
        inject_cycle: fault.inject_cycle,
        outcome,
        end_cycle: platform.dbg.soc.now,
    };

    // phase 1: fault-free run up to the injection cycle
    let pre = fault.inject_cycle.saturating_sub(platform.dbg.soc.now);
    if pre > 0 {
        match platform.run_app(pre) {
            Ok(AppExit::Budget) => {}
            // deterministically unreachable (inject_cycle < golden end),
            // but classify rather than abort if a surface drifts
            Ok(AppExit::Halted(Halt::UnhandledTrap { .. })) => {
                return Ok(result(platform, Outcome::Trap))
            }
            Ok(AppExit::Halted(Halt::Ebreak)) => {
                return Ok(result(platform, classify_end(platform, golden, outputs)))
            }
            Err(_) => return Ok(result(platform, Outcome::Hang)),
        }
    }

    inject(platform, fault)?;

    // phase 2: run under the watchdog, in slices
    let budget = golden
        .end_cycle
        .saturating_sub(fault.inject_cycle)
        .saturating_mul(watchdog_factor)
        .saturating_add(WATCHDOG_SLACK);
    let mut remaining = budget;
    let halt = loop {
        if remaining == 0 {
            break None; // watchdog expired
        }
        let slice = remaining.min(WATCHDOG_SLICE);
        remaining -= slice;
        match platform.run_app(slice) {
            Ok(AppExit::Budget) => continue,
            Ok(AppExit::Halted(h)) => break Some(Ok(h)),
            Err(e) => break Some(Err(e)),
        }
    };

    let outcome = match halt {
        None => Outcome::Hang,
        Some(Err(_)) => Outcome::Hang, // dead sleep / unserviceable hand-off
        Some(Ok(Halt::UnhandledTrap { .. })) => Outcome::Trap,
        Some(Ok(Halt::Ebreak)) => classify_end(platform, golden, outputs),
    };
    Ok(result(platform, outcome))
}

/// Classify a run that halted cleanly: output diff first (SDC), then
/// timing/path diff (timing-divergent), else masked.
fn classify_end(platform: &Platform, golden: &GoldenRecord, outputs: &[(u32, usize)]) -> Outcome {
    if output_digest(platform, outputs) != golden.output_digest {
        return Outcome::Sdc;
    }
    let soc = &platform.dbg.soc;
    let trace_same = soc
        .cpu
        .trace
        .as_ref()
        .map(|t| t.count == golden.retire_count && t.hash == golden.retire_hash)
        .unwrap_or(false);
    if soc.now == golden.end_cycle && soc.cpu.instret == golden.instret && trace_same {
        Outcome::Masked
    } else {
        Outcome::TimingDivergent
    }
}

/// Run a full campaign: golden phase once, then every point through
/// [`Fleet::run_sweep_forked`].
pub fn run_campaign(cfg: &PlatformConfig, fleet: Fleet, spec: &CampaignSpec) -> Result<CampaignReport> {
    run_campaign_cancellable(cfg, fleet, spec, &|| false)
}

/// [`run_campaign`] with a cancellation hook, polled once per point
/// (the server's session-shutdown path).
pub fn run_campaign_cancellable(
    cfg: &PlatformConfig,
    fleet: Fleet,
    spec: &CampaignSpec,
    cancelled: &(dyn Fn() -> bool + Sync),
) -> Result<CampaignReport> {
    spec.validate()?;

    // golden phase: boot + stage once, capture snapshot and oracle
    let mut warm = Platform::new(cfg.clone());
    let prog = stage_workload(&mut warm, &spec.workload)?;
    let outputs: Vec<(u32, usize)> = workloads::output_region(&spec.workload)
        .ok_or_else(|| anyhow!("workload `{}` has no output region map", spec.workload))?
        .into_iter()
        .map(|(sym, len)| Ok((prog.symbol(sym)?, len)))
        .collect::<Result<_>>()?;
    let geom = TargetGeometry::new(&prog, cfg);
    let (snap, golden) = golden_from(&mut warm, &outputs)?;
    drop(warm);

    let points: Vec<usize> = (0..spec.points).collect();
    let results = fleet.run_sweep_forked(
        cfg,
        spec.seed,
        points,
        Some(&snap),
        &|_| Ok(()),
        |platform, index, seed| {
            if cancelled() {
                bail!("campaign interrupted");
            }
            let fault = sample_fault(spec, &geom, &golden, seed);
            Ok(vec![run_point(platform, &golden, &outputs, spec.watchdog_factor, index, fault)?])
        },
    )?;

    Ok(CampaignReport {
        workload: spec.workload.clone(),
        backend: cfg.soc.backend.name().to_string(),
        points: spec.points,
        seed: spec.seed,
        golden,
        results,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Stage `asm`, capture the golden record, then inject one explicit
    /// fault at the warm cycle and return its classification.
    fn classify_oracle(
        asm: &str,
        outputs_syms: &[(&str, usize)],
        target: TargetSpace,
        addr_of: &dyn Fn(&Program) -> u32,
        bit: u8,
    ) -> Outcome {
        let cfg = PlatformConfig::default();
        let mut p = Platform::new(cfg);
        let prog = p.dbg.load_source(asm).unwrap();
        let outputs: Vec<(u32, usize)> =
            outputs_syms.iter().map(|&(s, l)| (prog.symbol(s).unwrap(), l)).collect();
        let (snap, golden) = golden_from(&mut p, &outputs).unwrap();
        p.restore(&snap).unwrap();
        let fault = FaultPoint {
            target,
            model: FaultModel::BitFlip,
            addr: addr_of(&prog),
            bit,
            inject_cycle: golden.warm_cycle,
        };
        let r = run_point(&mut p, &golden, &outputs, 4, 0, fault).unwrap();
        assert_eq!(r.index, 0);
        r.outcome
    }

    #[test]
    fn oracle_masked_nop_region_flip() {
        // flipping bit 7 of the 2nd nop turns `addi x0,x0,0` into
        // `addi x1,x0,0` -- x1 is unused, outputs and timing unchanged
        let asm = r#"
            _start:
                addi zero, zero, 0
                addi zero, zero, 0
                addi zero, zero, 0
                addi zero, zero, 0
                li t0, 42
                la t1, dst
                sw t0, 0(t1)
                ebreak
            .data
            dst: .word 0
        "#;
        let got = classify_oracle(
            asm,
            &[("dst", 4)],
            TargetSpace::SramCode,
            &|prog| prog.text_base + 4,
            7,
        );
        assert_eq!(got, Outcome::Masked);
    }

    #[test]
    fn oracle_sdc_store_source_flip() {
        // flip bit 0 of the source word: the copied value differs, the
        // run is otherwise identical -- silent data corruption
        let asm = r#"
            _start:
                la t0, src
                lw t1, 0(t0)
                la t2, dst
                sw t1, 0(t2)
                ebreak
            .data
            src: .word 0x1234
            dst: .word 0
        "#;
        let got = classify_oracle(
            asm,
            &[("dst", 4)],
            TargetSpace::SramData,
            &|prog| prog.symbol("src").unwrap(),
            0,
        );
        assert_eq!(got, Outcome::Sdc);
    }

    #[test]
    fn oracle_trap_illegal_opcode_flip() {
        // flipping opcode bit 0 makes the low bits `10` -- not a valid
        // 32-bit encoding, the core traps with mtvec unset and halts
        let asm = r#"
            _start:
                li t0, 1
                la t1, dst
                sw t0, 0(t1)
                ebreak
            .data
            dst: .word 0
        "#;
        let got = classify_oracle(
            asm,
            &[("dst", 4)],
            TargetSpace::SramCode,
            &|prog| prog.text_base,
            0,
        );
        assert_eq!(got, Outcome::Trap);
    }

    #[test]
    fn oracle_hang_branch_target_flip() {
        // `j skip` encodes as 0x0080006F (jal x0, +8); flipping bit 23
        // zeroes the offset -- `jal x0, 0`, a tight self-loop the
        // watchdog has to catch
        let asm = r#"
            _start:
                j skip
                addi zero, zero, 0
            skip:
                la t0, dst
                li t1, 7
                sw t1, 0(t0)
                ebreak
            .data
            dst: .word 0
        "#;
        let got = classify_oracle(
            asm,
            &[("dst", 4)],
            TargetSpace::SramCode,
            &|prog| prog.text_base,
            23,
        );
        assert_eq!(got, Outcome::Hang);
    }

    #[test]
    fn oracle_timing_divergent_loop_count_flip() {
        // flip bit 2 of the loop count (32 -> 36): four extra
        // iterations, same stored output -- different path, same answer
        let asm = r#"
            _start:
                la t0, n
                lw t1, 0(t0)
            loop:
                addi t1, t1, -1
                bnez t1, loop
                li t2, 5
                la t3, dst
                sw t2, 0(t3)
                ebreak
            .data
            n: .word 32
            dst: .word 0
        "#;
        let got = classify_oracle(
            asm,
            &[("dst", 4)],
            TargetSpace::SramData,
            &|prog| prog.symbol("n").unwrap(),
            2,
        );
        assert_eq!(got, Outcome::TimingDivergent);
    }

    #[test]
    fn sample_fault_is_deterministic_and_in_bounds() {
        let spec = CampaignSpec::new("mm_cpu").unwrap();
        let geom = TargetGeometry {
            code_base: 0,
            code_len: 0x400,
            data_base: 0x1000,
            data_len: 0x800,
            flash_len: 0x10_0000,
        };
        let golden = GoldenRecord {
            warm_cycle: 1_000,
            end_cycle: 51_000,
            instret: 40_000,
            retire_count: 40_000,
            retire_hash: 0xABCD,
            output_digest: 0x1234,
        };
        for seed in 0..2_000u64 {
            let a = sample_fault(&spec, &geom, &golden, seed);
            let b = sample_fault(&spec, &geom, &golden, seed);
            assert_eq!(a, b);
            assert!(a.bit < 32);
            assert!(
                (golden.warm_cycle..golden.end_cycle).contains(&a.inject_cycle),
                "{a:?} outside the golden window"
            );
            match a.target {
                TargetSpace::SramData => {
                    assert!(a.addr >= 0x1000 && a.addr < 0x1800 && a.addr % 4 == 0)
                }
                TargetSpace::SramCode => assert!(a.addr < 0x400 && a.addr % 4 == 0),
                TargetSpace::RegFile => assert!((1..=31).contains(&a.addr)),
                TargetSpace::Csr => assert!(a.addr < 8),
                TargetSpace::Flash => assert!(a.addr < 0x10_0000 && a.addr % 4 == 0),
            }
        }
    }

    #[test]
    fn stage_workload_covers_every_campaignable_builtin() {
        for &name in workloads::BUILTIN_NAMES {
            let campaignable =
                workloads::output_region(name).map(|r| !r.is_empty()).unwrap_or(false);
            let cfg = PlatformConfig::default();
            let mut p = Platform::new(cfg);
            let staged = stage_workload(&mut p, name);
            assert_eq!(staged.is_ok(), campaignable, "{name}: {staged:?}");
        }
    }

    #[test]
    fn golden_record_is_reproducible() {
        let cfg = PlatformConfig::default();
        let mut a = Platform::new(cfg.clone());
        let prog = stage_workload(&mut a, "mm_cpu").unwrap();
        let outputs = vec![(prog.symbol("c_buf").unwrap(), 121 * 4 * 4)];
        let (_, ga) = golden_from(&mut a, &outputs).unwrap();

        let mut b = Platform::new(cfg);
        stage_workload(&mut b, "mm_cpu").unwrap();
        let (_, gb) = golden_from(&mut b, &outputs).unwrap();
        assert_eq!(ga, gb);
        assert!(ga.end_cycle > ga.warm_cycle);
        assert!(ga.retire_count > 0);
    }

    #[test]
    fn small_campaign_classifies_every_point() {
        let cfg = PlatformConfig::default();
        let mut spec = CampaignSpec::new("mm_cpu").unwrap();
        spec.points = 16;
        spec.seed = 11;
        let report = run_campaign(&cfg, Fleet::serial(), &spec).unwrap();
        assert_eq!(report.results.len(), 16);
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.index, i, "serial order preserved");
        }
        assert_eq!(report.class_counts().iter().sum::<usize>(), 16);
    }
}
