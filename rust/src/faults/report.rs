//! Campaign results: the per-point outcome table, class counts, the
//! per-target-region breakdown, the AVF summary, and the text/JSON
//! renderers shared by `femu faults run|report` and the `faults.run`
//! server command.

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::engine::GoldenRecord;
use super::spec::{FaultModel, TargetSpace};
use super::Outcome;

/// One injection point's fault and classification. The full campaign
/// result is the ordered `Vec<PointResult>` — bit-identical for any
/// worker count and either execution backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PointResult {
    pub index: usize,
    pub target: TargetSpace,
    pub model: FaultModel,
    /// Byte address (SRAM/flash), register index (regfile), or CSR slot.
    pub addr: u32,
    pub bit: u8,
    pub inject_cycle: u64,
    pub outcome: Outcome,
    /// Cycle the faulted run ended at (halt, trap, or watchdog stop).
    pub end_cycle: u64,
}

/// A completed campaign: spec echo, golden oracle, and the outcome
/// table.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignReport {
    pub workload: String,
    /// Execution backend the campaign ran on (informational — the
    /// outcome table is identical across backends).
    pub backend: String,
    pub points: usize,
    pub seed: u64,
    pub golden: GoldenRecord,
    pub results: Vec<PointResult>,
}

impl CampaignReport {
    /// Outcome counts, indexed by [`Outcome::index`].
    pub fn class_counts(&self) -> [usize; 5] {
        let mut counts = [0usize; 5];
        for r in &self.results {
            counts[r.outcome.index()] += 1;
        }
        counts
    }

    /// Per-target-region rows `(target, counts)` for every region that
    /// received at least one injection, in canonical target order.
    pub fn region_table(&self) -> Vec<(TargetSpace, [usize; 5])> {
        let mut rows: Vec<(TargetSpace, [usize; 5])> = Vec::new();
        for t in TargetSpace::ALL {
            let mut counts = [0usize; 5];
            for r in self.results.iter().filter(|r| r.target == t) {
                counts[r.outcome.index()] += 1;
            }
            if counts.iter().sum::<usize>() > 0 {
                rows.push((t, counts));
            }
        }
        rows
    }

    /// Architectural vulnerability factor: the fraction of injections
    /// that visibly perturbed the run (everything but masked).
    pub fn avf(&self) -> f64 {
        avf_of(&self.class_counts())
    }

    /// JSON encoding. 64-bit hashes and the seed are hex *strings* —
    /// they do not survive an f64 round-trip as numbers.
    pub fn to_json(&self) -> Json {
        let counts = self.class_counts();
        let classes = Json::obj(
            Outcome::ALL
                .iter()
                .map(|o| (o.name(), Json::from(counts[o.index()] as i64)))
                .collect(),
        );
        let regions = Json::Arr(
            self.region_table()
                .into_iter()
                .map(|(t, counts)| {
                    let mut fields = vec![
                        ("target", Json::from(t.name())),
                        ("points", Json::from(counts.iter().sum::<usize>() as i64)),
                    ];
                    for o in Outcome::ALL {
                        fields.push((o.name(), Json::from(counts[o.index()] as i64)));
                    }
                    fields.push(("avf", Json::from(avf_of(&counts))));
                    Json::obj(fields)
                })
                .collect(),
        );
        let results = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("index", Json::from(r.index as i64)),
                        ("target", Json::from(r.target.name())),
                        ("model", Json::from(r.model.name())),
                        ("addr", Json::from(i64::from(r.addr))),
                        ("bit", Json::from(i64::from(r.bit))),
                        ("inject_cycle", Json::from(r.inject_cycle as i64)),
                        ("outcome", Json::from(r.outcome.name())),
                        ("end_cycle", Json::from(r.end_cycle as i64)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("workload", Json::from(self.workload.as_str())),
            ("backend", Json::from(self.backend.as_str())),
            ("points", Json::from(self.points as i64)),
            ("seed", hex_u64(self.seed)),
            (
                "golden",
                Json::obj(vec![
                    ("warm_cycle", Json::from(self.golden.warm_cycle as i64)),
                    ("end_cycle", Json::from(self.golden.end_cycle as i64)),
                    ("instret", Json::from(self.golden.instret as i64)),
                    ("retire_count", Json::from(self.golden.retire_count as i64)),
                    ("retire_hash", hex_u64(self.golden.retire_hash)),
                    ("output_digest", hex_u64(self.golden.output_digest)),
                ]),
            ),
            ("classes", classes),
            ("avf", Json::from(self.avf())),
            ("regions", regions),
            ("results", results),
        ])
    }

    /// Decode [`CampaignReport::to_json`] output (the `femu faults
    /// report` path). Derived tables (`classes`, `regions`, `avf`) are
    /// recomputed from `results`, not trusted from the document.
    pub fn from_json(json: &Json) -> Result<CampaignReport> {
        let golden = json.get("golden").context("reading golden record")?;
        let results = json
            .get("results")?
            .as_arr()?
            .iter()
            .enumerate()
            .map(|(i, r)| {
                (|| -> Result<PointResult> {
                    Ok(PointResult {
                        index: r.get("index")?.as_usize()?,
                        target: TargetSpace::parse(r.str_field("target")?)?,
                        model: FaultModel::parse(r.str_field("model")?)?,
                        addr: u32::try_from(r.get("addr")?.as_i64()?)?,
                        bit: u8::try_from(r.get("bit")?.as_i64()?)?,
                        inject_cycle: u64::try_from(r.get("inject_cycle")?.as_i64()?)?,
                        outcome: Outcome::parse(r.str_field("outcome")?)?,
                        end_cycle: u64::try_from(r.get("end_cycle")?.as_i64()?)?,
                    })
                })()
                .with_context(|| format!("reading result {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(CampaignReport {
            workload: json.str_field("workload")?.to_string(),
            backend: json.str_field("backend")?.to_string(),
            points: json.get("points")?.as_usize()?,
            seed: parse_hex_u64(json.str_field("seed")?)?,
            golden: GoldenRecord {
                warm_cycle: u64::try_from(golden.get("warm_cycle")?.as_i64()?)?,
                end_cycle: u64::try_from(golden.get("end_cycle")?.as_i64()?)?,
                instret: u64::try_from(golden.get("instret")?.as_i64()?)?,
                retire_count: u64::try_from(golden.get("retire_count")?.as_i64()?)?,
                retire_hash: parse_hex_u64(golden.str_field("retire_hash")?)?,
                output_digest: parse_hex_u64(golden.str_field("output_digest")?)?,
            },
            results,
        })
    }

    /// Human-readable report: campaign header, the class-count table,
    /// the AVF line, and the per-target-region breakdown.
    pub fn render_text(&self) -> String {
        let counts = self.class_counts();
        let total = self.results.len().max(1);
        let mut out = String::new();
        out.push_str(&format!(
            "fault campaign: {} on {} backend, {} points, seed {:#x}\n",
            self.workload, self.backend, self.points, self.seed
        ));
        out.push_str(&format!(
            "golden run: {} cycles warm -> {} cycles end, {} retired, output {:#018x}\n\n",
            self.golden.warm_cycle,
            self.golden.end_cycle,
            self.golden.retire_count,
            self.golden.output_digest
        ));
        out.push_str(&format!("  {:<24} {:>8} {:>9}\n", "class", "points", "fraction"));
        for o in Outcome::ALL {
            let c = counts[o.index()];
            out.push_str(&format!(
                "  {:<24} {:>8} {:>8.1}%\n",
                o.name(),
                c,
                100.0 * c as f64 / total as f64
            ));
        }
        out.push_str(&format!("\n  AVF (1 - masked fraction): {:.3}\n\n", self.avf()));
        out.push_str(&format!(
            "  {:<10} {:>7} {:>7} {:>5} {:>5} {:>5} {:>7} {:>7}\n",
            "region", "points", "masked", "sdc", "trap", "hang", "timing", "avf"
        ));
        for (t, counts) in self.region_table() {
            out.push_str(&format!(
                "  {:<10} {:>7} {:>7} {:>5} {:>5} {:>5} {:>7} {:>7.3}\n",
                t.name(),
                counts.iter().sum::<usize>(),
                counts[Outcome::Masked.index()],
                counts[Outcome::Sdc.index()],
                counts[Outcome::Trap.index()],
                counts[Outcome::Hang.index()],
                counts[Outcome::TimingDivergent.index()],
                avf_of(&counts),
            ));
        }
        out
    }
}

fn avf_of(counts: &[usize; 5]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    1.0 - counts[Outcome::Masked.index()] as f64 / total as f64
}

fn hex_u64(v: u64) -> Json {
    Json::from(format!("{v:#x}").as_str())
}

fn parse_hex_u64(s: &str) -> Result<u64> {
    let digits = s
        .strip_prefix("0x")
        .or_else(|| s.strip_prefix("0X"))
        .ok_or_else(|| anyhow!("expected 0x-prefixed hex, got `{s}`"))?;
    u64::from_str_radix(digits, 16).map_err(|e| anyhow!("bad hex `{s}`: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> CampaignReport {
        let mk = |index, target, outcome| PointResult {
            index,
            target,
            model: FaultModel::BitFlip,
            addr: 0x100 + index as u32 * 4,
            bit: (index % 32) as u8,
            inject_cycle: 1_000 + index as u64,
            outcome,
            end_cycle: 9_000 + index as u64,
        };
        CampaignReport {
            workload: "mm_cpu".to_string(),
            backend: "interp".to_string(),
            points: 6,
            seed: 0xFA17_C0DE,
            golden: GoldenRecord {
                warm_cycle: 1_000,
                end_cycle: 9_000,
                instret: 7_500,
                retire_count: 7_500,
                retire_hash: 0xDEAD_BEEF_CAFE_F00D,
                output_digest: 0x0123_4567_89AB_CDEF,
            },
            results: vec![
                mk(0, TargetSpace::SramData, Outcome::Masked),
                mk(1, TargetSpace::SramData, Outcome::Sdc),
                mk(2, TargetSpace::SramCode, Outcome::Trap),
                mk(3, TargetSpace::RegFile, Outcome::Hang),
                mk(4, TargetSpace::Csr, Outcome::TimingDivergent),
                mk(5, TargetSpace::Flash, Outcome::Masked),
            ],
        }
    }

    #[test]
    fn counts_regions_and_avf() {
        let r = sample_report();
        assert_eq!(r.class_counts(), [2, 1, 1, 1, 1]);
        assert!((r.avf() - (1.0 - 2.0 / 6.0)).abs() < 1e-12);
        let regions = r.region_table();
        assert_eq!(regions.len(), 5);
        assert_eq!(regions[0].0, TargetSpace::SramData);
        assert_eq!(regions[0].1[Outcome::Sdc.index()], 1);
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let r = sample_report();
        let text = r.to_json().to_string();
        let back = CampaignReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        // hex fields really are strings on the wire
        assert!(text.contains("\"0xdeadbeefcafef00d\""));
        assert!(text.contains("\"0xfa17c0de\""));
    }

    #[test]
    fn render_text_mentions_every_class_and_region() {
        let text = sample_report().render_text();
        for o in Outcome::ALL {
            assert!(text.contains(o.name()), "missing {}", o.name());
        }
        for t in TargetSpace::ALL {
            assert!(text.contains(t.name()), "missing {}", t.name());
        }
        assert!(text.contains("AVF"));
    }

    #[test]
    fn hex_parsing_is_strict() {
        assert_eq!(parse_hex_u64("0xff").unwrap(), 255);
        assert!(parse_hex_u64("ff").is_err());
        assert!(parse_hex_u64("0xzz").is_err());
    }
}
