//! Snapshot-powered fault-injection campaigns (DESIGN.md §15).
//!
//! HEEPocrates-class TinyAI deployments run firmware out of noisy,
//! low-voltage SRAM where single-event upsets are a first-order design
//! concern. This subsystem turns the emulator into a resilience
//! evaluation platform: a campaign boots and warms a workload **once**,
//! saves a golden snapshot plus a golden run record (exit kind, cycle
//! count, retired-pc digest, output-memory digest), then fans N
//! injection points out through
//! [`Fleet::run_sweep_forked`](crate::coordinator::Fleet::run_sweep_forked).
//! Every point restores the golden image, injects exactly one fault —
//! fully derived from the campaign seed *before* execution, so the
//! outcome table is bit-identical for any worker count and across the
//! interp/blocks backends — runs under a slice-based watchdog, and is
//! classified by diffing against the golden record.
//!
//! Module layout:
//!
//! * [`spec`] — the campaign specification (workload, target spaces,
//!   fault models, injection window, point count/seed), parsed from
//!   TOML (`femu faults run --campaign FILE`) or built from CLI flags;
//! * [`engine`] — golden-run capture, deterministic fault sampling,
//!   injection through the existing bus/snapshot surfaces, the
//!   watchdog, and the outcome classifier;
//! * [`report`] — per-target-region breakdown, the architectural
//!   vulnerability factor (AVF) summary, and the text/JSON renderers
//!   shared by `femu faults run|report` and the `faults.run` server
//!   command (proto v7).

pub mod engine;
pub mod report;
pub mod spec;

use anyhow::{bail, Result};

pub use engine::{
    golden_from, inject, run_campaign, run_campaign_cancellable, run_point, sample_fault,
    stage_workload, FaultPoint, GoldenRecord, TargetGeometry,
};
pub use report::{CampaignReport, PointResult};
pub use spec::{CampaignSpec, FaultModel, TargetSpace};

/// How a faulted run differs from the golden run. Classification is a
/// strict priority order — trap, then hang, then output diff, then
/// timing diff — so every run lands in exactly one class (there is no
/// "unclassified" by construction).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Outcome {
    /// Completed; architectural outputs, cycle count, and retired-pc
    /// stream all match the golden run.
    Masked,
    /// Completed without any trap, but the output memory region (or
    /// UART stream) differs from the golden run — the dangerous class.
    Sdc,
    /// The core halted on an unhandled trap (illegal instruction, bus
    /// error, misaligned access).
    Trap,
    /// The watchdog budget expired, or the guest wedged in a state that
    /// cannot make progress (dead WFI sleep with no wake source, a
    /// service request the harness cannot satisfy).
    Hang,
    /// Outputs match the golden run but the cycle count or retired-pc
    /// stream differs — the run took a different path to the same
    /// answer.
    TimingDivergent,
}

impl Outcome {
    /// Every class, in canonical report order.
    pub const ALL: [Outcome; 5] =
        [Outcome::Masked, Outcome::Sdc, Outcome::Trap, Outcome::Hang, Outcome::TimingDivergent];

    /// Canonical (wire/JSON) name.
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Masked => "masked",
            Outcome::Sdc => "silent-data-corruption",
            Outcome::Trap => "trap",
            Outcome::Hang => "hang",
            Outcome::TimingDivergent => "timing-divergent",
        }
    }

    /// Index into [`Outcome::ALL`]-shaped count tables.
    pub fn index(self) -> usize {
        match self {
            Outcome::Masked => 0,
            Outcome::Sdc => 1,
            Outcome::Trap => 2,
            Outcome::Hang => 3,
            Outcome::TimingDivergent => 4,
        }
    }

    pub fn parse(s: &str) -> Result<Outcome> {
        for o in Outcome::ALL {
            if o.name() == s {
                return Ok(o);
            }
        }
        bail!("unknown outcome class `{s}`");
    }
}

/// FNV-1a 64-bit over a byte stream (same parameters as the snapshot
/// and trace framing) — the output-memory digest of the golden record.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_names_roundtrip_and_index() {
        for (i, o) in Outcome::ALL.into_iter().enumerate() {
            assert_eq!(o.index(), i);
            assert_eq!(Outcome::parse(o.name()).unwrap(), o);
        }
        assert!(Outcome::parse("melted").is_err());
    }

    #[test]
    fn fnv_matches_reference_vector() {
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
    }
}
