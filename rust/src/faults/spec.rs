//! Campaign specification: what to fault, how, and how many times.
//!
//! A spec comes from three places that all funnel through
//! [`CampaignSpec`]: a campaign TOML file (`femu faults run --campaign
//! FILE`), bare CLI flags (`--builtin/--points/--seed/--targets/
//! --models/--window`), and the `faults.run` server command. Validation
//! happens once, in [`CampaignSpec::validate`], so every surface
//! rejects the same bad inputs with the same messages.

use anyhow::{anyhow, bail, Result};

use crate::util::toml::Doc;
use crate::workloads;

/// Hard cap on campaign size — a runaway-request backstop for the
/// server surface, far above any CI or interactive campaign.
pub const MAX_POINTS: usize = 1_000_000;

/// Where a fault lands: the architectural state spaces of the emulated
/// X-HEEP platform that real SEUs hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TargetSpace {
    /// The workload's data segment in SRAM (operand/result buffers).
    SramData,
    /// The workload's text segment in SRAM — exercises the blocks
    /// backend's self-modifying-code invalidation on every hit.
    SramCode,
    /// The integer register file, x1..x31 (x0 is architecturally zero).
    RegFile,
    /// The machine CSRs (mstatus/mie/mip/mtvec/mscratch/mepc/mcause/mtval).
    Csr,
    /// External SPI flash contents.
    Flash,
}

impl TargetSpace {
    /// Every target space, in canonical report order.
    pub const ALL: [TargetSpace; 5] = [
        TargetSpace::SramData,
        TargetSpace::SramCode,
        TargetSpace::RegFile,
        TargetSpace::Csr,
        TargetSpace::Flash,
    ];

    /// Canonical (wire/JSON/CLI) name.
    pub fn name(self) -> &'static str {
        match self {
            TargetSpace::SramData => "sram-data",
            TargetSpace::SramCode => "sram-code",
            TargetSpace::RegFile => "regfile",
            TargetSpace::Csr => "csr",
            TargetSpace::Flash => "flash",
        }
    }

    /// Index into [`TargetSpace::ALL`]-shaped tables.
    pub fn index(self) -> usize {
        match self {
            TargetSpace::SramData => 0,
            TargetSpace::SramCode => 1,
            TargetSpace::RegFile => 2,
            TargetSpace::Csr => 3,
            TargetSpace::Flash => 4,
        }
    }

    pub fn parse(s: &str) -> Result<TargetSpace> {
        for t in TargetSpace::ALL {
            if t.name() == s {
                return Ok(t);
            }
        }
        bail!(
            "unknown target space `{s}` (want {})",
            TargetSpace::ALL.map(TargetSpace::name).join("|")
        );
    }

    /// Parse a comma list (`"sram-data,csr"`) or the keyword `all`.
    pub fn parse_list(s: &str) -> Result<Vec<TargetSpace>> {
        parse_name_list(s, TargetSpace::parse, &TargetSpace::ALL)
    }
}

/// What the fault does to the targeted 32-bit word.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultModel {
    /// Invert one bit (the classic SEU).
    BitFlip,
    /// Clear one bit at injection time (transient stuck-low sample).
    StuckAt0,
    /// Set one bit at injection time (transient stuck-high sample).
    StuckAt1,
    /// Invert three adjacent bits, wrapping within the word (a
    /// multi-bit upset burst).
    Burst,
}

impl FaultModel {
    /// Every model, in canonical order.
    pub const ALL: [FaultModel; 4] =
        [FaultModel::BitFlip, FaultModel::StuckAt0, FaultModel::StuckAt1, FaultModel::Burst];

    /// Canonical (wire/JSON/CLI) name.
    pub fn name(self) -> &'static str {
        match self {
            FaultModel::BitFlip => "bit-flip",
            FaultModel::StuckAt0 => "stuck-at-0",
            FaultModel::StuckAt1 => "stuck-at-1",
            FaultModel::Burst => "burst",
        }
    }

    /// Apply the model to `word` at bit position `bit` (0..32).
    pub fn apply(self, word: u32, bit: u8) -> u32 {
        let bit = u32::from(bit) % 32;
        match self {
            FaultModel::BitFlip => word ^ (1 << bit),
            FaultModel::StuckAt0 => word & !(1 << bit),
            FaultModel::StuckAt1 => word | (1 << bit),
            FaultModel::Burst => {
                let mut w = word;
                for i in 0..3 {
                    w ^= 1 << ((bit + i) % 32);
                }
                w
            }
        }
    }

    pub fn parse(s: &str) -> Result<FaultModel> {
        for m in FaultModel::ALL {
            if m.name() == s {
                return Ok(m);
            }
        }
        bail!("unknown fault model `{s}` (want {})", FaultModel::ALL.map(FaultModel::name).join("|"));
    }

    /// Parse a comma list (`"bit-flip,burst"`) or the keyword `all`.
    pub fn parse_list(s: &str) -> Result<Vec<FaultModel>> {
        parse_name_list(s, FaultModel::parse, &FaultModel::ALL)
    }
}

fn parse_name_list<T: Copy>(
    s: &str,
    parse: impl Fn(&str) -> Result<T>,
    all: &[T],
) -> Result<Vec<T>> {
    let s = s.trim();
    if s == "all" {
        return Ok(all.to_vec());
    }
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(parse(part)?);
    }
    if out.is_empty() {
        bail!("empty list `{s}`");
    }
    Ok(out)
}

/// A full campaign specification. Everything a campaign does is a pure
/// function of this struct plus the platform config — same spec, same
/// outcome table, for any worker count and either execution backend.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSpec {
    /// Built-in workload name ([`crate::workloads::BUILTIN_NAMES`]).
    pub workload: String,
    /// Number of injection points.
    pub points: usize,
    /// Campaign seed; per-point faults derive from
    /// [`point_seed`](crate::coordinator::fleet::point_seed)`(seed, index)`.
    pub seed: u64,
    /// Target spaces faults are drawn from (uniformly).
    pub targets: Vec<TargetSpace>,
    /// Fault models faults are drawn from (uniformly).
    pub models: Vec<FaultModel>,
    /// Injection window as fractions of the golden run's duration,
    /// `0.0..=1.0` with `window.0 <= window.1`.
    pub window: (f64, f64),
    /// Watchdog budget multiplier: a faulted run may spend up to
    /// `factor x` the golden run's remaining cycles (plus fixed slack)
    /// before it is classified as a hang.
    pub watchdog_factor: u64,
}

impl CampaignSpec {
    /// A default campaign over `workload`: 100 points, every target
    /// space, single bit-flips, the full run as the injection window.
    pub fn new(workload: &str) -> Result<CampaignSpec> {
        let spec = CampaignSpec {
            workload: workload.to_string(),
            points: 100,
            seed: 0xF417,
            targets: TargetSpace::ALL.to_vec(),
            models: vec![FaultModel::BitFlip],
            window: (0.0, 1.0),
            watchdog_factor: 4,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Single validation point for every surface (TOML, CLI, server).
    pub fn validate(&self) -> Result<()> {
        let known = workloads::builtin(&self.workload).is_some();
        if !known {
            bail!(
                "unknown workload `{}` (have: {})",
                self.workload,
                workloads::BUILTIN_NAMES.join(", ")
            );
        }
        let outputs = workloads::output_region(&self.workload)
            .ok_or_else(|| anyhow!("workload `{}` has no output region map", self.workload))?;
        if outputs.is_empty() {
            bail!(
                "workload `{}` needs host artifacts / has no memory output region -- \
                 not campaignable",
                self.workload
            );
        }
        if self.points == 0 || self.points > MAX_POINTS {
            bail!("points {} out of range 1..={MAX_POINTS}", self.points);
        }
        if self.targets.is_empty() {
            bail!("no target spaces selected");
        }
        if self.models.is_empty() {
            bail!("no fault models selected");
        }
        let (lo, hi) = self.window;
        if !(0.0..=1.0).contains(&lo) || !(0.0..=1.0).contains(&hi) || lo > hi {
            bail!("injection window {lo}..{hi} must satisfy 0 <= lo <= hi <= 1");
        }
        if self.watchdog_factor < 2 {
            bail!("watchdog factor {} too small (need >= 2)", self.watchdog_factor);
        }
        Ok(())
    }

    /// Parse a campaign TOML document:
    ///
    /// ```toml
    /// [campaign]
    /// workload = "mm_cpu"
    /// points = 1000
    /// seed = 0xF417
    /// targets = "sram-data,sram-code,regfile,csr,flash"  # or "all"
    /// models = "bit-flip"                                # or "all"
    /// window_lo = 0.0
    /// window_hi = 1.0
    /// watchdog_factor = 4
    /// ```
    pub fn from_toml(text: &str) -> Result<CampaignSpec> {
        let doc = Doc::parse(text)?;
        let workload = doc.str_or("campaign.workload", "mm_cpu")?;
        let mut spec = CampaignSpec {
            workload,
            points: doc.u64_or("campaign.points", 100)? as usize,
            seed: doc.u64_or("campaign.seed", 0xF417)?,
            targets: TargetSpace::parse_list(&doc.str_or("campaign.targets", "all")?)?,
            models: FaultModel::parse_list(&doc.str_or("campaign.models", "bit-flip")?)?,
            window: (
                doc.f64_or("campaign.window_lo", 0.0)?,
                doc.f64_or("campaign.window_hi", 1.0)?,
            ),
            watchdog_factor: doc.u64_or("campaign.watchdog_factor", 4)?,
        };
        spec.targets.sort_unstable();
        spec.targets.dedup();
        spec.models.sort_unstable();
        spec.models.dedup();
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_parse_back() {
        for t in TargetSpace::ALL {
            assert_eq!(TargetSpace::parse(t.name()).unwrap(), t);
        }
        for m in FaultModel::ALL {
            assert_eq!(FaultModel::parse(m.name()).unwrap(), m);
        }
        assert!(TargetSpace::parse("dram").is_err());
        assert!(FaultModel::parse("latchup").is_err());
    }

    #[test]
    fn list_parsing() {
        assert_eq!(TargetSpace::parse_list("all").unwrap(), TargetSpace::ALL.to_vec());
        assert_eq!(
            TargetSpace::parse_list("csr, flash").unwrap(),
            vec![TargetSpace::Csr, TargetSpace::Flash]
        );
        assert_eq!(FaultModel::parse_list("burst").unwrap(), vec![FaultModel::Burst]);
        assert!(TargetSpace::parse_list("").is_err());
        assert!(TargetSpace::parse_list("csr,warp").is_err());
    }

    #[test]
    fn fault_models_apply() {
        assert_eq!(FaultModel::BitFlip.apply(0b1000, 3), 0);
        assert_eq!(FaultModel::BitFlip.apply(0, 0), 1);
        assert_eq!(FaultModel::StuckAt0.apply(0xFFFF_FFFF, 31), 0x7FFF_FFFF);
        assert_eq!(FaultModel::StuckAt1.apply(0, 31), 0x8000_0000);
        // burst wraps within the word
        assert_eq!(FaultModel::Burst.apply(0, 0), 0b111);
        assert_eq!(FaultModel::Burst.apply(0, 31), 0x8000_0003);
    }

    #[test]
    fn toml_roundtrip_and_defaults() {
        let spec = CampaignSpec::from_toml(
            r#"
            [campaign]
            workload = "acquisition"
            points = 64
            seed = 0xBEEF
            targets = "sram-code,csr"
            models = "all"
            window_lo = 0.25
            window_hi = 0.75
            "#,
        )
        .unwrap();
        assert_eq!(spec.workload, "acquisition");
        assert_eq!(spec.points, 64);
        assert_eq!(spec.seed, 0xBEEF);
        assert_eq!(spec.targets, vec![TargetSpace::SramCode, TargetSpace::Csr]);
        assert_eq!(spec.models, FaultModel::ALL.to_vec());
        assert_eq!(spec.window, (0.25, 0.75));

        let defaults = CampaignSpec::from_toml("[campaign]\nworkload = \"mm_cpu\"").unwrap();
        assert_eq!(defaults.points, 100);
        assert_eq!(defaults.models, vec![FaultModel::BitFlip]);
        assert_eq!(defaults.targets, TargetSpace::ALL.to_vec());
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(CampaignSpec::new("warp_drive").is_err());
        // UART-only workload needs artifacts -- not campaignable
        assert!(CampaignSpec::new("classifier_mailbox").is_err());
        let mut spec = CampaignSpec::new("mm_cpu").unwrap();
        spec.points = 0;
        assert!(spec.validate().is_err());
        let mut spec = CampaignSpec::new("mm_cpu").unwrap();
        spec.points = MAX_POINTS + 1;
        assert!(spec.validate().is_err());
        let mut spec = CampaignSpec::new("mm_cpu").unwrap();
        spec.window = (0.8, 0.2);
        assert!(spec.validate().is_err());
        let mut spec = CampaignSpec::new("mm_cpu").unwrap();
        spec.targets.clear();
        assert!(spec.validate().is_err());
        let mut spec = CampaignSpec::new("mm_cpu").unwrap();
        spec.watchdog_factor = 1;
        assert!(spec.validate().is_err());
    }
}
