//! The reference backend: the event-driven fetch-decode-dispatch loop,
//! ported verbatim from the pre-refactor `Soc::run`.
//!
//! This is the semantic oracle every other backend is diffed against.
//! The pieces of the loop that any backend must share — the halted /
//! sleep-fast-forward handling, the single-step path, and the CS
//! hand-off checks — are factored out here so the block backend falls
//! back onto *this exact code*, not a reimplementation.

use crate::cpu::CpuState;
use crate::soc::{RunExit, Soc};

use super::{BackendKind, ExecBackend, SliceResult};

/// The reference fetch-decode-dispatch interpreter. Stateless: all
/// derived caching (the word-tagged decode cache) lives in the CPU.
#[derive(Clone, Copy, Debug, Default)]
pub struct InterpBackend;

impl ExecBackend for InterpBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Interp
    }

    fn run_slice(&mut self, soc: &mut Soc, budget: u64) -> SliceResult {
        let (start_now, start_instret) = (soc.now, soc.cpu.instret);
        let deadline = soc.now.saturating_add(budget);
        soc.refresh_irq_lines();
        let exit = loop {
            match idle_step(soc, deadline) {
                Idle::Exit(e) => break e,
                Idle::Continue => continue,
                Idle::Run => {}
            }
            if let Some(e) = single_step(soc) {
                break e;
            }
        };
        SliceResult {
            exit,
            cycles: soc.now - start_now,
            instret: soc.cpu.instret - start_instret,
        }
    }
}

/// Outcome of the shared non-running-state handling at the top of a
/// backend loop iteration.
pub(super) enum Idle {
    /// Core is running and inside budget: execute instructions.
    Run,
    /// Loop again (a sleep fast-forward advanced time).
    Continue,
    /// The slice is over.
    Exit(RunExit),
}

/// Halted / sleeping / budget handling shared by every backend: the
/// sleep path fast-forwards the clock to the next device event instead
/// of ticking idle cycles.
pub(super) fn idle_step(soc: &mut Soc, deadline: u64) -> Idle {
    match soc.cpu.state {
        CpuState::Halted(h) => return Idle::Exit(RunExit::Halted(h)),
        CpuState::Sleeping if !soc.cpu.interrupt_pending() => {
            return match soc.next_event() {
                None => Idle::Exit(RunExit::DeadSleep),
                Some(t) if t > deadline => {
                    soc.now = deadline;
                    soc.post_step();
                    Idle::Exit(RunExit::CycleBudget)
                }
                Some(t) => {
                    let before = soc.now;
                    soc.now = t.max(soc.now);
                    soc.post_step();
                    // forward-progress guard: a past-time event that
                    // neither advances the clock nor wakes the core
                    // would spin forever
                    if soc.now == before
                        && soc.cpu.state == CpuState::Sleeping
                        && !soc.cpu.interrupt_pending()
                    {
                        // step the clock one cycle and re-evaluate
                        soc.now += 1;
                    }
                    Idle::Continue
                }
            };
        }
        _ => {}
    }
    if soc.now >= deadline {
        return Idle::Exit(RunExit::CycleBudget);
    }
    Idle::Run
}

/// One interpreted instruction plus its post-step — the single-step
/// reference path both backends share.
pub(super) fn single_step(soc: &mut Soc) -> Option<RunExit> {
    let pc = soc.cpu.pc;
    let r = soc.cpu.step(&mut soc.bus, soc.now);
    soc.now += r.cycles as u64;
    // profile capture attributes *every* cycle (trap/IRQ entry too) to
    // the pc that paid it, so per-function totals conserve exactly; the
    // blocks backend records the identical stream from its replay loop
    if let Some(p) = soc.bus.profile.as_deref_mut() {
        p.record(pc, r.cycles, r.retired);
    }
    if r.retired {
        soc.stats.instructions += 1;
        // retire timestamps are post-increment (the cycle the
        // instruction completes) — the block backend records the same
        // instant, which is what keeps the streams bit-identical
        if let Some(t) = soc.bus.trace.as_deref_mut() {
            t.retire(soc.now, pc);
        }
    }
    soc.post_step();
    service_exit(soc)
}

/// CS hand-off checks (mailbox doorbell / ADC refill) after a
/// post-step.
pub(super) fn service_exit(soc: &mut Soc) -> Option<RunExit> {
    if let Some(off) = soc.bus.mailbox.take_pending() {
        soc.stats.mailbox_rings += 1;
        return Some(RunExit::MailboxRing(off));
    }
    if soc.bus.spi_adc.wants_refill() {
        return Some(RunExit::AdcRefill);
    }
    None
}
