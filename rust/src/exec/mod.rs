//! Pluggable execution backends: the API that owns the run loop.
//!
//! The paper's premise is that emulation speed and trustworthy
//! perf/energy numbers must coexist. This module is the seam that makes
//! that safe: every way of advancing the guest lives behind
//! [`ExecBackend`], the SoC delegates [`crate::soc::Soc::run`] to the
//! configured backend, and the *semantics* stay centralized — all
//! backends execute instructions through the one
//! `Cpu::exec_decoded` path, so speed work can never fork the model.
//!
//! Two backends ship:
//!
//! * [`interp`] — the reference fetch-decode-dispatch interpreter, the
//!   verbatim event loop the SoC always had.
//! * [`blocks`] — basic-block superinstructions: decode once per block,
//!   replay with fused accounting, invalidate on self-modifying writes
//!   via the SRAM page write generations ([`crate::mem`]).
//!
//! The bit-identity contract (every backend produces the same retired
//! instruction stream, cycle counts, perf counters, and snapshot bytes)
//! is enforced, not assumed: [`diff`] runs workloads on two backends in
//! lockstep and `femu diff` / the `backend_differential` tests gate it
//! (DESIGN.md §11).

pub mod blocks;
pub mod diff;
pub mod interp;

pub use blocks::BlockBackend;
pub use interp::InterpBackend;

use anyhow::bail;

use crate::soc::{RunExit, Soc};

/// Which execution engine drives the core. Selectable per platform
/// (config `backend`), per CLI invocation (`--backend`), and per server
/// session (`session.open` `backend` field).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// The reference interpreter ([`InterpBackend`]).
    #[default]
    Interp,
    /// Block-compiled superinstructions ([`BlockBackend`]): same
    /// numbers, more guest MIPS.
    Blocks,
}

impl BackendKind {
    /// Parse a user-facing backend name (CLI / config / protocol).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "interp" => Ok(Self::Interp),
            "blocks" => Ok(Self::Blocks),
            other => bail!("unknown backend `{other}` (want interp|blocks)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Interp => "interp",
            Self::Blocks => "blocks",
        }
    }

    /// Instantiate a fresh backend of this kind.
    pub fn create(self) -> Box<dyn ExecBackend> {
        match self {
            Self::Interp => Box::new(InterpBackend),
            Self::Blocks => Box::<BlockBackend>::default(),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Accounting for one [`ExecBackend::run_slice`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SliceResult {
    /// Why the slice ended.
    pub exit: RunExit,
    /// Cycles consumed by the slice (including sleep fast-forwards).
    pub cycles: u64,
    /// Instructions retired by the slice.
    pub instret: u64,
}

/// Backend-internal counters (all zero for the stateless interpreter).
/// These are diagnostics, not architectural state: the self-modifying
/// code tests use them to observe block re-decodes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Compiled blocks dispatched on the fast path.
    pub block_dispatches: u64,
    /// Blocks (re)compiled — a rebuild after a self-modifying write
    /// shows up as a second build of the same entry pc.
    pub blocks_built: u64,
    /// Cached blocks dropped on a write-generation mismatch.
    pub block_invalidations: u64,
    /// Instructions executed through the single-step reference path.
    pub slow_steps: u64,
    /// Sum of the worst-case cycle bounds of every dispatched block.
    pub bounded_cycles: u64,
    /// Cycles actually consumed inside dispatched blocks. The static
    /// WCET contract is `block_cycles <= bounded_cycles`, always — the
    /// bounds-vs-reality tests assert it after real runs.
    pub block_cycles: u64,
}

/// One compiled (or statically recovered) basic block, as exported by
/// [`ExecBackend::block_map`] and by the analyzer's CFG — the common
/// currency of the precompile handshake (DESIGN.md §12).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct BlockInfo {
    /// Entry pc.
    pub pc: u32,
    /// Instructions in the block.
    pub len: u32,
    /// Worst-case cycles the whole block can consume.
    pub max_cycles: u64,
}

/// The execution API. A backend owns the run loop: it advances the
/// core, the clock, and the instruction count, and returns at exactly
/// the same architectural points the reference interpreter would
/// (halt, CS hand-off, budget).
///
/// Contract (enforced by `femu diff`): for any guest and any slice
/// budgets, every backend must produce bit-identical architectural
/// state, cycle counts, perf counters, and retired-instruction streams.
/// Backends may hold *derived* state only (decode caches, compiled
/// blocks) — nothing a snapshot needs to capture, which is why interp
/// and block snapshots of the same execution are byte-comparable.
pub trait ExecBackend: Send {
    fn kind(&self) -> BackendKind;

    /// Run until halt, a CS hand-off, or `budget` cycles elapse.
    fn run_slice(&mut self, soc: &mut Soc, budget: u64) -> SliceResult;

    /// Snapshot-save hook. Backends hold no architectural state, so the
    /// default does nothing; it exists so an exotic backend could flush
    /// lazily-materialized architectural effects before serialization.
    fn save_hook(&self) {}

    /// Snapshot-restore / reprogram hook: the memory image under the
    /// backend may have changed arbitrarily — derived caches must go.
    fn restore_hook(&mut self) {}

    /// Internal counters for diagnostics and tests.
    fn exec_stats(&self) -> ExecStats {
        ExecStats::default()
    }

    /// Warm derived caches for the given block-entry pcs (produced by
    /// the static analyzer, [`crate::analyze`]) before execution
    /// starts. Purely an optimization hook: a backend that ignores it
    /// is still correct, because precompiled state is *derived* state —
    /// the bit-identity contract is unaffected (only `exec_stats`
    /// change). The default does nothing (the interpreter has no
    /// caches).
    fn precompile(&mut self, soc: &Soc, entries: &[u32]) {
        let _ = (soc, entries);
    }

    /// The backend's current derived block view, for comparison against
    /// the analyzer's statically recovered CFG. Backends without block
    /// caches return an empty map.
    fn block_map(&self) -> Vec<BlockInfo> {
        Vec::new()
    }
}
