//! Lockstep differential validation: run the same workload on two
//! execution backends and prove — not assume — that they are
//! bit-identical.
//!
//! [`lockstep`] advances two [`Platform`]s in `checkpoint_cycles`
//! slices and, at every checkpoint, compares:
//!
//! * the exit reason and the cycle clock,
//! * the retired-instruction counter and the rolling
//!   [`RetireTrace`](crate::cpu::RetireTrace) digest of the retired pc
//!   stream (armed on both CPUs for the duration of the diff),
//! * the **full snapshot payload bytes** — which subsumes registers,
//!   CSRs, every memory bank, every peripheral, the perf counters, and
//!   the energy-relevant power-state residencies in one comparison.
//!
//! The first mismatch is reported as a [`Divergence`] with enough
//! context (checkpoint, cycle, recent pcs) to bisect. On top of the
//! single-workload driver, [`lockstep_workloads`] fans a standard
//! suite across a [`Fleet`], and [`diff_experiments`] re-runs the
//! paper's §V experiments (fig4 / fig5 / case C) once per backend —
//! reusing the experiment drivers' own forked sweeps — and compares
//! every published number bit-for-bit. `femu diff` is a thin CLI over
//! these (DESIGN.md §11).

use anyhow::{bail, Result};

use crate::config::PlatformConfig;
use crate::coordinator::experiments;
use crate::coordinator::{AppExit, Fleet, Platform};
use crate::workloads::programs;

use super::BackendKind;

/// Knobs for a [`lockstep`] run.
#[derive(Clone, Copy, Debug)]
pub struct LockstepOptions {
    /// Compare state every this many guest cycles.
    pub checkpoint_cycles: u64,
    /// Give up (as an error, not a divergence) if the workload has not
    /// halted after this many cycles.
    pub max_cycles: u64,
    /// When non-zero (`femu diff --trace`), arm a full event ring
    /// ([`crate::trace`]) with these categories on both platforms: the
    /// checkpoints additionally compare ring digests, and a divergence
    /// report carries both sides' serialized captures.
    pub trace_mask: u8,
}

impl Default for LockstepOptions {
    fn default() -> Self {
        Self { checkpoint_cycles: 100_000, max_cycles: 1 << 32, trace_mask: 0 }
    }
}

/// The first point where two backends disagreed.
#[derive(Clone, Debug)]
pub struct Divergence {
    /// Checkpoint index (1-based) at which the mismatch surfaced.
    pub checkpoint: u64,
    /// Backend A's cycle clock at that checkpoint.
    pub cycle: u64,
    /// Human-readable description of what differed.
    pub what: String,
    /// Serialized `FEMUTRAC` captures from each side at the divergence
    /// point, present when the diff ran with tracing enabled
    /// ([`LockstepOptions::trace_mask`]) — the CLI writes them next to
    /// the report so CI can upload them as failure artifacts.
    pub trace_a: Option<Vec<u8>>,
    pub trace_b: Option<Vec<u8>>,
}

/// Outcome of one lockstep diff.
#[derive(Clone, Debug)]
pub struct LockstepReport {
    pub workload: String,
    pub backend_a: BackendKind,
    pub backend_b: BackendKind,
    /// Checkpoints compared (including the final one).
    pub checkpoints: u64,
    /// Guest cycles covered.
    pub cycles: u64,
    /// Instructions retired (backend A's count).
    pub instret: u64,
    /// `None` means bit-identical at every checkpoint.
    pub divergence: Option<Divergence>,
}

impl LockstepReport {
    pub fn matched(&self) -> bool {
        self.divergence.is_none()
    }
}

impl std::fmt::Display for LockstepReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.divergence {
            None => write!(
                f,
                "{}: {} == {} over {} cycles / {} instret ({} checkpoints)",
                self.workload,
                self.backend_a,
                self.backend_b,
                self.cycles,
                self.instret,
                self.checkpoints,
            ),
            Some(d) => write!(
                f,
                "{}: {} != {} at checkpoint {} (cycle {}): {}",
                self.workload, self.backend_a, self.backend_b, d.checkpoint, d.cycle, d.what,
            ),
        }
    }
}

/// Build two platforms from the same config, differing only in the
/// configured execution backend.
pub fn platform_pair(
    cfg: &PlatformConfig,
    a: BackendKind,
    b: BackendKind,
) -> (Platform, Platform) {
    let mut cfg_a = cfg.clone();
    cfg_a.soc.backend = a;
    let mut cfg_b = cfg.clone();
    cfg_b.soc.backend = b;
    (Platform::new(cfg_a), Platform::new(cfg_b))
}

/// Advance `a` and `b` in lockstep slices and compare at every
/// checkpoint. The platforms must be identically prepared (same guest,
/// same datasets/services); the backends under test are whatever each
/// platform was configured with.
pub fn lockstep(
    workload: &str,
    a: &mut Platform,
    b: &mut Platform,
    opts: &LockstepOptions,
) -> Result<LockstepReport> {
    // arm the retired-pc digests for the duration of the diff
    a.dbg.soc.cpu.trace = Some(Box::default());
    b.dbg.soc.cpu.trace = Some(Box::default());
    // optionally arm full event rings (femu diff --trace): checkpoints
    // then also compare ring digests, and a divergence carries captures
    if opts.trace_mask != 0 {
        let tcfg =
            crate::trace::TraceConfig { mask: opts.trace_mask, ..crate::trace::TraceConfig::default() };
        a.dbg.soc.set_trace(tcfg);
        b.dbg.soc.set_trace(tcfg);
    }

    let start = a.dbg.soc.now;
    let start_instret = a.dbg.soc.cpu.instret;
    let mut checkpoints = 0u64;
    let mut divergence = None;
    loop {
        let ra = a.run_app(opts.checkpoint_cycles);
        let rb = b.run_app(opts.checkpoint_cycles);
        checkpoints += 1;
        let (xa, xb) = match (ra, rb) {
            (Ok(xa), Ok(xb)) => (xa, xb),
            (Err(ea), Err(eb)) => {
                let (ea, eb) = (format!("{ea:#}"), format!("{eb:#}"));
                if ea == eb {
                    // identical failure on both sides: the workload is
                    // broken, not the backends — inconclusive
                    bail!("workload failed identically on both backends: {ea}");
                }
                divergence = Some(Divergence {
                    checkpoint: checkpoints,
                    cycle: a.dbg.soc.now,
                    what: format!("errors differ: a: {ea}; b: {eb}"),
                    trace_a: None,
                    trace_b: None,
                });
                break;
            }
            (ra, rb) => {
                let describe = |r: &Result<AppExit>| match r {
                    Ok(x) => format!("{x:?}"),
                    Err(e) => format!("error: {e:#}"),
                };
                divergence = Some(Divergence {
                    checkpoint: checkpoints,
                    cycle: a.dbg.soc.now,
                    what: format!("a {} vs b {}", describe(&ra), describe(&rb)),
                    trace_a: None,
                    trace_b: None,
                });
                break;
            }
        };
        if let Some(what) = compare_checkpoint(a, b, xa, xb) {
            divergence = Some(Divergence {
                checkpoint: checkpoints,
                cycle: a.dbg.soc.now,
                what,
                trace_a: None,
                trace_b: None,
            });
            break;
        }
        if matches!(xa, AppExit::Halted(_)) {
            break;
        }
        if a.dbg.soc.now - start >= opts.max_cycles {
            bail!(
                "workload `{workload}` did not halt within {} cycles (no divergence found)",
                opts.max_cycles
            );
        }
    }

    if let Some(d) = &mut divergence {
        let capture = |p: &Platform| {
            p.dbg.soc.trace_ring().map(|t| {
                let banks = p.dbg.soc.bus.banks.len() as u32;
                crate::trace::format::TraceDump::from_ring(t, p.dbg.soc.freq_hz, banks).to_bytes()
            })
        };
        d.trace_a = capture(a);
        d.trace_b = capture(b);
    }

    let report = LockstepReport {
        workload: workload.to_string(),
        backend_a: a.dbg.soc.backend_kind(),
        backend_b: b.dbg.soc.backend_kind(),
        checkpoints,
        cycles: a.dbg.soc.now - start,
        instret: a.dbg.soc.cpu.instret - start_instret,
        divergence,
    };
    // disarm: leave the platforms as we found them
    a.dbg.soc.cpu.trace = None;
    b.dbg.soc.cpu.trace = None;
    a.dbg.soc.take_trace();
    b.dbg.soc.take_trace();
    Ok(report)
}

/// Compare everything observable at a checkpoint; `None` == identical.
fn compare_checkpoint(a: &Platform, b: &Platform, xa: AppExit, xb: AppExit) -> Option<String> {
    if xa != xb {
        return Some(format!("exit {xa:?} vs {xb:?}"));
    }
    let (sa, sb) = (&a.dbg.soc, &b.dbg.soc);
    if sa.now != sb.now {
        return Some(format!("cycle clock {} vs {}", sa.now, sb.now));
    }
    if sa.cpu.instret != sb.cpu.instret {
        return Some(format!("instret {} vs {}", sa.cpu.instret, sb.cpu.instret));
    }
    if sa.cpu.trace != sb.cpu.trace {
        let recent = |s: &crate::soc::Soc| {
            s.cpu
                .trace
                .as_ref()
                .map(|t| {
                    t.recent_pcs().iter().map(|pc| format!("{pc:#x}")).collect::<Vec<_>>().join(",")
                })
                .unwrap_or_default()
        };
        return Some(format!(
            "retired-pc stream diverged (recent a: [{}], b: [{}])",
            recent(sa),
            recent(sb)
        ));
    }
    // full event rings, when armed: the digest covers every event ever
    // pushed (wraparound included), so equal digests + totals mean the
    // two backends emitted the exact same event stream
    if let (Some(ta), Some(tb)) = (sa.trace_ring(), sb.trace_ring()) {
        if ta.digest() != tb.digest() || ta.total() != tb.total() {
            return Some(format!(
                "trace streams diverged (a: {} events, digest {:#018x}; b: {} events, digest {:#018x})",
                ta.total(),
                ta.digest(),
                tb.total(),
                tb.digest()
            ));
        }
    }
    // the big hammer: full snapshot payloads, byte for byte — covers
    // registers, CSRs, memories, peripherals, perf counters, energy
    // residencies. Traces are not serialized, so arming them above did
    // not perturb this comparison.
    let (pa, pb) = (a.snapshot(), b.snapshot());
    let (ba, bb) = (pa.payload(), pb.payload());
    if ba != bb {
        let at = ba.iter().zip(bb.iter()).position(|(x, y)| x != y);
        return Some(match at {
            Some(i) => format!(
                "snapshot payloads differ at byte {i} of {}/{} ({:#04x} vs {:#04x})",
                ba.len(),
                bb.len(),
                ba[i],
                bb[i]
            ),
            None => format!("snapshot payload lengths differ ({} vs {})", ba.len(), bb.len()),
        });
    }
    None
}

// =====================================================================
// Workload suite
// =====================================================================

/// The standard lockstep suite: a dense compute kernel, a
/// control/memory-heavy kernel, an interrupt-and-sleep acquisition
/// loop, and a self-modifying patch loop — together they cross every
/// fast-path boundary the block backend has (device access, WFI,
/// interrupts, write-generation invalidation).
pub const LOCKSTEP_WORKLOADS: [&str; 4] = ["mm_cpu", "fft_cpu", "acquisition", "smc_patch"];

/// A guest that rewrites one of its own instructions between two passes
/// over the same loop: pass 1 runs `addi s0, s0, 1`, then the patcher
/// stores the pre-assembled encoding of `addi s0, s0, 8` over it and
/// runs the loop again. Any stale decoded state (icache word tags,
/// compiled blocks) yields the wrong s0.
pub fn smc_patch_source() -> String {
    format!(
        r#"{prelude}
_start:
    li   s0, 0
    li   s1, 2          # two passes
pass:
loop_head:
    addi s0, s0, 1      # patched to `addi s0, s0, 8` after pass 1
    addi s1, s1, -1
    beqz s1, done
    # patch: overwrite loop_head with the replacement encoding
    la   t0, loop_head
    la   t1, patch_word
    lw   t2, 0(t1)
    sw   t2, 0(t0)
    j    pass
done:
    mv   a0, s0         # expect 1 + 8 = 9
    ebreak
.data
patch_word:
    .word 0x00840413    # addi s0, s0, 8
"#,
        prelude = programs::PRELUDE,
    )
}

/// Load + service setup for one named suite workload.
fn prepare(p: &mut Platform, workload: &str) -> Result<()> {
    match workload {
        "mm_cpu" => {
            p.dbg.load_source(&programs::mm_cpu(16, 8, 4))?;
        }
        "fft_cpu" => {
            p.dbg.load_source(&programs::fft_cpu(64))?;
        }
        "acquisition" => {
            p.dbg.load_source(&programs::acquisition(400, 0))?;
            p.start_adc((0..400).collect(), 100_000.0);
        }
        "smc_patch" => {
            p.dbg.load_source(&smc_patch_source())?;
        }
        other => bail!("unknown lockstep workload `{other}`"),
    }
    Ok(())
}

/// [`lockstep`] an arbitrary assembly source on a fresh platform pair
/// (the `femu diff <prog.s>` path).
pub fn lockstep_source(
    cfg: &PlatformConfig,
    name: &str,
    source: &str,
    a: BackendKind,
    b: BackendKind,
    opts: &LockstepOptions,
) -> Result<LockstepReport> {
    let (mut pa, mut pb) = platform_pair(cfg, a, b);
    pa.dbg.load_source(source)?;
    pb.dbg.load_source(source)?;
    lockstep(name, &mut pa, &mut pb, opts)
}

/// [`lockstep`] one named suite workload on a fresh platform pair.
pub fn lockstep_workload(
    cfg: &PlatformConfig,
    workload: &str,
    a: BackendKind,
    b: BackendKind,
    opts: &LockstepOptions,
) -> Result<LockstepReport> {
    let (mut pa, mut pb) = platform_pair(cfg, a, b);
    prepare(&mut pa, workload)?;
    prepare(&mut pb, workload)?;
    lockstep(workload, &mut pa, &mut pb, opts)
}

/// Statically analyze `p`'s loaded memory and warm its block cache with
/// the recovered block map ([`crate::analyze`] feeding
/// [`crate::soc::Soc::precompile`]). Returns how many entries were
/// offered to the backend.
fn precompile_from_analysis(p: &mut Platform, cfg: &PlatformConfig, name: &str) -> usize {
    let acfg = crate::analyze::AnalyzeConfig::from_platform(cfg);
    let report = crate::analyze::analyze_soc(&p.dbg.soc, name, &acfg);
    let entries = report.block_entries();
    p.dbg.soc.precompile(&entries);
    entries.len()
}

/// The `femu diff --precompile` proof: run a workload on two *blocks*
/// platforms, one cold and one with its cache precompiled from the
/// static analyzer's block map, and show the warm-up is architecturally
/// invisible — precompiled blocks are derived state, so every
/// checkpoint (exits, clocks, retired streams, full snapshot payloads)
/// must stay bit-identical.
pub fn lockstep_workload_precompiled(
    cfg: &PlatformConfig,
    workload: &str,
    opts: &LockstepOptions,
) -> Result<LockstepReport> {
    let (mut pa, mut pb) = platform_pair(cfg, BackendKind::Blocks, BackendKind::Blocks);
    prepare(&mut pa, workload)?;
    prepare(&mut pb, workload)?;
    precompile_from_analysis(&mut pb, cfg, workload);
    let mut r = lockstep(workload, &mut pa, &mut pb, opts)?;
    r.workload = format!("{workload}+precompile");
    Ok(r)
}

/// Cold-vs-precompiled diff of an arbitrary assembly source (the
/// `femu diff <prog.s> --precompile` path).
pub fn lockstep_source_precompiled(
    cfg: &PlatformConfig,
    name: &str,
    source: &str,
    opts: &LockstepOptions,
) -> Result<LockstepReport> {
    let (mut pa, mut pb) = platform_pair(cfg, BackendKind::Blocks, BackendKind::Blocks);
    pa.dbg.load_source(source)?;
    pb.dbg.load_source(source)?;
    precompile_from_analysis(&mut pb, cfg, name);
    let mut r = lockstep(name, &mut pa, &mut pb, opts)?;
    r.workload = format!("{name}+precompile");
    Ok(r)
}

/// The whole suite cold-vs-precompiled, one fleet point per workload.
pub fn lockstep_workloads_precompiled(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    opts: &LockstepOptions,
) -> Result<Vec<LockstepReport>> {
    let opts = *opts;
    fleet.run_sweep(cfg, 0xD1FF, LOCKSTEP_WORKLOADS.to_vec(), |cfg, workload, _seed| {
        Ok(vec![lockstep_workload_precompiled(cfg, workload, &opts)?])
    })
}

/// The whole suite, one fleet point per workload (reports in suite
/// order regardless of worker count).
pub fn lockstep_workloads(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    a: BackendKind,
    b: BackendKind,
    opts: &LockstepOptions,
) -> Result<Vec<LockstepReport>> {
    let opts = *opts;
    fleet.run_sweep(cfg, 0xD1FF, LOCKSTEP_WORKLOADS.to_vec(), |cfg, workload, _seed| {
        Ok(vec![lockstep_workload(cfg, workload, a, b, &opts)?])
    })
}

// =====================================================================
// Experiment-level diff
// =====================================================================

/// Bitwise comparison of one §V experiment run per-backend.
#[derive(Clone, Debug)]
pub struct ExperimentDiff {
    pub experiment: String,
    /// Result points compared.
    pub points: usize,
    /// One line per differing field; empty == bit-identical.
    pub mismatches: Vec<String>,
}

impl ExperimentDiff {
    pub fn matched(&self) -> bool {
        self.mismatches.is_empty()
    }
}

/// Push a mismatch line unless the two floats are bit-identical
/// (`to_bits`: the experiments' determinism contract is exact, not
/// approximate, so no epsilon).
fn diff_f64(ms: &mut Vec<String>, ctx: &str, field: &str, x: f64, y: f64) {
    if x.to_bits() != y.to_bits() {
        ms.push(format!("{ctx}: {field} {x} != {y}"));
    }
}

fn diff_eq<T: PartialEq + std::fmt::Debug>(
    ms: &mut Vec<String>,
    ctx: &str,
    field: &str,
    x: &T,
    y: &T,
) {
    if x != y {
        ms.push(format!("{ctx}: {field} {x:?} != {y:?}"));
    }
}

/// Run fig4 / fig5 / case C once per backend — through the experiment
/// drivers' own forked sweeps ([`Fleet::run_sweep_forked`] underneath)
/// — and compare every published number bit-for-bit. `window_s` and
/// `scale` shrink fig4 / case C exactly like the benches do.
pub fn diff_experiments(
    fleet: &Fleet,
    cfg: &PlatformConfig,
    a: BackendKind,
    b: BackendKind,
    window_s: f64,
    scale: usize,
) -> Result<Vec<ExperimentDiff>> {
    let mut cfg_a = cfg.clone();
    cfg_a.soc.backend = a;
    let mut cfg_b = cfg.clone();
    cfg_b.soc.backend = b;
    let mut out = Vec::new();

    let fa = experiments::fig4_sweep(fleet, &cfg_a, window_s, 0xF16_4)?;
    let fb = experiments::fig4_sweep(fleet, &cfg_b, window_s, 0xF16_4)?;
    let mut ms = Vec::new();
    diff_eq(&mut ms, "fig4", "point count", &fa.len(), &fb.len());
    for (i, (x, y)) in fa.iter().zip(&fb).enumerate() {
        let ctx = format!("fig4[{i}]");
        diff_eq(&mut ms, &ctx, "model", &x.model, &y.model);
        diff_f64(&mut ms, &ctx, "sample_rate_hz", x.sample_rate_hz, y.sample_rate_hz);
        diff_f64(&mut ms, &ctx, "total_s", x.total_s, y.total_s);
        diff_f64(&mut ms, &ctx, "active_s", x.active_s, y.active_s);
        diff_f64(&mut ms, &ctx, "sleep_s", x.sleep_s, y.sleep_s);
        diff_f64(&mut ms, &ctx, "active_mj", x.active_mj, y.active_mj);
        diff_f64(&mut ms, &ctx, "sleep_mj", x.sleep_mj, y.sleep_mj);
        diff_f64(&mut ms, &ctx, "total_mj", x.total_mj, y.total_mj);
    }
    out.push(ExperimentDiff { experiment: "fig4".into(), points: fa.len(), mismatches: ms });

    let fa = experiments::fig5_all(fleet, &cfg_a, 0xF15)?;
    let fb = experiments::fig5_all(fleet, &cfg_b, 0xF15)?;
    let mut ms = Vec::new();
    diff_eq(&mut ms, "fig5", "point count", &fa.len(), &fb.len());
    for (i, (x, y)) in fa.iter().zip(&fb).enumerate() {
        let ctx = format!("fig5[{i}]");
        diff_eq(&mut ms, &ctx, "kernel", &x.kernel, &y.kernel);
        diff_eq(&mut ms, &ctx, "implementation", &x.implementation, &y.implementation);
        diff_eq(&mut ms, &ctx, "model", &x.model, &y.model);
        diff_eq(&mut ms, &ctx, "cycles", &x.cycles, &y.cycles);
        diff_f64(&mut ms, &ctx, "time_s", x.time_s, y.time_s);
        diff_f64(&mut ms, &ctx, "energy_mj", x.energy_mj, y.energy_mj);
        diff_eq(&mut ms, &ctx, "validated", &x.validated, &y.validated);
    }
    out.push(ExperimentDiff { experiment: "fig5".into(), points: fa.len(), mismatches: ms });

    let ca = experiments::case_c(fleet, &cfg_a, scale)?;
    let cb = experiments::case_c(fleet, &cfg_b, scale)?;
    let mut ms = Vec::new();
    diff_eq(&mut ms, "case_c", "windows", &ca.windows, &cb.windows);
    diff_eq(&mut ms, "case_c", "samples_per_window", &ca.samples_per_window, &cb.samples_per_window);
    diff_f64(&mut ms, "case_c", "virt_window_s", ca.virt_window_s, cb.virt_window_s);
    diff_f64(&mut ms, "case_c", "phys_window_s", ca.phys_window_s, cb.phys_window_s);
    diff_f64(&mut ms, "case_c", "virt_total_s", ca.virt_total_s, cb.virt_total_s);
    diff_f64(&mut ms, "case_c", "phys_total_s", ca.phys_total_s, cb.phys_total_s);
    diff_f64(&mut ms, "case_c", "speedup", ca.speedup, cb.speedup);
    out.push(ExperimentDiff { experiment: "case_c".into(), points: 2, mismatches: ms });

    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lockstep_trivially_matches_itself() {
        let cfg = PlatformConfig::default();
        let r = lockstep_workload(
            &cfg,
            "mm_cpu",
            BackendKind::Interp,
            BackendKind::Interp,
            &LockstepOptions::default(),
        )
        .unwrap();
        assert!(r.matched(), "{r}");
        assert!(r.instret > 0);
    }

    #[test]
    fn lockstep_flags_different_programs() {
        // different guests: the retired streams must diverge, and the
        // driver must say so instead of erroring
        let cfg = PlatformConfig::default();
        let (mut a, mut b) = platform_pair(&cfg, BackendKind::Interp, BackendKind::Interp);
        a.dbg.load_source("_start: li a0, 1\n li a1, 2\nebreak").unwrap();
        b.dbg.load_source("_start: li a0, 1\n li a1, 3\nebreak").unwrap();
        let r = lockstep("mismatch", &mut a, &mut b, &LockstepOptions::default()).unwrap();
        assert!(!r.matched());
    }

    #[test]
    fn unknown_workload_is_an_error() {
        let cfg = PlatformConfig::default();
        let err = lockstep_workload(
            &cfg,
            "nope",
            BackendKind::Interp,
            BackendKind::Blocks,
            &LockstepOptions::default(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("unknown lockstep workload"));
    }
}
