//! Block-compiled backend: basic-block superinstructions.
//!
//! Extends the CPU's word-tagged decode cache one level up: instead of
//! caching one decoded instruction per word, cache a straight-line run
//! of decoded instructions per *entry pc* and replay it without
//! per-instruction fetch, decode, event polling, or interrupt checks.
//!
//! ## Why this is exact, not approximate
//!
//! A block is only dispatched when (all checked at dispatch time):
//!
//! * the core is `Running` and no enabled interrupt is ready
//!   ([`crate::cpu::Cpu::irq_ready`]) — interrupt entry always takes
//!   the single-step reference path;
//! * the block's worst-case cycle bound fits inside the slice budget —
//!   budget exits happen at exactly the reference boundaries;
//! * the bound also ends strictly before the SoC event horizon
//!   ([`crate::soc::Soc::event_horizon`]) — no device event, timer
//!   comparator, or CGRA completion can become due mid-block, which is
//!   precisely the invariant that makes the skipped per-instruction
//!   `post_step` calls no-ops (the same invariant the sleep
//!   fast-forward has always relied on);
//! * the SRAM page the block was decoded from is powered and its write
//!   generation ([`crate::mem::GEN_PAGE_SHIFT`]) is unchanged — any
//!   store, DMA/CGRA write, bulk load, power-gate poison, or snapshot
//!   restore bumps the generation and forces a re-decode: the
//!   self-modifying-code hook.
//!
//! During replay the block bails back to the reference path before any
//! load/store that leaves SRAM (device reads are side-effecting and
//! waits differ), after any trap / WFI / halt, and after any store into
//! the block's own page (the remaining pre-decoded instructions could
//! be stale). Every instruction executes through the shared
//! `Cpu::exec_decoded` with the true running cycle count, and SRAM
//! fetches are zero-wait, so cycles, registers, memory, and the retired
//! stream come out bit-identical to the interpreter — `femu diff` and
//! the `backend_differential` tests hold that line.

use crate::cpu::{CpuState, Timing};
use crate::isa::{self, Instr};
use crate::mem::GEN_PAGE_SHIFT;
use crate::perfmon::PowerState;
use crate::soc::{RunExit, Soc};

use super::interp::{idle_step, service_exit, single_step, Idle};
use super::{BackendKind, BlockInfo, ExecBackend, ExecStats, SliceResult};

/// Direct-mapped block-cache capacity (entry-pc slots).
const BLOCK_SLOTS: usize = 1 << 14;

/// Upper bound on instructions per block (blocks are also cut at
/// write-generation page boundaries so each maps to exactly one page).
/// Shared with the static analyzer so its recovered CFG cuts blocks at
/// exactly the pcs this backend does.
pub(crate) const MAX_BLOCK_LEN: usize = 64;

/// One compiled basic block: straight-line decoded instructions up to
/// and including the first control transfer (or anything that can
/// retarget the pc or unmask interrupts).
struct Block {
    /// Entry pc — the cache tag.
    pc: u32,
    /// SRAM location the block was decoded from.
    bank: usize,
    page: usize,
    /// The page's write generation at decode time.
    gen: u64,
    /// Worst-case cycles the whole block can consume (sum of
    /// per-instruction maxima, traps included).
    max_cycles: u64,
    /// Pre-decoded instructions with their raw words.
    body: Vec<(Instr, u32)>,
}

/// The block-compiled execution backend.
pub struct BlockBackend {
    blocks: Vec<Option<Box<Block>>>,
    stats: ExecStats,
}

impl Default for BlockBackend {
    fn default() -> Self {
        Self { blocks: (0..BLOCK_SLOTS).map(|_| None).collect(), stats: ExecStats::default() }
    }
}

enum Dispatch {
    /// A block ran (post-step included); exit the slice if `Some`.
    Ran(Option<RunExit>),
    /// No dispatchable block here: single-step this instruction.
    Fallback,
}

impl ExecBackend for BlockBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Blocks
    }

    fn run_slice(&mut self, soc: &mut Soc, budget: u64) -> SliceResult {
        let (start_now, start_instret) = (soc.now, soc.cpu.instret);
        let deadline = soc.now.saturating_add(budget);
        soc.refresh_irq_lines();
        let exit = loop {
            match idle_step(soc, deadline) {
                Idle::Exit(e) => break e,
                Idle::Continue => continue,
                Idle::Run => {}
            }
            match self.try_block(soc, deadline) {
                Dispatch::Ran(Some(e)) => break e,
                Dispatch::Ran(None) => continue,
                Dispatch::Fallback => {}
            }
            self.stats.slow_steps += 1;
            if let Some(e) = single_step(soc) {
                break e;
            }
        };
        SliceResult {
            exit,
            cycles: soc.now - start_now,
            instret: soc.cpu.instret - start_instret,
        }
    }

    fn restore_hook(&mut self) {
        for b in &mut self.blocks {
            *b = None;
        }
    }

    fn exec_stats(&self) -> ExecStats {
        self.stats
    }

    /// Warm the block cache from statically recovered entry pcs
    /// ([`crate::analyze`] exports them). Entries that don't decode to
    /// at least one instruction, live outside powered SRAM, or lose a
    /// direct-mapped slot conflict are skipped — the on-demand path
    /// still handles them, so this can only ever *reduce* warm-up work,
    /// never change results.
    fn precompile(&mut self, soc: &Soc, entries: &[u32]) {
        for &pc in entries {
            let Some(bank) = soc.bus.bank_index(pc) else { continue };
            match soc.bus.banks[bank].state() {
                PowerState::Active | PowerState::ClockGated => {}
                _ => continue,
            }
            let slot = Self::slot(pc);
            if self.blocks[slot].is_some() {
                // already warmed, or a direct-mapped conflict: first
                // entry wins, the loser warms on demand
                continue;
            }
            let off = soc.bus.bank_offset(pc);
            let page = off >> GEN_PAGE_SHIFT;
            let gen = soc.bus.banks[bank].page_gen(off);
            if let Some(b) = build_block(soc, pc, bank, page, gen) {
                self.blocks[slot] = Some(Box::new(b));
                self.stats.blocks_built += 1;
            }
        }
    }

    fn block_map(&self) -> Vec<BlockInfo> {
        let mut map: Vec<BlockInfo> = self
            .blocks
            .iter()
            .flatten()
            .map(|b| BlockInfo { pc: b.pc, len: b.body.len() as u32, max_cycles: b.max_cycles })
            .collect();
        map.sort();
        map
    }
}

impl BlockBackend {
    #[inline]
    fn slot(pc: u32) -> usize {
        (pc as usize >> 2) & (BLOCK_SLOTS - 1)
    }

    /// Validate-or-build the block at the current pc, then run it if
    /// its worst-case bound fits the budget and the event horizon.
    fn try_block(&mut self, soc: &mut Soc, deadline: u64) -> Dispatch {
        if soc.cpu.irq_ready() {
            return Dispatch::Fallback;
        }
        let pc = soc.cpu.pc;
        let Some(bank) = soc.bus.bank_index(pc) else {
            return Dispatch::Fallback;
        };
        match soc.bus.banks[bank].state() {
            PowerState::Active | PowerState::ClockGated => {}
            // fetch would fault — let the reference path take the trap
            _ => return Dispatch::Fallback,
        }
        let off = soc.bus.bank_offset(pc);
        let page = off >> GEN_PAGE_SHIFT;
        let gen = soc.bus.banks[bank].page_gen(off);

        let slot = Self::slot(pc);
        let cached = match &self.blocks[slot] {
            Some(b) if b.pc == pc => {
                if b.gen == gen {
                    true
                } else {
                    // the page was written since decode: re-decode
                    self.stats.block_invalidations += 1;
                    false
                }
            }
            _ => false,
        };
        if !cached {
            match build_block(soc, pc, bank, page, gen) {
                Some(b) => {
                    self.blocks[slot] = Some(Box::new(b));
                    self.stats.blocks_built += 1;
                }
                None => {
                    self.blocks[slot] = None;
                    return Dispatch::Fallback;
                }
            }
        }
        let block = self.blocks[slot].as_deref().expect("block just validated");
        let bound = soc.now.saturating_add(block.max_cycles);
        if bound > deadline || bound >= soc.event_horizon() {
            return Dispatch::Fallback;
        }
        // forward-progress guard: a block whose *first* instruction is
        // a device (non-SRAM) access would bail out of the replay loop
        // before executing anything — dispatching it makes zero
        // progress, and `Ran(None)` would re-dispatch it forever. Let
        // the reference path execute it instead.
        if let Some(&(instr, _)) = block.body.first() {
            if let Instr::Load { rs1, imm, .. } | Instr::Store { rs1, imm, .. } = instr {
                let addr = soc.cpu.regs[rs1 as usize].wrapping_add(imm as u32);
                if soc.bus.bank_index(addr).is_none() {
                    return Dispatch::Fallback;
                }
            }
        }
        self.stats.block_dispatches += 1;
        self.stats.bounded_cycles += block.max_cycles;
        Dispatch::Ran(exec_block(soc, block, &mut self.stats))
    }
}

/// Replay a validated block. Preconditions (checked by the caller):
/// core `Running`, no ready interrupt, and `now + max_cycles` inside
/// both the budget and the event horizon — under those, skipping the
/// per-instruction post-step is exact, so the only divergence sources
/// left are bus side effects, and the loop breaks back to the
/// reference path before any of them.
fn exec_block(soc: &mut Soc, block: &Block, stats: &mut ExecStats) -> Option<RunExit> {
    let start = soc.now;
    for &(instr, word) in &block.body {
        // bail before any access that could leave SRAM: device reads
        // are side-effecting and bridge/periph waits differ — the
        // single-step path handles them with full post-step coverage
        match instr {
            Instr::Load { rs1, imm, .. } | Instr::Store { rs1, imm, .. } => {
                let addr = soc.cpu.regs[rs1 as usize].wrapping_add(imm as u32);
                if soc.bus.bank_index(addr).is_none() {
                    break;
                }
            }
            _ => {}
        }
        let pc = soc.cpu.pc;
        let r = soc.cpu.exec_decoded(instr, word, 0, &mut soc.bus, soc.now);
        soc.now += r.cycles as u64;
        // identical record stream to the single-step path (same pc,
        // same true cycle cost) — profiles stay bit-identical across
        // backends by construction
        if let Some(p) = soc.bus.profile.as_deref_mut() {
            p.record(pc, r.cycles, r.retired);
        }
        if r.retired {
            soc.stats.instructions += 1;
            // same post-increment timestamp as the single-step path
            if let Some(t) = soc.bus.trace.as_deref_mut() {
                t.retire(soc.now, pc);
            }
        }
        // trap / wfi / ebreak: state changed — the shared loop decides
        if !r.retired || soc.cpu.state != CpuState::Running {
            break;
        }
        // a store into the block's own page may have rewritten the
        // instructions we pre-decoded: stop replaying them
        if let Instr::Store { rs1, imm, .. } = instr {
            let addr = soc.cpu.regs[rs1 as usize].wrapping_add(imm as u32);
            if soc.bus.bank_index(addr) == Some(block.bank)
                && soc.bus.bank_offset(addr) >> GEN_PAGE_SHIFT == block.page
            {
                break;
            }
        }
    }
    // cycles actually consumed vs the dispatch bound: the WCET contract
    // (`block_cycles <= bounded_cycles`) the analyzer tests assert
    stats.block_cycles += soc.now - start;
    soc.post_step();
    service_exit(soc)
}

/// Decode a basic block starting at `pc`: straight-line instructions up
/// to and including the first terminator, bounded by [`MAX_BLOCK_LEN`]
/// and the enclosing write-generation page. Returns `None` when not
/// even the first word decodes (the reference path takes the illegal
/// trap).
fn build_block(soc: &Soc, pc: u32, bank: usize, page: usize, gen: u64) -> Option<Block> {
    let bank_ref = &soc.bus.banks[bank];
    let base_off = soc.bus.bank_offset(pc);
    let (body, max_cycles) = scan_block(&soc.cpu.timing, pc, &mut |p| {
        let off = base_off + (p.wrapping_sub(pc) as usize);
        bank_ref.fetch32(off).ok()
    });
    if body.is_empty() {
        return None;
    }
    Some(Block { pc, bank, page, gen, max_cycles, body })
}

/// The one block-shape scanner: decode straight-line instructions from
/// `pc` up to and including the first terminator, bounded by
/// [`MAX_BLOCK_LEN`] and the enclosing write-generation page
/// ([`GEN_PAGE_SHIFT`] applied to the pc — SRAM starts at 0 and banks
/// are page-multiples, so pc pages and bank-offset pages cut at the
/// same addresses). Shared between [`build_block`] (dynamic warm-up)
/// and the static analyzer's CFG recovery ([`crate::analyze`]), which
/// is what makes "statically recovered block map == dynamically
/// compiled block map" provable rather than coincidental.
pub(crate) fn scan_block(
    t: &Timing,
    pc: u32,
    fetch: &mut dyn FnMut(u32) -> Option<u32>,
) -> (Vec<(Instr, u32)>, u64) {
    let page = pc >> GEN_PAGE_SHIFT;
    let mut body = Vec::new();
    let mut max_cycles = 0u64;
    let mut p = pc;
    loop {
        let Some(word) = fetch(p) else { break };
        let Some(instr) = isa::decode(word) else { break };
        body.push((instr, word));
        max_cycles += t.worst_cycles(instr) as u64;
        if is_terminator(instr) || body.len() >= MAX_BLOCK_LEN {
            break;
        }
        p = p.wrapping_add(4);
        if p >> GEN_PAGE_SHIFT != page {
            break;
        }
    }
    (body, max_cycles)
}

/// Instructions that end a block: control transfers, plus anything that
/// can retarget the pc or change interrupt visibility (CSR writes and
/// `mret` can unmask a pending interrupt; the next dispatch re-checks).
pub(crate) fn is_terminator(i: Instr) -> bool {
    matches!(
        i,
        Instr::Branch { .. }
            | Instr::Jal { .. }
            | Instr::Jalr { .. }
            | Instr::Ecall
            | Instr::Ebreak
            | Instr::Wfi
            | Instr::Mret
            | Instr::Csr { .. }
    )
}
