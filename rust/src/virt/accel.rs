//! Accelerator virtualization: mailbox requests executed as AOT-compiled
//! JAX/Pallas artifacts through PJRT.
//!
//! Paper §III-A/§IV-B: before an accelerator exists as RTL, it runs as a
//! software model on the CS; the guest communicates through predefined
//! DRAM regions. Our software models are the L1/L2 kernels lowered once
//! at build time (`python/compile/aot.py`) — at emulation time only the
//! compiled HLO executes, via [`crate::runtime::Runtime`]. Python never
//! runs on this path.
//!
//! Request block layout at `BRIDGE + req_off` (i32 words in CS DRAM):
//!
//! ```text
//! [ kernel_id, n_args, arg0 words ..., arg1 words ..., results ... ]
//! ```
//!
//! Tensor shapes come from the artifact manifest. The guest supplies the
//! first `n_args` manifest arguments; the remainder (e.g. classifier
//! weights) must be bound CS-side with [`AccelService::bind_params`] —
//! mirroring the paper's flow where the model parameters live with the
//! CS-side software model, not in guest memory. Results are written
//! immediately after the guest-provided args, and completion is
//! scheduled after a modeled CS turnaround latency.
//!
//! Functional-validation note (§V-B step 5): the virtualized path is for
//! *correctness*; its latency is a configurable placeholder
//! ([`DEFAULT_LATENCY_CYCLES`]) — performance/energy numbers come from
//! the RTL (CGRA-emulator) stage.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Runtime, TensorI32};
use crate::soc::Soc;

/// Modeled CS turnaround (AXI + service scheduling) in guest cycles.
pub const DEFAULT_LATENCY_CYCLES: u64 = 10_000;

/// The FFT stage-twiddle tables as runtime tensors, in artifact argument
/// order (all twr stages, then all twi stages). Callers executing the
/// `fft512` or `model` artifacts append these after their data arguments.
pub fn fft_table_tensors(n: usize) -> Vec<TensorI32> {
    crate::workloads::reference::fft_stage_twiddles(n)
        .into_iter()
        .map(|t| {
            let len = t.len();
            TensorI32::new(vec![len], t).expect("table tensor")
        })
        .collect()
}

/// kernel_id -> artifact entry name.
pub fn entry_name(kernel_id: u32) -> Option<&'static str> {
    match kernel_id {
        0 => Some("matmul"),
        1 => Some("conv2d"),
        2 => Some("fft512"),
        3 => Some("model"),
        _ => None,
    }
}

pub struct AccelService {
    runtime: Runtime,
    latency_cycles: u64,
    /// CS-bound trailing arguments per entry (e.g. model weights).
    bound: HashMap<String, Vec<TensorI32>>,
    /// Requests served (observability).
    pub requests_served: u64,
}

impl AccelService {
    pub fn new(runtime: Runtime) -> Self {
        let mut service = Self {
            runtime,
            latency_cycles: DEFAULT_LATENCY_CYCLES,
            bound: HashMap::new(),
            requests_served: 0,
        };
        // the FFT artifact's twiddle tables are CS-owned trailing args
        service.bound.insert("fft512".into(), fft_table_tensors(512));
        service
    }

    pub fn with_latency(mut self, cycles: u64) -> Self {
        self.latency_cycles = cycles;
        self
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Bind CS-side trailing arguments for an entry (model weights etc.).
    /// For the `model` entry the FFT twiddle tables are appended
    /// automatically after the supplied parameters.
    pub fn bind_params(&mut self, entry: &str, mut params: Vec<TensorI32>) {
        if entry == "model" {
            params.extend(fft_table_tensors(512));
        }
        self.bound.insert(entry.to_string(), params);
    }

    /// Service a mailbox ring ([`crate::soc::RunExit::MailboxRing`]):
    /// parse the request block, execute the artifact, write results back,
    /// schedule completion.
    pub fn service(&mut self, soc: &mut Soc, req_off: u32) -> Result<()> {
        let dram = &soc.bus.cs_dram;
        let base = req_off as usize;
        let kernel_id = dram.read32(base).map_err(|e| anyhow!("request header: {e:?}"))?;
        let n_args = dram.read32(base + 4).map_err(|e| anyhow!("request header: {e:?}"))? as usize;
        let name = entry_name(kernel_id)
            .ok_or_else(|| anyhow!("unknown mailbox kernel id {kernel_id}"))?;
        let entry = self
            .runtime
            .manifest()
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact entry `{name}` not loaded"))?
            .clone();

        let bound = self.bound.get(name).cloned().unwrap_or_default();
        if n_args + bound.len() != entry.args.len() {
            bail!(
                "entry `{name}`: guest provided {n_args} args + {} bound, manifest wants {}",
                bound.len(),
                entry.args.len()
            );
        }

        // unpack guest-provided args
        let mut inputs = Vec::with_capacity(entry.args.len());
        let mut off = base + 8;
        for spec in entry.args.iter().take(n_args) {
            let n: usize = spec.shape.iter().product();
            let words = soc
                .bus
                .cs_dram
                .read_i32_slice(off, n)
                .map_err(|e| anyhow!("arg read at {off:#x}: {e:?}"))?;
            inputs.push(TensorI32::new(spec.shape.clone(), words)?);
            off += n * 4;
        }
        inputs.extend(bound);

        let results = self.runtime.execute(name, &inputs)?;
        // results land right after the guest-provided args
        for t in &results {
            soc.bus
                .cs_dram
                .write_i32_slice(off, t.data())
                .map_err(|e| anyhow!("result write at {off:#x}: {e:?}"))?;
            off += t.len() * 4;
        }

        soc.bus.mailbox.schedule_completion(soc.now + self.latency_cycles);
        self.requests_served += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{RunExit, Soc, SocConfig};
    use crate::util::Rng;
    use crate::workloads::reference as refimpl;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn service() -> Option<AccelService> {
        let rt = Runtime::load_or_skip(artifact_dir(), "accel-virtualization test")?;
        Some(AccelService::new(rt).with_latency(500))
    }

    /// Drive a guest that rings the mailbox for the matmul artifact and
    /// checks the result against the Rust oracle.
    #[test]
    fn guest_matmul_via_mailbox_matches_oracle() {
        let Some(mut accel) = service() else { return };
        let (m, k, n) = (121usize, 16usize, 4usize);
        let mut rng = Rng::new(9);
        let a = rng.vec_i32(m * k, -1000, 1000);
        let b = rng.vec_i32(k * n, -1000, 1000);

        let mut soc = Soc::new(SocConfig::default());
        let req_off = 0x2000u32;
        // CS stages the operands in the request block (a real guest would
        // write them through the bridge window; staging is equivalent and
        // exercises the same parsing path)
        soc.bus.cs_dram.write32(req_off as usize, 0).unwrap(); // matmul
        soc.bus.cs_dram.write32(req_off as usize + 4, 2).unwrap(); // 2 args
        soc.bus.cs_dram.write_i32_slice(req_off as usize + 8, &a).unwrap();
        soc.bus.cs_dram.write_i32_slice(req_off as usize + 8 + a.len() * 4, &b).unwrap();

        let prog = crate::isa::assemble(&format!(
            r#"
            .equ MBOX, 0x20000800
            _start:
                li  t0, MBOX
                li  t1, 1
                sw  t1, 8(t0)    # irq enable
                li  t1, 0x100000 # MIE mailbox line
                csrw mie, t1
                li  t1, {req_off}
                sw  t1, 12(t0)
                li  t1, 1
                sw  t1, 0(t0)    # ring
            wait:
                lw  t2, 4(t0)
                andi t3, t2, 1
                bnez t3, done
                wfi
                j   wait
            done:
                ebreak
            "#
        ))
        .unwrap();
        soc.load(&prog).unwrap();

        let ring_at;
        match soc.run(10_000_000) {
            RunExit::MailboxRing(off) => {
                assert_eq!(off, req_off);
                ring_at = soc.now;
                accel.service(&mut soc, off).unwrap();
            }
            other => panic!("{other:?}"),
        }
        match soc.run(10_000_000) {
            RunExit::Halted(_) => {}
            other => panic!("{other:?}"),
        }
        // completion respected the modeled latency
        assert!(soc.now >= ring_at + 500, "now {} ring {ring_at}", soc.now);

        let res_off = req_off as usize + 8 + (a.len() + b.len()) * 4;
        let got = soc.bus.cs_dram.read_i32_slice(res_off, m * n).unwrap();
        assert_eq!(got, refimpl::matmul_i32(&a, &b, m, k, n));
        assert_eq!(accel.requests_served, 1);
    }

    #[test]
    fn model_entry_with_bound_params() {
        let Some(mut accel) = service() else { return };
        let mut soc = Soc::new(SocConfig::default());
        let mut rng = Rng::new(11);
        // bind classifier weights CS-side
        let w1 = TensorI32::new(vec![64, 32], rng.vec_i32(64 * 32, -(1 << 14), 1 << 14)).unwrap();
        let b1 = TensorI32::new(vec![32], rng.vec_i32(32, -100, 100)).unwrap();
        let w2 = TensorI32::new(vec![32, 4], rng.vec_i32(32 * 4, -(1 << 14), 1 << 14)).unwrap();
        let b2 = TensorI32::new(vec![4], rng.vec_i32(4, -100, 100)).unwrap();
        accel.bind_params("model", vec![w1, b1, w2, b2]);

        let window = rng.vec_i32(512, -(1 << 15), 1 << 15);
        let req = 0x3000usize;
        soc.bus.cs_dram.write32(req, 3).unwrap(); // model
        soc.bus.cs_dram.write32(req + 4, 1).unwrap(); // window only
        soc.bus.cs_dram.write_i32_slice(req + 8, &window).unwrap();
        accel.service(&mut soc, req as u32).unwrap();
        let logits = soc.bus.cs_dram.read_i32_slice(req + 8 + 512 * 4, 4).unwrap();
        // sanity: deterministic, not all equal
        let logits2 = {
            let mut soc2 = Soc::new(SocConfig::default());
            soc2.bus.cs_dram.write32(req, 3).unwrap();
            soc2.bus.cs_dram.write32(req + 4, 1).unwrap();
            soc2.bus.cs_dram.write_i32_slice(req + 8, &window).unwrap();
            accel.service(&mut soc2, req as u32).unwrap();
            soc2.bus.cs_dram.read_i32_slice(req + 8 + 512 * 4, 4).unwrap()
        };
        assert_eq!(logits, logits2);
        assert!(logits.iter().any(|&x| x != logits[0]));
    }

    #[test]
    fn rejects_bad_requests() {
        let Some(mut accel) = service() else { return };
        let mut soc = Soc::new(SocConfig::default());
        soc.bus.cs_dram.write32(0, 99).unwrap(); // unknown kernel
        assert!(accel.service(&mut soc, 0).is_err());
        soc.bus.cs_dram.write32(0, 0).unwrap(); // matmul
        soc.bus.cs_dram.write32(4, 1).unwrap(); // wrong arg count
        assert!(accel.service(&mut soc, 0).is_err());
    }
}
