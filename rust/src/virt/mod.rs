//! CS-side virtualization services (paper §III-A / §IV-B).
//!
//! The four virtualization capabilities that define FEMU, each decoupling
//! guest software from physical hardware:
//!
//! * [`debugger`] — full control of the HS (load / run / halt /
//!   breakpoints / inspection) without external probes; enables scripted
//!   batch testing.
//! * [`adc`] — the software half of the dual circular-FIFO sample
//!   streaming (storage → CS memory → RH FIFO at the configured rate).
//! * [`flash`] — DRAM-backed non-volatile storage with read **and**
//!   write support (the §V-C 250x transfer-speedup mechanism).
//! * [`accel`] — accelerator software models: mailbox requests executed
//!   as AOT-compiled JAX/Pallas artifacts through PJRT.

pub mod accel;
pub mod adc;
pub mod debugger;
pub mod flash;

pub use accel::AccelService;
pub use adc::AdcService;
pub use debugger::DebugSession;
pub use flash::FlashService;
