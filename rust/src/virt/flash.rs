//! Flash virtualization: CS-side dataset staging and result readback.
//!
//! Paper §III-A: virtualized flash removes the latency/bandwidth limits
//! of physical flash — large inputs stream in quickly, test vectors are
//! trivially injected, and results/logs can be written back. The device
//! half (timing + guest register interface) is
//! [`crate::periph::SpiFlash`]; this service is the CS half that stages
//! datasets and collects what the guest wrote.

use crate::soc::Soc;
use crate::workloads::signals;

#[derive(Clone, Copy, Debug, Default)]
pub struct FlashStats {
    pub words_transferred: u64,
    pub busy_cycles: u64,
}

/// CS-side flash dataset manager.
#[derive(Clone, Debug, Default)]
pub struct FlashService;

impl FlashService {
    /// Stage raw bytes at a flash byte offset.
    pub fn stage_bytes(soc: &mut Soc, offset: usize, bytes: &[u8]) {
        soc.bus.spi_flash.load(offset, bytes);
    }

    /// Stage i32 samples (LE words) at a flash byte offset.
    pub fn stage_samples(soc: &mut Soc, offset: usize, samples: &[i32]) {
        Self::stage_bytes(soc, offset, &signals::to_le_bytes(samples));
    }

    /// Stage a sequence of fixed-size windows back to back, returning the
    /// per-window byte offsets (the §V-C layout: 240 windows of 35 000
    /// 16-bit samples, stored as one word per sample).
    pub fn stage_windows(soc: &mut Soc, base: usize, windows: &[Vec<i32>]) -> Vec<usize> {
        let mut offsets = Vec::with_capacity(windows.len());
        let mut off = base;
        for w in windows {
            offsets.push(off);
            Self::stage_samples(soc, off, w);
            off += w.len() * 4;
        }
        offsets
    }

    /// Read back i32 words the guest wrote to flash.
    pub fn read_samples(soc: &Soc, offset: usize, n: usize) -> Vec<i32> {
        soc.bus
            .spi_flash
            .dump(offset, n * 4)
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    /// Transfer statistics (for the Case C study).
    pub fn stats(soc: &Soc) -> FlashStats {
        FlashStats {
            words_transferred: soc.bus.spi_flash.words_transferred(),
            busy_cycles: soc.bus.spi_flash.busy_cycles(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{Soc, SocConfig};

    #[test]
    fn stage_and_guest_read() {
        let mut soc = Soc::new(SocConfig::default());
        FlashService::stage_samples(&mut soc, 0x100, &[7, -8, 9]);
        let prog = crate::isa::assemble(
            r#"
            .equ FLASH, 0x20000400
            _start:
                li t0, FLASH
                li t1, 0x100
                sw t1, 8(t0)     # ADDR
                lw a0, 12(t0)    # DATA
                lw a1, 12(t0)
                lw a2, 12(t0)
                ebreak
            "#,
        )
        .unwrap();
        soc.load(&prog).unwrap();
        soc.run_to_halt(1_000_000);
        assert_eq!(soc.cpu.regs[10] as i32, 7);
        assert_eq!(soc.cpu.regs[11] as i32, -8);
        assert_eq!(soc.cpu.regs[12] as i32, 9);
        let stats = FlashService::stats(&soc);
        assert_eq!(stats.words_transferred, 3);
    }

    #[test]
    fn guest_write_cs_readback() {
        let mut soc = Soc::new(SocConfig::default());
        let prog = crate::isa::assemble(
            r#"
            .equ FLASH, 0x20000400
            _start:
                li t0, FLASH
                li t1, 0x200
                sw t1, 8(t0)
                li t1, 1234
                sw t1, 12(t0)   # DATA write
                li t1, -5
                sw t1, 12(t0)
                ebreak
            "#,
        )
        .unwrap();
        soc.load(&prog).unwrap();
        soc.run_to_halt(1_000_000);
        assert_eq!(FlashService::read_samples(&soc, 0x200, 2), vec![1234, -5]);
    }

    #[test]
    fn windows_layout() {
        let mut soc = Soc::new(SocConfig::default());
        let windows = vec![vec![1, 2], vec![3, 4], vec![5, 6]];
        let offs = FlashService::stage_windows(&mut soc, 0, &windows);
        assert_eq!(offs, vec![0, 8, 16]);
        assert_eq!(FlashService::read_samples(&soc, 8, 2), vec![3, 4]);
    }
}
