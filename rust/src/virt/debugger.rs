//! Debugger virtualization: the CS's full-control window into the HS.
//!
//! Paper §IV-B: the X-HEEP JTAG pins are wired to PS GPIOs and driven by
//! OpenOCD+GDB from Ubuntu, giving "complete control over X-HEEP directly
//! from the Ubuntu environment" with no external probe. This module is
//! that control plane with the JTAG bit-banging elided (the emulated core
//! is in-process; DESIGN.md §2 documents the substitution): load/reset/
//! run/halt, software breakpoints, register and memory inspection, UART
//! capture — everything needed for scripted batch testing (§III-A).

use std::collections::BTreeSet;

use anyhow::{anyhow, Result};

use crate::cpu::{CpuState, Halt};
use crate::isa::{assemble, Program};
use crate::soc::{RunExit, Soc};

/// Why a debug run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DebugStop {
    Breakpoint(u32),
    Halted(Halt),
    /// CS service needed (mailbox/ADC) — the coordinator must handle it
    /// and resume.
    Service(RunExit),
    Budget,
}

/// A debug session wrapping the SoC.
pub struct DebugSession {
    pub soc: Soc,
    breakpoints: BTreeSet<u32>,
    /// UART bytes captured across the session.
    uart_log: Vec<u8>,
}

impl DebugSession {
    pub fn new(soc: Soc) -> Self {
        Self { soc, breakpoints: BTreeSet::new(), uart_log: Vec::new() }
    }

    /// Assemble and load a program, pointing the core at its entry
    /// (the "seamless reprogramming" path).
    pub fn load_source(&mut self, asm: &str) -> Result<Program> {
        let prog = assemble(asm)?;
        self.soc.load(&prog)?;
        Ok(prog)
    }

    pub fn load_program(&mut self, prog: &Program) -> Result<()> {
        self.soc.load(prog)
    }

    /// Reset the core to an entry point without reloading memory.
    pub fn reset(&mut self, entry: u32) {
        self.soc.cpu.reset(entry);
    }

    // ---- breakpoints ----------------------------------------------------

    pub fn add_breakpoint(&mut self, addr: u32) {
        self.breakpoints.insert(addr);
    }

    pub fn remove_breakpoint(&mut self, addr: u32) {
        self.breakpoints.remove(&addr);
    }

    pub fn breakpoints(&self) -> impl Iterator<Item = u32> + '_ {
        self.breakpoints.iter().copied()
    }

    // ---- execution ------------------------------------------------------

    /// Run until a stop condition. With no breakpoints this is the fast
    /// event-driven path; with breakpoints the core is single-stepped.
    pub fn run(&mut self, max_cycles: u64) -> DebugStop {
        let stop = if self.breakpoints.is_empty() {
            match self.soc.run(max_cycles) {
                RunExit::Halted(h) => DebugStop::Halted(h),
                RunExit::CycleBudget => DebugStop::Budget,
                other => DebugStop::Service(other),
            }
        } else {
            self.run_stepped(max_cycles)
        };
        self.uart_log.extend(self.soc.bus.uart.drain());
        stop
    }

    fn run_stepped(&mut self, max_cycles: u64) -> DebugStop {
        let deadline = self.soc.now.saturating_add(max_cycles);
        loop {
            if self.breakpoints.contains(&self.soc.cpu.pc)
                && self.soc.cpu.state == CpuState::Running
            {
                return DebugStop::Breakpoint(self.soc.cpu.pc);
            }
            // one step at a time: budget of 1 forces a single iteration
            match self.soc.run(1) {
                RunExit::Halted(h) => return DebugStop::Halted(h),
                RunExit::CycleBudget => {
                    if self.soc.now >= deadline {
                        return DebugStop::Budget;
                    }
                }
                other => return DebugStop::Service(other),
            }
        }
    }

    /// Single-step one instruction.
    pub fn step(&mut self) -> DebugStop {
        match self.soc.run(1) {
            RunExit::Halted(h) => DebugStop::Halted(h),
            RunExit::CycleBudget => DebugStop::Budget,
            other => DebugStop::Service(other),
        }
    }

    // ---- inspection -----------------------------------------------------

    pub fn pc(&self) -> u32 {
        self.soc.cpu.pc
    }

    pub fn reg(&self, i: usize) -> u32 {
        self.soc.cpu.regs[i]
    }

    pub fn set_reg(&mut self, i: usize, v: u32) {
        if i != 0 {
            self.soc.cpu.regs[i] = v;
        }
    }

    /// Read a word from SRAM / bridge window, ignoring power states.
    pub fn read32(&self, addr: u32) -> Result<u32> {
        self.soc.bus.debug_read32(addr).ok_or_else(|| anyhow!("unmapped address {addr:#x}"))
    }

    pub fn write32(&mut self, addr: u32, v: u32) -> Result<()> {
        self.soc.bus.debug_write32(addr, v).ok_or_else(|| anyhow!("unmapped address {addr:#x}"))
    }

    /// Bulk i32 injection at a symbol/address (operand staging).
    pub fn write_i32_slice(&mut self, addr: u32, values: &[i32]) -> Result<()> {
        for (i, v) in values.iter().enumerate() {
            self.write32(addr + (i * 4) as u32, *v as u32)?;
        }
        Ok(())
    }

    /// Bulk i32 readback.
    pub fn read_i32_slice(&self, addr: u32, n: usize) -> Result<Vec<i32>> {
        (0..n).map(|i| self.read32(addr + (i * 4) as u32).map(|v| v as i32)).collect()
    }

    /// UART output captured so far.
    pub fn uart(&mut self) -> Vec<u8> {
        self.uart_log.extend(self.soc.bus.uart.drain());
        self.uart_log.clone()
    }

    /// Serialize the debug-session state: SoC, breakpoints, captured
    /// UART log.
    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        self.soc.save_state(w);
        w.u32(self.breakpoints.len() as u32);
        for &bp in &self.breakpoints {
            w.u32(bp);
        }
        w.bytes(&self.uart_log);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.soc.restore_state(r)?;
        let n = r.u32()? as usize;
        self.breakpoints.clear();
        for _ in 0..n {
            self.breakpoints.insert(r.u32()?);
        }
        self.uart_log = r.bytes()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::SocConfig;

    fn session() -> DebugSession {
        DebugSession::new(Soc::new(SocConfig::default()))
    }

    #[test]
    fn load_run_inspect() {
        let mut dbg = session();
        dbg.load_source("_start:\nli a0, 99\nebreak").unwrap();
        assert_eq!(dbg.run(10_000), DebugStop::Halted(Halt::Ebreak));
        assert_eq!(dbg.reg(10), 99);
    }

    #[test]
    fn breakpoint_hits_and_resumes() {
        let mut dbg = session();
        dbg.load_source(
            r#"
            _start:
                li a0, 1
            bp_here:
                li a0, 2
                ebreak
            "#,
        )
        .unwrap();
        // bp at third word? _start li (1 instr small) -> bp_here at 4
        dbg.add_breakpoint(4);
        assert_eq!(dbg.run(10_000), DebugStop::Breakpoint(4));
        assert_eq!(dbg.reg(10), 1);
        // step over the breakpoint, then resume to halt
        dbg.step();
        assert_eq!(dbg.run(10_000), DebugStop::Halted(Halt::Ebreak));
        assert_eq!(dbg.reg(10), 2);
    }

    #[test]
    fn memory_injection_and_readback() {
        let mut dbg = session();
        let prog = dbg
            .load_source(
                r#"
                _start:
                    la t0, buf
                    lw a0, 0(t0)
                    lw a1, 4(t0)
                    add a2, a0, a1
                    la t1, out
                    sw a2, 0(t1)
                    ebreak
                .data
                buf: .space 8
                out: .word 0
                "#,
            )
            .unwrap();
        let buf = prog.symbol("buf").unwrap();
        let out = prog.symbol("out").unwrap();
        dbg.write_i32_slice(buf, &[40, 2]).unwrap();
        dbg.run(10_000);
        assert_eq!(dbg.read_i32_slice(out, 1).unwrap(), vec![42]);
    }

    #[test]
    fn uart_capture_accumulates() {
        let mut dbg = session();
        dbg.load_source(
            r#"
            .equ UART, 0x20000000
            _start:
                li t0, UART
                li t1, 65
                sw t1, 0(t0)
                ebreak
            "#,
        )
        .unwrap();
        dbg.run(10_000);
        assert_eq!(dbg.uart(), b"A".to_vec());
    }

    #[test]
    fn scripted_batch_reload() {
        // paper §III-A: automation of a batch of tests from a script —
        // run two different programs on the same session back to back.
        let mut dbg = session();
        dbg.load_source("_start: li a0, 1\nebreak").unwrap();
        dbg.run(1_000);
        assert_eq!(dbg.reg(10), 1);
        dbg.load_source("_start: li a0, 2\nebreak").unwrap();
        dbg.run(1_000);
        assert_eq!(dbg.reg(10), 2);
    }
}
