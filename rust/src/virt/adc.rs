//! ADC virtualization: the CS software FIFO of the dual-buffer scheme.
//!
//! Paper §III-A/§IV-B: two circular buffers — a software FIFO moving
//! samples from large storage (SD card) into CS memory, and a hardware
//! FIFO moving them from CS memory into the RH at the configured
//! sampling rate. [`crate::periph::SpiAdc`] is the hardware half; this
//! service is the software half: it owns the full dataset and answers
//! the device's refill requests chunk by chunk, so the guest always
//! finds sample k available at its nominal time `k / f_s`.

use crate::soc::Soc;

/// Default software-FIFO chunk (samples per refill).
pub const CHUNK: usize = 128;

#[derive(Clone, Debug)]
pub struct AdcService {
    dataset: Vec<i32>,
    pos: usize,
    chunk: usize,
}

impl AdcService {
    pub fn new(dataset: Vec<i32>) -> Self {
        Self { dataset, pos: 0, chunk: CHUNK }
    }

    pub fn with_chunk(dataset: Vec<i32>, chunk: usize) -> Self {
        assert!(chunk > 0);
        Self { dataset, pos: 0, chunk }
    }

    /// Configure the stream on the device: `sample_rate_hz` paced against
    /// the SoC clock, starting now. Pre-fills the hardware FIFO.
    pub fn start(&mut self, soc: &mut Soc, sample_rate_hz: f64) {
        assert!(sample_rate_hz > 0.0);
        let period = (soc.freq_hz as f64 / sample_rate_hz).round().max(1.0) as u64;
        self.pos = 0;
        soc.bus.spi_adc.configure_stream(self.dataset.len() as u64, period, soc.now);
        self.refill(soc);
    }

    /// Answer a refill request (the [`crate::soc::RunExit::AdcRefill`]
    /// hand-off): push up to one chunk into the hardware FIFO.
    /// Push chunks until the hardware FIFO is full or the dataset is
    /// exhausted.
    pub fn refill(&mut self, soc: &mut Soc) {
        while self.pos < self.dataset.len() {
            let end = (self.pos + self.chunk).min(self.dataset.len());
            let accepted = soc.bus.spi_adc.refill(&self.dataset[self.pos..end]);
            self.pos += accepted;
            if accepted == 0 {
                break; // FIFO full
            }
        }
    }

    pub fn samples_total(&self) -> usize {
        self.dataset.len()
    }

    pub fn samples_fed(&self) -> usize {
        self.pos
    }

    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.i32s(&self.dataset);
        w.u64(self.pos as u64);
        w.u64(self.chunk as u64);
    }

    /// Rebuild a service from snapshot state (the device half lives in
    /// the SoC image; this is only the CS software FIFO).
    pub fn from_state(r: &mut crate::snapshot::Reader) -> anyhow::Result<AdcService> {
        let dataset = r.i32s()?;
        let pos = r.u64()? as usize;
        let chunk = r.u64()? as usize;
        if chunk == 0 || pos > dataset.len() {
            anyhow::bail!("snapshot corrupt: ADC service pos {pos}/chunk {chunk}");
        }
        Ok(AdcService { dataset, pos, chunk })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{RunExit, Soc, SocConfig};
    use crate::workloads::programs;

    #[test]
    fn paced_acquisition_end_to_end() {
        let n: usize = 400;
        let rate = 10_000.0; // 10 kHz at 20 MHz -> period 2000 cycles
        let dataset: Vec<i32> = (0..n as i32).collect();
        let mut soc = Soc::new(SocConfig::default());
        let prog = crate::isa::assemble(&programs::acquisition(n as u64, 2)).unwrap();
        soc.load(&prog).unwrap();
        let mut adc = AdcService::new(dataset);
        adc.start(&mut soc, rate);
        loop {
            match soc.run(200_000_000) {
                RunExit::AdcRefill => adc.refill(&mut soc),
                RunExit::Halted(_) => break,
                other => panic!("{other:?}"),
            }
        }
        assert!(!soc.bus.spi_adc.underrun(), "dual-FIFO pacing must not underrun");
        assert_eq!(soc.bus.spi_adc.consumed(), n as u64);
        // total time ~ (n-1) * period (sample k due at k*period) + handling
        let expect = (n as u64 - 1) * 2_000;
        assert!(
            soc.now >= expect && soc.now < expect + expect / 5,
            "now={} expect~{expect}",
            soc.now
        );
        // last buffered samples visible in guest memory (circular buffer
        // holds the tail)
        let buf = prog.symbol("buf").unwrap();
        let first = soc.bus.debug_read32(buf).unwrap() as i32;
        assert!(first >= 0 && (first as usize) < n);
        assert_eq!(adc.samples_fed(), n);
    }

    #[test]
    fn refill_respects_fifo_capacity() {
        let mut soc = Soc::new(SocConfig::default());
        let mut adc = AdcService::new((0..10_000).collect());
        adc.start(&mut soc, 1000.0);
        // only the FIFO depth can be pre-filled
        assert_eq!(adc.samples_fed(), crate::periph::spi_adc::HW_FIFO_DEPTH);
    }
}
