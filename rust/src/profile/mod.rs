//! Cycle-exact guest profiler with energy attribution (DESIGN.md §14).
//!
//! The profiler hangs off the bus like the trace ring
//! ([`crate::trace`]): an `Option<Box<Profiler>>` the shared retire
//! path of *both* exec backends feeds with `(pc, cycles, retired)`
//! records. Because the interp backend and the blocks backend replay
//! the identical architectural instruction stream (the `femu diff`
//! contract), the per-pc histograms they produce are bit-identical by
//! construction — `femu profile --validate` and CI prove it on every
//! builtin.
//!
//! Capture is a dense per-word bucket array over the SRAM span (pc
//! buckets; ~1 MiB for the default 256 KiB SRAM), so the hot path is
//! two adds and no branches beyond the `active` gate. Folding up to
//! function granularity happens *off* the hot path, at read time,
//! using the [`crate::analyze`] CFG/call-graph symbols — which also
//! guarantees `femu analyze --json` and profile JSON share one
//! symbol-naming scheme ([`crate::analyze::symbol_name`]).
//!
//! Accounting contract (tested in `tests/profile_metrics.rs`):
//!
//! * **cycles conserve exactly**: every cycle the run loop advances
//!   while the profiler is active lands in exactly one pc bucket
//!   (including trap/IRQ-entry cycles, charged to the interrupted pc);
//!   cycles the profiler never saw (WFI sleep fast-forward, cycles
//!   before arming) are the `[idle]` residual, so
//!   `Σ per-function + idle == window == perf_snapshot() delta`.
//! * **energy conserves exactly**: the measured window energy
//!   ([`EnergyModel::estimate`] over the perf-counter delta) is split
//!   proportionally to attributed cycles across functions, and
//!   `[idle]` absorbs the exact remainder (`total_mj` minus the
//!   function shares) — sleep-state energy is never invented.
//!
//! Like the trace ring, the profiler is **derived state**: never
//! snapshotted, reset (with a fresh perf baseline) on program load and
//! snapshot restore. When unarmed the backends pay one branch per
//! instruction; `perf_hotpaths/profile_off_overhead` gates that in CI.

use std::collections::BTreeMap;

use crate::energy::EnergyModel;
use crate::perfmon::{PerfSnapshot, PowerState};
use crate::util::json::Json;

/// Pseudo-function absorbing cycles outside the profiled window's
/// attributed stream (WFI sleep fast-forward).
pub const IDLE_NAME: &str = "[idle]";
/// Pseudo-function for pcs no known function contains.
pub const UNKNOWN_NAME: &str = "[unknown]";

/// The capture side: a dense per-word histogram over the SRAM span.
///
/// Owned by the bus (`bus.profile`) so both exec backends reach it from
/// their retire hooks; all folding/reporting lives in free functions so
/// none of it is anywhere near the hot path.
pub struct Profiler {
    /// Hot-path gate: `record` is two adds when true, one branch when
    /// false. Arming allocates; pausing does not free.
    active: bool,
    /// Cycles per pc bucket (index `pc >> 2`).
    bucket_cycles: Vec<u64>,
    /// Retired instructions per pc bucket.
    bucket_instret: Vec<u64>,
    /// Out-of-span fallback (executing pcs above the SRAM span).
    other_cycles: u64,
    other_instret: u64,
    /// Σ recorded cycles == non-idle window cycles.
    attributed: u64,
    /// Σ recorded retires.
    retired: u64,
    /// Total records seen (retired or not) — phantom-sample checks.
    records: u64,
    /// Cycle counter when the window opened (arm or reset).
    start_cycle: u64,
    /// pc when the window opened: the call-graph root for server-side
    /// reads, where no assembled program (with symbols) is at hand.
    entry_pc: u32,
    /// Perf counters when the window opened; per-power-state splits and
    /// energy attribution price the delta against this.
    baseline: PerfSnapshot,
}

impl Profiler {
    /// `span_bytes` is the executable span covered by dense buckets
    /// (the SRAM span: banks × bank size); `now`/`pc`/`baseline` open
    /// the first window.
    pub fn new(span_bytes: u32, now: u64, pc: u32, baseline: PerfSnapshot) -> Self {
        let buckets = (span_bytes / 4) as usize;
        Self {
            active: true,
            bucket_cycles: vec![0; buckets],
            bucket_instret: vec![0; buckets],
            other_cycles: 0,
            other_instret: 0,
            attributed: 0,
            retired: 0,
            records: 0,
            start_cycle: now,
            entry_pc: pc,
            baseline,
        }
    }

    /// Hot-path record: attribute `cycles` to `pc`'s bucket. Called by
    /// both backends after every `cpu.step`/`exec_decoded`, retired or
    /// not, so trap and IRQ-entry cycles conserve too.
    #[inline]
    pub fn record(&mut self, pc: u32, cycles: u32, retired: bool) {
        if !self.active {
            return;
        }
        self.records += 1;
        self.attributed += cycles as u64;
        let idx = (pc >> 2) as usize;
        if idx < self.bucket_cycles.len() {
            self.bucket_cycles[idx] += cycles as u64;
            if retired {
                self.bucket_instret[idx] += 1;
            }
        } else {
            self.other_cycles += cycles as u64;
            if retired {
                self.other_instret += 1;
            }
        }
        if retired {
            self.retired += 1;
        }
    }

    /// Pause/resume capture without dropping history (the bench gate's
    /// armed-but-paused configuration measures exactly this state).
    pub fn set_active(&mut self, on: bool) {
        self.active = on;
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Drop all recorded history and open a fresh window at `now` —
    /// the load/restore path (derived state: profiles never survive a
    /// snapshot boundary). Keeps the bucket allocation and the
    /// active/paused setting.
    pub fn reset(&mut self, now: u64, pc: u32, baseline: PerfSnapshot) {
        self.bucket_cycles.iter_mut().for_each(|c| *c = 0);
        self.bucket_instret.iter_mut().for_each(|c| *c = 0);
        self.other_cycles = 0;
        self.other_instret = 0;
        self.attributed = 0;
        self.retired = 0;
        self.records = 0;
        self.start_cycle = now;
        self.entry_pc = pc;
        self.baseline = baseline;
    }

    pub fn attributed_cycles(&self) -> u64 {
        self.attributed
    }

    pub fn retired(&self) -> u64 {
        self.retired
    }

    pub fn records(&self) -> u64 {
        self.records
    }

    pub fn start_cycle(&self) -> u64 {
        self.start_cycle
    }

    /// pc at window open — the analysis root for server-side reads.
    pub fn entry_pc(&self) -> u32 {
        self.entry_pc
    }

    pub fn baseline(&self) -> &PerfSnapshot {
        &self.baseline
    }

    /// Non-zero buckets as `(pc, cycles, instret)`, pc-ascending (the
    /// annotated-disassembly export walks this).
    pub fn nonzero(&self) -> impl Iterator<Item = (u32, u64, u64)> + '_ {
        self.bucket_cycles
            .iter()
            .zip(&self.bucket_instret)
            .enumerate()
            .filter(|(_, (&c, &i))| c != 0 || i != 0)
            .map(|(idx, (&c, &i))| ((idx as u32) << 2, c, i))
    }

    /// Order-independent FNV-1a digest of the full capture — the
    /// backend bit-identity checks compare these.
    pub fn digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut put = |h: &mut u64, v: u64| {
            for b in v.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        for (pc, c, i) in self.nonzero() {
            put(&mut h, pc as u64);
            put(&mut h, c);
            put(&mut h, i);
        }
        put(&mut h, self.other_cycles);
        put(&mut h, self.other_instret);
        put(&mut h, self.attributed);
        put(&mut h, self.retired);
        put(&mut h, self.records);
        h
    }
}

/// The symbol view reports fold buckets with: function entries and
/// names (the [`crate::analyze::symbol_name`] scheme) plus the static
/// call edges. Built by [`crate::analyze::Report::function_table`].
pub struct FunctionTable {
    /// `(entry pc, name)` sorted by entry; a pc belongs to the function
    /// with the largest entry at or below it.
    entries: Vec<(u32, String)>,
    /// Static call edges: caller entry → callee entries.
    calls: BTreeMap<u32, Vec<u32>>,
    /// The analysis entry point — the folded-stack root.
    root: u32,
}

impl FunctionTable {
    pub fn new(mut entries: Vec<(u32, String)>, calls: BTreeMap<u32, Vec<u32>>, root: u32) -> Self {
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        entries.dedup_by(|a, b| a.0 == b.0);
        Self { entries, calls, root }
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Index of the function containing `pc`: largest entry ≤ pc.
    fn index_of(&self, pc: u32) -> Option<usize> {
        match self.entries.binary_search_by(|e| e.0.cmp(&pc)) {
            Ok(i) => Some(i),
            Err(0) => None,
            Err(i) => Some(i - 1),
        }
    }

    fn exact(&self, entry: u32) -> Option<usize> {
        self.entries.binary_search_by(|e| e.0.cmp(&entry)).ok()
    }

    /// Name of the function containing `pc`, if any.
    pub fn name_at(&self, pc: u32) -> Option<&str> {
        self.index_of(pc).map(|i| self.entries[i].1.as_str())
    }
}

/// One function's line in a profile report.
#[derive(Clone, Debug)]
pub struct FnProfile {
    pub name: String,
    pub entry: u32,
    /// Cycles spent at pcs inside this function (self time).
    pub flat_cycles: u64,
    /// Instructions retired at pcs inside this function.
    pub flat_instret: u64,
    /// This function's proportional share of the window's active
    /// energy, in millijoules.
    pub flat_mj: f64,
    /// Self time plus all statically-reachable callees' inclusive time
    /// (recursion cycles counted once, at the first-visited function).
    pub incl_cycles: u64,
    /// Canonical call path root→…→self for the folded-stack export.
    pub stack: Vec<String>,
}

/// A folded, attributed profile window — everything the JSON, folded
/// stack, text, and annotated exports render from.
pub struct ProfileReport {
    pub backend: String,
    pub model: String,
    pub freq_hz: u64,
    /// Total window length: `now - start_cycle`.
    pub window_cycles: u64,
    /// Cycles the retire hooks recorded (== Σ per-function flat).
    pub attributed_cycles: u64,
    /// Window cycles the hooks never saw (WFI sleep fast-forward).
    pub idle_cycles: u64,
    pub retired: u64,
    /// Real functions plus `[unknown]` when non-empty; flat-cycle
    /// descending. `Σ flat_cycles == attributed_cycles` exactly.
    pub functions: Vec<FnProfile>,
    /// `total_mj - Σ functions.flat_mj` — exact by construction.
    pub idle_mj: f64,
    pub total_mj: f64,
    pub active_mj: f64,
    pub sleep_mj: f64,
    /// Per-domain power-state cycle deltas over the window, in
    /// [`PerfSnapshot::domains`] order: `(domain, [cycles; 4])`.
    pub states: Vec<(String, [u64; 4])>,
}

/// Fold a capture into a report. `perf_now` must come from the same
/// monitor the profiler's baseline was snapped from (the owning Soc).
pub fn build_report(
    prof: &Profiler,
    now: u64,
    perf_now: &PerfSnapshot,
    table: &FunctionTable,
    model: &EnergyModel,
    backend: &str,
) -> ProfileReport {
    let delta = perf_now.delta(prof.baseline());
    let energy = model.estimate(&delta);

    let window = now.saturating_sub(prof.start_cycle);
    let attributed = prof.attributed;
    let idle_cycles = window.saturating_sub(attributed);

    // fold buckets to function granularity; slot `n` is [unknown]
    let n = table.entries.len();
    let mut flat_cycles = vec![0u64; n + 1];
    let mut flat_instret = vec![0u64; n + 1];
    for (pc, c, i) in prof.nonzero() {
        let slot = table.index_of(pc).unwrap_or(n);
        flat_cycles[slot] += c;
        flat_instret[slot] += i;
    }
    flat_cycles[n] += prof.other_cycles;
    flat_instret[n] += prof.other_instret;

    // proportional energy attribution over the measured active energy;
    // [idle] absorbs the exact residual of total_mj
    let share = |cycles: u64| {
        if attributed == 0 {
            0.0
        } else {
            energy.active_mj * cycles as f64 / attributed as f64
        }
    };

    let incl = inclusive(table, &flat_cycles[..n]);
    let stacks = stacks(table);

    let mut functions = Vec::new();
    for (i, (entry, name)) in table.entries.iter().enumerate() {
        if flat_cycles[i] == 0 && incl[i] == 0 {
            continue;
        }
        functions.push(FnProfile {
            name: name.clone(),
            entry: *entry,
            flat_cycles: flat_cycles[i],
            flat_instret: flat_instret[i],
            flat_mj: share(flat_cycles[i]),
            incl_cycles: incl[i],
            stack: stacks[i].clone(),
        });
    }
    if flat_cycles[n] != 0 || flat_instret[n] != 0 {
        functions.push(FnProfile {
            name: UNKNOWN_NAME.to_string(),
            entry: 0,
            flat_cycles: flat_cycles[n],
            flat_instret: flat_instret[n],
            flat_mj: share(flat_cycles[n]),
            incl_cycles: flat_cycles[n],
            stack: vec![UNKNOWN_NAME.to_string()],
        });
    }
    functions.sort_by(|a, b| b.flat_cycles.cmp(&a.flat_cycles).then(a.entry.cmp(&b.entry)));

    let fn_mj: f64 = functions.iter().map(|f| f.flat_mj).sum();
    let idle_mj = energy.total_mj - fn_mj;

    let states = delta
        .domains()
        .iter()
        .map(|(d, c)| (d.to_string(), c.counts))
        .collect();

    ProfileReport {
        backend: backend.to_string(),
        model: model.name.clone(),
        freq_hz: model.freq_hz,
        window_cycles: window,
        attributed_cycles: attributed,
        idle_cycles,
        retired: prof.retired,
        functions,
        idle_mj,
        total_mj: energy.total_mj,
        active_mj: energy.active_mj,
        sleep_mj: energy.sleep_mj,
        states,
    }
}

/// Inclusive cycles per function: flat plus all statically-reachable
/// callees, memoized; recursion cycles are counted once at the
/// first-visited function (deterministic: visit order is entry order).
fn inclusive(table: &FunctionTable, flat: &[u64]) -> Vec<u64> {
    let n = table.entries.len();
    let mut memo: Vec<Option<u64>> = vec![None; n];
    let mut on_stack = vec![false; n];
    for i in 0..n {
        incl_visit(table, flat, &mut memo, &mut on_stack, i);
    }
    memo.into_iter().map(|v| v.unwrap_or(0)).collect()
}

fn incl_visit(
    table: &FunctionTable,
    flat: &[u64],
    memo: &mut Vec<Option<u64>>,
    on_stack: &mut Vec<bool>,
    i: usize,
) -> u64 {
    if let Some(v) = memo[i] {
        return v;
    }
    if on_stack[i] {
        return 0; // recursion cycle: already being counted upstream
    }
    on_stack[i] = true;
    let mut total = flat[i];
    let entry = table.entries[i].0;
    if let Some(callees) = table.calls.get(&entry) {
        for callee in callees {
            if let Some(j) = table.exact(*callee) {
                total = total.saturating_add(incl_visit(table, flat, memo, on_stack, j));
            }
        }
    }
    on_stack[i] = false;
    memo[i] = Some(total);
    total
}

/// Canonical call path root→F per function: BFS over the static call
/// edges from the table root. Functions the root can't reach get a
/// single-frame stack.
fn stacks(table: &FunctionTable) -> Vec<Vec<String>> {
    let n = table.entries.len();
    let mut parent: Vec<Option<usize>> = vec![None; n];
    let mut seen = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    if let Some(root) = table.exact(table.root) {
        seen[root] = true;
        queue.push_back(root);
    }
    while let Some(i) = queue.pop_front() {
        let entry = table.entries[i].0;
        if let Some(callees) = table.calls.get(&entry) {
            for callee in callees {
                if let Some(j) = table.exact(*callee) {
                    if !seen[j] {
                        seen[j] = true;
                        parent[j] = Some(i);
                        queue.push_back(j);
                    }
                }
            }
        }
    }
    (0..n)
        .map(|i| {
            let mut path = vec![table.entries[i].1.clone()];
            if seen[i] {
                let mut at = i;
                while let Some(p) = parent[at] {
                    path.push(table.entries[p].1.clone());
                    at = p;
                }
            }
            path.reverse();
            path
        })
        .collect()
}

impl ProfileReport {
    /// Machine-readable report; function names use the same scheme as
    /// `femu analyze --json` (see [`crate::analyze::symbol_name`]).
    pub fn to_json(&self) -> Json {
        let functions: Vec<Json> = self
            .functions
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("name", Json::Str(f.name.clone())),
                    ("entry", Json::Num(f.entry as f64)),
                    ("flat_cycles", Json::Num(f.flat_cycles as f64)),
                    ("flat_instret", Json::Num(f.flat_instret as f64)),
                    ("flat_mj", Json::Num(f.flat_mj)),
                    ("incl_cycles", Json::Num(f.incl_cycles as f64)),
                    (
                        "stack",
                        Json::Arr(f.stack.iter().map(|s| Json::Str(s.clone())).collect()),
                    ),
                ])
            })
            .collect();
        let states: Vec<Json> = self
            .states
            .iter()
            .map(|(d, c)| {
                let mut fields = vec![("domain", Json::Str(d.clone()))];
                for s in PowerState::ALL {
                    fields.push((s.name(), Json::Num(c[s as usize] as f64)));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("backend", Json::Str(self.backend.clone())),
            ("model", Json::Str(self.model.clone())),
            ("freq_hz", Json::Num(self.freq_hz as f64)),
            ("window_cycles", Json::Num(self.window_cycles as f64)),
            ("attributed_cycles", Json::Num(self.attributed_cycles as f64)),
            ("idle_cycles", Json::Num(self.idle_cycles as f64)),
            ("retired", Json::Num(self.retired as f64)),
            ("total_mj", Json::Num(self.total_mj)),
            ("active_mj", Json::Num(self.active_mj)),
            ("sleep_mj", Json::Num(self.sleep_mj)),
            ("idle_mj", Json::Num(self.idle_mj)),
            ("functions", Json::Arr(functions)),
            ("states", Json::Arr(states)),
        ])
    }

    /// Folded-stack export, one `a;b;c count` line per function —
    /// pipe straight into flamegraph.pl.
    pub fn to_folded(&self) -> String {
        let mut out = String::new();
        for f in &self.functions {
            if f.flat_cycles == 0 {
                continue;
            }
            out.push_str(&f.stack.join(";"));
            out.push(' ');
            out.push_str(&f.flat_cycles.to_string());
            out.push('\n');
        }
        if self.idle_cycles > 0 {
            out.push_str(IDLE_NAME);
            out.push(' ');
            out.push_str(&self.idle_cycles.to_string());
            out.push('\n');
        }
        out
    }

    /// Human-readable flat/inclusive table plus the power-state splits.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "profile [{}]: {} window cycles ({} attributed, {} idle), {} retired",
            self.backend, self.window_cycles, self.attributed_cycles, self.idle_cycles, self.retired
        );
        let _ = writeln!(
            s,
            "  energy [{}]: {:.6} mJ total ({:.6} active, {:.6} sleep)",
            self.model, self.total_mj, self.active_mj, self.sleep_mj
        );
        let _ = writeln!(
            s,
            "  {:<24} {:>12} {:>10} {:>12} {:>12}",
            "function", "flat cycles", "instret", "incl cycles", "energy mJ"
        );
        for f in &self.functions {
            let _ = writeln!(
                s,
                "  {:<24} {:>12} {:>10} {:>12} {:>12.6}",
                f.name, f.flat_cycles, f.flat_instret, f.incl_cycles, f.flat_mj
            );
        }
        let _ = writeln!(
            s,
            "  {:<24} {:>12} {:>10} {:>12} {:>12.6}",
            IDLE_NAME, self.idle_cycles, 0, self.idle_cycles, self.idle_mj
        );
        let _ = writeln!(s, "  power-state residency over the window (cycles):");
        for (d, c) in &self.states {
            let _ = writeln!(
                s,
                "    {:<10} active {:>12}  clock_gated {:>12}  power_gated {:>12}  retention {:>12}",
                d,
                c[PowerState::Active as usize],
                c[PowerState::ClockGated as usize],
                c[PowerState::PowerGated as usize],
                c[PowerState::Retention as usize],
            );
        }
        s
    }
}

/// Annotated disassembly of every pc the capture touched, grouped by
/// function; `fetch` supplies instruction words (image or live bus).
pub fn render_annotated(
    prof: &Profiler,
    table: &FunctionTable,
    fetch: impl Fn(u32) -> Option<u32>,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let mut current: Option<String> = None;
    for (pc, cycles, instret) in prof.nonzero() {
        let name = table.name_at(pc).unwrap_or(UNKNOWN_NAME).to_string();
        if current.as_deref() != Some(&name) {
            let _ = writeln!(s, "{name}:");
            current = Some(name);
        }
        let text = match fetch(pc) {
            Some(word) => crate::isa::disassemble_word(word, pc),
            None => "<no image>".to_string(),
        };
        let _ = writeln!(s, "  {pc:#010x}  {cycles:>10} cycles  {instret:>8} ret  {text}");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> FunctionTable {
        // main @0 calls leaf @0x40; helper @0x80 is unreachable
        let entries = vec![
            (0x00, "main".to_string()),
            (0x40, "leaf".to_string()),
            (0x80, "helper".to_string()),
        ];
        let mut calls = BTreeMap::new();
        calls.insert(0x00u32, vec![0x40u32]);
        FunctionTable::new(entries, calls, 0x00)
    }

    fn profiler() -> Profiler {
        Profiler::new(0x100, 0, 0, PerfSnapshot::default())
    }

    #[test]
    fn buckets_attribute_by_largest_entry_at_or_below() {
        let t = table();
        assert_eq!(t.name_at(0x00), Some("main"));
        assert_eq!(t.name_at(0x3c), Some("main"));
        assert_eq!(t.name_at(0x40), Some("leaf"));
        assert_eq!(t.name_at(0x7c), Some("leaf"));
        assert_eq!(t.name_at(0x9c), Some("helper"));
    }

    #[test]
    fn record_conserves_and_digest_is_stable() {
        let mut p = profiler();
        p.record(0x00, 2, true);
        p.record(0x04, 3, true);
        p.record(0x04, 1, false); // trap entry: cycles, no retire
        assert_eq!(p.attributed_cycles(), 6);
        assert_eq!(p.retired(), 2);
        assert_eq!(p.records(), 3);
        let d1 = p.digest();

        let mut q = profiler();
        q.record(0x00, 2, true);
        q.record(0x04, 3, true);
        q.record(0x04, 1, false);
        assert_eq!(q.digest(), d1);

        q.record(0x08, 1, true);
        assert_ne!(q.digest(), d1);
    }

    #[test]
    fn paused_profiler_records_nothing() {
        let mut p = profiler();
        p.set_active(false);
        p.record(0x00, 5, true);
        assert_eq!(p.records(), 0);
        assert_eq!(p.attributed_cycles(), 0);
        p.set_active(true);
        p.record(0x00, 5, true);
        assert_eq!(p.records(), 1);
    }

    #[test]
    fn reset_drops_history_and_reopens_window() {
        let mut p = profiler();
        p.record(0x00, 5, true);
        p.reset(1000, 0x40, PerfSnapshot::default());
        assert_eq!(p.records(), 0);
        assert_eq!(p.attributed_cycles(), 0);
        assert_eq!(p.start_cycle(), 1000);
        assert_eq!(p.entry_pc(), 0x40);
        assert_eq!(p.nonzero().count(), 0);
    }

    #[test]
    fn out_of_span_pcs_fold_to_unknown() {
        let mut p = profiler();
        p.record(0x4000_0000, 7, true); // bridge space: beyond buckets
        let m = EnergyModel::femu();
        let r = build_report(&p, 7, &PerfSnapshot::default(), &table(), &m, "interp");
        let unknown = r.functions.iter().find(|f| f.name == UNKNOWN_NAME).unwrap();
        assert_eq!(unknown.flat_cycles, 7);
        assert_eq!(unknown.flat_instret, 1);
    }

    #[test]
    fn report_folds_flat_inclusive_and_stacks() {
        let mut p = profiler();
        p.record(0x00, 10, true); // main
        p.record(0x44, 30, true); // leaf
        let m = EnergyModel::femu();
        let r = build_report(&p, 50, &PerfSnapshot::default(), &table(), &m, "interp");

        assert_eq!(r.window_cycles, 50);
        assert_eq!(r.attributed_cycles, 40);
        assert_eq!(r.idle_cycles, 10);
        let total: u64 = r.functions.iter().map(|f| f.flat_cycles).sum();
        assert_eq!(total, r.attributed_cycles);

        let main = r.functions.iter().find(|f| f.name == "main").unwrap();
        let leaf = r.functions.iter().find(|f| f.name == "leaf").unwrap();
        assert_eq!(main.flat_cycles, 10);
        assert_eq!(main.incl_cycles, 40); // flat + leaf
        assert_eq!(leaf.incl_cycles, 30);
        assert_eq!(leaf.stack, vec!["main".to_string(), "leaf".to_string()]);
        // helper never ran and is reachable by nobody: not in the report
        assert!(r.functions.iter().all(|f| f.name != "helper"));

        let folded = r.to_folded();
        assert!(folded.contains("main;leaf 30"), "{folded}");
        assert!(folded.contains("[idle] 10"), "{folded}");

        let text = r.render_text();
        assert!(text.contains("main"), "{text}");
        assert!(text.contains("[idle]"), "{text}");
    }

    #[test]
    fn recursion_counts_once_in_inclusive_view() {
        let entries = vec![(0x00, "a".to_string()), (0x40, "b".to_string())];
        let mut calls = BTreeMap::new();
        calls.insert(0x00u32, vec![0x40u32]);
        calls.insert(0x40u32, vec![0x00u32]); // b calls a: a<->b cycle
        let t = FunctionTable::new(entries, calls, 0x00);
        let incl = inclusive(&t, &[10, 20]);
        assert_eq!(incl[0], 30); // a: flat 10 + b 20, cycle edge ignored
        assert_eq!(incl[1], 20); // b memoized while a was on stack
    }

    #[test]
    fn json_export_round_trips_and_conserves() {
        let mut p = profiler();
        p.record(0x00, 4, true);
        let m = EnergyModel::femu();
        let r = build_report(&p, 4, &PerfSnapshot::default(), &table(), &m, "blocks");
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("backend").unwrap().as_str().unwrap(), "blocks");
        assert_eq!(parsed.get("attributed_cycles").unwrap().as_i64().unwrap(), 4);
    }

    #[test]
    fn annotated_output_names_functions() {
        let mut p = profiler();
        p.record(0x00, 2, true);
        let out = render_annotated(&p, &table(), |_pc| Some(0x0000_0013)); // nop
        assert!(out.starts_with("main:"), "{out}");
        assert!(out.contains("0x00000000"), "{out}");
    }
}
