//! Trace exporters: VCD (waveform viewers — the general-pipeline
//! successor of [`crate::perfmon::vcd`]'s power-only export, sharing its
//! identifier/encoding helpers so both render identically) and
//! JSON-lines (one object per event, streamable/greppable). Both consume
//! a [`TraceDump`], so they work identically on a live ring capture and
//! a `FEMUTRAC` file read back from disk; `femu trace dump` is the CLI
//! over both.

use std::fmt::Write as _;

use crate::perfmon::vcd::{bits, domain_names, ident};
use crate::perfmon::PowerState;
use crate::util::Json;

use super::format::TraceDump;
use super::{bus_region, category, kind, TraceEvent};

/// One event as a JSON object (shared by the JSONL exporter and the
/// server's `trace.read` frames, so wire and file forms agree).
pub fn event_json(ev: &TraceEvent, num_banks: usize) -> Json {
    let cycle = ("cycle", Json::Num(ev.cycle as f64));
    let event = ("event", Json::Str(ev.kind_name().into()));
    match ev.kind {
        kind::RETIRE => Json::obj(vec![cycle, event, ("pc", hex(ev.a))]),
        kind::BUS_READ | kind::BUS_WRITE => Json::obj(vec![
            cycle,
            event,
            ("region", Json::Str(bus_region::name(ev.arg).into())),
            ("addr", hex(ev.a)),
            ("value", hex(ev.b)),
            ("wait", Json::Num(ev.aux as f64)),
        ]),
        kind::IRQ_RAISE | kind::IRQ_DROP => Json::obj(vec![
            cycle,
            event,
            ("line", Json::Num(ev.arg as f64)),
            ("lines", hex(ev.a)),
        ]),
        _ => Json::obj(vec![
            cycle,
            event,
            ("domain", Json::Str(domain_label(ev.aux as usize, num_banks))),
            ("state", Json::Str(state_label(ev.arg))),
        ]),
    }
}

/// JSON-lines export: a metadata line followed by one line per retained
/// event. Deterministic (sorted keys), so repeat runs diff cleanly.
pub fn to_jsonl(dump: &TraceDump) -> String {
    let mut out = String::new();
    let meta = Json::obj(vec![(
        "trace",
        Json::obj(vec![
            ("categories", Json::Str(dump.categories())),
            ("digest", Json::Str(format!("{:#018x}", dump.digest))),
            ("dropped", Json::Num(dump.dropped() as f64)),
            ("freq_hz", Json::Num(dump.freq_hz as f64)),
            ("retained", Json::Num(dump.events.len() as f64)),
            ("total", Json::Num(dump.total as f64)),
        ]),
    )]);
    let _ = writeln!(out, "{meta}");
    let num_banks = dump.num_banks as usize;
    for ev in &dump.events {
        let _ = writeln!(out, "{}", event_json(ev, num_banks));
    }
    out
}

/// VCD export. Declares one signal group per *enabled* category:
/// `retire_pc[31:0]`, `bus_addr/bus_data[31:0]` + `bus_we` + `bus_wait`,
/// `irq_lines[31:0]`, and a 2-bit state vector per power domain (same
/// encoding as the perfmon VCD: 00 active, 01 clock-gated,
/// 10 power-gated, 11 retention). Values start as `x` until the first
/// event — a dump taken after snapshot restore has no fabricated
/// history.
pub fn to_vcd(dump: &TraceDump) -> String {
    let freq = dump.freq_hz.max(1);
    let ns_per_cycle = 1e9 / freq as f64;
    let num_banks = dump.num_banks as usize;
    let mut out = String::new();
    let _ = writeln!(out, "$comment femu trace (categories: {}) $end", dump.categories());
    let _ = writeln!(
        out,
        "$comment one tick = one cycle = {ns_per_cycle:.1} ns at {freq} Hz $end"
    );
    let _ = writeln!(out, "$timescale 1 ns $end");
    let _ = writeln!(out, "$scope module femu_trace $end");

    let mut next = 0usize;
    let mut declare = |out: &mut String, width: usize, name: &str| -> String {
        let id = ident(next);
        next += 1;
        let _ = writeln!(out, "$var wire {width} {id} {name} $end");
        id
    };
    let mut retire_pc = None;
    let mut bus_vars = None;
    let mut irq_lines = None;
    let mut power_vars: Vec<String> = Vec::new();
    if dump.mask & category::RETIRE != 0 {
        retire_pc = Some(declare(&mut out, 32, "retire_pc"));
    }
    if dump.mask & category::BUS != 0 {
        bus_vars = Some((
            declare(&mut out, 32, "bus_addr"),
            declare(&mut out, 32, "bus_data"),
            declare(&mut out, 1, "bus_we"),
            declare(&mut out, 16, "bus_wait"),
        ));
    }
    if dump.mask & category::IRQ != 0 {
        irq_lines = Some(declare(&mut out, 32, "irq_lines"));
    }
    if dump.mask & category::POWER != 0 {
        for name in domain_names(num_banks) {
            let id = declare(&mut out, 2, &format!("power_{name}"));
            power_vars.push(id);
        }
    }
    let _ = writeln!(out, "$upscope $end");
    let _ = writeln!(out, "$enddefinitions $end");

    // all signals unknown until their first event
    let _ = writeln!(out, "#0");
    if let Some(id) = &retire_pc {
        let _ = writeln!(out, "bx {id}");
    }
    if let Some((addr, data, we, wait)) = &bus_vars {
        let _ = writeln!(out, "bx {addr}");
        let _ = writeln!(out, "bx {data}");
        let _ = writeln!(out, "x{we}");
        let _ = writeln!(out, "bx {wait}");
    }
    if let Some(id) = &irq_lines {
        let _ = writeln!(out, "bx {id}");
    }
    for id in &power_vars {
        let _ = writeln!(out, "bx {id}");
    }

    let mut last_time = 0u64;
    for ev in &dump.events {
        let t = (ev.cycle as f64 * ns_per_cycle) as u64;
        if t != last_time {
            let _ = writeln!(out, "#{t}");
            last_time = t;
        }
        match ev.kind {
            kind::RETIRE => {
                if let Some(id) = &retire_pc {
                    let _ = writeln!(out, "b{:b} {id}", ev.a);
                }
            }
            kind::BUS_READ | kind::BUS_WRITE => {
                if let Some((addr, data, we, wait)) = &bus_vars {
                    let _ = writeln!(out, "b{:b} {addr}", ev.a);
                    let _ = writeln!(out, "b{:b} {data}", ev.b);
                    let w = (ev.kind == kind::BUS_WRITE) as u8;
                    let _ = writeln!(out, "{w}{we}");
                    let _ = writeln!(out, "b{:b} {wait}", ev.aux);
                }
            }
            kind::IRQ_RAISE | kind::IRQ_DROP => {
                if let Some(id) = &irq_lines {
                    let _ = writeln!(out, "b{:b} {id}", ev.a);
                }
            }
            _ => {
                if let Some(id) = power_vars.get(ev.aux as usize) {
                    let b = match PowerState::from_u8(ev.arg) {
                        Ok(s) => bits(s),
                        Err(_) => "xx",
                    };
                    let _ = writeln!(out, "b{b} {id}");
                }
            }
        }
    }
    out
}

fn hex(v: u32) -> Json {
    Json::Str(format!("{v:#010x}"))
}

fn domain_label(index: usize, num_banks: usize) -> String {
    domain_names(num_banks)
        .into_iter()
        .nth(index)
        .unwrap_or_else(|| format!("domain{index}"))
}

fn state_label(tag: u8) -> String {
    match PowerState::from_u8(tag) {
        Ok(s) => s.name().into(),
        Err(_) => format!("state{tag}"),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{TraceConfig, TraceRing};
    use super::*;

    fn sample_dump() -> TraceDump {
        let mut ring = TraceRing::new(TraceConfig { mask: category::ALL, depth: 64 });
        ring.retire(10, 0x180);
        ring.bus_write(14, bus_region::PERIPH, 0x2000_0000, 0x55, 3);
        ring.bus_read(18, bus_region::BRIDGE, 0x3000_0010, 0xAB, 40);
        ring.irq_edges(20, 0x80);
        ring.power(25, 4, PowerState::ClockGated.to_u8());
        ring.retire(31, 0x184);
        TraceDump::from_ring(&ring, 20_000_000, 2)
    }

    #[test]
    fn jsonl_lines_parse_and_label() {
        let dump = sample_dump();
        let text = to_jsonl(&dump);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + dump.events.len());
        for line in &lines {
            Json::parse(line).unwrap();
        }
        assert!(lines[0].contains("\"categories\""));
        assert!(lines[1].contains("\"retire\"") && lines[1].contains("0x00000180"));
        assert!(lines[2].contains("\"bus_write\"") && lines[2].contains("periph"));
        assert!(lines[3].contains("\"bus_read\"") && lines[3].contains("bridge"));
        assert!(lines[4].contains("\"irq_raise\""));
        assert!(lines[5].contains("\"power\"") && lines[5].contains("cgra"));
        assert!(lines[5].contains("clock_gated"));
    }

    #[test]
    fn vcd_structure_and_times() {
        let dump = sample_dump();
        let vcd = to_vcd(&dump);
        assert!(vcd.contains("$timescale 1 ns $end"));
        assert!(vcd.contains("retire_pc"));
        assert!(vcd.contains("bus_addr"));
        assert!(vcd.contains("irq_lines"));
        assert!(vcd.contains("power_cgra"));
        assert!(vcd.contains("power_mem_bank1"));
        // 10 cycles at 20 MHz = 500 ns; 31 cycles = 1550 ns
        assert!(vcd.contains("#500"), "{vcd}");
        assert!(vcd.contains("#1550"), "{vcd}");
        // retire pc value in binary (0x184 = 110000100)
        assert!(vcd.contains("b110000100 "), "{vcd}");
    }

    #[test]
    fn vcd_declares_only_enabled_categories() {
        let mut ring = TraceRing::new(TraceConfig { mask: category::RETIRE, depth: 64 });
        ring.retire(1, 4);
        let vcd = to_vcd(&TraceDump::from_ring(&ring, 20_000_000, 2));
        assert!(vcd.contains("retire_pc"));
        assert!(!vcd.contains("bus_addr"));
        assert!(!vcd.contains("power_cpu"));
    }
}
