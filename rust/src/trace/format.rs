//! `FEMUTRAC`: the on-disk trace container, framed and versioned exactly
//! like `FEMUSNAP` (DESIGN.md §13):
//!
//! ```text
//! magic "FEMUTRAC" | version u32 | payload_len u64 | fnv1a64(payload) | payload
//! ```
//!
//! The payload is a small header (mask, platform clock, bank count —
//! enough for the exporters to label signals), the ring's lifetime
//! totals (per-category counts + stream digest, which cover events lost
//! to wraparound), and the retained event window as fixed-width
//! [`TraceEvent`] records. Reads validate magic, version, length,
//! checksum, record alignment, kind bytes, count/total consistency, and
//! cycle monotonicity — a truncated or corrupted file is an error,
//! never a panic.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::snapshot::{Reader, Writer};

use super::{category, fnv1a64, TraceEvent, TraceRing, EVENT_BYTES};

/// File/stream magic.
pub const MAGIC: [u8; 8] = *b"FEMUTRAC";

/// Trace format version. Bump on any layout change; readers reject
/// mismatches outright (no cross-version migration).
pub const VERSION: u32 = 1;

/// Header size in bytes: magic + version + payload_len + checksum.
const HEADER_LEN: usize = 8 + 4 + 8 + 8;

/// A decoded (or about-to-be-encoded) trace file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceDump {
    /// Category mask the ring recorded with.
    pub mask: u8,
    /// Platform clock, for time labeling in exporters.
    pub freq_hz: u64,
    /// Memory bank count, for power-domain naming in exporters.
    pub num_banks: u32,
    /// Total events ever recorded (≥ `events.len()`).
    pub total: u64,
    /// Per-category lifetime totals `[retire, bus, irq, power]`.
    pub counts: [u64; category::COUNT],
    /// Rolling FNV-1a64 over every encoded record ever pushed.
    pub digest: u64,
    /// The retained window, oldest to newest.
    pub events: Vec<TraceEvent>,
}

impl TraceDump {
    /// Capture a ring's current contents.
    pub fn from_ring(ring: &TraceRing, freq_hz: u64, num_banks: u32) -> Self {
        Self {
            mask: ring.mask(),
            freq_hz,
            num_banks,
            total: ring.total(),
            counts: ring.counts(),
            digest: ring.digest(),
            events: ring.events(),
        }
    }

    /// Events lost to ring wraparound before this dump was taken.
    pub fn dropped(&self) -> u64 {
        self.total - self.events.len() as u64
    }

    /// Canonical category list of the recording mask.
    pub fn categories(&self) -> String {
        super::category_list(self.mask)
    }

    /// Serialize to the framed `FEMUTRAC` form.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u8(self.mask);
        w.u64(self.freq_hz);
        w.u32(self.num_banks);
        w.u64(self.total);
        for c in self.counts {
            w.u64(c);
        }
        w.u64(self.digest);
        let mut flat = Vec::with_capacity(self.events.len() * EVENT_BYTES);
        for ev in &self.events {
            flat.extend_from_slice(&ev.encode());
        }
        w.bytes(&flat);
        let payload = w.into_payload();
        let mut bytes = Vec::with_capacity(HEADER_LEN + payload.len());
        bytes.extend_from_slice(&MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes
    }

    /// Validate and decode a framed trace.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < HEADER_LEN {
            bail!("trace truncated: {} bytes, need at least {HEADER_LEN}", bytes.len());
        }
        if bytes[..8] != MAGIC {
            bail!("not a FEMU trace (bad magic)");
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION {
            bail!("trace version {version} unsupported (this build reads version {VERSION})");
        }
        let payload_len = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        if bytes.len() - HEADER_LEN != payload_len {
            bail!(
                "trace truncated or padded: header says {payload_len} payload bytes, have {}",
                bytes.len() - HEADER_LEN
            );
        }
        let checksum = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
        let payload = &bytes[HEADER_LEN..];
        let actual = fnv1a64(payload);
        if checksum != actual {
            bail!("trace corrupt: checksum {actual:#x} != recorded {checksum:#x}");
        }

        let mut r = Reader::new(payload);
        let mask = r.u8()?;
        let freq_hz = r.u64()?;
        let num_banks = r.u32()?;
        let total = r.u64()?;
        let mut counts = [0u64; category::COUNT];
        for c in &mut counts {
            *c = r.u64()?;
        }
        let digest = r.u64()?;
        let flat = r.bytes()?;
        if let Err(e) = r.finish() {
            bail!("trace corrupt: trailing payload bytes ({e})");
        }
        if flat.len() % EVENT_BYTES != 0 {
            bail!(
                "trace corrupt: event blob of {} bytes is not a multiple of {EVENT_BYTES}",
                flat.len()
            );
        }
        let mut events = Vec::with_capacity(flat.len() / EVENT_BYTES);
        let mut last_cycle = 0u64;
        for chunk in flat.chunks_exact(EVENT_BYTES) {
            let ev = TraceEvent::decode(chunk.try_into().unwrap())?;
            if ev.cycle < last_cycle {
                bail!(
                    "trace corrupt: cycle goes backwards ({} after {last_cycle})",
                    ev.cycle
                );
            }
            last_cycle = ev.cycle;
            events.push(ev);
        }
        if (events.len() as u64) > total {
            bail!(
                "trace corrupt: {} retained events exceed recorded total {total}",
                events.len()
            );
        }
        if counts.iter().sum::<u64>() != total {
            bail!(
                "trace corrupt: per-category counts sum to {} but total is {total}",
                counts.iter().sum::<u64>()
            );
        }
        Ok(Self { mask, freq_hz, num_banks, total, counts, digest, events })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes()).with_context(|| format!("writing trace {path:?}"))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path).with_context(|| format!("reading trace {path:?}"))?;
        Self::from_bytes(&bytes).with_context(|| format!("validating trace {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::super::{bus_region, kind, TraceConfig};
    use super::*;

    fn sample_ring() -> TraceRing {
        let mut ring = TraceRing::new(TraceConfig { mask: category::ALL, depth: 64 });
        ring.retire(10, 0x180);
        ring.bus_write(14, bus_region::PERIPH, 0x2000_0000, 0x55, 3);
        ring.irq_edges(20, 0x80);
        ring.power(25, 4, 1);
        ring.retire(31, 0x184);
        ring
    }

    #[test]
    fn dump_roundtrip() {
        let dump = TraceDump::from_ring(&sample_ring(), 20_000_000, 2);
        let bytes = dump.to_bytes();
        let back = TraceDump::from_bytes(&bytes).unwrap();
        assert_eq!(back, dump);
        assert_eq!(back.total, 5);
        assert_eq!(back.dropped(), 0);
        assert_eq!(back.counts, [2, 1, 1, 1]);
    }

    #[test]
    fn frame_validation_catches_corruption() {
        let good = TraceDump::from_ring(&sample_ring(), 20_000_000, 2).to_bytes();
        assert!(TraceDump::from_bytes(&good).is_ok());

        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0xFF;
        let err = TraceDump::from_bytes(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");

        let mut short = good.clone();
        short.truncate(short.len() - 3);
        assert!(TraceDump::from_bytes(&short).is_err());
        assert!(TraceDump::from_bytes(&good[..10]).is_err());

        let mut magic = good.clone();
        magic[0] = b'X';
        let err = TraceDump::from_bytes(&magic).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");

        let mut vers = good;
        vers[8] = 0xEE;
        let err = TraceDump::from_bytes(&vers).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn payload_consistency_checks() {
        // a frame-valid payload with a bad kind byte must still be rejected
        let mut dump = TraceDump::from_ring(&sample_ring(), 20_000_000, 2);
        dump.events[0].kind = kind::POWER + 9;
        let err = TraceDump::from_bytes(&dump.to_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("kind"), "{err:#}");

        // cycles running backwards
        let mut dump = TraceDump::from_ring(&sample_ring(), 20_000_000, 2);
        dump.events[1].cycle = 1;
        let err = TraceDump::from_bytes(&dump.to_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("backwards"), "{err:#}");

        // counts out of sync with the total
        let mut dump = TraceDump::from_ring(&sample_ring(), 20_000_000, 2);
        dump.counts[0] += 1;
        let err = TraceDump::from_bytes(&dump.to_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("counts"), "{err:#}");

        // more retained events than the lifetime total
        let mut dump = TraceDump::from_ring(&sample_ring(), 20_000_000, 2);
        dump.total = 1;
        dump.counts = [1, 0, 0, 0];
        let err = TraceDump::from_bytes(&dump.to_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("exceed"), "{err:#}");
    }
}
