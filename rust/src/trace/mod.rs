//! High-throughput binary trace ring: the observability layer that turns
//! the emulator from a numbers-reproducer into a debuggable platform
//! (ROADMAP item 5; BEE's motivation is "full waveforms at high
//! throughput, no waiting on long runs").
//!
//! Four event categories, each with its own enable bit in a [`TraceRing`]
//! mask ([`category`]):
//!
//! * **retire** — one event per retired instruction (cycle, pc), recorded
//!   by both execution backends at identical timestamps: the interpreter
//!   hooks `single_step`, the block backend hooks its replay loop, and
//!   both derive the timestamp from the same per-instruction cycle
//!   accounting, so the streams are bit-identical by construction
//!   (`femu trace validate` and the CI `trace-validate` job prove it).
//! * **bus** — CPU-initiated non-SRAM transactions (peripheral and
//!   CS-bridge reads/writes, with address, value, and wait states). The
//!   SRAM fast path is deliberately unhooked: tracing must never tax the
//!   hot loop, and DMA/CGRA master traffic is visible through their own
//!   completion events.
//! * **irq** — edges on the combined interrupt lines (machine timer +
//!   fast lines), recorded where the lines are refreshed so both
//!   backends observe the same edge at the same cycle.
//! * **power** — power-state transitions per clock/power domain,
//!   recorded only on real state changes.
//!
//! The ring is fixed-capacity (power-of-two, [`TraceConfig::depth`])
//! and overwrites oldest events on wrap, so a long run keeps the newest
//! window; per-category counts and a rolling FNV-1a64 stream digest
//! cover **every** event ever recorded, including overwritten ones —
//! two runs are event-identical iff their digests and totals match.
//!
//! Cost contract: with no ring attached the hot paths pay one
//! `Option` branch; with a ring attached but a category disabled they
//! pay one more mask test. The `perf_hotpaths` bench measures
//! trace-off vs no-trace guest MIPS and the CI bench gate holds the
//! ratio (`trace_off_overhead`) at ≤3%.
//!
//! Snapshot semantics: the ring is **derived state** like backend block
//! caches — never serialized. Restore clears it and resyncs the IRQ-edge
//! baseline, so a restored platform produces no phantom events
//! (DESIGN.md §13).
//!
//! On-disk form: [`format::TraceDump`] (`FEMUTRAC`, versioned and
//! checksummed like `FEMUSNAP`); exporters to VCD and JSON-lines live in
//! [`export`]. `femu trace dump` is the CLI over both.

pub mod export;
pub mod format;

use anyhow::{bail, Result};

/// Per-category enable bits for the ring mask.
pub mod category {
    pub const RETIRE: u8 = 1 << 0;
    pub const BUS: u8 = 1 << 1;
    pub const IRQ: u8 = 1 << 2;
    pub const POWER: u8 = 1 << 3;
    pub const ALL: u8 = RETIRE | BUS | IRQ | POWER;
    /// Number of categories (indexes the per-category count array).
    pub const COUNT: usize = 4;
}

/// Event kind discriminants (byte 8 of the encoded record).
pub mod kind {
    pub const RETIRE: u8 = 1;
    pub const BUS_READ: u8 = 2;
    pub const BUS_WRITE: u8 = 3;
    pub const IRQ_RAISE: u8 = 4;
    pub const IRQ_DROP: u8 = 5;
    pub const POWER: u8 = 6;
}

/// Region codes for bus events (the `arg` byte of `BUS_*` records).
pub mod bus_region {
    pub const PERIPH: u8 = 0;
    pub const BRIDGE: u8 = 1;

    pub fn name(code: u8) -> &'static str {
        match code {
            PERIPH => "periph",
            BRIDGE => "bridge",
            _ => "unknown",
        }
    }
}

/// Encoded size of one event record (fixed-width, little-endian).
pub const EVENT_BYTES: usize = 20;

/// Default ring capacity in events.
pub const DEFAULT_DEPTH: usize = 1 << 16;

/// FNV-1a 64-bit offset basis (same family as the snapshot checksum).
pub(crate) const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Fold `bytes` into a rolling FNV-1a64 state.
pub(crate) fn fnv1a64_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One-shot FNV-1a64 of a buffer.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_fold(FNV_OFFSET, bytes)
}

/// Parse a category list: `all`, `none`, or a comma-separated subset of
/// `retire,bus,irq,power`.
pub fn parse_categories(s: &str) -> Result<u8> {
    match s.trim() {
        "all" => return Ok(category::ALL),
        "none" | "" => return Ok(0),
        _ => {}
    }
    let mut mask = 0u8;
    for part in s.split(',') {
        mask |= match part.trim() {
            "retire" => category::RETIRE,
            "bus" => category::BUS,
            "irq" => category::IRQ,
            "power" => category::POWER,
            other => bail!("unknown trace category `{other}` (want retire|bus|irq|power|all|none)"),
        };
    }
    Ok(mask)
}

/// Render a mask back to its canonical category list.
pub fn category_list(mask: u8) -> String {
    if mask == 0 {
        return "none".into();
    }
    let mut parts = Vec::new();
    for (bit, name) in [
        (category::RETIRE, "retire"),
        (category::BUS, "bus"),
        (category::IRQ, "irq"),
        (category::POWER, "power"),
    ] {
        if mask & bit != 0 {
            parts.push(name);
        }
    }
    parts.join(",")
}

/// Ring configuration (the `[trace]` TOML table / `--trace` CLI flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceConfig {
    /// Category enable mask; 0 means tracing is off (no ring attached
    /// when configured through [`crate::soc::SocConfig`]).
    pub mask: u8,
    /// Ring capacity in events (rounded up to a power of two, min 16).
    pub depth: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { mask: 0, depth: DEFAULT_DEPTH }
    }
}

/// One trace record. Fixed-width so the on-disk form, the digest input,
/// and the in-memory form are the same 20 bytes:
///
/// ```text
/// cycle u64 | kind u8 | arg u8 | aux u16 | a u32 | b u32
/// ```
///
/// Field meaning per kind:
///
/// | kind        | arg          | aux        | a            | b     |
/// |-------------|--------------|------------|--------------|-------|
/// | `RETIRE`    | 0            | 0          | pc           | 0     |
/// | `BUS_READ`  | region code  | wait cycles| address      | value |
/// | `BUS_WRITE` | region code  | wait cycles| address      | value |
/// | `IRQ_RAISE` | line bit     | 0          | lines after  | 0     |
/// | `IRQ_DROP`  | line bit     | 0          | lines after  | 0     |
/// | `POWER`     | power state  | domain idx | 0            | 0     |
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub cycle: u64,
    pub kind: u8,
    pub arg: u8,
    pub aux: u16,
    pub a: u32,
    pub b: u32,
}

impl TraceEvent {
    pub fn encode(&self) -> [u8; EVENT_BYTES] {
        let mut out = [0u8; EVENT_BYTES];
        out[0..8].copy_from_slice(&self.cycle.to_le_bytes());
        out[8] = self.kind;
        out[9] = self.arg;
        out[10..12].copy_from_slice(&self.aux.to_le_bytes());
        out[12..16].copy_from_slice(&self.a.to_le_bytes());
        out[16..20].copy_from_slice(&self.b.to_le_bytes());
        out
    }

    /// Decode one record, rejecting unknown kinds (corruption guard).
    pub fn decode(b: &[u8; EVENT_BYTES]) -> Result<TraceEvent> {
        let ev = TraceEvent {
            cycle: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            kind: b[8],
            arg: b[9],
            aux: u16::from_le_bytes(b[10..12].try_into().unwrap()),
            a: u32::from_le_bytes(b[12..16].try_into().unwrap()),
            b: u32::from_le_bytes(b[16..20].try_into().unwrap()),
        };
        if !(kind::RETIRE..=kind::POWER).contains(&ev.kind) {
            bail!("trace corrupt: unknown event kind {}", ev.kind);
        }
        Ok(ev)
    }

    /// The category bit this event belongs to.
    pub fn category(&self) -> u8 {
        match self.kind {
            kind::RETIRE => category::RETIRE,
            kind::BUS_READ | kind::BUS_WRITE => category::BUS,
            kind::IRQ_RAISE | kind::IRQ_DROP => category::IRQ,
            _ => category::POWER,
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            kind::RETIRE => "retire",
            kind::BUS_READ => "bus_read",
            kind::BUS_WRITE => "bus_write",
            kind::IRQ_RAISE => "irq_raise",
            kind::IRQ_DROP => "irq_drop",
            kind::POWER => "power",
            _ => "unknown",
        }
    }
}

/// Per-category count index: retire=0, bus=1, irq=2, power=3.
fn count_index(kind: u8) -> usize {
    match kind {
        kind::RETIRE => 0,
        kind::BUS_READ | kind::BUS_WRITE => 1,
        kind::IRQ_RAISE | kind::IRQ_DROP => 2,
        _ => 3,
    }
}

/// The live ring. Owned by the bus (`soc.bus.trace`) so the CPU step
/// paths, the bus itself, and the SoC event hooks can all reach it with
/// one `Option` branch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRing {
    mask: u8,
    cap: usize,
    buf: Vec<TraceEvent>,
    /// Total events ever recorded (monotone; `head % cap` is the write
    /// slot, so wraparound keeps the newest `cap` events).
    head: u64,
    counts: [u64; category::COUNT],
    digest: u64,
    /// Last observed combined IRQ lines (edge-detection baseline). Kept
    /// current even when the `irq` category is disabled, so enabling it
    /// mid-run never manufactures stale edges.
    last_irq_lines: u32,
}

impl TraceRing {
    pub fn new(cfg: TraceConfig) -> Self {
        let cap = cfg.depth.max(16).next_power_of_two();
        Self {
            mask: cfg.mask,
            cap,
            buf: Vec::new(),
            head: 0,
            counts: [0; category::COUNT],
            digest: FNV_OFFSET,
            last_irq_lines: 0,
        }
    }

    pub fn mask(&self) -> u8 {
        self.mask
    }

    pub fn set_mask(&mut self, mask: u8) {
        self.mask = mask;
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total events ever recorded (including those overwritten on wrap).
    pub fn total(&self) -> u64 {
        self.head
    }

    /// Events currently retained.
    pub fn len(&self) -> usize {
        self.head.min(self.cap as u64) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.head == 0
    }

    /// Events lost to wraparound.
    pub fn dropped(&self) -> u64 {
        self.head - self.len() as u64
    }

    /// Rolling FNV-1a64 over every encoded record ever pushed.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Per-category totals: `[retire, bus, irq, power]`.
    pub fn counts(&self) -> [u64; category::COUNT] {
        self.counts
    }

    pub fn retires(&self) -> u64 {
        self.counts[0]
    }

    pub fn irq_events(&self) -> u64 {
        self.counts[2]
    }

    fn push(&mut self, ev: TraceEvent) {
        self.digest = fnv1a64_fold(self.digest, &ev.encode());
        self.counts[count_index(ev.kind)] += 1;
        let slot = (self.head % self.cap as u64) as usize;
        if slot < self.buf.len() {
            self.buf[slot] = ev;
        } else {
            self.buf.push(ev);
        }
        self.head += 1;
    }

    /// Record an instruction retire. One mask test when the category is
    /// disabled — the hot-path cost the bench gate enforces.
    #[inline]
    pub fn retire(&mut self, cycle: u64, pc: u32) {
        if self.mask & category::RETIRE == 0 {
            return;
        }
        self.push(TraceEvent { cycle, kind: kind::RETIRE, arg: 0, aux: 0, a: pc, b: 0 });
    }

    /// Record a CPU-initiated non-SRAM read.
    #[inline]
    pub fn bus_read(&mut self, cycle: u64, region: u8, addr: u32, value: u32, wait: u32) {
        if self.mask & category::BUS == 0 {
            return;
        }
        self.push(TraceEvent {
            cycle,
            kind: kind::BUS_READ,
            arg: region,
            aux: wait.min(u16::MAX as u32) as u16,
            a: addr,
            b: value,
        });
    }

    /// Record a CPU-initiated non-SRAM write.
    #[inline]
    pub fn bus_write(&mut self, cycle: u64, region: u8, addr: u32, value: u32, wait: u32) {
        if self.mask & category::BUS == 0 {
            return;
        }
        self.push(TraceEvent {
            cycle,
            kind: kind::BUS_WRITE,
            arg: region,
            aux: wait.min(u16::MAX as u32) as u16,
            a: addr,
            b: value,
        });
    }

    /// Observe the combined IRQ lines; records one `IRQ_RAISE`/`IRQ_DROP`
    /// per changed bit (raises first, ascending bit order). The baseline
    /// updates even when the category is disabled.
    #[inline]
    pub fn irq_edges(&mut self, cycle: u64, lines: u32) {
        let prev = self.last_irq_lines;
        if lines == prev {
            return;
        }
        self.last_irq_lines = lines;
        if self.mask & category::IRQ == 0 {
            return;
        }
        let mut raised = lines & !prev;
        while raised != 0 {
            let bit = raised.trailing_zeros();
            raised &= raised - 1;
            self.push(TraceEvent {
                cycle,
                kind: kind::IRQ_RAISE,
                arg: bit as u8,
                aux: 0,
                a: lines,
                b: 0,
            });
        }
        let mut dropped = prev & !lines;
        while dropped != 0 {
            let bit = dropped.trailing_zeros();
            dropped &= dropped - 1;
            self.push(TraceEvent {
                cycle,
                kind: kind::IRQ_DROP,
                arg: bit as u8,
                aux: 0,
                a: lines,
                b: 0,
            });
        }
    }

    /// Record a power-state transition (callers gate on real changes).
    #[inline]
    pub fn power(&mut self, cycle: u64, domain: u16, state: u8) {
        if self.mask & category::POWER == 0 {
            return;
        }
        self.push(TraceEvent { cycle, kind: kind::POWER, arg: state, aux: domain, a: 0, b: 0 });
    }

    /// Reset the IRQ-edge baseline without recording events (used after
    /// snapshot restore, so the restored line state never reads as an
    /// edge).
    pub fn resync(&mut self, lines: u32) {
        self.last_irq_lines = lines;
    }

    /// Drop all recorded events and counts; keeps mask and capacity.
    /// (Snapshot restore calls this — the ring is derived state.)
    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.counts = [0; category::COUNT];
        self.digest = FNV_OFFSET;
    }

    /// Retained events, oldest to newest.
    pub fn events(&self) -> Vec<TraceEvent> {
        let len = self.len() as u64;
        (self.head - len..self.head)
            .map(|i| self.buf[(i % self.cap as u64) as usize])
            .collect()
    }

    /// Cursor-based drain for streaming (`trace.read`): returns up to
    /// `max` events starting at absolute event index `cursor`, the next
    /// cursor value, and how many events between `cursor` and the first
    /// returned one were already lost to wraparound.
    pub fn events_from(&self, cursor: u64, max: usize) -> (Vec<TraceEvent>, u64, u64) {
        let oldest = self.head - self.len() as u64;
        let start = cursor.clamp(oldest, self.head);
        let skipped = start.saturating_sub(cursor);
        let end = self.head.min(start + max as u64);
        let evs =
            (start..end).map(|i| self.buf[(i % self.cap as u64) as usize]).collect();
        (evs, end, skipped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_parse_roundtrip() {
        assert_eq!(parse_categories("all").unwrap(), category::ALL);
        assert_eq!(parse_categories("none").unwrap(), 0);
        assert_eq!(parse_categories("retire,irq").unwrap(), category::RETIRE | category::IRQ);
        assert_eq!(parse_categories(" power , bus ").unwrap(), category::POWER | category::BUS);
        assert!(parse_categories("waveform").is_err());
        assert_eq!(category_list(category::ALL), "retire,bus,irq,power");
        assert_eq!(category_list(0), "none");
        for mask in 0..=category::ALL {
            assert_eq!(parse_categories(&category_list(mask)).unwrap(), mask);
        }
    }

    #[test]
    fn event_codec_roundtrip() {
        let ev = TraceEvent {
            cycle: 0x0123_4567_89AB_CDEF,
            kind: kind::BUS_WRITE,
            arg: bus_region::BRIDGE,
            aux: 0xBEEF,
            a: 0xDEAD_0000,
            b: 0x1234_5678,
        };
        assert_eq!(TraceEvent::decode(&ev.encode()).unwrap(), ev);
        let mut bad = ev.encode();
        bad[8] = 0xEE;
        assert!(TraceEvent::decode(&bad).is_err());
    }

    #[test]
    fn masked_categories_record_nothing() {
        let mut ring = TraceRing::new(TraceConfig { mask: category::RETIRE, depth: 64 });
        ring.retire(10, 0x180);
        ring.bus_read(11, bus_region::PERIPH, 0x2000_0000, 7, 2);
        ring.power(12, 3, 1);
        ring.irq_edges(13, 0x80);
        assert_eq!(ring.total(), 1);
        assert_eq!(ring.counts(), [1, 0, 0, 0]);
        // baseline still tracked: enabling irq later sees no stale edge
        ring.set_mask(category::ALL);
        ring.irq_edges(14, 0x80);
        assert_eq!(ring.irq_events(), 0);
        ring.irq_edges(15, 0);
        assert_eq!(ring.irq_events(), 1);
    }

    #[test]
    fn wraparound_keeps_newest() {
        let mut ring = TraceRing::new(TraceConfig { mask: category::ALL, depth: 16 });
        assert_eq!(ring.capacity(), 16);
        for i in 0..100u64 {
            ring.retire(i, i as u32);
        }
        assert_eq!(ring.total(), 100);
        assert_eq!(ring.len(), 16);
        assert_eq!(ring.dropped(), 84);
        let evs = ring.events();
        assert_eq!(evs.len(), 16);
        assert_eq!(evs.first().unwrap().cycle, 84);
        assert_eq!(evs.last().unwrap().cycle, 99);
        // digest covers all 100 events: identical replay, identical digest
        let mut replay = TraceRing::new(TraceConfig { mask: category::ALL, depth: 1024 });
        for i in 0..100u64 {
            replay.retire(i, i as u32);
        }
        assert_eq!(ring.digest(), replay.digest());
    }

    #[test]
    fn irq_edges_decompose_per_bit() {
        let mut ring = TraceRing::new(TraceConfig { mask: category::ALL, depth: 64 });
        ring.irq_edges(5, 0b101);
        ring.irq_edges(9, 0b010);
        let evs = ring.events();
        assert_eq!(evs.len(), 5);
        assert_eq!((evs[0].kind, evs[0].arg), (kind::IRQ_RAISE, 0));
        assert_eq!((evs[1].kind, evs[1].arg), (kind::IRQ_RAISE, 2));
        assert_eq!((evs[2].kind, evs[2].arg), (kind::IRQ_RAISE, 1));
        assert_eq!((evs[3].kind, evs[3].arg), (kind::IRQ_DROP, 0));
        assert_eq!((evs[4].kind, evs[4].arg), (kind::IRQ_DROP, 2));
        assert_eq!(evs[0].cycle, 5);
        assert_eq!(evs[1].cycle, 5);
        assert_eq!(evs[2].cycle, 9);
        assert_eq!(evs[4].a, 0b010); // lines-after snapshot on every edge
    }

    #[test]
    fn cursor_stream_drains_and_reports_loss() {
        let mut ring = TraceRing::new(TraceConfig { mask: category::ALL, depth: 16 });
        for i in 0..10u64 {
            ring.retire(i, 0);
        }
        let (evs, next, skipped) = ring.events_from(0, 4);
        assert_eq!((evs.len(), next, skipped), (4, 4, 0));
        let (evs, next, skipped) = ring.events_from(next, 100);
        assert_eq!((evs.len(), next, skipped), (6, 10, 0));
        // overflow the ring past the reader's cursor
        for i in 10..40u64 {
            ring.retire(i, 0);
        }
        let (evs, next, skipped) = ring.events_from(10, 1000);
        assert_eq!(evs.len(), 16);
        assert_eq!(next, 40);
        assert_eq!(skipped, 14); // events 10..24 were overwritten
        // cursor beyond head clamps to empty
        let (evs, next, _) = ring.events_from(1000, 10);
        assert!(evs.is_empty());
        assert_eq!(next, 40);
    }

    #[test]
    fn clear_resets_everything_but_identity() {
        let mut ring = TraceRing::new(TraceConfig { mask: category::ALL, depth: 32 });
        ring.retire(1, 2);
        ring.irq_edges(2, 1);
        ring.clear();
        assert_eq!(ring.total(), 0);
        assert_eq!(ring.counts(), [0; category::COUNT]);
        assert_eq!(ring.digest(), FNV_OFFSET);
        assert_eq!(ring.mask(), category::ALL);
        // resync: restoring into asserted lines is not an edge
        ring.resync(1);
        ring.irq_edges(3, 1);
        assert_eq!(ring.irq_events(), 0);
    }
}
