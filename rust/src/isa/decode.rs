//! RV32IM instruction decoder.
//!
//! Decodes a raw 32-bit instruction word into [`Instr`]. Unknown encodings
//! return `None`; the CPU raises an illegal-instruction trap for those.

use super::{AluOp, BranchOp, CsrOp, Instr, LoadOp, StoreOp};

#[inline]
fn bits(word: u32, hi: u32, lo: u32) -> u32 {
    (word >> lo) & ((1 << (hi - lo + 1)) - 1)
}

#[inline]
fn sign_extend(value: u32, bits: u32) -> i32 {
    let shift = 32 - bits;
    ((value << shift) as i32) >> shift
}

#[inline]
fn imm_i(word: u32) -> i32 {
    sign_extend(bits(word, 31, 20), 12)
}

#[inline]
fn imm_s(word: u32) -> i32 {
    sign_extend((bits(word, 31, 25) << 5) | bits(word, 11, 7), 12)
}

#[inline]
fn imm_b(word: u32) -> i32 {
    sign_extend(
        (bits(word, 31, 31) << 12)
            | (bits(word, 7, 7) << 11)
            | (bits(word, 30, 25) << 5)
            | (bits(word, 11, 8) << 1),
        13,
    )
}

#[inline]
fn imm_u(word: u32) -> i32 {
    (word & 0xFFFF_F000) as i32
}

#[inline]
fn imm_j(word: u32) -> i32 {
    sign_extend(
        (bits(word, 31, 31) << 20)
            | (bits(word, 19, 12) << 12)
            | (bits(word, 20, 20) << 11)
            | (bits(word, 30, 21) << 1),
        21,
    )
}

/// Decode one 32-bit instruction word. Returns `None` for encodings
/// outside the supported RV32IM+Zicsr subset.
pub fn decode(word: u32) -> Option<Instr> {
    let opcode = bits(word, 6, 0);
    let rd = bits(word, 11, 7) as u8;
    let rs1 = bits(word, 19, 15) as u8;
    let rs2 = bits(word, 24, 20) as u8;
    let funct3 = bits(word, 14, 12);
    let funct7 = bits(word, 31, 25);

    Some(match opcode {
        0b0110111 => Instr::Lui { rd, imm: imm_u(word) },
        0b0010111 => Instr::Auipc { rd, imm: imm_u(word) },
        0b1101111 => Instr::Jal { rd, imm: imm_j(word) },
        0b1100111 if funct3 == 0 => Instr::Jalr { rd, rs1, imm: imm_i(word) },
        0b1100011 => {
            let op = match funct3 {
                0b000 => BranchOp::Eq,
                0b001 => BranchOp::Ne,
                0b100 => BranchOp::Lt,
                0b101 => BranchOp::Ge,
                0b110 => BranchOp::Ltu,
                0b111 => BranchOp::Geu,
                _ => return None,
            };
            Instr::Branch { op, rs1, rs2, imm: imm_b(word) }
        }
        0b0000011 => {
            let op = match funct3 {
                0b000 => LoadOp::Lb,
                0b001 => LoadOp::Lh,
                0b010 => LoadOp::Lw,
                0b100 => LoadOp::Lbu,
                0b101 => LoadOp::Lhu,
                _ => return None,
            };
            Instr::Load { op, rd, rs1, imm: imm_i(word) }
        }
        0b0100011 => {
            let op = match funct3 {
                0b000 => StoreOp::Sb,
                0b001 => StoreOp::Sh,
                0b010 => StoreOp::Sw,
                _ => return None,
            };
            Instr::Store { op, rs1, rs2, imm: imm_s(word) }
        }
        0b0010011 => {
            let imm = imm_i(word);
            let op = match funct3 {
                0b000 => AluOp::Add,
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                0b001 if funct7 == 0 => AluOp::Sll,
                0b101 if funct7 == 0 => AluOp::Srl,
                0b101 if funct7 == 0b0100000 => AluOp::Sra,
                _ => return None,
            };
            // shift-immediates keep only shamt in imm
            let imm = match op {
                AluOp::Sll | AluOp::Srl | AluOp::Sra => (imm & 0x1F) as i32,
                _ => imm,
            };
            Instr::OpImm { op, rd, rs1, imm }
        }
        0b0110011 => {
            let op = match (funct7, funct3) {
                (0b0000000, 0b000) => AluOp::Add,
                (0b0100000, 0b000) => AluOp::Sub,
                (0b0000000, 0b001) => AluOp::Sll,
                (0b0000000, 0b010) => AluOp::Slt,
                (0b0000000, 0b011) => AluOp::Sltu,
                (0b0000000, 0b100) => AluOp::Xor,
                (0b0000000, 0b101) => AluOp::Srl,
                (0b0100000, 0b101) => AluOp::Sra,
                (0b0000000, 0b110) => AluOp::Or,
                (0b0000000, 0b111) => AluOp::And,
                (0b0000001, 0b000) => AluOp::Mul,
                (0b0000001, 0b001) => AluOp::Mulh,
                (0b0000001, 0b010) => AluOp::Mulhsu,
                (0b0000001, 0b011) => AluOp::Mulhu,
                (0b0000001, 0b100) => AluOp::Div,
                (0b0000001, 0b101) => AluOp::Divu,
                (0b0000001, 0b110) => AluOp::Rem,
                (0b0000001, 0b111) => AluOp::Remu,
                _ => return None,
            };
            Instr::Op { op, rd, rs1, rs2 }
        }
        0b0001111 => Instr::Fence, // fence / fence.i — no-ops in this model
        0b1110011 => match funct3 {
            0b000 => match word {
                0x0000_0073 => Instr::Ecall,
                0x0010_0073 => Instr::Ebreak,
                0x1050_0073 => Instr::Wfi,
                0x3020_0073 => Instr::Mret,
                _ => return None,
            },
            0b001 => Instr::Csr { op: CsrOp::Rw, rd, rs1, csr: bits(word, 31, 20) as u16, imm: false },
            0b010 => Instr::Csr { op: CsrOp::Rs, rd, rs1, csr: bits(word, 31, 20) as u16, imm: false },
            0b011 => Instr::Csr { op: CsrOp::Rc, rd, rs1, csr: bits(word, 31, 20) as u16, imm: false },
            0b101 => Instr::Csr { op: CsrOp::Rw, rd, rs1, csr: bits(word, 31, 20) as u16, imm: true },
            0b110 => Instr::Csr { op: CsrOp::Rs, rd, rs1, csr: bits(word, 31, 20) as u16, imm: true },
            0b111 => Instr::Csr { op: CsrOp::Rc, rd, rs1, csr: bits(word, 31, 20) as u16, imm: true },
            _ => return None,
        },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // Known encodings cross-checked against the RISC-V spec / GNU as.
    #[test]
    fn decode_known_words() {
        // addi x1, x0, 42  -> 0x02A00093
        assert_eq!(
            decode(0x02A0_0093),
            Some(Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 0, imm: 42 })
        );
        // lui a0, 0x12345 -> 0x12345537
        assert_eq!(decode(0x1234_5537), Some(Instr::Lui { rd: 10, imm: 0x1234_5000 }));
        // add x3, x1, x2 -> 0x002081B3
        assert_eq!(decode(0x0020_81B3), Some(Instr::Op { op: AluOp::Add, rd: 3, rs1: 1, rs2: 2 }));
        // mul x5, x6, x7 -> 0x027302B3
        assert_eq!(decode(0x0273_02B3), Some(Instr::Op { op: AluOp::Mul, rd: 5, rs1: 6, rs2: 7 }));
        // lw x8, -4(x2) -> 0xFFC12403
        assert_eq!(
            decode(0xFFC1_2403),
            Some(Instr::Load { op: LoadOp::Lw, rd: 8, rs1: 2, imm: -4 })
        );
        // sw x8, 8(x2) -> 0x00812423
        assert_eq!(
            decode(0x0081_2423),
            Some(Instr::Store { op: StoreOp::Sw, rs1: 2, rs2: 8, imm: 8 })
        );
        // beq x1, x2, +8 -> 0x00208463
        assert_eq!(
            decode(0x0020_8463),
            Some(Instr::Branch { op: BranchOp::Eq, rs1: 1, rs2: 2, imm: 8 })
        );
        // jal ra, +16 -> 0x010000EF
        assert_eq!(decode(0x0100_00EF), Some(Instr::Jal { rd: 1, imm: 16 }));
        // srai x1, x1, 3 -> 0x4030D093
        assert_eq!(
            decode(0x4030_D093),
            Some(Instr::OpImm { op: AluOp::Sra, rd: 1, rs1: 1, imm: 3 })
        );
        // ecall / ebreak / wfi / mret
        assert_eq!(decode(0x0000_0073), Some(Instr::Ecall));
        assert_eq!(decode(0x0010_0073), Some(Instr::Ebreak));
        assert_eq!(decode(0x1050_0073), Some(Instr::Wfi));
        assert_eq!(decode(0x3020_0073), Some(Instr::Mret));
        // csrrw x0, mstatus(0x300), x1 -> 0x30009073
        assert_eq!(
            decode(0x3000_9073),
            Some(Instr::Csr { op: CsrOp::Rw, rd: 0, rs1: 1, csr: 0x300, imm: false })
        );
    }

    #[test]
    fn negative_branch_offset() {
        // bne x5, x6, -12 -> imm_b encoding; from GNU as: 0xFE629AE3
        assert_eq!(
            decode(0xFE62_9AE3),
            Some(Instr::Branch { op: BranchOp::Ne, rs1: 5, rs2: 6, imm: -12 })
        );
    }

    #[test]
    fn rejects_unknown() {
        assert_eq!(decode(0x0000_0000), None);
        assert_eq!(decode(0xFFFF_FFFF), None);
        // opcode 0b1100111 with funct3 != 0 is not jalr
        assert_eq!(decode(0x0000_9067), None);
    }
}
