//! Two-pass RV32IM assembler.
//!
//! Guest programs (the case-study kernels, the acquisition loops, the
//! end-to-end TinyAI app in [`crate::workloads`]) are written in assembly
//! text and assembled here into a loadable [`Program`]. Supported syntax:
//!
//! * labels (`loop:`), `.text` / `.data` sections
//! * data directives: `.word`, `.half`, `.byte` (values or label refs),
//!   `.space N`, `.align N`, `.equ NAME, value`
//! * all RV32IM+Zicsr instructions from [`super::Instr`]
//! * pseudo-instructions: `nop`, `li`, `la`, `mv`, `not`, `neg`, `j`,
//!   `jr`, `ret`, `call`, `beqz`, `bnez`, `blez`, `bgez`, `bltz`, `bgtz`,
//!   `seqz`, `snez`, `csrr`, `csrw`, `csrsi`, `csrci`
//! * named CSRs (`mstatus`, `mie`, ... ) and ABI or `xN` register names
//! * `%hi(sym)` / `%lo(sym)` relocations in `lui` / `addi` / loads / stores
//!
//! Diagnostics carry line numbers. Addresses: `.text` is placed at
//! `Options::text_base`, `.data` at `Options::data_base`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::{encode, parse_reg, AluOp, BranchOp, CsrOp, Instr, LoadOp, Reg, StoreOp};

/// Assembly output: words for the text section, bytes for the data
/// section, and the symbol table.
#[derive(Clone, Debug)]
pub struct Program {
    pub text: Vec<u32>,
    pub data: Vec<u8>,
    pub text_base: u32,
    pub data_base: u32,
    pub symbols: BTreeMap<String, u32>,
    /// Entry point (address of the `_start` symbol if present, else
    /// `text_base`).
    pub entry: u32,
}

impl Program {
    pub fn symbol(&self, name: &str) -> Result<u32> {
        self.symbols
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("unknown symbol `{name}`"))
    }
}

/// Assembler placement options.
#[derive(Clone, Copy, Debug)]
pub struct Options {
    pub text_base: u32,
    pub data_base: u32,
}

impl Default for Options {
    fn default() -> Self {
        // Matches the emulated X-HEEP address map (crate::soc): code in
        // SRAM bank 0, data in SRAM bank 1.
        Self { text_base: 0x0000_0000, data_base: 0x0002_0000 }
    }
}

/// Assemble with default placement.
pub fn assemble(src: &str) -> Result<Program> {
    assemble_with(src, Options::default())
}

/// Assemble with explicit section bases.
pub fn assemble_with(src: &str, opts: Options) -> Result<Program> {
    let lines = preprocess(src);
    let mut asm = Assembler::new(opts);
    asm.pass1(&lines)?;
    asm.pass2(&lines)?;
    let entry = asm.symbols.get("_start").copied().unwrap_or(opts.text_base);
    Ok(Program {
        text: asm.text,
        data: asm.data,
        text_base: opts.text_base,
        data_base: opts.data_base,
        symbols: asm.symbols,
        entry,
    })
}

#[derive(Clone, Debug)]
struct Line {
    no: usize,
    label: Option<String>,
    op: Option<String>,
    args: Vec<String>,
}

/// Strip comments, split labels, tokenize operands.
fn preprocess(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        let mut line = raw;
        if let Some(p) = line.find(['#', ';']) {
            line = &line[..p];
        }
        if let Some(p) = line.find("//") {
            line = &line[..p];
        }
        let mut line = line.trim();
        let mut label = None;
        if let Some(colon) = line.find(':') {
            let (l, rest) = line.split_at(colon);
            let l = l.trim();
            if !l.is_empty() && l.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '.') {
                label = Some(l.to_string());
                line = rest[1..].trim();
            }
        }
        let (op, args) = match line.split_whitespace().next() {
            None => (None, Vec::new()),
            Some(op) => {
                let rest = line[op.len()..].trim();
                let args = split_args(rest);
                (Some(op.to_lowercase()), args)
            }
        };
        if label.is_some() || op.is_some() {
            out.push(Line { no: i + 1, label, op, args });
        }
    }
    out
}

/// Split operands on commas, but keep `off(reg)` together and respect
/// parentheses in `%lo(sym)(reg)` forms.
fn split_args(s: &str) -> Vec<String> {
    let mut args = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' => {
                depth += 1;
                cur.push(c);
            }
            ')' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if depth == 0 => {
                args.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        args.push(cur.trim().to_string());
    }
    args
}

#[derive(Clone, Copy, PartialEq)]
enum Section {
    Text,
    Data,
}

struct Assembler {
    opts: Options,
    symbols: BTreeMap<String, u32>,
    equs: BTreeMap<String, i64>,
    text: Vec<u32>,
    data: Vec<u8>,
}

impl Assembler {
    fn new(opts: Options) -> Self {
        Self {
            opts,
            symbols: BTreeMap::new(),
            equs: BTreeMap::new(),
            text: Vec::new(),
            data: Vec::new(),
        }
    }

    /// Pass 1: compute section sizes and record symbol addresses.
    fn pass1(&mut self, lines: &[Line]) -> Result<()> {
        let mut section = Section::Text;
        let mut text_pc = self.opts.text_base;
        let mut data_pc = self.opts.data_base;
        for line in lines {
            let res: Result<()> = (|| {
                if let Some(label) = &line.label {
                    let addr = if section == Section::Text { text_pc } else { data_pc };
                    if self.symbols.insert(label.clone(), addr).is_some() {
                        bail!("duplicate label `{label}`");
                    }
                }
                let Some(op) = &line.op else { return Ok(()) };
                match op.as_str() {
                    ".text" => section = Section::Text,
                    ".data" => section = Section::Data,
                    ".global" | ".globl" | ".section" | ".option" => {}
                    ".equ" | ".set" => {
                        if line.args.len() != 2 {
                            bail!(".equ wants `NAME, value`");
                        }
                        let v = self.imm_value(&line.args[1])?;
                        self.equs.insert(line.args[0].clone(), v);
                    }
                    ".word" => {
                        self.expect_data(section, op)?;
                        data_pc += 4 * line.args.len() as u32;
                    }
                    ".half" => {
                        self.expect_data(section, op)?;
                        data_pc += 2 * line.args.len() as u32;
                    }
                    ".byte" => {
                        self.expect_data(section, op)?;
                        data_pc += line.args.len() as u32;
                    }
                    ".space" => {
                        self.expect_data(section, op)?;
                        data_pc += self.imm_value(&line.args[0])? as u32;
                    }
                    ".align" => {
                        let a = 1u32 << self.imm_value(&line.args[0])?;
                        match section {
                            Section::Text => text_pc = text_pc.next_multiple_of(a),
                            Section::Data => data_pc = data_pc.next_multiple_of(a),
                        }
                    }
                    _ if op.starts_with('.') => bail!("unknown directive `{op}`"),
                    _ => {
                        if section != Section::Text {
                            bail!("instruction `{op}` outside .text");
                        }
                        text_pc += 4 * self.instr_size(op, &line.args)? as u32;
                    }
                }
                Ok(())
            })();
            res.with_context(|| format!("line {}", line.no))?;
        }
        Ok(())
    }

    fn expect_data(&self, section: Section, op: &str) -> Result<()> {
        if section != Section::Data {
            bail!("`{op}` outside .data");
        }
        Ok(())
    }

    /// Number of 32-bit words an instruction expands to.
    fn instr_size(&self, op: &str, args: &[String]) -> Result<usize> {
        Ok(match op {
            "li" => {
                let v = self.imm_value(args.get(1).map(String::as_str).unwrap_or("0"))?;
                if (-2048..2048).contains(&v) {
                    1
                } else {
                    2
                }
            }
            "la" | "call" => 2,
            _ => 1,
        })
    }

    /// Pass 2: encode.
    fn pass2(&mut self, lines: &[Line]) -> Result<()> {
        let mut section = Section::Text;
        let mut text_pc = self.opts.text_base;
        let mut data_pc = self.opts.data_base;
        for line in lines {
            let res: Result<()> = (|| {
                let Some(op) = &line.op else { return Ok(()) };
                match op.as_str() {
                    ".text" => section = Section::Text,
                    ".data" => section = Section::Data,
                    ".global" | ".globl" | ".section" | ".option" | ".equ" | ".set" => {}
                    ".word" => {
                        for a in &line.args {
                            let v = self.value_or_symbol(a)? as u32;
                            self.data.extend_from_slice(&v.to_le_bytes());
                            data_pc += 4;
                        }
                    }
                    ".half" => {
                        for a in &line.args {
                            let v = self.value_or_symbol(a)? as u16;
                            self.data.extend_from_slice(&v.to_le_bytes());
                            data_pc += 2;
                        }
                    }
                    ".byte" => {
                        for a in &line.args {
                            self.data.push(self.value_or_symbol(a)? as u8);
                            data_pc += 1;
                        }
                    }
                    ".space" => {
                        let n = self.imm_value(&line.args[0])? as usize;
                        self.data.extend(std::iter::repeat(0u8).take(n));
                        data_pc += n as u32;
                    }
                    ".align" => {
                        let a = 1u32 << self.imm_value(&line.args[0])?;
                        match section {
                            Section::Text => {
                                while text_pc % a != 0 {
                                    self.text.push(encode(Instr::OpImm {
                                        op: AluOp::Add,
                                        rd: 0,
                                        rs1: 0,
                                        imm: 0,
                                    }));
                                    text_pc += 4;
                                }
                            }
                            Section::Data => {
                                while data_pc % a != 0 {
                                    self.data.push(0);
                                    data_pc += 1;
                                }
                            }
                        }
                    }
                    _ => {
                        let instrs = self.encode_instr(op, &line.args, text_pc)?;
                        for i in instrs {
                            self.text.push(encode(i));
                            text_pc += 4;
                        }
                    }
                }
                Ok(())
            })();
            res.with_context(|| format!("line {}", line.no))?;
        }
        Ok(())
    }

    // ---- operand parsing -------------------------------------------------

    fn reg(&self, s: &str) -> Result<Reg> {
        parse_reg(s).ok_or_else(|| anyhow!("bad register `{s}`"))
    }

    /// A pure numeric immediate or `.equ` constant (no labels).
    fn imm_value(&self, s: &str) -> Result<i64> {
        if let Some(v) = self.equs.get(s) {
            return Ok(*v);
        }
        parse_int(s).ok_or_else(|| anyhow!("bad immediate `{s}`"))
    }

    /// Immediate, `.equ` constant, label address, or %hi/%lo relocation.
    fn value_or_symbol(&self, s: &str) -> Result<i64> {
        if let Some(inner) = s.strip_prefix("%hi(").and_then(|r| r.strip_suffix(')')) {
            let v = self.value_or_symbol(inner)?;
            return Ok(((v as u32).wrapping_add(0x800) >> 12) as i64);
        }
        if let Some(inner) = s.strip_prefix("%lo(").and_then(|r| r.strip_suffix(')')) {
            let v = self.value_or_symbol(inner)? as u32;
            return Ok(((v & 0xFFF) as i32 as i64).wrapping_sub(if v & 0x800 != 0 { 4096 } else { 0 }));
        }
        if let Some(v) = self.equs.get(s) {
            return Ok(*v);
        }
        if let Some(v) = parse_int(s) {
            return Ok(v);
        }
        self.symbols
            .get(s)
            .map(|&a| a as i64)
            .ok_or_else(|| anyhow!("unknown symbol or bad value `{s}`"))
    }

    fn imm12(&self, s: &str) -> Result<i32> {
        let v = self.value_or_symbol(s)?;
        if !(-2048..2048).contains(&v) {
            bail!("immediate {v} out of 12-bit range");
        }
        Ok(v as i32)
    }

    /// Parse `off(reg)` or `%lo(sym)(reg)` memory operands.
    fn mem_operand(&self, s: &str) -> Result<(i32, Reg)> {
        let open = s.rfind('(').ok_or_else(|| anyhow!("bad memory operand `{s}`"))?;
        let close = s.rfind(')').ok_or_else(|| anyhow!("bad memory operand `{s}`"))?;
        if close < open {
            bail!("bad memory operand `{s}`");
        }
        let reg = self.reg(s[open + 1..close].trim())?;
        let off_str = s[..open].trim();
        let off = if off_str.is_empty() { 0 } else { self.imm12(off_str)? };
        Ok((off, reg))
    }

    fn branch_target(&self, s: &str, pc: u32) -> Result<i32> {
        let target = self.value_or_symbol(s)?;
        let off = target - pc as i64;
        if !(-4096..4096).contains(&off) || off % 2 != 0 {
            bail!("branch target `{s}` out of range (offset {off})");
        }
        Ok(off as i32)
    }

    fn jump_target(&self, s: &str, pc: u32) -> Result<i32> {
        let target = self.value_or_symbol(s)?;
        let off = target - pc as i64;
        if !(-(1 << 20)..(1 << 20)).contains(&off) || off % 2 != 0 {
            bail!("jump target `{s}` out of range (offset {off})");
        }
        Ok(off as i32)
    }

    fn csr_addr(&self, s: &str) -> Result<u16> {
        use super::csr::*;
        Ok(match s {
            "mstatus" => MSTATUS,
            "mie" => MIE,
            "mtvec" => MTVEC,
            "mscratch" => MSCRATCH,
            "mepc" => MEPC,
            "mcause" => MCAUSE,
            "mtval" => MTVAL,
            "mip" => MIP,
            "mcycle" => MCYCLE,
            "minstret" => MINSTRET,
            "mcycleh" => MCYCLEH,
            "minstreth" => MINSTRETH,
            "mhartid" => MHARTID,
            other => {
                let v = self.imm_value(other)?;
                if !(0..4096).contains(&v) {
                    bail!("CSR address {v} out of range");
                }
                v as u16
            }
        })
    }

    // ---- instruction encoding --------------------------------------------

    fn encode_instr(&self, op: &str, args: &[String], pc: u32) -> Result<Vec<Instr>> {
        let a = |i: usize| -> Result<&str> {
            args.get(i).map(String::as_str).ok_or_else(|| anyhow!("missing operand {i}"))
        };
        let want = |n: usize| -> Result<()> {
            if args.len() != n {
                bail!("`{op}` wants {n} operands, got {}", args.len());
            }
            Ok(())
        };

        // R-type and I-type ALU tables
        let rr = |aop: AluOp| -> Result<Vec<Instr>> {
            want(3)?;
            Ok(vec![Instr::Op { op: aop, rd: self.reg(a(0)?)?, rs1: self.reg(a(1)?)?, rs2: self.reg(a(2)?)? }])
        };
        let ri = |aop: AluOp, shift: bool| -> Result<Vec<Instr>> {
            want(3)?;
            let imm = if shift {
                let v = self.imm_value(a(2)?)?;
                if !(0..32).contains(&v) {
                    bail!("shift amount {v} out of range");
                }
                v as i32
            } else {
                self.imm12(a(2)?)?
            };
            Ok(vec![Instr::OpImm { op: aop, rd: self.reg(a(0)?)?, rs1: self.reg(a(1)?)?, imm }])
        };
        let ld = |lop: LoadOp| -> Result<Vec<Instr>> {
            want(2)?;
            let (imm, rs1) = self.mem_operand(a(1)?)?;
            Ok(vec![Instr::Load { op: lop, rd: self.reg(a(0)?)?, rs1, imm }])
        };
        let st = |sop: StoreOp| -> Result<Vec<Instr>> {
            want(2)?;
            let (imm, rs1) = self.mem_operand(a(1)?)?;
            Ok(vec![Instr::Store { op: sop, rs1, rs2: self.reg(a(0)?)?, imm }])
        };
        let br = |bop: BranchOp| -> Result<Vec<Instr>> {
            want(3)?;
            Ok(vec![Instr::Branch {
                op: bop,
                rs1: self.reg(a(0)?)?,
                rs2: self.reg(a(1)?)?,
                imm: self.branch_target(a(2)?, pc)?,
            }])
        };
        let brz = |bop: BranchOp, swap: bool| -> Result<Vec<Instr>> {
            want(2)?;
            let r = self.reg(a(0)?)?;
            let (rs1, rs2) = if swap { (0, r) } else { (r, 0) };
            Ok(vec![Instr::Branch { op: bop, rs1, rs2, imm: self.branch_target(a(1)?, pc)? }])
        };

        match op {
            // ALU register-register
            "add" => rr(AluOp::Add),
            "sub" => rr(AluOp::Sub),
            "sll" => rr(AluOp::Sll),
            "slt" => rr(AluOp::Slt),
            "sltu" => rr(AluOp::Sltu),
            "xor" => rr(AluOp::Xor),
            "srl" => rr(AluOp::Srl),
            "sra" => rr(AluOp::Sra),
            "or" => rr(AluOp::Or),
            "and" => rr(AluOp::And),
            "mul" => rr(AluOp::Mul),
            "mulh" => rr(AluOp::Mulh),
            "mulhsu" => rr(AluOp::Mulhsu),
            "mulhu" => rr(AluOp::Mulhu),
            "div" => rr(AluOp::Div),
            "divu" => rr(AluOp::Divu),
            "rem" => rr(AluOp::Rem),
            "remu" => rr(AluOp::Remu),
            // ALU immediate
            "addi" => ri(AluOp::Add, false),
            "slti" => ri(AluOp::Slt, false),
            "sltiu" => ri(AluOp::Sltu, false),
            "xori" => ri(AluOp::Xor, false),
            "ori" => ri(AluOp::Or, false),
            "andi" => ri(AluOp::And, false),
            "slli" => ri(AluOp::Sll, true),
            "srli" => ri(AluOp::Srl, true),
            "srai" => ri(AluOp::Sra, true),
            // loads/stores
            "lb" => ld(LoadOp::Lb),
            "lh" => ld(LoadOp::Lh),
            "lw" => ld(LoadOp::Lw),
            "lbu" => ld(LoadOp::Lbu),
            "lhu" => ld(LoadOp::Lhu),
            "sb" => st(StoreOp::Sb),
            "sh" => st(StoreOp::Sh),
            "sw" => st(StoreOp::Sw),
            // branches
            "beq" => br(BranchOp::Eq),
            "bne" => br(BranchOp::Ne),
            "blt" => br(BranchOp::Lt),
            "bge" => br(BranchOp::Ge),
            "bltu" => br(BranchOp::Ltu),
            "bgeu" => br(BranchOp::Geu),
            "bgt" => {
                want(3)?;
                Ok(vec![Instr::Branch {
                    op: BranchOp::Lt,
                    rs1: self.reg(a(1)?)?,
                    rs2: self.reg(a(0)?)?,
                    imm: self.branch_target(a(2)?, pc)?,
                }])
            }
            "ble" => {
                want(3)?;
                Ok(vec![Instr::Branch {
                    op: BranchOp::Ge,
                    rs1: self.reg(a(1)?)?,
                    rs2: self.reg(a(0)?)?,
                    imm: self.branch_target(a(2)?, pc)?,
                }])
            }
            "beqz" => brz(BranchOp::Eq, false),
            "bnez" => brz(BranchOp::Ne, false),
            "bltz" => brz(BranchOp::Lt, false),
            "bgez" => brz(BranchOp::Ge, false),
            "bgtz" => brz(BranchOp::Lt, true),
            "blez" => brz(BranchOp::Ge, true),
            // jumps
            "jal" => match args.len() {
                1 => Ok(vec![Instr::Jal { rd: 1, imm: self.jump_target(a(0)?, pc)? }]),
                2 => Ok(vec![Instr::Jal { rd: self.reg(a(0)?)?, imm: self.jump_target(a(1)?, pc)? }]),
                n => bail!("`jal` wants 1 or 2 operands, got {n}"),
            },
            "jalr" => match args.len() {
                1 => Ok(vec![Instr::Jalr { rd: 1, rs1: self.reg(a(0)?)?, imm: 0 }]),
                3 => Ok(vec![Instr::Jalr {
                    rd: self.reg(a(0)?)?,
                    rs1: self.reg(a(1)?)?,
                    imm: self.imm12(a(2)?)?,
                }]),
                2 => {
                    let (imm, rs1) = self.mem_operand(a(1)?)?;
                    Ok(vec![Instr::Jalr { rd: self.reg(a(0)?)?, rs1, imm }])
                }
                n => bail!("`jalr` wants 1-3 operands, got {n}"),
            },
            "j" => {
                want(1)?;
                Ok(vec![Instr::Jal { rd: 0, imm: self.jump_target(a(0)?, pc)? }])
            }
            "jr" => {
                want(1)?;
                Ok(vec![Instr::Jalr { rd: 0, rs1: self.reg(a(0)?)?, imm: 0 }])
            }
            "ret" => {
                want(0)?;
                Ok(vec![Instr::Jalr { rd: 0, rs1: 1, imm: 0 }])
            }
            "call" => {
                // auipc ra, %hi(off) ; jalr ra, ra, %lo(off) — fixed 2-word
                want(1)?;
                let target = self.value_or_symbol(a(0)?)?;
                let off = (target - pc as i64) as i32;
                let hi = ((off as u32).wrapping_add(0x800) & 0xFFFF_F000) as i32;
                let lo = off.wrapping_sub(hi);
                Ok(vec![
                    Instr::Auipc { rd: 1, imm: hi },
                    Instr::Jalr { rd: 1, rs1: 1, imm: lo },
                ])
            }
            // upper immediates
            "lui" => {
                want(2)?;
                let v = self.value_or_symbol(a(1)?)?;
                // accept either a raw 20-bit page number or a %hi() value
                let imm = if a(1)?.starts_with("%hi(") {
                    ((v as u32) << 12) as i32
                } else {
                    if !(0..(1 << 20)).contains(&v) {
                        bail!("lui immediate {v} out of 20-bit range");
                    }
                    ((v as u32) << 12) as i32
                };
                Ok(vec![Instr::Lui { rd: self.reg(a(0)?)?, imm }])
            }
            "auipc" => {
                want(2)?;
                let v = self.imm_value(a(1)?)?;
                Ok(vec![Instr::Auipc { rd: self.reg(a(0)?)?, imm: ((v as u32) << 12) as i32 }])
            }
            // pseudo
            "nop" => {
                want(0)?;
                Ok(vec![Instr::OpImm { op: AluOp::Add, rd: 0, rs1: 0, imm: 0 }])
            }
            "li" => {
                want(2)?;
                let rd = self.reg(a(0)?)?;
                let v64 = self.imm_value(a(1)?)?;
                if !(-(1i64 << 31)..(1i64 << 32)).contains(&v64) {
                    bail!("li immediate {v64} out of 32-bit range");
                }
                let v = v64 as u32 as i32;
                if (-2048..2048).contains(&(v as i64)) {
                    Ok(vec![Instr::OpImm { op: AluOp::Add, rd, rs1: 0, imm: v }])
                } else {
                    let hi = ((v as u32).wrapping_add(0x800) & 0xFFFF_F000) as i32;
                    let lo = v.wrapping_sub(hi);
                    Ok(vec![
                        Instr::Lui { rd, imm: hi },
                        Instr::OpImm { op: AluOp::Add, rd, rs1: rd, imm: lo },
                    ])
                }
            }
            "la" => {
                want(2)?;
                let rd = self.reg(a(0)?)?;
                let v = self.value_or_symbol(a(1)?)? as u32 as i32;
                let hi = ((v as u32).wrapping_add(0x800) & 0xFFFF_F000) as i32;
                let lo = v.wrapping_sub(hi);
                Ok(vec![
                    Instr::Lui { rd, imm: hi },
                    Instr::OpImm { op: AluOp::Add, rd, rs1: rd, imm: lo },
                ])
            }
            "mv" => {
                want(2)?;
                Ok(vec![Instr::OpImm {
                    op: AluOp::Add,
                    rd: self.reg(a(0)?)?,
                    rs1: self.reg(a(1)?)?,
                    imm: 0,
                }])
            }
            "not" => {
                want(2)?;
                Ok(vec![Instr::OpImm {
                    op: AluOp::Xor,
                    rd: self.reg(a(0)?)?,
                    rs1: self.reg(a(1)?)?,
                    imm: -1,
                }])
            }
            "neg" => {
                want(2)?;
                Ok(vec![Instr::Op {
                    op: AluOp::Sub,
                    rd: self.reg(a(0)?)?,
                    rs1: 0,
                    rs2: self.reg(a(1)?)?,
                }])
            }
            "seqz" => {
                want(2)?;
                Ok(vec![Instr::OpImm {
                    op: AluOp::Sltu,
                    rd: self.reg(a(0)?)?,
                    rs1: self.reg(a(1)?)?,
                    imm: 1,
                }])
            }
            "snez" => {
                want(2)?;
                Ok(vec![Instr::Op {
                    op: AluOp::Sltu,
                    rd: self.reg(a(0)?)?,
                    rs1: 0,
                    rs2: self.reg(a(1)?)?,
                }])
            }
            // system
            "ecall" => {
                want(0)?;
                Ok(vec![Instr::Ecall])
            }
            "ebreak" => {
                want(0)?;
                Ok(vec![Instr::Ebreak])
            }
            "wfi" => {
                want(0)?;
                Ok(vec![Instr::Wfi])
            }
            "mret" => {
                want(0)?;
                Ok(vec![Instr::Mret])
            }
            "fence" | "fence.i" => Ok(vec![Instr::Fence]),
            // CSRs
            "csrrw" | "csrrs" | "csrrc" => {
                want(3)?;
                let cop = match op {
                    "csrrw" => CsrOp::Rw,
                    "csrrs" => CsrOp::Rs,
                    _ => CsrOp::Rc,
                };
                Ok(vec![Instr::Csr {
                    op: cop,
                    rd: self.reg(a(0)?)?,
                    rs1: self.reg(a(2)?)?,
                    csr: self.csr_addr(a(1)?)?,
                    imm: false,
                }])
            }
            "csrrwi" | "csrrsi" | "csrrci" => {
                want(3)?;
                let cop = match op {
                    "csrrwi" => CsrOp::Rw,
                    "csrrsi" => CsrOp::Rs,
                    _ => CsrOp::Rc,
                };
                let z = self.imm_value(a(2)?)?;
                if !(0..32).contains(&z) {
                    bail!("zimm {z} out of range");
                }
                Ok(vec![Instr::Csr {
                    op: cop,
                    rd: self.reg(a(0)?)?,
                    rs1: z as u8,
                    csr: self.csr_addr(a(1)?)?,
                    imm: true,
                }])
            }
            "csrr" => {
                want(2)?;
                Ok(vec![Instr::Csr {
                    op: CsrOp::Rs,
                    rd: self.reg(a(0)?)?,
                    rs1: 0,
                    csr: self.csr_addr(a(1)?)?,
                    imm: false,
                }])
            }
            "csrw" => {
                want(2)?;
                Ok(vec![Instr::Csr {
                    op: CsrOp::Rw,
                    rd: 0,
                    rs1: self.reg(a(1)?)?,
                    csr: self.csr_addr(a(0)?)?,
                    imm: false,
                }])
            }
            "csrsi" => {
                want(2)?;
                let z = self.imm_value(a(1)?)?;
                Ok(vec![Instr::Csr {
                    op: CsrOp::Rs,
                    rd: 0,
                    rs1: z as u8,
                    csr: self.csr_addr(a(0)?)?,
                    imm: true,
                }])
            }
            "csrci" => {
                want(2)?;
                let z = self.imm_value(a(1)?)?;
                Ok(vec![Instr::Csr {
                    op: CsrOp::Rc,
                    rd: 0,
                    rs1: z as u8,
                    csr: self.csr_addr(a(0)?)?,
                    imm: true,
                }])
            }
            other => bail!("unknown instruction `{other}`"),
        }
    }
}

fn parse_int(s: &str) -> Option<i64> {
    let s = s.trim();
    let (neg, s) = match s.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, s),
    };
    let v = if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        i64::from_str_radix(&hex.replace('_', ""), 16).ok()?
    } else if let Some(bin) = s.strip_prefix("0b").or_else(|| s.strip_prefix("0B")) {
        i64::from_str_radix(&bin.replace('_', ""), 2).ok()?
    } else {
        s.replace('_', "").parse::<i64>().ok()?
    };
    Some(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::super::decode;
    use super::*;

    #[test]
    fn assemble_simple_loop() {
        let p = assemble(
            r#"
            _start:
                li   t0, 10        # counter
                li   t1, 0         # acc
            loop:
                add  t1, t1, t0
                addi t0, t0, -1
                bnez t0, loop
                ebreak
            "#,
        )
        .unwrap();
        assert_eq!(p.entry, 0);
        assert_eq!(p.text.len(), 6);
        // all words decode
        for w in &p.text {
            assert!(decode(*w).is_some(), "word {w:#x}");
        }
        assert_eq!(p.symbol("loop").unwrap(), 8);
    }

    #[test]
    fn li_expansion_sizes() {
        let p = assemble("li a0, 100").unwrap();
        assert_eq!(p.text.len(), 1);
        let p = assemble("li a0, 0x12345678").unwrap();
        assert_eq!(p.text.len(), 2);
        // li of value with bit 11 set needs the +0x800 correction
        let p = assemble("li a0, 0x8800").unwrap();
        assert_eq!(p.text.len(), 2);
        assert_eq!(
            decode(p.text[0]),
            Some(Instr::Lui { rd: 10, imm: 0x9000u32 as i32 })
        );
        assert_eq!(
            decode(p.text[1]),
            Some(Instr::OpImm { op: AluOp::Add, rd: 10, rs1: 10, imm: -0x800 })
        );
    }

    #[test]
    fn data_section_and_symbols() {
        let p = assemble(
            r#"
            .data
            tbl:    .word 1, 2, 3
            msg:    .byte 0x41, 0x42
                    .align 2
            buf:    .space 16
            .text
            _start: la a0, tbl
                    lw a1, 0(a0)
                    ebreak
            "#,
        )
        .unwrap();
        assert_eq!(p.symbol("tbl").unwrap(), 0x0002_0000);
        assert_eq!(p.symbol("msg").unwrap(), 0x0002_000C);
        assert_eq!(p.symbol("buf").unwrap(), 0x0002_0010);
        assert_eq!(p.data.len(), 0x20);
        assert_eq!(&p.data[0..4], &[1, 0, 0, 0]);
        assert_eq!(p.data[12], 0x41);
    }

    #[test]
    fn word_can_hold_label_address() {
        let p = assemble(
            r#"
            .data
            a:  .word 7
            ptr:.word a
            .text
            nop
            "#,
        )
        .unwrap();
        let ptr_bytes = &p.data[4..8];
        assert_eq!(u32::from_le_bytes(ptr_bytes.try_into().unwrap()), 0x0002_0000);
    }

    #[test]
    fn equ_constants() {
        let p = assemble(
            r#"
            .equ UART_BASE, 0x20000000
            .equ N, 16
            li a0, UART_BASE
            li a1, N
            "#,
        )
        .unwrap();
        assert_eq!(p.text.len(), 3); // 2 for the big one, 1 small
    }

    #[test]
    fn branches_backward_and_forward() {
        let p = assemble(
            r#"
            _start:
                beqz a0, end
            mid:
                addi a0, a0, -1
                bnez a0, mid
            end:
                ebreak
            "#,
        )
        .unwrap();
        // beqz forward: target 12, pc 0 -> +12
        match decode(p.text[0]).unwrap() {
            Instr::Branch { imm, .. } => assert_eq!(imm, 12),
            other => panic!("{other:?}"),
        }
        // bnez backward: target 4, pc 8 -> -4
        match decode(p.text[2]).unwrap() {
            Instr::Branch { imm, .. } => assert_eq!(imm, -4),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn csr_names() {
        let p = assemble("csrr t0, mcycle\ncsrw mtvec, t1\ncsrrsi t2, mstatus, 8").unwrap();
        match decode(p.text[0]).unwrap() {
            Instr::Csr { csr, .. } => assert_eq!(csr, 0xB00),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = assemble("nop\nbogus x1, x2\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        let err = assemble("lw a0, 99999(a1)").unwrap_err();
        assert!(format!("{err:#}").contains("12-bit"), "{err:#}");
        let err = assemble("dup:\ndup:").unwrap_err();
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
    }

    #[test]
    fn hi_lo_relocations() {
        let p = assemble(
            r#"
            .data
            var: .word 0
            .text
            lui  a0, %hi(var)
            lw   a1, %lo(var)(a0)
            sw   a1, %lo(var)(a0)
            "#,
        )
        .unwrap();
        // var = 0x20000: hi=0x20, lo=0
        assert_eq!(decode(p.text[0]), Some(Instr::Lui { rd: 10, imm: 0x0002_0000 }));
        assert_eq!(
            decode(p.text[1]),
            Some(Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 10, imm: 0 })
        );
    }

    #[test]
    fn call_and_ret() {
        let p = assemble(
            r#"
            _start:
                call func
                ebreak
            func:
                ret
            "#,
        )
        .unwrap();
        assert_eq!(p.text.len(), 4);
        assert_eq!(decode(p.text[3]), Some(Instr::Jalr { rd: 0, rs1: 1, imm: 0 }));
    }
}
