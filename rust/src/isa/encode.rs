//! RV32IM instruction encoder — the exact inverse of [`super::decode`]
//! over the supported subset (property-tested in `rust/tests/prop_isa.rs`).
//!
//! Used by the assembler and by tests that need known-good words.

use super::{AluOp, BranchOp, CsrOp, Instr, LoadOp, StoreOp};

fn enc_r(funct7: u32, rs2: u8, rs1: u8, funct3: u32, rd: u8, opcode: u32) -> u32 {
    (funct7 << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (funct3 << 12)
        | ((rd as u32) << 7)
        | opcode
}

fn enc_i(imm: i32, rs1: u8, funct3: u32, rd: u8, opcode: u32) -> u32 {
    (((imm as u32) & 0xFFF) << 20)
        | ((rs1 as u32) << 15)
        | (funct3 << 12)
        | ((rd as u32) << 7)
        | opcode
}

fn enc_s(imm: i32, rs2: u8, rs1: u8, funct3: u32, opcode: u32) -> u32 {
    let imm = imm as u32;
    (((imm >> 5) & 0x7F) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (funct3 << 12)
        | ((imm & 0x1F) << 7)
        | opcode
}

fn enc_b(imm: i32, rs2: u8, rs1: u8, funct3: u32, opcode: u32) -> u32 {
    let imm = imm as u32;
    (((imm >> 12) & 1) << 31)
        | (((imm >> 5) & 0x3F) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (funct3 << 12)
        | (((imm >> 1) & 0xF) << 8)
        | (((imm >> 11) & 1) << 7)
        | opcode
}

fn enc_u(imm: i32, rd: u8, opcode: u32) -> u32 {
    ((imm as u32) & 0xFFFF_F000) | ((rd as u32) << 7) | opcode
}

fn enc_j(imm: i32, rd: u8, opcode: u32) -> u32 {
    let imm = imm as u32;
    (((imm >> 20) & 1) << 31)
        | (((imm >> 1) & 0x3FF) << 21)
        | (((imm >> 11) & 1) << 20)
        | (((imm >> 12) & 0xFF) << 12)
        | ((rd as u32) << 7)
        | opcode
}

fn alu_funct(op: AluOp) -> (u32, u32) {
    // (funct7, funct3) for the R-type form
    match op {
        AluOp::Add => (0b0000000, 0b000),
        AluOp::Sub => (0b0100000, 0b000),
        AluOp::Sll => (0b0000000, 0b001),
        AluOp::Slt => (0b0000000, 0b010),
        AluOp::Sltu => (0b0000000, 0b011),
        AluOp::Xor => (0b0000000, 0b100),
        AluOp::Srl => (0b0000000, 0b101),
        AluOp::Sra => (0b0100000, 0b101),
        AluOp::Or => (0b0000000, 0b110),
        AluOp::And => (0b0000000, 0b111),
        AluOp::Mul => (0b0000001, 0b000),
        AluOp::Mulh => (0b0000001, 0b001),
        AluOp::Mulhsu => (0b0000001, 0b010),
        AluOp::Mulhu => (0b0000001, 0b011),
        AluOp::Div => (0b0000001, 0b100),
        AluOp::Divu => (0b0000001, 0b101),
        AluOp::Rem => (0b0000001, 0b110),
        AluOp::Remu => (0b0000001, 0b111),
    }
}

/// Encode an instruction to its 32-bit word.
///
/// Panics on forms the ISA cannot represent (e.g. `OpImm` with `Sub`,
/// branch offsets out of range) — the assembler validates ranges first and
/// reports source-level errors; encode-level panics indicate internal bugs.
pub fn encode(instr: Instr) -> u32 {
    match instr {
        Instr::Lui { rd, imm } => enc_u(imm, rd, 0b0110111),
        Instr::Auipc { rd, imm } => enc_u(imm, rd, 0b0010111),
        Instr::Jal { rd, imm } => {
            assert!((-(1 << 20)..(1 << 20)).contains(&imm) && imm % 2 == 0, "jal imm {imm}");
            enc_j(imm, rd, 0b1101111)
        }
        Instr::Jalr { rd, rs1, imm } => {
            assert!((-2048..2048).contains(&imm), "jalr imm {imm}");
            enc_i(imm, rs1, 0, rd, 0b1100111)
        }
        Instr::Branch { op, rs1, rs2, imm } => {
            assert!((-4096..4096).contains(&imm) && imm % 2 == 0, "branch imm {imm}");
            let funct3 = match op {
                BranchOp::Eq => 0b000,
                BranchOp::Ne => 0b001,
                BranchOp::Lt => 0b100,
                BranchOp::Ge => 0b101,
                BranchOp::Ltu => 0b110,
                BranchOp::Geu => 0b111,
            };
            enc_b(imm, rs2, rs1, funct3, 0b1100011)
        }
        Instr::Load { op, rd, rs1, imm } => {
            assert!((-2048..2048).contains(&imm), "load imm {imm}");
            let funct3 = match op {
                LoadOp::Lb => 0b000,
                LoadOp::Lh => 0b001,
                LoadOp::Lw => 0b010,
                LoadOp::Lbu => 0b100,
                LoadOp::Lhu => 0b101,
            };
            enc_i(imm, rs1, funct3, rd, 0b0000011)
        }
        Instr::Store { op, rs1, rs2, imm } => {
            assert!((-2048..2048).contains(&imm), "store imm {imm}");
            let funct3 = match op {
                StoreOp::Sb => 0b000,
                StoreOp::Sh => 0b001,
                StoreOp::Sw => 0b010,
            };
            enc_s(imm, rs2, rs1, funct3, 0b0100011)
        }
        Instr::OpImm { op, rd, rs1, imm } => match op {
            AluOp::Sll | AluOp::Srl | AluOp::Sra => {
                assert!((0..32).contains(&imm), "shamt {imm}");
                let (funct7, funct3) = alu_funct(op);
                enc_r(funct7, imm as u8, rs1, funct3, rd, 0b0010011)
            }
            AluOp::Add | AluOp::Slt | AluOp::Sltu | AluOp::Xor | AluOp::Or | AluOp::And => {
                assert!((-2048..2048).contains(&imm), "opimm imm {imm}");
                let (_, funct3) = alu_funct(op);
                enc_i(imm, rs1, funct3, rd, 0b0010011)
            }
            other => panic!("no immediate form for {other:?}"),
        },
        Instr::Op { op, rd, rs1, rs2 } => {
            let (funct7, funct3) = alu_funct(op);
            enc_r(funct7, rs2, rs1, funct3, rd, 0b0110011)
        }
        Instr::Fence => 0x0000_000F,
        Instr::Ecall => 0x0000_0073,
        Instr::Ebreak => 0x0010_0073,
        Instr::Wfi => 0x1050_0073,
        Instr::Mret => 0x3020_0073,
        Instr::Csr { op, rd, rs1, csr, imm } => {
            let base = match op {
                CsrOp::Rw => 0b001,
                CsrOp::Rs => 0b010,
                CsrOp::Rc => 0b011,
            };
            let funct3 = if imm { base | 0b100 } else { base };
            enc_i(csr as i32, rs1, funct3, rd, 0b1110011)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::decode;
    use super::*;

    #[test]
    fn encode_matches_known_words() {
        assert_eq!(encode(Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 0, imm: 42 }), 0x02A0_0093);
        assert_eq!(encode(Instr::Op { op: AluOp::Mul, rd: 5, rs1: 6, rs2: 7 }), 0x0273_02B3);
        assert_eq!(encode(Instr::Load { op: LoadOp::Lw, rd: 8, rs1: 2, imm: -4 }), 0xFFC1_2403);
        assert_eq!(encode(Instr::Jal { rd: 1, imm: 16 }), 0x0100_00EF);
        assert_eq!(encode(Instr::Wfi), 0x1050_0073);
    }

    #[test]
    fn roundtrip_spot_checks() {
        let cases = [
            Instr::Lui { rd: 31, imm: -4096 },
            Instr::Auipc { rd: 0, imm: 0x7FFF_F000 },
            Instr::Jal { rd: 1, imm: -1048576 },
            Instr::Jalr { rd: 2, rs1: 3, imm: -2048 },
            Instr::Branch { op: BranchOp::Geu, rs1: 30, rs2: 31, imm: 4094 },
            Instr::Branch { op: BranchOp::Lt, rs1: 1, rs2: 2, imm: -4096 },
            Instr::Store { op: StoreOp::Sb, rs1: 7, rs2: 8, imm: 2047 },
            Instr::OpImm { op: AluOp::Sra, rd: 9, rs1: 10, imm: 31 },
            Instr::OpImm { op: AluOp::Sltu, rd: 11, rs1: 12, imm: -1 },
            Instr::Op { op: AluOp::Remu, rd: 13, rs1: 14, rs2: 15 },
            Instr::Csr { op: CsrOp::Rc, rd: 16, rs1: 17, csr: 0xB00, imm: true },
            Instr::Fence,
            Instr::Mret,
        ];
        for c in cases {
            assert_eq!(decode(encode(c)), Some(c), "{c:?}");
        }
    }

    #[test]
    #[should_panic]
    fn rejects_sub_immediate() {
        encode(Instr::OpImm { op: AluOp::Sub, rd: 1, rs1: 1, imm: 1 });
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_branch() {
        encode(Instr::Branch { op: BranchOp::Eq, rs1: 0, rs2: 0, imm: 5000 });
    }
}
